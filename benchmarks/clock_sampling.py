"""Paper Table I: OFU error vs clock scrape interval.

1 s baseline over 3000 s of sustained matmul at three steady sizes plus an
alternating workload; subsample at 5/10/20/30 s and report σ and the 95%
CI of the OFU deviation (in percentage points).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, timed
from repro.core.peaks import TPU_V5E
from repro.telemetry.counters import Event, SimulatedDeviceBackend, StepProfile
from repro.telemetry.scrape import scrape

DURATION_S = 3000.0
INTERVALS = (5, 10, 20, 30)


def _workloads():
    out = {}
    for n in (4096, 8192, 16384):
        # larger matmuls sustain higher duty
        duty = {4096: 0.50, 8192: 0.55, 16384: 0.58}[n]
        out[f"N={n}"] = SimulatedDeviceBackend(
            StepProfile(mxu_time_s=duty * 1.2, step_time_s=1.2),
            seed=n)
    # alternating 16384 <-> 4096 every 10 s
    events = [Event(start_s=t, end_s=t + 10, slowdown=1.18)
              for t in range(10, int(DURATION_S), 20)]
    out["Alt"] = SimulatedDeviceBackend(
        StepProfile(mxu_time_s=0.58 * 1.2, step_time_s=1.2),
        events=events, seed=7)
    return out


def run() -> list[Row]:
    rows = []
    for name, be in _workloads().items():
        (base,), us = timed(lambda: (scrape(be, DURATION_S, 1.0),), repeat=1)
        ofu1 = base.tpa * base.clock_mhz / TPU_V5E.f_max_mhz
        for iv in INTERVALS:
            sub = base.subsample(iv)
            ofu_iv = sub.tpa * sub.clock_mhz / TPU_V5E.f_max_mhz
            # windowed deviation: compare window means at matching coverage
            n = min(len(ofu_iv), len(ofu1) // iv)
            dev = []
            for w in range(0, n, max(1, n // 20)):
                a = ofu_iv[w:w + n // 20 or 1].mean()
                b = ofu1[w * iv:(w + (n // 20 or 1)) * iv].mean()
                dev.append((a - b) * 100)
            dev = np.array(dev)
            ci95 = 1.96 * dev.std() / np.sqrt(max(len(dev), 1))
            rows.append(Row(
                f"table1.{name}.interval={iv}s", us / len(ofu1),
                f"sigma={dev.std():.3f}pp ci95=+-{abs(ci95):.3f}pp"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
