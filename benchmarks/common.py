"""Shared benchmark plumbing: each benchmark module exposes run() -> rows,
where a row is (name, us_per_call, derived) — us_per_call times the core
operation, derived carries the paper-comparable numbers.

Suites that publish machine-readable results share `BENCH_fleet.json`
(one file, merged BY CASE NAME so whichever suite runs second never
clobbers the other's rows): record cases with `bench_case` and flush
with `merge_bench_json`."""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass


@dataclass
class Row:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.2f},{self.derived}"


def timed(fn, *args, repeat: int = 3, **kw):
    """(result, us_per_call) for the fastest of `repeat` calls."""
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best * 1e6


def bench_case(cases: list, name: str, median: float, units: str,
               **metrics) -> None:
    """Record one benchmark case: print the BENCH json line (the driver
    greps for it) and append the structured row to `cases` for
    `merge_bench_json`."""
    print("BENCH " + json.dumps({"name": name, **metrics}))
    cases.append({"name": name, "median": median, "units": units,
                  "metrics": metrics})


def merge_bench_json(cases: list, *, suite: str = "fleet_engine") -> str:
    """Merge `cases` into BENCH_fleet.json BY NAME (path overridable via
    the BENCH_FLEET_JSON env var).  Several suites share the file —
    fleet_engine, the scenario scorecard, production_correlation — and
    whichever runs second must not clobber the others' rows."""
    path = os.environ.get("BENCH_FLEET_JSON", "BENCH_fleet.json")
    doc = {"schema": 1, "suite": suite, "cases": []}
    if os.path.exists(path):
        try:
            with open(path) as f:
                prev = json.load(f)
            if isinstance(prev.get("cases"), list):
                doc = prev
        except (json.JSONDecodeError, OSError):
            pass                 # corrupt file: rewrite from scratch
    fresh = {c["name"] for c in cases}
    doc["cases"] = [c for c in doc["cases"]
                    if c.get("name") not in fresh] + cases
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    return path
