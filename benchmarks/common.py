"""Shared benchmark plumbing: each benchmark module exposes run() -> rows,
where a row is (name, us_per_call, derived) — us_per_call times the core
operation, derived carries the paper-comparable numbers."""
from __future__ import annotations

import time
from dataclasses import dataclass


@dataclass
class Row:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.2f},{self.derived}"


def timed(fn, *args, repeat: int = 3, **kw):
    """(result, us_per_call) for the fastest of `repeat` calls."""
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best * 1e6
