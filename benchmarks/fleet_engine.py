"""Fleet-engine throughput: vectorized vs per-device scalar simulation,
and the fused multi-job grid vs the per-job engine loop.

Metric is simulated device-seconds per wall-second — how much fleet
telemetry one CPU core can synthesize in real time.  The scalar reference
is timed on a small slice (it is the thing being replaced); the vectorized
engine is then timed head-to-head on the same slice AND at the paper's
operating point (1,000 devices x 1 hour at 30 s scrapes).  The fused case
runs a 600-job / ~10k-device sweep through `simulate_fleet` both ways
(per-job loop vs one padded multi-job grid).  The collector case measures
the continuous-monitoring loop's per-round overhead (scrape -> windowed
ingest -> regression/divergence detect) for a 64-job fleet.  Emits BENCH
json lines with the headline numbers for the driver.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import Row, timed
from repro.fleet.collector import Collector, CollectorConfig, JobStream
from repro.fleet.engine import simulate_devices
from repro.fleet.jobs import JobSpec, simulate_fleet
from repro.fleet.streaming import StreamingRollup
from repro.telemetry.counters import (Event, SimulatedDeviceBackend,
                                      StepProfile)
from repro.telemetry.scrape import scrape
from repro.telemetry.source import SimulatorSource

PROFILE = StepProfile(mxu_time_s=0.84, step_time_s=2.0)
EVENTS = [Event(start_s=600, end_s=1200, slowdown=2.5)]
INTERVAL_S = 30.0


def _sweep_specs(n_jobs: int = 600, max_devices: int = 17):
    """The §V-B-scale sweep: 600 jobs, ~10k sampled devices, ragged
    durations, a few evented/straggling jobs."""
    return [JobSpec(f"sweep-{i}", "granite-3-2b", chips=max_devices,
                    true_duty=0.2 + 0.03 * (i % 8),
                    duration_s=600.0 + 150.0 * (i % 4),
                    scrape_interval_s=INTERVAL_S, seed=i,
                    events=[Event(300, 600, slowdown=2.5)] if i % 50 == 0
                    else (),
                    straggler_sigma=0.15 if i % 25 == 0 else 0.0)
            for i in range(n_jobs)]


def _scalar(n_dev: int, duration_s: float) -> None:
    rng = np.random.default_rng(0)
    for _ in range(n_dev):
        be = SimulatedDeviceBackend(PROFILE, events=EVENTS,
                                    seed=int(rng.integers(0, 2 ** 31)))
        scrape(be, duration_s, INTERVAL_S)


def _vector(n_dev: int, duration_s: float) -> None:
    simulate_devices(PROFILE, duration_s=duration_s, interval_s=INTERVAL_S,
                     events=EVENTS, n_devices=n_dev, seed=0)


def run_jax(rows: list[Row] | None = None) -> list[Row]:
    """jax engine backend + device-side rollup ingest (ISSUE 6).

    Defaults to 100k devices x 1 hour of 30 s scrapes; the paper-scale
    1M x 24 h point is the same code one env knob away
    (FLEET_JAX_DEVICES=1000000 FLEET_JAX_HOURS=24 — practical only with
    real accelerators and a device mesh, ~11 GB per f32 grid).  Reports
    the jax engine head-to-head with the fused-NumPy engine on the SAME
    operating point, plus all three rollup-ingest paths: the pallas
    histogram-accumulate kernel (interpret mode off-TPU), its XLA
    fallback, and the host-side NumPy bucketize.
    """
    rows = [] if rows is None else rows
    try:
        import jax
        from repro.fleet.engine_jax import simulate_jobs_jax
        from repro.kernels.fleet_hist import _interpret, ofu_bucket_hist
    except Exception as e:  # pragma: no cover — env without jax
        print(f"BENCH-SKIP fleet_engine_jax ({type(e).__name__}: {e})")
        return rows
    from repro.fleet.engine import JobSlot, simulate_jobs_fused

    n_dev = int(os.environ.get("FLEET_JAX_DEVICES", "100000"))
    hours = float(os.environ.get("FLEET_JAX_HOURS", "1"))
    dur = hours * 3600.0
    devsec = n_dev * dur
    repeat = 1 if n_dev >= 50_000 else 3
    slot = JobSlot(PROFILE, dur, INTERVAL_S, events=EVENTS,
                   stragglers=np.ones(n_dev))

    def _sim():
        (g,) = simulate_jobs_jax([slot], seed=0)
        jax.block_until_ready((g.tpa, g.clock_mhz))
        return g

    g = _sim()                              # compile off the clock
    g, us_jax = timed(_sim, repeat=repeat)
    (gn,), us_np = timed(
        lambda: simulate_jobs_fused([slot], seed=0), repeat=repeat)
    thr_jax = devsec / (us_jax / 1e6)
    label = f"fleet_engine.jax_{n_dev}dev_{hours:g}h"
    rows.append(Row(label, us_jax,
                    f"device_seconds_per_wall_s={thr_jax:.0f} "
                    f"numpy_wall_s={us_np / 1e6:.2f}"))

    # rollup ingest over the device grid: pallas vs XLA vs host NumPy.
    # The kernels get identical inputs (same grid, same aligned bucket
    # map the StreamingRollup routing would derive).
    bucket_s = 300.0
    S = int(g.tpa.shape[1])
    n_cells = n_dev * S
    spb = max(int(round(bucket_s / INTERVAL_S)), 1)
    col = np.arange(S) // spb
    roll = StreamingRollup(bucket_s=bucket_s)
    kw = dict(inv_fmax=1.0 / slot.chip.f_max_mhz, edges=roll.edges,
              col_bucket=col, n_buckets=int(col[-1]) + 1 if S else 0)

    def _kernel(use_pallas):
        out = ofu_bucket_hist(g.tpa, g.clock_mhz, use_pallas=use_pallas,
                              **kw)
        jax.block_until_ready(out)
        return out

    _kernel(True), _kernel(False)           # compile off the clock
    (h_pl, _), us_pl = timed(_kernel, True, repeat=repeat)
    (h_xla, _), us_xla = timed(_kernel, False, repeat=repeat)

    def _dev_ingest():                      # full add_grid device route
        r = StreamingRollup(bucket_s=bucket_s)
        r.add_grid("j", g, chips=n_dev)
        return r

    def _host_ingest():                     # fused-NumPy baseline
        r = StreamingRollup(bucket_s=bucket_s)
        r.add_grid("j", gn, chips=n_dev)
        return r

    r_dev, us_dev = timed(_dev_ingest, repeat=repeat)
    r_host, us_host = timed(_host_ingest, repeat=repeat)
    interp = _interpret()
    rows.append(Row("fleet_engine.jax_ingest_pallas", us_pl,
                    f"samples_per_s={n_cells / (us_pl / 1e6):.0f} "
                    f"interpret={int(interp)}"))
    rows.append(Row("fleet_engine.jax_ingest_xla", us_xla,
                    f"samples_per_s={n_cells / (us_xla / 1e6):.0f}"))
    rows.append(Row("fleet_engine.jax_ingest_host_numpy", us_host,
                    f"samples_per_s={n_cells / (us_host / 1e6):.0f}"))

    # cross-backend sanity on the spot the driver reads: the two ingest
    # kernels agree bitwise, and the engines agree statistically
    assert np.array_equal(np.asarray(h_pl), np.asarray(h_xla))
    ofu_jax = float(r_dev.fleet_stats(qs=()).mean[0])
    ofu_np = float(r_host.fleet_stats(qs=()).mean[0])

    print("BENCH " + json.dumps({
        "name": "fleet_engine_jax",
        "devices": n_dev,
        "hours": hours,
        "jax_wall_s": round(us_jax / 1e6, 3),
        "numpy_wall_s": round(us_np / 1e6, 3),
        "jax_devsec_per_s": round(thr_jax),
        "pallas_interpret": interp,
        "ingest_pallas_samples_per_s": round(n_cells / (us_pl / 1e6)),
        "ingest_xla_samples_per_s": round(n_cells / (us_xla / 1e6)),
        "ingest_numpy_samples_per_s": round(n_cells / (us_host / 1e6)),
        "ingest_device_route_wall_s": round(us_dev / 1e6, 3),
        "first_bucket_ofu_jax": round(ofu_jax, 4),
        "first_bucket_ofu_numpy": round(ofu_np, 4),
    }))
    return rows


def run() -> list[Row]:
    rows = []
    # -- head-to-head on the same slice (16 devices x 30 min) -------------
    n_dev, dur = 16, 1800.0
    devsec = n_dev * dur
    _, us_scalar = timed(_scalar, n_dev, dur, repeat=2)
    _, us_vector = timed(_vector, n_dev, dur, repeat=3)
    thr_scalar = devsec / (us_scalar / 1e6)
    thr_vector = devsec / (us_vector / 1e6)
    speedup = us_scalar / us_vector
    rows.append(Row("fleet_engine.scalar_16dev_30min", us_scalar,
                    f"device_seconds_per_wall_s={thr_scalar:.0f}"))
    rows.append(Row("fleet_engine.vector_16dev_30min", us_vector,
                    f"device_seconds_per_wall_s={thr_vector:.0f} "
                    f"speedup={speedup:.1f}x"))

    # -- the acceptance operating point: 1000 devices x 1 hour ------------
    spec = JobSpec("bench-fleet", "granite-3-2b", chips=1000,
                   true_duty=0.35, duration_s=3600.0,
                   scrape_interval_s=INTERVAL_S, seed=0)
    t0 = time.perf_counter()
    (tel,) = simulate_fleet([spec], max_devices=1000)
    roll = StreamingRollup(bucket_s=300)
    roll.add_job(tel)
    wall_s = time.perf_counter() - t0
    devsec_full = 1000 * 3600.0
    thr_full = devsec_full / wall_s
    rows.append(Row("fleet_engine.vector_1000dev_1h_rollup", wall_s * 1e6,
                    f"device_seconds_per_wall_s={thr_full:.0f} "
                    f"wall_s={wall_s:.2f} ofu={tel.ofu * 100:.1f}% "
                    f"buckets={roll.n_buckets}"))

    print("BENCH " + json.dumps({
        "name": "fleet_engine",
        "scalar_devsec_per_s": round(thr_scalar),
        "vector_devsec_per_s": round(thr_vector),
        "speedup_x": round(speedup, 1),
        "fleet_1000dev_1h_wall_s": round(wall_s, 3),
        "fleet_devsec_per_s": round(thr_full),
    }))

    # -- fused multi-job grid: 600 jobs / ~10k devices, one padded pass ----
    # interleaved (per-job, fused) pairs + median pair ratio, so machine
    # load drift hits both sides of the comparison equally
    max_dev = 17
    specs = _sweep_specs(600, max_dev)
    devsec_sweep = sum(min(s.chips, max_dev) * s.duration_s for s in specs)
    tels = simulate_fleet(specs, max_devices=max_dev)        # warm caches
    pairs = []
    for _ in range(5):
        t0 = time.perf_counter()
        simulate_fleet(specs, max_devices=max_dev, engine="vector")
        t1 = time.perf_counter()
        simulate_fleet(specs, max_devices=max_dev, engine="fused")
        pairs.append((t1 - t0, time.perf_counter() - t1))
    us_perjob = min(p[0] for p in pairs) * 1e6
    us_fused = min(p[1] for p in pairs) * 1e6
    ratios = sorted(pj / f for pj, f in pairs)
    fused_speedup = ratios[len(ratios) // 2]
    thr_fused = devsec_sweep / (us_fused / 1e6)
    n_dev_total = sum(t.grid.n_devices for t in tels)
    rows.append(Row("fleet_engine.perjob_600job_sweep", us_perjob,
                    f"device_seconds_per_wall_s="
                    f"{devsec_sweep / (us_perjob / 1e6):.0f}"))
    rows.append(Row("fleet_engine.fused_600job_sweep", us_fused,
                    f"device_seconds_per_wall_s={thr_fused:.0f} "
                    f"speedup={fused_speedup:.1f}x devices={n_dev_total}"))
    print("BENCH " + json.dumps({
        "name": "fleet_engine_fused",
        "jobs": len(specs),
        "devices": n_dev_total,
        "perjob_wall_s": round(us_perjob / 1e6, 3),
        "fused_wall_s": round(us_fused / 1e6, 3),
        "fused_speedup_x": round(fused_speedup, 1),
        "fused_devsec_per_s": round(thr_fused),
    }))

    run_jax(rows)

    # -- collector round overhead: scrape -> windowed ingest -> detect -----
    # 64 monitored jobs x 16 devices, 5-minute rounds at 30 s scrapes: the
    # continuous loop must be a rounding error next to the round period.
    n_jobs, n_dev_c, round_s = 64, 16, 300.0
    n_rounds = 12

    def _collector_run():
        streams = [JobStream(
            f"mon-{i}",
            SimulatorSource(PROFILE, duration_s=n_rounds * round_s,
                            interval_s=INTERVAL_S, n_devices=n_dev_c,
                            seed=i,
                            events=EVENTS if i % 16 == 0 else ()),
            chips=256, group="bf16", app_mfu=0.38)
            for i in range(n_jobs)]
        col = Collector(streams, CollectorConfig(
            round_s=round_s, bucket_s=round_s, retain=8))
        return col.run()

    reports, us_total = timed(_collector_run, repeat=3)
    us_round = us_total / n_rounds
    samples_round = sum(r.samples for r in reports) / n_rounds
    devsec_round = n_jobs * n_dev_c * round_s
    thr_col = devsec_round / (us_round / 1e6)
    rows.append(Row("fleet_engine.collector_round_64job", us_round,
                    f"samples_per_round={samples_round:.0f} "
                    f"device_seconds_per_wall_s={thr_col:.0f} "
                    f"alerts={sum(len(r.alerts) for r in reports)}"))
    print("BENCH " + json.dumps({
        "name": "fleet_collector",
        "jobs": n_jobs,
        "devices": n_jobs * n_dev_c,
        "rounds": n_rounds,
        "round_ms": round(us_round / 1e3, 2),
        "collector_devsec_per_s": round(thr_col),
    }))

    # -- trace store: columnar archive vs CSV, chunked replay throughput --
    # One day of a 16-device job at 30 s scrapes, replayed through the
    # rollup two ways: materialize-everything CSV vs O(chunk) streaming
    # over the columnar archive (hour-long polls crossing chunk bounds).
    import tempfile

    from repro.telemetry.source import TraceReplaySource, read_trace, \
        write_trace
    from repro.telemetry.tracestore import archive_nbytes

    n_dev_t, day_s = 16, 86400.0
    grid = simulate_devices(PROFILE, duration_s=day_s,
                            interval_s=INTERVAL_S, events=EVENTS,
                            n_devices=n_dev_t, seed=3)
    n_cells = grid.tpa.size
    with tempfile.TemporaryDirectory() as tmp:
        csv_path = os.path.join(tmp, "day.csv")
        ctr_path = os.path.join(tmp, "day.ctr")
        write_trace(grid, csv_path)
        write_trace(grid, ctr_path, chunk_samples=512)
        csv_b, ctr_b = os.path.getsize(csv_path), archive_nbytes(ctr_path)

        def _csv_replay():
            roll = StreamingRollup(bucket_s=1800.0)
            roll.add_grid("day", read_trace(csv_path))
            return roll

        def _chunked_replay():
            roll = StreamingRollup(bucket_s=1800.0)
            src = TraceReplaySource(ctr_path)
            while not src.exhausted:
                g = src.poll(3600.0)
                if g.tpa.size:
                    roll.add_grid("day", g)
            return src.reader, roll

        _, us_csv = timed(_csv_replay, repeat=3)
        (reader, _), us_chunk = timed(_chunked_replay, repeat=3)
    compression = csv_b / ctr_b
    thr_csv = n_cells / (us_csv / 1e6)
    thr_chunk = n_cells / (us_chunk / 1e6)
    resident_frac = reader.peak_resident_samples / n_cells
    rows.append(Row("fleet_engine.trace_replay_csv_1day", us_csv,
                    f"samples_per_s={thr_csv:.0f} bytes={csv_b}"))
    rows.append(Row("fleet_engine.trace_replay_chunked_1day", us_chunk,
                    f"samples_per_s={thr_chunk:.0f} bytes={ctr_b} "
                    f"compression={compression:.1f}x "
                    f"peak_resident_frac={resident_frac:.3f}"))
    print("BENCH " + json.dumps({
        "name": "trace_store",
        "devices": n_dev_t,
        "samples": n_cells,
        "csv_bytes": csv_b,
        "columnar_bytes": ctr_b,
        "compression_x": round(compression, 1),
        "csv_replay_samples_per_s": round(thr_csv),
        "chunked_replay_samples_per_s": round(thr_chunk),
        "peak_resident_frac": round(resident_frac, 4),
    }))

    # -- serving layer: store query latency + HTTP requests/s -------------
    # The 64-job fixture from the collector case, published into a
    # FleetStore and interrogated the way a dashboard fleet does: a COLD
    # pass (every query computed — a fresh generation just landed) and a
    # WARM pass (the common case: pollers repeating queries between
    # rounds, answered from the generation cache), plus real HTTP
    # round-trips through the stdlib server (mostly ETag 304s).
    from repro.serve.client import FleetClient
    from repro.serve.http import FleetAPIServer
    from repro.serve.store import FleetStore

    streams = [JobStream(
        f"mon-{i}",
        SimulatorSource(PROFILE, duration_s=n_rounds * round_s,
                        interval_s=INTERVAL_S, n_devices=n_dev_c, seed=i,
                        events=EVENTS if i % 16 == 0 else ()),
        chips=256, group="bf16", app_mfu=0.38)
        for i in range(n_jobs)]
    col = Collector(streams, CollectorConfig(
        round_s=round_s, bucket_s=round_s, retain=8))
    col.run()
    store = FleetStore()
    store.update_from(col)
    job_ids = sorted(col.rollup.jobs)

    def _query_pass():
        n = 2
        store.fleet_series()
        store.alerts()
        for jid in job_ids:
            store.job_series(jid)
            n += 1
        store.top_regressions(k=5, window=4, min_duration=2)
        store.goodput()
        store.divergence()
        return n + 3

    def _cold_pass():
        store.update_from(col)          # new generation: cache cleared
        return _query_pass()

    n_q, us_cold = timed(_cold_pass, repeat=3)
    _query_pass()                        # prime the generation cache
    reps = 10
    def _warm_passes():
        for _ in range(reps):
            _query_pass()
    _, us_warm_total = timed(_warm_passes, repeat=3)
    us_warm = us_warm_total / reps
    qps_cold = n_q / (us_cold / 1e6)
    qps_warm = n_q / (us_warm / 1e6)
    rows.append(Row("fleet_engine.serve_store_cold_64job", us_cold,
                    f"queries_per_s={qps_cold:.0f} queries={n_q}"))
    rows.append(Row("fleet_engine.serve_store_warm_64job", us_warm,
                    f"queries_per_s={qps_warm:.0f} cached=1"))

    with FleetAPIServer(store) as server:
        client = FleetClient(server.url)
        client.fleet()                   # prime the client ETag cache
        n_http = 100

        def _http_pass():
            for k in range(n_http):
                if k % 4 == 0:
                    client.job(job_ids[k % len(job_ids)])
                else:
                    client.fleet()       # repeat poll -> 304

        _, us_http = timed(_http_pass, repeat=3)
    rps_http = n_http / (us_http / 1e6)
    rows.append(Row("fleet_engine.serve_http_64job", us_http / n_http,
                    f"requests_per_s={rps_http:.0f} "
                    f"hits_304={client.hits_304}"))
    print("BENCH " + json.dumps({
        "name": "serve_query",
        "jobs": n_jobs,
        "store_queries_per_s_cold": round(qps_cold),
        "store_queries_per_s": round(qps_warm),
        "http_requests_per_s": round(rps_http),
        "http_304_frac": round(client.hits_304 / max(client.requests, 1),
                               3),
    }))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
