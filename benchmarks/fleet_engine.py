"""Fleet-engine throughput: vectorized vs per-device scalar simulation,
and the fused multi-job grid vs the per-job engine loop.

Metric is simulated device-seconds per wall-second — how much fleet
telemetry one CPU core can synthesize in real time.  The scalar reference
is timed on a small slice (it is the thing being replaced); the vectorized
engine is then timed head-to-head on the same slice AND at the paper's
operating point (1,000 devices x 1 hour at 30 s scrapes).  The fused case
runs a 600-job / ~10k-device sweep through `simulate_fleet` both ways
(per-job loop vs one padded multi-job grid).  The collector case measures
the continuous-monitoring loop's per-round overhead (scrape -> windowed
ingest -> regression/divergence detect) for a 64-job fleet.  The ingest
case drives the horizontal write path (delta blobs -> sharded aggregator
-> k-way reduce) at 10k-host scale against the npz pairwise baseline.

Every case emits a BENCH json line for the driver AND lands in
`BENCH_fleet.json` (path overridable via the env var of the same name):
a machine-readable per-case {name, median, units, metrics} table next to
the human CSV rows.
"""
from __future__ import annotations

import os
import threading
import time

import numpy as np

from benchmarks.common import Row, bench_case, merge_bench_json, timed

_CASES: list[dict] = []


def _bench(name: str, median: float, units: str, **metrics) -> None:
    """Record one benchmark case (BENCH line + structured row for
    `BENCH_fleet.json` — shared plumbing in benchmarks.common)."""
    bench_case(_CASES, name, median, units, **metrics)


def _write_json() -> str:
    return merge_bench_json(_CASES)
from repro.fleet.collector import Collector, CollectorConfig, JobStream
from repro.fleet.engine import simulate_devices
from repro.fleet.jobs import JobSpec, simulate_fleet
from repro.fleet.streaming import StreamingRollup
from repro.telemetry.counters import (Event, SimulatedDeviceBackend,
                                      StepProfile)
from repro.telemetry.scrape import scrape
from repro.telemetry.source import SimulatorSource

PROFILE = StepProfile(mxu_time_s=0.84, step_time_s=2.0)
EVENTS = [Event(start_s=600, end_s=1200, slowdown=2.5)]
INTERVAL_S = 30.0


def _sweep_specs(n_jobs: int = 600, max_devices: int = 17):
    """The §V-B-scale sweep: 600 jobs, ~10k sampled devices, ragged
    durations, a few evented/straggling jobs."""
    return [JobSpec(f"sweep-{i}", "granite-3-2b", chips=max_devices,
                    true_duty=0.2 + 0.03 * (i % 8),
                    duration_s=600.0 + 150.0 * (i % 4),
                    scrape_interval_s=INTERVAL_S, seed=i,
                    events=[Event(300, 600, slowdown=2.5)] if i % 50 == 0
                    else (),
                    straggler_sigma=0.15 if i % 25 == 0 else 0.0)
            for i in range(n_jobs)]


def _scalar(n_dev: int, duration_s: float) -> None:
    rng = np.random.default_rng(0)
    for _ in range(n_dev):
        be = SimulatedDeviceBackend(PROFILE, events=EVENTS,
                                    seed=int(rng.integers(0, 2 ** 31)))
        scrape(be, duration_s, INTERVAL_S)


def _vector(n_dev: int, duration_s: float) -> None:
    simulate_devices(PROFILE, duration_s=duration_s, interval_s=INTERVAL_S,
                     events=EVENTS, n_devices=n_dev, seed=0)


def run_jax(rows: list[Row] | None = None) -> list[Row]:
    """jax engine backend + device-side rollup ingest (ISSUE 6).

    Defaults to 100k devices x 1 hour of 30 s scrapes; the paper-scale
    1M x 24 h point is the same code one env knob away
    (FLEET_JAX_DEVICES=1000000 FLEET_JAX_HOURS=24 — practical only with
    real accelerators and a device mesh, ~11 GB per f32 grid).  Reports
    the jax engine head-to-head with the fused-NumPy engine on the SAME
    operating point, plus all three rollup-ingest paths: the pallas
    histogram-accumulate kernel (interpret mode off-TPU), its XLA
    fallback, and the host-side NumPy bucketize.
    """
    rows = [] if rows is None else rows
    try:
        import jax
        from repro.fleet.engine_jax import simulate_jobs_jax
        from repro.kernels.fleet_hist import _interpret, ofu_bucket_hist
    except Exception as e:  # pragma: no cover — env without jax
        print(f"BENCH-SKIP fleet_engine_jax ({type(e).__name__}: {e})")
        return rows
    from repro.fleet.engine import JobSlot, simulate_jobs_fused

    n_dev = int(os.environ.get("FLEET_JAX_DEVICES", "100000"))
    hours = float(os.environ.get("FLEET_JAX_HOURS", "1"))
    dur = hours * 3600.0
    devsec = n_dev * dur
    repeat = 1 if n_dev >= 50_000 else 3
    slot = JobSlot(PROFILE, dur, INTERVAL_S, events=EVENTS,
                   stragglers=np.ones(n_dev))

    def _sim():
        (g,) = simulate_jobs_jax([slot], seed=0)
        jax.block_until_ready((g.tpa, g.clock_mhz))
        return g

    g = _sim()                              # compile off the clock
    g, us_jax = timed(_sim, repeat=repeat)
    (gn,), us_np = timed(
        lambda: simulate_jobs_fused([slot], seed=0), repeat=repeat)
    thr_jax = devsec / (us_jax / 1e6)
    label = f"fleet_engine.jax_{n_dev}dev_{hours:g}h"
    rows.append(Row(label, us_jax,
                    f"device_seconds_per_wall_s={thr_jax:.0f} "
                    f"numpy_wall_s={us_np / 1e6:.2f}"))

    # rollup ingest over the device grid: pallas vs XLA vs host NumPy.
    # The kernels get identical inputs (same grid, same aligned bucket
    # map the StreamingRollup routing would derive).
    bucket_s = 300.0
    S = int(g.tpa.shape[1])
    n_cells = n_dev * S
    spb = max(int(round(bucket_s / INTERVAL_S)), 1)
    col = np.arange(S) // spb
    roll = StreamingRollup(bucket_s=bucket_s)
    kw = dict(inv_fmax=1.0 / slot.chip.f_max_mhz, edges=roll.edges,
              col_bucket=col, n_buckets=int(col[-1]) + 1 if S else 0)

    def _kernel(use_pallas):
        out = ofu_bucket_hist(g.tpa, g.clock_mhz, use_pallas=use_pallas,
                              **kw)
        jax.block_until_ready(out)
        return out

    _kernel(True), _kernel(False)           # compile off the clock
    (h_pl, _), us_pl = timed(_kernel, True, repeat=repeat)
    (h_xla, _), us_xla = timed(_kernel, False, repeat=repeat)

    def _dev_ingest():                      # full add_grid device route
        r = StreamingRollup(bucket_s=bucket_s)
        r.add_grid("j", g, chips=n_dev)
        return r

    def _host_ingest():                     # fused-NumPy baseline
        r = StreamingRollup(bucket_s=bucket_s)
        r.add_grid("j", gn, chips=n_dev)
        return r

    r_dev, us_dev = timed(_dev_ingest, repeat=repeat)
    r_host, us_host = timed(_host_ingest, repeat=repeat)
    interp = _interpret()
    rows.append(Row("fleet_engine.jax_ingest_pallas", us_pl,
                    f"samples_per_s={n_cells / (us_pl / 1e6):.0f} "
                    f"interpret={int(interp)}"))
    rows.append(Row("fleet_engine.jax_ingest_xla", us_xla,
                    f"samples_per_s={n_cells / (us_xla / 1e6):.0f}"))
    rows.append(Row("fleet_engine.jax_ingest_host_numpy", us_host,
                    f"samples_per_s={n_cells / (us_host / 1e6):.0f}"))

    # cross-backend sanity on the spot the driver reads: the two ingest
    # kernels agree bitwise, and the engines agree statistically
    assert np.array_equal(np.asarray(h_pl), np.asarray(h_xla))
    ofu_jax = float(r_dev.fleet_stats(qs=()).mean[0])
    ofu_np = float(r_host.fleet_stats(qs=()).mean[0])

    _bench(
        "fleet_engine_jax", round(thr_jax), "device_seconds_per_wall_s",
        devices=n_dev,
        hours=hours,
        jax_wall_s=round(us_jax / 1e6, 3),
        numpy_wall_s=round(us_np / 1e6, 3),
        jax_devsec_per_s=round(thr_jax),
        pallas_interpret=interp,
        ingest_pallas_samples_per_s=round(n_cells / (us_pl / 1e6)),
        ingest_xla_samples_per_s=round(n_cells / (us_xla / 1e6)),
        ingest_numpy_samples_per_s=round(n_cells / (us_host / 1e6)),
        ingest_device_route_wall_s=round(us_dev / 1e6, 3),
        first_bucket_ofu_jax=round(ofu_jax, 4),
        first_bucket_ofu_numpy=round(ofu_np, 4),
    )
    return rows


def run_ingest(rows: list[Row] | None = None) -> list[Row]:
    """Ingest tier at fleet scale (ISSUE 7): 10k hosts / 1M devices of
    delta traffic through the sharded aggregator.

    Each host pre-bins ~100 devices into an 8-bucket rollup and ships
    two rounds of `delta_bytes()` blobs (round 2 is a true delta: only
    the new bucket rows), plus a slice of duplicate redeliveries — the
    at-least-once pattern.  Reported: ingest MB/s and blobs/s through
    `IngestAggregator.submit`, k-way merges/s for the two-level
    `fleet_rollup` reduce, and p99 dashboard read latency while ingest
    and publishes keep running.  The decode+merge HEAD-TO-HEAD (npz
    pairwise `from_bytes`+`merge` fold vs v2 submit + `merge_many`
    reduce) runs on a subset (`FLEET_INGEST_NPZ_HOSTS`, default 1024) —
    the npz path at 10k hosts would dominate the suite's wall clock —
    and both sides are per-host rates, so the speedup transfers.
    Correctness is checked against single-process ingestion of the
    same observations (bucketwise identical).
    """
    from repro.serve import (FleetAPIServer, FleetClient, FleetStore,
                             IngestAggregator)

    rows = [] if rows is None else rows
    n_hosts = int(os.environ.get("FLEET_INGEST_HOSTS", "10000"))
    npz_hosts = min(int(os.environ.get("FLEET_INGEST_NPZ_HOSTS", "1024")),
                    n_hosts)
    dev_per_host = 100
    bins, n_buckets, bucket_s = 64, 8, 300.0
    half = n_buckets // 2
    rng = np.random.default_rng(7)

    # -- synthesize two rounds of per-host delta traffic ------------------
    # and fold the SAME observations into one single-process reference
    reference = StreamingRollup(bucket_s, bins=bins)
    deltas1, deltas2 = [], []
    sample_hosts = []                   # kept live for the head-to-head
    for i in range(n_hosts):
        roll = StreamingRollup(bucket_s, bins=bins)
        job, grp = f"job-{i % 97}", ("bf16" if i % 2 else "fp8")
        h1 = rng.poisson(3.0, (half, bins)).astype(float)
        s1 = h1.sum(axis=1) * rng.uniform(0.2, 0.6)
        roll.observe_hist(job, h1, s1, group=grp, weight=dev_per_host)
        reference.observe_hist(job, h1, s1, group=grp,
                               weight=dev_per_host)
        deltas1.append(roll.delta_bytes(0))
        acked = roll.generation
        h2 = rng.poisson(3.0, (n_buckets - half, bins)).astype(float)
        s2 = h2.sum(axis=1) * rng.uniform(0.2, 0.6)
        roll.observe_hist(job, h2, s2, b0=half, group=grp,
                          weight=dev_per_host)
        reference.observe_hist(job, h2, s2, b0=half, group=grp,
                               weight=dev_per_host)
        deltas2.append(roll.delta_bytes(acked))
        if i < npz_hosts:
            sample_hosts.append(roll)

    # -- decode+merge head-to-head: npz pairwise vs v2 submit+reduce ------
    blobs_npz = [h.to_bytes() for h in sample_hosts]
    blobs_v2 = [h.to_bytes_v2() for h in sample_hosts]

    def _npz_pairwise():
        acc = StreamingRollup(bucket_s, bins=bins)
        for b in blobs_npz:
            acc.merge(StreamingRollup.from_bytes(b))
        return acc

    def _v2_submit():
        agg = IngestAggregator(n_shards=4)
        for i, b in enumerate(blobs_v2):
            agg.submit(f"h{i}", b)
        return agg.fleet_rollup()

    acc_npz, us_npz = timed(_npz_pairwise, repeat=3)
    acc_v2, us_v2 = timed(_v2_submit, repeat=3)
    speedup = us_npz / us_v2
    npz_rate = npz_hosts / (us_npz / 1e6)
    v2_rate = npz_hosts / (us_v2 / 1e6)
    identical = all(
        np.allclose(acc_npz._hists[s], acc_v2._hists[s],
                    rtol=1e-9, atol=1e-12)
        and np.allclose(acc_npz._sums[s], acc_v2._sums[s],
                        rtol=1e-9, atol=1e-12)
        for s in acc_npz._hists)
    rows.append(Row(f"fleet_engine.ingest_npz_pairwise_{npz_hosts}host",
                    us_npz, f"hosts_per_s={npz_rate:.0f}"))
    rows.append(Row(f"fleet_engine.ingest_v2_submit_{npz_hosts}host",
                    us_v2, f"hosts_per_s={v2_rate:.0f} "
                    f"speedup={speedup:.1f}x identical={int(identical)}"))

    # -- full-scale ingest: all hosts, both rounds, a duplicate slice -----
    agg = IngestAggregator(n_shards=8, max_queue=64)
    n_blobs = ingest_bytes = 0
    t0 = time.perf_counter()
    for round_blobs in (deltas1, deltas2):
        for i, b in enumerate(round_blobs):
            agg.submit(f"host-{i}", b)
            n_blobs += 1
            ingest_bytes += len(b)
    for i in range(0, n_hosts, 37):     # at-least-once redelivery
        agg.submit(f"host-{i}", deltas2[i])
        n_blobs += 1
        ingest_bytes += len(deltas2[i])
    ingest_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    fleet = agg.fleet_rollup()
    reduce_s = time.perf_counter() - t0
    mb_per_s = ingest_bytes / 1e6 / ingest_s
    blobs_per_s = n_blobs / ingest_s
    merges_per_s = n_hosts / reduce_s
    fleet_identical = (
        set(fleet._hists) == set(reference._hists) and all(
            np.allclose(fleet._hists[s], reference._hists[s],
                        rtol=1e-9, atol=1e-12)
            and np.allclose(fleet._sums[s], reference._sums[s],
                            rtol=1e-9, atol=1e-12)
            for s in reference._hists))
    stats = agg.stats()
    rows.append(Row(f"fleet_engine.ingest_submit_{n_hosts}host",
                    ingest_s * 1e6 / n_blobs,
                    f"mb_per_s={mb_per_s:.1f} "
                    f"blobs_per_s={blobs_per_s:.0f} "
                    f"duplicates={stats['duplicates']}"))
    rows.append(Row(f"fleet_engine.ingest_reduce_{n_hosts}host",
                    reduce_s * 1e6,
                    f"merges_per_s={merges_per_s:.0f} "
                    f"identical={int(fleet_identical)}"))

    # -- p99 dashboard read latency under live ingest ---------------------
    store = FleetStore()
    agg.publish(store, clock_s=0.0)
    lat: list[float] = []
    stop = threading.Event()
    with FleetAPIServer(store, aggregator=agg) as server:
        def _reader():
            client = FleetClient(server.url, timeout_s=10.0)
            while not stop.is_set():
                t = time.perf_counter()
                client.fleet()
                lat.append(time.perf_counter() - t)

        readers = [threading.Thread(target=_reader, daemon=True)
                   for _ in range(4)]
        for th in readers:
            th.start()
        t_end = time.perf_counter() + 2.0
        i = writer_blobs = 0
        while time.perf_counter() < t_end:
            agg.submit(f"host-{i % n_hosts}", deltas2[i % n_hosts])
            i += 1
            writer_blobs += 1
            if i % 2000 == 0:           # fresh generation mid-read-storm
                agg.publish(store, clock_s=float(i))
        stop.set()
        for th in readers:
            th.join(timeout=10)
    lat_ms = np.sort(np.asarray(lat)) * 1e3
    p99_ms = float(lat_ms[int(0.99 * (lat_ms.size - 1))])
    p50_ms = float(lat_ms[lat_ms.size // 2])
    rows.append(Row(f"fleet_engine.ingest_read_p99_{n_hosts}host",
                    p99_ms * 1e3,
                    f"p50_ms={p50_ms:.2f} p99_ms={p99_ms:.2f} "
                    f"reads={lat_ms.size} "
                    f"concurrent_blobs={writer_blobs}"))

    _bench(
        "ingest_tier", round(mb_per_s, 1), "MB_per_s",
        hosts=n_hosts,
        devices=n_hosts * dev_per_host,
        blobs=n_blobs,
        ingest_mb_per_s=round(mb_per_s, 1),
        blobs_per_s=round(blobs_per_s),
        merges_per_s=round(merges_per_s),
        reduce_wall_s=round(reduce_s, 3),
        decode_merge_speedup_x=round(speedup, 1),
        npz_hosts_per_s=round(npz_rate),
        v2_hosts_per_s=round(v2_rate),
        duplicates=stats["duplicates"],
        bucketwise_identical=bool(identical and fleet_identical),
        p99_read_ms=round(p99_ms, 2),
        p50_read_ms=round(p50_ms, 2),
        concurrent_reads=int(lat_ms.size),
    )
    return rows


def run() -> list[Row]:
    rows = []
    # -- head-to-head on the same slice (16 devices x 30 min) -------------
    n_dev, dur = 16, 1800.0
    devsec = n_dev * dur
    _, us_scalar = timed(_scalar, n_dev, dur, repeat=2)
    _, us_vector = timed(_vector, n_dev, dur, repeat=3)
    thr_scalar = devsec / (us_scalar / 1e6)
    thr_vector = devsec / (us_vector / 1e6)
    speedup = us_scalar / us_vector
    rows.append(Row("fleet_engine.scalar_16dev_30min", us_scalar,
                    f"device_seconds_per_wall_s={thr_scalar:.0f}"))
    rows.append(Row("fleet_engine.vector_16dev_30min", us_vector,
                    f"device_seconds_per_wall_s={thr_vector:.0f} "
                    f"speedup={speedup:.1f}x"))

    # -- the acceptance operating point: 1000 devices x 1 hour ------------
    spec = JobSpec("bench-fleet", "granite-3-2b", chips=1000,
                   true_duty=0.35, duration_s=3600.0,
                   scrape_interval_s=INTERVAL_S, seed=0)
    t0 = time.perf_counter()
    (tel,) = simulate_fleet([spec], max_devices=1000)
    roll = StreamingRollup(bucket_s=300)
    roll.add_job(tel)
    wall_s = time.perf_counter() - t0
    devsec_full = 1000 * 3600.0
    thr_full = devsec_full / wall_s
    rows.append(Row("fleet_engine.vector_1000dev_1h_rollup", wall_s * 1e6,
                    f"device_seconds_per_wall_s={thr_full:.0f} "
                    f"wall_s={wall_s:.2f} ofu={tel.ofu * 100:.1f}% "
                    f"buckets={roll.n_buckets}"))

    _bench(
        "fleet_engine", round(thr_full), "device_seconds_per_wall_s",
        scalar_devsec_per_s=round(thr_scalar),
        vector_devsec_per_s=round(thr_vector),
        speedup_x=round(speedup, 1),
        fleet_1000dev_1h_wall_s=round(wall_s, 3),
        fleet_devsec_per_s=round(thr_full),
    )

    # -- fused multi-job grid: 600 jobs / ~10k devices, one padded pass ----
    # interleaved (per-job, fused) pairs + median pair ratio, so machine
    # load drift hits both sides of the comparison equally
    max_dev = 17
    specs = _sweep_specs(600, max_dev)
    devsec_sweep = sum(min(s.chips, max_dev) * s.duration_s for s in specs)
    tels = simulate_fleet(specs, max_devices=max_dev)        # warm caches
    pairs = []
    for _ in range(5):
        t0 = time.perf_counter()
        simulate_fleet(specs, max_devices=max_dev, engine="vector")
        t1 = time.perf_counter()
        simulate_fleet(specs, max_devices=max_dev, engine="fused")
        pairs.append((t1 - t0, time.perf_counter() - t1))
    us_perjob = min(p[0] for p in pairs) * 1e6
    us_fused = min(p[1] for p in pairs) * 1e6
    ratios = sorted(pj / f for pj, f in pairs)
    fused_speedup = ratios[len(ratios) // 2]
    thr_fused = devsec_sweep / (us_fused / 1e6)
    n_dev_total = sum(t.grid.n_devices for t in tels)
    rows.append(Row("fleet_engine.perjob_600job_sweep", us_perjob,
                    f"device_seconds_per_wall_s="
                    f"{devsec_sweep / (us_perjob / 1e6):.0f}"))
    rows.append(Row("fleet_engine.fused_600job_sweep", us_fused,
                    f"device_seconds_per_wall_s={thr_fused:.0f} "
                    f"speedup={fused_speedup:.1f}x devices={n_dev_total}"))
    _bench(
        "fleet_engine_fused", round(thr_fused),
        "device_seconds_per_wall_s",
        jobs=len(specs),
        devices=n_dev_total,
        perjob_wall_s=round(us_perjob / 1e6, 3),
        fused_wall_s=round(us_fused / 1e6, 3),
        fused_speedup_x=round(fused_speedup, 1),
        fused_devsec_per_s=round(thr_fused),
    )

    run_jax(rows)

    # -- collector round overhead: scrape -> windowed ingest -> detect -----
    # 64 monitored jobs x 16 devices, 5-minute rounds at 30 s scrapes: the
    # continuous loop must be a rounding error next to the round period.
    n_jobs, n_dev_c, round_s = 64, 16, 300.0
    n_rounds = 12

    def _collector_run():
        streams = [JobStream(
            f"mon-{i}",
            SimulatorSource(PROFILE, duration_s=n_rounds * round_s,
                            interval_s=INTERVAL_S, n_devices=n_dev_c,
                            seed=i,
                            events=EVENTS if i % 16 == 0 else ()),
            chips=256, group="bf16", app_mfu=0.38)
            for i in range(n_jobs)]
        col = Collector(streams, CollectorConfig(
            round_s=round_s, bucket_s=round_s, retain=8))
        return col.run()

    reports, us_total = timed(_collector_run, repeat=3)
    us_round = us_total / n_rounds
    samples_round = sum(r.samples for r in reports) / n_rounds
    devsec_round = n_jobs * n_dev_c * round_s
    thr_col = devsec_round / (us_round / 1e6)
    rows.append(Row("fleet_engine.collector_round_64job", us_round,
                    f"samples_per_round={samples_round:.0f} "
                    f"device_seconds_per_wall_s={thr_col:.0f} "
                    f"alerts={sum(len(r.alerts) for r in reports)}"))
    _bench(
        "fleet_collector", round(us_round / 1e3, 2), "ms_per_round",
        jobs=n_jobs,
        devices=n_jobs * n_dev_c,
        rounds=n_rounds,
        round_ms=round(us_round / 1e3, 2),
        collector_devsec_per_s=round(thr_col),
    )

    # -- trace store: columnar archive vs CSV, chunked replay throughput --
    # One day of a 16-device job at 30 s scrapes, replayed through the
    # rollup two ways: materialize-everything CSV vs O(chunk) streaming
    # over the columnar archive (hour-long polls crossing chunk bounds).
    import tempfile

    from repro.telemetry.source import TraceReplaySource, read_trace, \
        write_trace
    from repro.telemetry.tracestore import archive_nbytes

    n_dev_t, day_s = 16, 86400.0
    grid = simulate_devices(PROFILE, duration_s=day_s,
                            interval_s=INTERVAL_S, events=EVENTS,
                            n_devices=n_dev_t, seed=3)
    n_cells = grid.tpa.size
    with tempfile.TemporaryDirectory() as tmp:
        csv_path = os.path.join(tmp, "day.csv")
        ctr_path = os.path.join(tmp, "day.ctr")
        write_trace(grid, csv_path)
        write_trace(grid, ctr_path, chunk_samples=512)
        csv_b, ctr_b = os.path.getsize(csv_path), archive_nbytes(ctr_path)

        def _csv_replay():
            roll = StreamingRollup(bucket_s=1800.0)
            roll.add_grid("day", read_trace(csv_path))
            return roll

        def _chunked_replay():
            roll = StreamingRollup(bucket_s=1800.0)
            src = TraceReplaySource(ctr_path)
            while not src.exhausted:
                g = src.poll(3600.0)
                if g.tpa.size:
                    roll.add_grid("day", g)
            return src.reader, roll

        _, us_csv = timed(_csv_replay, repeat=3)
        (reader, _), us_chunk = timed(_chunked_replay, repeat=3)
    compression = csv_b / ctr_b
    thr_csv = n_cells / (us_csv / 1e6)
    thr_chunk = n_cells / (us_chunk / 1e6)
    resident_frac = reader.peak_resident_samples / n_cells
    rows.append(Row("fleet_engine.trace_replay_csv_1day", us_csv,
                    f"samples_per_s={thr_csv:.0f} bytes={csv_b}"))
    rows.append(Row("fleet_engine.trace_replay_chunked_1day", us_chunk,
                    f"samples_per_s={thr_chunk:.0f} bytes={ctr_b} "
                    f"compression={compression:.1f}x "
                    f"peak_resident_frac={resident_frac:.3f}"))
    _bench(
        "trace_store", round(thr_chunk), "samples_per_s",
        devices=n_dev_t,
        samples=n_cells,
        csv_bytes=csv_b,
        columnar_bytes=ctr_b,
        compression_x=round(compression, 1),
        csv_replay_samples_per_s=round(thr_csv),
        chunked_replay_samples_per_s=round(thr_chunk),
        peak_resident_frac=round(resident_frac, 4),
    )

    # -- codecs: ctr-v2 container compression + decode throughput ---------
    # The always-on-recording question: what does a day of live counters
    # cost on disk?  The fixture is DCGM-WIRE precision (activity at 3
    # decimals, clock in whole MHz — what dcgmi/NVML actually deliver,
    # via `quantize_wire`), because that is what a live recorder stores;
    # full-precision f32 noise has a much higher entropy floor.  The
    # acceptance bar is >= 15x smaller than CSV for the dbz codec.
    from repro.telemetry.backends.fake import quantize_wire
    from repro.telemetry.scrape import DeviceGrid as _DG
    from repro.telemetry.tracestore import read_archive, write_archive

    q_tpa, q_clk = quantize_wire(grid.tpa, grid.clock_mhz)
    wire = _DG(INTERVAL_S, q_tpa.astype(np.float32),
               q_clk.astype(np.float32))
    with tempfile.TemporaryDirectory() as tmp:
        csv_path = os.path.join(tmp, "wire.csv")
        write_trace(wire, csv_path)
        csv_wire_b = os.path.getsize(csv_path)
        sizes, decode_thr = {}, {}
        for tag, path, kw in (
                ("v1_npz", os.path.join(tmp, "wire.ctr"), {}),
                ("v2_raw", os.path.join(tmp, "raw.ctr2"),
                 {"codec": "raw"}),
                ("v2_dbz", os.path.join(tmp, "dbz.ctr2"),
                 {"codec": "dbz"})):
            write_trace(wire, path, chunk_samples=512, **kw)
            sizes[tag] = archive_nbytes(path)
            back, us_dec = timed(lambda p=path: read_archive(p), repeat=3)
            decode_thr[tag] = n_cells / (us_dec / 1e6)
            assert back.tpa.tobytes() == wire.tpa.tobytes(), tag
    ratio_dbz = csv_wire_b / sizes["v2_dbz"]
    ratio_v1 = csv_wire_b / sizes["v1_npz"]
    assert ratio_dbz >= 15.0, (
        f"dbz compression regressed to {ratio_dbz:.1f}x vs CSV "
        f"(acceptance floor is 15x)")
    rows.append(Row(
        "fleet_engine.trace_codecs_dbz_1day",
        n_cells / decode_thr["v2_dbz"] * 1e6,
        f"compression={ratio_dbz:.1f}x bytes={sizes['v2_dbz']} "
        f"decode_samples_per_s={decode_thr['v2_dbz']:.0f}"))
    _bench(
        "trace_codecs", round(ratio_dbz, 1), "x_vs_csv",
        devices=n_dev_t,
        samples=n_cells,
        csv_bytes=csv_wire_b,
        v1_npz_bytes=sizes["v1_npz"],
        v2_raw_bytes=sizes["v2_raw"],
        v2_dbz_bytes=sizes["v2_dbz"],
        v1_compression_x=round(ratio_v1, 1),
        dbz_compression_x=round(ratio_dbz, 1),
        dbz_decode_samples_per_s=round(decode_thr["v2_dbz"]),
        raw_decode_samples_per_s=round(decode_thr["v2_raw"]),
        v1_decode_samples_per_s=round(decode_thr["v1_npz"]),
    )

    # -- serving layer: store query latency + HTTP requests/s -------------
    # The 64-job fixture from the collector case, published into a
    # FleetStore and interrogated the way a dashboard fleet does: a COLD
    # pass (every query computed — a fresh generation just landed) and a
    # WARM pass (the common case: pollers repeating queries between
    # rounds, answered from the generation cache), plus real HTTP
    # round-trips through the stdlib server (mostly ETag 304s).
    from repro.serve.client import FleetClient
    from repro.serve.http import FleetAPIServer
    from repro.serve.store import FleetStore

    streams = [JobStream(
        f"mon-{i}",
        SimulatorSource(PROFILE, duration_s=n_rounds * round_s,
                        interval_s=INTERVAL_S, n_devices=n_dev_c, seed=i,
                        events=EVENTS if i % 16 == 0 else ()),
        chips=256, group="bf16", app_mfu=0.38)
        for i in range(n_jobs)]
    col = Collector(streams, CollectorConfig(
        round_s=round_s, bucket_s=round_s, retain=8))
    col.run()
    store = FleetStore()
    store.update_from(col)
    job_ids = sorted(col.rollup.jobs)

    def _query_pass():
        n = 2
        store.fleet_series()
        store.alerts()
        for jid in job_ids:
            store.job_series(jid)
            n += 1
        store.top_regressions(k=5, window=4, min_duration=2)
        store.goodput()
        store.divergence()
        return n + 3

    def _cold_pass():
        store.update_from(col)          # new generation: cache cleared
        return _query_pass()

    n_q, us_cold = timed(_cold_pass, repeat=3)
    _query_pass()                        # prime the generation cache
    reps = 10
    def _warm_passes():
        for _ in range(reps):
            _query_pass()
    _, us_warm_total = timed(_warm_passes, repeat=3)
    us_warm = us_warm_total / reps
    qps_cold = n_q / (us_cold / 1e6)
    qps_warm = n_q / (us_warm / 1e6)
    rows.append(Row("fleet_engine.serve_store_cold_64job", us_cold,
                    f"queries_per_s={qps_cold:.0f} queries={n_q}"))
    rows.append(Row("fleet_engine.serve_store_warm_64job", us_warm,
                    f"queries_per_s={qps_warm:.0f} cached=1"))

    with FleetAPIServer(store) as server:
        client = FleetClient(server.url)
        client.fleet()                   # prime the client ETag cache
        n_http = 100

        def _http_pass():
            for k in range(n_http):
                if k % 4 == 0:
                    client.job(job_ids[k % len(job_ids)])
                else:
                    client.fleet()       # repeat poll -> 304

        _, us_http = timed(_http_pass, repeat=3)
    rps_http = n_http / (us_http / 1e6)
    rows.append(Row("fleet_engine.serve_http_64job", us_http / n_http,
                    f"requests_per_s={rps_http:.0f} "
                    f"hits_304={client.hits_304}"))
    _bench(
        "serve_query", round(rps_http), "requests_per_s",
        jobs=n_jobs,
        store_queries_per_s_cold=round(qps_cold),
        store_queries_per_s=round(qps_warm),
        http_requests_per_s=round(rps_http),
        http_304_frac=round(client.hits_304 / max(client.requests, 1), 3),
    )

    run_ingest(rows)

    path = _write_json()
    print(f"BENCH-JSON {path} cases={len(_CASES)}")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
