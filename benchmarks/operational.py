"""Paper §VI (Figs. 6/7): operational case studies.

A. Embodied-agent regression: injected host-sync serialization (the Gloo
   debug-flag case) -> OFU collapse detected by the recovery service,
   2.5x improvement after the fix.
B. Mixed-precision pretraining at 6,144 chips: effective-peak (Eq. 12)
   MFU vs OFU across precision-mode switches; point vs per-job correlation.
C. World-model remat accounting: 3F-billed vs 4F-executed divergence and
   the corrected counter.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, timed
from repro.core.ofu import effective_peak, ofu_series, pearson_r
from repro.fleet.jobs import JobSpec, build_profile, simulate_job
from repro.fleet.recovery import RecoveryService
from repro.telemetry.counters import Event, SimulatedDeviceBackend
from repro.telemetry.scrape import scrape


def case_a() -> list[Row]:
    spec = JobSpec("embodied", "phi-3-vision-4.2b", chips=256,
                   true_duty=0.42, duration_s=3600, scrape_interval_s=30,
                   events=[Event(start_s=0, end_s=2400, slowdown=2.5,
                                 kind="host_sync_debug_flag")])
    (tel,), us = timed(lambda: (simulate_job(spec, max_devices=2),),
                       repeat=1)
    s = tel.device_series[0]
    ofu = ofu_series(s.tpa, s.clock_mhz)
    before = ofu[:80].mean()     # during the debug-flag period
    after = ofu[80:].mean()      # after removing the flag
    svc = RecoveryService(factor_threshold=1.8, sustain_samples=3,
                          cooldown_samples=1000)
    detected_at = None
    # replay as if the healthy period came first, then the regression,
    # mirroring the production timeline (fix deployed -> regression later)
    timeline = np.concatenate([ofu[80:], ofu[:80]])
    for i, v in enumerate(timeline):
        if svc.observe("embodied", float(v)) is not None:
            detected_at = i
            break
    return [Row("fig6.embodied_agent_regression", us,
                f"ofu_during_bug={before * 100:.1f}% "
                f"ofu_after_fix={after * 100:.1f}% "
                f"improvement={after / before:.2f}x "
                f"detected_after_samples={detected_at}")]


def case_b() -> list[Row]:
    rng = np.random.default_rng(5)
    n_jobs = 174
    mixed = {"bf16": 0.3, "fp8": 0.5, "int8": 0.2}
    bf16_only = {"bf16": 1.0}
    point_m, point_o = [], []
    job_m, job_o = [], []
    tput = 55.0  # constant TFLOP/s/chip across modes (the paper's probe)
    for j in range(n_jobs):
        mode = mixed if j % 4 else bf16_only
        peff = effective_peak(mode)
        mfu_true = tput / peff
        spec = JobSpec(f"mp{j}", "zamba2-7b", chips=6144,
                       precisions=dict(mode), true_duty=mfu_true,
                       duration_s=600, seed=j)
        tel = simulate_job(spec, max_devices=1)
        s = tel.device_series[0]
        ofu = ofu_series(s.tpa, s.clock_mhz)
        # per-timestep app MFU with measurement noise (90 s emission)
        mfu_pts = mfu_true * (1 + rng.normal(0, 0.06, len(ofu)))
        point_m.extend(mfu_pts)
        point_o.extend(ofu)
        job_m.append(float(np.mean(mfu_pts)))
        job_o.append(float(np.mean(ofu)))
    r_point = pearson_r(point_m, point_o)
    r_job = pearson_r(job_m, job_o)
    # BF16-only vs mixed agreement (paper: within ~1 pp)
    bf_idx = [j for j in range(n_jobs) if j % 4 == 0]
    mx_idx = [j for j in range(n_jobs) if j % 4]
    gap_bf = np.mean([abs(job_m[j] - job_o[j]) for j in bf_idx]) * 100
    gap_mx = np.mean([abs(job_m[j] - job_o[j]) for j in mx_idx]) * 100
    return [Row("fig7.mixed_precision_6144", 0.0,
                f"r_pointwise={r_point:.3f} r_per_job={r_job:.3f} "
                f"bf16_mfu={np.mean([job_m[j] for j in bf_idx]) * 100:.1f}% "
                f"mixed_mfu={np.mean([job_m[j] for j in mx_idx]) * 100:.1f}% "
                f"agreement_bf16={gap_bf:.2f}pp agreement_mixed={gap_mx:.2f}pp")]


def case_c() -> list[Row]:
    bad = simulate_job(JobSpec("wfm", "phi-3-vision-4.2b", chips=256,
                               true_duty=0.36, duration_s=600, remat=True),
                       max_devices=1)
    # corrected counter: bills 4F when full activation checkpointing is on
    prof, app, _ = build_profile(
        JobSpec("wfm_fix", "phi-3-vision-4.2b", chips=256, true_duty=0.36,
                duration_s=600, remat=True))
    corrected = app * 4 / 3
    return [Row("sec6c.remat_accounting", 0.0,
                f"reported_mfu={bad.app_mfu * 100:.1f}% ofu={bad.ofu * 100:.1f}% "
                f"corrected_mfu={corrected * 100:.1f}% "
                f"gap_after_fix={abs(corrected - bad.ofu) * 100:.1f}pp")]


def run() -> list[Row]:
    return case_a() + case_b() + case_c()


if __name__ == "__main__":
    for r in run():
        print(r.csv())
