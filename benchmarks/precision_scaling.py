"""Paper Fig. 3: throughput speedup over the baseline precision vs size.

TPU mapping: fp32 plays TF32's role as the 1x baseline; bf16 = 2x... on
v5e the ladder is fp32(0.25x) : bf16(1x) : int8/fp8(2x) relative to bf16 —
we report speedups over fp32 so the theoretical multipliers are 4x / 8x.
Block-scale bookkeeping (AQT-style int8 scales) erodes small-size speedup,
recovering with K — the paper's NVFP4 SF-overhead effect.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row
from repro.core.ofu import ofu_point
from repro.core.peaks import TPU_V5E
from repro.core.tile_quant import (overhead, pick_policy,
                                   scale_factor_overhead)
from repro.telemetry.counters import SimulatedDeviceBackend, StepProfile

SIZES = (512, 1024, 2048, 4096, 8192, 16384)


def _efficiency(n: int, prec: str) -> float:
    """Achieved/peak for a sustained n^3 matmul at precision prec."""
    oh = overhead(n, n, n, pick_policy(n, n, n, prec))
    sf = scale_factor_overhead(n, n, n, prec)
    # theoretical-FLOPs throughput: padded work + SF handling are waste
    return 1.0 / ((1 + oh) * (1 + sf))


def _step_model(n: int, prec: str):
    """(step_time, tpa) for a sustained n^3 matmul at precision prec.

    executed = theoretical x (1+tile_oh); mxu_busy = executed/peak;
    non-MXU time = SF bookkeeping (VPU) + 5% fixed launch overhead.
    """
    oh = overhead(n, n, n, pick_policy(n, n, n, prec))
    sf = scale_factor_overhead(n, n, n, prec)
    theo = 2.0 * n ** 3
    busy = theo * (1 + oh) / (TPU_V5E.peak_tflops(prec) * 1e12)
    step = busy * (1 + sf) / 0.95
    return step, busy / step


def _ofu_of(n: int, prec: str) -> float:
    step, tpa_true = _step_model(n, prec)
    prof = StepProfile(mxu_time_s=tpa_true * step, step_time_s=step)
    be = SimulatedDeviceBackend(prof, seed=n)
    tpa, clk = be.poll(30.0)
    return ofu_point(tpa, clk)


def run() -> list[Row]:
    rows = []
    base = "fp32"
    for prec in ("bf16", "int8"):
        meas, ofu_derived = [], []
        for n in SIZES:
            # measured speedup: theoretical-FLOPs throughput ratio
            meas.append(_step_model(n, base)[0] / _step_model(n, prec)[0])
            # OFU-derived: (OFU_p x Peak_p) / (OFU_base x Peak_base)
            ofu_derived.append(
                (_ofu_of(n, prec) * TPU_V5E.peak_tflops(prec))
                / (_ofu_of(n, base) * TPU_V5E.peak_tflops(base)))
        theo = TPU_V5E.peak_tflops(prec) / TPU_V5E.peak_tflops(base)
        rows.append(Row(
            f"fig3.speedup_over_fp32.{prec}", 0.0,
            f"theoretical={theo:.1f}x "
            f"measured@{SIZES[0]}={meas[0]:.2f}x "
            f"measured@{SIZES[-1]}={meas[-1]:.2f}x "
            f"ofu_derived@{SIZES[-1]}={ofu_derived[-1]:.2f}x "
            f"agreement={abs(ofu_derived[-1] - meas[-1]) / meas[-1] * 100:.1f}%"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
