"""Paper Table II + Fig. 4: OFU vs Adjusted-OFU prediction accuracy on
random GEMMs.

500 random (M, K, N) matmuls per (chip, precision) with dims random
multiples of 16 (the paper's §V-A protocol).  For each: the device executes
2·Meff·Neff·Keff FLOPs (tile quantization); App-MFU ground truth counts
2MNK; raw OFU sees the padded duty cycle; Adjusted OFU divides it out via
the exact grid profile (Eq. 8).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, timed
from repro.core.ofu import AccuracyReport, adjusted_ofu, ofu_point
from repro.core.peaks import CHIPS
from repro.core.tile_quant import (pick_policy, profiled_flops,
                                   scale_factor_overhead)
from repro.telemetry.counters import SimulatedDeviceBackend, StepProfile

N_MATMULS = 500
CONFIGS = [("tpu-v5e", "bf16"), ("tpu-v5e", "int8"), ("tpu-v5e", "fp32"),
           ("tpu-v6e-like", "bf16"), ("tpu-v6e-like", "int8")]


def _one(chip, prec, rng, i):
    # dims: random multiples of 16 (paper protocol); 5-minute sustained
    # matmuls -> sizes large enough to run steady-state
    M, K, N = (int(x) * 16 for x in rng.integers(48, 640, 3))
    pol = pick_policy(M, N, K, prec)
    theo = 2.0 * M * N * K
    execd = float(profiled_flops(M, N, K, pol))
    sf = scale_factor_overhead(M, N, K, prec)
    peak = chip.peak_tflops(prec) * 1e12

    # per-shape achievable efficiency (alignment/size-dependent) + noise
    base_eff = float(np.clip(0.92 - 30.0 / min(M, N, K)
                             - rng.normal(0, 0.01), 0.3, 0.98))
    busy = execd / peak
    step = busy * (1 + sf) / base_eff
    be = SimulatedDeviceBackend(
        StepProfile(mxu_time_s=busy, step_time_s=step, jitter=0.01),
        chip=chip, seed=int(rng.integers(0, 2 ** 31)))
    # the paper profiles each matmul for 5 minutes -> 10 averaged windows
    polls = [be.poll(30.0) for _ in range(10)]
    tpa = float(np.mean([p[0] for p in polls]))
    clk = float(np.mean([p[1] for p in polls]))

    ofu = ofu_point(tpa, clk, chip) * 100
    adj = adjusted_ofu(ofu, theo, execd)
    # ground truth App MFU: theoretical FLOPs over wall time vs peak, at
    # the TRUE mean clock — the OFU side only saw point samples of it, so
    # a residual clock-sampling error survives adjustment (paper: the ~1pp
    # systematic left on GB200 from 10 kHz sampling overhead), plus the
    # app's own wall-clock measurement noise.
    clock_frac = be.clock_model.mean_clock(busy / step) / chip.f_max_mhz
    app = theo / (step * peak) * clock_frac * 100
    app *= 1 + rng.normal(0, 0.004)
    return ofu, adj, app


def run(n_matmuls: int = N_MATMULS) -> list[Row]:
    rows = []
    for chip_name, prec in CONFIGS:
        chip = CHIPS[chip_name]
        rng = np.random.default_rng(hash((chip_name, prec)) % 2 ** 31)
        ofus, adjs, apps = [], [], []

        def sweep():
            for i in range(n_matmuls):
                o, a, t = _one(chip, prec, rng, i)
                ofus.append(o)
                adjs.append(a)
                apps.append(t)

        _, us = timed(sweep, repeat=1)
        for est, vals in (("OFU", ofus), ("AdjOFU", adjs)):
            rep = AccuracyReport.build(est, vals, apps)
            rows.append(Row(
                f"table2.{chip_name}.{prec}.{est}", us / n_matmuls,
                f"mae={rep.mae_pp:.2f}pp le2pp={rep.within_2pp * 100:.0f}% "
                f"le5pp={rep.within_5pp * 100:.0f}%"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
