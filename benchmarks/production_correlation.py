"""Paper Fig. 5 + Table III + §V-C: 608 production jobs, MFU-vs-OFU
correlation, per-scale error table, and the two FLOPs-miscalculation case
studies.

The fleet is the shared `repro.fleet.table3` fixture (the paper's exact
scale mix; the 288-GPU group runs the DeepSeek-style MoE with the buggy
`naive_moe` counter, 17 of the 256-GPU jobs the hybrid with
`naive_hybrid` — the ~82 affected jobs of §V-C).  This is the OFFLINE
half of the correlation story: batch rollups + `divergence.analyze` +
`correlation.analyze_correlation`.  `tools/fleet_correlate.py
--self-check` replays the SAME fixture through a live Collector and the
HTTP serve path and asserts the numbers match bucketwise.

Emits a `production_correlation` case into `BENCH_fleet.json` with the
headline numbers (r before/after exclusion, flagged counts, MAE).
"""
from __future__ import annotations

from benchmarks.common import Row, bench_case, merge_bench_json, timed
from repro.fleet import table3
from repro.fleet.correlation import analyze_correlation
from repro.fleet.divergence import analyze
from repro.fleet.jobs import JobSpec, simulate_job

_CASES: list[dict] = []


def build_fleet(seed: int = 0):
    """Offline JobPoints for the Fig. 5 sweep (shared fixture)."""
    return table3.build_fleet(seed)


def run() -> list[Row]:
    rows = []
    jobs, us = timed(table3.build_jobs, repeat=1)
    roll, mfu = table3.offline_rollups(jobs)
    points = roll.to_job_points()
    truth = table3.affected_ids(jobs)
    affected = set().union(*truth.values()) if truth else set()

    rep = analyze(points, flag_rel_err=table3.FLAG_REL_ERR)
    flagged = {p.job_id for p in rep.flagged}
    rows.append(Row(
        "fig5.correlation", us / len(points),
        f"n={len(points)} r_all={rep.r_all:.2f} "
        f"r_after_exclusion={rep.r_clean:.2f} flagged={len(rep.flagged)} "
        f"exact_match={flagged == affected} "
        f"mae={rep.mae_all * 100:.1f}pp "
        f"within10pp={rep.frac_within_10pp * 100:.0f}% "
        f"over20pp={rep.frac_over_20pp * 100:.1f}%"))
    flagged_variants = {}
    for p in rep.flagged:
        flagged_variants[p.flops_variant] = \
            flagged_variants.get(p.flops_variant, 0) + 1
    rows.append(Row("fig5.flagged_breakdown", 0.0,
                    " ".join(f"{k}={v}" for k, v in
                             sorted(flagged_variants.items()))))
    for chips, (n, mfu_pct, err) in sorted(rep.by_scale.items()):
        rows.append(Row(f"table3.gpus={chips}", 0.0,
                        f"jobs={n} mfu={mfu_pct * 100:.1f}% "
                        f"abs_err={err * 100:.1f}pp"))

    # ---- the correlation tier proper: OFU/MFU join + ratio detector ----
    crep, us_corr = timed(analyze_correlation, mfu, roll, repeat=1)
    cflagged = {f.job_id for f in crep.flagged}
    rows.append(Row(
        "correlation.miscalc_scan", us_corr / max(crep.n_jobs, 1),
        f"n={crep.n_jobs} r_all={crep.r_all:.2f} "
        f"r_after_exclusion={crep.r_clean:.2f} flagged={len(cflagged)} "
        f"exact_match={cflagged == affected} "
        f"mae={crep.mae * 100:.1f}pp"))

    bench_case(
        _CASES, "production_correlation", round(crep.r_clean, 3),
        "pearson_r",
        jobs=crep.n_jobs,
        r_all=round(crep.r_all, 3),
        r_after_exclusion=round(crep.r_clean, 3),
        flagged=len(cflagged),
        affected=len(affected),
        exact_match=bool(cflagged == affected and flagged == affected),
        mae_pp=round(crep.mae * 100, 2),
        build_wall_s=round(us / 1e6, 3),
    )

    # ---- §V-C case studies (before/after FLOPs-counter fixes) ----
    moe_bad = simulate_job(JobSpec("cs1", "deepseek-v3-671b", chips=288,
                                   flops_variant="naive_moe", true_duty=0.26,
                                   duration_s=240), max_devices=1)
    moe_fix = simulate_job(JobSpec("cs1f", "deepseek-v3-671b", chips=288,
                                   flops_variant="exact", true_duty=0.26,
                                   duration_s=240), max_devices=1)
    rows.append(Row(
        "sec5c.case1_moe_latent", 0.0,
        f"reported_mfu={moe_bad.app_mfu * 100:.2f}% ofu={moe_bad.ofu * 100:.2f}% "
        f"rel_err={abs(moe_bad.app_mfu - moe_bad.ofu) / moe_bad.ofu * 100:.1f}% "
        f"corrected_mfu={moe_fix.app_mfu * 100:.2f}% "
        f"corrected_rel_err={abs(moe_fix.app_mfu - moe_fix.ofu) / moe_fix.ofu * 100:.1f}%"))
    hyb_bad = simulate_job(JobSpec("cs2", "zamba2-7b", chips=1024,
                                   flops_variant="naive_hybrid",
                                   true_duty=0.2, duration_s=240),
                           max_devices=1)
    hyb_fix = simulate_job(JobSpec("cs2f", "zamba2-7b", chips=1536,
                                   flops_variant="exact", true_duty=0.2,
                                   duration_s=240), max_devices=1)
    rows.append(Row(
        "sec5c.case2_hybrid", 0.0,
        f"reported_mfu={hyb_bad.app_mfu * 100:.2f}% ofu={hyb_bad.ofu * 100:.2f}% "
        f"rel_err={abs(hyb_bad.app_mfu - hyb_bad.ofu) / hyb_bad.ofu * 100:.1f}% "
        f"fixed_mfu={hyb_fix.app_mfu * 100:.2f}% "
        f"fixed_rel_err={abs(hyb_fix.app_mfu - hyb_fix.ofu) / hyb_fix.ofu * 100:.1f}%"))

    path = merge_bench_json(_CASES)
    print(f"BENCH-JSON {path} cases={len(_CASES)}")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
