"""Paper Fig. 5 + Table III + §V-C: 608 production jobs, MFU-vs-OFU
correlation, per-scale error table, and the two FLOPs-miscalculation case
studies.

The fleet is reconstructed at the paper's exact scale mix (Table III row
counts).  The 288-GPU group runs the DeepSeek-style MoE with the buggy
`naive_moe` counter (case 1); a slice of 256-GPU jobs runs the hybrid with
`naive_hybrid` (case 2) — together the ~82 affected jobs of §V-C.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, timed
from repro.fleet.divergence import JobPoint, analyze
from repro.fleet.jobs import JobSpec, simulate_job

# Table III scale mix: (gpus, jobs)
SCALE_MIX = [(8, 6), (16, 48), (64, 52), (128, 48), (256, 76), (288, 65),
             (512, 144), (736, 11), (768, 57), (1024, 49), (1536, 10),
             (2944, 33), (5888, 9)]

HEALTHY_ARCHS = ["qwen3-4b", "granite-3-2b", "llama3.2-3b", "mamba2-780m",
                 "phi-3-vision-4.2b", "deepseek-moe-16b"]


def build_fleet(seed: int = 0) -> list[JobPoint]:
    rng = np.random.default_rng(seed)
    points = []
    hybrid_bugs = 17  # + 65 MoE jobs at 288 GPUs = 82 affected (paper)
    for chips, njobs in SCALE_MIX:
        for j in range(njobs):
            jid = f"{chips}g_{j}"
            duty = float(np.clip(rng.normal(0.28, 0.10), 0.08, 0.55))
            if chips == 288:      # §V-C case 1
                arch, variant = "deepseek-v3-671b", "naive_moe"
                # the affected MoE jobs ran at low true efficiency; with the
                # ~3x counter inflation they REPORTED ~40% MFU (Table III)
                duty = float(np.clip(rng.normal(0.13, 0.03), 0.06, 0.25))
            elif chips == 256 and hybrid_bugs > 0:   # §V-C case 2
                arch, variant = "zamba2-7b", "naive_hybrid"
                hybrid_bugs -= 1
            else:
                arch = HEALTHY_ARCHS[int(rng.integers(len(HEALTHY_ARCHS)))]
                variant = "exact"
            t = simulate_job(JobSpec(jid, arch, chips=chips,
                                     flops_variant=variant, true_duty=duty,
                                     duration_s=240,
                                     seed=int(rng.integers(2 ** 31))),
                             max_devices=1)
            # wall-clock measurement noise in the app's timing path shrinks
            # with scale (paper: small jobs show much larger abs err)
            noise = rng.normal(0, 0.25 / np.sqrt(max(chips / 64, 1)))
            mfu = max(t.app_mfu * (1 + noise), 0.01)
            points.append(JobPoint(jid, arch, chips, mfu, t.ofu, variant))
    return points


def run() -> list[Row]:
    rows = []
    points, us = timed(build_fleet, repeat=1)
    rep = analyze(points, flag_rel_err=0.45)
    rows.append(Row(
        "fig5.correlation", us / len(points),
        f"n={len(points)} r_all={rep.r_all:.2f} "
        f"r_after_exclusion={rep.r_clean:.2f} flagged={len(rep.flagged)} "
        f"mae={rep.mae_all * 100:.1f}pp "
        f"within10pp={rep.frac_within_10pp * 100:.0f}% "
        f"over20pp={rep.frac_over_20pp * 100:.1f}%"))
    flagged_variants = {}
    for p in rep.flagged:
        flagged_variants[p.flops_variant] = \
            flagged_variants.get(p.flops_variant, 0) + 1
    rows.append(Row("fig5.flagged_breakdown", 0.0,
                    " ".join(f"{k}={v}" for k, v in
                             sorted(flagged_variants.items()))))
    for chips, (n, mfu, err) in sorted(rep.by_scale.items()):
        rows.append(Row(f"table3.gpus={chips}", 0.0,
                        f"jobs={n} mfu={mfu * 100:.1f}% "
                        f"abs_err={err * 100:.1f}pp"))

    # ---- §V-C case studies (before/after FLOPs-counter fixes) ----
    moe_bad = simulate_job(JobSpec("cs1", "deepseek-v3-671b", chips=288,
                                   flops_variant="naive_moe", true_duty=0.26,
                                   duration_s=240), max_devices=1)
    moe_fix = simulate_job(JobSpec("cs1f", "deepseek-v3-671b", chips=288,
                                   flops_variant="exact", true_duty=0.26,
                                   duration_s=240), max_devices=1)
    rows.append(Row(
        "sec5c.case1_moe_latent", 0.0,
        f"reported_mfu={moe_bad.app_mfu * 100:.2f}% ofu={moe_bad.ofu * 100:.2f}% "
        f"rel_err={abs(moe_bad.app_mfu - moe_bad.ofu) / moe_bad.ofu * 100:.1f}% "
        f"corrected_mfu={moe_fix.app_mfu * 100:.2f}% "
        f"corrected_rel_err={abs(moe_fix.app_mfu - moe_fix.ofu) / moe_fix.ofu * 100:.1f}%"))
    hyb_bad = simulate_job(JobSpec("cs2", "zamba2-7b", chips=1024,
                                   flops_variant="naive_hybrid",
                                   true_duty=0.2, duration_s=240),
                           max_devices=1)
    hyb_fix = simulate_job(JobSpec("cs2f", "zamba2-7b", chips=1536,
                                   flops_variant="exact", true_duty=0.2,
                                   duration_s=240), max_devices=1)
    rows.append(Row(
        "sec5c.case2_hybrid", 0.0,
        f"reported_mfu={hyb_bad.app_mfu * 100:.2f}% ofu={hyb_bad.ofu * 100:.2f}% "
        f"rel_err={abs(hyb_bad.app_mfu - hyb_bad.ofu) / hyb_bad.ofu * 100:.1f}% "
        f"fixed_mfu={hyb_fix.app_mfu * 100:.2f}% "
        f"fixed_rel_err={abs(hyb_fix.app_mfu - hyb_fix.ofu) / hyb_fix.ofu * 100:.1f}%"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
