"""Emit the EXPERIMENTS.md §Dry-run and §Roofline tables from the dry-run
artifacts.  Usage:  PYTHONPATH=src:. python -m benchmarks.report [dir]"""
from __future__ import annotations

import glob
import json
import os
import sys

from benchmarks.roofline import roofline_terms


def load(d: str):
    recs = {}
    for p in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(p) as f:
            recs[os.path.basename(p)[:-5]] = json.load(f)
    return recs


def dryrun_table(recs) -> str:
    out = ["| cell | mesh | compile | peak mem/dev | HLO FLOPs/dev | "
           "coll bytes/dev | AG/AR/RS/A2A/CP count |",
           "|---|---|---|---|---|---|---|"]
    for tag, r in recs.items():
        if r.get("skipped"):
            out.append(f"| {r['arch']} {r['shape']} | "
                       f"{'2x16x16' if tag.endswith('multi') else '16x16'} | "
                       "— | — | — | — | skipped (sub-quadratic rule) |")
            continue
        c = r["hlo"]["collective_counts"]
        out.append(
            f"| {r['arch']} {r['shape']} | {r['mesh']} | "
            f"{r['compile_s']:.0f}s | "
            f"{r['memory']['peak_bytes'] / 2**30:.1f} GiB | "
            f"{r['hlo']['flops']:.2e} | "
            f"{sum(r['hlo']['collective_bytes'].values()):.2e} | "
            f"{c['all-gather']:.0f}/{c['all-reduce']:.0f}/"
            f"{c['reduce-scatter']:.0f}/{c['all-to-all']:.0f}/"
            f"{c['collective-permute']:.0f} |")
    return "\n".join(out)


def roofline_table(recs, mesh_suffix="_single") -> str:
    out = ["| cell | compute | memory | collective | dominant | "
           "6ND/HLO | roofline frac | mem/dev |",
           "|---|---|---|---|---|---|---|---|"]
    for tag, r in recs.items():
        if not tag.endswith(mesh_suffix):
            continue
        name = f"{r['arch']} {r['shape']}"
        if r.get("skipped"):
            out.append(f"| {name} | — | — | — | skipped | — | — | — |")
            continue
        t = roofline_terms(r)
        out.append(
            f"| {name} | {t['compute_s'] * 1e3:.1f} ms | "
            f"{t['memory_s'] * 1e3:.1f} ms | "
            f"{t['collective_s'] * 1e3:.1f} ms | "
            f"{t['dominant'].replace('_s', '')} | "
            f"{t['useful_ratio'] * 100:.0f}% | "
            f"{t['roofline_fraction'] * 100:.1f}% | "
            f"{t['peak_mem_gib']:.1f} GiB |")
    return "\n".join(out)


def main():
    d = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    recs = load(d)
    print("### Dry-run table\n")
    print(dryrun_table(recs))
    print("\n### Roofline table (single-pod 16x16)\n")
    print(roofline_table(recs))


if __name__ == "__main__":
    main()
