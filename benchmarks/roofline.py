"""Roofline analysis per (arch × shape × mesh) from the dry-run artifacts.

Three terms per cell (per-device, v5e constants):
  compute    = HLO_FLOPs / peak_FLOP/s          (197 TF/s bf16)
  memory     = HLO_traffic_bytes / HBM_bw       (819 GB/s)
  collective = collective_wire_bytes / link_bw  (50 GB/s/link)

HLO_FLOPs / traffic / collective bytes are the trip-count-aware per-device
numbers from repro.launch.hlo_analysis (raw XLA cost_analysis counts scan
bodies once — see EXPERIMENTS.md §Dry-run notes).  Also reported:
MODEL_FLOPS = 6·N_active·D and its ratio to HLO_FLOPs (remat/redundancy
visibility), and a one-line "what would move the dominant term".
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import Row
from repro.core.peaks import TPU_V5E

# prefer the optimized-layout artifacts when present (the §Perf "after");
# the paper-faithful baseline table lives in experiments/dryrun and
# EXPERIMENTS.md §Roofline
_DEFAULT = ("experiments/dryrun_opt"
            if os.path.isdir("experiments/dryrun_opt")
            else "experiments/dryrun")
DRYRUN_DIR = os.environ.get("DRYRUN_DIR", _DEFAULT)


def roofline_terms(rec: dict) -> dict:
    chips = rec["devices"]
    peak = TPU_V5E.peak_tflops("bf16") * 1e12
    hbm = TPU_V5E.hbm_gbps * 1e9
    link = TPU_V5E.ici_gbps * 1e9

    flops_dev = rec["hlo"]["flops"]
    bytes_dev = rec["hlo"]["traffic_bytes"]
    coll_dev = sum(rec["hlo"]["collective_bytes"].values())

    compute_s = flops_dev / peak
    memory_s = bytes_dev / hbm
    coll_s = coll_dev / link
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s}
    dom = max(terms, key=terms.get)
    bound = max(compute_s, memory_s, coll_s)

    model_flops = rec["model_flops_6nd"]
    # useful fraction: model FLOPs per device vs compiled FLOPs per device
    useful = (model_flops / chips) / flops_dev if flops_dev else 0.0
    # roofline fraction: time the useful math needs at peak / bound time
    frac = ((model_flops / chips) / peak) / bound if bound else 0.0
    return {**terms, "dominant": dom, "bound_s": bound,
            "model_flops": model_flops, "useful_ratio": useful,
            "roofline_fraction": frac,
            "peak_mem_gib": rec["memory"]["peak_bytes"] / 2 ** 30}


_ADVICE = {
    "compute_s": "lower executed FLOPs: cut remat recompute / padded tiles",
    "memory_s": "cut HBM traffic: fuse casts, shrink fp32 intermediates, "
                "bigger microbatch reuse",
    "collective_s": "restructure sharding: fewer/overlapped all-gathers, "
                    "reduce-scatter grads, SP boundary placement",
}


def run() -> list[Row]:
    rows = []
    cells = sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json")))
    if not cells:
        return [Row("roofline.missing", 0.0,
                    f"no dry-run artifacts in {DRYRUN_DIR}; run "
                    "python -m repro.launch.dryrun --all --both-meshes")]
    for path in cells:
        with open(path) as f:
            rec = json.load(f)
        tag = os.path.basename(path)[:-5]
        if rec.get("skipped"):
            rows.append(Row(f"roofline.{tag}", 0.0, "skipped (sub-quadratic "
                            "rule, DESIGN.md)"))
            continue
        t = roofline_terms(rec)
        rows.append(Row(
            f"roofline.{tag}", 0.0,
            f"compute={t['compute_s'] * 1e3:.2f}ms "
            f"memory={t['memory_s'] * 1e3:.2f}ms "
            f"collective={t['collective_s'] * 1e3:.2f}ms "
            f"dominant={t['dominant'].replace('_s', '')} "
            f"roofline_frac={t['roofline_fraction'] * 100:.1f}% "
            f"useful={t['useful_ratio'] * 100:.1f}% "
            f"mem={t['peak_mem_gib']:.1f}GiB | "
            f"{_ADVICE[t['dominant']]}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
