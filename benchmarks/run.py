"""Benchmark orchestrator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Modules:
  tile_quantization      Fig. 1   (tile/block-policy FLOP overhead)
  precision_scaling      Fig. 3   (speedup over baseline precision)
  clock_sampling         Table I  (scrape-interval noise)
  prediction_accuracy    Table II / Fig. 4 (OFU vs Adjusted OFU accuracy)
  production_correlation Fig. 5 / Table III / SecV-C (608-job fleet)
  operational            Fig. 6 / Fig. 7 / SecVI-C (case studies)
  fleet_engine           scalar-vs-vectorized simulation throughput
  roofline               assigned-arch roofline table (needs dry-run JSONs)
"""
import sys
import traceback


def main() -> None:
    from benchmarks import (clock_sampling, fleet_engine, operational,
                            precision_scaling, prediction_accuracy,
                            production_correlation, roofline,
                            tile_quantization)
    mods = [tile_quantization, precision_scaling, clock_sampling,
            prediction_accuracy, production_correlation, operational,
            fleet_engine, roofline]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    failures = 0
    for mod in mods:
        name = mod.__name__.split(".")[-1]
        if only and only != name:
            continue
        try:
            for row in mod.run():
                print(row.csv())
        except Exception as e:
            failures += 1
            print(f"{name},0,ERROR:{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(f"{failures} benchmark modules failed")


if __name__ == '__main__':
    main()
