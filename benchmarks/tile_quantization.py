"""Paper Fig. 1: FLOP overhead vs matrix size, aligned + random shapes,
per precision and block policy.

The closed-form sweep is exact for our Pallas GEMM (static grid == executed
FLOPs — asserted per-call against live kernel executions in interpret mode
at the small end of the sweep).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, timed
from repro.core.tile_quant import overhead, pick_policy

PRECISIONS = ("bf16", "int8", "fp32")


def _band(vals):
    return (f"mean={np.mean(vals) * 100:.2f}% max={np.max(vals) * 100:.2f}%")


def run(verify_kernel: bool = True) -> list[Row]:
    rows = []
    rng = np.random.default_rng(0)

    for prec in PRECISIONS:
        # aligned sweep (multiples of 128), N = 512 .. 16384
        big = [overhead(n, n, n, pick_policy(n, n, n, prec))
               for n in range(4096, 16385, 128)]
        small = [overhead(n, n, n, pick_policy(n, n, n, prec))
                 for n in range(128, 512, 128)]
        rows.append(Row(f"fig1.aligned.{prec}.N>=4096", 0.0, _band(big)))
        rows.append(Row(f"fig1.aligned.{prec}.N<512", 0.0, _band(small)))

        # random (not 128-aligned) shapes
        rand = []
        for _ in range(300):
            m, n, k = rng.integers(256, 12288, 3)
            rand.append(overhead(int(m), int(n), int(k),
                                 pick_policy(int(m), int(n), int(k), prec)))
        ge4096 = []
        for _ in range(300):
            m, n, k = rng.integers(4096, 12288, 3)
            ge4096.append(overhead(int(m), int(n), int(k),
                                   pick_policy(int(m), int(n), int(k), prec)))
        rows.append(Row(f"fig1.random.{prec}.all", 0.0, _band(rand)))
        rows.append(Row(f"fig1.random.{prec}.N>=4096", 0.0, _band(ge4096)))

    if verify_kernel:
        # live kernel executions: the profile must match the closed form
        import jax.numpy as jnp
        from repro.kernels import ops
        from repro.core.tile_quant import profiled_flops
        us = 0.0
        checked = 0
        for m, n, k in ((300, 200, 150), (129, 257, 513), (512, 384, 640)):
            x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
            y = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
            (out, prof), t = timed(ops.matmul, x, y, repeat=1)
            assert prof.profiled_flops == profiled_flops(m, n, k, prof.policy)
            us += t
            checked += 1
        rows.append(Row("fig1.kernel_grid_vs_closed_form", us / checked,
                        f"exact_match_on={checked} shapes (0 FLOP error)"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
