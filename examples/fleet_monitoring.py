"""Fleet monitoring walkthrough — the paper's §II/§V/§VI story end-to-end:

1. a mixed fleet of jobs (some with buggy FLOPs counters, one with an
   injected host-sync regression, one straggler) emits ONLY hardware
   counters (one fused multi-job engine pass);
2. the collector computes per-job OFU (Eq. 11);
3. divergence triage flags the FLOPs miscalculations (§V-C);
4. the regression detector + recovery service catch the 2.5x collapse
   (§VI-A) and the straggler monitor isolates the slow device;
5. the goodput rollup shows OFU covering 100% of chip-hours;
6. the same pipeline replays a RECORDED trace (no simulator in the loop)
   and tree-reduces per-host rollups into one fleet dashboard;
7. a continuous Collector daemon polls a SimulatorSource AND a
   TraceReplaySource round after round into a windowed rollup, retimes
   scrape intervals adaptively, and prints rolling regression alerts —
   the paper's live-dashboard deployment instead of batch ingestion;
8. the serving layer puts an HTTP dashboard API in front of it: a
   ServiceDaemon paces the collector on a (simulated) wall clock,
   publishing every round into a FleetStore, and a FleetClient queries
   fleet series / top regressions / alerts over stdlib HTTP — repeat
   polls ride generation ETags as 304s.

  PYTHONPATH=src python examples/fleet_monitoring.py
"""
import os
import sys
import tempfile

sys.path.insert(0, "src")

import numpy as np

from repro.core.ofu import ofu_series
from repro.fleet import (AdaptiveConfig, Collector, CollectorConfig,
                         JobSpec, JobStream, RecoveryService,
                         StragglerMonitor, StreamingRollup, analyze,
                         rollup, simulate_fleet)
from repro.fleet.distributed import host_partition, tree_reduce
from repro.fleet.divergence import JobPoint
from repro.fleet.regression import detect_regressions, scan_rollup
from repro.telemetry import (Event, SimulatorSource, StepProfile,
                             TraceReplaySource, write_trace)
from repro.telemetry.tracestore import archive_nbytes


def main():
    specs = [
        JobSpec("dense-a", "qwen3-4b", chips=256, true_duty=0.42,
                duration_s=1200),
        JobSpec("dense-b", "llama3.2-3b", chips=512, true_duty=0.38,
                duration_s=1200),
        JobSpec("ssm-pretrain", "mamba2-780m", chips=128, true_duty=0.33,
                duration_s=1200),
        # never onboarded to app-level MFU reporting (the 80% problem, §II)
        JobSpec("legacy-job", "deepseek-moe-16b", chips=512, true_duty=0.22,
                duration_s=1200, flops_variant="none"),
        # §V-C case 1: MoE with latent projections the counter misses
        JobSpec("moe-16b-exp3", "deepseek-v3-671b", chips=288,
                flops_variant="naive_moe", true_duty=0.25, duration_s=1200),
        # §V-C case 2: hybrid billed as attention+MLP everywhere
        JobSpec("hybrid-8b", "zamba2-7b", chips=256,
                flops_variant="naive_hybrid", true_duty=0.28,
                duration_s=1200),
        # §VI-A: debug flag merged to main -> host-sync serialization
        JobSpec("embodied-agent", "phi-3-vision-4.2b", chips=256,
                true_duty=0.45, duration_s=1200,
                events=[Event(600, 1200, slowdown=2.5)]),
        # a straggling device in an otherwise healthy job
        JobSpec("straggly", "granite-3-2b", chips=64, true_duty=0.40,
                duration_s=1200, straggler_sigma=0.0, seed=9),
    ]

    print("== scraping fleet (30 s interval, hardware counters only) ==")
    # vectorized engine: every sampled device of every job in one pass
    tels = {t.spec.job_id: t
            for t in simulate_fleet(specs, max_devices=32)}
    points = [JobPoint(t.spec.job_id, t.spec.arch, t.spec.chips,
                       t.app_mfu, t.ofu, t.spec.flops_variant)
              for t in tels.values()]
    for p in points:
        print(f"  {p.job_id:16s} chips={p.chips:4d} "
              f"app_mfu={p.mfu * 100:5.1f}% ofu={p.ofu * 100:5.1f}%")

    print("\n== divergence triage (FLOPs miscalculation signature) ==")
    rep = analyze(points)
    for p in rep.flagged:
        print(f"  FLAGGED {p.job_id}: app-reported {p.mfu * 100:.1f}% vs "
              f"OFU {p.ofu * 100:.1f}% (rel err {p.rel_err * 100:.0f}%) -> "
              "audit the framework FLOPs formula, or check for a runtime "
              "regression (below)")

    print("\n== regression detection + autonomous recovery (§VI-A) ==")
    svc = RecoveryService(factor_threshold=1.8, sustain_samples=3,
                          cooldown_samples=100)
    s = tels["embodied-agent"].device_series[0]
    ofu = ofu_series(s.tpa, s.clock_mhz)
    for i, v in enumerate(ofu):
        a = svc.observe("embodied-agent", float(v))
        if a:
            print(f"  recovery action at sample {i}: {a.reason} "
                  f"(factor {a.factor:.2f}x) -> restart from checkpoint")
    print(f"  ofu before regression: {ofu[:20].mean() * 100:.1f}%  "
          f"during: {ofu[25:].mean() * 100:.1f}%")

    print("\n== straggler isolation ==")
    per_dev = np.array([se.tpa.mean()
                        for se in tels["straggly"].device_series] + [0.11])
    flagged = StragglerMonitor().flag(per_dev)
    print(f"  device duty cycles: {np.round(per_dev, 3)} -> "
          f"flag devices {flagged}")

    print("\n== streaming rollup (per-job / per-precision / fleet) ==")
    roll = StreamingRollup(bucket_s=300)
    for t in tels.values():
        roll.add_job(t)
    print(" ", roll.summary())
    f = roll.fleet_stats()
    for b in range(roll.n_buckets):
        print(f"  t={f.centers_s[b]:6.0f}s p10={f.percentiles[10][b] * 100:5.1f}% "
              f"p50={f.percentiles[50][b] * 100:5.1f}% "
              f"p90={f.percentiles[90][b] * 100:5.1f}%")
    # the bucketed per-job series feeds the same regression detector
    regs = detect_regressions(roll.job_ofu("embodied-agent"),
                              window=2, min_duration=1)
    detail = f"factor {regs[0].factor:.2f}x" if regs else "none found"
    print(f"  bucketed detector on embodied-agent: "
          f"{len(regs)} regression(s), {detail}")

    print("\n== goodput rollup (§II) ==")
    print(" ", rollup(list(tels.values())).summary())

    print("\n== trace replay (source-agnostic pipeline) ==")
    # record the regressed job's counters, then drive the SAME rollup +
    # detector from the replayed file — no simulator in the loop
    with tempfile.NamedTemporaryFile(suffix=".jsonl", delete=False) as fh:
        trace_path = fh.name
    try:
        write_trace(tels["embodied-agent"].grid, trace_path)
        replay_roll = StreamingRollup(bucket_s=120)
        replay_roll.add_grid("replayed-agent",
                             TraceReplaySource(trace_path).scrapes(),
                             group="bf16", chips=256,
                             app_mfu=tels["embodied-agent"].app_mfu)
        found = scan_rollup(replay_roll, window=2, min_duration=1)
        for jid, regs in found.items():
            print(f"  {trace_path} -> {jid}: {len(regs)} regression(s), "
                  f"factor {regs[0].factor:.2f}x")

        # the fleet-scale archive path: the same trace as a chunked
        # COLUMNAR store (telemetry/tracestore.py) — smaller on disk,
        # and replayable in O(chunk) memory instead of O(trace)
        ctr_path = trace_path + ".ctr"
        write_trace(tels["embodied-agent"].grid, ctr_path,
                    chunk_samples=8)
        ctr_src = TraceReplaySource(ctr_path)
        ctr_roll = StreamingRollup(bucket_s=120)
        while not ctr_src.exhausted:          # stream, chunk by chunk
            grid = ctr_src.poll(240)
            if grid.tpa.size:
                ctr_roll.add_grid("archived-agent", grid, group="bf16",
                                  chips=256)
        rd = ctr_src.reader
        jsonl_b = os.path.getsize(trace_path)
        ctr_b = archive_nbytes(ctr_path)
        total = tels["embodied-agent"].grid.tpa.size
        found = scan_rollup(ctr_roll, window=2, min_duration=1)
        print(f"  columnar archive: {ctr_b:,} B vs {jsonl_b:,} B jsonl "
              f"({jsonl_b / ctr_b:.1f}x smaller), peak resident "
              f"{rd.peak_resident_samples}/{total} samples, regression "
              f"still detected: {'archived-agent' in found}")
        for f in os.listdir(ctr_path):
            os.unlink(os.path.join(ctr_path, f))
        os.rmdir(ctr_path)
    finally:
        os.unlink(trace_path)

    print("\n== distributed rollup (per-host merge -> fleet dashboard) ==")
    hosts = host_partition(list(tels.values()), 3)
    blobs = []
    for h, host_tels in enumerate(hosts):
        local = StreamingRollup(bucket_s=300)
        for t in host_tels:
            local.add_job(t)
        blob = local.to_bytes()
        blobs.append(blob)
        print(f"  host{h}: {len(host_tels)} jobs -> {len(blob)} B snapshot")
    fleet = tree_reduce(blobs)
    print(" ", fleet.summary())
    same = np.allclose(fleet.fleet_stats().mean, roll.fleet_stats().mean,
                       equal_nan=True)
    print(f"  bucketwise identical to single-process rollup: {same}")

    print("\n== continuous monitoring (collector daemon, windowed) ==")
    # the same pipeline as a LONG-LIVED loop: poll sources incrementally,
    # fold into a bounded windowed rollup, detect + alert every round,
    # and retime scrape intervals adaptively (Table I tradeoff).  One
    # stream is generative; one replays the recorded trace from above —
    # the collector never knows the difference.
    prof = StepProfile(mxu_time_s=0.84, step_time_s=2.0)
    with tempfile.NamedTemporaryFile(suffix=".csv", delete=False) as fh:
        replay_path = fh.name
    try:
        write_trace(tels["embodied-agent"].grid, replay_path)
        streams = [
            JobStream("live-healthy",
                      SimulatorSource(prof, duration_s=2400, interval_s=30,
                                      n_devices=8, seed=11),
                      chips=256, group="bf16"),
            JobStream("live-regressing",
                      SimulatorSource(prof, duration_s=2400, interval_s=30,
                                      n_devices=8, seed=12,
                                      events=[Event(1350, 2400,
                                                    slowdown=2.5)]),
                      chips=512, group="bf16"),
            JobStream("replayed-agent", TraceReplaySource(replay_path),
                      chips=256, group="bf16",
                      app_mfu=tels["embodied-agent"].app_mfu),
        ]
        col = Collector(streams, CollectorConfig(
            round_s=300, bucket_s=150, retain=8,
            detector={"window": 3, "min_duration": 1},
            adaptive=AdaptiveConfig(min_interval_s=7.5)))
        for rep in col.run():
            line = (f"  round {rep.round_idx} t={rep.t_s:5.0f}s "
                    f"samples={rep.samples:4d} "
                    f"interval[live-regressing]="
                    f"{rep.intervals['live-regressing']:4.1f}s")
            print(line)
            for a in rep.alerts:
                print(f"    ALERT {a.summary()}")
        print(" ", col.rollup.summary())
        at = col.rollup.job_alltime("live-regressing")
        print(f"  live-regressing all-time OFU (survives eviction): "
              f"{at['mean'] * 100:.1f}%")
    finally:
        os.unlink(replay_path)

    print("\n== serving the fleet (daemon + HTTP dashboard API) ==")
    # the same continuous loop, deployed: a ServiceDaemon paces rounds on
    # the wall clock (simulated here, so the example finishes instantly)
    # and publishes each one into a FleetStore; dashboards poll a
    # stdlib-only JSON API whose ETags make unchanged polls free (304)
    from repro.serve import (FleetAPIServer, FleetClient, ServiceDaemon,
                             SimClock)
    streams = [
        JobStream("served-healthy",
                  SimulatorSource(prof, duration_s=2400, interval_s=30,
                                  n_devices=8, seed=31), chips=256),
        JobStream("served-regressing",
                  SimulatorSource(prof, duration_s=2400, interval_s=30,
                                  n_devices=8, seed=32,
                                  events=[Event(1200, 2400,
                                                slowdown=2.5)]),
                  chips=512),
    ]
    clk = SimClock()
    daemon = ServiceDaemon(
        Collector(streams,
                  CollectorConfig(round_s=300, bucket_s=300, retain=8,
                                  detector={"window": 3,
                                            "min_duration": 1})),
        clock=clk.monotonic, sleep=clk.sleep)
    with daemon, FleetAPIServer(daemon.store) as server:
        daemon.run()
        client = FleetClient(server.url)
        fleet = client.fleet()
        print(f"  GET {server.url}/v1/fleet -> generation "
              f"{fleet['generation']}, weighted OFU "
              f"{fleet['weighted_ofu'] * 100:.1f}%")
        worst = client.top_regressions(k=3, window=3, min_duration=1)
        for reg in worst["regressions"]:
            print(f"  top regression: {reg['job_id']} "
                  f"factor {reg['factor']:.2f}x "
                  f"(bucket {reg['start_bucket']}, "
                  f"{'ongoing' if reg['ongoing'] else 'recovered'})")
        alerts = client.alerts()
        print(f"  /v1/alerts: {alerts['total']} fired, "
              f"open episodes {alerts['active_episodes']}")
        client.fleet()
        print(f"  repeat poll: {client.hits_304} x 304 via ETag "
              f"(store cache hits={daemon.store.cache_hits})")


if __name__ == "__main__":
    main()
