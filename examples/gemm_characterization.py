"""Controlled-GEMM characterization (paper §IV) against the live Pallas
kernel: tile quantization, block-policy selection, and the adjusted-OFU
pipeline — executed for real in interpret mode.

  PYTHONPATH=src python examples/gemm_characterization.py
"""
import sys

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.core.ofu import adjusted_ofu
from repro.core.tile_quant import pick_policy
from repro.kernels import ops

SHAPES = [(300, 200, 150), (512, 512, 512), (640, 1000, 480),
          (1100, 900, 700)]


def main():
    rng = np.random.default_rng(0)
    print(f"{'M,N,K':>16s} {'policy':>12s} {'FLOPs 2MNK':>12s} "
          f"{'executed':>12s} {'overhead':>9s} {'OFU':>6s} {'adjOFU':>7s}")
    for M, N, K in SHAPES:
        x = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
        y = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
        out, prof = ops.matmul(x, y)
        # pretend the device reported 60% duty at 97% clock while running
        # this shape: raw OFU includes padded-tile work; Eq. 8 removes it
        raw_ofu = 0.60 * 0.97 * 100
        adj = adjusted_ofu(raw_ofu, prof.theoretical_flops,
                           prof.profiled_flops)
        print(f"{f'{M},{N},{K}':>16s} {prof.policy.name:>12s} "
              f"{prof.theoretical_flops:>12,d} {prof.profiled_flops:>12,d} "
              f"{prof.overhead * 100:>8.2f}% {raw_ofu:>5.1f}% {adj:>6.1f}%")
    print("\nexecuted FLOPs are exact: the Pallas grid is static "
          "(closed form == grid, 0-FLOP error; cf. paper's <1000-FLOP nvJet "
          "match).")


if __name__ == "__main__":
    main()
