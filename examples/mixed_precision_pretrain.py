"""Mixed-precision pretraining telemetry (paper §VI-B, Fig. 7).

A hybrid Mamba-Transformer pretrain alternates between mixed precision
(bf16+int8) and bf16-only debugging periods.  Observed TFLOP/s stays
constant, so the app-reported MFU jumps whenever the effective peak
(Eq. 12 harmonic mean) drops — and OFU, which never sees the numeric
format, tracks the same jump from the hardware side.

  PYTHONPATH=src python examples/mixed_precision_pretrain.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core.ofu import effective_peak, ofu_series, pearson_r
from repro.fleet.jobs import JobSpec, simulate_job

MODES = {"mixed (bf16+int8)": {"bf16": 0.4, "int8": 0.6},
         "bf16-only (debug)": {"bf16": 1.0}}
TPUT = 52.0  # constant achieved TFLOP/s per chip across both modes


def main():
    print(f"constant observed throughput: {TPUT:.0f} TFLOP/s/chip "
          f"on 6,144 chips\n")
    series_m, series_o = [], []
    for name, mix in MODES.items():
        peff = effective_peak(mix)
        mfu = TPUT / peff
        tel = simulate_job(JobSpec(name, "zamba2-7b", chips=6144,
                                   precisions=mix, true_duty=mfu,
                                   duration_s=900), max_devices=2)
        print(f"{name:20s} P_eff={peff:6.1f} TF/s  "
              f"app_mfu={mfu * 100:5.1f}%  ofu={tel.ofu * 100:5.1f}%  "
              f"gap={(abs(tel.ofu - mfu)) * 100:.2f}pp")
        s = tel.device_series[0]
        series_o.extend(ofu_series(s.tpa, s.clock_mhz))
        series_m.extend([mfu] * len(s.tpa))

    r = pearson_r(series_m, series_o)
    print(f"\nOFU tracks the precision-mode MFU shift with no knowledge of "
          f"the numeric format (pointwise r={r:.3f}).")


if __name__ == "__main__":
    main()
