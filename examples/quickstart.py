"""Quickstart: train a small model end-to-end with OFU monitoring,
atomic checkpointing, and crash recovery — the full §VI loop on CPU.

  PYTHONPATH=src python examples/quickstart.py [--steps 60] [--arch qwen3-4b]

The default runs the reduced same-family config of the chosen architecture.
On a real v5e pod, drop --smoke-scale and point --arch at any of the ten
assigned architectures (see src/repro/configs/).

Fleet engine quickstart (vectorized telemetry, repro.fleet.engine):
simulate thousands of devices x hours of 30 s scrapes in well under a
second, then roll them up into streaming per-job/per-precision/fleet
OFU percentiles:

    from repro.fleet import JobSpec, StreamingRollup, simulate_fleet

    specs = [JobSpec(f"job{i}", "granite-3-2b", chips=1000,
                     true_duty=0.35, duration_s=3600) for i in range(4)]
    roll = StreamingRollup(bucket_s=300)
    for tel in simulate_fleet(specs, max_devices=1000):
        roll.add_job(tel)
    print(roll.summary())                    # fleet-wide weighted OFU
    series = roll.job_ofu("job0")            # feed to detect_regressions
    p50 = roll.fleet_stats().percentiles[50]  # bucketed fleet median

`simulate_fleet(..., engine="scalar")` selects the per-device reference
backend instead; `benchmarks/fleet_engine.py` measures the gap (~45x on
a laptop core, ~15M simulated device-seconds per wall-second).  See
examples/fleet_monitoring.py for the full §V/§VI monitoring loop.
"""
import argparse
import json
import sys

sys.path.insert(0, "src")

from repro.configs.base import ShapeSpec, get_config
from repro.flops.accounting import step_flops
from repro.optim import adamw
from repro.train.trainer import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_quickstart")
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    shape = ShapeSpec("quickstart", args.seq, args.batch, "train")
    print(f"training {cfg.name} ({cfg.family}) seq={args.seq} "
          f"batch={args.batch} for {args.steps} steps")

    trainer = Trainer(
        cfg, shape,
        opt_cfg=adamw.OptConfig(peak_lr=1e-3, warmup_steps=5,
                                decay_steps=args.steps),
        train_cfg=TrainConfig(total_steps=args.steps, ckpt_every=10,
                              ckpt_dir=args.ckpt_dir, log_every=5),
        flops_per_step=step_flops(cfg, shape, executed=True).total)
    out = trainer.run()

    if out["final_loss"] is None:
        print(f"checkpoint at step {out['final_step']} already >= "
              f"--steps {args.steps}: nothing to do (delete "
              f"{args.ckpt_dir} or raise --steps to continue training).")
        return
    print(json.dumps(out["metrics"][-3:], indent=1, default=float))
    print(f"final loss {out['final_loss']:.3f} after {out['final_step']} "
          f"steps; OFU per step logged via the simulated counter backend.")
    print("kill it mid-run and re-run: it resumes from the atomic "
          "checkpoint with an identical data stream.")


if __name__ == "__main__":
    main()
