from repro.configs.base import (  # noqa: F401
    SHAPES, ModelConfig, ShapeSpec, cache_specs, get_config, input_specs,
    list_configs, make_inputs, register,
)
