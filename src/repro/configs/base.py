"""Model/shape configuration system.

Every assigned architecture is a `ModelConfig` (exact published numbers) plus a
`smoke()` reduction of the same family for CPU tests.  Input shapes are the four
assigned (seq_len, global_batch, kind) cells; `input_specs()` produces
ShapeDtypeStruct stand-ins (no allocation) for the dry-run.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description.  Families:

    dense    -- GQA transformer (llama/qwen/granite/nemotron)
    moe      -- fine-grained MoE w/ shared experts (deepseek-moe)
    mla_moe  -- MLA attention + MoE + MTP (deepseek-v3)
    ssm      -- Mamba2 / SSD, attention-free
    hybrid   -- Mamba2 backbone + periodic shared attention (zamba2)
    encdec   -- encoder-decoder (whisper; conv frontend stubbed)
    vlm      -- dense backbone + patch-embedding stub frontend (phi-3-vision)
    """

    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- MoE ---
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    first_dense_layers: int = 0  # deepseek: leading dense MLP layers

    # --- MLA (deepseek-v3) ---
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_dim: int = 0
    qk_nope_dim: int = 0
    v_head_dim: int = 0
    mtp_depth: int = 0  # multi-token-prediction blocks

    # --- SSM (mamba2 / zamba2) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_ngroups: int = 1
    ssm_chunk: int = 256
    conv_width: int = 4
    attn_every: int = 0  # hybrid: shared attention block every N layers

    # --- enc-dec (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 1500  # whisper 30s audio -> 1500 frames (stub frontend)

    # --- vlm (phi-3-vision) ---
    num_image_tokens: int = 0

    # --- misc ---
    qk_norm: bool = False
    activation: str = "silu"  # silu | gelu | relu2
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"

    # remat policy: "nothing" | "dots" | "none"
    remat: str = "nothing"

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # ---- derived ----
    @property
    def d_inner(self) -> int:  # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """True if long_500k is runnable (SSM/hybrid: O(1)-state decode)."""
        return self.family in ("ssm", "hybrid")

    def supports_shape(self, shape: ShapeSpec) -> bool:
        if shape.name == "long_500k" and not self.sub_quadratic:
            return False  # pure full-attention archs skip long-context decode
        return True

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        kw = dict(
            num_layers=2,
            d_model=64,
            num_heads=4,
            num_kv_heads=max(1, min(self.num_kv_heads, 2)) if self.num_kv_heads else 0,
            head_dim=16,
            d_ff=128,
            vocab_size=256,
        )
        if self.num_experts:
            kw.update(num_experts=4, top_k=2, d_ff_expert=32,
                      num_shared_experts=min(self.num_shared_experts, 1),
                      first_dense_layers=min(self.first_dense_layers, 1))
        if self.q_lora_rank or self.kv_lora_rank:
            kw.update(q_lora_rank=32, kv_lora_rank=16, qk_rope_dim=8,
                      qk_nope_dim=8, v_head_dim=16, head_dim=16)
        if self.mtp_depth:
            kw.update(mtp_depth=1)
        if self.ssm_state:
            kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=8)
        if self.attn_every:
            kw.update(attn_every=2, num_layers=4)
        if self.encoder_layers:
            kw.update(encoder_layers=2, encoder_seq=16)
        if self.num_image_tokens:
            kw.update(num_image_tokens=4)
        return replace(self, name=self.name + "-smoke", **kw)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def list_configs() -> list[str]:
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def _load_all() -> None:
    # import side-effect registers each arch
    from repro.configs import (  # noqa: F401
        deepseek_moe_16b, deepseek_v3_671b, qwen3_4b, nemotron_4_340b,
        granite_3_2b, llama3_2_3b, whisper_small, phi_3_vision_4_2b,
        mamba2_780m, zamba2_7b,
    )


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------
def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of one (arch, shape) cell.

    train/prefill : tokens + labels (+ frontend stubs)
    decode        : one new token per sequence + the KV/SSM caches at seq_len
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f = jnp.dtype(cfg.dtype)

    def sds(shp, dt=f):
        return jax.ShapeDtypeStruct(shp, dt)

    if shape.kind in ("train", "prefill"):
        # VLM: image patches occupy the first num_image_tokens positions of the
        # assigned seq_len, so total sequence length stays exactly S.
        S_txt = S - cfg.num_image_tokens if cfg.family == "vlm" else S
        specs = {"tokens": sds((B, S_txt), i32)}
        if shape.kind == "train":
            specs["labels"] = sds((B, S), i32)
        if cfg.family == "vlm":
            # modality frontend is a STUB: precomputed patch embeddings
            specs["patch_embeds"] = sds((B, cfg.num_image_tokens, cfg.d_model))
        if cfg.family == "encdec":
            # conv frontend stub: precomputed mel-frame embeddings
            specs["frame_embeds"] = sds((B, cfg.encoder_seq, cfg.d_model))
        return specs

    # ---- decode: one new token against caches of length S ----
    specs = {"tokens": sds((B, 1), i32), "cache_index": sds((), i32)}
    specs.update(cache_specs(cfg, B, S, f))
    if cfg.family == "encdec":
        specs["encoder_out"] = sds((B, cfg.encoder_seq, cfg.d_model))
    return specs


def cache_specs(cfg: ModelConfig, B: int, S: int, dt) -> dict:
    """Decode-cache ShapeDtypeStructs (stacked over layers)."""
    def sds(shp, d=dt):
        return jax.ShapeDtypeStruct(shp, d)

    L = cfg.num_layers
    specs: dict = {}
    if cfg.family in ("dense", "moe", "mla_moe", "vlm", "encdec", "hybrid"):
        if cfg.family == "mla_moe":
            # MLA compressed cache: latent c_kv + decoupled rope key
            specs["kv_cache"] = sds((L, B, S, cfg.kv_lora_rank + cfg.qk_rope_dim))
        elif cfg.family == "hybrid":
            n_attn = len([i for i in range(L) if i % cfg.attn_every == 0])
            specs["k_cache"] = sds((n_attn, B, S, cfg.num_kv_heads, cfg.head_dim))
            specs["v_cache"] = sds((n_attn, B, S, cfg.num_kv_heads, cfg.head_dim))
        else:
            nl = L if cfg.family != "encdec" else cfg.num_layers
            specs["k_cache"] = sds((nl, B, S, cfg.num_kv_heads, cfg.head_dim))
            specs["v_cache"] = sds((nl, B, S, cfg.num_kv_heads, cfg.head_dim))
    if cfg.family in ("ssm", "hybrid"):
        specs["ssm_state"] = sds((L, B, cfg.ssm_nheads, cfg.ssm_head_dim,
                                  cfg.ssm_state), jnp.float32)
        specs["conv_state"] = sds(
            (L, B, cfg.conv_width - 1,
             cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state))
    return specs


def make_inputs(cfg: ModelConfig, shape: ShapeSpec, seed: int = 0) -> dict:
    """Materialized inputs for smoke tests / examples (small shapes only)."""
    specs = input_specs(cfg, shape)
    rng = np.random.default_rng(seed)
    out = {}
    for k, s in specs.items():
        if np.issubdtype(s.dtype, np.integer):
            if k == "cache_index":
                out[k] = jnp.asarray(min(shape.seq_len - 1, 7), s.dtype)
            else:
                out[k] = jnp.asarray(
                    rng.integers(0, cfg.vocab_size, s.shape), s.dtype)
        else:
            out[k] = jnp.asarray(rng.standard_normal(s.shape) * 0.02, s.dtype)
    return out
