"""DeepSeekMoE-16B [arXiv:2401.06066; hf] — fine-grained MoE, 2 shared + 64 routed top-6."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,            # per-expert FFN width (fine-grained)
    vocab_size=102_400,
    num_experts=64,
    num_shared_experts=2,
    top_k=6,
    d_ff_expert=1408,
    first_dense_layers=1,  # layer 0 is a dense MLP (d_ff = 4*... use 10944)
    activation="silu",
))
