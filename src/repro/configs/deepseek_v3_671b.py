"""DeepSeek-V3 671B [arXiv:2412.19437; hf] — MLA, 1 shared + 256 routed top-8, MTP."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-v3-671b",
    family="mla_moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,      # MLA: all heads share one compressed latent cache
    d_ff=18_432,           # dense-MLP width for the first_dense_layers
    vocab_size=129_280,
    num_experts=256,
    num_shared_experts=1,
    top_k=8,
    d_ff_expert=2048,
    first_dense_layers=3,
    # MLA geometry (paper table 1)
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_rope_dim=64,
    qk_nope_dim=128,
    v_head_dim=128,
    head_dim=192,          # qk_nope + qk_rope
    mtp_depth=1,
    activation="silu",
))
