"""Mamba2-780M [arXiv:2405.21060; unverified] — SSD (state-space duality), attention-free."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50_280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,      # -> 48 SSD heads
    ssm_ngroups=1,
    ssm_chunk=256,
    conv_width=4,
    tie_embeddings=True,
))
