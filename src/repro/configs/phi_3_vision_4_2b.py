"""Phi-3-vision-4.2B [hf:microsoft/Phi-3-vision-128k-instruct; hf] — phi3-mini
backbone; CLIP patch frontend is a STUB (input_specs provides patch embeddings)."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32_064,
    num_image_tokens=576,   # 24x24 CLIP-L patch grid (stubbed)
    activation="silu",
))
