"""Whisper-small [arXiv:2212.04356; unverified] — enc-dec; conv frontend is a STUB
(input_specs provides precomputed 1500-frame embeddings)."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-small",
    family="encdec",
    num_layers=12,          # decoder layers
    encoder_layers=12,
    encoder_seq=1500,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51_865,
    activation="gelu",
))
