"""Zamba2-7B [arXiv:2411.15242; unverified] — Mamba2 backbone + shared attention blocks."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    head_dim=112,
    d_ff=14_336,          # shared-attention block MLP width
    vocab_size=32_000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,      # -> 112 SSD heads
    ssm_ngroups=2,
    ssm_chunk=256,
    conv_width=4,
    attn_every=6,         # shared attention block applied every 6 layers
))
