"""OFU — the paper's primary contribution: a hardware-counter-derived,
precision-agnostic FLOP-utilization metric with characterized error terms."""
from repro.core.ofu import (  # noqa: F401
    AccuracyReport, adjusted_ofu, effective_peak, hist_percentile, mae,
    mfu_from_throughput, ofu_mean, ofu_point, ofu_series, pct_within,
    pearson_r,
)
from repro.core.peaks import CHIPS, DEFAULT_CHIP, TPU_V5E, ChipSpec  # noqa: F401
from repro.core.tile_quant import (  # noqa: F401
    TilePolicy, correction_factor, effective_dims, overhead, pick_policy,
    profiled_flops, scale_factor_overhead, theoretical_flops,
)
