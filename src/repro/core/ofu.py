"""Overall FLOP Utilization (OFU) — the paper's core metric, Eq. 1/8/9/12.

OFU consumes ONLY hardware-counter streams (matrix-pipe duty cycle + clock
point samples); it never sees model architecture.  Everything model-aware
(App MFU, FLOPs counters) lives in repro.flops — keeping the paper's trust
boundary between the two estimators.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Optional, Sequence

import numpy as np

from repro.core.peaks import DEFAULT_CHIP, ChipSpec


# ---------------------------------------------------------------------------
# Eq. 1: OFU = TPA × f / f_max
# ---------------------------------------------------------------------------
def ofu_point(tpa: float, clock_mhz: float,
              chip: ChipSpec = DEFAULT_CHIP) -> float:
    """One OFU reading from one (TPA, clock) counter pair, in [0, 1]."""
    return float(tpa) * float(clock_mhz) / chip.f_max_mhz


def ofu_series(tpa: np.ndarray, clock_mhz: np.ndarray,
               chip: ChipSpec = DEFAULT_CHIP) -> np.ndarray:
    """Eq. 11: element-wise OFU over aligned counter series."""
    return np.asarray(tpa, float) * np.asarray(clock_mhz, float) / chip.f_max_mhz


def ofu_mean(tpa: np.ndarray, clock_mhz: np.ndarray,
             chip: ChipSpec = DEFAULT_CHIP) -> float:
    """Job-level OFU: mean over all devices × time samples (paper Eq. 11)."""
    return float(np.mean(ofu_series(tpa, clock_mhz, chip)))


# ---------------------------------------------------------------------------
# Eq. 8: tile-quantization-adjusted OFU
# ---------------------------------------------------------------------------
def adjusted_ofu(ofu: float, theoretical_flops: float,
                 profiled_flops: float) -> float:
    """OFU_adj = OFU × FLOPs_theoretical / FLOPs_profiled."""
    if profiled_flops <= 0:
        return ofu
    return ofu * theoretical_flops / profiled_flops


# ---------------------------------------------------------------------------
# Eq. 12: effective peak for mixed precision (FLOPs-weighted harmonic mean)
# ---------------------------------------------------------------------------
def effective_peak(flops_by_precision: dict[str, float],
                   chip: ChipSpec = DEFAULT_CHIP) -> float:
    """P_eff = Σ F_i / Σ (F_i / P_i) in TFLOP/s."""
    num = sum(flops_by_precision.values())
    den = sum(f / chip.peak_tflops(p)
              for p, f in flops_by_precision.items() if f > 0)
    return num / den if den else chip.peak_tflops()


def mfu_from_throughput(tflops_per_chip: float, peak_tflops: float) -> float:
    """Eq. 10 (normalized to one chip): achieved / peak."""
    return tflops_per_chip / peak_tflops


# ---------------------------------------------------------------------------
# Eq. 9 + §V-A accuracy statistics
# ---------------------------------------------------------------------------
def mae(estimates: Sequence[float], truth: Sequence[float]) -> float:
    e, t = np.asarray(estimates, float), np.asarray(truth, float)
    return float(np.mean(np.abs(e - t)))


def pct_within(estimates: Sequence[float], truth: Sequence[float],
               bound_pp: float) -> float:
    """Fraction of samples with |error| <= bound (same units as inputs)."""
    e, t = np.asarray(estimates, float), np.asarray(truth, float)
    return float(np.mean(np.abs(e - t) <= bound_pp))


def hist_percentile(edges: np.ndarray, counts: np.ndarray,
                    q: float) -> float:
    """Percentile q (0–100) from a weighted histogram, by linear
    interpolation within the containing bin.

    This is the streaming-rollup primitive: fleet-scale OFU percentiles are
    maintained as fixed-size per-bucket histograms (O(1) memory per time
    bucket regardless of device count), and read out through this function.
    Returns NaN for an empty histogram.
    """
    counts = np.asarray(counts, float)
    edges = np.asarray(edges, float)
    total = counts.sum()
    if total <= 0:
        return float("nan")
    cum = np.cumsum(counts)
    target = total * min(max(q, 0.0), 100.0) / 100.0
    i = int(np.searchsorted(cum, target))
    i = min(i, len(counts) - 1)
    prev = cum[i - 1] if i > 0 else 0.0
    frac = (target - prev) / counts[i] if counts[i] > 0 else 0.0
    return float(edges[i] + frac * (edges[i + 1] - edges[i]))


def hist_percentile_grid(edges: np.ndarray, counts: np.ndarray,
                         qs: Sequence[float]) -> np.ndarray:
    """Vectorized `hist_percentile` over a stack of histograms.

    counts: (B, bins) weighted histograms (one row per time bucket);
    qs: percentiles (0–100).  Returns (len(qs), B) — every bucket's
    percentile read out in one cumulative-sum pass, NaN where a bucket is
    empty.  Semantics match the scalar readout exactly (linear
    interpolation within the containing bin).
    """
    counts = np.asarray(counts, float)
    edges = np.asarray(edges, float)
    B, bins = counts.shape
    qs_arr = np.clip(np.asarray(qs, float), 0.0, 100.0)
    if B == 0 or len(qs_arr) == 0:
        return np.empty((len(qs_arr), B))
    cum = np.cumsum(counts, axis=1)                      # (B, bins)
    total = cum[:, -1]
    target = total[None, :] * qs_arr[:, None] / 100.0    # (Q, B)
    # first bin with cum >= target (per-row searchsorted, side='left')
    i = np.minimum((cum[None, :, :] < target[:, :, None]).sum(axis=2),
                   bins - 1)                             # (Q, B)
    rows = np.arange(B)[None, :]
    prev = np.where(i > 0, cum[rows, np.maximum(i - 1, 0)], 0.0)
    c = counts[rows, i]
    frac = np.where(c > 0, (target - prev) / np.where(c > 0, c, 1.0), 0.0)
    out = edges[i] + frac * (edges[i + 1] - edges[i])
    out[:, total <= 0] = np.nan
    return out


def pearson_r(a: Sequence[float], b: Sequence[float]) -> float:
    a, b = np.asarray(a, float), np.asarray(b, float)
    a = a - a.mean()
    b = b - b.mean()
    den = np.sqrt((a * a).sum() * (b * b).sum())
    return float((a * b).sum() / den) if den else 0.0


@dataclass
class AccuracyReport:
    """Summary row of paper Table II."""

    estimator: str
    mae_pp: float
    within_2pp: float
    within_5pp: float

    @classmethod
    def build(cls, name: str, est_pct: Sequence[float],
              truth_pct: Sequence[float]) -> "AccuracyReport":
        return cls(name, mae(est_pct, truth_pct),
                   pct_within(est_pct, truth_pct, 2.0),
                   pct_within(est_pct, truth_pct, 5.0))
