"""Theoretical peak FLOP/s derivation (paper Eq. 5–7), TPU-native.

The paper's point in §IV-D is that the *denominator* of any utilization
metric must be derived from the physical pipeline: units × FLOPs/cycle ×
the clock domain that pipeline actually runs at.  We reproduce that audit
for TPU v5e (the deploy target): 4 MXUs × (128×128 MACC = 2 FLOPs each)
× 1,500 MHz = 196.6 TFLOP/s bf16 — matching the published 197 TFLOP/s,
exactly as Eq. 6 recovers H100's published 989 TFLOP/s.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class ChipSpec:
    """One accelerator generation."""

    name: str
    num_mxu: int
    mxu_rows: int
    mxu_cols: int
    flops_per_macc: int
    f_max_mhz: float            # matrix-pipeline max clock (Eq. 6 subtlety)
    f_sm_max_mhz: float         # scalar/SM boost clock (may differ!)
    hbm_gbps: float             # HBM bandwidth, GB/s
    ici_gbps: float             # per-link interconnect bandwidth, GB/s
    ici_links: int              # links per chip
    hbm_gib: float              # HBM capacity
    # precision multipliers relative to the base (bf16) matrix pipeline
    precision_mult: dict = field(default_factory=dict)

    def peak_tflops(self, dtype: str = "bf16") -> float:
        """Eq. 5: SMs × FLOPs/cycle/SM × f_max / 1e12 (TPU: MXUs)."""
        base = (self.num_mxu * self.mxu_rows * self.mxu_cols
                * self.flops_per_macc * self.f_max_mhz * 1e6) / 1e12
        return base * self.precision_mult.get(dtype, 1.0)


# TPU v5e: 197 TFLOP/s bf16, 394 TOPS int8 (published); 819 GB/s HBM;
# ~50 GB/s/link ICI (per the assignment's hardware constants).
TPU_V5E = ChipSpec(
    name="tpu-v5e",
    num_mxu=4, mxu_rows=128, mxu_cols=128, flops_per_macc=2,
    f_max_mhz=1500.0,           # matrix pipeline clock -> 196.6 TF/s bf16
    f_sm_max_mhz=1740.0,        # scalar-core clock domain (≠ matrix clock,
                                # mirroring the H100 1980-vs-1830 split)
    hbm_gbps=819.0,
    ici_gbps=50.0,
    ici_links=4,
    hbm_gib=16.0,
    precision_mult={
        "bf16": 1.0,
        "int8": 2.0,            # 394 TOPS
        "fp8": 2.0,             # (v5e proxy for the paper's FP8 axis)
        "fp32": 0.25,           # bf16x3-pass emulation + fp32 accumulate
    },
)

# A next-gen point for the cross-generation claims (paper: H100 vs GB200).
TPU_V6E_LIKE = ChipSpec(
    name="tpu-v6e-like",
    num_mxu=4, mxu_rows=256, mxu_cols=256, flops_per_macc=2,
    f_max_mhz=1750.0,           # -> 917.5 TF/s bf16 (published ~918)
    f_sm_max_mhz=1850.0,
    hbm_gbps=1640.0,
    ici_gbps=100.0,
    ici_links=4,
    hbm_gib=32.0,
    precision_mult={"bf16": 1.0, "int8": 2.0, "fp8": 2.0, "fp32": 0.25},
)

CHIPS = {c.name: c for c in (TPU_V5E, TPU_V6E_LIKE)}
DEFAULT_CHIP = TPU_V5E
