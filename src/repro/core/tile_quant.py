"""Tile quantization (paper Eq. 2–4), TPU/Pallas-native.

GEMM grids pad (M, N, K) up to BlockSpec tile multiples (first ceiling) and
— on megacore parts — the tile grid is again rounded up to a whole number of
core clusters (second ceiling), exactly Eq. 4's two-level hierarchy.  Because
a Pallas grid is static, `profiled_flops()` here is EXACT for our kernel (the
closed-form-vs-grid test asserts 0-FLOP error, cf. the paper's <1000-FLOP
nvJet match).  For XLA-chosen dot lowerings the tiling is opaque (the paper's
XMMA/CUTLASS caveat); there we fall back on compiled cost_analysis().

The block-shape policy below plays the role of cuBLAS kernel selection: an
intermediate library layer, invisible to the application, that materially
changes executed FLOPs (paper §IV-A).
"""
from __future__ import annotations

import math
from dataclasses import dataclass


@dataclass(frozen=True)
class TilePolicy:
    """BlockSpec tile dims + core-cluster grouping (Eq. 4's (C_M, C_N))."""

    tm: int
    tn: int
    tk: int
    cm: int = 1
    cn: int = 1
    name: str = "custom"


def _ceil_to(x: int, t: int) -> int:
    return -(-x // t) * t


def effective_dims(M: int, N: int, K: int,
                   policy: TilePolicy) -> tuple[int, int, int]:
    """Eq. 3 + Eq. 4: two successive ceilings (tiles, then core clusters)."""
    m_tiles = -(-M // policy.tm)
    n_tiles = -(-N // policy.tn)
    m_eff = _ceil_to(m_tiles, policy.cm) * policy.tm
    n_eff = _ceil_to(n_tiles, policy.cn) * policy.tn
    k_eff = _ceil_to(K, policy.tk)
    return m_eff, n_eff, k_eff


def profiled_flops(M: int, N: int, K: int, policy: TilePolicy) -> int:
    """FLOPs the hardware executes: 2·M_eff·N_eff·K_eff ≥ 2MNK."""
    me, ne, ke = effective_dims(M, N, K, policy)
    return 2 * me * ne * ke


def theoretical_flops(M: int, N: int, K: int) -> int:
    return 2 * M * N * K


def overhead(M: int, N: int, K: int, policy: TilePolicy) -> float:
    """Eq. 2: (FLOPs_profiled − 2MNK) / 2MNK."""
    th = theoretical_flops(M, N, K)
    return (profiled_flops(M, N, K, policy) - th) / th


# ---------------------------------------------------------------------------
# block-shape policy picker — our nvMatmulHeuristics analogue
# ---------------------------------------------------------------------------
# VMEM budget: ~128 KiB per buffer slot is a comfortable v5e working set for
# a double-buffered 3-operand GEMM tile; MXU wants dims in multiples of 128
# (8 sublanes × 128 lanes; 128×128 systolic tiles).
_POLICIES = {
    # large well-aligned shapes: big tiles, megacore-style 2-cluster M split
    "mxu_512": TilePolicy(512, 512, 512, cm=2, cn=1, name="mxu_512"),
    # default for medium shapes
    "mxu_256": TilePolicy(256, 256, 256, cm=1, cn=1, name="mxu_256"),
    # small / poorly aligned shapes (CUTLASS-2-analogue)
    "mxu_128": TilePolicy(128, 128, 128, cm=1, cn=1, name="mxu_128"),
    # int8 doubles the K appetite (same bytes per tile)
    "mxu_256_k512": TilePolicy(256, 256, 512, cm=1, cn=1, name="mxu_256_k512"),
    # fp32 runs smaller tiles (3-pass emulation triples the VMEM footprint)
    "mxu_128_fp32": TilePolicy(128, 128, 128, cm=1, cn=1, name="mxu_128_fp32"),
}


# larger tiles amortize pipeline setup / raise MXU occupancy: model that as
# a per-tile-size efficiency penalty so the picker trades padding vs
# efficiency the way nvMatmulHeuristics does.
_TILE_PENALTY = {128: 1.08, 256: 1.02, 512: 1.00}


def pick_policy(M: int, N: int, K: int, dtype: str = "bf16") -> TilePolicy:
    """Shape/precision-driven policy choice (the library layer of §IV-A).

    Evaluates the candidate BlockSpec set and picks the minimum of
    (executed FLOPs × tile-efficiency penalty) — bigger tiles for big
    aligned problems, smaller tiles when edge padding would dominate,
    precision-dependent candidate sets (fp32 runs 3-pass emulation and is
    capped at 128³ tiles; int8 gets a deeper-K candidate).
    """
    if dtype == "fp32":
        return _POLICIES["mxu_128_fp32"]
    cands = ["mxu_128", "mxu_256", "mxu_512"]
    if dtype in ("int8", "fp8"):
        cands.append("mxu_256_k512")

    def cost(name: str) -> float:
        p = _POLICIES[name]
        return (profiled_flops(M, N, K, p)
                * _TILE_PENALTY[p.tm]
                * (1.0 + scale_factor_overhead(M, N, K, dtype)
                   * (128.0 / p.tk)))

    return _POLICIES[min(cands, key=cost)]


def correction_factor(M: int, N: int, K: int,
                      policy: TilePolicy | None = None,
                      dtype: str = "bf16") -> float:
    """FLOPs_theoretical / FLOPs_profiled — the Eq. 8 adjustment term."""
    policy = policy or pick_policy(M, N, K, dtype)
    return theoretical_flops(M, N, K) / profiled_flops(M, N, K, policy)


# ---------------------------------------------------------------------------
# block-scale bookkeeping overhead for quantized formats (paper §IV-B)
# ---------------------------------------------------------------------------
def scale_factor_overhead(M: int, N: int, K: int, dtype: str) -> float:
    """Fractional throughput overhead from per-tile scale-factor handling.

    The paper: FP8 keeps one SF block per 128×128 input tile; NVFP4 one per
    128×64 — quadrupling SF traffic.  TPU int8 (AQT-style) keeps one fp32
    scale per 128×128 quantization tile; modeled as extra VPU cycles per
    MXU tile that shrink with K (amortized over the contraction).
    """
    if dtype not in ("int8", "fp8"):
        return 0.0
    blocks_per_tile = {"int8": 3, "fp8": 3}[dtype]
    # SF handling cost ~ blocks × (setup cycles) / (MACC cycles per tile)
    macc_cycles = max(K, 1)  # K-deep accumulation per 128×128 output tile
    return blocks_per_tile * 96.0 / macc_cycles
