from repro.data.pipeline import Prefetcher, synthetic_batch  # noqa: F401
