"""Deterministic synthetic token pipeline, shardable across hosts.

Batches are a pure function of (seed, step, host) — restart-safe (a resumed
job regenerates exactly the stream it would have seen) and host-shardable
(each host materializes only its slice of the global batch), which is the
property a 1000-node input pipeline actually needs.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from queue import Queue
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeSpec


def synthetic_batch(cfg: ModelConfig, shape: ShapeSpec, step: int, *,
                    seed: int = 0, host_id: int = 0,
                    num_hosts: int = 1) -> dict:
    """Materialize this host's slice of the global batch for `step`."""
    assert shape.global_batch % num_hosts == 0
    B = shape.global_batch // num_hosts
    S = shape.seq_len
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, step, host_id]))
    S_txt = S - cfg.num_image_tokens if cfg.family == "vlm" else S
    batch = {"tokens": rng.integers(
        0, cfg.vocab_size, (B, S_txt)).astype(np.int32)}
    if shape.kind == "train":
        batch["labels"] = rng.integers(
            0, cfg.vocab_size, (B, S)).astype(np.int32)
    if cfg.family == "vlm":
        batch["patch_embeds"] = (rng.standard_normal(
            (B, cfg.num_image_tokens, cfg.d_model)) * 0.02
        ).astype(cfg.dtype)
    if cfg.family == "encdec":
        batch["frame_embeds"] = (rng.standard_normal(
            (B, cfg.encoder_seq, cfg.d_model)) * 0.02).astype(cfg.dtype)
    return batch


class Prefetcher:
    """Background-thread prefetch of the deterministic stream."""

    def __init__(self, cfg: ModelConfig, shape: ShapeSpec, *,
                 start_step: int = 0, seed: int = 0, host_id: int = 0,
                 num_hosts: int = 1, depth: int = 2):
        self.cfg, self.shape = cfg, shape
        self.seed, self.host_id, self.num_hosts = seed, host_id, num_hosts
        self.step = start_step
        self.q: Queue = Queue(maxsize=depth)
        self._stop = threading.Event()
        self._t = threading.Thread(target=self._work, daemon=True)
        self._t.start()

    def _work(self):
        s = self.step
        while not self._stop.is_set():
            b = synthetic_batch(self.cfg, self.shape, s, seed=self.seed,
                                host_id=self.host_id,
                                num_hosts=self.num_hosts)
            self.q.put((s, b))
            s += 1

    def __iter__(self) -> Iterator[tuple[int, dict]]:
        return self

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            self.q.get_nowait()
        except Exception:
            pass
