"""Fleet layer: job simulation, streaming/distributed rollups, divergence
triage, regression detection + recovery, goodput.

Exports resolve lazily (PEP 562) so the replay/live telemetry path —
`import repro.fleet.streaming` + detectors driven by a TraceReplaySource —
never drags the generative simulator (engine/jobs) into the process.
"""
from __future__ import annotations

from importlib import import_module

_EXPORTS = {
    "AdaptiveConfig": "repro.fleet.collector",
    "AdaptiveScrapeController": "repro.fleet.collector",
    "Alert": "repro.fleet.collector",
    "AlertDeduper": "repro.fleet.collector",
    "Collector": "repro.fleet.collector",
    "CollectorConfig": "repro.fleet.collector",
    "FleetCollector": "repro.fleet.collector",
    "JobStream": "repro.fleet.collector",
    "RoundReport": "repro.fleet.collector",
    "DivergenceReport": "repro.fleet.divergence",
    "JobPoint": "repro.fleet.divergence",
    "analyze": "repro.fleet.divergence",
    "analyze_rollup": "repro.fleet.divergence",
    "DEFAULT_OFU_FLOOR": "repro.fleet.divergence",
    "CorrelationConfig": "repro.fleet.correlation",
    "CorrelationReport": "repro.fleet.correlation",
    "MfuRollup": "repro.fleet.correlation",
    "MiscalcFinding": "repro.fleet.correlation",
    "analyze_correlation": "repro.fleet.correlation",
    "joined_series": "repro.fleet.correlation",
    "rolling_pearson": "repro.fleet.correlation",
    "scan_miscalc": "repro.fleet.correlation",
    "tile_quant_factor": "repro.fleet.correlation",
    # defined in the telemetry layer — resolving it must not load the
    # simulator (engine re-exports it only for back-compat)
    "DeviceGrid": "repro.telemetry.scrape",
    "CounterFault": "repro.fleet.engine",
    "EngineParams": "repro.fleet.engine",
    "JobSlot": "repro.fleet.engine",
    "apply_faults": "repro.fleet.engine",
    "fault_factors": "repro.fleet.engine",
    "simulate_devices": "repro.fleet.engine",
    "simulate_jobs_fused": "repro.fleet.engine",
    # jax backend — resolving it imports jax, so it stays lazy like
    # everything else here
    "simulate_jobs_jax": "repro.fleet.engine_jax",
    "FleetRollup": "repro.fleet.goodput",
    "GoodputEvent": "repro.fleet.goodput",
    "goodput_from_rollup": "repro.fleet.goodput",
    "rollup": "repro.fleet.goodput",
    "scan_goodput": "repro.fleet.goodput",
    "JobSpec": "repro.fleet.jobs",
    "JobTelemetry": "repro.fleet.jobs",
    "build_profile": "repro.fleet.jobs",
    "simulate_fleet": "repro.fleet.jobs",
    "simulate_job": "repro.fleet.jobs",
    "BucketStats": "repro.fleet.streaming",
    "StreamingRollup": "repro.fleet.streaming",
    "WindowedRollup": "repro.fleet.streaming",
    "precision_label": "repro.fleet.streaming",
    "host_partition": "repro.fleet.distributed",
    "tree_reduce": "repro.fleet.distributed",
    "RecoveryAction": "repro.fleet.recovery",
    "RecoveryService": "repro.fleet.recovery",
    "StragglerMonitor": "repro.fleet.recovery",
    "Regression": "repro.fleet.regression",
    "detect_regressions": "repro.fleet.regression",
    "scan_rollup": "repro.fleet.regression",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    val = getattr(import_module(mod), name)
    globals()[name] = val
    return val


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))
