from repro.fleet.divergence import DivergenceReport, JobPoint, analyze  # noqa: F401
from repro.fleet.engine import (  # noqa: F401
    DeviceGrid, EngineParams, simulate_devices,
)
from repro.fleet.goodput import FleetRollup, rollup  # noqa: F401
from repro.fleet.jobs import (  # noqa: F401
    JobSpec, JobTelemetry, build_profile, simulate_fleet, simulate_job,
)
from repro.fleet.streaming import (  # noqa: F401
    BucketStats, StreamingRollup, precision_label,
)
from repro.fleet.recovery import (  # noqa: F401
    RecoveryAction, RecoveryService, StragglerMonitor,
)
from repro.fleet.regression import Regression, detect_regressions  # noqa: F401
