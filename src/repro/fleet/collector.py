"""Continuous collector daemon: the paper's §VI operational loop.

The batch pipeline (source → rollup → detector) answers "what happened";
the paper's deployed story is *continuous* visibility — OFU dashboards
that caught the 2.5× Gloo regression live.  This module closes that loop:

  * `Collector` drives repeated `TelemetrySource.poll()` rounds into one
    incremental `WindowedRollup` (bounded memory: full per-bucket detail
    for the retention window, all-time totals beyond it) and fires
    `regression.scan_rollup` + `divergence.analyze_rollup` after every
    round, with per-episode alert deduplication and clear-side hysteresis
    so a sustained collapse pages once, not once per round.
  * `AdaptiveScrapeController` implements the Table I noise-vs-interval
    tradeoff as a controller: when a job's per-round OFU dispersion spikes
    (something is happening — an event boundary, a straggler, clock
    throttling), tighten its scrape interval for resolution; when it has
    been quiet, relax it back toward the cheap cadence.  Every retiming
    goes through the shared §IV-C `check_scrape_interval` policy.
  * `FleetCollector` runs per-host collectors and periodically
    `tree_reduce`s their windowed snapshots into one fleet rollup — raw
    scrapes never leave their host, dashboards update every round.

See docs/ARCHITECTURE.md for where this sits in the pipeline and how a
live DCGM/libtpu `BackendSource` slots under it unchanged.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np

from repro.core.peaks import DEFAULT_CHIP, ChipSpec
from repro.fleet.correlation import (CorrelationConfig, MfuRollup,
                                     scan_miscalc)
from repro.fleet.distributed import tree_reduce
from repro.fleet.divergence import DEFAULT_OFU_FLOOR, analyze_rollup
from repro.fleet.goodput import scan_goodput
from repro.fleet.regression import scan_rollup

#: the fleet-scope pseudo job id goodput alerts carry (no single job
#: owns a fleet-wide OFU drop)
FLEET_SCOPE = "__fleet__"
from repro.fleet.streaming import WindowedRollup
from repro.telemetry.counters import (MAX_HW_AVG_WINDOW_S,
                                      check_scrape_interval)
from repro.telemetry.source import TelemetrySource


@dataclass
class JobStream:
    """One monitored job: a telemetry source plus its rollup metadata."""

    job_id: str
    source: TelemetrySource
    chips: Optional[int] = None      # true device count (chip-weighting)
    group: str = "unknown"           # precision mix / cohort label
    app_mfu: Optional[float] = None  # app-reported MFU, enables divergence
    arch: str = "unknown"
    flops_variant: str = "exact"
    chip: ChipSpec = DEFAULT_CHIP
    #: live app-MFU sample stream (`telemetry.mfu.MfuReplaySource`, or a
    #: `MfuReporter.to_source()` snapshot): polled every round alongside
    #: the counter source into the collector's `MfuRollup`.  When set and
    #: `app_mfu` is None, the job's divergence metadata tracks the
    #: reporter's running mean instead of a static scalar.
    mfu_source: Optional[object] = None


# ---------------------------------------------------------------------------
# Adaptive scrape scheduling (Table I noise-vs-interval tradeoff)
# ---------------------------------------------------------------------------
@dataclass
class AdaptiveConfig:
    """Knobs for `AdaptiveScrapeController`.

    The controller trades scrape cost against temporal resolution: Table I
    shows short intervals buy per-bucket noise averaging (more samples per
    bucket) at higher collection cost.  Dispersion is cheap to watch, so
    we pay for resolution only when a job's samples start disagreeing.
    """

    min_interval_s: float = 5.0
    max_interval_s: float = MAX_HW_AVG_WINDOW_S   # §IV-C hard ceiling
    tighten: float = 0.5         # interval multiplier on a dispersion spike
    relax: float = 2.0           # interval multiplier after quiet_rounds
    spike_ratio: float = 2.0     # round std vs EMA baseline => spike
    quiet_rounds: int = 3        # consecutive calm rounds before relaxing
    ema: float = 0.2             # baseline update rate
    episode_aware: bool = True   # pin a job hot while an alert is open

    def __post_init__(self):
        if not 0 < self.min_interval_s <= self.max_interval_s:
            raise ValueError(f"need 0 < min_interval_s "
                             f"({self.min_interval_s}) <= max_interval_s "
                             f"({self.max_interval_s})")
        # the ceiling itself must satisfy §IV-C, or relaxing would push a
        # source into average-of-averages territory
        check_scrape_interval(self.max_interval_s)


class AdaptiveScrapeController:
    """Per-job scrape-interval controller.

    `update(job_id, ofu_samples, interval_s)` returns the interval the
    NEXT round should use: tightened (× cfg.tighten, floored at
    min_interval_s) when the round's OFU standard deviation exceeds
    `spike_ratio` × the job's EMA baseline, relaxed (× cfg.relax, capped
    at max_interval_s) after `quiet_rounds` consecutive calm rounds, and
    unchanged otherwise.  Every returned interval passes
    `check_scrape_interval` by construction of the bounds.

    DETECTOR-AWARE scheduling: `episode_open=True` (the collector passes
    it while the job has an open regression/divergence alert episode)
    overrides the dispersion signal — the interval tightens toward
    min_interval_s and HOLDS there for as long as the episode stays open,
    because an active incident wants maximum temporal resolution even
    when the regressed level itself is quiet.  Once the episode clears,
    the normal quiet-rounds relaxation takes the interval back up.
    """

    def __init__(self, cfg: Optional[AdaptiveConfig] = None):
        self.cfg = cfg or AdaptiveConfig()
        self._baseline: dict = {}    # job_id -> EMA of round std
        self._quiet: dict = {}       # job_id -> consecutive calm rounds

    def update(self, job_id: str, ofu_samples: np.ndarray,
               interval_s: float, *, episode_open: bool = False) -> float:
        cfg = self.cfg
        samples = np.asarray(ofu_samples, float).ravel()
        if episode_open and cfg.episode_aware:
            # an open alert episode pins the job hot: step toward the
            # floor and never bank quiet rounds while the incident lasts
            # (the dispersion branch below handles pre-detection spikes)
            self._quiet[job_id] = 0
            if samples.size >= 2:
                std = float(np.std(samples))
                base = self._baseline.get(job_id)
                # absorb the episode's dispersion so post-clear rounds
                # compare against the regime they actually live in
                self._baseline[job_id] = std if base is None \
                    else (1 - cfg.ema) * base + cfg.ema * std
            new = min(cfg.max_interval_s,
                      max(cfg.min_interval_s, interval_s * cfg.tighten))
            if new != interval_s:
                check_scrape_interval(new)
            return new
        if samples.size < 2:
            return interval_s
        std = float(np.std(samples))
        base = self._baseline.get(job_id)
        new = interval_s
        if base is not None and std > cfg.spike_ratio * max(base, 1e-4):
            # clamp into [min, max] — a degraded source may START beyond
            # max_interval_s, and a half-step from there can still
            # overshoot the §IV-C ceiling
            new = min(cfg.max_interval_s,
                      max(cfg.min_interval_s, interval_s * cfg.tighten))
            self._quiet[job_id] = 0
            # bounded staleness: absorb the spike level at a CAPPED rate,
            # so a one-round transient barely moves the baseline (the next
            # quiet round still looks quiet against the pre-spike level)
            # but a PERMANENT dispersion shift re-baselines within ~a
            # dozen rounds instead of pinning the interval at min forever
            self._baseline[job_id] = (1 - cfg.ema) * base + cfg.ema \
                * min(std, cfg.spike_ratio * max(base, 1e-4))
        else:
            quiet = self._quiet.get(job_id, 0) + 1
            self._quiet[job_id] = quiet
            if quiet >= cfg.quiet_rounds and interval_s < cfg.max_interval_s:
                new = min(cfg.max_interval_s, interval_s * cfg.relax)
                self._quiet[job_id] = 0
            self._baseline[job_id] = std if base is None \
                else (1 - cfg.ema) * base + cfg.ema * std
        if new != interval_s:
            # §IV-C on every RETIMING; an unchanged interval is the
            # source's own pre-existing policy (a degraded strict=False
            # source may legitimately sit beyond the averaging window —
            # the first tighten pulls it into the compliant band and the
            # relax ceiling keeps it there)
            check_scrape_interval(new)
        return new


# ---------------------------------------------------------------------------
# Alert deduplication + hysteresis
# ---------------------------------------------------------------------------
@dataclass
class Alert:
    """One fired alert (an episode fires once; see AlertDeduper)."""

    round_idx: int
    t_s: float                   # collector clock when fired
    job_id: str
    kind: str                    # 'regression'|'divergence'|'goodput'|'miscalc'
    message: str
    factor: float = float("nan")  # regression factor / divergence rel err

    def summary(self) -> str:
        return (f"[round {self.round_idx} t={self.t_s:.0f}s] "
                f"{self.kind.upper()} {self.job_id}: {self.message}")


class AlertDeduper:
    """Per-episode dedup with clear-side hysteresis and anchor tracking.

    A detector finding is keyed (job, kind) plus an optional EPISODE
    ANCHOR (the regression's absolute start bucket).  A sighting matches
    an active episode when its anchor is within `anchor_tolerance` of the
    episode's — matching refreshes the stored anchor, so the gradual
    drift that window eviction induces (it erodes the detector's
    reference baseline, shifting the detected start index of one and the
    same collapse) is tracked, not re-paged.  A sighting with no nearby
    active episode is a NEW episode and fires — a second, distinct
    collapse pages even while an older dip still sits in the retained
    window.  Episodes retire after `clear_rounds` consecutive rounds
    unseen (hysteresis against threshold flicker), re-arming the slot.
    """

    def __init__(self, clear_rounds: int = 2, *, anchor_tolerance: int = 0):
        if clear_rounds < 1:
            raise ValueError(f"clear_rounds={clear_rounds} must be >= 1")
        self.clear_rounds = int(clear_rounds)
        self.anchor_tolerance = int(anchor_tolerance)
        self._active: dict = {}    # key -> list of [anchor, quiet_rounds]

    def offer(self, key, anchor: Optional[int] = None) -> bool:
        """Register a sighting; True if an alert should fire."""
        episodes = self._active.setdefault(key, [])
        for ep in episodes:
            if (anchor is None) == (ep[0] is None) and (
                    anchor is None
                    or abs(anchor - ep[0]) <= self.anchor_tolerance):
                ep[0] = anchor       # track drift
                ep[1] = -1           # seen this round (tick() sets to 0)
                return False
        episodes.append([anchor, -1])
        return True

    def tick(self) -> None:
        """End of round: age episodes, retire those quiet long enough."""
        for key, episodes in list(self._active.items()):
            kept = []
            for ep in episodes:
                ep[1] += 1
                if ep[1] < self.clear_rounds:
                    kept.append(ep)
            if kept:
                self._active[key] = kept
            else:
                del self._active[key]

    @property
    def active(self) -> list:
        return sorted(self._active, key=repr)

    @property
    def active_jobs(self) -> set:
        """Jobs with at least one open episode of any kind — what the
        detector-aware adaptive scheduler keys its tighten/hold on."""
        return {key[0] for key in self._active}


# ---------------------------------------------------------------------------
# The collector daemon
# ---------------------------------------------------------------------------
@dataclass
class CollectorConfig:
    round_s: float = 300.0       # wall-time collected per round
    bucket_s: float = 300.0
    retain: int = 24             # window buckets kept in full detail
    bins: int = 128
    detector: dict = field(      # kwargs for regression.scan_rollup
        default_factory=lambda: {"window": 4, "min_duration": 2})
    flag_rel_err: float = 0.30   # divergence threshold
    ofu_floor: float = DEFAULT_OFU_FLOOR   # idle jobs exempt from flagging
    clear_rounds: int = 2        # alert hysteresis
    adaptive: Optional[AdaptiveConfig] = None   # None = fixed intervals
    #: kwargs for `goodput.scan_goodput` (e.g. {"drop_threshold": 0.25,
    #: "window": 4, "min_duration": 2}); None disables the fleet-wide
    #: goodput drop detector (the default — fleet scans are opt-in)
    goodput: Optional[dict] = None
    #: kwargs for `correlation.CorrelationConfig` (e.g.
    #: {"ratio_high": 1.5}); the default {} enables the OFU/MFU-ratio
    #: miscalculation detector with stock thresholds — it is a no-op
    #: until some stream carries an `mfu_source`.  None disables it.
    miscalc: Optional[dict] = field(default_factory=dict)

    def __post_init__(self):
        if self.round_s <= 0:
            raise ValueError(f"round_s={self.round_s} must be positive")
        if self.adaptive and self.adaptive.max_interval_s > self.round_s:
            # relaxing beyond the round length would starve poll() of a
            # full sample; clamp the controller's ceiling to the cadence
            raise ValueError(
                f"adaptive max_interval_s={self.adaptive.max_interval_s} "
                f"exceeds round_s={self.round_s}; a round must fit at "
                "least one scrape")


@dataclass
class RoundReport:
    """What one collection round did — the dashboard's refresh record."""

    round_idx: int
    t_s: float                   # collector clock after the round
    samples: int                 # counter samples ingested this round
    alerts: list
    intervals: dict              # job_id -> interval_s after retiming
    rollup_summary: str

    def summary(self) -> str:
        lines = [f"round {self.round_idx} t={self.t_s:.0f}s "
                 f"samples={self.samples} alerts={len(self.alerts)} | "
                 f"{self.rollup_summary}"]
        lines += [f"  {a.summary()}" for a in self.alerts]
        return "\n".join(lines)


def _require_bounded(streams: Sequence[JobStream]) -> None:
    """Reject run(n_rounds=None) over streams that can never exhaust."""
    unbounded = [st.job_id for st in streams if not st.source.bounded]
    if unbounded:
        raise ValueError(
            f"n_rounds is required when any stream is unbounded "
            f"(no finite duration_s / bounded override): {unbounded}")


class Collector:
    """Long-lived collection loop over a set of job streams.

    Each `poll_round()`:
      1. polls every non-exhausted stream for the next `round_s` seconds
         of counters and folds the grids into the windowed rollup;
      2. lets the adaptive controller retime retimable sources from the
         round's OFU dispersion;
      3. scans the retained window with the regression detector and the
         divergence triage, routing findings through the alert deduper.

    The rollup is a `WindowedRollup`, so a collector that runs for a week
    holds the same memory as one that ran for an hour; `snapshot()` ships
    the windowed state to a reducer (see `FleetCollector`).
    """

    def __init__(self, streams: Sequence[JobStream],
                 config: Optional[CollectorConfig] = None, *,
                 rollup: Optional[WindowedRollup] = None,
                 clock_s: float = 0.0, round_idx: int = 0,
                 on_grid=None):
        """`rollup`/`clock_s`/`round_idx` restore a collector from a
        `snapshot()` across a process restart: pass
        `WindowedRollup.from_bytes(snap)` plus the old collector's clock
        and round count, and `seek()` each replay source to where its
        predecessor's cursor stood — polling resumes mid-trace with the
        retained window intact.  The rollup snapshot does NOT carry the
        alert log or episode hysteresis; restore those separately via
        `restore_alert_state(alert_state())` (as `ServiceDaemon`
        persistence does), or an episode still open across the restart
        re-fires once.

        `on_grid(stream, grid)` is the per-poll round hook: called with
        every non-empty polled DeviceGrid BEFORE rollup ingestion — the
        recording-mode tee point (`repro.serve.ServiceDaemon` routes
        grids into per-job `TraceWriter`s through it)."""
        self.streams = list(streams)
        ids = [st.job_id for st in self.streams]
        if len(set(ids)) != len(ids):
            raise ValueError(f"duplicate job_ids in streams: {ids}")
        self.config = config or CollectorConfig()
        cfg = self.config
        if rollup is not None and (rollup.bucket_s != cfg.bucket_s
                                   or rollup.retain != cfg.retain
                                   or rollup.bins != cfg.bins):
            raise ValueError(
                f"restored rollup (bucket_s={rollup.bucket_s}, "
                f"retain={rollup.retain}, bins={rollup.bins}) does not "
                f"match config (bucket_s={cfg.bucket_s}, "
                f"retain={cfg.retain}, bins={cfg.bins})")
        self.rollup = rollup if rollup is not None else WindowedRollup(
            cfg.bucket_s, retain=cfg.retain, bins=cfg.bins)
        self.controller = (AdaptiveScrapeController(cfg.adaptive)
                           if cfg.adaptive else None)
        #: app-reported MFU samples bucketed on the SAME grid as the
        #: rollup — the correlation tier's other half
        self.mfu = MfuRollup(cfg.bucket_s)
        self._miscalc_cfg = None if cfg.miscalc is None else \
            CorrelationConfig(**{"ofu_floor": cfg.ofu_floor,
                                 **cfg.miscalc})
        # eviction drifts a detection's start index by at most the
        # detector's reference window per round; anchors within that
        # tolerance are the same episode
        self.deduper = AlertDeduper(
            cfg.clear_rounds,
            anchor_tolerance=cfg.detector.get("window", 10))
        self.round_idx = int(round_idx)
        self.clock_s = float(clock_s)
        self.alerts: list = []       # every alert ever fired, in order
        self.on_grid = on_grid

    @property
    def done(self) -> bool:
        return all(st.source.exhausted for st in self.streams)

    # -- stream churn (a long-lived daemon's jobs come and go) ----------
    def add_stream(self, stream: JobStream) -> None:
        """Attach a stream mid-run; it joins the NEXT poll round.  Its
        grids carry their own absolute t0_s, so a late joiner lands in
        the right buckets (samples older than the retention horizon fold
        into the all-time totals, exactly as batch ingestion would)."""
        if any(st.job_id == stream.job_id for st in self.streams):
            raise ValueError(f"duplicate job_id {stream.job_id!r}")
        self.streams.append(stream)

    def remove_stream(self, job_id: str) -> JobStream:
        """Detach a stream and return it.  Already-ingested buckets stay
        in the rollup (history is history); the regression sweep stops
        scanning the job next round, and any open alert episode retires
        after `clear_rounds` quiet rounds like a recovery would."""
        for k, st in enumerate(self.streams):
            if st.job_id == job_id:
                return self.streams.pop(k)
        raise KeyError(f"no stream with job_id {job_id!r} "
                       f"(have {[s.job_id for s in self.streams]})")

    def snapshot(self) -> bytes:
        """The windowed rollup's wire-format state (kilobytes)."""
        return self.rollup.to_bytes()

    # -- alert history + episode hysteresis (restart persistence) -------
    def alert_state(self) -> dict:
        """JSON-safe snapshot of the alert log AND the deduper's open
        episodes — what `ServiceDaemon.persist` writes so a restarted
        daemon neither forgets fired alerts nor re-pages episodes it
        already surfaced."""
        return {
            "alerts": [{"round_idx": a.round_idx, "t_s": a.t_s,
                        "job_id": a.job_id, "kind": a.kind,
                        "message": a.message,
                        "factor": float(a.factor)
                        if np.isfinite(a.factor) else None}
                       for a in self.alerts],
            "episodes": [[list(key), ep[0], ep[1]]
                         for key, eps in self.deduper._active.items()
                         for ep in eps],
        }

    def restore_alert_state(self, state: dict) -> None:
        """Rebuild the alert log and open-episode hysteresis from
        `alert_state()` output (the `ServiceDaemon.restore` path).  An
        episode that was open at persist time is re-armed as open here,
        so the detector re-seeing the same collapse next round refreshes
        it silently instead of paging a duplicate."""
        self.alerts = [
            Alert(int(a["round_idx"]), float(a["t_s"]), a["job_id"],
                  a["kind"], a["message"],
                  factor=float("nan") if a.get("factor") is None
                  else float(a["factor"]))
            for a in state.get("alerts", ())]
        active: dict = {}
        for key, anchor, quiet in state.get("episodes", ()):
            active.setdefault(tuple(key), []).append(
                [None if anchor is None else int(anchor), int(quiet)])
        self.deduper._active = active

    # -- one round ------------------------------------------------------
    def _collect(self) -> int:
        cfg = self.config
        n_samples = 0
        # last round's open episodes drive detector-aware retiming (the
        # detectors for THIS round haven't run yet when we poll)
        hot = self.deduper.active_jobs if self.controller else ()
        for st in self.streams:
            # the app reporter's samples land first, so this round's
            # divergence metadata already reflects them
            if st.mfu_source is not None and not st.mfu_source.exhausted:
                t_s, mfu = st.mfu_source.poll(cfg.round_s)
                if len(t_s):
                    self.mfu.observe_series(st.job_id, t_s, mfu)
            src = st.source
            if src.exhausted:
                continue
            grid = src.poll(cfg.round_s)
            if grid.tpa.size == 0:
                continue
            if self.on_grid is not None:
                self.on_grid(st, grid)
            app_mfu = st.app_mfu
            if app_mfu is None and st.mfu_source is not None:
                app_mfu = self.mfu.job_mean(st.job_id)
            ofu = self.rollup.add_grid(
                st.job_id, grid, chip=st.chip, group=st.group,
                chips=st.chips, app_mfu=app_mfu, arch=st.arch,
                flops_variant=st.flops_variant)
            n_samples += grid.tpa.size
            if self.controller is not None and src.retimable:
                new = self.controller.update(st.job_id, ofu,
                                             src.interval_s,
                                             episode_open=st.job_id in hot)
                if new != src.interval_s:
                    src.set_interval(new)
        return n_samples

    def _detect(self) -> list:
        cfg = self.config
        fired = []
        live = [st.job_id for st in self.streams]
        for jid, regs in scan_rollup(self.rollup, jobs=live,
                                     **cfg.detector).items():
            for r in regs:
                # each detection is an episode anchored at its ABSOLUTE
                # start bucket; the deduper tracks anchor drift and
                # swallows repeats, so one collapse pages once while a
                # later, distinct collapse still pages
                anchor = self.rollup.bucket0 + r.start_idx
                if self.deduper.offer((jid, "regression"), anchor=anchor):
                    state = "ongoing" if r.end_idx is None else "recovered"
                    fired.append(Alert(
                        self.round_idx, self.clock_s, jid, "regression",
                        f"{r.factor:.2f}x OFU collapse "
                        f"({r.ref_ofu * 100:.1f}% -> {r.low_ofu * 100:.1f}%"
                        f", {state})", factor=r.factor))
        if cfg.goodput is not None:
            for ev in scan_goodput(self.rollup, **cfg.goodput):
                anchor = self.rollup.bucket0 + ev.start_idx
                if self.deduper.offer((FLEET_SCOPE, "goodput"),
                                      anchor=anchor):
                    state = "ongoing" if ev.end_idx is None else "recovered"
                    fired.append(Alert(
                        self.round_idx, self.clock_s, FLEET_SCOPE,
                        "goodput",
                        f"fleet OFU down {ev.drop_frac * 100:.0f}% "
                        f"({ev.ref_ofu * 100:.1f}% -> "
                        f"{ev.low_ofu * 100:.1f}%, {state})",
                        factor=ev.drop_frac))
        if self._miscalc_cfg is not None:
            # like divergence, a miscalculated counter is a property of
            # the whole joined population, not a window event — episodes
            # are unanchored and stay open while the ratio stays out
            for f in scan_miscalc(self.mfu, self.rollup,
                                  config=self._miscalc_cfg):
                if self.deduper.offer((f.job_id, "miscalc")):
                    fired.append(Alert(
                        self.round_idx, self.clock_s, f.job_id,
                        "miscalc",
                        f"reported MFU {f.mfu * 100:.1f}% is "
                        f"{f.ratio:.2f}x adjusted OFU "
                        f"{f.ofu_adj * 100:.1f}% over {f.n_buckets} "
                        f"buckets ({f.direction}) — FLOPs accounting "
                        "suspect", factor=f.ratio))
        rep = analyze_rollup(self.rollup, flag_rel_err=cfg.flag_rel_err,
                             ofu_floor=cfg.ofu_floor, empty_ok=True)
        if rep is not None:
            for p in rep.flagged:
                if self.deduper.offer((p.job_id, "divergence")):
                    fired.append(Alert(
                        self.round_idx, self.clock_s, p.job_id,
                        "divergence",
                        f"app MFU {p.mfu * 100:.1f}% vs OFU "
                        f"{p.ofu * 100:.1f}% (rel err "
                        f"{p.rel_err * 100:.0f}%) — audit the FLOPs "
                        "counter", factor=p.rel_err))
        self.deduper.tick()
        return fired

    def poll_round(self) -> RoundReport:
        """Collect one round, run the detectors, return the report."""
        cfg = self.config
        n_samples = self._collect()
        self.clock_s += cfg.round_s
        self.round_idx += 1
        fired = self._detect()
        self.alerts.extend(fired)
        return RoundReport(
            self.round_idx, self.clock_s, n_samples, fired,
            {st.job_id: getattr(st.source, "interval_s", None)
             for st in self.streams},
            self.rollup.summary())

    def run(self, n_rounds: Optional[int] = None) -> list:
        """Round loop: until every stream is exhausted, or n_rounds."""
        if n_rounds is None:
            _require_bounded(self.streams)
        reports = []
        while (n_rounds is None or len(reports) < n_rounds) \
                and not self.done:
            reports.append(self.poll_round())
        return reports


class FleetCollector:
    """Per-host collectors + periodic tree_reduce rounds.

    Each host's `Collector` sees only its own streams; every
    `reduce_every` rounds the hosts' windowed snapshots tree-reduce into
    `self.fleet` — the continuously-refreshing fleet dashboard state.
    Host-level alerts keep firing locally; `scan()` runs the regression
    sweep over the reduced fleet view.
    """

    def __init__(self, collectors: Sequence[Collector], *, fanin: int = 2,
                 reduce_every: int = 1):
        if not collectors:
            raise ValueError("FleetCollector needs at least one Collector")
        if reduce_every < 1:
            raise ValueError(f"reduce_every={reduce_every} must be >= 1")
        self.collectors = list(collectors)
        self.fanin = int(fanin)
        self.reduce_every = int(reduce_every)
        self.fleet: Optional[WindowedRollup] = None
        self.rounds = 0

    @property
    def done(self) -> bool:
        return all(c.done for c in self.collectors)

    def poll_round(self) -> list:
        """Drive every host one round; reduce snapshots when due."""
        reports = [c.poll_round() for c in self.collectors]
        self.rounds += 1
        if self.rounds % self.reduce_every == 0:
            self.fleet = tree_reduce(
                [c.snapshot() for c in self.collectors], fanin=self.fanin)
        return reports

    def run(self, n_rounds: Optional[int] = None) -> list:
        if n_rounds is None:
            _require_bounded([st for c in self.collectors
                              for st in c.streams])
        reports = []
        while (n_rounds is None or len(reports) < n_rounds) \
                and not self.done:
            reports.append(self.poll_round())
        return reports

    def scan(self, **detector_kw) -> dict:
        """Regression sweep over the latest reduced fleet rollup."""
        if self.fleet is None:
            return {}
        kw = detector_kw or {"window": 4, "min_duration": 2}
        return scan_rollup(self.fleet, **kw)
