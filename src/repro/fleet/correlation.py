"""OFU<->MFU correlation tier: join, rolling r, and the §V-C
miscalculation detector.

`MfuRollup` holds the app-reported half: per-job, time-bucketed MFU
samples (fed by `telemetry.mfu.MfuReporter` / `MfuReplaySource`, or
POSTed through the serve tier).  It uses the SAME right-closed bucket
rule as `StreamingRollup` — a scrape at t covers (t - interval, t], so
bucket k-1 owns a boundary sample — which is what makes (job, bucket)
keys join exactly against the counter-derived OFU rollup.

On the joined series this module computes:

  * rolling Pearson r over trailing bucket windows (`rolling_pearson`);
  * tile-quantization-corrected residuals — OFU is adjusted by the
    arch's dominant-GEMM padding factor (Eq. 8) before comparison, so
    the residual reflects accounting, not tiling;
  * the miscalculation signature (`scan_miscalc`): a job whose
    MFU / adjusted-OFU ratio sits persistently outside
    [ratio_low, ratio_high] is reporting FLOPs it did not execute
    (`naive_moe`, `naive_hybrid`) or under-billing them.  Jobs below
    `ofu_floor` are exempt — an idle denominator proves nothing.

`analyze_correlation` wraps the lot into one report (fleet r with and
without the flagged set, MAE, per-scale error table) — the live-path
counterpart of `divergence.analyze`, consumed by
`serve.store.FleetStore.correlation` and `/v1/query?kind=correlation`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.ofu import pearson_r
from repro.core.peaks import DEFAULT_CHIP, ChipSpec
from repro.fleet.divergence import DEFAULT_OFU_FLOOR

_TQ_CACHE: dict = {}


def tile_quant_factor(arch: str, chip: ChipSpec = DEFAULT_CHIP) -> float:
    """Mean executed/theoretical FLOPs ratio for the arch's dominant
    GEMMs (Eq. 8's correction denominator); 1.0 for unknown archs so
    the correction degrades to identity instead of failing the scan."""
    key = (arch, chip.name)
    hit = _TQ_CACHE.get(key)
    if hit is None:
        try:
            from repro.configs.base import get_config
            from repro.fleet.jobs import _tile_quant_factor
            hit = float(_tile_quant_factor(get_config(arch), chip))
        except (KeyError, ValueError, ImportError):
            hit = 1.0
        _TQ_CACHE[key] = hit
    return hit


class MfuRollup:
    """Per-job bucketed MFU accumulator — sparse (dict-of-buckets per
    job), mergeable, and cheap to copy: app reporters are per-job log
    streams, orders of magnitude lighter than device counter grids."""

    __slots__ = ("bucket_s", "_acc", "generation")

    def __init__(self, bucket_s: float = 300.0):
        if bucket_s <= 0:
            raise ValueError(f"bucket_s={bucket_s} must be positive")
        self.bucket_s = float(bucket_s)
        self._acc: dict = {}    # job_id -> {bucket_idx: [w_sum, wv_sum]}
        self.generation = 0

    def _bucket(self, t_s: float) -> int:
        # the ONE bucketing rule, scalar form of StreamingRollup's
        return max(int(np.ceil(t_s / self.bucket_s)) - 1, 0)

    # -- ingest ---------------------------------------------------------
    def observe(self, job_id: str, t_s: float, mfu: float,
                weight: float = 1.0) -> None:
        if not job_id:
            raise ValueError("job_id must be non-empty")
        if weight <= 0:
            raise ValueError(f"weight={weight} must be positive")
        buckets = self._acc.setdefault(job_id, {})
        acc = buckets.setdefault(self._bucket(float(t_s)), [0.0, 0.0])
        acc[0] += float(weight)
        acc[1] += float(weight) * float(mfu)
        self.generation += 1

    def observe_series(self, job_id: str, t_s, mfu) -> None:
        """Bulk ingest aligned (t_s, mfu) arrays (one reporter poll)."""
        t = np.asarray(t_s, float).ravel()
        v = np.asarray(mfu, float).ravel()
        if t.shape != v.shape:
            raise ValueError(
                f"t_s {t.shape} and mfu {v.shape} must align")
        if not t.size:
            return
        if not job_id:
            raise ValueError("job_id must be non-empty")
        b = np.maximum(np.ceil(t / self.bucket_s).astype(int) - 1, 0)
        buckets = self._acc.setdefault(job_id, {})
        for idx in np.unique(b):
            sel = b == idx
            acc = buckets.setdefault(int(idx), [0.0, 0.0])
            acc[0] += float(np.count_nonzero(sel))
            acc[1] += float(v[sel].sum())
        self.generation += 1

    def merge(self, other: "MfuRollup") -> "MfuRollup":
        """Element-wise accumulate (associative + commutative, like
        `StreamingRollup.merge` — host shards reduce the same way)."""
        if abs(other.bucket_s - self.bucket_s) > 1e-9:
            raise ValueError(
                f"bucket_s mismatch: {self.bucket_s} vs {other.bucket_s}")
        for jid, buckets in other._acc.items():
            mine = self._acc.setdefault(jid, {})
            for idx, (w, wv) in buckets.items():
                acc = mine.setdefault(idx, [0.0, 0.0])
                acc[0] += w
                acc[1] += wv
        self.generation += 1
        return self

    def copy(self) -> "MfuRollup":
        out = MfuRollup(self.bucket_s)
        out._acc = {jid: {idx: list(acc) for idx, acc in buckets.items()}
                    for jid, buckets in self._acc.items()}
        out.generation = self.generation
        return out

    # -- readout --------------------------------------------------------
    @property
    def jobs(self) -> list:
        return list(self._acc)

    def job_buckets(self, job_id: str) -> np.ndarray:
        """Sorted absolute bucket indices holding samples for a job."""
        return np.array(sorted(self._acc.get(job_id, {})), dtype=int)

    def job_series(self, job_id: str):
        """(bucket_idx, per-bucket weighted-mean MFU) aligned arrays."""
        buckets = self._acc.get(job_id, {})
        idx = np.array(sorted(buckets), dtype=int)
        mean = np.array([buckets[i][1] / buckets[i][0] for i in idx],
                        dtype=float)
        return idx, mean

    def job_mean(self, job_id: str) -> Optional[float]:
        """Weight-weighted all-time MFU, or None if the job never
        reported — the value collector rounds feed into job metadata."""
        buckets = self._acc.get(job_id)
        if not buckets:
            return None
        w = sum(acc[0] for acc in buckets.values())
        wv = sum(acc[1] for acc in buckets.values())
        return wv / w

    def n_samples(self, job_id: str) -> float:
        return sum(acc[0] for acc in self._acc.get(job_id, {}).values())

    # -- wire (the POST /v1/mfu body) -----------------------------------
    def to_payload(self) -> dict:
        """JSON-ready dump: {"bucket_s", "jobs": {id: [[bucket, w, wv]]}}."""
        return {"bucket_s": self.bucket_s,
                "jobs": {jid: [[int(i), acc[0], acc[1]]
                               for i, acc in sorted(buckets.items())]
                         for jid, buckets in self._acc.items()}}

    def apply_payload(self, payload: dict) -> int:
        """Accumulate a `to_payload` dump (or a raw-sample body:
        {"job_id", "samples": [[t_s, mfu], ...]}).  Returns the number
        of rows applied; raises ValueError on a malformed body."""
        if not isinstance(payload, dict):
            raise ValueError("payload must be a JSON object")
        if "samples" in payload:
            jid = payload.get("job_id")
            samples = payload["samples"]
            if not jid or not isinstance(samples, list):
                raise ValueError(
                    'raw body needs "job_id" and "samples": [[t_s, mfu]]')
            try:
                pairs = [(float(t), float(v)) for t, v in samples]
            except (TypeError, ValueError):
                raise ValueError(
                    "samples must be [t_s, mfu] number pairs") from None
            if pairs:
                t, v = zip(*pairs)
                self.observe_series(jid, t, v)
            return len(pairs)
        jobs = payload.get("jobs")
        if not isinstance(jobs, dict):
            raise ValueError('payload needs "jobs" or "samples"')
        b = payload.get("bucket_s", self.bucket_s)
        if abs(float(b) - self.bucket_s) > 1e-9:
            raise ValueError(
                f"bucket_s mismatch: store has {self.bucket_s}, "
                f"payload has {b}")
        n = 0
        for jid, rows in jobs.items():
            if not jid or not isinstance(rows, list):
                raise ValueError("jobs must map id -> [[bucket, w, wv]]")
            mine = self._acc.setdefault(jid, {})
            for row in rows:
                try:
                    idx, w, wv = int(row[0]), float(row[1]), float(row[2])
                except (TypeError, ValueError, IndexError):
                    raise ValueError(
                        "rows must be [bucket, weight, weighted_sum] "
                        "triples") from None
                if w <= 0:
                    raise ValueError(f"row weight {w} must be positive")
                acc = mine.setdefault(idx, [0.0, 0.0])
                acc[0] += w
                acc[1] += wv
                n += 1
        if n:
            self.generation += 1
        return n


# ---------------------------------------------------------------------------
# join + statistics
# ---------------------------------------------------------------------------
def joined_series(mfu_roll: MfuRollup, roll, job_id: str):
    """Align one job's MFU and OFU bucket series by ABSOLUTE bucket
    index; returns (bucket_idx, mfu, ofu) over the intersection (empty
    arrays when either side lacks the job).  `roll` is a Streaming- or
    WindowedRollup (`bucket0` anchors window rows to absolute buckets).
    """
    if abs(mfu_roll.bucket_s - roll.bucket_s) > 1e-9:
        raise ValueError(f"bucket_s mismatch: MFU {mfu_roll.bucket_s} "
                         f"vs OFU {roll.bucket_s}")
    midx, mval = mfu_roll.job_series(job_id)
    stats = roll.job_stats(job_id, qs=())
    empty = np.empty(0)
    if not midx.size or not stats.mean.size:
        return empty.astype(int), empty, empty
    rows = np.nonzero(stats.weight > 0)[0]
    oidx = rows + roll.bucket0
    common, mi, oi = np.intersect1d(midx, oidx, return_indices=True)
    return common, mval[mi], stats.mean[rows][oi]


def rolling_pearson(x, y, window: int = 8) -> np.ndarray:
    """Trailing-window Pearson r at every index (0.0 until two points
    are in the window or while variance is degenerate) — the dashboard
    sparkline for "is this job's app report tracking its counters"."""
    if window < 2:
        raise ValueError(f"window={window} must be >= 2")
    x = np.asarray(x, float)
    y = np.asarray(y, float)
    if x.shape != y.shape or x.ndim != 1:
        raise ValueError("x and y must be equal-length 1-D")
    out = np.zeros(x.size)
    for i in range(x.size):
        lo = max(0, i - window + 1)
        if i - lo >= 1:
            out[i] = pearson_r(x[lo:i + 1], y[lo:i + 1])
    return out


@dataclass(frozen=True)
class MiscalcFinding:
    """One job flagged by the OFU/MFU-ratio detector."""

    job_id: str
    ratio: float            # mean MFU / mean adjusted OFU
    mfu: float
    ofu: float              # raw (uncorrected) joined-bucket mean
    ofu_adj: float          # tile-quantization-corrected (Eq. 8)
    tq_factor: float
    n_buckets: int
    first_bucket: int       # absolute bucket of the first joined sample
    direction: str          # 'inflated' | 'deflated'

    def to_dict(self) -> dict:
        return {"job_id": self.job_id, "ratio": self.ratio,
                "mfu": self.mfu, "ofu": self.ofu,
                "ofu_adj": self.ofu_adj, "tq_factor": self.tq_factor,
                "n_buckets": self.n_buckets,
                "first_bucket": self.first_bucket,
                "direction": self.direction}


@dataclass
class CorrelationConfig:
    """Knobs for the miscalculation scan (defaults match §V-C: the
    naive counters inflate reported FLOPs ~1.8-3x, healthy reporting
    noise stays well inside +-50%)."""

    ratio_high: float = 1.5
    ratio_low: Optional[float] = None    # default: 1 / ratio_high
    min_buckets: int = 1
    ofu_floor: float = DEFAULT_OFU_FLOOR
    window: int = 8

    def __post_init__(self):
        if self.ratio_high <= 1.0:
            raise ValueError(
                f"ratio_high={self.ratio_high} must be > 1")
        if self.ratio_low is None:
            self.ratio_low = 1.0 / self.ratio_high
        if not 0 < self.ratio_low < 1.0:
            raise ValueError(
                f"ratio_low={self.ratio_low} must be in (0, 1)")
        if self.min_buckets < 1:
            raise ValueError(
                f"min_buckets={self.min_buckets} must be >= 1")
        if self.window < 2:
            raise ValueError(f"window={self.window} must be >= 2")


def _job_join_stats(mfu_roll, roll, job_id, cfg):
    """Per-job joined aggregates, or None when the join is too thin to
    judge (no overlap, too few buckets, sub-floor OFU)."""
    idx, mval, oval = joined_series(mfu_roll, roll, job_id)
    if idx.size < cfg.min_buckets:
        return None
    meta = roll.job_meta(job_id) or {}
    tq = tile_quant_factor(meta.get("arch", "unknown"))
    mfu = float(mval.mean())
    ofu = float(oval.mean())
    ofu_adj = ofu / tq
    return {"job_id": job_id, "idx": idx, "mfu": mfu, "ofu": ofu,
            "ofu_adj": ofu_adj, "tq": tq, "meta": meta,
            "r_rolling": float(rolling_pearson(
                mval, oval, cfg.window)[-1]) if idx.size >= 2 else 0.0}


def _joined_rows(mfu_roll, roll, cfg) -> list:
    rows = []
    for jid in sorted(set(mfu_roll.jobs) & set(roll.jobs)):
        s = _job_join_stats(mfu_roll, roll, jid, cfg)
        if s is not None:
            rows.append(s)
    return rows


def _scan_rows(rows: list, cfg: CorrelationConfig) -> list:
    findings = []
    for s in rows:
        if s["ofu_adj"] < cfg.ofu_floor:
            continue
        ratio = s["mfu"] / s["ofu_adj"]
        if cfg.ratio_low <= ratio <= cfg.ratio_high:
            continue
        findings.append(MiscalcFinding(
            job_id=s["job_id"], ratio=ratio, mfu=s["mfu"], ofu=s["ofu"],
            ofu_adj=s["ofu_adj"], tq_factor=s["tq"],
            n_buckets=int(s["idx"].size),
            first_bucket=int(s["idx"][0]),
            direction="inflated" if ratio > 1.0 else "deflated"))
    findings.sort(key=lambda f: abs(np.log(max(f.ratio, 1e-12))),
                  reverse=True)
    return findings


def scan_miscalc(mfu_roll: MfuRollup, roll, *,
                 config: Optional[CorrelationConfig] = None) -> list:
    """Flag every joined job whose MFU / adjusted-OFU ratio falls
    outside [ratio_low, ratio_high] — the §V-C miscalculation
    signature.  Returns `MiscalcFinding`s sorted by |log ratio| desc
    (worst offender first)."""
    cfg = config or CorrelationConfig()
    return _scan_rows(_joined_rows(mfu_roll, roll, cfg), cfg)


@dataclass
class CorrelationReport:
    """Fleet-level join summary: the live-path Table III."""

    n_jobs: int                  # jobs with a usable join
    r_all: float                 # per-job mean MFU vs adjusted OFU
    r_clean: float               # same, flagged jobs excluded
    mae: float                   # mean |MFU - adjusted OFU|
    flagged: list = field(default_factory=list)   # MiscalcFinding
    by_scale: dict = field(default_factory=dict)  # chips -> (n, mfu, ae)
    jobs: list = field(default_factory=list)      # per-job rows (dict)

    def to_payload(self) -> dict:
        """Strict-JSON dict (finite floats only) for the serve tier."""
        def _f(x):
            return float(x) if np.isfinite(x) else None
        return {
            "n_jobs": self.n_jobs,
            "r_all": _f(self.r_all), "r_clean": _f(self.r_clean),
            "mae": _f(self.mae),
            "flagged": [f.to_dict() for f in self.flagged],
            "by_scale": {str(c): {"jobs": n, "mfu": _f(m),
                                  "abs_err": _f(e)}
                         for c, (n, m, e) in sorted(self.by_scale.items())},
            "jobs": self.jobs,
        }

    def summary(self) -> str:
        lines = [f"joined_jobs={self.n_jobs} r_all={self.r_all:.3f} "
                 f"r_after_exclusion={self.r_clean:.3f} "
                 f"mae={self.mae * 100:.1f}pp "
                 f"flagged={len(self.flagged)}"]
        for chips, (n, m, e) in sorted(self.by_scale.items()):
            lines.append(f"  chips={chips:>5d} jobs={n:>4d} "
                         f"mfu={m * 100:5.1f}% abs_err={e * 100:5.1f}pp")
        return "\n".join(lines)


def analyze_correlation(mfu_roll: MfuRollup, roll, *,
                        config: Optional[CorrelationConfig] = None
                        ) -> CorrelationReport:
    """Join every reporting job against its OFU rollup and build the
    fleet report: correlation with/without the miscalculation set, MAE
    of tile-quantization-corrected residuals, per-scale error table.

    Degenerate populations (no joins, one job, zero variance) yield
    finite zeros, never NaN — the payload must survive strict JSON.
    """
    cfg = config or CorrelationConfig()
    rows = _joined_rows(mfu_roll, roll, cfg)
    flagged = _scan_rows(rows, cfg)
    flagged_ids = {f.job_id for f in flagged}

    if not rows:
        return CorrelationReport(n_jobs=0, r_all=0.0, r_clean=0.0,
                                 mae=0.0, flagged=flagged)
    mfu = np.array([s["mfu"] for s in rows])
    adj = np.array([s["ofu_adj"] for s in rows])
    err = np.abs(mfu - adj)
    clean = [i for i, s in enumerate(rows)
             if s["job_id"] not in flagged_ids]

    by_scale: dict = {}
    scale = np.array([int(s["meta"].get("chips") or 0) for s in rows])
    for chips in sorted(set(scale.tolist())):
        sel = scale == chips
        by_scale[chips] = (int(sel.sum()), float(mfu[sel].mean()),
                           float(err[sel].mean()))

    job_rows = [{"job_id": s["job_id"],
                 "arch": s["meta"].get("arch", "unknown"),
                 "chips": int(s["meta"].get("chips") or 0),
                 "n_buckets": int(s["idx"].size),
                 "mfu": s["mfu"], "ofu": s["ofu"],
                 "ofu_adj": s["ofu_adj"], "tq_factor": s["tq"],
                 "residual": s["mfu"] - s["ofu_adj"],
                 "r_rolling": s["r_rolling"],
                 "flagged": s["job_id"] in flagged_ids}
                for s in rows]

    return CorrelationReport(
        n_jobs=len(rows),
        r_all=pearson_r(mfu, adj) if len(rows) >= 2 else 0.0,
        r_clean=pearson_r(mfu[clean], adj[clean])
        if len(clean) >= 2 else 0.0,
        mae=float(err.mean()),
        flagged=flagged,
        by_scale=by_scale,
        jobs=job_rows,
    )
