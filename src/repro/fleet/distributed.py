"""Distributed rollup reduction: per-host rollups → one fleet dashboard.

`StreamingRollup` is a monoid element — per-bucket histogram weights and
value sums ADD — so any reduction tree over per-host rollups reproduces
single-process ingestion bucket for bucket.  This module models the
multi-host wiring: each host folds only its own devices' scrapes into a
local rollup, ships the fixed-size `to_bytes()` snapshot (kilobytes,
independent of device count), and `tree_reduce` folds the snapshots level
by level — raw scrapes never leave their host.
"""
from __future__ import annotations

from typing import Sequence

from repro.fleet.streaming import StreamingRollup


def _empty_like(roll: StreamingRollup) -> StreamingRollup:
    return StreamingRollup(roll.bucket_s, bins=roll.bins,
                           lo=float(roll.edges[0]), hi=float(roll.edges[-1]))


def host_partition(items: Sequence, n_hosts: int) -> list:
    """Round-robin items (specs, telemetries, device ids) across hosts."""
    if n_hosts < 1:
        raise ValueError(f"n_hosts={n_hosts} must be >= 1")
    return [list(items[h::n_hosts]) for h in range(n_hosts)]


def tree_reduce(rollups: Sequence, *, fanin: int = 2) -> StreamingRollup:
    """Reduce per-host rollups to one fleet rollup, `fanin` at a time.

    Elements may be StreamingRollup objects or their `to_bytes()` blobs
    (deserialized on arrival, as a reducer host would).  Inputs are never
    mutated; the result is a fresh rollup.  Because merge is associative
    and commutative, every (fanin, ordering) choice yields bucketwise-
    identical fleet stats.
    """
    if fanin < 2:
        raise ValueError(f"fanin={fanin} must be >= 2")
    level = [StreamingRollup.from_bytes(r)
             if isinstance(r, (bytes, bytearray)) else r for r in rollups]
    if not level:
        raise ValueError("tree_reduce needs at least one rollup")
    if len(level) == 1:
        return _empty_like(level[0]).merge(level[0])
    while len(level) > 1:
        nxt = []
        for i in range(0, len(level), fanin):
            acc = _empty_like(level[i])
            for r in level[i:i + fanin]:
                acc.merge(r)
            nxt.append(acc)
        level = nxt
    return level[0]
