"""Distributed rollup reduction: per-host rollups → one fleet dashboard.

`StreamingRollup` is a monoid element — per-bucket histogram weights and
value sums ADD — so any reduction tree over per-host rollups reproduces
single-process ingestion bucket for bucket.  This module models the
multi-host wiring: each host folds only its own devices' scrapes into a
local rollup, ships the fixed-size `to_bytes()` snapshot (kilobytes,
independent of device count), and `tree_reduce` folds the snapshots level
by level — raw scrapes never leave their host.
"""
from __future__ import annotations

from typing import Sequence

from repro.fleet.streaming import StreamingRollup


def _empty_like(roll: StreamingRollup) -> StreamingRollup:
    # polymorphic: a WindowedRollup reduces to a WindowedRollup (same
    # retention), so collector snapshots tree-reduce like batch rollups
    return roll.spawn_empty()


def host_partition(items: Sequence, n_hosts: int) -> list:
    """Round-robin items (specs, telemetries, device ids) across hosts."""
    if n_hosts < 1:
        raise ValueError(f"n_hosts={n_hosts} must be >= 1")
    return [list(items[h::n_hosts]) for h in range(n_hosts)]


def tree_reduce(rollups: Sequence, *, fanin: int = 2) -> StreamingRollup:
    """Reduce per-host rollups to one fleet rollup, `fanin` at a time.

    Elements may be StreamingRollup/WindowedRollup objects or their
    `to_bytes()` blobs (deserialized on arrival, as a reducer host would —
    the wire format is self-describing).  Inputs are never mutated; the
    result is a fresh rollup.  Because merge is associative and
    commutative — windowed merges align by absolute bucket index and
    evict identically regardless of order — every (fanin, ordering)
    choice yields bucketwise-identical fleet stats.
    """
    if fanin < 2:
        raise ValueError(f"fanin={fanin} must be >= 2")
    level = [StreamingRollup.from_bytes(r)
             if isinstance(r, (bytes, bytearray)) else r for r in rollups]
    if not level:
        raise ValueError("tree_reduce needs at least one rollup")
    if len(level) == 1:
        return _empty_like(level[0]).merge(level[0])
    while len(level) > 1:
        nxt = []
        for i in range(0, len(level), fanin):
            group = level[i:i + fanin]
            # accumulate into a windowed rollup whenever the group has
            # one: windowed absorbs plain (a window starting at bucket 0)
            # but not vice versa, so the choice must not depend on which
            # host happens to come first
            seed = next((r for r in group
                         if getattr(r, "retain", None) is not None),
                        group[0])
            # one vectorized k-way fold per group (falls back to the
            # pairwise loop automatically when the group is windowed)
            acc = _empty_like(seed).merge_many(group)
            nxt.append(acc)
        level = nxt
    return level[0]
