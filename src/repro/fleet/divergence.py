"""MFU-vs-OFU divergence triage (paper §V-B/§V-C).

Given a population of jobs with both app-reported MFU and counter-derived
OFU, compute the correlation table, flag jobs whose divergence exceeds a
threshold (the FLOPs-miscalculation signature), and report the correlation
with/without the flagged set — the paper's r = 0.53 -> 0.78 move.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.ofu import mae, pearson_r


@dataclass
class JobPoint:
    job_id: str
    arch: str
    chips: int
    mfu: float      # fraction
    ofu: float      # fraction
    flops_variant: str = "exact"

    @property
    def abs_err(self) -> float:
        return abs(self.mfu - self.ofu)

    @property
    def rel_err(self) -> float:
        return abs(self.mfu - self.ofu) / max(self.ofu, 1e-6)


@dataclass
class DivergenceReport:
    r_all: float
    r_clean: float
    mae_all: float
    flagged: list
    frac_within_10pp: float
    frac_over_20pp: float
    by_scale: dict

    def summary(self) -> str:
        lines = [
            f"jobs_r_all={self.r_all:.3f} r_after_exclusion={self.r_clean:.3f}",
            f"mae={self.mae_all * 100:.1f}pp "
            f"within10pp={self.frac_within_10pp * 100:.1f}% "
            f"over20pp={self.frac_over_20pp * 100:.1f}% "
            f"flagged={len(self.flagged)}",
        ]
        for chips, (n, m, e) in sorted(self.by_scale.items()):
            lines.append(f"  chips={chips:>5d} jobs={n:>4d} "
                         f"mfu={m * 100:5.1f}% abs_err={e * 100:5.1f}pp")
        return "\n".join(lines)


#: Jobs whose OFU sits below this fraction are too idle to triage: the
#: rel_err denominator is numerically meaningless there (a parked job
#: with OFU=1e-4 and any nonzero reported MFU looks like a 1000x
#: miscalculation).  Sub-floor jobs still count toward the correlation
#: and error statistics — they are only exempt from FLAGGING.
DEFAULT_OFU_FLOOR = 0.02


def _empty_report() -> DivergenceReport:
    """NaN-free placeholder for an empty population — every field a
    strict-JSON serializer can pass through unchanged."""
    return DivergenceReport(r_all=0.0, r_clean=0.0, mae_all=0.0,
                            flagged=[], frac_within_10pp=1.0,
                            frac_over_20pp=0.0, by_scale={})


def analyze(jobs: list, *, flag_rel_err: float = 0.30,
            ofu_floor: float = DEFAULT_OFU_FLOOR) -> DivergenceReport:
    """Flag jobs with relative divergence > flag_rel_err (miscalc signature).

    Jobs with OFU below `ofu_floor` are never flagged (their rel_err is
    dominated by the denominator floor, not by miscalculation), and
    degenerate populations (empty, single job, zero variance) yield
    finite zero-correlation defaults rather than NaN — the report must
    survive `json.dumps(allow_nan=False)` on the serve path.
    """
    if not jobs:
        return _empty_report()
    mfu = np.array([j.mfu for j in jobs])
    ofu = np.array([j.ofu for j in jobs])
    err = np.abs(mfu - ofu)

    flagged = [j for j in jobs
               if j.ofu >= ofu_floor and j.rel_err > flag_rel_err]
    flagged_ids = {j.job_id for j in flagged}
    clean = [j for j in jobs if j.job_id not in flagged_ids]

    by_scale: dict = {}
    for chips in sorted({j.chips for j in jobs}):
        grp = [j for j in jobs if j.chips == chips]
        by_scale[chips] = (len(grp),
                           float(np.mean([j.mfu for j in grp])),
                           float(np.mean([j.abs_err for j in grp])))

    # pearson_r already returns 0.0 on a zero-variance denominator; the
    # len guards keep the <2-sample mean subtraction from warning/NaN-ing
    return DivergenceReport(
        r_all=pearson_r(mfu, ofu) if len(jobs) >= 2 else 0.0,
        r_clean=pearson_r([j.mfu for j in clean], [j.ofu for j in clean])
        if len(clean) >= 2 else 0.0,
        mae_all=float(err.mean()),
        flagged=flagged,
        frac_within_10pp=float(np.mean(err <= 0.10)),
        frac_over_20pp=float(np.mean(err > 0.20)),
        by_scale=by_scale,
    )


def analyze_rollup(roll, *, flag_rel_err: float = 0.30,
                   ofu_floor: float = DEFAULT_OFU_FLOOR,
                   empty_ok: bool = False) -> Optional[DivergenceReport]:
    """Triage straight off a StreamingRollup (simulated, replayed, or
    tree-reduced): uses the rollup's per-job OFU plus the app-reported MFU
    registered at ingest (add_job, or add_grid(app_mfu=...) for traces).

    empty_ok=True returns None instead of raising when no job carries MFU
    metadata — the continuous-collector case, where triage runs every
    round whether or not MFU-reporting jobs have appeared yet."""
    pts = roll.to_job_points()
    if not pts:
        if empty_ok:
            return None
        raise ValueError(
            "rollup has no jobs with app-MFU metadata; ingest via add_job "
            "or add_grid(app_mfu=...) before divergence triage")
    return analyze(pts, flag_rel_err=flag_rel_err, ofu_floor=ofu_floor)
