"""Vectorized fleet telemetry engine (fleet-scale §V-B/§VI simulation).

The scalar `SimulatedDeviceBackend` advances one device one poll at a time
— Python loops over sub-step duty samples and OU clock sub-steps — which
tops out at a few hundred device-minutes per wall-second.  The paper's
fleet scenarios (608 jobs, thousands of GPUs, hours of scrapes) need four
orders of magnitude more.  This engine simulates the SAME generative model
as batched NumPy array ops, at two fusion levels:

  * `simulate_devices` — one device group (one job) as an
    (n_devices, n_samples) grid: duty via `telemetry.counters.duty_grid`
    (vectorized event masks) averaged over the hardware window, clock via
    one batched OU pass (`ClockModel.simulate_batch`), per-step jitter as
    a single lognormal draw.
  * `simulate_jobs_fused` — a whole MULTI-JOB fleet stacked into one
    padded (total_devices, S_max) grid.  Ragged job durations pad to the
    longest job and are sliced back on output (OU padding sits at the tail
    of each row, so valid samples are untouched); jobs are grouped by
    (scrape interval, clock-model constants) so each group shares one time
    grid, one jitter draw, and ONE batched OU recurrence — the per-group
    Python cost is O(S_max × K) regardless of job count.  Event-free jobs
    skip the duty sub-sample grid entirely (their deterministic duty is
    constant in time); evented jobs evaluate it device-batched.

The scalar backend remains the reference implementation; equivalence is
statistical (same seed/profile ⇒ matching tpa/clock statistics within
tolerance), covered by tests/test_fleet_engine.py.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from repro.core.peaks import DEFAULT_CHIP, ChipSpec
from repro.telemetry.clock import ClockModel
from repro.telemetry.counters import (Event, StepProfile,
                                      check_scrape_interval, event_factors)
from repro.telemetry.scrape import DeviceGrid  # noqa: F401  (re-export)


@dataclass
class EngineParams:
    """Fidelity knobs for the vectorized path.

    (A clock_substeps knob existed through PR 1; it is gone because the OU
    drive — duty at window ends — is piecewise-constant within a scrape
    interval, so `simulate_batch`'s exact discretization takes ONE step
    per scrape sample and sub-steps only ever added intermediate clipping
    >10σ from the clip bounds.)
    """

    n_sub_max: int = 64          # duty sub-samples per averaging window


@dataclass
class JobSlot:
    """One job's slot in a fused multi-job grid (the engine-level view:
    no configs, no FLOPs — just the step profile and its timeline)."""

    profile: StepProfile
    duration_s: float
    interval_s: float
    events: Sequence[Event] = ()
    stragglers: Optional[np.ndarray] = None   # (n_devices,); default: [1.0]
    chip: ChipSpec = DEFAULT_CHIP
    clock_model: Optional[ClockModel] = None


# ---------------------------------------------------------------------------
# Fault injection: post-hoc counter perturbation (scenario ground truth)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class CounterFault:
    """A declarative counter-stream perturbation with a known timeline.

    `Event` feeds the GENERATIVE model (it changes what the simulated
    hardware does, sample statistics and OU drive included).  A
    CounterFault instead perturbs the OBSERVED counters after the engine
    pass — multiplicative masks over the (device, sample) grid — which is
    what the scenario library needs for ground-truth labels: the
    perturbation applies identically on every backend (scalar, vector,
    fused, jax), so a detector scorecard measures the detector, never
    engine-equivalence noise.

    Timeline: active on samples with start_s <= t < end_s.  period_s > 0
    gates that window into repeating bursts (active for the first
    `active_frac` of each period — preemption waves, MoE imbalance
    bursts).  diurnal_amp adds a sinusoidal duty modulation with period
    diurnal_period_s (multi-tenant inference load shapes).

    Scope: all devices by default; `devices` pins an explicit row subset,
    else `device_frac` takes the leading ceil(frac × D) rows (stable and
    seed-free — straggler-host scenarios stay reproducible).
    """

    start_s: float = 0.0
    end_s: float = float("inf")
    duty_scale: float = 1.0          # multiplies tpa while active
    clock_scale: float = 1.0         # multiplies clock_mhz while active
    device_frac: float = 1.0
    devices: Optional[tuple] = None  # explicit device rows (wins over frac)
    period_s: float = 0.0
    active_frac: float = 1.0
    diurnal_amp: float = 0.0
    diurnal_period_s: float = 86400.0
    kind: str = "fault"

    def __post_init__(self):
        if self.end_s < self.start_s:
            raise ValueError(f"fault window [{self.start_s}, {self.end_s}) "
                             "is reversed")
        if not 0.0 < self.device_frac <= 1.0:
            raise ValueError(f"device_frac={self.device_frac} must be in "
                             "(0, 1]")
        if self.period_s < 0 or not 0.0 < self.active_frac <= 1.0:
            raise ValueError(f"need period_s >= 0 (got {self.period_s}) "
                             f"and active_frac in (0, 1] "
                             f"(got {self.active_frac})")
        if abs(self.diurnal_amp) > 1.0:
            raise ValueError(f"diurnal_amp={self.diurnal_amp} must stay "
                             "within ±1 (duty cannot go negative)")


def fault_factors(faults: Sequence[CounterFault], times_s: np.ndarray,
                  n_devices: int) -> tuple[np.ndarray, np.ndarray]:
    """(duty, clock) multiplicative factor grids, shape (D, S) float32.

    Later faults compound multiplicatively with earlier ones on samples
    where both are active (a throttled straggler is both slow AND hot).
    """
    t = np.asarray(times_s, float).ravel()
    duty = np.ones((n_devices, t.size), dtype=np.float32)
    clock = np.ones((n_devices, t.size), dtype=np.float32)
    for f in faults:
        on = (f.start_s <= t) & (t < f.end_s)
        if f.period_s > 0:
            phase = np.mod(t - f.start_s, f.period_s)
            on &= phase < f.active_frac * f.period_s
        if not on.any():
            continue
        if f.devices is not None:
            rows = np.asarray(f.devices, int)
            if rows.size and (rows.min() < 0 or rows.max() >= n_devices):
                raise ValueError(f"fault devices {list(rows)} out of range "
                                 f"for {n_devices} device(s)")
        else:
            rows = np.arange(int(np.ceil(f.device_frac * n_devices)))
        d = np.full(t.size, 1.0, dtype=np.float32)
        d[on] = f.duty_scale
        if f.diurnal_amp:
            wave = 1.0 + f.diurnal_amp * np.sin(
                2.0 * np.pi * t / f.diurnal_period_s)
            d[on] = (d * wave.astype(np.float32))[on]
        duty[rows] *= d[None, :]
        if f.clock_scale != 1.0:
            c = np.full(t.size, 1.0, dtype=np.float32)
            c[on] = f.clock_scale
            clock[rows] *= c[None, :]
    return duty, clock


def apply_faults(grid: DeviceGrid,
                 faults: Sequence[CounterFault]) -> DeviceGrid:
    """Perturb a simulated grid's counters per the fault timeline.

    Pure post-processing: multiplies tpa/clock by `fault_factors` masks
    (duty clipped back into [0, 1]) and returns a NEW DeviceGrid with the
    same interval/t0.  Works on host numpy grids and jax device grids
    alike — the arithmetic goes through the grid arrays' own operators,
    so a device-resident grid stays on device.
    """
    if not faults:
        return grid
    if grid.tpa.size == 0:
        return DeviceGrid(grid.interval_s, grid.tpa, grid.clock_mhz,
                          t0_s=grid.t0_s)
    duty_f, clock_f = fault_factors(faults, grid.times_s, grid.n_devices)
    tpa = (grid.tpa * duty_f).clip(0.0, 1.0)
    clk = (grid.clock_mhz * clock_f).clip(0.0, None)
    return DeviceGrid(grid.interval_s, tpa, clk, t0_s=grid.t0_s)


def simulate_devices(profile: StepProfile, *, duration_s: float,
                     interval_s: float,
                     chip: ChipSpec = DEFAULT_CHIP,
                     clock_model: Optional[ClockModel] = None,
                     events: Sequence[Event] = (),
                     stragglers=None, n_devices: Optional[int] = None,
                     seed: int = 0,
                     params: Optional[EngineParams] = None) -> DeviceGrid:
    """Simulate a whole device group's counter streams in one shot.

    stragglers: optional (n_devices,) per-device step-time multipliers;
    defaults to 1.0 everywhere.  All devices share the step profile and
    event timeline (the per-job model `simulate_job` uses); straggler
    spread is the per-device degree of freedom.  n_devices defaults to
    len(stragglers) (or 1); passing BOTH requires them to agree — the
    old behaviour quietly simulated len(stragglers) devices whatever
    n_devices said.

    Implemented as a single-slot fused pass — `simulate_jobs_fused` is the
    one grid evaluator, whether one job or six hundred.
    """
    if stragglers is None:
        stragglers = np.ones(1 if n_devices is None else n_devices)
    stragglers = np.asarray(stragglers, float)
    if n_devices is not None and n_devices != len(stragglers):
        raise ValueError(f"n_devices={n_devices} conflicts with "
                         f"len(stragglers)={len(stragglers)}")
    slot = JobSlot(profile, duration_s, interval_s, events=events,
                   stragglers=stragglers, chip=chip, clock_model=clock_model)
    return simulate_jobs_fused([slot], seed=seed, params=params)[0]


def simulate_jobs_fused(slots: Sequence[JobSlot], *, seed: int = 0,
                        params: Optional[EngineParams] = None
                        ) -> list[DeviceGrid]:
    """Simulate many jobs as fused multi-job grids; one DeviceGrid per slot.

    Jobs sharing (scrape interval, clock-model constants) fuse into one
    padded (total_devices, S_max) grid with shared RNG streams; the result
    list is aligned with `slots` regardless of grouping.
    """
    params = params or EngineParams()
    rng = np.random.default_rng(seed)
    out: list = [None] * len(slots)
    for members in group_slots(slots).values():
        _simulate_group(members, out, rng, params)
    return out


def group_slots(slots: Sequence[JobSlot]) -> dict:
    """Group slots by (scrape interval, clock-model constants) — the
    fusion key every batched backend (NumPy here, jax in `engine_jax`)
    shares, so each group gets one time grid and one OU recurrence.
    Values are [(slot index, slot, resolved ClockModel), ...]."""
    groups: dict = {}
    for i, sl in enumerate(slots):
        cm = sl.clock_model or ClockModel(chip=sl.chip)
        key = (float(sl.interval_s), cm.theta, cm.sigma_mhz,
               cm.throttle_frac, cm.f_min_frac, cm.chip.f_max_mhz)
        groups.setdefault(key, []).append((i, sl, cm))
    return groups


def _simulate_group(members, out, rng, params: EngineParams) -> None:
    """One fused pass over all jobs sharing an interval + clock model."""
    interval = float(members[0][1].interval_s)
    cm = members[0][2]
    strag_list = [np.ones(1) if sl.stragglers is None
                  else np.atleast_1d(np.asarray(sl.stragglers, float))
                  for _, sl, _ in members]
    n_dev = np.array([len(s) for s in strag_list])
    S = np.array([max(int(sl.duration_s / interval), 0)
                  for _, sl, _ in members])
    S_max = int(S.max())
    if S_max <= 0:
        for (i, _, _), st in zip(members, strag_list):
            out[i] = DeviceGrid(interval, np.empty((len(st), 0)),
                                np.empty((len(st), 0)))
        return
    avg_w = check_scrape_interval(interval, strict=False)

    J = len(members)
    step = np.array([sl.profile.step_time_s for _, sl, _ in members])
    mxu = np.array([sl.profile.mxu_time_s for _, sl, _ in members])
    jit = np.array([sl.profile.jitter for _, sl, _ in members])
    # same effective sub-sample count as the scalar backend (per job)
    n_eff = np.clip(avg_w / np.maximum(step / 4, 1e-3), 8, 4096).astype(int)
    has_ev = np.array([bool(sl.events) for _, sl, _ in members])
    dev_job = np.repeat(np.arange(J), n_dev)          # (D,) row -> job
    strag = np.concatenate(strag_list)                # (D,)
    D = len(strag)
    t_end = (np.arange(S_max) + 1.0) * interval

    # --- duty: hardware-averaged over the trailing window -----------------
    # the whole tpa pipeline runs float32: counters are duty fractions in
    # [0, 1], so 1e-7 relative granularity is noise-free headroom, and the
    # grid passes move half the bytes
    ratio = (mxu / step).astype(np.float32)           # full-rate duty (J,)
    strag32 = strag.astype(np.float32)
    tpa = np.empty((D, S_max), dtype=np.float32)
    plain = ~has_ev[dev_job]
    # no events -> deterministic duty is constant in time: skip the sub grid
    tpa[plain] = np.minimum(np.float32(1.0), ratio[dev_job][plain]
                            / strag32[plain])[:, None]
    if has_ev.any():
        ev_jobs = np.flatnonzero(has_ev)
        n_sub = int(min(params.n_sub_max, n_eff[ev_jobs].max()))
        offs = (np.arange(n_sub) / n_sub) * avg_w
        ts = (t_end[:, None] - avg_w) + offs[None, :]  # (S_max, n_sub)
        row_off = np.concatenate([[0], np.cumsum(n_dev)])
        # one bounded (S_max, n_sub) base grid per evented job, device
        # rows in bounded blocks — resident memory scales with neither
        # job count nor device count
        block = max(1, 2 ** 24 // (S_max * n_sub))
        for j in ev_jobs:
            slow, scale = event_factors(members[j][1].events, ts)
            base_j = ((mxu[j] * scale)
                      / (step[j] * slow)).astype(np.float32)
            for b0 in range(row_off[j], row_off[j + 1], block):
                rb = slice(b0, min(b0 + block, row_off[j + 1]))
                duty = base_j[None, :, :] / strag32[rb, None, None]
                np.minimum(duty, np.float32(1.0), out=duty)
                tpa[rb] = duty.mean(axis=2, dtype=np.float32)
    # one lognormal draw per (device, sample) with the scalar path's
    # mean-of-n-jittered-subsamples dispersion (σ ≈ jitter / n_eff) —
    # a single shared stream for the whole group
    jitter = rng.standard_normal((D, S_max), dtype=np.float32)
    jitter *= (jit / n_eff).astype(np.float32)[dev_job][:, None]
    np.exp(jitter, out=jitter)
    tpa *= jitter
    np.clip(tpa, 0.0, 1.0, out=tpa)

    # --- clock: ONE batched OU pass for every device of every job ---------
    base_end = np.broadcast_to(ratio[:, None], (J, S_max)).copy()
    for j in np.flatnonzero(has_ev):
        slow_e, scale_e = event_factors(members[j][1].events, t_end - 1e-6)
        base_end[j] = (mxu[j] * scale_e) / (step[j] * slow_e)
    duty_end = base_end[dev_job]
    duty_end /= strag32[:, None]
    np.minimum(duty_end, np.float32(1.0), out=duty_end)             # (D, S)
    # exact OU discretization: one step per scrape sample (the drive is
    # constant within each interval, so no sub-stepping is needed)
    clock = cm.simulate_batch(duty_end, dt_s=interval,
                              seed=int(rng.integers(0, 2 ** 31)))

    row0 = 0
    for (i, _, _), nd, Sj in zip(members, n_dev, S):
        # copies (cheap vs the simulation) so holding one job's telemetry
        # never pins the whole group's padded arrays in memory
        out[i] = DeviceGrid(interval,
                            np.ascontiguousarray(tpa[row0:row0 + nd, :Sj]),
                            np.ascontiguousarray(clock[row0:row0 + nd, :Sj]))
        row0 += nd
