"""Vectorized fleet telemetry engine (fleet-scale §V-B/§VI simulation).

The scalar `SimulatedDeviceBackend` advances one device one poll at a time
— Python loops over sub-step duty samples and OU clock sub-steps — which
tops out at a few hundred device-minutes per wall-second.  The paper's
fleet scenarios (608 jobs, thousands of GPUs, hours of scrapes) need four
orders of magnitude more.  This engine simulates the SAME generative model
as batched NumPy array ops over an (n_devices, n_samples) grid:

  * duty integration: one (D, S, n_sub) grid evaluation via
    `telemetry.counters.duty_grid` (vectorized event masks), averaged over
    the hardware window — replacing D×S Python polls;
  * clock: one batched OU pass (`ClockModel.simulate_batch`) whose
    recurrence loops only over time sub-steps, never over devices;
  * per-step jitter: a single (D, S) lognormal draw matching the scalar
    backend's effective averaging count.

The scalar backend remains the reference implementation; equivalence is
statistical (same seed/profile ⇒ matching tpa/clock statistics within
tolerance), covered by tests/test_fleet_engine.py.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from repro.core.peaks import DEFAULT_CHIP, ChipSpec
from repro.telemetry.clock import ClockModel
from repro.telemetry.counters import (MAX_HW_AVG_WINDOW_S, Event, StepProfile,
                                      duty_grid, event_factors)
from repro.telemetry.scrape import ScrapeSeries


@dataclass
class EngineParams:
    """Fidelity knobs for the vectorized path."""

    n_sub_max: int = 64          # duty sub-samples per averaging window
    clock_substeps_max: int = 16  # OU sub-steps per scrape interval


@dataclass
class DeviceGrid:
    """Batched scrape result: row d is device d's aligned counter series."""

    interval_s: float
    tpa: np.ndarray              # (n_devices, n_samples)
    clock_mhz: np.ndarray        # (n_devices, n_samples)

    @property
    def n_devices(self) -> int:
        return self.tpa.shape[0]

    @property
    def times_s(self) -> np.ndarray:
        """Poll instants (window ends) shared by every device."""
        return (np.arange(self.tpa.shape[1]) + 1) * self.interval_s

    def series(self, d: int) -> ScrapeSeries:
        return ScrapeSeries(self.interval_s, self.tpa[d], self.clock_mhz[d])

    def to_series_list(self) -> list:
        return [self.series(d) for d in range(self.n_devices)]


def simulate_devices(profile: StepProfile, *, duration_s: float,
                     interval_s: float,
                     chip: ChipSpec = DEFAULT_CHIP,
                     clock_model: Optional[ClockModel] = None,
                     events: Sequence[Event] = (),
                     stragglers=None, n_devices: int = 1,
                     seed: int = 0,
                     params: EngineParams = EngineParams()) -> DeviceGrid:
    """Simulate a whole device group's counter streams in one shot.

    stragglers: optional (n_devices,) per-device step-time multipliers;
    defaults to 1.0 everywhere.  All devices share the step profile and
    event timeline (the per-job model `simulate_job` uses); straggler
    spread is the per-device degree of freedom.
    """
    cm = clock_model or ClockModel(chip=chip)
    if stragglers is None:
        stragglers = np.ones(n_devices)
    stragglers = np.asarray(stragglers, float)
    if n_devices not in (1, len(stragglers)):
        raise ValueError(f"n_devices={n_devices} conflicts with "
                         f"len(stragglers)={len(stragglers)}")
    D = len(stragglers)
    S = int(duration_s / interval_s)
    if S <= 0:
        return DeviceGrid(interval_s, np.empty((D, 0)), np.empty((D, 0)))
    rng = np.random.default_rng(seed)
    t_end = (np.arange(S) + 1.0) * interval_s
    avg_w = min(interval_s, MAX_HW_AVG_WINDOW_S)
    if interval_s > MAX_HW_AVG_WINDOW_S:
        # same degraded-mode semantics (and warning) as non-strict scrape():
        # each sample only reflects the trailing 30 s of its interval
        warnings.warn(
            f"scrape interval {interval_s}s exceeds the "
            f"{MAX_HW_AVG_WINDOW_S}s hardware averaging window "
            "(average-of-averages, paper §IV-C); readings only cover the "
            f"trailing {MAX_HW_AVG_WINDOW_S}s of each interval",
            RuntimeWarning, stacklevel=2)

    # --- duty: hardware-averaged over the trailing window -----------------
    # same effective sub-sample count as the scalar backend, capped for the
    # (D, S, n_sub) grid's memory footprint
    n_eff = int(np.clip(avg_w / max(profile.step_time_s / 4, 1e-3),
                        8, 4096))
    n_sub = min(n_eff, params.n_sub_max)
    offs = (np.arange(n_sub) / n_sub) * avg_w
    ts = (t_end[:, None] - avg_w) + offs[None, :]            # (S, n_sub)
    duty = duty_grid(profile, ts[None, :, :],
                     straggler=stragglers[:, None, None],
                     events=events)                          # (D, S, n_sub)
    tpa = duty.mean(axis=2)
    # one lognormal draw per (device, sample) with the scalar path's
    # mean-of-n-jittered-subsamples dispersion (σ ≈ jitter / n_eff)
    tpa = tpa * np.exp(rng.standard_normal((D, S))
                       * profile.jitter / n_eff)
    np.clip(tpa, 0.0, 1.0, out=tpa)

    # --- clock: batched OU point samples at window ends -------------------
    slow_e, scale_e = event_factors(events, t_end - 1e-6)    # (S,)
    duty_end = np.minimum(
        1.0, (profile.mxu_time_s * scale_e)[None, :]
        / (profile.step_time_s * slow_e)[None, :]
        / stragglers[:, None])                               # (D, S)
    K = int(np.clip(round(cm.theta * interval_s * 2), 1,
                    params.clock_substeps_max))
    duty_sub = np.repeat(duty_end, K, axis=1)                # (D, S*K)
    clk = cm.simulate_batch(duty_sub, dt_s=interval_s / K,
                            seed=int(rng.integers(0, 2 ** 31)))
    clock = np.ascontiguousarray(clk[:, K - 1::K])
    return DeviceGrid(interval_s, tpa, clock)
