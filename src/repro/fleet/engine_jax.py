"""jax backend for the fused fleet engine (fleet-scale what-if sweeps).

Reproduces `simulate_jobs_fused`'s generative model on jax so scenario
sweeps scale past what a NumPy grid affords (ROADMAP: "as fast as the
hardware allows"; the MegaScale-class fleets in PAPERS.md are 10k+
accelerators).  Same structure, device arrays instead of ndarrays:

  * jobs grouped by `engine.group_slots` — one padded (D, S_max) grid,
    one jitter draw, and one OU recurrence per (interval, clock-model)
    group, exactly like the NumPy path;
  * evented duty averages the per-window sub-samples with a `lax.scan`
    over the n_sub axis, so resident memory stays O(D·S) however finely
    the hardware window is sub-sampled;
  * the clock is `ClockModel.simulate_batch`'s exact one-step-per-
    interval discretization — `(a, sd) = cm.ou_step_constants(dt)` — as
    a `lax.scan` over time with a (D,) carry;
  * grids carry a `with_sharding_constraint` over a 1-D device mesh
    (rows = devices axis), so on multi-chip hosts XLA partitions the
    whole pipeline; on a single device it is a no-op.

Equivalence to the NumPy reference is statistical, not bitwise (jax
threefry vs NumPy philox draws), frozen by the same-tolerance property
suite in tests/test_engine_jax.py.  The grids come back as device
arrays: `StreamingRollup.add_grid` recognizes them and reduces OFU
histograms on-device (`repro.kernels.fleet_hist`) instead of pulling
per-device telemetry to host — pass materialize=True to opt out.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.fleet.engine import EngineParams, JobSlot, group_slots
from repro.telemetry.counters import check_scrape_interval, event_factors
from repro.telemetry.scrape import DeviceGrid


def default_mesh() -> Optional[jax.sharding.Mesh]:
    """1-D mesh over every visible accelerator; None on single-device
    hosts (a sharding constraint there is pure overhead)."""
    devs = jax.devices()
    if len(devs) <= 1:
        return None
    return jax.sharding.Mesh(np.array(devs), ("devices",))


def _shard(x, mesh):
    """Constrain rows (devices) across the mesh; skipped when rows do not
    divide the mesh (jit lowering rejects uneven shards)."""
    if mesh is None or x.shape[0] % mesh.size:
        return x
    spec = jax.sharding.PartitionSpec("devices",
                                      *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec))


@functools.partial(jax.jit, static_argnames=("S", "n_sub", "consts", "mesh"))
def _group_device_sim(ratio, strag, dev_job, sig, ev_base, ev_rows,
                      ev_job_of_row, strag_e, base_end, k_jit, k_clk, *,
                      S: int, n_sub: int, consts: tuple, mesh):
    """Device half of one fused group: (tpa, clock), both (D, S) f32."""
    f32 = jnp.float32
    D = strag.shape[0]

    # --- duty -> tpa: constant rows for event-free jobs, lax.scan mean
    # over the window sub-samples for evented rows ------------------------
    duty_p = jnp.minimum(1.0, jnp.take(ratio, dev_job) / strag)
    tpa_det = jnp.broadcast_to(duty_p[:, None], (D, S))
    if ev_rows.shape[0]:
        def sub_step(acc, base_k):               # base_k: (J_e, S)
            d = jnp.minimum(1.0, jnp.take(base_k, ev_job_of_row, axis=0)
                            / strag_e[:, None])
            return acc + d, None
        acc, _ = jax.lax.scan(
            sub_step, jnp.zeros((ev_rows.shape[0], S), f32), ev_base)
        tpa_det = tpa_det.at[ev_rows].set(acc * (1.0 / n_sub))
    tpa_det = _shard(tpa_det, mesh)
    # single lognormal jitter draw, σ ≈ jitter / n_eff (NumPy path's
    # mean-of-n-jittered-subsamples dispersion)
    z = jax.random.normal(k_jit, (D, S), dtype=f32)
    tpa = jnp.clip(tpa_det * jnp.exp(z * sig[:, None]), 0.0, 1.0)

    # --- clock: exact OU discretization, one lax.scan step per sample ----
    a, sd, f_min, f_max, throttle = consts
    duty_end = jnp.minimum(1.0, jnp.take(base_end, dev_job, axis=0)
                           / strag[:, None])
    # drive = μ(duty)·(1−a) + σ·dW, time-major like simulate_batch
    drive = (f_max * (1.0 - a)) * (1.0 - throttle * duty_end.T) \
        + sd * jax.random.normal(k_clk, (S, D), dtype=f32)

    def ou_step(cur, dr):
        cur = jnp.clip(cur * a + dr, f_min, f_max)
        return cur, cur

    cur0 = f_max * (1.0 - throttle * duty_end[:, 0])   # mean_clock(duty₀)
    _, f = jax.lax.scan(ou_step, cur0, drive)
    return tpa, _shard(f.T, mesh)


def _simulate_group_jax(members, out, rng, params, mesh, materialize):
    """Host half: mirrors `engine._simulate_group`'s prep (same event
    factors, same n_eff/n_sub policy), then hands one jitted call the
    per-group arrays."""
    interval = float(members[0][1].interval_s)
    cm = members[0][2]
    strag_list = [np.ones(1) if sl.stragglers is None
                  else np.atleast_1d(np.asarray(sl.stragglers, float))
                  for _, sl, _ in members]
    n_dev = np.array([len(s) for s in strag_list])
    S = np.array([max(int(sl.duration_s / interval), 0)
                  for _, sl, _ in members])
    S_max = int(S.max())
    if S_max <= 0:
        for (i, _, _), st in zip(members, strag_list):
            out[i] = DeviceGrid(interval, np.empty((len(st), 0)),
                                np.empty((len(st), 0)))
        return
    avg_w = check_scrape_interval(interval, strict=False)

    J = len(members)
    step = np.array([sl.profile.step_time_s for _, sl, _ in members])
    mxu = np.array([sl.profile.mxu_time_s for _, sl, _ in members])
    jit = np.array([sl.profile.jitter for _, sl, _ in members])
    n_eff = np.clip(avg_w / np.maximum(step / 4, 1e-3), 8, 4096).astype(int)
    has_ev = np.array([bool(sl.events) for _, sl, _ in members])
    dev_job = np.repeat(np.arange(J), n_dev).astype(np.int32)
    strag = np.concatenate(strag_list).astype(np.float32)
    t_end = (np.arange(S_max) + 1.0) * interval

    ratio = (mxu / step).astype(np.float32)
    sig = (jit / n_eff).astype(np.float32)[dev_job]

    # per-window sub-sample base grids for evented jobs, (n_sub, J_e, S)
    n_sub = 1
    ev_rows = np.empty(0, np.int32)
    ev_job_of_row = np.empty(0, np.int32)
    ev_base = np.empty((1, 0, S_max), np.float32)
    if has_ev.any():
        ev_jobs = np.flatnonzero(has_ev)
        n_sub = int(min(params.n_sub_max, n_eff[ev_jobs].max()))
        offs = (np.arange(n_sub) / n_sub) * avg_w
        ts = (t_end[:, None] - avg_w) + offs[None, :]   # (S_max, n_sub)
        bases = []
        for j in ev_jobs:
            slow, scale = event_factors(members[j][1].events, ts)
            bases.append(((mxu[j] * scale)
                          / (step[j] * slow)).astype(np.float32).T)
        ev_base = np.stack(bases, axis=1)               # (n_sub, J_e, S)
        ev_rows = np.flatnonzero(has_ev[dev_job]).astype(np.int32)
        job_to_e = np.cumsum(has_ev) - 1
        ev_job_of_row = job_to_e[dev_job[ev_rows]].astype(np.int32)

    base_end = np.broadcast_to(ratio[:, None], (J, S_max)).copy()
    for j in np.flatnonzero(has_ev):
        slow_e, scale_e = event_factors(members[j][1].events, t_end - 1e-6)
        base_end[j] = ((mxu[j] * scale_e) / (step[j] * slow_e)) \
            .astype(np.float32)

    a, sd = cm.ou_step_constants(interval)
    consts = (a, sd, cm.chip.f_max_mhz * cm.f_min_frac,
              float(cm.chip.f_max_mhz), cm.throttle_frac)
    k_jit, k_clk = (jax.random.PRNGKey(int(rng.integers(0, 2 ** 31)))
                    for _ in range(2))
    tpa, clock = _group_device_sim(
        jnp.asarray(ratio), jnp.asarray(strag), jnp.asarray(dev_job),
        jnp.asarray(sig), jnp.asarray(ev_base),
        jnp.asarray(ev_rows), jnp.asarray(ev_job_of_row),
        jnp.asarray(strag[ev_rows]), jnp.asarray(base_end),
        k_jit, k_clk, S=S_max, n_sub=n_sub, consts=consts, mesh=mesh)

    row0 = 0
    for (i, _, _), nd, Sj in zip(members, n_dev, S):
        t, c = tpa[row0:row0 + nd, :Sj], clock[row0:row0 + nd, :Sj]
        if materialize:
            t, c = np.asarray(t), np.asarray(c)
        out[i] = DeviceGrid(interval, t, c)
        row0 += nd


def simulate_jobs_jax(slots: Sequence[JobSlot], *, seed: int = 0,
                      params: Optional[EngineParams] = None,
                      mesh="auto", materialize: bool = False
                      ) -> list[DeviceGrid]:
    """jax twin of `simulate_jobs_fused`; one DeviceGrid per slot.

    mesh: "auto" shards grid rows over every visible accelerator (no-op
    on one device); pass a 1-D `jax.sharding.Mesh` with a "devices"
    axis, or None to disable.  materialize=False (default) leaves the
    grids as device arrays so `StreamingRollup.add_grid` can reduce
    them on-device; True copies back to NumPy.
    """
    params = params or EngineParams()
    rng = np.random.default_rng(seed)
    if isinstance(mesh, str):
        if mesh != "auto":
            raise ValueError(f"unknown mesh spec {mesh!r} "
                             "(expected 'auto', a Mesh, or None)")
        mesh = default_mesh()
    out: list = [None] * len(slots)
    for members in group_slots(slots).values():
        _simulate_group_jax(members, out, rng, params, mesh, materialize)
    return out
