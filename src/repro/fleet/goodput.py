"""Fleet-wide goodput rollup (paper §II: the efficiency-review vantage).

Aggregates chip-hour-weighted OFU across all jobs, reports coverage (the
80%-of-GPU-hours-invisible problem app-level MFU has, vs OFU's 100%), and
ranks the largest recoverable-waste pools.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class FleetRollup:
    chip_hours: float
    weighted_ofu: float
    app_mfu_coverage: float       # fraction of chip-hours with app MFU
    ofu_coverage: float           # always 1.0 — the paper's point
    waste_ranking: list           # [(job_id, wasted_chip_hours), ...]

    def summary(self) -> str:
        top = ", ".join(f"{j}:{w:.0f}ch" for j, w in self.waste_ranking[:3])
        return (f"fleet chip_hours={self.chip_hours:.0f} "
                f"ofu={self.weighted_ofu * 100:.1f}% "
                f"app_mfu_coverage={self.app_mfu_coverage * 100:.0f}% "
                f"ofu_coverage=100% top_waste=[{top}]")


def rollup(jobs, *, healthy_ofu: float = 0.40,
           has_app_mfu=lambda j: j.spec.flops_variant != "none") -> FleetRollup:
    """jobs: iterable of JobTelemetry."""
    chip_hours = 0.0
    ofu_weighted = 0.0
    covered = 0.0
    waste = []
    for j in jobs:
        ch = j.spec.chips * j.spec.duration_s / 3600.0
        chip_hours += ch
        ofu = j.ofu
        ofu_weighted += ofu * ch
        if has_app_mfu(j):
            covered += ch
        waste.append((j.spec.job_id, max(0.0, healthy_ofu - ofu)
                      / healthy_ofu * ch))
    waste.sort(key=lambda t: -t[1])
    return FleetRollup(
        chip_hours=chip_hours,
        weighted_ofu=ofu_weighted / max(chip_hours, 1e-9),
        app_mfu_coverage=covered / max(chip_hours, 1e-9),
        ofu_coverage=1.0,
        waste_ranking=waste,
    )
