"""Fleet-wide goodput rollup (paper §II: the efficiency-review vantage).

Aggregates chip-hour-weighted OFU across all jobs, reports coverage (the
80%-of-GPU-hours-invisible problem app-level MFU has, vs OFU's 100%), and
ranks the largest recoverable-waste pools.

Two input domains, one report shape:

  * `rollup(jobs)` — batch, over simulated/observed `JobTelemetry`;
    weights are true chip-hours.
  * `from_rollup(roll)` — streaming, over a `StreamingRollup` (plain,
    windowed, or tree-reduced from many hosts); weights are the rollup's
    chip-weighted sample mass.  Because the underlying histograms merge
    associatively, this view is MERGE-CONSISTENT: goodput over a
    tree-reduced fleet equals goodput over single-process ingest
    (property-tested in tests/test_goodput.py).

`scan_goodput` is the third detector the scorecard scores: a fleet-level
OFU-drop scan (Google's ML Productivity Goodput decomposition collapses
to "chip-hours not converted to useful flops" here), reusing the
regression change detector over the fleet-wide bucket series.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.fleet.regression import detect_regressions


@dataclass
class FleetRollup:
    chip_hours: float
    weighted_ofu: float
    app_mfu_coverage: float       # fraction of chip-hours with app MFU
    ofu_coverage: float           # always 1.0 — the paper's point
    waste_ranking: list           # [(job_id, wasted_chip_hours), ...]

    def summary(self) -> str:
        top = ", ".join(f"{j}:{w:.0f}ch" for j, w in self.waste_ranking[:3])
        return (f"fleet chip_hours={self.chip_hours:.0f} "
                f"ofu={self.weighted_ofu * 100:.1f}% "
                f"app_mfu_coverage={self.app_mfu_coverage * 100:.0f}% "
                f"ofu_coverage=100% top_waste=[{top}]")


def rollup(jobs, *, healthy_ofu: float = 0.40,
           has_app_mfu=lambda j: j.spec.flops_variant != "none") -> FleetRollup:
    """jobs: iterable of JobTelemetry."""
    chip_hours = 0.0
    ofu_weighted = 0.0
    covered = 0.0
    waste = []
    for j in jobs:
        ch = j.spec.chips * j.spec.duration_s / 3600.0
        chip_hours += ch
        ofu = j.ofu
        ofu_weighted += ofu * ch
        if has_app_mfu(j):
            covered += ch
        waste.append((j.spec.job_id, max(0.0, healthy_ofu - ofu)
                      / healthy_ofu * ch))
    waste.sort(key=lambda t: -t[1])
    return FleetRollup(
        chip_hours=chip_hours,
        weighted_ofu=ofu_weighted / max(chip_hours, 1e-9),
        app_mfu_coverage=covered / max(chip_hours, 1e-9),
        ofu_coverage=1.0,
        waste_ranking=waste,
    )


def from_rollup(roll, *, healthy_ofu: float = 0.40) -> FleetRollup:
    """The same goodput report off a `StreamingRollup`/`WindowedRollup`.

    Weights are the rollup's chip-weighted sample mass (all-time totals
    for windowed rollups, so eviction never shrinks a job's footprint);
    app-MFU coverage comes from the metadata registered at ingest.
    Jobs whose scope holds no samples yet contribute nothing — an empty
    or all-idle rollup reports weighted_ofu 0.0 with zero weight rather
    than NaN.
    """
    if not np.isfinite(healthy_ofu) or healthy_ofu <= 0:
        raise ValueError(f"healthy_ofu={healthy_ofu} must be a positive "
                         "finite number")
    windowed = getattr(roll, "retain", None) is not None
    total_w = covered_w = ofu_w = 0.0
    waste = []
    for jid in sorted(roll.jobs):
        if windowed:
            at = roll.job_alltime(jid, qs=())
            w, mean = float(at["weight"]), float(at["mean"])
        else:
            s = roll.job_stats(jid, qs=())
            w = float(np.nansum(s.weight))
            mean = float(np.nansum(s.mean * s.weight) / w) if w > 0 \
                else float("nan")
        if w <= 0 or not np.isfinite(mean):
            continue
        total_w += w
        ofu_w += mean * w
        if roll.job_meta(jid) is not None:
            covered_w += w
        waste.append((jid, max(0.0, healthy_ofu - mean) / healthy_ofu * w))
    waste.sort(key=lambda t: -t[1])
    return FleetRollup(
        chip_hours=total_w,
        weighted_ofu=ofu_w / total_w if total_w > 0 else 0.0,
        app_mfu_coverage=covered_w / total_w if total_w > 0 else 0.0,
        ofu_coverage=1.0,
        waste_ranking=waste,
    )


#: package-level alias (`repro.fleet.goodput_from_rollup`) — "from_rollup"
#: alone is too generic a name to hoist out of this module
goodput_from_rollup = from_rollup


# ---------------------------------------------------------------------------
# Goodput drop detection (the scorecard's third detector)
# ---------------------------------------------------------------------------
@dataclass
class GoodputEvent:
    """A sustained fleet-wide OFU drop: chip-hours burning without the
    matrix pipes converting them — the goodput decomposition's 'lost
    productivity' term surfacing in counters."""

    start_idx: int
    end_idx: int | None             # None = ongoing
    drop_frac: float                # 1 - low/ref (fraction of OFU lost)
    ref_ofu: float
    low_ofu: float


def scan_goodput(roll, *, drop_threshold: float = 0.25, window: int = 4,
                 min_duration: int = 2) -> list[GoodputEvent]:
    """Scan the FLEET-wide bucket series for sustained OFU drops.

    A drop of more than `drop_threshold` (fractional) versus the trailing
    healthy fleet level, sustained `min_duration` buckets, is an event.
    Runs the shared `detect_regressions` change detector under the hood
    (a relative drop of d is a regression factor of 1/(1-d)), so the
    goodput detector inherits its drift tracking and recovery semantics.
    Indices are rollup-relative; add `roll.bucket0` for absolute buckets.
    """
    if not 0.0 < drop_threshold < 1.0:
        raise ValueError(f"drop_threshold={drop_threshold} must be in "
                         "(0, 1)")
    series = roll.fleet_ofu()
    if not len(series) or not np.isfinite(series).any():
        return []
    regs = detect_regressions(series, window=window,
                              factor_threshold=1.0 / (1.0 - drop_threshold),
                              min_duration=min_duration)
    return [GoodputEvent(r.start_idx, r.end_idx,
                         drop_frac=1.0 - r.low_ofu / max(r.ref_ofu, 1e-9),
                         ref_ofu=r.ref_ofu, low_ofu=r.low_ofu)
            for r in regs]
