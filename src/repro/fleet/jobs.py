"""Fleet job model: ties a (config, shape) workload to telemetry + app MFU.

A `JobSpec` describes one production job the way the fleet sees it: chips,
architecture, which FLOPs counter its framework uses (including the buggy
variants of paper §V-C), precision mix, and its *true* efficiency (duty
cycle) — which the fleet does NOT observe directly.  `simulate_job` produces
what the fleet DOES observe: hardware-counter scrapes per device, and the
application-reported MFU computed from the (possibly wrong) FLOPs counter.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import cached_property
from typing import Optional, Sequence

import numpy as np

from repro.configs.base import SHAPES, ShapeSpec, get_config
from repro.core.ofu import effective_peak, ofu_mean
from repro.core.peaks import DEFAULT_CHIP, ChipSpec
from repro.core.tile_quant import pick_policy, profiled_flops, theoretical_flops
from repro.flops.accounting import step_flops
from repro.telemetry.counters import (Event, SimulatedDeviceBackend,
                                      StepProfile, check_scrape_interval)
from repro.telemetry.scrape import DeviceGrid, ScrapeSeries, scrape


@dataclass
class JobSpec:
    job_id: str
    arch: str
    shape: str = "train_4k"
    chips: int = 256
    user: str = "researcher"
    flops_variant: str = "exact"     # exact | naive_moe | naive_hybrid | ...
    precisions: dict = field(default_factory=lambda: {"bf16": 1.0})
    true_duty: float = 0.35          # ground-truth MXU duty cycle
    duration_s: float = 600.0
    scrape_interval_s: float = 30.0
    events: Sequence[Event] = ()
    straggler_sigma: float = 0.0     # per-device step-time spread
    #: post-hoc counter perturbations (`fleet.engine.CounterFault`) —
    #: the scenario library's ground-truth injection point.  Unlike
    #: `events`, faults never reach the generative model: they apply to
    #: the finished grid via `apply_faults`, identically on every engine.
    faults: Sequence = ()
    seed: int = 0
    chip: ChipSpec = DEFAULT_CHIP
    # remat=True is the §VI-C world-model case (hardware executes 4F while
    # the app counter bills 3F); the default fleet job runs without it.
    remat: bool = False


@dataclass
class JobTelemetry:
    spec: JobSpec
    grid: DeviceGrid                   # sampled devices' aligned counters
    app_mfu: float                     # what the framework reports (Eq. 10)
    app_mfu_exact: float               # with a correct FLOPs counter
    step_time_s: float
    executed_tflops_per_step: float

    @cached_property
    def device_series(self) -> list:
        """Per sampled device: ScrapeSeries (materialized lazily from the
        grid — fleet sweeps that stay on the batched path never pay for
        per-device objects)."""
        return self.grid.to_series_list()

    @property
    def ofu(self) -> float:
        """Job-level OFU per Eq. 11 (mean over devices × samples)."""
        return ofu_mean(self.grid.tpa, self.grid.clock_mhz, self.spec.chip)


def _tile_quant_factor(cfg, chip: ChipSpec) -> float:
    """Mean executed/theoretical FLOPs ratio for the job's dominant GEMMs."""
    d = cfg.d_model
    shapes = [(4096, d, d), (4096, cfg.d_ff or d, d)]
    f = [profiled_flops(m, n, k, pick_policy(m, n, k))
         / theoretical_flops(m, n, k) for m, n, k in shapes]
    return float(np.mean(f))


#: (workload fields) -> (StepProfile, app_mfu, app_mfu_exact).  The
#: derivation is deterministic, and a 600-job fleet sweep reuses a few
#: dozen distinct workloads — memoizing keeps profile math off the
#: fused path's critical path.
_PROFILE_CACHE: dict = {}
_CACHE_CAP = 65536


def _cache_put(cache: dict, key, val):
    """Insert with FIFO eviction — long-lived collector processes must
    not grow memoization state without bound."""
    if len(cache) >= _CACHE_CAP:
        cache.pop(next(iter(cache)))
    cache[key] = val
    return val


def build_profile(spec: JobSpec) -> tuple[StepProfile, float, float]:
    """Derive the per-device step profile + app-reported MFUs for a job.

    Memoized on the spec's workload fields (arch/shape/chips/FLOPs
    variant/precisions/duty/chip); each call returns a FRESH StepProfile
    so callers may tweak theirs without poisoning the cache.
    """
    chip = spec.chip
    key = (spec.arch, spec.shape, spec.chips, spec.flops_variant,
           spec.remat, spec.true_duty,
           # every ChipSpec field the profile math reads — name alone
           # would alias customized chips onto the stock entry
           chip.name, chip.num_mxu, chip.mxu_rows, chip.mxu_cols,
           chip.flops_per_macc, chip.f_max_mhz,
           tuple(sorted(chip.precision_mult.items())),
           tuple(sorted(spec.precisions.items())))
    hit = _PROFILE_CACHE.get(key)
    if hit is None:
        hit = _cache_put(_PROFILE_CACHE, key, _build_profile_uncached(spec))
    prof, app, app_exact = hit
    return (StepProfile(prof.mxu_time_s, prof.step_time_s,
                        dict(prof.flops_by_precision), prof.jitter),
            app, app_exact)


def _build_profile_uncached(spec: JobSpec) -> tuple[StepProfile, float, float]:
    cfg = get_config(spec.arch)
    shape = SHAPES[spec.shape]
    chip = spec.chip

    exact = step_flops(cfg, shape, variant="exact", executed=False,
                       remat=spec.remat)
    executed = step_flops(cfg, shape, variant="exact", executed=True,
                          remat=spec.remat)
    reported = step_flops(cfg, shape, variant=spec.flops_variant,
                          executed=False, remat=spec.remat)

    tq = _tile_quant_factor(cfg, chip)
    executed_mxu = executed.total_mxu * tq

    peak_eff = effective_peak(spec.precisions, chip)      # TFLOP/s per chip
    fleet_peak = peak_eff * 1e12 * spec.chips
    mxu_time = executed_mxu / fleet_peak                  # at full clock
    step_time = mxu_time / max(spec.true_duty, 1e-3)

    # App MFU (Eq. 10): reported FLOPs / (step_time × chips × peak).
    # NOTE the counter convention: app counters bill 3F (no remat term) —
    # exactly the §VI-C miscount when remat is on, unless the variant fixes it.
    app = reported.total_mxu / (step_time * fleet_peak)
    app_exact = exact.total_mxu / (step_time * fleet_peak)
    prof = StepProfile(mxu_time_s=mxu_time, step_time_s=step_time,
                       flops_by_precision={
                           p: executed_mxu * f
                           for p, f in spec.precisions.items()})
    return prof, float(app), float(app_exact)


#: (seed, straggler_sigma, n_dev) -> (stragglers, seed vector): the draws
#: are a pure function of the spec, so repeated sweeps over the same specs
#: skip thousands of Generator constructions.
_DRAW_CACHE: dict = {}


def _job_draws(seed: int, sigma: float, n_dev: int):
    key = (seed, sigma, n_dev)
    hit = _DRAW_CACHE.get(key)
    if hit is None:
        rng = np.random.default_rng(seed)
        stragglers = np.exp(rng.standard_normal(n_dev) * sigma)
        # seeds[0] feeds the batched engines; seeds[1 + d] device d's
        # scalar backend
        seeds = rng.integers(0, 2 ** 31, size=n_dev + 1)
        hit = _cache_put(_DRAW_CACHE, key, (stragglers, seeds))
    return hit


def _prep_job(spec: JobSpec, max_devices: int):
    """Per-spec setup shared by every engine: §IV-C check, profile math,
    and the job's straggler/seed draws (same RNG stream on every path)."""
    # same §IV-C policy scrape() enforces on the scalar path — all
    # engines must reject average-of-averages configs identically
    check_scrape_interval(spec.scrape_interval_s)
    prof, app, app_exact = build_profile(spec)
    n_dev = min(spec.chips, max_devices)
    stragglers, seeds = _job_draws(spec.seed, spec.straggler_sigma, n_dev)
    return prof, app, app_exact, stragglers, seeds


def _telemetry(spec: JobSpec, prof: StepProfile, app: float,
               app_exact: float, grid: DeviceGrid) -> JobTelemetry:
    if spec.faults:
        # post-hoc by design: every engine produces the same unperturbed
        # grid (up to its usual equivalence), so the injected fault is
        # EXACTLY the declared perturbation on all of them
        from repro.fleet.engine import apply_faults
        grid = apply_faults(grid, spec.faults)
    executed_tflops = sum(prof.flops_by_precision.values()) / 1e12
    return JobTelemetry(spec, grid, app, app_exact, prof.step_time_s,
                        executed_tflops)


def simulate_job(spec: JobSpec, max_devices: int = 4, *,
                 engine: str = "auto") -> JobTelemetry:
    """Simulate the job's observable counter streams.

    engine: 'vector' (default under 'auto') runs the whole device group as
    one batched pass through repro.fleet.engine; 'jax' the same pass on
    the jax backend (device arrays out — see repro.fleet.engine_jax);
    'scalar' keeps the per-device, per-poll reference backend
    (`SimulatedDeviceBackend`).  All draw from the same generative model;
    equivalence is covered by tests/test_fleet_engine.py and
    tests/test_engine_jax.py.
    """
    from repro.fleet.engine import JobSlot, simulate_devices

    prof, app, app_exact, stragglers, seeds = _prep_job(spec, max_devices)
    if engine in ("auto", "fused"):
        # a single job's fused grid degenerates to the per-job batched pass
        engine = "vector"
    if engine == "vector":
        grid = simulate_devices(
            prof, duration_s=spec.duration_s,
            interval_s=spec.scrape_interval_s, chip=spec.chip,
            events=spec.events, stragglers=stragglers,
            seed=int(seeds[0]))
    elif engine == "jax":
        from repro.fleet.engine_jax import simulate_jobs_jax
        grid = simulate_jobs_jax(
            [JobSlot(prof, spec.duration_s, spec.scrape_interval_s,
                     events=spec.events, stragglers=stragglers,
                     chip=spec.chip)], seed=int(seeds[0]))[0]
    elif engine == "scalar":
        series = []
        for d, straggle in enumerate(stragglers):
            be = SimulatedDeviceBackend(
                prof, chip=spec.chip, events=spec.events,
                straggler_factor=float(straggle),
                seed=int(seeds[1 + d]))
            series.append(scrape(be, spec.duration_s,
                                 spec.scrape_interval_s))
        grid = DeviceGrid.from_series(series)
    else:
        raise ValueError(f"unknown engine {engine!r} (expected 'auto', "
                         "'fused', 'jax', 'vector' or 'scalar')")
    return _telemetry(spec, prof, app, app_exact, grid)


def _simulate_fleet_fused(specs: Sequence[JobSpec], max_devices: int, *,
                          backend: str = "numpy") -> list[JobTelemetry]:
    from repro.fleet.engine import JobSlot, simulate_jobs_fused

    slots, meta, entropy = [], [], []
    for spec in specs:
        prof, app, app_exact, stragglers, seeds = _prep_job(spec, max_devices)
        slots.append(JobSlot(prof, spec.duration_s, spec.scrape_interval_s,
                             events=spec.events, stragglers=stragglers,
                             chip=spec.chip))
        meta.append((spec, prof, app, app_exact))
        entropy.append(int(seeds[0]))
    # one master seed for the fused grid's shared RNG streams, derived
    # deterministically from every job's own stream
    seed = int(np.random.default_rng(entropy or [0]).integers(0, 2 ** 31))
    if backend == "jax":
        from repro.fleet.engine_jax import simulate_jobs_jax
        grids = simulate_jobs_jax(slots, seed=seed)
    else:
        grids = simulate_jobs_fused(slots, seed=seed)
    return [_telemetry(spec, prof, app, app_exact, g)
            for (spec, prof, app, app_exact), g in zip(meta, grids)]


def simulate_fleet(specs: Sequence[JobSpec], *, max_devices: int = 4,
                   engine: str = "auto") -> list[JobTelemetry]:
    """Simulate a whole fleet of jobs.

    engine: 'fused' (default under 'auto') stacks EVERY job into padded
    (total_devices, S_max) multi-job grids — shared RNG streams, one duty
    evaluation and one batched OU pass per (interval, clock-model) group —
    so the §V-B/§VI scenarios (608-job correlation sweeps, 2.5× regression
    hunts) cost one grid pass instead of a Python loop of per-job passes.
    'jax' runs the same fused grids on the jax backend
    (repro.fleet.engine_jax: lax.scan OU, mesh-sharded rows, device-array
    grids that `StreamingRollup.add_grid` reduces on-accelerator).
    'vector' keeps the per-job batched pass, 'scalar' the per-device
    reference loop; all engines draw from the same generative model
    (equivalence: tests/test_fleet_engine.py, tests/test_engine_jax.py).

    Reproducibility semantics: the fused grid's jitter/clock noise comes
    from ONE stream seeded by the whole sweep, so a job's exact counter
    realization is deterministic given (specs, order) but not a pure
    function of its own JobSpec.seed.  To re-simulate one job of a sweep
    bit-for-bit on its own (e.g. to bisect a regression), use
    engine='vector', whose streams are per-job.
    """
    if engine == "auto":
        engine = "fused"
    if engine in ("fused", "jax"):
        return _simulate_fleet_fused(
            specs, max_devices,
            backend="jax" if engine == "jax" else "numpy")
    if engine not in ("vector", "scalar"):
        raise ValueError(f"unknown engine {engine!r} (expected 'auto', "
                         "'fused', 'jax', 'vector' or 'scalar')")
    return [simulate_job(s, max_devices=max_devices, engine=engine)
            for s in specs]
