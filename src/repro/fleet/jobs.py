"""Fleet job model: ties a (config, shape) workload to telemetry + app MFU.

A `JobSpec` describes one production job the way the fleet sees it: chips,
architecture, which FLOPs counter its framework uses (including the buggy
variants of paper §V-C), precision mix, and its *true* efficiency (duty
cycle) — which the fleet does NOT observe directly.  `simulate_job` produces
what the fleet DOES observe: hardware-counter scrapes per device, and the
application-reported MFU computed from the (possibly wrong) FLOPs counter.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np

from repro.configs.base import SHAPES, ShapeSpec, get_config
from repro.core.ofu import effective_peak, ofu_mean
from repro.core.peaks import DEFAULT_CHIP, ChipSpec
from repro.core.tile_quant import pick_policy, profiled_flops, theoretical_flops
from repro.flops.accounting import step_flops
from repro.telemetry.counters import Event, SimulatedDeviceBackend, StepProfile
from repro.telemetry.scrape import ScrapeSeries, scrape


@dataclass
class JobSpec:
    job_id: str
    arch: str
    shape: str = "train_4k"
    chips: int = 256
    user: str = "researcher"
    flops_variant: str = "exact"     # exact | naive_moe | naive_hybrid | ...
    precisions: dict = field(default_factory=lambda: {"bf16": 1.0})
    true_duty: float = 0.35          # ground-truth MXU duty cycle
    duration_s: float = 600.0
    scrape_interval_s: float = 30.0
    events: Sequence[Event] = ()
    straggler_sigma: float = 0.0     # per-device step-time spread
    seed: int = 0
    chip: ChipSpec = DEFAULT_CHIP
    # remat=True is the §VI-C world-model case (hardware executes 4F while
    # the app counter bills 3F); the default fleet job runs without it.
    remat: bool = False


@dataclass
class JobTelemetry:
    spec: JobSpec
    device_series: list                # per sampled device: ScrapeSeries
    app_mfu: float                     # what the framework reports (Eq. 10)
    app_mfu_exact: float               # with a correct FLOPs counter
    step_time_s: float
    executed_tflops_per_step: float

    @property
    def ofu(self) -> float:
        """Job-level OFU per Eq. 11 (mean over devices × samples)."""
        vals = [ofu_mean(s.tpa, s.clock_mhz, self.spec.chip)
                for s in self.device_series]
        return float(np.mean(vals))


def _tile_quant_factor(cfg, chip: ChipSpec) -> float:
    """Mean executed/theoretical FLOPs ratio for the job's dominant GEMMs."""
    d = cfg.d_model
    shapes = [(4096, d, d), (4096, cfg.d_ff or d, d)]
    f = [profiled_flops(m, n, k, pick_policy(m, n, k))
         / theoretical_flops(m, n, k) for m, n, k in shapes]
    return float(np.mean(f))


def build_profile(spec: JobSpec) -> tuple[StepProfile, float, float]:
    """Derive the per-device step profile + app-reported MFUs for a job."""
    cfg = get_config(spec.arch)
    shape = SHAPES[spec.shape]
    chip = spec.chip

    exact = step_flops(cfg, shape, variant="exact", executed=False,
                       remat=spec.remat)
    executed = step_flops(cfg, shape, variant="exact", executed=True,
                          remat=spec.remat)
    reported = step_flops(cfg, shape, variant=spec.flops_variant,
                          executed=False, remat=spec.remat)

    tq = _tile_quant_factor(cfg, chip)
    executed_mxu = executed.total_mxu * tq

    peak_eff = effective_peak(spec.precisions, chip)      # TFLOP/s per chip
    fleet_peak = peak_eff * 1e12 * spec.chips
    mxu_time = executed_mxu / fleet_peak                  # at full clock
    step_time = mxu_time / max(spec.true_duty, 1e-3)

    # App MFU (Eq. 10): reported FLOPs / (step_time × chips × peak).
    # NOTE the counter convention: app counters bill 3F (no remat term) —
    # exactly the §VI-C miscount when remat is on, unless the variant fixes it.
    app = reported.total_mxu / (step_time * fleet_peak)
    app_exact = exact.total_mxu / (step_time * fleet_peak)
    prof = StepProfile(mxu_time_s=mxu_time, step_time_s=step_time,
                       flops_by_precision={
                           p: executed_mxu * f
                           for p, f in spec.precisions.items()})
    return prof, float(app), float(app_exact)


def simulate_job(spec: JobSpec, max_devices: int = 4, *,
                 engine: str = "auto") -> JobTelemetry:
    """Simulate the job's observable counter streams.

    engine: 'vector' (default under 'auto') runs the whole device group as
    one batched pass through repro.fleet.engine; 'scalar' keeps the
    per-device, per-poll reference backend (`SimulatedDeviceBackend`).
    Both draw from the same generative model; equivalence is covered by
    tests/test_fleet_engine.py.
    """
    from repro.fleet.engine import simulate_devices
    from repro.telemetry.counters import MAX_HW_AVG_WINDOW_S

    if spec.scrape_interval_s > MAX_HW_AVG_WINDOW_S:
        # same §IV-C policy scrape() enforces on the scalar path — both
        # engines must reject average-of-averages configs identically
        raise ValueError(
            f"scrape interval {spec.scrape_interval_s}s exceeds the "
            f"{MAX_HW_AVG_WINDOW_S}s hardware averaging window "
            "(average-of-averages, paper §IV-C)")
    prof, app, app_exact = build_profile(spec)
    rng = np.random.default_rng(spec.seed)
    n_dev = min(spec.chips, max_devices)
    if engine == "auto":
        engine = "vector"
    if engine == "vector":
        stragglers = np.exp(rng.standard_normal(n_dev)
                            * spec.straggler_sigma)
        grid = simulate_devices(
            prof, duration_s=spec.duration_s,
            interval_s=spec.scrape_interval_s, chip=spec.chip,
            events=spec.events, stragglers=stragglers,
            seed=int(rng.integers(0, 2 ** 31)))
        series = grid.to_series_list()
    elif engine == "scalar":
        series = []
        for d in range(n_dev):
            straggle = float(np.exp(rng.standard_normal()
                                    * spec.straggler_sigma))
            be = SimulatedDeviceBackend(
                prof, chip=spec.chip, events=spec.events,
                straggler_factor=straggle,
                seed=int(rng.integers(0, 2 ** 31)))
            series.append(scrape(be, spec.duration_s,
                                 spec.scrape_interval_s))
    else:
        raise ValueError(f"unknown engine {engine!r} "
                         "(expected 'auto', 'vector' or 'scalar')")
    executed_tflops = sum(prof.flops_by_precision.values()) / 1e12
    return JobTelemetry(spec, series, app, app_exact, prof.step_time_s,
                        executed_tflops)


def simulate_fleet(specs: Sequence[JobSpec], *, max_devices: int = 4,
                   engine: str = "auto") -> list[JobTelemetry]:
    """Simulate a whole fleet of jobs (one batched engine pass per job).

    This is the §V-B/§VI entry point: thousands of devices × hours of
    scrapes complete in seconds on CPU, so the paper's fleet scenarios
    (608-job correlation, 2.5× regression hunts, mixed-precision tracking)
    run at full scale instead of on a sampled handful of devices.
    """
    return [simulate_job(s, max_devices=max_devices, engine=engine)
            for s in specs]
