"""Autonomous job recovery service (paper §VI-B: Mission Control analogue).

Consumes per-job OFU streams; on a sustained collapse below an absolute
floor or a relative regression, issues a recovery action.  The trainer
(repro.train.trainer) registers a callback so the action actually restarts
from the latest checkpoint — closing the loop the paper describes.

Two feeding modes:

  * `observe(job_id, ofu)` — raw per-scrape OFU samples; the service runs
    its own sustained-collapse policy (absolute floor, relative
    regression, cooldown).
  * `consume_alerts(alerts)` — downstream of a `fleet.collector.Collector`:
    the collector's deduper has already turned detector findings into
    per-episode alerts, so each REGRESSION alert maps to at most one
    recovery action (idempotent under re-feeding the collector's
    append-only alert log, e.g. once per poll round).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from repro.fleet.regression import detect_regressions


@dataclass
class RecoveryAction:
    job_id: str
    reason: str
    at_sample: int
    factor: float


@dataclass
class RecoveryService:
    """Policy: restart when OFU collapses by `factor_threshold` for
    `sustain_samples` consecutive scrapes, or drops below `abs_floor`."""

    factor_threshold: float = 2.0
    abs_floor: float = 0.02
    sustain_samples: int = 5
    cooldown_samples: int = 20
    on_recover: Optional[Callable[[RecoveryAction], None]] = None
    #: only restart on regressions at least this severe when consuming
    #: collector alerts (alerts carry the detector's factor)
    min_alert_factor: float = 2.0
    _history: dict = field(default_factory=dict)
    _last_action: dict = field(default_factory=dict)
    _seen_alerts: set = field(default_factory=set)
    actions: list = field(default_factory=list)

    def observe(self, job_id: str, ofu: float) -> Optional[RecoveryAction]:
        h = self._history.setdefault(job_id, [])
        h.append(float(ofu))
        i = len(h) - 1
        if i - self._last_action.get(job_id, -10 ** 9) < self.cooldown_samples:
            return None
        if len(h) < 2 * self.sustain_samples:
            return None
        recent = h[-self.sustain_samples:]
        action = None
        if all(v < self.abs_floor for v in recent):
            action = RecoveryAction(job_id, "ofu_below_floor", i,
                                    factor=float("inf"))
        else:
            regs = detect_regressions(
                np.array(h), factor_threshold=self.factor_threshold,
                min_duration=self.sustain_samples)
            if regs and regs[-1].end_idx is None:
                action = RecoveryAction(job_id, "sustained_regression", i,
                                        factor=regs[-1].factor)
        if action is not None:
            self._fire(action, job_id, i)
        return action

    def _fire(self, action: RecoveryAction, job_id: str, at: int) -> None:
        self._last_action[job_id] = at
        self.actions.append(action)
        if self.on_recover is not None:
            self.on_recover(action)

    def consume_alerts(self, alerts) -> list[RecoveryAction]:
        """Turn collector REGRESSION alert episodes into recovery actions.

        `alerts` is any iterable of `fleet.collector.Alert` (the
        collector's append-only `alerts` log, or one round's
        `RoundReport.alerts`).  Each episode fires AT MOST once — the
        call is idempotent under overlapping/refed logs — and only when
        the detected factor reaches `min_alert_factor` (an ongoing 1.6×
        wobble should page a human, not bounce the job).  Returns the
        actions fired by THIS call.
        """
        fired = []
        for a in alerts:
            if a.kind != "regression":
                continue
            key = (a.job_id, a.round_idx, a.t_s, a.message)
            if key in self._seen_alerts:
                continue
            self._seen_alerts.add(key)
            factor = float(a.factor)
            if not np.isfinite(factor) or factor < self.min_alert_factor:
                continue
            action = RecoveryAction(a.job_id, "collector_regression",
                                    at_sample=a.round_idx, factor=factor)
            self._fire(action, a.job_id, a.round_idx)
            fired.append(action)
        return fired


@dataclass
class StragglerMonitor:
    """Per-device duty-cycle spread -> straggler flags (fleet resilience).

    A device whose duty cycle sits `sigma_threshold` robust-σ below the job
    median is flagged — the restart/replace decision input at 1000+ nodes.
    """

    sigma_threshold: float = 4.0

    def flag(self, per_device_tpa: np.ndarray) -> list[int]:
        x = np.asarray(per_device_tpa, float)
        med = np.median(x)
        mad = np.median(np.abs(x - med)) + 1e-9
        z = (x - med) / (1.4826 * mad)
        return [int(i) for i in np.nonzero(z < -self.sigma_threshold)[0]]
