"""Autonomous job recovery service (paper §VI-B: Mission Control analogue).

Consumes per-job OFU streams; on a sustained collapse below an absolute
floor or a relative regression, issues a recovery action.  The trainer
(repro.train.trainer) registers a callback so the action actually restarts
from the latest checkpoint — closing the loop the paper describes.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from repro.fleet.regression import detect_regressions


@dataclass
class RecoveryAction:
    job_id: str
    reason: str
    at_sample: int
    factor: float


@dataclass
class RecoveryService:
    """Policy: restart when OFU collapses by `factor_threshold` for
    `sustain_samples` consecutive scrapes, or drops below `abs_floor`."""

    factor_threshold: float = 2.0
    abs_floor: float = 0.02
    sustain_samples: int = 5
    cooldown_samples: int = 20
    on_recover: Optional[Callable[[RecoveryAction], None]] = None
    _history: dict = field(default_factory=dict)
    _last_action: dict = field(default_factory=dict)
    actions: list = field(default_factory=list)

    def observe(self, job_id: str, ofu: float) -> Optional[RecoveryAction]:
        h = self._history.setdefault(job_id, [])
        h.append(float(ofu))
        i = len(h) - 1
        if i - self._last_action.get(job_id, -10 ** 9) < self.cooldown_samples:
            return None
        if len(h) < 2 * self.sustain_samples:
            return None
        recent = h[-self.sustain_samples:]
        action = None
        if all(v < self.abs_floor for v in recent):
            action = RecoveryAction(job_id, "ofu_below_floor", i,
                                    factor=float("inf"))
        else:
            regs = detect_regressions(
                np.array(h), factor_threshold=self.factor_threshold,
                min_duration=self.sustain_samples)
            if regs and regs[-1].end_idx is None:
                action = RecoveryAction(job_id, "sustained_regression", i,
                                        factor=regs[-1].factor)
        if action is not None:
            self._last_action[job_id] = i
            self.actions.append(action)
            if self.on_recover is not None:
                self.on_recover(action)
        return action


@dataclass
class StragglerMonitor:
    """Per-device duty-cycle spread -> straggler flags (fleet resilience).

    A device whose duty cycle sits `sigma_threshold` robust-σ below the job
    median is flagged — the restart/replace decision input at 1000+ nodes.
    """

    sigma_threshold: float = 4.0

    def flag(self, per_device_tpa: np.ndarray) -> list[int]:
        x = np.asarray(per_device_tpa, float)
        med = np.median(x)
        mad = np.median(np.abs(x - med)) + 1e-9
        z = (x - med) / (1.4826 * mad)
        return [int(i) for i in np.nonzero(z < -self.sigma_threshold)[0]]
