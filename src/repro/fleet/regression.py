"""OFU regression detection (paper §VI-A: the 2.5× Gloo-debug case).

A rolling-window change detector over a job's OFU time series: flags
sustained collapses (ratio of reference window to current window above a
threshold) and recoveries, and quantifies the regression factor.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np


@dataclass
class Regression:
    start_idx: int
    end_idx: Optional[int]          # None = ongoing
    factor: float                   # reference_ofu / regressed_ofu
    ref_ofu: float
    low_ofu: float


def detect_regressions(ofu: np.ndarray, *, window: int = 10,
                       factor_threshold: float = 1.5,
                       min_duration: int = 5) -> list[Regression]:
    """Scan an OFU series for sustained drops vs the trailing healthy mean."""
    ofu = np.asarray(ofu, float)
    out: list[Regression] = []
    ref = None
    in_reg = None
    lows: list[float] = []
    for i in range(len(ofu)):
        w = ofu[max(0, i - window):i + 1]
        cur = float(np.mean(w[-min(len(w), min_duration):]))
        if ref is None and i >= window:
            ref = float(np.mean(ofu[:window]))
        if ref is None:
            continue
        if in_reg is None:
            if cur < ref / factor_threshold:
                in_reg = i - min_duration + 1
                lows = [cur]
            else:
                ref = 0.9 * ref + 0.1 * cur  # track slow drift
        else:
            lows.append(cur)
            if cur > ref / factor_threshold:
                low = float(np.mean(lows[:-1])) if len(lows) > 1 else lows[0]
                out.append(Regression(in_reg, i, ref / max(low, 1e-9),
                                      ref, low))
                in_reg = None
    if in_reg is not None:
        low = float(np.mean(lows))
        out.append(Regression(in_reg, None, ref / max(low, 1e-9), ref, low))
    return out


def scan_rollup(roll, *, jobs=None, **detector_kw) -> dict[str, list[Regression]]:
    """Run the detector over every job series in a rollup (simulated,
    replayed, windowed, or tree-reduced from many hosts — the detector
    never knows).

    Returns {job_id: regressions} for jobs with at least one detection —
    the sweep a fleet dashboard performs after each reduction round.
    `jobs` restricts the sweep (a continuous collector scans only streams
    that are still live).  Detection indices are relative to the rollup's
    stored buckets; add `roll.bucket0` for absolute bucket indices when
    scanning a windowed rollup.
    """
    out = {}
    for jid in (roll.jobs if jobs is None else jobs):
        regs = detect_regressions(roll.job_ofu(jid), **detector_kw)
        if regs:
            out[jid] = regs
    return out
