"""Streaming OFU rollups: per-job / per-precision / fleet-wide percentiles
over time buckets (the paper's §II efficiency-review dashboards at §V-B
fleet scale).

State per (scope, time-bucket) is a fixed-size weighted histogram, so
memory is O(buckets × scopes), independent of device count or scrape rate
— a 5,888-GPU job streams through the same few kilobytes a 8-GPU job does.
Readouts go through `core.ofu.hist_percentile_grid`; per-job bucket means
feed the existing `regression.detect_regressions` detector unchanged, and
`to_job_points` bridges into `divergence.analyze`.

Rollups are distributed-ready monoid elements: per-bucket histograms and
weighted sums ADD, so `merge()` is associative and commutative by
construction, and `to_bytes()`/`from_bytes()` ship a host's rollup to a
reducer (`fleet.distributed.tree_reduce`) without moving raw scrapes.
"""
from __future__ import annotations

import io
import json
from dataclasses import dataclass, field

import numpy as np

from repro.core.ofu import hist_percentile_grid, ofu_series
from repro.core.peaks import DEFAULT_CHIP, ChipSpec

_FLEET = "__fleet__"


def precision_label(precisions: dict) -> str:
    """Canonical group label for a job's precision mix, e.g. 'bf16+fp8'."""
    return "+".join(sorted(p for p, f in precisions.items() if f > 0)) \
        or "unknown"


@dataclass
class BucketStats:
    """One scope's readout: aligned per-bucket arrays."""

    bucket_s: float
    mean: np.ndarray                     # NaN where a bucket saw no samples
    weight: np.ndarray
    percentiles: dict = field(default_factory=dict)   # q -> (B,) array

    @property
    def centers_s(self) -> np.ndarray:
        return (np.arange(len(self.mean)) + 0.5) * self.bucket_s


class StreamingRollup:
    """Incremental fleet OFU aggregator over fixed time buckets.

    observe() takes raw aligned counter-derived OFU samples (any shape) and
    folds them into per-job, per-group (precision mix by default), and
    fleet-wide histograms; readouts are percentile/mean time series.
    """

    def __init__(self, bucket_s: float = 300.0, *, bins: int = 128,
                 lo: float = 0.0, hi: float = 1.1):
        self.bucket_s = float(bucket_s)
        self.bins = int(bins)
        self.edges = np.linspace(lo, hi, bins + 1)
        self._hists: dict = {}      # scope -> (B, bins) weights, grown lazily
        self._sums: dict = {}       # scope -> (B,) weighted value sums
        self._job_meta: dict = {}   # job_id -> dict (app_mfu, chips, ...)
        self.n_buckets = 0

    # -- ingest -------------------------------------------------------------
    def _scope_arrays(self, scope: str, b_needed: int):
        if b_needed > self.n_buckets:
            self.n_buckets = b_needed
        h = self._hists.get(scope)
        if h is None or h.shape[0] < self.n_buckets:
            nh = np.zeros((self.n_buckets, self.bins))
            ns = np.zeros(self.n_buckets)
            if h is not None:
                nh[:h.shape[0]] = h
                ns[:h.shape[0]] = self._sums[scope]
            self._hists[scope], self._sums[scope] = nh, ns
        return self._hists[scope], self._sums[scope]

    def observe(self, job_id: str, t_s: np.ndarray, ofu: np.ndarray, *,
                group: str = "unknown", weight: float = 1.0) -> None:
        """Fold OFU samples at times t_s into every scope this job hits."""
        t_s = np.asarray(t_s, float).ravel()
        v = np.asarray(ofu, float).ravel()
        # right-closed buckets: a scrape at t covers (t - interval, t], so a
        # boundary sample (t == k·bucket_s) belongs to bucket k-1, not k —
        # otherwise every run grows a spurious one-sample trailing bucket
        b = np.maximum(np.ceil(t_s / self.bucket_s).astype(int) - 1, 0)
        k = np.clip(np.digitize(v, self.edges) - 1, 0, self.bins - 1)
        b_needed = int(b.max()) + 1 if len(b) else 0
        for scope in (("job", job_id), ("group", group), ("group", _FLEET)):
            h, s = self._scope_arrays(scope, b_needed)
            np.add.at(h, (b, k), weight)
            np.add.at(s, b, v * weight)

    def add_job(self, tel, *, group: str | None = None) -> None:
        """Ingest a JobTelemetry: every sampled device's OFU series,
        chip-weighted so each job contributes its full fleet footprint.
        (A thin wrapper over the source-agnostic add_grid.)"""
        spec = tel.spec
        self.add_grid(spec.job_id, tel.grid, chip=spec.chip,
                      group=group or precision_label(spec.precisions),
                      chips=spec.chips, app_mfu=tel.app_mfu, arch=spec.arch,
                      flops_variant=spec.flops_variant)

    def add_grid(self, job_id: str, grid, *, chip: ChipSpec = DEFAULT_CHIP,
                 group: str = "unknown", chips: int | None = None,
                 app_mfu: float | None = None, arch: str = "unknown",
                 flops_variant: str = "exact") -> None:
        """Ingest a DeviceGrid from ANY TelemetrySource — the
        source-agnostic twin of add_job, used when counters come from a
        replayed trace or a live poller instead of a simulated JobSpec.

        chips: the job's true device count for chip-weighting (defaults to
        the grid's sampled device count); app_mfu (with arch /
        flops_variant) registers the metadata `to_job_points` needs for
        divergence triage.
        """
        chips = grid.n_devices if chips is None else chips
        if app_mfu is not None:
            self._job_meta[job_id] = {
                "chips": chips, "app_mfu": float(app_mfu), "arch": arch,
                "flops_variant": flops_variant}
        ofu = ofu_series(grid.tpa, grid.clock_mhz, chip)
        self.observe(job_id, np.broadcast_to(grid.times_s, ofu.shape), ofu,
                     group=group, weight=chips / max(grid.n_devices, 1))

    # -- distribution: merge + wire format ----------------------------------
    def merge(self, other: "StreamingRollup") -> "StreamingRollup":
        """Fold another rollup into this one (in place; returns self).

        Per-bucket histogram weights and value sums ADD, so merge is
        associative and commutative by construction — any reduction tree
        over per-host rollups yields the same fleet state as single-
        process ingestion.
        """
        if (self.bucket_s != other.bucket_s or self.bins != other.bins
                or not np.array_equal(self.edges, other.edges)):
            raise ValueError("cannot merge rollups with different "
                             "bucketing (bucket_s/bins/edges must match)")
        n = max(self.n_buckets, other.n_buckets)
        for scope, oh in other._hists.items():
            h, s = self._scope_arrays(scope, n)
            h[:oh.shape[0]] += oh
            s[:oh.shape[0]] += other._sums[scope]
        for jid, m in other._job_meta.items():
            self._job_meta.setdefault(jid, dict(m))
        return self

    def to_bytes(self) -> bytes:
        """Self-contained snapshot (compressed npz): what a host ships to
        the tree reducer instead of its raw scrapes."""
        meta = {"bucket_s": self.bucket_s, "bins": self.bins,
                "n_buckets": self.n_buckets,
                "scopes": [list(k) for k in self._hists],
                "job_meta": self._job_meta}
        arrays = {"edges": self.edges,
                  "meta": np.frombuffer(
                      json.dumps(meta, default=lambda o: o.item()).encode(),
                      dtype=np.uint8)}
        for idx, scope in enumerate(self._hists):
            arrays[f"h{idx}"] = self._hists[scope]
            arrays[f"s{idx}"] = self._sums[scope]
        buf = io.BytesIO()
        np.savez_compressed(buf, **arrays)
        return buf.getvalue()

    @classmethod
    def from_bytes(cls, blob: bytes) -> "StreamingRollup":
        with np.load(io.BytesIO(blob)) as z:
            meta = json.loads(bytes(z["meta"]).decode())
            edges = z["edges"]
            roll = cls(meta["bucket_s"], bins=meta["bins"],
                       lo=float(edges[0]), hi=float(edges[-1]))
            roll.edges = edges.copy()
            roll.n_buckets = int(meta["n_buckets"])
            for idx, key in enumerate(meta["scopes"]):
                scope = tuple(key)
                roll._hists[scope] = z[f"h{idx}"].copy()
                roll._sums[scope] = z[f"s{idx}"].copy()
            roll._job_meta = meta["job_meta"]
        return roll

    # -- readout ------------------------------------------------------------
    def _stats(self, scope, qs=(10, 50, 90)) -> BucketStats:
        h = self._hists.get(scope)
        if h is None:
            empty = np.empty(0)
            return BucketStats(self.bucket_s, empty, empty)
        if h.shape[0] < self.n_buckets:            # pad lazily-grown scopes
            h, s = self._scope_arrays(scope, self.n_buckets)
        else:
            s = self._sums[scope]
        w = h.sum(axis=1)
        with np.errstate(invalid="ignore", divide="ignore"):
            mean = np.where(w > 0, s / np.maximum(w, 1e-12), np.nan)
        # all buckets × all percentiles in one cumulative-sum readout
        grid = hist_percentile_grid(self.edges, h, tuple(qs))
        pct = {q: grid[k] for k, q in enumerate(qs)}
        return BucketStats(self.bucket_s, mean, w, pct)

    def job_stats(self, job_id: str, qs=(10, 50, 90)) -> BucketStats:
        return self._stats(("job", job_id), qs)

    def group_stats(self, group: str, qs=(10, 50, 90)) -> BucketStats:
        return self._stats(("group", group), qs)

    def fleet_stats(self, qs=(10, 50, 90)) -> BucketStats:
        return self._stats(("group", _FLEET), qs)

    @property
    def jobs(self) -> list:
        return [k[1] for k in self._hists if k[0] == "job"]

    @property
    def groups(self) -> list:
        return [k[1] for k in self._hists
                if k[0] == "group" and k[1] != _FLEET]

    def job_ofu(self, job_id: str, *, fill: bool = True) -> np.ndarray:
        """Per-bucket mean OFU series — detector-ready input for
        `regression.detect_regressions`.  fill=True forward-fills empty
        buckets so the detector never sees NaN gaps."""
        mean = self.job_stats(job_id, qs=()).mean.copy()
        if fill and len(mean):
            good = ~np.isnan(mean)
            if good.any():
                idx = np.maximum.accumulate(
                    np.where(good, np.arange(len(mean)), -1))
                first = int(np.argmax(good))
                idx[idx < 0] = first
                mean = mean[idx]
        return mean

    def to_job_points(self):
        """Bridge to `divergence.analyze`: one JobPoint per ingested job
        (requires app MFU captured via add_job)."""
        from repro.fleet.divergence import JobPoint
        out = []
        for jid in self.jobs:
            m = self._job_meta.get(jid)
            if m is None:
                continue
            s = self.job_stats(jid, qs=())
            ofu = float(np.nansum(s.mean * s.weight)
                        / max(np.nansum(s.weight), 1e-12))
            out.append(JobPoint(jid, m["arch"], m["chips"], m["app_mfu"],
                                ofu, m["flops_variant"]))
        return out

    def summary(self) -> str:
        f = self.fleet_stats()
        w = np.nansum(f.weight)
        mean = float(np.nansum(f.mean * f.weight) / max(w, 1e-12))
        last = f.percentiles.get(50, np.array([np.nan]))[-1] \
            if self.n_buckets else float("nan")
        return (f"fleet_rollup buckets={self.n_buckets} "
                f"jobs={len(self.jobs)} groups={len(self.groups)} "
                f"weighted_ofu={mean * 100:.1f}% "
                f"last_bucket_p50={last * 100:.1f}%")
