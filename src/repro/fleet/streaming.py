"""Streaming OFU rollups: per-job / per-precision / fleet-wide percentiles
over time buckets (the paper's §II efficiency-review dashboards at §V-B
fleet scale).

State per (scope, time-bucket) is a fixed-size weighted histogram, so
memory is O(buckets × scopes), independent of device count or scrape rate
— a 5,888-GPU job streams through the same few kilobytes a 8-GPU job does.
Readouts go through `core.ofu.hist_percentile_grid`; per-job bucket means
feed the existing `regression.detect_regressions` detector unchanged, and
`to_job_points` bridges into `divergence.analyze`.

Rollups are distributed-ready monoid elements: per-bucket histograms and
weighted sums ADD, so `merge()` is associative and commutative by
construction, and `to_bytes()`/`from_bytes()` ship a host's rollup to a
reducer (`fleet.distributed.tree_reduce`) without moving raw scrapes.
"""
from __future__ import annotations

import io
import json
from dataclasses import dataclass, field

import numpy as np

from repro.core.ofu import hist_percentile, hist_percentile_grid, ofu_series
from repro.core.peaks import DEFAULT_CHIP, ChipSpec
from repro.fleet import wire

_FLEET = "__fleet__"


def _is_device_array(x) -> bool:
    """True for jax device arrays (without importing jax up front) — the
    signal that `add_grid` should reduce on-device via the fused kernel."""
    mod = type(x).__module__
    return mod.startswith("jax") or mod.startswith("jaxlib")


def precision_label(precisions: dict) -> str:
    """Canonical group label for a job's precision mix, e.g. 'bf16+fp8'."""
    return "+".join(sorted(p for p, f in precisions.items() if f > 0)) \
        or "unknown"


def weighted_mean(stats: "BucketStats") -> float:
    """Weight-weighted mean OFU over a readout (0.0 when empty) — the one
    scalar a dashboard headline shows; shared by `summary()`,
    `to_job_points`, and the serving layer's goodput rollup."""
    w = float(np.nansum(stats.weight))
    return float(np.nansum(stats.mean * stats.weight) / max(w, 1e-12))


@dataclass
class BucketStats:
    """One scope's readout: aligned per-bucket arrays."""

    bucket_s: float
    mean: np.ndarray                     # NaN where a bucket saw no samples
    weight: np.ndarray
    percentiles: dict = field(default_factory=dict)   # q -> (B,) array
    #: absolute start of bucket 0 — nonzero for windowed rollups, whose
    #: retained rows begin at the retention horizon, not at t=0
    t0_s: float = 0.0

    @property
    def centers_s(self) -> np.ndarray:
        return self.t0_s + (np.arange(len(self.mean)) + 0.5) * self.bucket_s

    def payload(self) -> dict:
        """JSON-ready readout (arrays → lists, NaN → null): the wire shape
        the serving layer (`repro.serve`) returns for time-series queries."""
        return {"bucket_s": self.bucket_s, "t0_s": self.t0_s,
                "t_s": _json_list(self.centers_s),
                "mean": _json_list(self.mean),
                "weight": _json_list(self.weight),
                "percentiles": {f"{q:g}": _json_list(v)
                                for q, v in self.percentiles.items()}}


def _json_list(a) -> list:
    """Array → JSON-safe list (NaN/inf become null, not bare tokens)."""
    return [float(x) if np.isfinite(x) else None
            for x in np.asarray(a, float).ravel()]


def _ffill(mean: np.ndarray) -> np.ndarray:
    """Forward-fill NaN gaps (leading NaNs take the first real value) —
    the shared detector-input conditioning for per-bucket mean series."""
    if len(mean):
        good = ~np.isnan(mean)
        if good.any():
            idx = np.maximum.accumulate(
                np.where(good, np.arange(len(mean)), -1))
            first = int(np.argmax(good))
            idx[idx < 0] = first
            mean = mean[idx]
    return mean


class StreamingRollup:
    """Incremental fleet OFU aggregator over fixed time buckets.

    observe() takes raw aligned counter-derived OFU samples (any shape) and
    folds them into per-job, per-group (precision mix by default), and
    fleet-wide histograms; readouts are percentile/mean time series.
    """

    #: absolute index of the first stored bucket row; always 0 here — the
    #: windowed subclass advances it as old buckets are evicted
    bucket0 = 0

    def __init__(self, bucket_s: float = 300.0, *, bins: int = 128,
                 lo: float = 0.0, hi: float = 1.1):
        self.bucket_s = float(bucket_s)
        self.bins = int(bins)
        self.edges = np.linspace(lo, hi, bins + 1)
        self._hists: dict = {}      # scope -> (B, bins) weights, grown lazily
        self._sums: dict = {}       # scope -> (B,) weighted value sums
        self._job_meta: dict = {}   # job_id -> dict (app_mfu, chips, ...)
        self.n_buckets = 0
        #: monotone mutation counter: bumps once per ingest/merge, and
        #: `_touched[scope][row]` remembers the generation that last
        #: changed each bucket row — what `delta_bytes(since)` cuts on
        self.generation = 0
        self._touched: dict = {}    # scope -> (B,) int64 generation stamps

    def spawn_empty(self) -> "StreamingRollup":
        """A fresh rollup with this one's bucketing (reduction identity)."""
        return type(self)(self.bucket_s, bins=self.bins,
                          lo=float(self.edges[0]), hi=float(self.edges[-1]))

    # -- ingest -------------------------------------------------------------
    def _scope_arrays(self, scope: str, b_needed: int):
        if b_needed > self.n_buckets:
            self.n_buckets = b_needed
        h = self._hists.get(scope)
        if h is None or h.shape[0] < self.n_buckets:
            nh = np.zeros((self.n_buckets, self.bins))
            ns = np.zeros(self.n_buckets)
            nt = np.zeros(self.n_buckets, dtype=np.int64)
            if h is not None:
                nh[:h.shape[0]] = h
                ns[:h.shape[0]] = self._sums[scope]
                nt[:h.shape[0]] = self._touched[scope]
            self._hists[scope], self._sums[scope] = nh, ns
            self._touched[scope] = nt
        return self._hists[scope], self._sums[scope]

    def _bucketize(self, t_s, ofu):
        """(values, bucket indices, histogram bin indices) for raw samples.

        Right-closed buckets: a scrape at t covers (t - interval, t], so a
        boundary sample (t == k·bucket_s) belongs to bucket k-1, not k —
        otherwise every run grows a spurious one-sample trailing bucket.
        The ONE bucketing rule for plain and windowed rollups; it is what
        makes their retained-span readouts bucketwise identical.
        """
        t_s = np.asarray(t_s, float).ravel()
        v = np.asarray(ofu, float).ravel()
        b = np.maximum(np.ceil(t_s / self.bucket_s).astype(int) - 1, 0)
        k = np.clip(np.digitize(v, self.edges) - 1, 0, self.bins - 1)
        return v, b, k

    def observe(self, job_id: str, t_s: np.ndarray, ofu: np.ndarray, *,
                group: str = "unknown", weight: float = 1.0) -> None:
        """Fold OFU samples at times t_s into every scope this job hits."""
        v, b, k = self._bucketize(t_s, ofu)
        if not v.size:
            return
        self.generation += 1
        b_needed = int(b.max()) + 1
        for scope in (("job", job_id), ("group", group), ("group", _FLEET)):
            h, s = self._scope_arrays(scope, b_needed)
            np.add.at(h, (b, k), weight)
            np.add.at(s, b, v * weight)
            self._touched[scope][b] = self.generation

    def add_job(self, tel, *, group: str | None = None) -> np.ndarray:
        """Ingest a JobTelemetry: every sampled device's OFU series,
        chip-weighted so each job contributes its full fleet footprint.
        (A thin wrapper over the source-agnostic add_grid.)"""
        spec = tel.spec
        return self.add_grid(
            spec.job_id, tel.grid, chip=spec.chip,
            group=group or precision_label(spec.precisions),
            chips=spec.chips, app_mfu=tel.app_mfu, arch=spec.arch,
            flops_variant=spec.flops_variant)

    def add_grid(self, job_id: str, grid, *, chip: ChipSpec = DEFAULT_CHIP,
                 group: str = "unknown", chips: int | None = None,
                 app_mfu: float | None = None, arch: str = "unknown",
                 flops_variant: str = "exact") -> np.ndarray:
        """Ingest a DeviceGrid from ANY TelemetrySource — the
        source-agnostic twin of add_job, used when counters come from a
        replayed trace or a live poller instead of a simulated JobSpec.

        chips: the job's true device count for chip-weighting (defaults to
        the grid's sampled device count); app_mfu (with arch /
        flops_variant) registers the metadata `to_job_points` needs for
        divergence triage.  Returns the grid's OFU series so callers that
        need the raw samples (the collector's adaptive controller) don't
        recompute it.

        A grid holding jax device arrays (the `engine_jax` backend's
        output) is reduced ON-DEVICE: `repro.kernels.fleet_hist` fuses
        ofu_series + bucketize + bin-scatter, and only the few-KB
        (bucket, bin) histogram crosses to host.
        """
        chips = grid.n_devices if chips is None else chips
        if app_mfu is not None:
            self._job_meta[job_id] = {
                "chips": chips, "app_mfu": float(app_mfu), "arch": arch,
                "flops_variant": flops_variant}
        weight = chips / max(grid.n_devices, 1)
        if _is_device_array(grid.tpa):
            return self._ingest_device_grid(job_id, grid, chip, group,
                                            weight)
        ofu = ofu_series(grid.tpa, grid.clock_mhz, chip)
        self.observe(job_id, np.broadcast_to(grid.times_s, ofu.shape), ofu,
                     group=group, weight=weight)
        return ofu

    def _ingest_device_grid(self, job_id, grid, chip, group, weight):
        """jax-grid ingest: per-device OFU never reaches the host — the
        fused kernel reduces the grid to per-bucket histograms on the
        accelerator and the result folds through `observe_hist`.  Time
        bucketing follows `_bucketize`'s right-closed rule exactly (the
        column->bucket map is computed here with the same formula); bin
        edges are compared in f32, the telemetry dtype.  Returns the
        device OFU expression for callers that want raw samples.
        """
        from repro.kernels.fleet_hist import ofu_bucket_hist
        t_s = grid.times_s
        inv_fmax = 1.0 / chip.f_max_mhz
        if t_s.size == 0 or grid.n_devices == 0:
            return grid.tpa * grid.clock_mhz * inv_fmax
        b_abs = np.maximum(
            np.ceil(t_s / self.bucket_s).astype(int) - 1, 0)
        b0 = int(b_abs[0])
        hist, sums = ofu_bucket_hist(
            grid.tpa, grid.clock_mhz, inv_fmax=inv_fmax, edges=self.edges,
            col_bucket=b_abs - b0, n_buckets=int(b_abs[-1]) - b0 + 1)
        self.observe_hist(job_id, np.asarray(hist, float),
                          np.asarray(sums, float), b0=b0, group=group,
                          weight=weight)
        return grid.tpa * grid.clock_mhz * inv_fmax

    def observe_hist(self, job_id: str, hist: np.ndarray,
                     sums: np.ndarray, *, b0: int = 0,
                     group: str = "unknown", weight: float = 1.0) -> None:
        """Fold PRE-BINNED per-bucket histogram rows into every scope —
        the histogram-domain twin of observe(), fed by the device-side
        fused ingest.  hist: (B, bins) counts; sums: (B,) value sums;
        b0: the ABSOLUTE bucket index of row 0.  Rows must use this
        rollup's bin edges (hist widths add only in a shared basis).
        """
        hist = np.asarray(hist)
        if hist.shape[0] == 0:
            return
        if hist.shape[1] != self.bins:
            raise ValueError(f"histogram has {hist.shape[1]} bins, "
                             f"rollup has {self.bins}")
        self.generation += 1
        b_needed = b0 + hist.shape[0]
        for scope in (("job", job_id), ("group", group), ("group", _FLEET)):
            h, s = self._scope_arrays(scope, b_needed)
            h[b0:b_needed] += hist * weight
            s[b0:b_needed] += np.asarray(sums) * weight
            self._touched[scope][b0:b_needed] = self.generation

    # -- distribution: merge + wire format ----------------------------------
    def merge(self, other: "StreamingRollup") -> "StreamingRollup":
        """Fold another rollup into this one (in place; returns self).

        Per-bucket histogram weights and value sums ADD, so merge is
        associative and commutative by construction — any reduction tree
        over per-host rollups yields the same fleet state as single-
        process ingestion.
        """
        if (self.bucket_s != other.bucket_s or self.bins != other.bins
                or not np.array_equal(self.edges, other.edges)):
            raise ValueError("cannot merge rollups with different "
                             "bucketing (bucket_s/bins/edges must match)")
        if getattr(other, "retain", None) is not None:
            raise ValueError("cannot merge a WindowedRollup into a plain "
                             "StreamingRollup (retention/eviction state "
                             "would be lost); merge the other way around")
        self.generation += 1
        n = max(self.n_buckets, other.n_buckets)
        for scope, oh in other._hists.items():
            h, s = self._scope_arrays(scope, n)
            h[:oh.shape[0]] += oh
            s[:oh.shape[0]] += other._sums[scope]
            self._touched[scope][:oh.shape[0]] = self.generation
        for jid, m in other._job_meta.items():
            self._job_meta.setdefault(jid, dict(m))
        return self

    def merge_many(self, others) -> "StreamingRollup":
        """Fold MANY rollups in at once (in place; returns self) —
        equivalent to a pairwise `merge` fold, but per scope the aligned
        per-bucket arrays are stacked and reduced with one
        `np.add.reduce` instead of N separate adds, and every scope is
        grown to its final size exactly once instead of once per input.
        The k-way reduction step `tree_reduce` and the ingest aggregator
        stand on.

        Windowed rollups (self or any input) fall back to the pairwise
        loop — eviction alignment is inherently sequential.
        """
        others = [o for o in others if o is not None]
        if not others:
            return self
        if getattr(self, "retain", None) is not None or any(
                getattr(o, "retain", None) is not None for o in others):
            for o in others:
                self.merge(o)
            return self
        for o in others:
            if (self.bucket_s != o.bucket_s or self.bins != o.bins
                    or not np.array_equal(self.edges, o.edges)):
                raise ValueError("cannot merge rollups with different "
                                 "bucketing (bucket_s/bins/edges must "
                                 "match)")
        self.generation += 1
        n = max([self.n_buckets] + [o.n_buckets for o in others])
        # per scope: group inputs by row count so each group stacks into
        # one contiguous reduction; chunked to bound the stack's memory
        chunk = 512
        per_scope: dict = {}
        for o in others:
            for scope, oh in o._hists.items():
                per_scope.setdefault(scope, {}).setdefault(
                    oh.shape[0], []).append((oh, o._sums[scope]))
        for scope, by_rows in per_scope.items():
            h, s = self._scope_arrays(scope, n)
            for rows, parts in by_rows.items():
                if len(parts) == 1:
                    h[:rows] += parts[0][0]
                    s[:rows] += parts[0][1]
                else:
                    for i in range(0, len(parts), chunk):
                        blk = parts[i:i + chunk]
                        h[:rows] += np.add.reduce(
                            np.stack([p[0] for p in blk]))
                        s[:rows] += np.add.reduce(
                            np.stack([p[1] for p in blk]))
            self._touched[scope][:max(by_rows)] = self.generation
        for o in others:
            for jid, m in o._job_meta.items():
                self._job_meta.setdefault(jid, dict(m))
        return self

    def _snapshot_extra(self, meta: dict, arrays: dict) -> None:
        """Hook for subclasses to extend the wire format (no-op here)."""

    def to_bytes(self) -> bytes:
        """Self-contained snapshot (compressed npz): what a host ships to
        the tree reducer instead of its raw scrapes.  The format is
        self-describing — `from_bytes` restores a plain or windowed rollup
        according to what was serialized."""
        meta = {"bucket_s": self.bucket_s, "bins": self.bins,
                "n_buckets": self.n_buckets,
                "scopes": [list(k) for k in self._hists],
                "job_meta": self._job_meta}
        arrays = {"edges": self.edges}
        for idx, scope in enumerate(self._hists):
            arrays[f"h{idx}"] = self._hists[scope]
            arrays[f"s{idx}"] = self._sums[scope]
        self._snapshot_extra(meta, arrays)
        arrays["meta"] = np.frombuffer(
            json.dumps(meta, default=lambda o: o.item()).encode(),
            dtype=np.uint8)
        buf = io.BytesIO()
        np.savez_compressed(buf, **arrays)
        return buf.getvalue()

    # -- wire format v2: delta snapshots --------------------------------
    def to_bytes_v2(self) -> bytes:
        """Full snapshot on the zero-copy v2 wire (`fleet.wire`): raw
        little-endian header + contiguous columns, decoded by
        `np.frombuffer` views — no zip framing, no zlib.  `from_bytes`
        accepts it (dispatch on magic); npz `to_bytes` remains the
        self-describing compatibility format and the only one carrying
        windowed retention state."""
        return wire.encode(self, 0)

    def delta_bytes(self, since_generation: int = 0) -> bytes:
        """Ship only the bucket rows touched after `since_generation` —
        O(new buckets) per round instead of O(history).

        The blob carries `seq = self.generation`; rows hold the scope's
        full CUMULATIVE histogram for that bucket (replace semantics),
        so a receiver holding a mirror of the state at
        `since_generation` applies it idempotently: duplicates are
        detected by `seq`, retries need no dedup log.  `since=0` is a
        full snapshot."""
        return wire.encode(self, since_generation)

    def apply_delta(self, blob) -> bool:
        """Apply a v2 delta to this MIRROR of the sender's rollup.

        Returns True when applied, False for a duplicate (the blob's
        `seq` is not ahead of this mirror — at-least-once redelivery is
        a no-op).  Raises ValueError on a sequence GAP (`since` ahead of
        this mirror: a delta in between was lost; the sender must
        re-encode from this mirror's generation) or a bucketing
        mismatch."""
        return self.apply_snapshot(wire.decode(blob))

    def apply_snapshot(self, snap) -> bool:
        """`apply_delta` after decode — the aggregator's entry point
        (decode once outside the shard lock, apply under it)."""
        if getattr(self, "retain", None) is not None:
            raise ValueError("delta snapshots apply to plain "
                             "StreamingRollup mirrors; windowed state "
                             "travels via the npz format")
        if snap.seq <= self.generation:
            return False                       # duplicate delivery
        if snap.since > self.generation:
            raise ValueError(
                f"delta gap: blob covers generations ({snap.since}, "
                f"{snap.seq}] but this mirror is at {self.generation}; "
                f"re-encode with delta_bytes({self.generation})")
        if (self.bucket_s != snap.bucket_s or self.bins != snap.bins
                or not np.array_equal(self.edges, snap.edges)):
            raise ValueError("cannot apply a snapshot with different "
                             "bucketing (bucket_s/bins/edges must match)")
        if snap.n_buckets > self.n_buckets:
            self.n_buckets = snap.n_buckets
        for scope, idx, hist, sums in snap.scopes:
            h, s = self._scope_arrays(scope, snap.n_buckets)
            h[idx] = hist                     # REPLACE: rows carry the
            s[idx] = sums                     # sender's cumulative state
            self._touched[scope][idx] = snap.seq
        for jid, m in snap.job_meta.items():
            self._job_meta[jid] = dict(m)
        self.generation = snap.seq
        return True

    @classmethod
    def from_bytes(cls, blob: bytes) -> "StreamingRollup":
        """Restore a snapshot; dispatches on the leading magic (v2 raw
        vs npz zip) and on the serialized kind, so a reducer
        deserializes plain, windowed, and v2 snapshots through the one
        entry point `tree_reduce` uses."""
        if wire.is_v2(blob):
            return wire.restore(blob)
        with np.load(io.BytesIO(blob)) as z:
            meta = json.loads(bytes(z["meta"]).decode())
            edges = z["edges"]
            lo, hi = float(edges[0]), float(edges[-1])
            if meta.get("kind") == "windowed":
                roll: StreamingRollup = WindowedRollup(
                    meta["bucket_s"], retain=meta["retain"],
                    bins=meta["bins"], lo=lo, hi=hi)
                roll.bucket0 = int(meta["bucket0"])
                for idx, key in enumerate(meta["escopes"]):
                    scope = tuple(key)
                    roll._ev_hist[scope] = z[f"e{idx}"].copy()
                    roll._ev_sum[scope] = float(z["esums"][idx])
            else:
                roll = StreamingRollup(meta["bucket_s"], bins=meta["bins"],
                                       lo=lo, hi=hi)
            roll.edges = edges.copy()
            roll.n_buckets = int(meta["n_buckets"])
            # npz blobs predate generation stamps: every restored row
            # counts as touched at generation 1, so a later
            # delta_bytes(0) still ships the full restored state
            roll.generation = 1
            for idx, key in enumerate(meta["scopes"]):
                scope = tuple(key)
                roll._hists[scope] = z[f"h{idx}"].copy()
                roll._sums[scope] = z[f"s{idx}"].copy()
                roll._touched[scope] = np.ones(
                    roll._hists[scope].shape[0], dtype=np.int64)
            roll._job_meta = meta["job_meta"]
        return roll

    # -- readout ------------------------------------------------------------
    def _stats(self, scope, qs=(10, 50, 90)) -> BucketStats:
        t0 = self.bucket0 * self.bucket_s
        h = self._hists.get(scope)
        if h is None:
            empty = np.empty(0)
            return BucketStats(self.bucket_s, empty, empty, t0_s=t0)
        s = self._sums[scope]
        if h.shape[0] < self.n_buckets:            # pad lazily-grown scopes
            # ...LOCALLY: readouts run concurrently on published rollup
            # copies (one FleetStore snapshot, many HTTP reader threads),
            # so _stats must never resize/reassign the shared arrays —
            # a racing reader could see a torn _scope_arrays reassignment
            pad = self.n_buckets - h.shape[0]
            h = np.concatenate([h, np.zeros((pad, self.bins))])
            s = np.concatenate([s, np.zeros(pad)])
        w = h.sum(axis=1)
        with np.errstate(invalid="ignore", divide="ignore"):
            mean = np.where(w > 0, s / np.maximum(w, 1e-12), np.nan)
        # all buckets × all percentiles in one cumulative-sum readout
        grid = hist_percentile_grid(self.edges, h, tuple(qs))
        pct = {q: grid[k] for k, q in enumerate(qs)}
        return BucketStats(self.bucket_s, mean, w, pct, t0_s=t0)

    def job_stats(self, job_id: str, qs=(10, 50, 90)) -> BucketStats:
        return self._stats(("job", job_id), qs)

    def group_stats(self, group: str, qs=(10, 50, 90)) -> BucketStats:
        return self._stats(("group", group), qs)

    def fleet_stats(self, qs=(10, 50, 90)) -> BucketStats:
        return self._stats(("group", _FLEET), qs)

    @property
    def jobs(self) -> list:
        return [k[1] for k in self._hists if k[0] == "job"]

    @property
    def groups(self) -> list:
        return [k[1] for k in self._hists
                if k[0] == "group" and k[1] != _FLEET]

    def job_meta(self, job_id: str):
        """Copy of the metadata registered for a job at ingest (chips /
        app_mfu / arch / flops_variant), or None if the job never reported
        an app MFU — what the serving layer attaches to job queries."""
        m = self._job_meta.get(job_id)
        return dict(m) if m is not None else None

    def job_ofu(self, job_id: str, *, fill: bool = True) -> np.ndarray:
        """Per-bucket mean OFU series — detector-ready input for
        `regression.detect_regressions`.  fill=True forward-fills empty
        buckets so the detector never sees NaN gaps."""
        mean = self.job_stats(job_id, qs=()).mean.copy()
        return _ffill(mean) if fill else mean

    def fleet_ofu(self, *, fill: bool = True) -> np.ndarray:
        """Fleet-wide per-bucket mean OFU series (chip-weighted across
        every job), detector-ready like `job_ofu` — what the goodput
        drop detector (`fleet.goodput.scan_goodput`) consumes."""
        mean = self.fleet_stats(qs=()).mean.copy()
        return _ffill(mean) if fill else mean

    def to_job_points(self):
        """Bridge to `divergence.analyze`: one JobPoint per ingested job
        (requires app MFU captured via add_job)."""
        from repro.fleet.divergence import JobPoint
        out = []
        for jid in self.jobs:
            m = self._job_meta.get(jid)
            if m is None:
                continue
            ofu = weighted_mean(self.job_stats(jid, qs=()))
            out.append(JobPoint(jid, m["arch"], m["chips"], m["app_mfu"],
                                ofu, m["flops_variant"]))
        return out

    def summary(self) -> str:
        f = self.fleet_stats()
        mean = weighted_mean(f)
        last = f.percentiles.get(50, np.array([np.nan]))[-1] \
            if self.n_buckets else float("nan")
        return (f"fleet_rollup buckets={self.n_buckets} "
                f"jobs={len(self.jobs)} groups={len(self.groups)} "
                f"weighted_ofu={mean * 100:.1f}% "
                f"last_bucket_p50={last * 100:.1f}%")


class WindowedRollup(StreamingRollup):
    """Ring-buffer rollup: full per-bucket detail for the LAST `retain`
    buckets, plus all-time totals for everything already evicted.

    A long-lived collector cannot let per-bucket state grow with uptime;
    this bounds it.  Retained buckets carry the same histograms a plain
    `StreamingRollup` would, so detector readouts over the retained span
    (`job_ofu`, `*_stats`) are bucketwise IDENTICAL to a fresh rollup fed
    the same samples — eviction only ever removes buckets older than the
    horizon, folding their mass into per-scope all-time histograms
    (`job_alltime` / `fleet_alltime` keep lifetime mean/percentiles
    readable after the detail is gone).

    The windowed state stays a monoid: retained rows align by ABSOLUTE
    bucket index and add, eviction transfers are additive and depend only
    on the union's newest bucket, so `merge()` remains associative and
    commutative and `tree_reduce` works unchanged over windowed snapshots.
    The one order-dependent edge: a sample already older than the horizon
    AT INGEST TIME folds straight into the all-time totals (it has no row
    to land in).

    Readout indices are window-relative; `bucket0` is the absolute index
    of row 0 (and `BucketStats.t0_s`/`centers_s` report absolute time), so
    alert keys can be pinned to absolute buckets across evictions.
    """

    def __init__(self, bucket_s: float = 300.0, *, retain: int = 24,
                 bins: int = 128, lo: float = 0.0, hi: float = 1.1):
        if retain < 1:
            raise ValueError(f"retain={retain} must be >= 1 bucket")
        super().__init__(bucket_s, bins=bins, lo=lo, hi=hi)
        self.retain = int(retain)
        self.bucket0 = 0
        self._ev_hist: dict = {}    # scope -> (bins,) evicted histogram
        self._ev_sum: dict = {}     # scope -> evicted weighted value sum

    def spawn_empty(self) -> "WindowedRollup":
        return WindowedRollup(self.bucket_s, retain=self.retain,
                              bins=self.bins, lo=float(self.edges[0]),
                              hi=float(self.edges[-1]))

    @property
    def end_bucket(self) -> int:
        """Absolute index one past the newest stored bucket."""
        return self.bucket0 + self.n_buckets

    # -- eviction -----------------------------------------------------------
    def _ev_arrays(self, scope) -> np.ndarray:
        h = self._ev_hist.get(scope)
        if h is None:
            h = self._ev_hist[scope] = np.zeros(self.bins)
            self._ev_sum[scope] = 0.0
        return h

    def _evict(self, rows: int) -> None:
        """Fold the oldest `rows` window rows into the all-time totals."""
        for scope in list(self._hists):
            h, s = self._hists[scope], self._sums[scope]
            drop = min(rows, h.shape[0])
            if drop and h[:drop].any():
                self._ev_arrays(scope)
                self._ev_hist[scope] += h[:drop].sum(axis=0)
                self._ev_sum[scope] += float(s[:drop].sum())
            self._hists[scope] = h[drop:].copy()
            self._sums[scope] = s[drop:].copy()
            self._touched[scope] = self._touched[scope][drop:].copy()
        self.bucket0 += rows
        self.n_buckets = max(self.n_buckets - rows, 0)

    def _advance_to(self, end_abs: int) -> None:
        """Evict until the window can hold absolute bucket end_abs - 1."""
        over = end_abs - (self.bucket0 + self.retain)
        if over > 0:
            self._evict(over)

    # -- ingest ---------------------------------------------------------
    def observe(self, job_id: str, t_s: np.ndarray, ofu: np.ndarray, *,
                group: str = "unknown", weight: float = 1.0) -> None:
        v, b_abs, k = self._bucketize(t_s, ofu)
        if not v.size:
            return
        self.generation += 1
        self._advance_to(int(b_abs.max()) + 1)
        live = b_abs >= self.bucket0
        rel = b_abs[live] - self.bucket0
        b_needed = int(rel.max()) + 1 if rel.size else 0
        for scope in (("job", job_id), ("group", group), ("group", _FLEET)):
            h, s = self._scope_arrays(scope, b_needed)
            if rel.size:
                np.add.at(h, (rel, k[live]), weight)
                np.add.at(s, rel, v[live] * weight)
                self._touched[scope][rel] = self.generation
            if not live.all():       # already past the horizon at ingest
                self._ev_arrays(scope)
                np.add.at(self._ev_hist[scope], k[~live], weight)
                self._ev_sum[scope] += float(v[~live].sum() * weight)

    def observe_hist(self, job_id: str, hist: np.ndarray,
                     sums: np.ndarray, *, b0: int = 0,
                     group: str = "unknown", weight: float = 1.0) -> None:
        """Pre-binned ingest with the window semantics of observe():
        advance the horizon to cover the newest row, land live rows in
        the window, and fold rows already past the horizon straight into
        the all-time totals (same edge `observe` documents)."""
        hist = np.asarray(hist)
        B = hist.shape[0]
        if B == 0:
            return
        if hist.shape[1] != self.bins:
            raise ValueError(f"histogram has {hist.shape[1]} bins, "
                             f"rollup has {self.bins}")
        sums = np.asarray(sums)
        self.generation += 1
        self._advance_to(b0 + B)
        cut = min(max(self.bucket0 - b0, 0), B)     # rows past the horizon
        live = B - cut
        rel0 = b0 + cut - self.bucket0
        for scope in (("job", job_id), ("group", group), ("group", _FLEET)):
            if cut and hist[:cut].any():
                self._ev_arrays(scope)
                self._ev_hist[scope] += hist[:cut].sum(axis=0) * weight
                self._ev_sum[scope] += float(sums[:cut].sum()) * weight
            h, s = self._scope_arrays(scope, rel0 + live if live else 0)
            if live:
                h[rel0:rel0 + live] += hist[cut:] * weight
                s[rel0:rel0 + live] += sums[cut:] * weight
                self._touched[scope][rel0:rel0 + live] = self.generation

    # -- distribution ---------------------------------------------------
    def merge(self, other: StreamingRollup) -> "WindowedRollup":
        """Fold another rollup in, aligning by ABSOLUTE bucket index.

        `other` may be windowed (same retain) or plain (treated as a
        window starting at bucket 0).  Rows older than the merged window's
        horizon fold into the all-time totals — exactly what eviction
        would have done had the data been ingested here.
        """
        if (self.bucket_s != other.bucket_s or self.bins != other.bins
                or not np.array_equal(self.edges, other.edges)):
            raise ValueError("cannot merge rollups with different "
                             "bucketing (bucket_s/bins/edges must match)")
        o_retain = getattr(other, "retain", None)
        if o_retain is not None and o_retain != self.retain:
            raise ValueError(f"cannot merge windowed rollups with "
                             f"different retention ({self.retain} vs "
                             f"{o_retain} buckets)")
        ob0 = other.bucket0
        self.generation += 1
        self._advance_to(max(self.end_bucket, ob0 + other.n_buckets))
        for scope, oh in other._hists.items():
            osum = other._sums[scope]
            cut = min(max(self.bucket0 - ob0, 0), oh.shape[0])
            if cut and oh[:cut].any():
                self._ev_arrays(scope)
                self._ev_hist[scope] += oh[:cut].sum(axis=0)
                self._ev_sum[scope] += float(osum[:cut].sum())
            live = oh.shape[0] - cut
            rel0 = ob0 + cut - self.bucket0
            h, s = self._scope_arrays(scope, rel0 + live if live > 0 else 0)
            if live > 0:
                h[rel0:rel0 + live] += oh[cut:]
                s[rel0:rel0 + live] += osum[cut:]
                self._touched[scope][rel0:rel0 + live] = self.generation
        for scope, eh in getattr(other, "_ev_hist", {}).items():
            self._ev_arrays(scope)
            self._ev_hist[scope] += eh
            self._ev_sum[scope] += other._ev_sum[scope]
        for jid, m in other._job_meta.items():
            self._job_meta.setdefault(jid, dict(m))
        return self

    def _snapshot_extra(self, meta: dict, arrays: dict) -> None:
        meta["kind"] = "windowed"
        meta["retain"] = self.retain
        meta["bucket0"] = self.bucket0
        meta["escopes"] = [list(k) for k in self._ev_hist]
        for idx, scope in enumerate(self._ev_hist):
            arrays[f"e{idx}"] = self._ev_hist[scope]
        arrays["esums"] = np.array([self._ev_sum[k] for k in self._ev_hist])

    # -- all-time readout (evicted + retained) ----------------------------
    def _alltime(self, scope, qs=(10, 50, 90)) -> dict:
        hist = np.zeros(self.bins)
        total = 0.0
        h = self._hists.get(scope)
        if h is not None:
            hist += h.sum(axis=0)
            total += float(self._sums[scope].sum())
        eh = self._ev_hist.get(scope)
        if eh is not None:
            hist += eh
            total += self._ev_sum[scope]
        w = float(hist.sum())
        return {"mean": total / w if w > 0 else float("nan"),
                "weight": w,
                "percentiles": {q: hist_percentile(self.edges, hist, q)
                                for q in qs}}

    def job_alltime(self, job_id: str, qs=(10, 50, 90)) -> dict:
        """Lifetime mean/weight/percentiles for a job — survives eviction."""
        return self._alltime(("job", job_id), qs)

    def fleet_alltime(self, qs=(10, 50, 90)) -> dict:
        return self._alltime(("group", _FLEET), qs)

    def summary(self) -> str:
        at = self.fleet_alltime(qs=())
        return (super().summary()
                + f" window=[{self.bucket0},{self.end_bucket}) "
                  f"retain={self.retain} "
                  f"alltime_ofu={at['mean'] * 100:.1f}%")
