"""The paper's Table III / Fig. 5 / §V-C fleet as a reusable fixture.

608 production jobs at the paper's exact scale mix, with the two FLOPs
miscalculation populations baked in: every 288-GPU job runs the
DeepSeek-style MoE with the buggy `naive_moe` counter (§V-C case 1,
~3x inflation) and 17 of the 256-GPU jobs run the hybrid with
`naive_hybrid` (case 2, ~1.8x inflation) — 82 affected jobs total.

One fixture, three consumers, bucketwise-identical numbers:

  * `benchmarks/production_correlation.py` — the OFFLINE path: batch
    rollups via `offline_rollups` + `divergence.analyze` /
    `correlation.analyze_correlation`;
  * `tools/fleet_correlate.py --self-check` — the LIVE path: the same
    jobs replayed round-for-round through `Collector` streams
    (`to_streams`) into `FleetStore` + the HTTP query surface;
  * the scenario library's miscalculation scenario (a small slice).

Identity between the paths is by construction, not by tolerance hunting:
both ingest the same `DeviceGrid`s and the same reported-MFU sample
series through the same right-closed bucketing (`ROUND_S == BUCKET_S`,
so each collector poll lands exactly one bucket, in the same order the
batch path folds it).

The app's reported MFU is modelled per SAMPLE (one log line every
`INTERVAL_S`), not per job: noise in the application's timing path is
i.i.d. across step-time measurements plus a small per-job calibration
bias, so per-job bucket means tighten with averaging and the healthy
population separates cleanly from the ~2-3x miscalculated one.  The
per-sample sigma shrinks with scale like the paper's Table III absolute
errors (small jobs are the noisy ones).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.fleet.correlation import MfuRollup
from repro.fleet.jobs import JobSpec, JobTelemetry, simulate_fleet
from repro.fleet.streaming import StreamingRollup

#: Table III scale mix: (gpus, jobs) — 608 rows total
SCALE_MIX = [(8, 6), (16, 48), (64, 52), (128, 48), (256, 76), (288, 65),
             (512, 144), (736, 11), (768, 57), (1024, 49), (1536, 10),
             (2944, 33), (5888, 9)]

HEALTHY_ARCHS = ["qwen3-4b", "granite-3-2b", "llama3.2-3b", "mamba2-780m",
                 "phi-3-vision-4.2b", "deepseek-moe-16b"]

#: §V-C populations: every job at MOE_CHIPS is case 1; the first
#: HYBRID_BUGS jobs at HYBRID_CHIPS are case 2 (65 + 17 = 82 affected)
MOE_CHIPS = 288
HYBRID_CHIPS = 256
HYBRID_BUGS = 17

#: the paper's Fig. 5 exclusion threshold — at this rel-err the flagged
#: set is exactly the miscalculated population (verified by the bench
#: and the CLI self-check)
FLAG_REL_ERR = 0.45

#: replay geometry: ROUND_S == BUCKET_S means one collector poll fills
#: exactly one bucket, making the live path's per-bucket accumulation
#: order identical to batch ingestion
INTERVAL_S = 30.0
BUCKET_S = 300.0
ROUND_S = BUCKET_S
DURATION_S = 1200.0              # 4 buckets, 40 MFU samples per job

#: reported-MFU noise model: per-sample sigma at the smallest scales
#: (shrinks ~1/sqrt(chips/64)) plus a per-job calibration bias
MFU_SAMPLE_SIGMA = 0.12
MFU_JOB_SIGMA = 0.02


@dataclass(frozen=True)
class Table3Job:
    """One fixture job: its spec, simulated counters, and the reported
    MFU sample series its application would have logged."""

    spec: JobSpec
    telemetry: JobTelemetry
    mfu_t: np.ndarray            # sample times (s), one per log line
    mfu_v: np.ndarray            # reported MFU at each sample

    @property
    def job_id(self) -> str:
        return self.spec.job_id


def build_specs(seed: int = 0) -> list[JobSpec]:
    """The 608 JobSpecs (deterministic in `seed`)."""
    rng = np.random.default_rng(seed)
    specs = []
    hybrid_bugs = HYBRID_BUGS
    for chips, njobs in SCALE_MIX:
        for j in range(njobs):
            jid = f"{chips}g_{j}"
            duty = float(np.clip(rng.normal(0.28, 0.10), 0.08, 0.55))
            if chips == MOE_CHIPS:            # §V-C case 1
                arch, variant = "deepseek-v3-671b", "naive_moe"
                # the affected MoE jobs ran at low true efficiency; with
                # the ~3x counter inflation they REPORTED ~40% MFU
                duty = float(np.clip(rng.normal(0.13, 0.03), 0.06, 0.25))
            elif chips == HYBRID_CHIPS and hybrid_bugs > 0:   # case 2
                arch, variant = "zamba2-7b", "naive_hybrid"
                hybrid_bugs -= 1
            else:
                arch = HEALTHY_ARCHS[int(rng.integers(len(HEALTHY_ARCHS)))]
                variant = "exact"
            specs.append(JobSpec(jid, arch, chips=chips,
                                 flops_variant=variant, true_duty=duty,
                                 duration_s=DURATION_S,
                                 scrape_interval_s=INTERVAL_S,
                                 seed=int(rng.integers(2 ** 31))))
    return specs


def _mfu_samples(spec: JobSpec, app_mfu: float, seed: int,
                 idx: int) -> tuple[np.ndarray, np.ndarray]:
    """The job's reported-MFU log stream: one sample per scrape tick,
    per-sample timing noise (scale-dependent) on a per-job bias.  Drawn
    from a child stream keyed on (seed, idx) so the series is a pure
    function of the fixture seed, independent of the simulation engine."""
    rng = np.random.default_rng([seed, 7919, idx])
    t = np.arange(INTERVAL_S, spec.duration_s + 1e-9, INTERVAL_S)
    sigma = MFU_SAMPLE_SIGMA / np.sqrt(max(spec.chips / 64.0, 1.0))
    bias = 1.0 + MFU_JOB_SIGMA * float(rng.standard_normal())
    v = app_mfu * bias * (1.0 + sigma * rng.standard_normal(t.size))
    return t, np.maximum(v, 1e-3)


def build_jobs(seed: int = 0, *, engine: str = "auto") -> list[Table3Job]:
    """Simulate the whole fixture fleet (counters + MFU log streams)."""
    specs = build_specs(seed)
    tels = simulate_fleet(specs, max_devices=1, engine=engine)
    jobs = []
    for idx, (spec, tel) in enumerate(zip(specs, tels)):
        t, v = _mfu_samples(spec, tel.app_mfu, seed, idx)
        jobs.append(Table3Job(spec, tel, t, v))
    return jobs


def offline_rollups(jobs, *, bucket_s: float = BUCKET_S):
    """Batch-ingest the fixture: (StreamingRollup, MfuRollup) — the
    offline twin of replaying `to_streams` through a Collector.  The
    job's divergence metadata carries the reported-MFU running mean,
    exactly what the live path's last round registers."""
    roll = StreamingRollup(bucket_s)
    mfu = MfuRollup(bucket_s)
    for job in jobs:
        spec = job.spec
        mfu.observe_series(spec.job_id, job.mfu_t, job.mfu_v)
        roll.add_grid(spec.job_id, job.telemetry.grid, chips=spec.chips,
                      app_mfu=mfu.job_mean(spec.job_id), arch=spec.arch,
                      flops_variant=spec.flops_variant)
    return roll, mfu


def build_fleet(seed: int = 0, *, engine: str = "auto"):
    """Offline `JobPoint`s for `divergence.analyze` (the Fig. 5 sweep)."""
    roll, _ = offline_rollups(build_jobs(seed, engine=engine))
    return roll.to_job_points()


def to_streams(jobs) -> list:
    """Live `JobStream`s: counter replay + app-MFU reporter replay, for
    driving the fixture through a `Collector` round-for-round."""
    from repro.fleet.collector import JobStream
    from repro.telemetry.mfu import MfuReplaySource
    from repro.telemetry.source import GridSource

    return [JobStream(job.spec.job_id, GridSource(job.telemetry.grid),
                      chips=job.spec.chips, arch=job.spec.arch,
                      flops_variant=job.spec.flops_variant,
                      mfu_source=MfuReplaySource(job.mfu_t, job.mfu_v))
            for job in jobs]


def affected_ids(jobs) -> dict:
    """Ground truth: flops_variant -> set of job_ids (the §V-C sets the
    detectors must flag exactly)."""
    out: dict = {}
    for job in jobs:
        if job.spec.flops_variant != "exact":
            out.setdefault(job.spec.flops_variant, set()).add(job.job_id)
    return out
