"""Rollup wire format v2 (``FRU2``): the zero-copy ingest hot path.

The v1 snapshot (`StreamingRollup.to_bytes`) is a compressed npz: self-
describing and portable, but every blob pays zip framing + zlib on both
ends and every array is copied out of the archive.  At fleet scale the
reducer decodes thousands of blobs per second, so v2 trades generality
for speed:

  * raw little-endian header + contiguous column layout — the decoder is
    `np.frombuffer` views into the blob, no decompression, no copies;
  * DELTA framing — a blob can carry only the bucket rows touched since
    a base generation (`since`), stamped with the encoder's generation
    (`seq`), so a host ships O(new buckets) per round, not O(history);
  * REPLACE semantics — a delta row holds the scope's full cumulative
    histogram for that bucket, so applying a delta to a mirror of the
    base state is idempotent (at-least-once delivery needs no dedup
    bookkeeping beyond the `seq` ordering check).

Layout (all integers little-endian, arrays 8-byte aligned)::

    offset  size          field
    0       4             magic  b"FRU2"
    4       2             version (u16, currently 1)
    6       2             flags   (u16; bit0 = delta, i.e. since > 0)
    8       8             since   (u64: base generation, 0 = full)
    16      8             seq     (u64: encoder generation)
    24      4             bins    (u32)
    28      4             n_buckets (u32: total rows at encode time)
    32      8             bucket_s (f64)
    40      4             meta_len (u32: JSON byte count)
    44      4             zero pad
    48      meta_len      meta JSON {"scopes", "rows", "job_meta"}
    -- pad to 8 --
    (bins+1) * 8          edges (f64)
    per scope, in meta order:
      n_rows * 4          row indices (u32, absolute bucket index), pad to 8
      n_rows * bins * 8   histogram rows (f64, C order)
      n_rows * 8          weighted value sums (f64)

npz (v1) stays the compatibility format — it alone carries windowed
retention state — and `StreamingRollup.from_bytes` dispatches on the
leading magic, so a reducer accepts either through one entry point.
"""
from __future__ import annotations

import json
import struct
from dataclasses import dataclass

import numpy as np

MAGIC = b"FRU2"
VERSION = 1
FLAG_DELTA = 1

_HEADER = struct.Struct("<4sHHQQIIdI4x")      # 48 bytes, meta follows
assert _HEADER.size == 48


def _pad8(n: int) -> int:
    return (-n) % 8


@dataclass
class WireSnapshot:
    """A decoded v2 blob: header fields + per-scope array VIEWS.

    The arrays are read-only `np.frombuffer` views into the original
    blob — zero copies until the rows are written into a destination
    rollup.  Keep the blob alive as long as the views are in use.
    """

    version: int
    flags: int
    since: int                   # base generation (0 = full snapshot)
    seq: int                     # encoder generation
    bins: int
    n_buckets: int
    bucket_s: float
    edges: np.ndarray            # (bins + 1,) f64 view
    scopes: list                 # [(scope_tuple, idx u32, hist, sums), ...]
    job_meta: dict
    nbytes: int

    @property
    def is_delta(self) -> bool:
        return bool(self.flags & FLAG_DELTA)


def is_v2(blob) -> bool:
    return bytes(blob[:4]) == MAGIC


def encode(roll, since: int = 0) -> bytes:
    """Serialize `roll`'s bucket rows touched after generation `since`.

    `since=0` is a full snapshot (every row ever written); any later cut
    ships only the rows whose cumulative state changed — the caller's
    ack cursor decides.  Rollups with retention/eviction state cannot be
    delta-framed (an evicted row has no cumulative value to replace);
    they stay on the npz format.
    """
    if getattr(roll, "retain", None) is not None:
        raise ValueError("wire format v2 carries plain StreamingRollup "
                         "snapshots; a WindowedRollup's eviction state "
                         "needs the npz format (to_bytes)")
    since = int(since)
    if since < 0:
        raise ValueError(f"since={since} must be >= 0")
    scopes, rows, arrays = [], [], []
    for scope, touched in roll._touched.items():
        idx = np.flatnonzero(touched > since)
        if idx.size == 0:
            continue
        scopes.append(list(scope))
        rows.append(int(idx.size))
        arrays.append((idx.astype("<u4"),
                       np.ascontiguousarray(roll._hists[scope][idx],
                                            dtype="<f8"),
                       np.ascontiguousarray(roll._sums[scope][idx],
                                            dtype="<f8")))
    meta = json.dumps({"scopes": scopes, "rows": rows,
                       "job_meta": roll._job_meta},
                      separators=(",", ":"),
                      default=lambda o: o.item()).encode()
    flags = FLAG_DELTA if since > 0 else 0
    parts = [_HEADER.pack(MAGIC, VERSION, flags, since, int(roll.generation),
                          roll.bins, roll.n_buckets, roll.bucket_s,
                          len(meta)),
             meta, b"\0" * _pad8(len(meta)),
             np.ascontiguousarray(roll.edges, dtype="<f8").tobytes()]
    for idx, hist, sums in arrays:
        parts.append(idx.tobytes())
        parts.append(b"\0" * _pad8(idx.nbytes))
        parts.append(hist.tobytes())
        parts.append(sums.tobytes())
    return b"".join(parts)


def decode(blob) -> WireSnapshot:
    """Parse a v2 blob into header fields + zero-copy array views."""
    blob = bytes(blob) if isinstance(blob, bytearray) else blob
    if len(blob) < _HEADER.size:
        raise ValueError(f"blob too short for a v2 header "
                         f"({len(blob)} bytes)")
    magic, version, flags, since, seq, bins, n_buckets, bucket_s, \
        meta_len = _HEADER.unpack_from(blob, 0)
    if magic != MAGIC:
        raise ValueError(f"bad magic {magic!r} (want {MAGIC!r})")
    if version != VERSION:
        raise ValueError(f"unsupported wire format v2 version {version}")
    off = _HEADER.size
    try:
        meta = json.loads(blob[off:off + meta_len].decode())
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise ValueError(f"corrupt v2 meta block: {e}") from None
    off += meta_len + _pad8(meta_len)
    rows = meta["rows"]
    if len(rows) != len(meta["scopes"]):
        raise ValueError("corrupt v2 meta: scopes/rows length mismatch")
    need = off + (bins + 1) * 8 + sum(
        r * 4 + _pad8(r * 4) + r * bins * 8 + r * 8 for r in rows)
    if len(blob) < need:
        raise ValueError(f"truncated v2 blob: {len(blob)} bytes, "
                         f"layout needs {need}")
    edges = np.frombuffer(blob, "<f8", count=bins + 1, offset=off)
    off += (bins + 1) * 8
    scopes = []
    for key, n_rows in zip(meta["scopes"], rows):
        idx = np.frombuffer(blob, "<u4", count=n_rows, offset=off)
        off += n_rows * 4 + _pad8(n_rows * 4)
        hist = np.frombuffer(blob, "<f8", count=n_rows * bins,
                             offset=off).reshape(n_rows, bins)
        off += n_rows * bins * 8
        sums = np.frombuffer(blob, "<f8", count=n_rows, offset=off)
        off += n_rows * 8
        if n_rows and int(idx.max()) >= n_buckets:
            raise ValueError(f"corrupt v2 blob: row index {int(idx.max())}"
                             f" >= n_buckets {n_buckets}")
        scopes.append((tuple(key), idx, hist, sums))
    return WireSnapshot(version, flags, since, seq, bins, n_buckets,
                        bucket_s, edges, scopes, meta["job_meta"],
                        len(blob))


def restore(blob):
    """Full v2 blob -> fresh `StreamingRollup` (the from_bytes v2 arm)."""
    from repro.fleet.streaming import StreamingRollup

    snap = decode(blob)
    if snap.is_delta:
        raise ValueError(
            f"blob is a delta (covers generations {snap.since}->"
            f"{snap.seq}]); apply_delta() it to a mirror of the base "
            "state — only since=0 blobs restore standalone")
    roll = StreamingRollup(snap.bucket_s, bins=snap.bins,
                           lo=float(snap.edges[0]),
                           hi=float(snap.edges[-1]))
    roll.edges = snap.edges.copy()
    roll.apply_snapshot(snap)
    return roll
