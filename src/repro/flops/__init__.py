from repro.flops.accounting import (  # noqa: F401
    Breakdown, decode_step_flops, forward_flops, model_flops_6nd,
    param_count_analytic, step_flops, train_step_flops,
)
