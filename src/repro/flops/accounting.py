"""Application-level FLOPs accounting (the "App MFU" side of the paper).

Counts matmul FLOPs (2mnk) per layer type, the convention shared by PaLM /
Megatron / OpenAI scaling laws (paper §IV-E).  Non-matmul (VPU) work is
tallied separately to quantify the paper's *non-tensor undercounting* term —
which is material for SSM archs (DESIGN.md §2).

Variants reproduce the production miscalculations of paper §V-C:
  exact        — correct per-layer-type accounting
  naive_moe    — assumes experts operate at the full hidden dim, ignoring
                 latent down-projection (the 288-GPU case: ~3x inflation)
  naive_hybrid — counts every layer as attention + dense MLP (the hybrid
                 Mamba case: Mamba/MoE layers miscounted)

All figures are per *global* step for a (cfg, shape) cell.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models.moe import capacity


@dataclass
class Breakdown:
    """FLOPs by category.  mxu: matmul work; vpu: vector-unit work."""

    mxu: dict = field(default_factory=dict)
    vpu: dict = field(default_factory=dict)

    def add(self, cat: str, flops: float, unit: str = "mxu"):
        d = self.mxu if unit == "mxu" else self.vpu
        d[cat] = d.get(cat, 0.0) + flops

    @property
    def total_mxu(self) -> float:
        return sum(self.mxu.values())

    @property
    def total_vpu(self) -> float:
        return sum(self.vpu.values())

    @property
    def total(self) -> float:
        return self.total_mxu + self.total_vpu

    def scaled(self, f: float) -> "Breakdown":
        return Breakdown({k: v * f for k, v in self.mxu.items()},
                         {k: v * f for k, v in self.vpu.items()})

    def merged(self, other: "Breakdown") -> "Breakdown":
        out = Breakdown(dict(self.mxu), dict(self.vpu))
        for k, v in other.mxu.items():
            out.mxu[k] = out.mxu.get(k, 0) + v
        for k, v in other.vpu.items():
            out.vpu[k] = out.vpu.get(k, 0) + v
        return out


# ---------------------------------------------------------------------------
# per-layer forward FLOPs, per token (context length ctx for attention)
# ---------------------------------------------------------------------------
def _gqa_flops(cfg: ModelConfig, ctx_len: float, causal: bool) -> dict:
    H, KV, hd, d = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.d_model
    eff = ctx_len * (0.5 if causal else 1.0)
    return {
        "attn_proj": 2 * d * (H + 2 * KV) * hd + 2 * H * hd * d,
        "attn_score": 2 * 2 * eff * H * hd,
    }


def _mla_flops(cfg: ModelConfig, ctx_len: float, causal: bool) -> dict:
    d, H = cfg.d_model, cfg.num_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    eff = ctx_len * (0.5 if causal else 1.0)
    proj = (2 * d * qr + 2 * qr * H * (dn + dr)          # q path
            + 2 * d * (kvr + dr) + 2 * kvr * H * (dn + dv)  # kv path
            + 2 * H * dv * d)                            # out
    score = 2 * eff * H * (dn + dr) + 2 * eff * H * dv
    return {"attn_proj": proj, "attn_score": score}


def _mla_decode_flops(cfg: ModelConfig, ctx_len: float) -> dict:
    """Absorbed-MLA decode: attention runs in latent space (kvr + dr wide)."""
    d, H = cfg.d_model, cfg.num_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    proj = (2 * d * qr + 2 * qr * H * (dn + dr)
            + 2 * d * (kvr + dr)
            + 2 * H * dn * kvr          # absorb w_k into q
            + 2 * H * kvr * dv          # absorb w_v out of o_latent
            + 2 * H * dv * d)
    score = 2 * ctx_len * H * (kvr + dr) + 2 * ctx_len * H * kvr
    return {"attn_proj": proj, "attn_score": score}


def _mlp_flops(cfg: ModelConfig, d_ff: int, d_in: int = 0) -> float:
    d = d_in or cfg.d_model
    n_mats = 3 if cfg.activation == "silu" else 2
    return 2 * d * d_ff * n_mats


def _moe_flops(cfg: ModelConfig, variant: str, executed: bool) -> dict:
    d, E = cfg.d_model, cfg.num_experts
    out = {"router": 2 * d * E}
    if variant == "naive_moe":
        # paper §V-C case 1: counter assumes experts run at full hidden width
        # (here: ignores fine-grained expert width AND latent routing) —
        # each routed expert billed as a full dense MLP of width cfg.d_ff*? .
        # The production bug billed hidden=2048 vs latent=512 (~3-4x / expert).
        out["experts"] = cfg.top_k * _mlp_flops(cfg, cfg.d_ff_expert * 4)
    else:
        pad = 1.0
        if executed:
            # capacity padding: slots are computed whether full or not
            C = capacity(cfg, 4096)
            pad = C * E / (4096 * cfg.top_k)
        out["experts"] = cfg.top_k * _mlp_flops(cfg, cfg.d_ff_expert) * pad
    if cfg.num_shared_experts:
        out["shared_experts"] = _mlp_flops(
            cfg, cfg.d_ff_expert * cfg.num_shared_experts)
    return out


def _mamba_flops(cfg: ModelConfig, decode: bool = False) -> tuple[dict, dict]:
    """Returns (mxu, vpu) per token for one Mamba2 block."""
    d, di = cfg.d_model, cfg.d_inner
    nh, hd, g, ds = (cfg.ssm_nheads, cfg.ssm_head_dim, cfg.ssm_ngroups,
                     cfg.ssm_state)
    Q = cfg.ssm_chunk
    conv_dim = di + 2 * g * ds
    mxu = {
        "ssm_proj": 2 * d * (2 * di + 2 * g * ds + nh) + 2 * di * d,
    }
    if decode:
        # recurrent step: outer product + contraction, VPU-ish but counted
        vpu_ssd = 2 * nh * hd * ds * 3
        mxu["ssd"] = 0.0
        vpu = {"ssd_step": vpu_ssd, "conv": 2 * cfg.conv_width * conv_dim,
               "gating": 10 * di}
        return mxu, vpu
    # chunked SSD per token: CB (Q*g*ds) + M@x (Q*hd per head pair) +
    # state build + state read (outer products)
    mxu["ssd"] = (2 * Q * g * ds          # C·Bᵀ within chunk
                  + 2 * Q * nh * hd / Q * Q  # (M @ x): Q mults per out elem
                  + 2 * nh * hd * ds       # chunk-state build
                  + 2 * nh * hd * ds)      # inter-chunk read (C·h)
    vpu = {"conv": 2 * cfg.conv_width * conv_dim,
           "ssd_decay": 6 * Q * nh,        # segsum/exp decay matrices
           "gating": 10 * di}
    return mxu, vpu


# ---------------------------------------------------------------------------
# whole-model forward, per global step
# ---------------------------------------------------------------------------
def forward_flops(cfg: ModelConfig, shape: ShapeSpec, *,
                  variant: str = "exact", executed: bool = False) -> Breakdown:
    """Forward-pass FLOPs for one global batch (train/prefill kinds)."""
    B, S = shape.global_batch, shape.seq_len
    N = B * S  # tokens
    bd = Breakdown()
    L = cfg.num_layers

    def add_layer(per_tok: dict, n_layers: int, unit="mxu", tokens=N):
        for k, v in per_tok.items():
            bd.add(k, v * n_layers * tokens, unit)

    if cfg.family in ("dense", "vlm"):
        add_layer(_gqa_flops(cfg, S, True), L)
        add_layer({"mlp": _mlp_flops(cfg, cfg.d_ff)}, L)
    elif cfg.family == "moe":
        nd = cfg.first_dense_layers
        add_layer(_gqa_flops(cfg, S, True), L)
        add_layer({"mlp": _mlp_flops(cfg, cfg.d_ff * 8)}, nd)  # dense lead-in
        for k, v in _moe_flops(cfg, variant, executed).items():
            bd.add(k, v * (L - nd) * N)
    elif cfg.family == "mla_moe":
        nd = cfg.first_dense_layers
        if variant == "naive_moe":
            # §V-C: latent projections not accounted — bills full MHA
            add_layer(_gqa_flops(cfg, S, True), L)
        else:
            add_layer(_mla_flops(cfg, S, True), L)
        add_layer({"mlp": _mlp_flops(cfg, cfg.d_ff)}, nd)
        for k, v in _moe_flops(cfg, variant, executed).items():
            bd.add(k, v * (L - nd) * N)
        if cfg.mtp_depth and shape.kind == "train":
            # MTP: one extra block + head over all tokens
            mtp = Breakdown()
            for k, v in _mla_flops(cfg, S, True).items():
                mtp.add(k, v * N)
            for k, v in _moe_flops(cfg, variant, executed).items():
                mtp.add(k, v * N)
            mtp.add("mtp_proj", 2 * 2 * cfg.d_model * cfg.d_model * N)
            mtp.add("lm_head", 2 * cfg.d_model * cfg.vocab_size * N)
            bd = bd.merged(mtp)
    elif cfg.family == "ssm":
        mxu, vpu = _mamba_flops(cfg)
        add_layer(mxu, L)
        add_layer(vpu, L, unit="vpu")
    elif cfg.family == "hybrid":
        if variant == "naive_hybrid":
            # §V-C case 2: every layer billed as attention + dense MLP
            add_layer(_gqa_flops(cfg, S, True), L)
            add_layer({"mlp": _mlp_flops(cfg, cfg.d_ff)}, L)
        else:
            mxu, vpu = _mamba_flops(cfg)
            add_layer(mxu, L)
            add_layer(vpu, L, unit="vpu")
            n_attn = len(range(0, L, cfg.attn_every))
            add_layer(_gqa_flops(cfg, S, True), n_attn)
            add_layer({"mlp": _mlp_flops(cfg, cfg.d_ff)}, n_attn)
    elif cfg.family == "encdec":
        Ne = B * cfg.encoder_seq
        add_layer(_gqa_flops(cfg, cfg.encoder_seq, False), cfg.encoder_layers,
                  tokens=Ne)
        add_layer({"mlp": _mlp_flops(cfg, cfg.d_ff)}, cfg.encoder_layers,
                  tokens=Ne)
        # decoder: self + cross + mlp
        add_layer(_gqa_flops(cfg, S, True), L)
        H, hd, d = cfg.num_heads, cfg.head_dim, cfg.d_model
        cross_kv = 2 * d * 2 * cfg.num_kv_heads * hd * Ne * L
        bd.add("cross_proj", cross_kv)
        add_layer({"cross_proj": 2 * d * H * hd + 2 * H * hd * d,
                   "cross_score": 2 * 2 * cfg.encoder_seq * H * hd}, L)
        add_layer({"mlp": _mlp_flops(cfg, cfg.d_ff)}, L)
    else:
        raise ValueError(cfg.family)

    if cfg.family == "vlm":
        bd.add("mm_connector", 2 * cfg.d_model ** 2 * B * cfg.num_image_tokens)

    bd.add("lm_head", 2 * cfg.d_model * cfg.vocab_size * N)
    # norms / residuals / softmax: VPU
    bd.add("norms", 12 * cfg.d_model * N * max(L, 1), "vpu")
    return bd


def train_step_flops(cfg: ModelConfig, shape: ShapeSpec, *,
                     variant: str = "exact", executed: bool = False,
                     remat: bool = True) -> Breakdown:
    """Train step = F forward + 2F backward (+F recompute when remat).

    Paper §VI-C: frameworks that miss the remat term under-report FLOPs by
    F/3 — the world-foundation-model case (26% -> 33% MFU after fixing).
    """
    fwd = forward_flops(cfg, shape, variant=variant, executed=executed)
    mult = 4.0 if (remat and executed) else 3.0
    if variant == "no_remat_accounting":
        mult = 3.0  # the buggy counter: ignores recompute even when remat on
    return fwd.scaled(mult)


def decode_step_flops(cfg: ModelConfig, shape: ShapeSpec, *,
                      variant: str = "exact") -> Breakdown:
    """One decode step (B new tokens, context length = shape.seq_len)."""
    B, S = shape.global_batch, shape.seq_len
    bd = Breakdown()
    L = cfg.num_layers

    def add(per_tok: dict, n_layers: int, unit="mxu"):
        for k, v in per_tok.items():
            bd.add(k, v * n_layers * B, unit)

    ctx = S  # decode attends to the full cache
    if cfg.family in ("dense", "vlm"):
        add(_gqa_flops(cfg, ctx, False), L)
        add({"mlp": _mlp_flops(cfg, cfg.d_ff)}, L)
    elif cfg.family == "moe":
        nd = cfg.first_dense_layers
        add(_gqa_flops(cfg, ctx, False), L)
        add({"mlp": _mlp_flops(cfg, cfg.d_ff * 8)}, nd)
        for k, v in _moe_flops(cfg, variant, False).items():
            bd.add(k, v * (L - nd) * B)
    elif cfg.family == "mla_moe":
        nd = cfg.first_dense_layers
        add(_mla_decode_flops(cfg, ctx), L)
        add({"mlp": _mlp_flops(cfg, cfg.d_ff)}, nd)
        for k, v in _moe_flops(cfg, variant, False).items():
            bd.add(k, v * (L - nd) * B)
    elif cfg.family == "ssm":
        mxu, vpu = _mamba_flops(cfg, decode=True)
        add(mxu, L)
        add(vpu, L, unit="vpu")
    elif cfg.family == "hybrid":
        mxu, vpu = _mamba_flops(cfg, decode=True)
        add(mxu, L)
        add(vpu, L, unit="vpu")
        n_attn = len(range(0, L, cfg.attn_every))
        add(_gqa_flops(cfg, ctx, False), n_attn)
        add({"mlp": _mlp_flops(cfg, cfg.d_ff)}, n_attn)
    elif cfg.family == "encdec":
        add(_gqa_flops(cfg, ctx, False), L)
        H, hd, d = cfg.num_heads, cfg.head_dim, cfg.d_model
        add({"cross_proj": (2 * d * H * hd + 2 * H * hd * d
                            + 2 * d * 2 * cfg.num_kv_heads * hd
                            * cfg.encoder_seq),
             "cross_score": 2 * 2 * cfg.encoder_seq * H * hd}, L)
        add({"mlp": _mlp_flops(cfg, cfg.d_ff)}, L)

    bd.add("lm_head", 2 * cfg.d_model * cfg.vocab_size * B)
    bd.add("norms", 12 * cfg.d_model * B * max(L, 1), "vpu")
    return bd


def step_flops(cfg: ModelConfig, shape: ShapeSpec, **kw) -> Breakdown:
    if shape.kind == "train":
        return train_step_flops(cfg, shape, **kw)
    if shape.kind == "prefill":
        kw.pop("remat", None)
        return forward_flops(cfg, shape, **kw)
    kw.pop("remat", None)
    kw.pop("executed", None)
    return decode_step_flops(cfg, shape, **kw)


# ---------------------------------------------------------------------------
# parameter counts & the 6·N·D convention
# ---------------------------------------------------------------------------
def param_count_analytic(cfg: ModelConfig, active_only: bool = False) -> float:
    """Matmul parameter count (embeddings excluded from the 6ND convention)."""
    d, L = cfg.d_model, cfg.num_layers
    n = 0.0
    per_mlp = (3 if cfg.activation == "silu" else 2)

    def attn_params():
        if cfg.family == "mla_moe":
            return (d * cfg.q_lora_rank
                    + cfg.q_lora_rank * cfg.num_heads
                    * (cfg.qk_nope_dim + cfg.qk_rope_dim)
                    + d * (cfg.kv_lora_rank + cfg.qk_rope_dim)
                    + cfg.kv_lora_rank * cfg.num_heads
                    * (cfg.qk_nope_dim + cfg.v_head_dim)
                    + cfg.num_heads * cfg.v_head_dim * d)
        return d * (cfg.num_heads + 2 * cfg.num_kv_heads) * cfg.head_dim \
            + cfg.num_heads * cfg.head_dim * d

    def mamba_params():
        return d * (2 * cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
                    + cfg.ssm_nheads) + cfg.d_inner * d

    if cfg.family in ("dense", "vlm"):
        n += L * (attn_params() + per_mlp * d * cfg.d_ff)
    elif cfg.family in ("moe", "mla_moe"):
        nd = cfg.first_dense_layers
        n += L * attn_params()
        ff_dense = cfg.d_ff * (8 if cfg.family == "moe" else 1)
        n += nd * per_mlp * d * ff_dense
        e = cfg.top_k if active_only else cfg.num_experts
        n += (L - nd) * (e + cfg.num_shared_experts) \
            * per_mlp * d * cfg.d_ff_expert
        n += (L - nd) * d * cfg.num_experts  # router
    elif cfg.family == "ssm":
        n += L * mamba_params()
    elif cfg.family == "hybrid":
        n += L * mamba_params()
        n += attn_params() + per_mlp * d * cfg.d_ff  # ONE shared block
    elif cfg.family == "encdec":
        n += cfg.encoder_layers * (attn_params() + per_mlp * d * cfg.d_ff)
        n += L * (attn_params() * 2 + per_mlp * d * cfg.d_ff)
    n += d * cfg.vocab_size  # lm head
    return n


def model_flops_6nd(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) per global step."""
    if shape.kind == "decode":
        tokens = shape.global_batch
        return 2 * param_count_analytic(cfg, active_only=True) * tokens
    tokens = shape.global_batch * shape.seq_len
    mult = 6 if shape.kind == "train" else 2
    return mult * param_count_analytic(cfg, active_only=True) * tokens
