"""Pallas TPU kernels (pl.pallas_call + BlockSpec VMEM tiling).

gemm             — MXU-tiled GEMM; static grid = exact FLOPs_profiled oracle
flash_attention  — online-softmax attention (train/prefill fast path)
ssd_scan         — Mamba2 SSD intra-chunk block
fleet_hist       — fused OFU histogram-accumulate (rollup device ingest)
ops              — jit'd wrappers (padding, GemmProfile metadata)
ref              — pure-jnp oracles for the allclose tests
"""
from repro.kernels import ops, ref  # noqa: F401
