"""Pallas flash attention (TPU fast path for train/prefill attention).

Grid: (batch·kv_heads, q_blocks, kv_blocks) with the online-softmax carry
(m, l, acc) in VMEM scratch; kv is the innermost (sequential) grid axis.
GQA is handled by blocking q over (KV, G) head groups so each kv head's
key/value block is loaded once per q block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  kv_steps: int, bq: int, bkv: int, scale: float,
                  causal: bool):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                       # (G, bq, hd)
    k = k_ref[0]                       # (bkv, hd)
    v = v_ref[0]                       # (bkv, hd)
    s = jax.lax.dot_general(
        q, k, (((2,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale    # (G, bq, bkv)

    if causal:
        qi = pl.program_id(1) * bq + jax.lax.broadcasted_iota(
            jnp.int32, (q.shape[0], bq, bkv), 1)
        kj = j * bkv + jax.lax.broadcasted_iota(
            jnp.int32, (q.shape[0], bq, bkv), 2)
        s = jnp.where(qi >= kj, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(-1)
    acc_ref[...] = (acc_ref[...] * corr[..., None]
                    + jax.lax.dot_general(
                        p.astype(v.dtype), v, (((2,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32))
    m_ref[...] = m_new

    @pl.when(j == kv_steps - 1)
    def _done():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[..., None]
                    ).astype(o_ref.dtype)


def flash_attention_kernel(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool, scale: float | None = None,
                           bq: int = 256, bkv: int = 256,
                           interpret: bool = False) -> jax.Array:
    """q: (B, Sq, H, hd); k/v: (B, Sk, KV, hd), H % KV == 0.

    Requires Sq % bq == 0 and Sk % bkv == 0 (callers pad).
    """
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    scale = hd ** -0.5 if scale is None else scale
    assert Sq % bq == 0 and Sk % bkv == 0

    # (B·KV, G, Sq, hd) query layout; kv: (B·KV, Sk, hd)
    qr = q.reshape(B, Sq, KV, G, hd).transpose(0, 2, 3, 1, 4) \
        .reshape(B * KV, G, Sq, hd)
    kr = k.transpose(0, 2, 1, 3).reshape(B * KV, Sk, hd)
    vr = v.transpose(0, 2, 1, 3).reshape(B * KV, Sk, hd)

    grid = (B * KV, Sq // bq, Sk // bkv)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, kv_steps=grid[2], bq=bq, bkv=bkv,
                          scale=scale, causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, G, bq, hd), lambda b, i, j: (b, 0, i, 0)),
            pl.BlockSpec((1, bkv, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bkv, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, bq, hd), lambda b, i, j: (b, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV, G, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, bq), jnp.float32),
            pltpu.VMEM((G, bq), jnp.float32),
            pltpu.VMEM((G, bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, KV, G, Sq, hd).transpose(0, 3, 1, 2, 4) \
        .reshape(B, Sq, H, hd)
