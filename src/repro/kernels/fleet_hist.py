"""Fused OFU histogram-accumulate — the device side of rollup ingest.

`StreamingRollup.add_grid` over a NumPy grid computes the per-device OFU
series on the host and scatter-adds it into per-bucket histograms.  For a
jax engine grid that round-trip is the bottleneck: a 1M-device day of
30 s scrapes is ~23 GB of per-device OFU that exists only to be reduced
into a few kilobytes of (bucket, bin) weights.  This module keeps the
reduction on the device:

    ofu = tpa * clock / f_max          (Eq. 1, elementwise)
    k   = bucketize(ofu, edges)        (comparison-based — see below)
    hist[b, k] += 1 ; sums[b] += ofu   (per time-bucket accumulate)

fused into one pass, so only the (n_buckets, bins) histogram and the
(n_buckets,) weighted sums ever reach the host.

Bin assignment is COMPARISON-based (count of edges ≤ value — digitize's
definition), never arithmetic on the value: XLA is free to contract or
reorder a `floor((v - lo) * inv_width)` chain at different intermediate
precision than the host, which flips samples sitting one ulp from a bin
edge.  Comparisons on identical f32 bits are exact, so the kernel, the
XLA fallback, and the NumPy oracle agree bin-for-bin by construction.

Two implementations share the arithmetic:

  * `pallas` — a `pl.pallas_call` kernel over a (device-blocks, buckets)
    grid: each step computes a tile's OFU, bins it via a one-hot
    compare against a bin iota, and accumulates one bucket row of the
    output in VMEM.  Requires bucket-aligned columns (every time bucket
    spans the same number of scrape columns — the steady-state shape);
    runs interpreted off-TPU like every other kernel in this package.
  * `xla` — a jnp searchsorted + scatter-add over (bucket, bin) keys;
    handles ragged column->bucket maps and is the fast path on CPU.

`ofu_bucket_hist` picks automatically; `bucket_hist_ref` is the NumPy
oracle the equivalence tests pin both against.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _edges_f32(edges: np.ndarray) -> np.ndarray:
    """Edge grid in the comparison dtype (f32, matching the engine's
    telemetry); must be strictly increasing."""
    edges = np.asarray(edges, np.float32)
    if edges.ndim != 1 or len(edges) < 2 or not (np.diff(edges) > 0).all():
        raise ValueError("edges must be a 1-D strictly-increasing grid")
    return edges


def _aligned_spb(col_bucket: np.ndarray, n_buckets: int) -> Optional[int]:
    """Samples-per-bucket when every bucket spans an equal run of columns
    (the last may run short); None when the map is ragged."""
    S = len(col_bucket)
    if S == 0 or n_buckets <= 0:
        return None
    spb = int(np.searchsorted(col_bucket, 1)) if n_buckets > 1 else S
    if spb <= 0:
        return None
    if np.array_equal(col_bucket, np.arange(S) // spb):
        return spb
    return None


# ---------------------------------------------------------------------------
# pallas kernel: (device-blocks, buckets) grid, one-hot bin accumulate
# ---------------------------------------------------------------------------
def _hist_kernel(tpa_ref, clock_ref, edges_ref, hist_ref, sum_ref, *,
                 n_rows: int, n_cols: int, spb: int, block_d: int,
                 bins: int, inv_fmax: float):
    i = pl.program_id(0)                     # device-row block
    ofu = tpa_ref[...] * clock_ref[...] * jnp.float32(inv_fmax)
    rows = jax.lax.broadcasted_iota(jnp.int32, ofu.shape, 0) + i * block_d
    cols = jax.lax.broadcasted_iota(jnp.int32, ofu.shape, 1) \
        + pl.program_id(1) * spb
    valid = ((rows < n_rows) & (cols < n_cols)).astype(ofu.dtype)
    n = ofu.size
    # digitize by comparison: bin = #edges ≤ v, minus one, clipped
    ge = ofu.reshape(n, 1) >= edges_ref[...]             # (n, bins+1)
    k = jnp.clip(ge.astype(jnp.int32).sum(axis=1) - 1, 0, bins - 1)
    onehot = (k.reshape(n, 1)
              == jax.lax.broadcasted_iota(jnp.int32, (n, bins), 1)) \
        .astype(ofu.dtype) * valid.reshape(n, 1)

    @pl.when(i == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)
        sum_ref[...] = jnp.zeros_like(sum_ref)

    hist_ref[...] += onehot.sum(axis=0, keepdims=True)
    sum_ref[...] += (ofu * valid).sum().reshape(1, 1)


@functools.partial(jax.jit, static_argnames=(
    "spb", "n_buckets", "inv_fmax", "interpret"))
def _hist_pallas(tpa, clock, edges, *, spb, n_buckets, inv_fmax, interpret):
    D, S = tpa.shape
    bins = edges.shape[1] - 1
    # one-hot tiles stay a few MB of VMEM: block_d * spb * bins * 4B.
    # Interpreted runs pay python per grid step, not VMEM — trade tile
    # memory for an ~8x smaller grid there.
    block_d = max(8, (65536 if interpret else 8192) // max(spb, 1))
    pad_d = -D % block_d
    pad_s = n_buckets * spb - S
    if pad_d or pad_s:
        tpa = jnp.pad(tpa, ((0, pad_d), (0, pad_s)))
        clock = jnp.pad(clock, ((0, pad_d), (0, pad_s)))
    grid = (tpa.shape[0] // block_d, n_buckets)
    hist, sums = pl.pallas_call(
        functools.partial(_hist_kernel, n_rows=D, n_cols=S, spb=spb,
                          block_d=block_d, bins=bins, inv_fmax=inv_fmax),
        grid=grid,
        in_specs=[pl.BlockSpec((block_d, spb), lambda i, j: (i, j)),
                  pl.BlockSpec((block_d, spb), lambda i, j: (i, j)),
                  pl.BlockSpec((1, bins + 1), lambda i, j: (0, 0))],
        out_specs=[pl.BlockSpec((1, bins), lambda i, j: (j, 0)),
                   pl.BlockSpec((1, 1), lambda i, j: (j, 0))],
        out_shape=[jax.ShapeDtypeStruct((n_buckets, bins), tpa.dtype),
                   jax.ShapeDtypeStruct((n_buckets, 1), tpa.dtype)],
        interpret=interpret,
    )(tpa, clock, edges)
    return hist, sums[:, 0]


# ---------------------------------------------------------------------------
# XLA fallback: searchsorted + scatter-add over (bucket, bin) keys
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("n_buckets", "inv_fmax"))
def _hist_xla(tpa, clock, edges, col_bucket, *, n_buckets, inv_fmax):
    bins = edges.shape[0] - 1
    ofu = tpa * clock * jnp.float32(inv_fmax)
    k = jnp.clip(jnp.searchsorted(edges, ofu.ravel(), side="right")
                 .astype(jnp.int32) - 1, 0, bins - 1)
    seg = jnp.broadcast_to(col_bucket[None, :], ofu.shape).ravel()
    hist = jnp.zeros(n_buckets * bins, ofu.dtype) \
        .at[seg * bins + k].add(1.0).reshape(n_buckets, bins)
    sums = jnp.zeros(n_buckets, ofu.dtype).at[seg].add(ofu.ravel())
    return hist, sums


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------
def ofu_bucket_hist(tpa, clock, *, inv_fmax: float, edges: np.ndarray,
                    col_bucket: np.ndarray, n_buckets: int,
                    use_pallas: Optional[bool] = None,
                    interpret: Optional[bool] = None):
    """Device-side fused ingest: (hist (B, bins), sums (B,)) f32 arrays.

    col_bucket: (S,) 0-based LOCAL bucket row per scrape column (the
    caller rebases absolute bucket indices).  use_pallas=None routes to
    the pallas kernel on TPU (bucket-aligned columns required, else the
    XLA scatter handles the ragged map) and to XLA elsewhere; pass True
    to force the kernel (interpreted off-TPU).
    """
    edges = _edges_f32(edges)
    col_bucket = np.asarray(col_bucket, np.int32)
    spb = _aligned_spb(col_bucket, n_buckets)
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas and spb is not None:
        return _hist_pallas(
            jnp.asarray(tpa), jnp.asarray(clock),
            jnp.asarray(edges).reshape(1, -1), spb=spb,
            n_buckets=n_buckets, inv_fmax=float(inv_fmax),
            interpret=_interpret() if interpret is None else interpret)
    return _hist_xla(jnp.asarray(tpa), jnp.asarray(clock),
                     jnp.asarray(edges), jnp.asarray(col_bucket),
                     n_buckets=n_buckets, inv_fmax=float(inv_fmax))


def bucket_hist_ref(tpa, clock, *, inv_fmax: float, edges: np.ndarray,
                    col_bucket: np.ndarray, n_buckets: int):
    """NumPy oracle with the device paths' exact f32 arithmetic."""
    edges = _edges_f32(edges)
    bins = len(edges) - 1
    tpa = np.asarray(tpa, np.float32)
    clock = np.asarray(clock, np.float32)
    ofu = tpa * clock * np.float32(inv_fmax)
    k = np.clip(np.searchsorted(edges, ofu.ravel(), side="right") - 1,
                0, bins - 1)
    seg = np.broadcast_to(np.asarray(col_bucket, np.int32)[None, :],
                          ofu.shape).ravel()
    hist = np.zeros((n_buckets, bins), np.float32)
    np.add.at(hist, (seg, k), np.float32(1.0))
    sums = np.zeros(n_buckets, np.float32)
    np.add.at(sums, seg, ofu.ravel())
    return hist, sums
