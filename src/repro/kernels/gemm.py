"""MXU-tiled Pallas GEMM — the controlled workload of paper §IV.

The kernel computes C = A @ B over an explicit (M/tm, N/tn, K/tk) grid with
fp32 (or int32) accumulation in VMEM scratch.  ops.py zero-pads operands up
to tile multiples before the call — tile quantization made *literal*: the
hardware (or interpreter) really executes 2·M_eff·N_eff·K_eff FLOPs, and the
static grid is the exact "NCU" ground truth for FLOPs_profiled.

Block shapes come from repro.core.tile_quant.TilePolicy — the library-layer
policy axis that replaces cuBLAS kernel-family selection (DESIGN.md §2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.tile_quant import TilePolicy


def _gemm_kernel(x_ref, y_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], y_ref[...],
                            preferred_element_type=acc_ref.dtype)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def gemm_padded(x: jax.Array, y: jax.Array, policy: TilePolicy, *,
                out_dtype=None, interpret: bool = False) -> jax.Array:
    """GEMM on tile-aligned operands.  x: (M_eff, K_eff); y: (K_eff, N_eff).

    Shapes MUST already be multiples of (tm, tk) / (tk, tn) — ops.matmul
    does the Eq. 3 padding and records the executed-FLOPs metadata.
    """
    M, K = x.shape
    K2, N = y.shape
    assert K == K2, (K, K2)
    tm, tn, tk = policy.tm, policy.tn, policy.tk
    assert M % tm == 0 and N % tn == 0 and K % tk == 0, \
        (M, N, K, tm, tn, tk)
    grid = (M // tm, N // tn, K // tk)

    acc_dtype = jnp.int32 if x.dtype == jnp.int8 else jnp.float32
    out_dtype = out_dtype or (jnp.int32 if x.dtype == jnp.int8 else x.dtype)

    return pl.pallas_call(
        functools.partial(_gemm_kernel, k_steps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, k: (i, k)),
            pl.BlockSpec((tk, tn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((tm, tn), acc_dtype)],
        interpret=interpret,
    )(x, y)


def grid_flops(M: int, N: int, K: int, policy: TilePolicy) -> int:
    """Executed FLOPs implied by the static grid (the closed-form oracle)."""
    tm, tn, tk = policy.tm, policy.tn, policy.tk
    m_tiles = -(-M // tm)
    n_tiles = -(-N // tn)
    me = -(-m_tiles // policy.cm) * policy.cm * tm
    ne = -(-n_tiles // policy.cn) * policy.cn * tn
    ke = -(-K // tk) * tk
    return 2 * me * ne * ke
