"""jit'd public wrappers around the Pallas kernels.

Each wrapper handles tile-quantization padding (Eq. 3: operands are
zero-padded up to BlockSpec multiples and the padded tiles are genuinely
computed), records the executed-FLOPs metadata the OFU pipeline consumes,
and selects interpret mode automatically off-TPU.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.tile_quant import TilePolicy, pick_policy
from repro.kernels import flash_attention as fa
from repro.kernels import gemm as gemm_mod
from repro.kernels import ssd_scan


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@dataclass(frozen=True)
class GemmProfile:
    """The per-GEMM record an NCU-style profile would give (paper §IV-A)."""

    M: int
    N: int
    K: int
    policy: TilePolicy
    theoretical_flops: int
    profiled_flops: int

    @property
    def overhead(self) -> float:
        return (self.profiled_flops - self.theoretical_flops) \
            / self.theoretical_flops


def _pad_to(x: jax.Array, m0: int, m1: int) -> jax.Array:
    p0 = -x.shape[0] % m0
    p1 = -x.shape[1] % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


def matmul(x: jax.Array, y: jax.Array, *,
           policy: Optional[TilePolicy] = None,
           dtype_name: Optional[str] = None,
           interpret: Optional[bool] = None
           ) -> tuple[jax.Array, GemmProfile]:
    """C = x @ y through the Pallas kernel, with tile-quantization padding.

    Returns (C, GemmProfile) — profile.profiled_flops is exact (static grid).
    """
    M, K = x.shape
    _, N = y.shape
    dtype_name = dtype_name or {"bfloat16": "bf16", "float32": "fp32",
                                "int8": "int8"}.get(x.dtype.name, "bf16")
    policy = policy or pick_policy(M, N, K, dtype_name)
    interpret = _interpret() if interpret is None else interpret

    xp = _pad_to(x, policy.tm * policy.cm, policy.tk)
    yp = _pad_to(y, policy.tk, policy.tn * policy.cn)
    out = _matmul_call(xp, yp, policy, interpret)
    prof = GemmProfile(M, N, K, policy, 2 * M * N * K,
                       gemm_mod.grid_flops(M, N, K, policy))
    return out[:M, :N], prof


@partial(jax.jit, static_argnums=(2, 3))
def _matmul_call(xp, yp, policy, interpret):
    return gemm_mod.gemm_padded(xp, yp, policy, interpret=interpret)


def flash(q, k, v, *, causal: bool, scale=None,
          bq: int = 256, bkv: int = 256,
          interpret: Optional[bool] = None) -> jax.Array:
    """Flash attention (pads Sq/Sk to block multiples; causal-safe)."""
    interpret = _interpret() if interpret is None else interpret
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    bq = min(bq, Sq)
    bkv = min(bkv, Sk)
    pq = -Sq % bq
    pk = -Sk % bkv
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        # pad keys with a -inf-score sentinel: zero k is fine because padded
        # q rows are dropped and padded k cols are masked by causality only
        # when Sq == Sk; for safety we mask via an explicit large-negative
        # bias on padded columns using value zeros (softmax weight ~ e^0) —
        # instead simply fall back to the reference path for ragged Sk.
        from repro.kernels.ref import ref_attention
        return ref_attention(q[:, :Sq], k, v, causal=causal, scale=scale)
    out = fa.flash_attention_kernel(q, k, v, causal=causal, scale=scale,
                                    bq=bq, bkv=bkv, interpret=interpret)
    return out[:, :Sq]


def ssd(x, dt, A, Bm, Cm, *, chunk: int,
        interpret: Optional[bool] = None) -> jax.Array:
    """Full chunked SSD using the Pallas intra-chunk kernel + jnp recurrence.

    Same contract as repro.models.ssm.ssd_chunked.
    """
    interpret = _interpret() if interpret is None else interpret
    Bsz, S, nh, hd = x.shape
    g, ds = Bm.shape[2], Bm.shape[3]
    hpg = nh // g
    Q = min(chunk, S)
    nc = S // Q
    assert nc * Q == S
    f32 = jnp.float32

    dtc = dt.reshape(Bsz, nc, Q, nh).astype(f32)
    dA = dtc * A
    dacs = jnp.cumsum(dA, axis=2)

    # broadcast B/C groups to heads for the kernel's per-head layout
    def to_heads(t):
        t = t.reshape(Bsz, nc, Q, g, 1, ds)
        t = jnp.broadcast_to(t, (Bsz, nc, Q, g, hpg, ds))
        return t.reshape(Bsz * nc, Q, nh, ds)

    xk = x.reshape(Bsz * nc, Q, nh, hd)
    y_intra = ssd_scan.ssd_intra_kernel(
        xk, dtc.reshape(Bsz * nc, Q, nh), dacs.reshape(Bsz * nc, Q, nh),
        to_heads(Bm), to_heads(Cm), interpret=interpret)
    y_intra = y_intra.reshape(Bsz, nc, Q, nh, hd).astype(f32)

    # ---- inter-chunk recurrence + contribution (jnp; see models.ssm) ----
    Bc = Bm.reshape(Bsz, nc, Q, g, ds)
    Cc = Cm.reshape(Bsz, nc, Q, g, ds)
    xc = x.reshape(Bsz, nc, Q, g, hpg, hd)
    decay_to_end = jnp.exp(dacs[:, :, -1:, :] - dacs)
    w = (dtc * decay_to_end).reshape(Bsz, nc, Q, g, hpg)
    states = jnp.einsum("bcqgd,bcqgh,bcqghp->bcghpd",
                        Bc.astype(f32), w, xc.astype(f32))
    chunk_decay = jnp.exp(dacs[:, :, -1, :])

    def step(h, inp):
        st, dec = inp
        h_in = h
        h = h * dec[:, :, None, None] + st.reshape(Bsz, nh, hd, ds)
        return h, h_in

    h0 = jnp.zeros((Bsz, nh, hd, ds), f32)
    _, h_prevs = jax.lax.scan(
        step, h0, (jnp.moveaxis(states, 1, 0),
                   jnp.moveaxis(chunk_decay, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)
    y_inter = jnp.einsum(
        "bcqgd,bcqgh,bcghpd->bcqghp",
        Cc.astype(f32), jnp.exp(dacs).reshape(Bsz, nc, Q, g, hpg),
        h_prevs.reshape(Bsz, nc, g, hpg, hd, ds))
    y = y_intra + y_inter.reshape(Bsz, nc, Q, nh, hd)
    return y.reshape(Bsz, S, nh, hd).astype(x.dtype)
