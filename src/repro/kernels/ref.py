"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_matmul(x: jax.Array, y: jax.Array, out_dtype=None) -> jax.Array:
    out_dtype = out_dtype or (jnp.int32 if x.dtype == jnp.int8 else x.dtype)
    acc = jnp.int32 if x.dtype == jnp.int8 else jnp.float32
    return jnp.dot(x, y, preferred_element_type=acc).astype(out_dtype)


def ref_attention(q, k, v, *, causal: bool, scale=None) -> jax.Array:
    """q: (B, Sq, H, hd); k/v: (B, Sk, KV, hd)."""
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    scale = hd ** -0.5 if scale is None else scale
    qg = q.reshape(B, Sq, KV, G, hd)
    s = jnp.einsum("bqkgd,bjkd->bkgqj", qg, k,
                   preferred_element_type=jnp.float32) * scale
    if causal:
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqj,bjkd->bkgqd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd).astype(q.dtype)


def ref_ssd_intra(x, dt, dacs, b, c) -> jax.Array:
    """Direct quadratic intra-chunk SSD (per-head B/C layout).

    x: (BC, Q, nh, hd); dt/dacs: (BC, Q, nh); b/c: (BC, Q, nh, ds).
    """
    f32 = jnp.float32
    Q = x.shape[1]
    cb = jnp.einsum("zqhd,zkhd->zhqk", c.astype(f32), b.astype(f32))
    seg = (dacs.astype(f32).transpose(0, 2, 1)[:, :, :, None]
           - dacs.astype(f32).transpose(0, 2, 1)[:, :, None, :])
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(mask[None, None], jnp.exp(seg), 0.0)
    m = cb * L * dt.astype(f32).transpose(0, 2, 1)[:, :, None, :]
    y = jnp.einsum("zhqk,zkhd->zqhd", m, x.astype(f32))
    return y.astype(x.dtype)
