"""Pallas kernel for the Mamba2 SSD intra-chunk block (the MXU hot spot).

Per (batch·chunk, head-block) grid cell it computes the quadratic
within-chunk term:  Y = ((C·Bᵀ) ∘ L(dA) ∘ dt) @ X
where L is the causal decay matrix from the within-chunk cumsum of dA.
The linear inter-chunk recurrence stays in jnp (repro.models.ssm) — it is
bandwidth-trivial and latency-bound, not MXU work.

B/C are pre-broadcast to per-head layout by ops.ssd_intra.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _ssd_kernel(x_ref, dt_ref, dacs_ref, b_ref, c_ref, o_ref, *, Q: int):
    # blocks: x (1, Q, HB, hd) dt/dacs (1, Q, HB) b/c (1, Q, HB, ds)
    x = x_ref[0]
    dt = dt_ref[0].astype(jnp.float32)
    dacs = dacs_ref[0].astype(jnp.float32)       # within-chunk cumsum of dA
    bmat = b_ref[0]
    cmat = c_ref[0]

    # CB[h, i, j] = <C_i, B_j> per head
    cb = jax.lax.dot_general(
        cmat.transpose(1, 0, 2), bmat.transpose(1, 0, 2),
        (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)       # (HB, Q, Q)

    # decay L[h, i, j] = exp(dacs_i - dacs_j) for i >= j else 0
    seg = dacs.T[:, :, None] - dacs.T[:, None, :]  # (HB, Q, Q)
    ii = jax.lax.broadcasted_iota(jnp.int32, seg.shape, 1)
    jj = jax.lax.broadcasted_iota(jnp.int32, seg.shape, 2)
    L = jnp.exp(jnp.where(ii >= jj, seg, NEG_INF))

    m = cb * L * dt.T[:, None, :]                 # (HB, Q, Q)
    y = jax.lax.dot_general(
        m.astype(x.dtype), x.transpose(1, 0, 2),
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)       # (HB, Q, hd)
    o_ref[0] = y.transpose(1, 0, 2).astype(o_ref.dtype)


def ssd_intra_kernel(x, dt, dacs, b, c, *, head_block: int = 8,
                     interpret: bool = False):
    """x: (BC, Q, nh, hd); dt/dacs: (BC, Q, nh); b/c: (BC, Q, nh, ds).

    BC = batch·chunks.  Returns the intra-chunk output (BC, Q, nh, hd).
    """
    BC, Q, nh, hd = x.shape
    ds = b.shape[-1]
    hb = min(head_block, nh)
    assert nh % hb == 0
    grid = (BC, nh // hb)
    return pl.pallas_call(
        functools.partial(_ssd_kernel, Q=Q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Q, hb, hd), lambda i, h: (i, 0, h, 0)),
            pl.BlockSpec((1, Q, hb), lambda i, h: (i, 0, h)),
            pl.BlockSpec((1, Q, hb), lambda i, h: (i, 0, h)),
            pl.BlockSpec((1, Q, hb, ds), lambda i, h: (i, 0, h, 0)),
            pl.BlockSpec((1, Q, hb, ds), lambda i, h: (i, 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, Q, hb, hd), lambda i, h: (i, 0, h, 0)),
        out_shape=jax.ShapeDtypeStruct((BC, Q, nh, hd), x.dtype),
        interpret=interpret,
    )(x, dt, dacs, b, c)
