import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input-shape)
cell on the production meshes, and extract the roofline inputs.

For each cell this produces a JSON record with:
  * compiled memory_analysis (bytes per device — proves it fits)
  * compiled cost_analysis (HLO FLOPs / bytes accessed)
  * collective-bytes by op kind, parsed from the optimized HLO
  * MODEL_FLOPS (6·N_active·D) and the analytic executed-FLOPs breakdown

Usage:
  python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/dryrun]
"""
import argparse
import json
import re
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, get_config, input_specs, list_configs
from repro.flops.accounting import model_flops_6nd, step_flops
from repro.launch.mesh import axes_of, make_ctx, make_production_mesh
from repro.launch.sharding import (batch_shardings, opt_state_shardings,
                                   param_shardings)
from repro.models import api as models
from repro.optim import adamw
from repro.train.steps import make_prefill_step, make_serve_step, \
    make_train_step


from repro.launch.hlo_analysis import analyze as analyze_hlo


# ---------------------------------------------------------------------------
# cell construction
# ---------------------------------------------------------------------------
def parallelism_for(cfg, shape, mesh, policy: str = "auto"):
    """(dp_axes, tp_axis) per arch/shape — the §Perf cell-A optimization.

    Small dense models (≤ ~8B params) are communication-bound under 16-way
    TP at 256 chips (measured 424 GiB/device/step of TP-boundary wire on
    granite train_4k); pure DP+FSDP over BOTH mesh axes cuts that ~20x.
    Big / MoE / head-heavy models keep the TP axis.  policy="baseline"
    reproduces the paper-faithful TP16 layout for §Perf before/after.
    """
    dp, tp = axes_of(mesh)
    if policy == "baseline":
        return dp, tp
    from repro.flops.accounting import param_count_analytic
    small = param_count_analytic(cfg) < 8e9
    # ssm/hybrid excluded: their (B,nc,nh,Q,Q) SSD intermediates need the
    # head-sharded TP layout (pure-DP measured 2.5x WORSE memory on zamba2
    # train — §Perf cell C iteration 1, refuted)
    pure_dp_ok = (small and shape.kind == "train"
                  and cfg.family in ("dense", "vlm", "encdec"))
    if pure_dp_ok:
        return dp + (tp,), None
    return dp, tp


def build_cell(arch: str, shape_name: str, mesh, *, fsdp: bool = True,
               opt_cfg: adamw.OptConfig | None = None,
               policy: str = "auto"):
    """Returns (jitted fn, arg ShapeDtypeStructs + shardings) for one cell."""
    from repro.models.common import ShardCtx
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if not cfg.supports_shape(shape):
        return None
    dp, tp = parallelism_for(cfg, shape, mesh, policy)

    # serving mode (§Perf cell B): EP² experts over the full mesh; drop
    # FSDP when the non-expert weights fit TP-sharded + replicated.
    # DECODE ONLY: at prefill token volume the EP² dispatch gathers dwarf
    # the weight gathers it saves (measured 6x worse on v3 prefill_32k).
    serving = policy != "baseline" and shape.kind == "decode"
    ep = None
    if serving:
        from repro.flops.accounting import param_count_analytic
        dense_bytes = (param_count_analytic(cfg, active_only=True) * 2
                       / (mesh.shape[tp] if tp else mesh.size))
        if cfg.num_experts and tp is not None \
                and cfg.num_experts % mesh.size == 0:
            ep = tuple(dp) + (tp,)
        # drop FSDP only when the weights actually fit without it: experts
        # must be EP²-shardable (else they'd replicate over data — measured
        # 238 GiB/dev on v3 decode at 512 chips where 256 % 512 != 0)
        experts_ok = not cfg.num_experts or ep is not None
        fsdp = fsdp and not (dense_bytes < 8e9 and experts_ok)
    ctx = ShardCtx(mesh=mesh, dp=dp, tp=tp, ep=ep)

    aparams = models.abstract_params(cfg)
    p_sh = param_shardings(cfg, aparams, mesh, dp, tp, fsdp,
                           serving=serving)
    b_specs = input_specs(cfg, shape)
    b_sh = batch_shardings(cfg, shape, mesh, dp, tp)

    if shape.kind == "train":
        big = cfg.num_layers * cfg.d_model > 250_000
        if opt_cfg is None:
            opt_cfg = adamw.OptConfig(
                moment_dtype="bfloat16" if big else "float32",
                factored_v=big)
        # gradient accumulation for the giants: activations scale with the
        # microbatch; fp32 grad accumulator is FSDP-sharded
        accum = 4 if big else 1
        aopt = jax.eval_shape(partial(adamw.init, opt_cfg), aparams)
        o_sh = opt_state_shardings(aopt, mesh, dp, tp, fsdp)
        # explicit out_shardings: without them the partitioner may produce
        # REPLICATED grads (all-reduce) instead of reduce-scattering into
        # the FSDP-sharded update (§Perf cell A, iteration 2)
        fn = jax.jit(make_train_step(cfg, opt_cfg, ctx, accum_steps=accum),
                     in_shardings=(p_sh, o_sh, b_sh),
                     out_shardings=(p_sh, o_sh, None),
                     donate_argnums=(0, 1))
        args = (aparams, aopt, b_specs)
    elif shape.kind == "prefill":
        fn = jax.jit(make_prefill_step(cfg, ctx), in_shardings=(p_sh, b_sh))
        args = (aparams, b_specs)
    else:  # decode
        fn = jax.jit(make_serve_step(cfg, ctx), in_shardings=(p_sh, b_sh),
                     donate_argnums=(1,))
        args = (aparams, b_specs)
    return fn, args, cfg, shape


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             fsdp: bool = True, hlo_dir: str | None = None) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    built = build_cell(arch, shape_name, mesh, fsdp=fsdp)
    if built is None:
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": "full-attention arch skips long_500k (DESIGN.md)"}
    fn, args, cfg, shape = built

    t0 = time.time()
    with mesh:
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()

    st = analyze_hlo(hlo, n_dev)
    if hlo_dir:
        os.makedirs(hlo_dir, exist_ok=True)
        tag = f"{arch}_{shape_name}_{'multi' if multi_pod else 'single'}"
        with open(os.path.join(hlo_dir, tag + ".hlo"), "w") as f:
            f.write(hlo)

    analytic = step_flops(cfg, shape, executed=True,
                          remat=(cfg.remat != "none"))
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": n_dev,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "peak_bytes": (getattr(mem, "argument_size_in_bytes", 0)
                           + getattr(mem, "temp_size_in_bytes", 0)),
        },
        # raw XLA cost analysis counts while bodies ONCE (see hlo_analysis)
        "cost_raw": {"flops": cost.get("flops", 0.0),
                     "bytes_accessed": cost.get("bytes accessed", 0.0)},
        # trip-count-aware per-device stats from the optimized HLO text
        "hlo": {"flops": st.flops,
                "traffic_bytes": st.traffic_bytes,
                "collective_bytes": st.collective_bytes,
                "collective_counts": st.collective_counts},
        "model_flops_6nd": model_flops_6nd(cfg, shape),
        "analytic_mxu_flops": analytic.total_mxu,
        "analytic_vpu_flops": analytic.total_vpu,
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--hlo-dir", default=None)
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in list_configs():
            for s in SHAPES:
                cells.append((a, s))
    else:
        cells.append((args.arch, args.shape))

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch}_{shape}_{'multi' if mp else 'single'}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print(f"[skip-cached] {tag}")
                continue
            try:
                rec = run_cell(arch, shape, multi_pod=mp,
                               fsdp=not args.no_fsdp, hlo_dir=args.hlo_dir)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                if rec.get("skipped"):
                    print(f"[skipped ] {tag}: {rec['reason']}")
                else:
                    print(f"[ok      ] {tag}: "
                          f"hlo_flops={rec['hlo']['flops']:.3e} "
                          f"peak_mem={rec['memory']['peak_bytes'] / 2**30:.2f}GiB "
                          f"compile={rec['compile_s']}s")
            except Exception as e:
                failures += 1
                print(f"[FAILED  ] {tag}: {type(e).__name__}: {e}")
                traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
