"""Trip-count-aware analysis of optimized HLO text.

XLA's built-in cost_analysis() counts every `while` body exactly ONCE —
a 40-layer scanned transformer reports ~1/40th of its real FLOPs (verified
empirically; see EXPERIMENTS.md §Dry-run notes).  This module re-derives
trip-aware totals directly from `compiled.as_text()`:

  * segments the module into computations,
  * extracts while trip counts from loop-condition constants,
  * propagates call multiplicities (while/fusion/call/cond/reduce),
  * counts dot FLOPs (result numel × contracting dims), conv FLOPs,
  * estimates HBM traffic (materializing-op result bytes × rw factor),
  * sums collective wire bytes by kind with ring-cost factors.

Everything is per-device (the SPMD module is the per-device program).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8,
                "f8e4m3fn": 1, "f8e5m2": 1, "s16": 2, "u16": 2, "s4": 1,
                "u4": 1, "c64": 8, "token": 0, "opaque": 0}

_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_SHAPE_RE = re.compile(r"\b(\w+)\[([\d,]*)\]")
_WHILE_RE = re.compile(r"condition=%?([\w\.\-]+), body=%?([\w\.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply|to)=%?([\w\.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*(.*)$")


def _shape_bytes(dt: str, dims: str) -> float:
    if dt not in _DTYPE_BYTES:
        return 0.0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def _result_info(rhs: str):
    """(kind, result_bytes, result_numel) from the text after '='."""
    # result type is everything before the op name; handle tuples
    m = re.match(r"\s*(\([^)]*\)|[\w\[\],\{\}:\s]*?)\s*([a-z][\w\-]*)\(", rhs)
    if not m:
        return None, 0.0, 0
    type_str, op = m.group(1), m.group(2)
    total_b = 0.0
    numel = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        b = _shape_bytes(dt, dims)
        total_b += b
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        if b:
            numel = max(numel, n)
    return op, total_b, numel


@dataclass
class Module:
    computations: dict           # name -> [lines]
    entry: str
    shapes: dict                 # value name -> (dtype, [dims])


def parse_module(hlo: str) -> Module:
    comps: dict = {}
    shapes: dict = {}
    entry = None
    cur = None
    for line in hlo.splitlines():
        if line and not line[0].isspace() and line.rstrip().endswith("{"):
            m = _HEADER_RE.match(line)
            if m:
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
                continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        comps[cur].append(line)
        # symbol table: %name = type op(...)
        mm = re.match(r"\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*", line)
        if mm:
            rhs = line.split("=", 1)[1]
            sm = _SHAPE_RE.search(rhs.split("(", 1)[0])
            if sm:
                dims = [int(d) for d in sm.group(2).split(",")] \
                    if sm.group(2) else []
                shapes[mm.group(1)] = (sm.group(1), dims)
    if entry is None and comps:
        entry = list(comps)[-1]
    return Module(comps, entry, shapes)


def _trip_count(mod: Module, cond: str) -> int:
    """Largest integer constant in the loop condition = iteration bound."""
    best = 1
    for line in mod.computations.get(cond, ()):
        for m in re.finditer(r"constant\((\d+)\)", line):
            best = max(best, int(m.group(1)))
    return best


def multiplicities(mod: Module) -> dict:
    """Execution count per computation (entry = 1, while bodies × trip)."""
    mult = {name: 0.0 for name in mod.computations}
    mult[mod.entry] = 1.0
    order = [mod.entry]
    seen = {mod.entry}
    # BFS over call edges, accumulating multiplicity (DAG-ish; HLO has no
    # recursion, but shared computations accumulate from multiple callers)
    idx = 0
    while idx < len(order):
        name = order[idx]
        idx += 1
        m = mult[name]
        for line in mod.computations.get(name, ()):
            wm = _WHILE_RE.search(line)
            if wm and "while(" in line:
                cond, body = wm.group(1), wm.group(2)
                t = _trip_count(mod, cond)
                for tgt, k in ((body, m * t), (cond, m * (t + 1))):
                    if tgt in mult:
                        mult[tgt] += k
                        if tgt not in seen:
                            seen.add(tgt)
                            order.append(tgt)
                continue
            bm = _BRANCH_RE.search(line)
            if bm:
                for tgt in re.findall(r"%?([\w\.\-]+)", bm.group(1)):
                    if tgt in mult:
                        mult[tgt] += m
                        if tgt not in seen:
                            seen.add(tgt)
                            order.append(tgt)
                continue
            cm = _CALLS_RE.search(line)
            if cm and cm.group(1) in mult:
                tgt = cm.group(1)
                mult[tgt] += m
                if tgt not in seen:
                    seen.add(tgt)
                    order.append(tgt)
    return mult


# ---------------------------------------------------------------------------
# FLOPs
# ---------------------------------------------------------------------------
def _dot_flops(mod: Module, line: str, numel: int) -> float:
    cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    ops = re.search(r"\bdot\(\s*%?([\w\.\-]+)", line)
    k = 1
    if cdims and ops and ops.group(1) in mod.shapes:
        _, lshape = mod.shapes[ops.group(1)]
        for d in (cdims.group(1).split(",") if cdims.group(1) else []):
            di = int(d)
            if di < len(lshape):
                k *= lshape[di]
    return 2.0 * numel * k


def _conv_flops(mod: Module, line: str, numel: int) -> float:
    m = re.search(r"convolution\(\s*%?([\w\.\-]+)\s*,\s*%?([\w\.\-]+)", line)
    if m and m.group(2) in mod.shapes:
        _, kshape = mod.shapes[m.group(2)]
        kn = 1
        for d in kshape:
            kn *= d
        out_ch = kshape[-1] if kshape else 1
        # per output element: kernel_numel / out_channels MACs
        return 2.0 * numel * kn / max(out_ch, 1)
    return 2.0 * numel


# ops whose results are materialized buffers (HBM traffic estimate)
_TRAFFIC_OPS = {"fusion", "dot", "convolution", "copy", "all-gather",
                "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "dynamic-slice", "dynamic-update-slice",
                "gather", "scatter", "reduce", "transpose", "broadcast",
                "concatenate", "slice", "pad", "select-and-scatter", "sort",
                "all-gather-start", "all-reduce-start", "iota",
                "collective-permute-start", "reduce-scatter-start"}

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _group_size(line: str, default: int) -> int:
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    return default


@dataclass
class HloStats:
    flops: float = 0.0
    traffic_bytes: float = 0.0
    collective_bytes: dict = field(default_factory=dict)
    collective_counts: dict = field(default_factory=dict)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def _fusion_bodies(mod: Module) -> set:
    """Computations called as fusion kernels (and reduce/scatter appliers):
    their internal ops never touch HBM — only the fusion op's operands and
    result do, and those are counted at the call site."""
    out = set()
    for lines in mod.computations.values():
        for line in lines:
            if re.search(r"\bfusion\(", line) or "to_apply=" in line:
                m = _CALLS_RE.search(line)
                if m:
                    out.add(m.group(1))
    return out


def analyze(hlo: str, n_devices: int) -> HloStats:
    mod = parse_module(hlo)
    mult = multiplicities(mod)
    fusion_bodies = _fusion_bodies(mod)
    st = HloStats(collective_bytes=dict.fromkeys(_COLL_KINDS, 0.0),
                  collective_counts=dict.fromkeys(_COLL_KINDS, 0.0))
    for name, lines in mod.computations.items():
        m = mult.get(name, 0.0)
        if m <= 0:
            continue
        in_fusion = name in fusion_bodies
        for line in lines:
            om = _OP_RE.match(line)
            if not om:
                continue
            op, rbytes, numel = _result_info(om.group(1))
            if op is None:
                continue
            base = op[:-6] if op.endswith("-start") else op
            if op == "dot":
                st.flops += m * _dot_flops(mod, line, numel)
            elif op == "convolution":
                st.flops += m * _conv_flops(mod, line, numel)
            if op in _TRAFFIC_OPS and not in_fusion:
                # result write + (approx) operand read of equal size
                st.traffic_bytes += m * rbytes * 2.0
            if base in _COLL_KINDS:
                g = _group_size(line, n_devices)
                frac = (g - 1) / g if g > 1 else 0.0
                wire = rbytes * (2 * frac if base == "all-reduce" else
                                 (1.0 if base == "collective-permute"
                                  else frac))
                st.collective_bytes[base] += m * wire
                st.collective_counts[base] += m
    return st
