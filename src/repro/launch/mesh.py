"""Production mesh construction.

Single pod : (data=16, model=16)            = 256 chips (v5e pod)
Multi-pod  : (pod=2, data=16, model=16)     = 512 chips

Defined as functions so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax

from repro.models.common import ShardCtx


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def axes_of(mesh) -> tuple[tuple[str, ...], str]:
    """(dp_axes, tp_axis) for a production mesh."""
    names = mesh.axis_names
    if "pod" in names:
        return ("pod", "data"), "model"
    return ("data",), "model"


def make_ctx(mesh) -> ShardCtx:
    dp, tp = axes_of(mesh)
    return ShardCtx(mesh=mesh, dp=dp, tp=tp)


def make_smoke_mesh(n: int = 1):
    """Tiny mesh over however many local devices exist (tests)."""
    devs = jax.devices()[:n]
    return jax.sharding.Mesh(
        __import__("numpy").array(devs).reshape(1, len(devs)),
        ("data", "model"))
