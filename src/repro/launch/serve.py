"""Serving driver: batched greedy decode with KV/SSM caches.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m --smoke \
      --tokens 32 --batch 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SHAPES, ShapeSpec, cache_specs, get_config
from repro.models import api as models
from repro.train.steps import make_serve_step


def init_caches(cfg, B, S):
    specs = cache_specs(cfg, B, S, jnp.dtype(cfg.dtype))
    return {k: jnp.zeros(v.shape, v.dtype) for k, v in specs.items()}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--ctx-len", type=int, default=64)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()

    B, S = args.batch, args.ctx_len
    params = models.init_params(cfg, jax.random.key(0))
    serve = jax.jit(make_serve_step(cfg), donate_argnums=(1,))

    batch = {"tokens": jnp.zeros((B, 1), jnp.int32),
             "cache_index": jnp.asarray(0, jnp.int32)}
    batch.update(init_caches(cfg, B, S))
    if cfg.family == "encdec":
        batch["encoder_out"] = jnp.zeros((B, cfg.encoder_seq, cfg.d_model),
                                         jnp.dtype(cfg.dtype))

    toks = []
    t0 = time.perf_counter()
    for i in range(args.tokens):
        nxt, caches = serve(params, batch)
        toks.append(np.asarray(nxt)[:, 0])
        batch = {"tokens": nxt.astype(jnp.int32),
                 "cache_index": jnp.asarray(i + 1, jnp.int32), **caches}
        if cfg.family == "encdec":
            batch["encoder_out"] = jnp.zeros(
                (B, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype))
    dt = time.perf_counter() - t0
    print(f"decoded {args.tokens} tokens x {B} seqs in {dt:.2f}s "
          f"({args.tokens * B / dt:.1f} tok/s)")
    print("sample:", np.stack(toks, 1)[0][:16])


if __name__ == "__main__":
    main()
