"""Sharding rules: parameter + optimizer-state + input PartitionSpecs.

Scheme (DESIGN.md §4):
  TP  — "model" axis: column-parallel in-projections (last dim), row-parallel
        out-projections (contracting dim), expert-parallel MoE (expert dim),
        vocab-parallel embeddings/head.
  FSDP— params/optimizer additionally sharded over the data axes (ZeRO-3);
        XLA all-gathers weights per scan step and reduce-scatters grads.
  All rules are divisibility-guarded: a dim that doesn't divide its axis
  stays replicated (e.g. whisper's 12 heads on a 16-way model axis).
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec, input_specs
from repro.models.common import ShardCtx

# leaf names -> parallelism class
_COL = {"wq", "wk", "wv", "wi", "wg", "wq_b", "wkv_b", "wkv_a", "wq_a",
        "in_proj", "router", "lm_head", "proj", "mm_connector"}
_ROW = {"wo", "out_proj"}


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    return int(np.prod([mesh.shape[a] for a in axes]))


def _fit(dim: int, mesh: Mesh, axes):
    """Return axes if dim divides their product, else a divisible fallback."""
    if axes is None:
        return None
    if not isinstance(axes, str) and len(axes) == 1:
        axes = axes[0]   # canonical singleton: ('data',) == 'data' sharding
    if dim % _axis_size(mesh, axes) == 0:
        return axes
    if not isinstance(axes, str) and len(axes) > 1:
        # try the trailing axis alone (e.g. "data" without "pod")
        if dim % mesh.shape[axes[-1]] == 0:
            return axes[-1]
    return None


_WRAPPERS = {"mu", "m", "v", "row", "col", "count", "p", "s", "c"}


def param_spec(path: str, shape: tuple, mesh: Mesh, dp, tp: str,
               fsdp: bool = True, serving: bool = False) -> P:
    """PartitionSpec for one parameter leaf, by path naming convention.

    Optimizer-state paths (…['wq']['m'], …['wi']['v']['row']) inherit the
    underlying parameter's rule: wrapper keys are stripped before matching.

    serving=True (§Perf cell B): experts go expert-parallel over the FULL
    mesh (dp×tp — e.g. 256-way, one DeepSeek-V3 expert per chip) and no
    FSDP gathers happen per decode step; pass fsdp=True only when the
    non-expert weights don't fit TP-sharded-replicated.
    """
    import re
    keys = [k for k in re.findall(r"\['([^']+)'\]", path)
            if k not in _WRAPPERS]
    name = keys[-1] if keys else path
    nd = len(shape)
    spec = [None] * nd
    dp_ax = dp if fsdp else None

    def set_ax(i, axes):
        if i < 0 or i >= nd:
            return  # factored moments drop dims; skip out-of-range rules
        a = _fit(shape[i], mesh, axes)
        if a is not None:
            spec[i] = a

    if "experts" in path and nd >= 3:
        # (L, E, in, out): expert-parallel over tp (train) or the whole
        # mesh (serving EP², §Perf cell B)
        ep_axes = (tuple(dp) + (tp,) if (serving and tp is not None)
                   else tp)
        set_ax(nd - 3, ep_axes)
        if name in _ROW:
            set_ax(nd - 1, dp_ax)
        else:
            set_ax(nd - 2, dp_ax)
    elif name == "embed":
        set_ax(0, tp)       # vocab-parallel
        set_ax(1, dp_ax)
    elif name == "conv_w":
        set_ax(nd - 1, tp)
    elif name in _COL and nd >= 2:
        set_ax(nd - 1, tp)
        set_ax(nd - 2, dp_ax)
    elif name in _ROW and nd >= 2:
        set_ax(nd - 2, tp)
        set_ax(nd - 1, dp_ax)
    elif nd >= 2 and shape[-1] >= 1024:
        set_ax(nd - 1, dp_ax)  # misc large matrices: FSDP only
    return P(*spec)


def param_shardings(cfg: ModelConfig, abstract_params, mesh: Mesh, dp,
                    tp: str, fsdp: bool = True, serving: bool = False):
    """NamedSharding tree matching the params tree."""
    def leaf(path, x):
        ps = jax.tree_util.keystr(path)
        return NamedSharding(mesh, param_spec(ps, x.shape, mesh, dp, tp,
                                              fsdp, serving))

    return jax.tree_util.tree_map_with_path(leaf, abstract_params)


def opt_state_shardings(opt_abstract, mesh: Mesh, dp, tp: str,
                        fsdp: bool = True):
    """Optimizer state inherits param shardings (wrapper keys stripped;
    divisibility-guarded for factored moments whose shapes drop a dim)."""
    def leaf(path, x):
        ps = jax.tree_util.keystr(path)
        return NamedSharding(mesh,
                             param_spec(ps, x.shape, mesh, dp, tp, fsdp))

    return jax.tree_util.tree_map_with_path(leaf, opt_abstract)


def batch_shardings(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh, dp,
                    tp: str) -> dict:
    """NamedShardings for every input in input_specs(cfg, shape)."""
    specs = input_specs(cfg, shape)
    B = shape.global_batch
    dp_fit = dp if B % _axis_size(mesh, dp) == 0 else None
    tp_size = mesh.shape[tp] if tp is not None else 0
    out = {}
    for k, s in specs.items():
        nd = len(s.shape)
        if k == "cache_index":
            out[k] = NamedSharding(mesh, P())
        elif k in ("tokens", "labels"):
            out[k] = NamedSharding(mesh, P(dp_fit, *([None] * (nd - 1))))
        elif k in ("patch_embeds", "frame_embeds", "encoder_out"):
            out[k] = NamedSharding(mesh, P(dp_fit, None, None))
        elif k in ("k_cache", "v_cache"):
            # (L, B, S, KV, hd): heads over tp when divisible, else seq
            KV = s.shape[3]
            if tp is not None and KV % tp_size == 0:
                sp = P(None, dp_fit, None if dp_fit else dp_seq(mesh, dp, s),
                       tp, None)
            else:
                sp = P(None, dp_fit, tp, None, None)
            out[k] = NamedSharding(mesh, sp)
        elif k == "kv_cache":  # MLA latent (L, B, S, D)
            out[k] = NamedSharding(mesh, P(None, dp_fit, tp, None))
        elif k == "ssm_state":  # (L, B, nh, hd, ds)
            nh = s.shape[2]
            sp = P(None, dp_fit,
                   tp if tp and nh % tp_size == 0 else None,
                   None, None)
            out[k] = NamedSharding(mesh, sp)
        elif k == "conv_state":  # (L, B, W-1, conv_dim)
            cd = s.shape[3]
            sp = P(None, dp_fit, None,
                   tp if tp and cd % tp_size == 0 else None)
            out[k] = NamedSharding(mesh, sp)
        else:
            out[k] = NamedSharding(mesh, P(*([None] * nd)))
    return out


def dp_seq(mesh, dp, s):
    """Shard cache sequence over the idle data axes when batch can't use
    them (single-stream long-context decode)."""
    S = s.shape[2]
    return dp if S % _axis_size(mesh, dp) == 0 else None
