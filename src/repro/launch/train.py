"""Training driver.

  PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
      --smoke --steps 50 --ckpt-dir /tmp/ck

--smoke runs the reduced same-family config on CPU; the full config is the
production path (requires the real mesh).  Either way the loop exercises
checkpoint/restart, the deterministic data stream, and OFU monitoring.
"""
from __future__ import annotations

import argparse
import json

from repro.configs.base import SHAPES, ShapeSpec, get_config
from repro.flops.accounting import step_flops
from repro.optim import adamw
from repro.train.trainer import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
        shape = ShapeSpec("smoke", args.seq, args.batch, "train")
    else:
        shape = SHAPES[args.shape]

    fl = step_flops(cfg, shape, executed=True).total
    trainer = Trainer(
        cfg, shape,
        opt_cfg=adamw.OptConfig(warmup_steps=5, decay_steps=args.steps),
        train_cfg=TrainConfig(total_steps=args.steps,
                              ckpt_every=args.ckpt_every,
                              ckpt_dir=args.ckpt_dir),
        flops_per_step=fl)
    out = trainer.run()
    print(json.dumps(out, indent=1, default=float))


if __name__ == "__main__":
    main()
