from repro.models.api import (  # noqa: F401
    abstract_params, decode_step, forward, init_params, param_count,
)
