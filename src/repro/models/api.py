"""Unified model API: init / forward / decode_step dispatched by family."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, ssm_models, transformer
from repro.models.common import ShardCtx


def _mod(cfg: ModelConfig):
    if cfg.family in ("dense", "moe", "mla_moe", "vlm"):
        return transformer
    if cfg.family in ("ssm", "hybrid"):
        return ssm_models
    if cfg.family == "encdec":
        return encdec
    raise ValueError(cfg.family)


def init_params(cfg: ModelConfig, key, dtype=None):
    return _mod(cfg).init_params(cfg, key, dtype)


def abstract_params(cfg: ModelConfig, dtype=None):
    """Param ShapeDtypeStructs without allocation (dry-run path)."""
    return jax.eval_shape(
        lambda k: init_params(cfg, k, dtype), jax.random.key(0))


def forward(cfg: ModelConfig, params, batch,
            ctx: Optional[ShardCtx] = None, **kw):
    return _mod(cfg).forward(cfg, params, batch, ctx, **kw)


def decode_step(cfg: ModelConfig, params, batch,
                ctx: Optional[ShardCtx] = None):
    return _mod(cfg).decode_step(cfg, params, batch, ctx)


def param_count(params) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))
