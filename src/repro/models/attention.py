"""Attention layers: GQA (dense/moe/vlm/encdec/hybrid) and MLA (deepseek-v3).

Each layer exposes:
  init(key, cfg)                         -> params (unstacked; callers vmap)
  apply(cfg, p, x, ...)                  -> full-sequence forward
  decode(cfg, p, x, caches, idx, ...)    -> single-token forward + cache update
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import (ShardCtx, apply_rope, constrain,
                                 decode_attention, dense_init,
                                 flash_attention, head_shardable, rms_norm)


# ===========================================================================
# GQA
# ===========================================================================
def gqa_init(key, cfg: ModelConfig, dtype):
    H, KV, hd, d = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.d_model
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], (d, H * hd), dtype),
        "wk": dense_init(ks[1], (d, KV * hd), dtype),
        "wv": dense_init(ks[2], (d, KV * hd), dtype),
        "wo": dense_init(ks[3], (H * hd, d), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _qkv(cfg: ModelConfig, p, x, positions, ctx):
    B, S, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (x @ p["wk"]).reshape(B, S, KV, hd)
    v = (x @ p["wv"]).reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if head_shardable(H, ctx):
        q = constrain(q, ctx, "dp", None, "tp", None)
    if head_shardable(KV, ctx):
        k = constrain(k, ctx, "dp", None, "tp", None)
        v = constrain(v, ctx, "dp", None, "tp", None)
    return q, k, v


def gqa_apply(cfg: ModelConfig, p, x, *, positions, causal: bool,
              ctx: Optional[ShardCtx], kv_override=None):
    """Full-sequence attention.  kv_override: (k, v) for cross-attention."""
    B, S, _ = x.shape
    q, k, v = _qkv(cfg, p, x, positions, ctx)
    if kv_override is not None:
        k, v = kv_override
    o = flash_attention(q, k, v, causal=causal, ctx=ctx)
    o = o.reshape(B, S, cfg.num_heads * cfg.head_dim)
    out = o @ p["wo"]
    return constrain(out, ctx, "dp", "tp", None)


def gqa_decode(cfg: ModelConfig, p, x, k_cache, v_cache, cache_index, *,
               ctx: Optional[ShardCtx], cross: bool = False,
               kv_override=None):
    """x: (B, 1, d); caches: (B, S, KV, hd).  Returns (out, k_cache, v_cache)."""
    B = x.shape[0]
    positions = jnp.full((1,), cache_index, jnp.int32)
    q, k_new, v_new = _qkv(cfg, p, x, positions, ctx)
    if cross:
        # cross-attention: static KV from the encoder, no cache update
        k, v = kv_override
        o = flash_attention(q, k, v, causal=False, ctx=ctx)
    else:
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k_new.astype(k_cache.dtype), (0, cache_index, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v_new.astype(v_cache.dtype), (0, cache_index, 0, 0))
        o = decode_attention(q, k_cache, v_cache, cache_index)
    out = o.reshape(B, 1, cfg.num_heads * cfg.head_dim) @ p["wo"]
    return constrain(out, ctx, "dp", None, None), k_cache, v_cache


# ===========================================================================
# MLA (multi-head latent attention, deepseek-v3)
#
# q: d -> q_lora -> H*(nope+rope); kv: d -> (kv_lora + rope_shared);
# decode cache stores only the compressed latent + shared rope key.
# ===========================================================================
def mla_init(key, cfg: ModelConfig, dtype):
    d, H = cfg.d_model, cfg.num_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq_a": dense_init(ks[0], (d, qr), dtype),
        "q_norm": jnp.ones((qr,), dtype),
        "wq_b": dense_init(ks[1], (qr, H * (dn + dr)), dtype),
        "wkv_a": dense_init(ks[2], (d, kvr + dr), dtype),
        "kv_norm": jnp.ones((kvr,), dtype),
        "wkv_b": dense_init(ks[3], (kvr, H * (dn + dv)), dtype),
        "wo": dense_init(ks[4], (H * dv, d), dtype),
    }


def _mla_q(cfg, p, x, positions, ctx):
    B, S, _ = x.shape
    H = cfg.num_heads
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    q = rms_norm(x @ p["wq_a"], p["q_norm"], cfg.norm_eps) @ p["wq_b"]
    q = q.reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    q = jnp.concatenate([q_nope, q_rope], -1)
    if head_shardable(H, ctx):
        q = constrain(q, ctx, "dp", None, "tp", None)
    return q


def _mla_kv_from_latent(cfg, p, latent, ctx):
    """latent: (B, S, kv_lora + rope) -> per-head k (nope+rope), v."""
    B, S, _ = latent.shape
    H = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    c_kv, k_rope = latent[..., :cfg.kv_lora_rank], latent[..., cfg.kv_lora_rank:]
    kv = rms_norm(c_kv, p["kv_norm"], cfg.norm_eps) @ p["wkv_b"]
    kv = kv.reshape(B, S, H, dn + dv)
    k_nope, v = kv[..., :dn], kv[..., dn:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, dr))], -1)
    if head_shardable(H, ctx):
        k = constrain(k, ctx, "dp", None, "tp", None)
        v = constrain(v, ctx, "dp", None, "tp", None)
    return k, v


def mla_apply(cfg: ModelConfig, p, x, *, positions, causal: bool,
              ctx: Optional[ShardCtx]):
    B, S, _ = x.shape
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    q = _mla_q(cfg, p, x, positions, ctx)
    latent = x @ p["wkv_a"]  # (B, S, kv_lora + rope)
    k_rope = apply_rope(latent[..., cfg.kv_lora_rank:][:, :, None, :],
                        positions, cfg.rope_theta)[:, :, 0, :]
    latent = jnp.concatenate([latent[..., :cfg.kv_lora_rank], k_rope], -1)
    k, v = _mla_kv_from_latent(cfg, p, latent, ctx)
    o = flash_attention(q, k, v, causal=causal, scale=(dn + dr) ** -0.5,
                        ctx=ctx)
    out = o.reshape(B, S, cfg.num_heads * cfg.v_head_dim) @ p["wo"]
    return constrain(out, ctx, "dp", "tp", None)


def mla_decode(cfg: ModelConfig, p, x, kv_cache, cache_index, *,
               ctx: Optional[ShardCtx]):
    """Absorbed MLA decode against the compressed latent cache.

    kv_cache: (B, S, kv_lora + rope) holding the *normalized* latent plus the
    shared roped key.  Per-head K/V are never expanded over S: wkv_b is
    absorbed into the query (scores) and the output (values), so attention
    runs directly in latent space — the whole point of MLA serving.
    """
    B = x.shape[0]
    H = cfg.num_heads
    dn, dr, dv, kvr = (cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim,
                       cfg.kv_lora_rank)
    positions = jnp.full((1,), cache_index, jnp.int32)
    q = _mla_q(cfg, p, x, positions, ctx)  # (B, 1, H, dn+dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]

    latent = x @ p["wkv_a"]
    c_kv = rms_norm(latent[..., :kvr], p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(latent[..., kvr:][:, :, None, :],
                        positions, cfg.rope_theta)[:, :, 0, :]
    new_entry = jnp.concatenate([c_kv, k_rope], -1)
    kv_cache = jax.lax.dynamic_update_slice(
        kv_cache, new_entry.astype(kv_cache.dtype), (0, cache_index, 0))
    cached_c = kv_cache[..., :kvr]      # (B, S, kvr)
    cached_r = kv_cache[..., kvr:]      # (B, S, dr)

    w_kv = p["wkv_b"].reshape(kvr, H, dn + dv)
    w_k, w_v = w_kv[..., :dn], w_kv[..., dn:]
    # absorb w_k into the query: (B,H,kvr)
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], w_k,
                       preferred_element_type=jnp.float32)
    s = (jnp.einsum("bhr,bsr->bhs", q_lat,
                    cached_c.astype(jnp.float32)) +
         jnp.einsum("bhd,bsd->bhs", q_rope[:, 0].astype(jnp.float32),
                    cached_r.astype(jnp.float32))) * (dn + dr) ** -0.5
    S = kv_cache.shape[1]
    valid = jnp.arange(S) <= cache_index
    s = jnp.where(valid[None, None], s, -jnp.inf)
    prob = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhs,bsr->bhr", prob, cached_c.astype(jnp.float32))
    o = jnp.einsum("bhr,rhd->bhd", o_lat, w_v.astype(jnp.float32))
    out = o.reshape(B, 1, H * dv).astype(x.dtype) @ p["wo"]
    return constrain(out, ctx, "dp", None, None), kv_cache
