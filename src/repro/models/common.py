"""Shared model building blocks: norms, rope, activations, flash attention (jnp),
sharding-constraint plumbing, and parameter init helpers.

All forward code is pure-functional JAX.  Sharding is expressed through an
optional `ShardCtx`; when it is None every constraint is a no-op so the same
code runs un-meshed on CPU smoke tests and fully sharded in the dry-run.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------------------
# sharding context
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShardCtx:
    """Carries the mesh + logical axis bindings into pure model code.

    dp : axis name(s) carrying the batch (e.g. ("pod", "data") multi-pod)
    tp : tensor-parallel axis name ("model"), or None when the model runs
         pure-DP/FSDP (small dense models where TP boundary collectives
         would dominate — see EXPERIMENTS.md §Perf cell A)
    """

    mesh: Mesh
    dp: tuple[str, ...]
    tp: Optional[str]
    # expert-parallel axes; None -> tp.  Serving uses the FULL mesh (EP²,
    # e.g. one DeepSeek-V3 expert per chip) — see §Perf cell B.
    ep: Optional[tuple] = None

    @property
    def ep_axes(self):
        return self.ep if self.ep is not None else self.tp

    @property
    def ep_covers_dp(self) -> bool:
        if self.ep is None:
            return False
        return any(a in self.ep for a in self.dp)

    def spec(self, *axes) -> NamedSharding:
        def resolve(a):
            if a == "dp":
                return self.dp
            if a == "tp":
                return self.tp
            if a == "ep":
                return self.ep_axes
            return a
        return NamedSharding(self.mesh, P(*(resolve(a) for a in axes)))


def constrain(x: jax.Array, ctx: Optional[ShardCtx], *axes) -> jax.Array:
    """with_sharding_constraint that degrades to a no-op without a mesh.

    `axes` uses logical names: "dp" (batch), "tp" (model), None (replicated).
    """
    if ctx is None:
        return x
    return jax.lax.with_sharding_constraint(x, ctx.spec(*axes))


def head_shardable(n: int, ctx: Optional[ShardCtx]) -> bool:
    """True if a head-count dimension divides the tensor-parallel axis size."""
    if ctx is None or ctx.tp is None:
        return False
    return n % ctx.mesh.shape[ctx.tp] == 0


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm with fp32 *statistics* but bf16 tensor math.

    Upcasting the whole tensor (x.astype(f32) * rsqrt * scale) makes every
    downstream TP-boundary collective and its cotangent fp32 — measured
    ~2x wire bytes on the 81-layer hybrid (§Perf cell C).  Only the
    variance reduction needs fp32.
    """
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1,
                   keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * scale.astype(x.dtype)


def activation_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu2":  # squared ReLU (nemotron)
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(name)


def gated(name: str) -> bool:
    """Gated (SwiGLU-style) MLPs use wi+wg; relu2/gelu archs use a plain wi."""
    return name == "silu"


# ---------------------------------------------------------------------------
# rotary embeddings (llama-style rotate-half)
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: (..., S) or (S,)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, hd/2)
    angles = angles[..., None, :]  # broadcast over heads
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# flash attention, pure-jnp (blockwise online softmax, no S×S materialization)
#
# This is the dry-run / CPU path.  The Pallas kernel in repro.kernels is the
# TPU fast path and is validated against repro.kernels.ref which shares this
# math.
# ---------------------------------------------------------------------------
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool, q_offset=0,
                    block: int = 512, scale: Optional[float] = None,
                    kv_len: Optional[jax.Array] = None,
                    ctx: Optional[ShardCtx] = None) -> jax.Array:
    """q: (B, Sq, H, hd); k/v: (B, Sk, KV, hd) with H % KV == 0.

    q_offset: global position of q[:, 0] (for causal masking during decode /
    chunked prefill).  kv_len: optional valid-length of k/v (decode caches).
    Returns (B, Sq, H, hd_v).

    Layout note: internally runs head-major (B, H, S, hd) with GQA KV heads
    repeated, and pins every scan carry to the head-sharded layout — without
    the explicit constraints XLA's SPMD partitioner oscillates between
    head- and sequence-sharded layouts across the online-softmax carries and
    inserts "involuntary full rematerialization" collectives (measured:
    ~160 GB/device of phantom all-gathers on a 2.5B dense model).
    """
    B, Sq, H, hd = q.shape
    _, Sk, KV, hd_v = v.shape
    G = H // KV
    scale = hd ** -0.5 if scale is None else scale

    shard_heads = head_shardable(H, ctx)

    def pin(x):  # (B, H, ...) head-sharded pin
        if not shard_heads:
            return x
        return constrain(x, ctx, *(("dp", "tp") + (None,) * (x.ndim - 2)))

    qh = pin(q.transpose(0, 2, 1, 3))                      # (B, H, Sq, hd)
    if G > 1:  # repeat KV heads -> clean head sharding
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    kh = pin(k.transpose(0, 2, 1, 3))                      # (B, H, Sk, hd)
    vh = pin(v.transpose(0, 2, 1, 3))

    block = min(block, Sk)
    nb = -(-Sk // block)
    pad = nb * block - Sk
    if pad:
        kh = jnp.pad(kh, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vh = jnp.pad(vh, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kb = jnp.moveaxis(kh.reshape(B, H, nb, block, hd), 2, 0)
    vb = jnp.moveaxis(vh.reshape(B, H, nb, block, hd_v), 2, 0)

    q_pos = q_offset + jnp.arange(Sq)

    def body(carry, inputs):
        m, l, acc = carry
        kj, vj, j = inputs
        s = pin(jnp.einsum("bhqd,bhjd->bhqj", qh, kj,
                           preferred_element_type=jnp.float32) * scale)
        kv_pos = j * block + jnp.arange(block)
        mask = jnp.ones((Sq, block), bool)
        if causal:
            mask &= q_pos[:, None] >= kv_pos[None, :]
        if kv_len is not None:
            mask &= kv_pos[None, :] < kv_len
        if pad:
            mask &= kv_pos[None, :] < Sk
        s = jnp.where(mask[None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(-1))
        # guard fully-masked rows (m_new == -inf)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        # no second mask on p: exp(-inf - finite) is already 0, and each
        # avoided (B,H,Sq,block) write is ~160 GiB/step on a 40-layer train
        p = jnp.exp(s - m_safe[..., None])
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = pin(l * corr + p.sum(-1))
        acc_new = pin(acc * corr[..., None] + jnp.einsum(
            "bhqj,bhjd->bhqd", p.astype(vj.dtype), vj,
            preferred_element_type=jnp.float32))
        return (pin(m_new), l_new, acc_new), None

    m0 = pin(jnp.full((B, H, Sq), -jnp.inf, jnp.float32))
    l0 = pin(jnp.zeros((B, H, Sq), jnp.float32))
    a0 = pin(jnp.zeros((B, H, Sq, hd_v), jnp.float32))
    # remat per kv-block: without this the backward pass saves the (Sq ×
    # block) probability tensor for EVERY iteration (flash-bwd recomputes
    # them blockwise instead — that is the whole point of flash attention)
    body = jax.checkpoint(body,
                          policy=jax.checkpoint_policies.nothing_saveable)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kb, vb, jnp.arange(nb)))
    out = acc / jnp.maximum(l, 1e-37)[..., None]           # (B, H, Sq, hd_v)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_index: jax.Array, *,
                     scale: Optional[float] = None) -> jax.Array:
    """Single-position attention against a (possibly longer) cache.

    q: (B, 1, H, hd); caches: (B, S, KV, hd).  Positions > cache_index masked.
    """
    B, _, H, hd = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    scale = hd ** -0.5 if scale is None else scale
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgd,bjkd->bkgj", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    valid = jnp.arange(S) <= cache_index
    s = jnp.where(valid[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgj,bjkd->bkgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, H, v_cache.shape[-1]).astype(q.dtype)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def dense_init(key, shape, dtype, scale: float = 1.0):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def stack_init(key, n: int, init_fn):
    """vmap an init over a leading layer dimension."""
    return jax.vmap(init_fn)(jax.random.split(key, n))
