"""Encoder-decoder model (whisper-small).

The conv/mel frontend is a STUB per the assignment: `input_specs()` provides
precomputed frame embeddings (B, encoder_seq, d_model).  Rope is used in
place of whisper's learned positions (noted in DESIGN.md) — the systems
behavior (shapes, FLOPs, sharding) is unchanged.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models.common import (ShardCtx, constrain, dense_init,
                                 flash_attention, head_shardable, rms_norm)
from repro.models.transformer import _remat, _sp, lm_logits


# ---------------------------------------------------------------------------
# cross attention (no rope; kv from encoder output)
# ---------------------------------------------------------------------------
def cross_init(key, cfg: ModelConfig, dtype):
    H, KV, hd, d = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.d_model
    ks = jax.random.split(key, 4)
    return {"wq": dense_init(ks[0], (d, H * hd), dtype),
            "wk": dense_init(ks[1], (d, KV * hd), dtype),
            "wv": dense_init(ks[2], (d, KV * hd), dtype),
            "wo": dense_init(ks[3], (H * hd, d), dtype)}


def cross_apply(cfg: ModelConfig, p, x, enc_out, ctx):
    B, S, _ = x.shape
    Se = enc_out.shape[1]
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (enc_out @ p["wk"]).reshape(B, Se, KV, hd)
    v = (enc_out @ p["wv"]).reshape(B, Se, KV, hd)
    if head_shardable(H, ctx):
        q = constrain(q, ctx, "dp", None, "tp", None)
    o = flash_attention(q, k, v, causal=False, ctx=ctx)
    out = o.reshape(B, S, H * hd) @ p["wo"]
    return constrain(out, ctx, "dp", None, None)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _enc_block_init(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    d = cfg.d_model
    return {"attn": attn.gqa_init(ks[0], cfg, dtype),
            "mlp": moe_mod.mlp_init(ks[1], cfg, dtype),
            "norm1": jnp.ones((d,), dtype), "norm2": jnp.ones((d,), dtype)}


def _dec_block_init(key, cfg, dtype):
    ks = jax.random.split(key, 3)
    d = cfg.d_model
    p = _enc_block_init(key, cfg, dtype)
    p["cross"] = cross_init(ks[2], cfg, dtype)
    p["norm3"] = jnp.ones((d,), dtype)
    return p


def init_params(cfg: ModelConfig, key, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    d, V = cfg.d_model, cfg.vocab_size
    return {
        "embed": (jax.random.normal(ks[0], (V, d), jnp.float32) * 0.02
                  ).astype(dtype),
        "enc_layers": jax.vmap(lambda k: _enc_block_init(k, cfg, dtype))(
            jax.random.split(ks[1], cfg.encoder_layers)),
        "dec_layers": jax.vmap(lambda k: _dec_block_init(k, cfg, dtype))(
            jax.random.split(ks[2], cfg.num_layers)),
        "enc_norm": jnp.ones((d,), dtype),
        "final_norm": jnp.ones((d,), dtype),
        "lm_head": dense_init(ks[3], (d, V), dtype),
    }


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------
def encode(cfg: ModelConfig, params, frame_embeds, ctx):
    x = _sp(frame_embeds.astype(jnp.dtype(cfg.dtype)), ctx)
    positions = jnp.arange(x.shape[1])

    def body(carry, p):
        h = rms_norm(carry, p["norm1"], cfg.norm_eps)
        a = attn.gqa_apply(cfg, p["attn"], h, positions=positions,
                           causal=False, ctx=ctx)
        x2 = _sp(carry + a, ctx)
        h = rms_norm(x2, p["norm2"], cfg.norm_eps)
        return _sp(x2 + moe_mod.mlp_apply(cfg, p["mlp"], h, ctx), ctx), None

    body = _remat(body, cfg)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _dec_block(cfg, p, x, enc_out, positions, ctx):
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    a = attn.gqa_apply(cfg, p["attn"], h, positions=positions, causal=True,
                       ctx=ctx)
    x = _sp(x + a, ctx)
    h = rms_norm(x, p["norm3"], cfg.norm_eps)
    x = _sp(x + cross_apply(cfg, p["cross"], h, enc_out, ctx), ctx)
    h = rms_norm(x, p["norm2"], cfg.norm_eps)
    return _sp(x + moe_mod.mlp_apply(cfg, p["mlp"], h, ctx), ctx)


def forward(cfg: ModelConfig, params, batch, ctx: Optional[ShardCtx] = None):
    enc_out = encode(cfg, params, batch["frame_embeds"], ctx)
    x = _sp(params["embed"][batch["tokens"]].astype(jnp.dtype(cfg.dtype)),
            ctx)
    positions = jnp.arange(x.shape[1])

    def body(carry, p):
        return _dec_block(cfg, p, carry, enc_out, positions, ctx), None

    body = _remat(body, cfg)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return lm_logits(cfg, params, h, ctx)


def decode_step(cfg: ModelConfig, params, batch,
                ctx: Optional[ShardCtx] = None):
    """Decoder step with self-attn KV cache; cross-attn reads encoder_out."""
    idx = batch["cache_index"]
    enc_out = batch["encoder_out"].astype(jnp.dtype(cfg.dtype))
    x = params["embed"][batch["tokens"]].astype(jnp.dtype(cfg.dtype))
    x = constrain(x, ctx, "dp", None, None)

    def body(carry, layer):
        p = layer["p"]
        h = rms_norm(carry, p["norm1"], cfg.norm_eps)
        a, nk, nv = attn.gqa_decode(cfg, p["attn"], h, layer["kc"],
                                    layer["vc"], idx, ctx=ctx)
        xx = carry + a
        h = rms_norm(xx, p["norm3"], cfg.norm_eps)
        xx = xx + cross_apply(cfg, p["cross"], h, enc_out, ctx)
        h = rms_norm(xx, p["norm2"], cfg.norm_eps)
        xx = xx + moe_mod.mlp_apply(cfg, p["mlp"], h, ctx)
        return xx, (nk, nv)

    x, (nk, nv) = jax.lax.scan(
        body, x, {"p": params["dec_layers"],
                  "kc": batch["k_cache"], "vc": batch["v_cache"]})
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return lm_logits(cfg, params, h, ctx), {"k_cache": nk, "v_cache": nv}
