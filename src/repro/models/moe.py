"""MLP layers: dense (gated / plain) and mixture-of-experts.

MoE uses the GShard-style dense one-hot dispatch, formulated so that under
pjit the dispatch/combine tensors shard over the expert axis (= "model" mesh
axis).  Experts are expert-parallel; the combine einsum contracts the sharded
expert axis and lowers to one all-reduce — no ragged all-to-all required for
the dry-run (a ragged path is the deploy-target fast path, see DESIGN.md).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import (ShardCtx, activation_fn, constrain,
                                 dense_init, gated)


# ---------------------------------------------------------------------------
# dense MLP
# ---------------------------------------------------------------------------
def mlp_init(key, cfg: ModelConfig, dtype, d_ff: Optional[int] = None):
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"wi": dense_init(ks[0], (d, ff), dtype),
         "wo": dense_init(ks[1], (ff, d), dtype)}
    if gated(cfg.activation):
        p["wg"] = dense_init(ks[2], (d, ff), dtype)
    return p


def mlp_apply(cfg: ModelConfig, p, x, ctx: Optional[ShardCtx]):
    act = activation_fn(cfg.activation)
    h = x @ p["wi"]
    h = constrain(h, ctx, "dp", None, "tp")
    if "wg" in p:
        h = act(x @ p["wg"]) * h
    else:
        h = act(h)
    out = h @ p["wo"]
    return constrain(out, ctx, "dp", "tp", None)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------
def moe_init(key, cfg: ModelConfig, dtype):
    d, E, ffe = cfg.d_model, cfg.num_experts, cfg.d_ff_expert
    ks = jax.random.split(key, 6)

    def one_expert(k):
        kk = jax.random.split(k, 3)
        p = {"wi": dense_init(kk[0], (d, ffe), dtype),
             "wo": dense_init(kk[1], (ffe, d), dtype)}
        if gated(cfg.activation):
            p["wg"] = dense_init(kk[2], (d, ffe), dtype)
        return p

    p = {"router": dense_init(ks[0], (d, E), jnp.float32),
         "experts": jax.vmap(one_expert)(jax.random.split(ks[1], E))}
    if cfg.num_shared_experts:
        p["shared"] = mlp_init(ks[2], cfg, dtype,
                               d_ff=cfg.d_ff_expert * cfg.num_shared_experts)
    return p


def capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    c = int(tokens_per_group * cfg.top_k / cfg.num_experts
            * cfg.capacity_factor)
    # round to an MXU-friendly multiple where it matters, keep >= top_k
    c = max(c, cfg.top_k)
    return -(-c // 8) * 8


def moe_apply(cfg: ModelConfig, p, x, ctx: Optional[ShardCtx],
              router_stats: bool = False):
    """x: (B, S, d).  Routing groups = batch rows (GShard grouping)."""
    B, S, d = x.shape
    if S == 1 and B > 1:
        # decode: route the whole batch as ONE group — per-row groups pad
        # every expert's capacity to top_k PER TOKEN (measured ~250x slot
        # waste on deepseek-v3 decode_32k; §Perf cell B iteration 2)
        y = moe_apply(cfg, p, x.reshape(1, B, d), ctx, router_stats)
        if router_stats:
            return y[0].reshape(B, S, d), y[1]
        return y.reshape(B, S, d)
    E, K = cfg.num_experts, cfg.top_k
    C = capacity(cfg, S)
    act = activation_fn(cfg.activation)
    # batch sharding of routing tensors: drop when EP spans the data axes
    bsp = None if (ctx is not None and ctx.ep_covers_dp) else "dp"

    logits = x.astype(jnp.float32) @ p["router"]          # (B, S, E)
    probs = jax.nn.softmax(logits, -1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)          # (B, S, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # position of each (token, k) assignment within its expert's capacity
    khot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)    # (B, S, K, E)
    flat = khot.reshape(B, S * K, E)
    pos = jnp.cumsum(flat, axis=1) - flat                  # (B, S*K, E)
    pos = pos.reshape(B, S, K, E)
    in_cap = (pos < C) & (khot > 0)

    # dispatch: (B, S, E, C) one-hot over capacity slots, sharded on E
    pos_in_e = (pos * khot).sum(-1)                        # (B, S, K)
    slot_hot = jax.nn.one_hot(pos_in_e, C, dtype=x.dtype)  # (B, S, K, C)
    keep = in_cap.any(-1).astype(x.dtype)                  # (B, S, K)

    def accum(carry, k):
        disp, comb = carry
        ek = jax.nn.one_hot(gate_idx[:, :, k], E, dtype=x.dtype)
        contrib = (ek[..., None] * slot_hot[:, :, k, None, :]
                   * keep[:, :, k, None, None])            # (B, S, E, C)
        return (disp + contrib,
                comb + contrib * gate_vals[:, :, k, None, None].astype(x.dtype)), None

    z = jnp.zeros((B, S, E, C), x.dtype)
    z = constrain(z, ctx, bsp, None, "ep", None)
    (dispatch, combine), _ = jax.lax.scan(accum, (z, z), jnp.arange(K))
    dispatch = constrain(dispatch, ctx, bsp, None, "ep", None)
    combine = constrain(combine, ctx, bsp, None, "ep", None)

    xe = jnp.einsum("bsd,bsec->becd", x, dispatch)         # (B, E, C, d)
    xe = constrain(xe, ctx, bsp, "ep", None, None)
    h = jnp.einsum("becd,edf->becf", xe, p["experts"]["wi"])
    if "wg" in p["experts"]:
        h = act(jnp.einsum("becd,edf->becf", xe, p["experts"]["wg"])) * h
    else:
        h = act(h)
    ye = jnp.einsum("becf,efd->becd", h, p["experts"]["wo"])
    ye = constrain(ye, ctx, bsp, "ep", None, None)
    y = jnp.einsum("becd,bsec->bsd", ye, combine)          # all-reduce over E
    y = constrain(y, ctx, bsp, "tp" if bsp else None, None)

    if cfg.num_shared_experts:
        y = y + mlp_apply(cfg, p["shared"], x, ctx)

    if router_stats:
        # load-balance aux loss (Switch-style)
        frac_tokens = jnp.mean(
            jax.nn.one_hot(gate_idx[..., 0], E, dtype=jnp.float32), (0, 1))
        frac_probs = jnp.mean(probs, (0, 1))
        aux = E * jnp.sum(frac_tokens * frac_probs)
        return y, aux
    return y
