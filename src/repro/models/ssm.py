"""Mamba2 (SSD — state-space duality) blocks, pure JAX.

Training/prefill uses the chunked SSD algorithm (quadratic intra-chunk,
linear inter-chunk recurrence); decode is the O(1)-state recurrent step.
The Pallas kernel in repro.kernels.ssd_scan accelerates the intra-chunk
matmuls on TPU; this module is the reference/dry-run path and shares its
math with repro.kernels.ref.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ShardCtx, constrain, dense_init, rms_norm


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------
def mamba_init(key, cfg: ModelConfig, dtype):
    d, di = cfg.d_model, cfg.d_inner
    g, ds, nh = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    conv_dim = di + 2 * g * ds
    ks = jax.random.split(key, 8)
    in_dim = 2 * di + 2 * g * ds + nh  # [z, x, B, C, dt]
    return {
        "in_proj": dense_init(ks[0], (d, in_dim), dtype),
        "conv_w": dense_init(ks[1], (cfg.conv_width, conv_dim), dtype, 0.5),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[2], (nh,), jnp.float32,
                                       jnp.log(1e-3), jnp.log(1e-1))))),
        "out_norm": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[3], (di, d), dtype),
    }


# ---------------------------------------------------------------------------
# causal depthwise conv
# ---------------------------------------------------------------------------
def causal_conv(x, w, b):
    """Depthwise causal conv as W shifted multiplies.  x: (B, S, C); w: (W, C).

    Written as elementwise ops (not conv_general_dilated with
    feature_group_count) because XLA SPMD cannot channel-partition grouped
    convs — it replicates the operand, blowing up per-device memory on
    wide SSM blocks.  W is tiny (4), so W shifted fmas are also faster.
    """
    W = w.shape[0]
    S = x.shape[1]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = b
    for i in range(W):
        out = out + xp[:, i:i + S] * w[i]
    return out


def conv_step(x_new, conv_state, w, b):
    """x_new: (B, C); conv_state: (B, W-1, C) rolling buffer."""
    full = jnp.concatenate([conv_state, x_new[:, None]], axis=1)  # (B, W, C)
    y = jnp.einsum("bwc,wc->bc", full, w) + b
    return y, full[:, 1:]


# ---------------------------------------------------------------------------
# SSD core (chunked)
# ---------------------------------------------------------------------------
def segsum(dA):
    """dA: (..., Q) -> (..., Q, Q) lower-triangular segment sums
    T[i, j] = sum_{k=j+1..i} dA[k] for i >= j, -inf above diagonal."""
    Q = dA.shape[-1]
    cs = jnp.cumsum(dA, -1)
    T = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool), 0)
    return jnp.where(mask, T, -jnp.inf)


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int,
                init_state=None, return_final=False,
                ctx: Optional[ShardCtx] = None):
    """Chunked SSD scan.

    x : (B, S, nh, hd)     dt: (B, S, nh)      A: (nh,) (negative)
    Bm, Cm: (B, S, g, ds)  heads are grouped nh = g * hpg.
    Returns y: (B, S, nh, hd) [, final_state (B, nh, hd, ds)].
    """
    Bsz, S, nh, hd = x.shape
    g, ds = Bm.shape[2], Bm.shape[3]
    hpg = nh // g
    Q = min(chunk, S)
    nc = S // Q
    assert nc * Q == S, f"seq {S} not divisible by chunk {Q}"

    f32 = jnp.float32

    # head-major layout throughout: the head dim (nh = g·hpg) is the only
    # dim that divides the model axis, so B/C are broadcast to per-head form
    # and every large intermediate is pinned head-sharded.  Without the pins
    # XLA leaves the (B,nc,nh,Q,Q) decay/score tensors replicated (~160 GiB
    # on zamba2 train_4k).
    def pin_h(t, h_axis):
        if ctx is None or ctx.tp is None \
                or nh % ctx.mesh.shape[ctx.tp] != 0:
            return t
        spec = ["dp"] + [None] * (t.ndim - 1)
        spec[h_axis] = "tp"
        return constrain(t, ctx, *spec)

    xc = pin_h(x.reshape(Bsz, nc, Q, nh, hd), 3)
    dtc = dt.reshape(Bsz, nc, Q, nh).astype(f32)
    Bh = jnp.broadcast_to(
        Bm.reshape(Bsz, S, g, 1, ds),
        (Bsz, S, g, hpg, ds)).reshape(Bsz, nc, Q, nh, ds)
    Ch = jnp.broadcast_to(
        Cm.reshape(Bsz, S, g, 1, ds),
        (Bsz, S, g, hpg, ds)).reshape(Bsz, nc, Q, nh, ds)
    Bh, Ch = pin_h(Bh, 3), pin_h(Ch, 3)

    dA = dtc * A  # (B, nc, Q, nh)
    dA_cs = jnp.cumsum(dA, axis=2)

    # ---- intra-chunk (quadratic within chunk) ----
    L = pin_h(jnp.exp(segsum(jnp.moveaxis(dA, 3, 2))), 2)  # (B,nc,nh,Q,Q)
    CB = pin_h(jnp.einsum("bcqhd,bckhd->bchqk", Ch, Bh,
                          preferred_element_type=f32), 2)  # (B,nc,nh,Q,Q)
    M = CB * L * jnp.moveaxis(dtc, 2, 3)[..., None, :]     # × dt_j
    y_intra = pin_h(jnp.einsum("bchqk,bckhp->bcqhp",
                               M, xc.astype(f32)), 3)

    # ---- chunk states ----
    decay_to_end = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)    # (B, nc, Q, nh)
    w = dtc * decay_to_end
    states = pin_h(jnp.einsum("bcqhd,bcqh,bcqhp->bchpd",
                              Bh.astype(f32), w, xc.astype(f32)), 2)

    # ---- inter-chunk recurrence ----
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])              # (B, nc, nh)
    h0 = (jnp.zeros((Bsz, nh, hd, ds), f32) if init_state is None
          else init_state.astype(f32))

    def step(h, inp):
        st, dec = inp  # st: (B, nh, hd, ds), dec: (B, nh)
        h_in = h
        h = h * dec[..., None, None] + st
        return h, h_in

    (h_final, h_prevs) = jax.lax.scan(
        step, h0, (jnp.moveaxis(states, 1, 0),
                   jnp.moveaxis(chunk_decay, 1, 0)))
    h_prevs = pin_h(jnp.moveaxis(h_prevs, 0, 1), 2)        # (B,nc,nh,hd,ds)

    # ---- inter-chunk contribution ----
    decay_in = jnp.exp(dA_cs)                              # (B, nc, Q, nh)
    y_inter = jnp.einsum("bcqhd,bcqh,bchpd->bcqhp",
                         Ch.astype(f32), decay_in, h_prevs)

    y = (y_intra + y_inter).reshape(Bsz, S, nh, hd).astype(x.dtype)
    if return_final:
        return y, h_final
    return y


def ssd_step(x, dt, A, Bm, Cm, h):
    """Single-token SSD recurrence.

    x: (B, nh, hd); dt: (B, nh); Bm/Cm: (B, g, ds); h: (B, nh, hd, ds).
    """
    Bsz, nh, hd = x.shape
    g, ds = Bm.shape[1], Bm.shape[2]
    hpg = nh // g
    f32 = jnp.float32
    dt = dt.astype(f32)
    dA = jnp.exp(dt * A)                                  # (B, nh)
    Bx = jnp.einsum("bgd,bghp->bghpd", Bm.astype(f32),
                    (dt.reshape(Bsz, g, hpg)[..., None]
                     * x.reshape(Bsz, g, hpg, hd).astype(f32)))
    h = h * dA[..., None, None] + Bx.reshape(Bsz, nh, hd, ds)
    y = jnp.einsum("bghpd,bgd->bghp", h.reshape(Bsz, g, hpg, hd, ds),
                   Cm.astype(f32))
    return y.reshape(Bsz, nh, hd).astype(x.dtype), h


# ---------------------------------------------------------------------------
# full block
# ---------------------------------------------------------------------------
def _split_in_proj(cfg: ModelConfig, proj):
    di, g, ds, nh = (cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state,
                     cfg.ssm_nheads)
    z = proj[..., :di]
    xBC = proj[..., di:di + di + 2 * g * ds]
    dt = proj[..., di + di + 2 * g * ds:]
    return z, xBC, dt


def _split_xbc(cfg: ModelConfig, xBC):
    di, g, ds = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state
    x = xBC[..., :di]
    Bm = xBC[..., di:di + g * ds]
    Cm = xBC[..., di + g * ds:]
    return x, Bm, Cm


def mamba_apply(cfg: ModelConfig, p, u, ctx: Optional[ShardCtx],
                use_kernel: bool = False):
    """Full-sequence Mamba2 mixer.  u: (B, S, d) (already normed)."""
    B, S, _ = u.shape
    nh, hd, g, ds = (cfg.ssm_nheads, cfg.ssm_head_dim, cfg.ssm_ngroups,
                     cfg.ssm_state)
    proj = u @ p["in_proj"]
    proj = constrain(proj, ctx, "dp", None, "tp")
    z, xBC, dt_raw = _split_in_proj(cfg, proj)
    xBC = causal_conv(xBC, p["conv_w"], p["conv_b"])
    xBC = jax.nn.silu(xBC)
    x, Bm, Cm = _split_xbc(cfg, xBC)
    x = x.reshape(B, S, nh, hd)
    Bm = Bm.reshape(B, S, g, ds)
    Cm = Cm.reshape(B, S, g, ds)
    # softplus at the proj boundary stays in compute dtype: an f32 cast here
    # promotes the cotangent of the FULL (B,S,in_dim) projection to f32
    # (pad of the dt slice), doubling backward activation bytes; dt is
    # upcast to f32 immediately downstream inside the SSD math.
    dt = jax.nn.softplus(dt_raw + p["dt_bias"].astype(dt_raw.dtype))
    A = -jnp.exp(p["A_log"])
    if use_kernel:
        from repro.kernels import ops as kops
        y = kops.ssd(x, dt, A, Bm, Cm, chunk=cfg.ssm_chunk)
    else:
        y = ssd_chunked(x, dt, A, Bm, Cm, cfg.ssm_chunk, ctx=ctx)
    y = y + (p["D"].astype(y.dtype)[:, None] * x)
    y = y.reshape(B, S, cfg.d_inner)
    y = constrain(y, ctx, "dp", None, "tp")
    # gate in compute dtype: fp32 casts here replicate (B,S,2d) activations
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps)
    out = y @ p["out_proj"]
    return constrain(out, ctx, "dp", "tp", None)


def mamba_decode(cfg: ModelConfig, p, u, ssm_state, conv_state):
    """Single-token step.  u: (B, 1, d); returns (out, ssm_state, conv_state)."""
    B = u.shape[0]
    nh, hd, g, ds = (cfg.ssm_nheads, cfg.ssm_head_dim, cfg.ssm_ngroups,
                     cfg.ssm_state)
    proj = (u[:, 0] @ p["in_proj"])
    z, xBC, dt_raw = _split_in_proj(cfg, proj)
    xBC, conv_state = conv_step(xBC, conv_state, p["conv_w"], p["conv_b"])
    xBC = jax.nn.silu(xBC)
    x, Bm, Cm = _split_xbc(cfg, xBC)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, ssm_state = ssd_step(x.reshape(B, nh, hd), dt, A,
                            Bm.reshape(B, g, ds), Cm.reshape(B, g, ds),
                            ssm_state)
    y = y + (p["D"][:, None] * x.reshape(B, nh, hd).astype(jnp.float32)
             ).astype(y.dtype)
    y = y.reshape(B, cfg.d_inner)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["out_norm"], cfg.norm_eps)
    return (y @ p["out_proj"])[:, None], ssm_state, conv_state
