"""Full models for the ssm (mamba2-780m) and hybrid (zamba2-7b) families.

zamba2 structure: a Mamba2 backbone with ONE shared attention+MLP block
(weights shared) applied before every `attn_every`-th layer.  Layers are
processed in groups: [shared-attn] -> scan(mamba x attn_every), which keeps
scan bodies homogeneous and lets decode index attention caches statically.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm
from repro.models.common import ShardCtx, constrain, dense_init, rms_norm
from repro.models.transformer import _remat, _sp, lm_logits


def _mamba_block_init(key, cfg: ModelConfig, dtype):
    return {"norm": jnp.ones((cfg.d_model,), dtype),
            "mixer": ssm.mamba_init(key, cfg, dtype)}


def init_params(cfg: ModelConfig, key, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    d, V, L = cfg.d_model, cfg.vocab_size, cfg.num_layers
    params = {
        "embed": (jax.random.normal(ks[0], (V, d), jnp.float32) * 0.02
                  ).astype(dtype),
        "final_norm": jnp.ones((d,), dtype),
        "layers": jax.vmap(lambda k: _mamba_block_init(k, cfg, dtype))(
            jax.random.split(ks[1], L)),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[2], (d, V), dtype)
    if cfg.family == "hybrid":
        kk = jax.random.split(ks[3], 3)
        params["shared_attn"] = {
            "attn": attn.gqa_init(kk[0], cfg, dtype),
            "mlp": moe_mod.mlp_init(kk[1], cfg, dtype),
            "norm1": jnp.ones((d,), dtype),
            "norm2": jnp.ones((d,), dtype),
        }
    return params


def _mamba_stack(cfg, stacked, x, ctx):
    def body(carry, p_layer):
        # pin the norm output back to SP so the full-sequence gather the
        # mixer needs happens on the bf16 tensor, not the hoisted f32
        # upcast inside rms_norm (§Perf cell C: halves gather bytes)
        h = _sp(rms_norm(carry, p_layer["norm"], cfg.norm_eps), ctx)
        return _sp(carry + ssm.mamba_apply(cfg, p_layer["mixer"], h, ctx),
                   ctx), None

    body = _remat(body, cfg)
    x, _ = jax.lax.scan(body, x, stacked)
    return x


def _shared_attn_apply(cfg, p, x, positions, ctx):
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    a = attn.gqa_apply(cfg, p["attn"], h, positions=positions, causal=True,
                       ctx=ctx)
    x = _sp(x + a, ctx)
    h = rms_norm(x, p["norm2"], cfg.norm_eps)
    return _sp(x + moe_mod.mlp_apply(cfg, p["mlp"], h, ctx), ctx)


def _groups(cfg: ModelConfig):
    """[(start, end), ...] mamba-layer groups, one shared-attn before each."""
    k = cfg.attn_every
    return [(s, min(s + k, cfg.num_layers)) for s in range(0, cfg.num_layers, k)]


def forward(cfg: ModelConfig, params, batch, ctx: Optional[ShardCtx] = None):
    x = params["embed"][batch["tokens"]].astype(jnp.dtype(cfg.dtype))
    x = _sp(x, ctx)
    S = x.shape[1]
    if cfg.family == "ssm":
        x = _mamba_stack(cfg, params["layers"], x, ctx)
    else:
        positions = jnp.arange(S)
        # shared-attn applications are OUTSIDE the layer scans, so they must
        # carry their own remat: without it the flash online-softmax scan
        # saves every kv-block iteration for backward (~30 GiB/device on
        # zamba2 train_4k).
        shared = _remat(
            lambda xx, p: (_shared_attn_apply(cfg, p, xx, positions, ctx),
                           None), cfg)
        for (s, e) in _groups(cfg):
            x, _ = shared(x, params["shared_attn"])
            sub = jax.tree.map(lambda a: a[s:e], params["layers"])
            x = _mamba_stack(cfg, sub, x, ctx)
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return lm_logits(cfg, params, h, ctx)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------
def _mamba_stack_decode(cfg, stacked, x, ssm_states, conv_states):
    def body(carry, layer):
        h = rms_norm(carry, layer["p"]["norm"], cfg.norm_eps)
        out, s_new, c_new = ssm.mamba_decode(cfg, layer["p"]["mixer"], h,
                                             layer["s"], layer["c"])
        return carry + out, (s_new, c_new)

    x, (s_new, c_new) = jax.lax.scan(
        body, x, {"p": stacked, "s": ssm_states, "c": conv_states})
    return x, s_new, c_new


def decode_step(cfg: ModelConfig, params, batch,
                ctx: Optional[ShardCtx] = None):
    idx = batch["cache_index"]
    x = params["embed"][batch["tokens"]].astype(jnp.dtype(cfg.dtype))
    x = constrain(x, ctx, "dp", None, None)
    new_caches = {}

    if cfg.family == "ssm":
        x, s_new, c_new = _mamba_stack_decode(
            cfg, params["layers"], x, batch["ssm_state"], batch["conv_state"])
        new_caches["ssm_state"], new_caches["conv_state"] = s_new, c_new
    else:
        kc, vc = batch["k_cache"], batch["v_cache"]
        s_parts, c_parts, k_parts, v_parts = [], [], [], []
        for j, (s, e) in enumerate(_groups(cfg)):
            h = rms_norm(x, params["shared_attn"]["norm1"], cfg.norm_eps)
            a, nk, nv = attn.gqa_decode(cfg, params["shared_attn"]["attn"], h,
                                        kc[j], vc[j], idx, ctx=ctx)
            x = x + a
            h = rms_norm(x, params["shared_attn"]["norm2"], cfg.norm_eps)
            x = x + moe_mod.mlp_apply(cfg, params["shared_attn"]["mlp"], h,
                                      ctx)
            k_parts.append(nk[None])
            v_parts.append(nv[None])
            sub = jax.tree.map(lambda a: a[s:e], params["layers"])
            x, s_new, c_new = _mamba_stack_decode(
                cfg, sub, x, batch["ssm_state"][s:e], batch["conv_state"][s:e])
            s_parts.append(s_new)
            c_parts.append(c_new)
        new_caches["k_cache"] = jnp.concatenate(k_parts, 0)
        new_caches["v_cache"] = jnp.concatenate(v_parts, 0)
        new_caches["ssm_state"] = jnp.concatenate(s_parts, 0)
        new_caches["conv_state"] = jnp.concatenate(c_parts, 0)

    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return lm_logits(cfg, params, h, ctx), new_caches
