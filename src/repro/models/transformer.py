"""Decoder-only transformer covering the dense / moe / mla_moe / vlm families.

Layers are stacked along a leading L axis and driven by jax.lax.scan (one
traced block regardless of depth — essential for 61/96-layer dry-run compile
times).  Heterogeneous stacks (deepseek first-k dense layers) are two scans.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models.common import ShardCtx, constrain, dense_init, rms_norm


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _block_init(key, cfg: ModelConfig, dtype, moe: bool):
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    if cfg.family == "mla_moe":
        a = attn.mla_init(ks[0], cfg, dtype)
    else:
        a = attn.gqa_init(ks[0], cfg, dtype)
    if moe:
        m = moe_mod.moe_init(ks[1], cfg, dtype)
    else:
        m = moe_mod.mlp_init(ks[1], cfg, dtype)
    return {"attn": a, "mlp": m,
            "norm1": jnp.ones((d,), dtype), "norm2": jnp.ones((d,), dtype)}


def init_params(cfg: ModelConfig, key, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    d, V, L = cfg.d_model, cfg.vocab_size, cfg.num_layers
    n_dense = cfg.first_dense_layers if cfg.num_experts else L
    n_moe = L - n_dense

    params = {
        "embed": (jax.random.normal(ks[0], (V, d), jnp.float32) * 0.02
                  ).astype(dtype),
        "final_norm": jnp.ones((d,), dtype),
    }
    if n_dense:
        params["dense_layers"] = jax.vmap(
            lambda k: _block_init(k, cfg, dtype, moe=False))(
                jax.random.split(ks[1], n_dense))
    if n_moe:
        params["moe_layers"] = jax.vmap(
            lambda k: _block_init(k, cfg, dtype, moe=True))(
                jax.random.split(ks[2], n_moe))
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[3], (d, V), dtype)
    if cfg.family == "vlm":
        params["mm_connector"] = dense_init(ks[4], (d, d), dtype)
    if cfg.mtp_depth:
        params["mtp"] = {
            "proj": dense_init(ks[5], (2 * d, d), dtype),
            "norm": jnp.ones((d,), dtype),
            "block": _block_init(ks[6], cfg, dtype, moe=bool(cfg.num_experts)),
        }
    return params


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------
def _sp(x, ctx):
    """Megatron-style sequence-parallel residual-stream constraint."""
    if ctx is None:
        return x
    S = x.shape[1]
    if ctx.tp is not None and S % ctx.mesh.shape[ctx.tp] == 0:
        return constrain(x, ctx, "dp", "tp", None)
    return constrain(x, ctx, "dp", None, None)


def block_apply(cfg: ModelConfig, p, x, positions, ctx, *, moe: bool,
                causal: bool = True):
    # norm outputs pinned to SP: the attention/MLP full-sequence gather
    # then moves to the bf16 tensor instead of the f32 rms upcast
    h = _sp(rms_norm(x, p["norm1"], cfg.norm_eps), ctx)
    if cfg.family == "mla_moe":
        a = attn.mla_apply(cfg, p["attn"], h, positions=positions,
                           causal=causal, ctx=ctx)
    else:
        a = attn.gqa_apply(cfg, p["attn"], h, positions=positions,
                           causal=causal, ctx=ctx)
    x = _sp(x + a, ctx)
    h = _sp(rms_norm(x, p["norm2"], cfg.norm_eps), ctx)
    if moe:
        m = moe_mod.moe_apply(cfg, p["mlp"], h, ctx)
    else:
        m = moe_mod.mlp_apply(cfg, p["mlp"], h, ctx)
    return _sp(x + m, ctx)


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    policy = (jax.checkpoint_policies.nothing_saveable
              if cfg.remat == "nothing"
              else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn, policy=policy)


def scan_stack(cfg: ModelConfig, stacked, x, positions, ctx, *, moe: bool):
    def body(carry, p_layer):
        return block_apply(cfg, p_layer, carry, positions, ctx, moe=moe), None

    body = _remat(body, cfg)
    x, _ = jax.lax.scan(body, x, stacked)
    return x


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------
def embed_inputs(cfg: ModelConfig, params, batch, ctx):
    tok = params["embed"][batch["tokens"]]  # gather
    if cfg.family == "vlm":
        img = batch["patch_embeds"] @ params["mm_connector"]
        x = jnp.concatenate([img, tok], axis=1)
    else:
        x = tok
    return _sp(x.astype(jnp.dtype(cfg.dtype)), ctx)


def forward(cfg: ModelConfig, params, batch, ctx: Optional[ShardCtx] = None,
            return_hidden: bool = False):
    """Full-sequence forward -> logits (B, S, V)."""
    x = embed_inputs(cfg, params, batch, ctx)
    S = x.shape[1]
    positions = jnp.arange(S)
    n_dense = cfg.first_dense_layers if cfg.num_experts else cfg.num_layers
    if "dense_layers" in params:
        x = scan_stack(cfg, params["dense_layers"], x, positions, ctx,
                       moe=False)
    if "moe_layers" in params:
        x = scan_stack(cfg, params["moe_layers"], x, positions, ctx, moe=True)
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_logits(cfg, params, h, ctx)
    if return_hidden:
        return logits, h
    return logits


def lm_logits(cfg: ModelConfig, params, h, ctx):
    w = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = h @ w.astype(h.dtype)
    return constrain(logits, ctx, "dp", None, "tp")


def mtp_logits(cfg: ModelConfig, params, h, batch, ctx):
    """DeepSeek-V3 multi-token prediction: one extra block predicting t+2.

    h: main-model hidden states (B, S, d).  Combines h[t] with emb(tok[t+1]).
    """
    p = params["mtp"]
    tok = params["embed"][batch["tokens"]]
    if cfg.family == "vlm":
        raise NotImplementedError
    nxt = jnp.roll(tok, -1, axis=1).astype(h.dtype)
    z = jnp.concatenate([rms_norm(h, p["norm"], cfg.norm_eps), nxt], -1)
    z = _sp(z @ p["proj"], ctx)
    S = z.shape[1]
    z = block_apply(cfg, p["block"], z, jnp.arange(S), ctx,
                    moe=bool(cfg.num_experts))
    return lm_logits(cfg, params, rms_norm(z, params["final_norm"],
                                           cfg.norm_eps), ctx)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------
def decode_step(cfg: ModelConfig, params, batch,
                ctx: Optional[ShardCtx] = None):
    """One decode step.  batch: tokens (B,1), cache_index (), caches.

    Returns (logits (B, 1, V), new_caches dict).
    """
    idx = batch["cache_index"]
    x = params["embed"][batch["tokens"]].astype(jnp.dtype(cfg.dtype))
    x = constrain(x, ctx, "dp", None, None)

    n_dense = cfg.first_dense_layers if cfg.num_experts else cfg.num_layers

    def body(carry, layer):
        xx = carry
        p, kc, vc, cache = layer["p"], layer.get("kc"), layer.get("vc"), None
        h = rms_norm(xx, p["norm1"], cfg.norm_eps)
        if cfg.family == "mla_moe":
            a, new_kv = attn.mla_decode(cfg, p["attn"], h, layer["kv"], idx,
                                        ctx=ctx)
            upd = {"kv": new_kv}
        else:
            a, nk, nv = attn.gqa_decode(cfg, p["attn"], h, kc, vc, idx,
                                        ctx=ctx)
            upd = {"kc": nk, "vc": nv}
        xx = xx + a
        h = rms_norm(xx, p["norm2"], cfg.norm_eps)
        m = (moe_mod.moe_apply(cfg, p["mlp"], h, ctx) if layer["moe"]
             else moe_mod.mlp_apply(cfg, p["mlp"], h, ctx))
        return xx + m, upd

    new_caches = {}
    x_cur = x
    if cfg.family == "mla_moe":
        kv = batch["kv_cache"]
        parts = []
        if n_dense:
            def dbody(c, layer):
                out, upd = body(c, {"p": layer["p"], "kv": layer["kv"],
                                    "moe": False})
                return out, upd["kv"]
            x_cur, kv_d = jax.lax.scan(
                dbody, x_cur, {"p": params["dense_layers"],
                               "kv": kv[:n_dense]})
            parts.append(kv_d)
        def mbody(c, layer):
            out, upd = body(c, {"p": layer["p"], "kv": layer["kv"],
                                "moe": True})
            return out, upd["kv"]
        x_cur, kv_m = jax.lax.scan(
            mbody, x_cur, {"p": params["moe_layers"], "kv": kv[n_dense:]})
        parts.append(kv_m)
        new_caches["kv_cache"] = jnp.concatenate(parts, 0)
    else:
        kc, vc = batch["k_cache"], batch["v_cache"]
        kparts, vparts = [], []
        off = 0
        for name, moe in (("dense_layers", False), ("moe_layers", True)):
            if name not in params:
                continue
            n = jax.tree_util.tree_leaves(params[name])[0].shape[0]
            def sbody(c, layer, moe=moe):
                out, upd = body(c, {"p": layer["p"], "kc": layer["kc"],
                                    "vc": layer["vc"], "moe": moe})
                return out, (upd["kc"], upd["vc"])
            x_cur, (nk, nv) = jax.lax.scan(
                sbody, x_cur, {"p": params[name],
                               "kc": kc[off:off + n], "vc": vc[off:off + n]})
            kparts.append(nk)
            vparts.append(nv)
            off += n
        new_caches["k_cache"] = (jnp.concatenate(kparts, 0)
                                 if len(kparts) > 1 else kparts[0])
        new_caches["v_cache"] = (jnp.concatenate(vparts, 0)
                                 if len(vparts) > 1 else vparts[0])

    h = rms_norm(x_cur, params["final_norm"], cfg.norm_eps)
    logits = lm_logits(cfg, params, h, ctx)
    return logits, new_caches
