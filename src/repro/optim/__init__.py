from repro.optim.adamw import OptConfig, global_norm, init, lr_at, update  # noqa: F401
