"""AdamW + cosine schedule, pure JAX (no optax dependency).

Distributed-memory options for 100B+ models on 16 GiB/chip v5e:
  moment_dtype="bfloat16" — half-width first moment
  factored_v=True         — Adafactor-style factored second moment for
                            matrices (row/col statistics), O(n+m) not O(nm)
Optimizer state inherits the parameter sharding rules (ZeRO-style: fully
sharded together with FSDP-sharded params).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    min_lr: float = 3e-5
    warmup_steps: int = 100
    decay_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"
    factored_v: bool = False


def lr_at(cfg: OptConfig, step) -> jax.Array:
    step = jnp.asarray(step, jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr + 0.5 * (cfg.peak_lr - cfg.min_lr) \
        * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def _factorable(p) -> bool:
    return p.ndim >= 2 and p.shape[-1] >= 128 and p.shape[-2] >= 128


def init(cfg: OptConfig, params):
    mdt = jnp.dtype(cfg.moment_dtype)

    def leaf(p):
        m = jnp.zeros_like(p, mdt)
        if cfg.factored_v and _factorable(p):
            v = {"row": jnp.zeros(p.shape[:-1], jnp.float32),
                 "col": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        else:
            v = jnp.zeros_like(p, jnp.float32)
        return {"m": m, "v": v}

    return {"mu": jax.tree.map(leaf, params),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(cfg: OptConfig, grads, state, params):
    """Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    lr = lr_at(cfg, count)
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gn + 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    c1 = 1 - b1 ** count.astype(jnp.float32)
    c2 = 1 - b2 ** count.astype(jnp.float32)

    def leaf(p, g, mu):
        g = g.astype(jnp.float32) * scale
        m = mu["m"].astype(jnp.float32) * b1 + g * (1 - b1)
        if isinstance(mu["v"], dict):  # factored second moment
            g2 = jnp.square(g) + 1e-30
            row = mu["v"]["row"] * b2 + g2.mean(-1) * (1 - b2)
            col = mu["v"]["col"] * b2 + g2.mean(-2) * (1 - b2)
            # rank-1 reconstruction: v ≈ row ⊗ col / mean(row)
            denom = jnp.maximum(row.mean(-1, keepdims=True), 1e-30)
            v_hat = (row[..., None] * col[..., None, :]
                     / denom[..., None]) / c2
            new_v = {"row": row, "col": col}
        else:
            new_v = mu["v"] * b2 + jnp.square(g) * (1 - b2)
            v_hat = new_v / c2
        m_hat = m / c1
        upd = m_hat / (jnp.sqrt(v_hat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
        return new_p, {"m": m.astype(mu["m"].dtype), "v": new_v}

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    outs = [leaf(p, g, mu) for p, g, mu in zip(flat_p, flat_g, flat_mu)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_mu = jax.tree.unflatten(treedef, [o[1] for o in outs])
    metrics = {"lr": lr, "grad_norm": gn}
    return new_params, {"mu": new_mu, "count": count}, metrics
