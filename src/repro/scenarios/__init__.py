"""Scenario library + detector scorecard (see scenarios.library)."""
from repro.scenarios.library import (  # noqa: F401
    DETECTORS, SCENARIOS, GroundTruthEvent, Scenario, build,
    scenario_names,
)
from repro.scenarios.scorecard import (  # noqa: F401
    FLOORS, SCHEMA, DetectorScore, ScenarioRun, check_floors,
    run_scenario, run_scorecard, score_alerts,
)
