"""Labeled fleet scenarios: ground-truth fault injection for detector scoring.

Each scenario is a declarative bundle: a small fleet of `JobSpec`s whose
`faults` field carries post-hoc `CounterFault` perturbations (fault type,
onset, affected jobs/devices, magnitude), plus `GroundTruthEvent` labels
saying what a perfect detector would report.  Because faults apply to the
FINISHED counter grid (`fleet.engine.apply_faults`), the injected ground
truth is exactly the declared perturbation on every engine backend —
scalar, vector, fused, and jax all replay the same labeled incident.

The library pins the paper's headline incidents and the fleet folklore
around them:

  * ``gloo_regression_2p5x``     — §VI's 2.5x collective-library collapse
  * ``mixed_precision_transition`` — FP8<->BF16 switch: OFU halves while the
    app's FLOPs counter keeps billing BF16 (the §V-C divergence story)
  * ``straggler_hosts``          — half the hosts limp, job mean sags
  * ``thermal_throttle``         — a clock-domain drop that later recovers
  * ``preemption_wave``          — two preemption-and-recovery waves across
    the fleet (drives `fleet.recovery` + the goodput detector)
  * ``moe_expert_imbalance``     — periodic expert-routing hot spots
  * ``diurnal_inference``        — benign multi-tenant load swings: ZERO
    labels, so every alert fired is a false positive (precision probe)
  * ``flops_miscalculation``     — §V-C live: the DeepSeek-style MoE's
    `naive_moe` counter (~3x) and the hybrid's `naive_hybrid` (~1.8x)
    stream inflated MFU through the app-reporter path; the correlation
    tier's OFU/MFU-ratio detector must flag exactly those two jobs

`scenarios.scorecard` replays these through the live `Collector` and
scores each detector's precision / recall / time-to-detect against the
labels.  Everything is seeded: `build(name)` is deterministic.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

from repro.fleet.collector import FLEET_SCOPE
from repro.fleet.engine import CounterFault
from repro.fleet.jobs import JobSpec

#: detectors the scorecard knows how to score
DETECTORS = ("regression", "divergence", "goodput", "miscalc")

#: shared scenario geometry — 2 h of 30 s scrapes, 5 min buckets/rounds:
#: long enough for a 4-bucket detector baseline on both sides of a
#: mid-run onset, small enough that the whole suite replays in CI
INTERVAL_S = 30.0
DURATION_S = 7200.0
BUCKET_S = 300.0


@dataclass(frozen=True)
class GroundTruthEvent:
    """One labeled incident: what a perfect detector would report."""

    job_id: str                  # FLEET_SCOPE for fleet-wide (goodput)
    detector: str                # 'regression' | 'divergence' | 'goodput'
    onset_s: float
    end_s: Optional[float] = None   # None = persists through end of run
    magnitude: float = 0.0          # regression factor / rel err / drop
    note: str = ""

    def __post_init__(self):
        if self.detector not in DETECTORS:
            raise ValueError(f"unknown detector {self.detector!r} "
                             f"(expected one of {DETECTORS})")
        if self.end_s is not None and self.end_s <= self.onset_s:
            raise ValueError(f"label window [{self.onset_s}, {self.end_s}] "
                             "is empty")


@dataclass
class Scenario:
    """A reproducible labeled fleet: specs with injected faults + the
    ground truth, plus the collector geometry the scorecard replays
    it under."""

    name: str
    description: str
    specs: list                  # JobSpec, faults attached
    labels: list                 # GroundTruthEvent
    detectors: Sequence[str] = DETECTORS   # which detectors are scored
    round_s: float = BUCKET_S
    bucket_s: float = BUCKET_S
    retain: int = 24
    detector_kw: dict = field(
        default_factory=lambda: {"window": 4, "min_duration": 2})
    goodput_kw: Optional[dict] = field(
        default_factory=lambda: {"drop_threshold": 0.25, "window": 4,
                                 "min_duration": 2})
    flag_rel_err: float = 0.30
    #: slack appended to each label window when matching alerts — covers
    #: detector sustain (min_duration buckets) + round quantization
    tolerance_s: float = 900.0
    #: job_id -> app-MFU override for the collector stream (None = the
    #: app's reporting follows the hardware, so divergence triage skips
    #: the job; absent = use the simulated app MFU as-is)
    app_mfu: dict = field(default_factory=dict)
    #: job_id -> reported-MFU stream for the collector's app-reporter
    #: path: jobs listed here replay a `MfuReplaySource.constant` series
    #: through `JobStream.mfu_source` (the live correlation tier) instead
    #: of carrying a static `app_mfu` scalar.  None = stream the job's
    #: simulated app MFU; a float = stream that level.
    mfu_stream: dict = field(default_factory=dict)
    #: kwargs for the collector's `CorrelationConfig` ({} = stock
    #: thresholds; None disables the miscalc detector)
    miscalc_kw: Optional[dict] = field(default_factory=dict)

    def __post_init__(self):
        ids = [s.job_id for s in self.specs]
        if len(set(ids)) != len(ids):
            raise ValueError(f"duplicate job_ids in scenario: {ids}")
        for jid in self.mfu_stream:
            if jid not in ids:
                raise ValueError(f"mfu_stream names unknown job {jid!r} "
                                 f"(have {sorted(ids)})")
        known = set(ids) | {FLEET_SCOPE}
        for lbl in self.labels:
            if lbl.job_id not in known:
                raise ValueError(f"label {lbl} names unknown job "
                                 f"(have {sorted(known)})")
            if lbl.detector not in self.detectors:
                raise ValueError(f"label {lbl} uses unscored detector "
                                 f"{lbl.detector!r}")

    @property
    def duration_s(self) -> float:
        return max(s.duration_s for s in self.specs)


def _job(job_id: str, arch: str, seed: int, **kw) -> JobSpec:
    kw.setdefault("shape", "train_4k")
    kw.setdefault("chips", 64)
    kw.setdefault("true_duty", 0.35)
    kw.setdefault("duration_s", DURATION_S)
    kw.setdefault("scrape_interval_s", INTERVAL_S)
    return JobSpec(job_id, arch, seed=seed, **kw)


def _healthy(n: int = 3, prefix: str = "healthy") -> list:
    """Background jobs every scenario carries — the precision side of the
    scorecard (alerts on these are false positives)."""
    archs = ["llama3.2-3b", "qwen3-4b", "granite-3-2b", "zamba2-7b"]
    return [_job(f"{prefix}-{k}", archs[k % len(archs)], seed=100 + k)
            for k in range(n)]


# ---------------------------------------------------------------------------
# the scenarios
# ---------------------------------------------------------------------------
def gloo_regression_2p5x() -> Scenario:
    """The paper's §VI headline: a collective-library upgrade quietly
    drops one job's duty cycle 2.5x mid-run and never recovers."""
    onset = 3600.0
    bad = _job("allreduce-7b", "llama3.2-3b", seed=7,
               faults=[CounterFault(start_s=onset, duty_scale=0.4,
                                    kind="gloo_regression")])
    return Scenario(
        name="gloo_regression_2p5x",
        description="2.5x sustained OFU collapse on one job "
                    "(collective-library regression, no recovery)",
        specs=[bad] + _healthy(3),
        labels=[GroundTruthEvent("allreduce-7b", "regression", onset,
                                 magnitude=2.5, note="duty 0.4x")],
        # a hardware slowdown drags app MFU down with it — no divergence
        # story here, so the app side of the faulted job goes unreported
        app_mfu={"allreduce-7b": None},
    )


def mixed_precision_transition() -> Scenario:
    """FP8<->BF16 switch: the MXU finishes the same work in ~55% of the
    cycles, but the framework's FLOPs counter keeps billing the BF16
    recipe — reported MFU holds while OFU steps down (divergence), and
    the step itself reads as a 1.8x regression."""
    onset = 3600.0
    bad = _job("fp8-pilot-13b", "qwen3-4b", seed=13,
               faults=[CounterFault(start_s=onset, duty_scale=0.55,
                                    kind="precision_transition")])
    return Scenario(
        name="mixed_precision_transition",
        description="BF16->FP8 cutover: OFU steps to 0.55x while app MFU "
                    "reports the stale BF16 accounting",
        specs=[bad] + _healthy(3),
        labels=[
            GroundTruthEvent("fp8-pilot-13b", "regression", onset,
                             magnitude=1.0 / 0.55, note="duty 0.55x"),
            GroundTruthEvent("fp8-pilot-13b", "divergence", onset,
                             magnitude=0.8,
                             note="stale BF16 FLOPs accounting"),
        ],
        # tighter retention so window eviction sheds the healthy prefix
        # and the divergence mean converges inside the run
        retain=12,
        tolerance_s=1800.0,
    )


def straggler_hosts() -> Scenario:
    """Half the job's hosts degrade to 20% duty (NIC flaps, a bad rack):
    the job mean sags to 0.6x — a 1.67x regression."""
    onset = 3600.0
    bad = _job("dense-32b", "granite-3-2b", seed=32,
               faults=[CounterFault(start_s=onset, duty_scale=0.2,
                                    device_frac=0.5, kind="straggler")])
    return Scenario(
        name="straggler_hosts",
        description="half the hosts limp at 0.2x duty; job mean drops "
                    "to 0.6x (1.67x regression)",
        specs=[bad] + _healthy(3),
        labels=[GroundTruthEvent("dense-32b", "regression", onset,
                                 magnitude=1.0 / 0.6,
                                 note="device_frac=0.5 at duty 0.2x")],
        app_mfu={"dense-32b": None},
    )


def thermal_throttle() -> Scenario:
    """A clock-domain drop: SMs throttle to 0.6x f_max for 40 minutes,
    then the cooling loop catches up — a RECOVERED regression."""
    onset, end = 2400.0, 4800.0
    bad = _job("prefill-70b", "zamba2-7b", seed=70, shape="prefill_32k",
               faults=[CounterFault(start_s=onset, end_s=end,
                                    clock_scale=0.6, kind="thermal")])
    return Scenario(
        name="thermal_throttle",
        description="clock throttles to 0.6x for 40 min, then recovers",
        specs=[bad] + _healthy(3),
        labels=[GroundTruthEvent("prefill-70b", "regression", onset,
                                 end_s=end, magnitude=1.0 / 0.6,
                                 note="clock 0.6x, bounded")],
        app_mfu={"prefill-70b": None},
    )


def preemption_wave() -> Scenario:
    """Two preemption-and-recovery waves roll the fleet: jobs park at 5%
    duty for 15 minutes, then resume.  Per-job recovered regressions plus
    two fleet-wide goodput drops — the scenario `fleet.recovery` feeds on."""
    w1, w1e = 3000.0, 3900.0
    w2, w2e = 5100.0, 6000.0
    f1 = CounterFault(start_s=w1, end_s=w1e, duty_scale=0.05,
                      kind="preemption")
    f2 = CounterFault(start_s=w2, end_s=w2e, duty_scale=0.05,
                      kind="preemption")
    archs = ["llama3.2-3b", "qwen3-4b", "granite-3-2b", "zamba2-7b",
             "phi-3-vision-4.2b"]
    waves = [(f1,), (f1, f2), (f1, f2), (f2,), (f2,)]
    specs = [_job(f"tenant-{k}", archs[k], seed=200 + k, faults=list(fs))
             for k, fs in enumerate(waves)]
    labels = []
    for k, fs in enumerate(waves):
        for f in fs:
            labels.append(GroundTruthEvent(
                f"tenant-{k}", "regression", f.start_s, end_s=f.end_s,
                magnitude=20.0, note="preempted to 0.05x duty"))
    labels += [
        GroundTruthEvent(FLEET_SCOPE, "goodput", w1, end_s=w1e,
                         magnitude=0.57, note="wave 1: 3/5 jobs parked"),
        GroundTruthEvent(FLEET_SCOPE, "goodput", w2, end_s=w2e,
                         magnitude=0.57, note="wave 2: 4/5 jobs parked"),
    ]
    return Scenario(
        name="preemption_wave",
        description="two preemption waves park 3-4 of 5 jobs at 0.05x "
                    "duty for 15 min each",
        specs=specs,
        labels=labels,
        app_mfu={s.job_id: None for s in specs},
    )


def moe_expert_imbalance() -> Scenario:
    """Expert-routing hot spots: every 30 minutes a 10-minute burst
    starves 3 of 4 sampled devices (duty 0.3x) while the hot expert's
    device stays busy — repeated short recovered regressions."""
    onset = 3600.0
    bad = _job("moe-16b", "deepseek-moe-16b", seed=16,
               flops_variant="exact",
               faults=[CounterFault(start_s=onset, duty_scale=0.3,
                                    device_frac=0.75, period_s=1800.0,
                                    active_frac=1.0 / 3.0,
                                    kind="expert_imbalance")])
    return Scenario(
        name="moe_expert_imbalance",
        description="periodic expert-imbalance bursts: 10 min at ~0.48x "
                    "job mean every 30 min",
        specs=[bad] + _healthy(3),
        # one label spanning the burst train — any burst detection is a
        # true positive; the deduper may page each burst separately
        labels=[GroundTruthEvent("moe-16b", "regression", onset,
                                 magnitude=1.0 / 0.475,
                                 note="periodic bursts, 3/4 devices")],
        app_mfu={"moe-16b": None},
    )


def diurnal_inference() -> Scenario:
    """Benign multi-tenant inference load: every job breathes ±20% on a
    shared diurnal cycle.  NO labels — every alert any detector fires
    here is a false positive, so this scenario is the precision probe."""
    shapes = ["decode_32k", "prefill_32k", "decode_32k", "prefill_32k"]
    archs = ["llama3.2-3b", "qwen3-4b", "phi-3-vision-4.2b", "granite-3-2b"]
    specs = [
        _job(f"serve-{k}", archs[k], seed=300 + k, shape=shapes[k],
             faults=[CounterFault(diurnal_amp=0.2,
                                  diurnal_period_s=DURATION_S,
                                  kind="diurnal_load")])
        for k in range(4)]
    return Scenario(
        name="diurnal_inference",
        description="benign ±20% diurnal load swings on 4 inference "
                    "tenants; zero labels (false-positive probe)",
        specs=specs,
        labels=[],
    )


def flops_miscalculation() -> Scenario:
    """§V-C replayed live: two jobs stream MFU computed from BUGGY FLOPs
    counters through the app-reporter path — the DeepSeek-style MoE
    bills dense FLOPs for sparse experts (`naive_moe`, ~3x inflation at
    671B/288 GPUs) and the hybrid bills attention math for its Mamba
    blocks (`naive_hybrid`, ~1.8x at 7B/256 GPUs).  The hardware is
    perfectly healthy: only the correlation tier's OFU/MFU-ratio scan
    (and divergence triage, once the reporter mean lands in the
    metadata) can see the books are cooked.  Three healthy jobs stream
    truthful MFU as the precision probe."""
    moe = _job("naive-moe-671b", "deepseek-v3-671b", seed=671, chips=288,
               flops_variant="naive_moe", true_duty=0.13)
    hyb = _job("naive-hybrid-7b", "zamba2-7b", seed=72, chips=256,
               flops_variant="naive_hybrid", true_duty=0.20)
    healthy = _healthy(3)
    specs = [moe, hyb] + healthy
    return Scenario(
        name="flops_miscalculation",
        description="two jobs report MFU from miscalculated FLOPs "
                    "counters (naive_moe ~3x, naive_hybrid ~1.8x); "
                    "counters are healthy — only the OFU<->MFU join "
                    "catches it",
        specs=specs,
        labels=[
            GroundTruthEvent("naive-moe-671b", "miscalc", 0.0,
                             magnitude=3.0,
                             note="dense-billed sparse experts"),
            GroundTruthEvent("naive-hybrid-7b", "miscalc", 0.0,
                             magnitude=1.8,
                             note="attention-billed Mamba blocks"),
            GroundTruthEvent("naive-moe-671b", "divergence", 0.0,
                             magnitude=1.9, note="rel err ~190%"),
            GroundTruthEvent("naive-hybrid-7b", "divergence", 0.0,
                             magnitude=0.85, note="rel err ~85%"),
        ],
        # every job streams its (possibly cooked) reported MFU live
        mfu_stream={s.job_id: None for s in specs},
    )


#: name -> builder; `build` is the public constructor
SCENARIOS = {
    "gloo_regression_2p5x": gloo_regression_2p5x,
    "mixed_precision_transition": mixed_precision_transition,
    "straggler_hosts": straggler_hosts,
    "thermal_throttle": thermal_throttle,
    "preemption_wave": preemption_wave,
    "moe_expert_imbalance": moe_expert_imbalance,
    "diurnal_inference": diurnal_inference,
    "flops_miscalculation": flops_miscalculation,
}


def scenario_names() -> list:
    return sorted(SCENARIOS)


def build(name: str) -> Scenario:
    """Construct a scenario by name (deterministic: same name, same
    scenario, same counter realization under a given engine)."""
    try:
        builder = SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r} "
                       f"(have {scenario_names()})") from None
    return builder()
