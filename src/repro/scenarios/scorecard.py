"""Detector scorecard: replay labeled scenarios, score the alerts.

`run_scenario` simulates a scenario's fleet once (any engine backend —
the faults are post-hoc, so they all carry identical ground truth),
replays the perturbed grids through a LIVE `Collector` via `GridSource`
(round-for-round, same code path production would run), and collects
every alert the detectors fire.

`score_alerts` matches alerts against the scenario's `GroundTruthEvent`
labels with tolerance windows:

  * an alert MATCHES a label when job ids agree, the alert kind equals
    the label's detector, and the alert fires inside
    ``[onset_s, end_s + tolerance_s]`` (end_s = end of run for
    open-ended labels);
  * **precision**  = matched alerts / fired alerts (1.0 when silent);
  * **recall**     = matched labels / labels (1.0 when nothing to find);
  * **time-to-detect** = first matching alert's collector clock minus
    the label's onset, averaged over detected labels (None if none).

`run_scorecard` sweeps the whole library into one JSON document
(schema ``fleet-scorecard-v1``), and `check_floors` enforces the pinned
per-(scenario, detector) floors — the CI contract that a detector
refactor may tighten but never silently regress.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

from repro.fleet.collector import (Collector, CollectorConfig, JobStream)
from repro.fleet.jobs import simulate_fleet
from repro.fleet.streaming import precision_label
from repro.scenarios.library import Scenario, build, scenario_names
from repro.telemetry.mfu import MfuReplaySource
from repro.telemetry.source import GridSource

SCHEMA = "fleet-scorecard-v1"


@dataclass
class ScenarioRun:
    """One replayed scenario: the collector's full alert log + handles
    for deeper inspection (recovery integration, debugging)."""

    scenario: Scenario
    alerts: list                 # every Alert the collector fired
    collector: object            # the Collector, post-run
    telemetry: list              # JobTelemetry per spec


@dataclass
class DetectorScore:
    """Precision / recall / time-to-detect for one (scenario, detector)."""

    scenario: str
    detector: str
    precision: float
    recall: float
    ttd_s: Optional[float]       # None = nothing detected (or no labels)
    n_alerts: int
    n_matched_alerts: int
    n_labels: int
    n_matched_labels: int

    def as_dict(self) -> dict:
        return {"precision": self.precision, "recall": self.recall,
                "ttd_s": self.ttd_s, "n_alerts": self.n_alerts,
                "matched_alerts": self.n_matched_alerts,
                "n_labels": self.n_labels,
                "matched_labels": self.n_matched_labels}


def run_scenario(sc: Scenario, *, engine: str = "fused",
                 max_devices: int = 4) -> ScenarioRun:
    """Simulate + replay one scenario through a live Collector."""
    tels = simulate_fleet(sc.specs, max_devices=max_devices, engine=engine)
    streams = []
    for spec, tel in zip(sc.specs, tels):
        app_mfu = sc.app_mfu.get(spec.job_id, tel.app_mfu)
        mfu_src = None
        if spec.job_id in sc.mfu_stream:
            # the job reports MFU LIVE through the app-reporter path:
            # a constant sample stream at the scrape cadence, at the
            # job's (possibly miscalculated) reported level — the
            # collector's MfuRollup + divergence metadata both follow
            # the reporter instead of a static scalar
            level = sc.mfu_stream[spec.job_id]
            mfu_src = MfuReplaySource.constant(
                tel.app_mfu if level is None else float(level),
                duration_s=spec.duration_s,
                interval_s=spec.scrape_interval_s)
            app_mfu = None
        streams.append(JobStream(
            spec.job_id, GridSource(tel.grid), chips=spec.chips,
            group=precision_label(spec.precisions), app_mfu=app_mfu,
            arch=spec.arch, flops_variant=spec.flops_variant,
            chip=spec.chip, mfu_source=mfu_src))
    col = Collector(streams, CollectorConfig(
        round_s=sc.round_s, bucket_s=sc.bucket_s, retain=sc.retain,
        detector=dict(sc.detector_kw),
        goodput=dict(sc.goodput_kw) if sc.goodput_kw is not None else None,
        flag_rel_err=sc.flag_rel_err,
        miscalc=dict(sc.miscalc_kw) if sc.miscalc_kw is not None
        else None))
    col.run()                    # GridSources are bounded: runs to the end
    return ScenarioRun(sc, list(col.alerts), col, tels)


def _label_window(sc: Scenario, lbl) -> tuple:
    end = lbl.end_s if lbl.end_s is not None else sc.duration_s
    return lbl.onset_s, end + sc.tolerance_s


def _matches(sc: Scenario, alert, lbl) -> bool:
    if alert.job_id != lbl.job_id or alert.kind != lbl.detector:
        return False
    lo, hi = _label_window(sc, lbl)
    return lo <= alert.t_s <= hi


def score_alerts(sc: Scenario, alerts: Sequence) -> dict:
    """Score one scenario's alert log: {detector: DetectorScore}."""
    out = {}
    for det in sc.detectors:
        fired = [a for a in alerts if a.kind == det]
        labels = [l for l in sc.labels if l.detector == det]
        matched_alerts = [a for a in fired
                          if any(_matches(sc, a, l) for l in labels)]
        ttds = []
        n_matched_labels = 0
        for lbl in labels:
            hits = sorted(a.t_s for a in fired if _matches(sc, a, lbl))
            if hits:
                n_matched_labels += 1
                ttds.append(hits[0] - lbl.onset_s)
        out[det] = DetectorScore(
            scenario=sc.name, detector=det,
            precision=len(matched_alerts) / len(fired) if fired else 1.0,
            recall=n_matched_labels / len(labels) if labels else 1.0,
            ttd_s=sum(ttds) / len(ttds) if ttds else None,
            n_alerts=len(fired), n_matched_alerts=len(matched_alerts),
            n_labels=len(labels), n_matched_labels=n_matched_labels)
    return out


def run_scorecard(names: Optional[Sequence[str]] = None, *,
                  engine: str = "fused", max_devices: int = 4) -> dict:
    """Replay + score scenarios into the frozen JSON document shape."""
    doc = {"schema": SCHEMA, "engine": engine, "scenarios": {}}
    for name in (names if names is not None else scenario_names()):
        sc = build(name)
        run = run_scenario(sc, engine=engine, max_devices=max_devices)
        scores = score_alerts(sc, run.alerts)
        doc["scenarios"][name] = {
            "description": sc.description,
            "n_jobs": len(sc.specs),
            "duration_s": sc.duration_s,
            "n_alerts": len(run.alerts),
            "detectors": {det: s.as_dict() for det, s in scores.items()},
        }
    return doc


# ---------------------------------------------------------------------------
# pinned floors — the CI contract
# ---------------------------------------------------------------------------
#: (scenario, detector) -> {"precision": min, "recall": min,
#: "ttd_s": max}.  Keys may pin any subset.  Values were set from the
#: measured scorecard with slack for engine-to-engine jitter; a detector
#: change may BEAT them, never regress them (tools/fleet_scorecard.py
#: --self-check fails CI on any violation).
FLOORS = {
    ("gloo_regression_2p5x", "regression"):
        {"precision": 1.0, "recall": 1.0, "ttd_s": 1200.0},
    ("gloo_regression_2p5x", "divergence"): {"precision": 1.0},
    ("gloo_regression_2p5x", "goodput"): {"precision": 1.0},
    ("mixed_precision_transition", "regression"):
        {"precision": 1.0, "recall": 1.0, "ttd_s": 1200.0},
    ("mixed_precision_transition", "divergence"):
        {"precision": 1.0, "recall": 1.0, "ttd_s": 2400.0},
    ("straggler_hosts", "regression"):
        {"precision": 1.0, "recall": 1.0, "ttd_s": 1200.0},
    ("straggler_hosts", "divergence"): {"precision": 1.0},
    ("thermal_throttle", "regression"):
        {"precision": 1.0, "recall": 1.0, "ttd_s": 1200.0},
    ("preemption_wave", "regression"):
        {"precision": 1.0, "recall": 0.85, "ttd_s": 1200.0},
    ("preemption_wave", "goodput"):
        {"precision": 1.0, "recall": 1.0, "ttd_s": 1200.0},
    ("moe_expert_imbalance", "regression"):
        {"precision": 1.0, "recall": 1.0, "ttd_s": 1200.0},
    ("diurnal_inference", "regression"): {"precision": 1.0},
    ("diurnal_inference", "divergence"): {"precision": 1.0},
    ("diurnal_inference", "goodput"): {"precision": 1.0},
    ("diurnal_inference", "miscalc"): {"precision": 1.0},
    ("flops_miscalculation", "miscalc"):
        {"precision": 1.0, "recall": 1.0, "ttd_s": 600.0},
    ("flops_miscalculation", "divergence"):
        {"precision": 1.0, "recall": 1.0, "ttd_s": 1200.0},
    ("flops_miscalculation", "regression"): {"precision": 1.0},
    ("flops_miscalculation", "goodput"): {"precision": 1.0},
}


def check_floors(doc: dict, floors: Optional[dict] = None) -> list:
    """Return human-readable floor violations (empty = scorecard holds).

    Precision/recall floors are minimums, ttd_s a maximum; a floored
    ttd_s also requires a detection (ttd None = undetected = violation).
    """
    floors = FLOORS if floors is None else floors
    bad = []
    for (scen, det), floor in sorted(floors.items()):
        entry = doc.get("scenarios", {}).get(scen, {}) \
                   .get("detectors", {}).get(det)
        if entry is None:
            bad.append(f"{scen}/{det}: missing from scorecard")
            continue
        for key in ("precision", "recall"):
            if key in floor and entry[key] < floor[key] - 1e-9:
                bad.append(f"{scen}/{det}: {key} {entry[key]:.3f} "
                           f"< floor {floor[key]:.3f}")
        if "ttd_s" in floor:
            ttd = entry.get("ttd_s")
            if ttd is None:
                bad.append(f"{scen}/{det}: no detection "
                           f"(ttd floor {floor['ttd_s']:.0f}s)")
            elif ttd > floor["ttd_s"] + 1e-9:
                bad.append(f"{scen}/{det}: ttd {ttd:.0f}s "
                           f"> floor {floor['ttd_s']:.0f}s")
    return bad
