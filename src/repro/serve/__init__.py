"""Serving layer: the read/query side of the fleet pipeline.

`FleetStore` indexes collector state into cacheable, generation-
versioned query answers; `ServiceDaemon` runs a collector on a real
wall clock (pacing, stream churn, snapshot persistence, recording tee);
`FleetAPIServer`/`FleetClient` put a stdlib-only JSON dashboard API in
front of it.  See docs/ARCHITECTURE.md § "The serving layer".
"""
from repro.serve.client import FleetAPIError, FleetClient  # noqa: F401
from repro.serve.daemon import ServiceDaemon, SimClock  # noqa: F401
from repro.serve.http import ApiError, FleetAPIServer  # noqa: F401
from repro.serve.store import FleetStore, alert_payload  # noqa: F401
