"""Serving layer: the read/query side of the fleet pipeline.

`FleetStore` indexes collector state into cacheable, generation-
versioned query answers; `ServiceDaemon` runs a collector on a real
wall clock (pacing, stream churn, snapshot persistence, recording tee);
`FleetAPIServer`/`FleetClient` put a stdlib-only JSON dashboard API in
front of it.  The WRITE half is `IngestAggregator` (sharded per-host
delta mirrors behind `POST /v1/ingest`) with `IngestClient` shipping
`delta_bytes()` blobs under capped-backoff retry.  See
docs/ARCHITECTURE.md § "The serving layer" and § "The ingest tier".
"""
from repro.serve.aggregator import (  # noqa: F401
    Backpressure, IngestAggregator, SnapshotGap)
from repro.serve.client import (  # noqa: F401
    FleetAPIError, FleetClient, IngestClient, backoff_delays)
from repro.serve.daemon import ServiceDaemon, SimClock  # noqa: F401
from repro.serve.http import ApiError, FleetAPIServer  # noqa: F401
from repro.serve.store import FleetStore, alert_payload  # noqa: F401
