"""Sharded ingest aggregator: the write half of the fleet API.

Per-host daemons ship `StreamingRollup.delta_bytes()` blobs (the v2 wire
format, `fleet.wire`); this tier turns thousands of those streams into
one queryable fleet rollup without ever centralizing raw scrapes:

  * hosts hash onto SHARDS (stable `crc32(host_id) % n_shards`), each
    shard owning an independent lock + per-host MIRROR rollups, so
    ingest scales across server threads with no global write lock;
  * a delta REPLACES the touched bucket rows of its host's mirror
    (`apply_snapshot`) — idempotent under at-least-once delivery, with
    the blob's `seq`/`since` generations ordering retries and exposing
    lost deltas as explicit gaps (HTTP 409, client re-encodes from the
    acked generation);
  * BACKPRESSURE is per shard: when more submits are in flight on one
    shard than `max_queue`, further submits are refused with a
    retry-after hint (HTTP 429 + `Retry-After`; `serve.client`'s capped
    exponential backoff honours it);
  * `fleet_rollup()` tree-reduces per-shard first, then cross-shard —
    both levels through the vectorized k-way `merge_many` — and
    `publish()` pushes the result into a `FleetStore` generation for
    the dashboard read path.

Decode happens OUTSIDE the shard lock (it is `np.frombuffer` views, but
corrupt blobs must not poison the lock), apply inside it.
"""
from __future__ import annotations

import threading
import zlib
from typing import Optional

from repro.fleet import wire
from repro.fleet.correlation import MfuRollup
from repro.fleet.streaming import StreamingRollup


class Backpressure(Exception):
    """Shard ingest queue is deep: retry after `retry_after_s`."""

    def __init__(self, shard: int, depth: int, retry_after_s: float):
        super().__init__(f"ingest shard {shard} has {depth} submits in "
                         f"flight; retry after {retry_after_s:g}s")
        self.shard = int(shard)
        self.depth = int(depth)
        self.retry_after_s = float(retry_after_s)


class SnapshotGap(Exception):
    """A delta arrived whose base generation is ahead of the mirror —
    an earlier delta was lost.  Carries the generation the aggregator
    HAS acked so the sender can re-encode from there."""

    def __init__(self, host: str, acked: int, message: str):
        super().__init__(message)
        self.host = host
        self.acked = int(acked)


class _Shard:
    __slots__ = ("lock", "gate", "mirrors", "inflight", "applied",
                 "duplicates", "gaps", "rejected", "bytes_in")

    def __init__(self):
        self.lock = threading.Lock()      # serializes mirror mutation
        self.gate = threading.Lock()      # guards the inflight counter
        self.mirrors: dict = {}           # host_id -> StreamingRollup
        self.inflight = 0
        self.applied = 0
        self.duplicates = 0
        self.gaps = 0
        self.rejected = 0
        self.bytes_in = 0


class IngestAggregator:
    """Accepts per-host delta blobs, maintains host mirrors per shard,
    reduces to one fleet rollup on demand.

    Thread-safe: `submit` from any number of server threads; shards
    contend only within themselves.  `max_queue` bounds the submits a
    single shard will hold in flight (queued on its lock) before
    refusing with `Backpressure`.
    """

    def __init__(self, *, n_shards: int = 4, max_queue: int = 32,
                 retry_after_s: float = 0.05,
                 mfu_bucket_s: float = 300.0):
        if n_shards < 1:
            raise ValueError(f"n_shards={n_shards} must be >= 1")
        if max_queue < 1:
            raise ValueError(f"max_queue={max_queue} must be >= 1")
        self.n_shards = int(n_shards)
        self.max_queue = int(max_queue)
        self.retry_after_s = float(retry_after_s)
        self._shards = [_Shard() for _ in range(self.n_shards)]
        # app-MFU samples (POST /v1/mfu) are per-JOB, not per-host, and
        # orders of magnitude lighter than counter deltas — one store
        # under one lock is plenty, no sharding needed
        self._mfu = MfuRollup(mfu_bucket_s)
        self._mfu_lock = threading.Lock()
        self.mfu_rows = 0
        self.publishes = 0

    def shard_of(self, host_id: str) -> int:
        """Stable host -> shard map (survives restarts and rescaling
        only by whole-fleet agreement — it is just crc32 mod shards)."""
        return zlib.crc32(host_id.encode()) % self.n_shards

    # -- ingest ---------------------------------------------------------
    def submit(self, host_id: str, blob) -> dict:
        """Decode + apply one delta blob from `host_id`.

        Returns ``{"applied": bool, "acked": int, "shard": int}`` where
        `acked` is the mirror's generation after the call — the cursor
        the host should delta from next.  Raises `Backpressure` when the
        shard is saturated, `SnapshotGap` on a lost-delta sequence gap,
        `ValueError` on a corrupt blob or bucketing mismatch.
        """
        if not host_id:
            raise ValueError("host_id must be non-empty")
        sid = self.shard_of(host_id)
        shard = self._shards[sid]
        with shard.gate:
            if shard.inflight >= self.max_queue:
                shard.rejected += 1
                raise Backpressure(sid, shard.inflight, self.retry_after_s)
            shard.inflight += 1
        try:
            snap = wire.decode(blob)          # zero-copy, outside the lock
            with shard.lock:
                mirror = shard.mirrors.get(host_id)
                if mirror is None:
                    mirror = StreamingRollup(
                        snap.bucket_s, bins=snap.bins,
                        lo=float(snap.edges[0]), hi=float(snap.edges[-1]))
                    mirror.edges = snap.edges.copy()
                    shard.mirrors[host_id] = mirror
                try:
                    applied = mirror.apply_snapshot(snap)
                except ValueError as e:
                    if snap.since > mirror.generation:
                        shard.gaps += 1
                        raise SnapshotGap(host_id, mirror.generation,
                                          str(e)) from None
                    raise
                shard.bytes_in += snap.nbytes
                if applied:
                    shard.applied += 1
                else:
                    shard.duplicates += 1
                acked = mirror.generation
            return {"applied": applied, "acked": acked, "shard": sid}
        finally:
            with shard.gate:
                shard.inflight -= 1

    def submit_mfu(self, payload: dict) -> dict:
        """Accumulate one POST /v1/mfu body — raw samples
        ({"job_id", "samples": [[t_s, mfu], ...]}) or a pre-bucketed
        `MfuRollup.to_payload()` dump.  Returns {"applied": rows};
        raises ValueError on a malformed body (HTTP 400)."""
        with self._mfu_lock:
            n = self._mfu.apply_payload(payload)
            self.mfu_rows += n
        return {"applied": n}

    # -- reduction + publish --------------------------------------------
    def fleet_rollup(self) -> Optional[StreamingRollup]:
        """Reduce every host mirror to one fleet rollup (None when no
        host has reported yet): per-shard k-way `merge_many` under each
        shard's lock, then one cross-shard `merge_many` — the two-level
        tree `fleet.distributed.tree_reduce` proves bucketwise-identical
        to single-process ingestion."""
        shard_views = []
        template = None
        for shard in self._shards:
            with shard.lock:
                if not shard.mirrors:
                    continue
                mirrors = list(shard.mirrors.values())
                if template is None:
                    template = mirrors[0]
                shard_views.append(
                    mirrors[0].spawn_empty().merge_many(mirrors))
        if not shard_views:
            return None
        return template.spawn_empty().merge_many(shard_views)

    def publish(self, store, *, clock_s: float = 0.0) -> int:
        """Reduce and push a new `FleetStore` generation (the rollup is
        freshly built and the MFU store snapshot-copied under its lock,
        so no further defensive copy is taken)."""
        roll = self.fleet_rollup()
        with self._mfu_lock:
            mfu = self._mfu.copy() if self._mfu.jobs else None
        self.publishes += 1
        return store.update(roll, mfu=mfu, round_idx=self.publishes,
                            clock_s=clock_s, copy=False)

    # -- observability --------------------------------------------------
    @property
    def hosts(self) -> int:
        return sum(len(s.mirrors) for s in self._shards)

    def stats(self) -> dict:
        """JSON-ready counters (the GET /v1/ingest payload)."""
        shards = [{"hosts": len(s.mirrors), "inflight": s.inflight,
                   "applied": s.applied, "duplicates": s.duplicates,
                   "gaps": s.gaps, "rejected": s.rejected,
                   "bytes_in": s.bytes_in} for s in self._shards]
        with self._mfu_lock:
            mfu_jobs = len(self._mfu.jobs)
        return {"n_shards": self.n_shards, "max_queue": self.max_queue,
                "hosts": self.hosts,
                "applied": sum(s["applied"] for s in shards),
                "duplicates": sum(s["duplicates"] for s in shards),
                "gaps": sum(s["gaps"] for s in shards),
                "rejected": sum(s["rejected"] for s in shards),
                "bytes_in": sum(s["bytes_in"] for s in shards),
                "mfu_jobs": mfu_jobs, "mfu_rows": self.mfu_rows,
                "publishes": self.publishes,
                "shards": shards}
