"""Thin Python client for the fleet dashboard API (`repro.serve.http`).

Stdlib `urllib` only.  The client keeps a per-URL (ETag, payload) cache
and sends `If-None-Match` on every repeat request: when the store
generation hasn't moved, the server answers 304 with no body and the
client returns its cached payload — the polling pattern every dashboard
widget uses, measured by `hits_304`.

    client = FleetClient(server.url)
    fleet = client.fleet()                    # GET /v1/fleet
    job = client.job("prod-llm-7b")           # GET /v1/jobs/prod-llm-7b
    worst = client.top_regressions(k=3)       # GET /v1/query?kind=...
    again = client.fleet()                    # 304 -> cached payload
"""
from __future__ import annotations

import json
from typing import Optional, Sequence
from urllib.error import HTTPError, URLError
from urllib.parse import quote, urlencode
from urllib.request import Request, urlopen


class FleetAPIError(RuntimeError):
    """A non-2xx API answer (carries the HTTP status)."""

    def __init__(self, status: int, message: str):
        super().__init__(f"HTTP {status}: {message}")
        self.status = int(status)


class FleetClient:
    """ETag-caching client over one server's base URL."""

    def __init__(self, base_url: str, *, timeout_s: float = 10.0):
        self.base_url = base_url.rstrip("/")
        self.timeout_s = float(timeout_s)
        self._cache: dict = {}        # url -> (etag, payload)
        self.requests = 0
        self.hits_304 = 0

    def _get(self, path: str, params: Optional[dict] = None) -> dict:
        url = self.base_url + path
        if params:
            url += "?" + urlencode({k: v for k, v in params.items()
                                    if v is not None})
        req = Request(url, headers={"Accept": "application/json"})
        cached = self._cache.get(url)
        if cached is not None:
            req.add_header("If-None-Match", cached[0])
        self.requests += 1
        try:
            with urlopen(req, timeout=self.timeout_s) as resp:
                etag = resp.headers.get("ETag")
                payload = json.loads(resp.read().decode())
        except HTTPError as e:
            if e.code == 304 and cached is not None:
                self.hits_304 += 1
                return cached[1]
            try:
                msg = json.loads(e.read().decode()).get("error", e.reason)
            except Exception:          # noqa: BLE001 — error body optional
                msg = str(e.reason)
            raise FleetAPIError(e.code, msg) from None
        except URLError as e:
            raise FleetAPIError(0, f"cannot reach {url}: {e.reason}") \
                from None
        if etag is not None:
            self._cache[url] = (etag, payload)
        return payload

    @staticmethod
    def _qs(qs: Optional[Sequence]) -> Optional[str]:
        return None if qs is None else ",".join(f"{q:g}" for q in qs)

    # -- endpoints ------------------------------------------------------
    def fleet(self, qs: Optional[Sequence] = None) -> dict:
        return self._get("/v1/fleet", {"qs": self._qs(qs)})

    def jobs(self) -> dict:
        return self._get("/v1/jobs")

    def job(self, job_id: str, qs: Optional[Sequence] = None) -> dict:
        return self._get(f"/v1/jobs/{quote(job_id, safe='')}",
                         {"qs": self._qs(qs)})

    def alerts(self, limit: Optional[int] = None) -> dict:
        return self._get("/v1/alerts", {"limit": limit})

    def query(self, kind: str, **params) -> dict:
        return self._get("/v1/query", {"kind": kind, **params})

    # -- conveniences over /v1/query ------------------------------------
    def top_regressions(self, k: int = 5, **detector_kw) -> dict:
        return self.query("top_regressions", k=k, **detector_kw)

    def goodput(self, healthy_ofu: Optional[float] = None) -> dict:
        return self.query("goodput", healthy_ofu=healthy_ofu)

    def divergence(self, flag_rel_err: Optional[float] = None) -> dict:
        return self.query("divergence", flag_rel_err=flag_rel_err)
