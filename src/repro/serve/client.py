"""Thin Python clients for the fleet API (`repro.serve.http`).

Stdlib `urllib` only, both directions of the wire:

  * `FleetClient` — the READ half.  Keeps a per-URL (ETag, payload)
    cache and sends `If-None-Match` on every repeat request: when the
    store generation hasn't moved, the server answers 304 with no body
    and the client returns its cached payload — the polling pattern
    every dashboard widget uses, measured by `hits_304`.  Every request
    carries a socket timeout, and transient transport failures (timeout,
    connection reset) are retried with the shared capped exponential
    backoff before surfacing as `FleetAPIError(status=0)`.
  * `IngestClient` — the WRITE half.  Owns the ack cursor for one
    host's rollup: each `push()` re-encodes `delta_bytes(acked)` and
    POSTs it to `/v1/ingest`, honouring 429 `Retry-After` (shard
    backpressure) and recovering from 409 sequence gaps by re-encoding
    from the generation the aggregator reports it HAS.

    client = FleetClient(server.url)
    fleet = client.fleet()                    # GET /v1/fleet
    job = client.job("prod-llm-7b")           # GET /v1/jobs/prod-llm-7b
    worst = client.top_regressions(k=3)       # GET /v1/query?kind=...
    again = client.fleet()                    # 304 -> cached payload

    pusher = IngestClient(server.url, "host-00", roll)
    roll.observe(...); pusher.push()          # ships only the new rows
"""
from __future__ import annotations

import json
import time
from typing import Callable, Iterator, Optional, Sequence
from urllib.error import HTTPError, URLError
from urllib.parse import quote, urlencode
from urllib.request import Request, urlopen


def backoff_delays(retries: int, *, base_s: float = 0.05,
                   cap_s: float = 2.0) -> Iterator[float]:
    """Capped exponential backoff schedule: base, 2*base, 4*base, ...
    clamped to `cap_s`, one delay per retry.  Shared by the read client
    (transient transport errors) and the ingest client (429/timeouts),
    so both halves of the wire pace themselves identically."""
    if retries < 0:
        raise ValueError(f"retries={retries} must be >= 0")
    if base_s <= 0 or cap_s <= 0:
        raise ValueError("backoff base_s and cap_s must be > 0")
    for attempt in range(retries):
        yield min(base_s * (2.0 ** attempt), cap_s)


class FleetAPIError(RuntimeError):
    """A non-2xx API answer (carries the HTTP status)."""

    def __init__(self, status: int, message: str):
        super().__init__(f"HTTP {status}: {message}")
        self.status = int(status)


class FleetClient:
    """ETag-caching client over one server's base URL.

    `timeout_s` bounds every socket operation (a stalled server can
    never hang a dashboard poll); `retries` transient transport failures
    are retried with capped exponential backoff before giving up.
    """

    def __init__(self, base_url: str, *, timeout_s: float = 10.0,
                 retries: int = 2, backoff_s: float = 0.05,
                 backoff_cap_s: float = 2.0,
                 sleep: Callable[[float], None] = time.sleep):
        self.base_url = base_url.rstrip("/")
        self.timeout_s = float(timeout_s)
        self.retries = int(retries)
        self.backoff_s = float(backoff_s)
        self.backoff_cap_s = float(backoff_cap_s)
        self._sleep = sleep
        self._cache: dict = {}        # url -> (etag, payload)
        self.requests = 0
        self.hits_304 = 0
        self.retried = 0

    def _get(self, path: str, params: Optional[dict] = None) -> dict:
        url = self.base_url + path
        if params:
            url += "?" + urlencode({k: v for k, v in params.items()
                                    if v is not None})
        req = Request(url, headers={"Accept": "application/json"})
        cached = self._cache.get(url)
        if cached is not None:
            req.add_header("If-None-Match", cached[0])
        delays = backoff_delays(self.retries, base_s=self.backoff_s,
                                cap_s=self.backoff_cap_s)
        while True:
            self.requests += 1
            try:
                with urlopen(req, timeout=self.timeout_s) as resp:
                    etag = resp.headers.get("ETag")
                    payload = json.loads(resp.read().decode())
            except HTTPError as e:
                # an HTTP answer means the server is alive — a non-2xx
                # status is the API's verdict, not a transport fault,
                # so it is never retried
                if e.code == 304 and cached is not None:
                    self.hits_304 += 1
                    return cached[1]
                try:
                    msg = json.loads(e.read().decode()).get("error",
                                                            e.reason)
                except Exception:      # noqa: BLE001 — error body optional
                    msg = str(e.reason)
                raise FleetAPIError(e.code, msg) from None
            # HTTPError subclasses URLError subclasses OSError, and
            # socket.timeout is TimeoutError — order matters above
            except (TimeoutError, URLError, OSError) as e:
                reason = getattr(e, "reason", e)
                delay = next(delays, None)
                if delay is None:
                    raise FleetAPIError(
                        0, f"cannot reach {url}: {reason}") from None
                self.retried += 1
                self._sleep(delay)
                continue
            if etag is not None:
                self._cache[url] = (etag, payload)
            return payload

    @staticmethod
    def _qs(qs: Optional[Sequence]) -> Optional[str]:
        return None if qs is None else ",".join(f"{q:g}" for q in qs)

    # -- endpoints ------------------------------------------------------
    def fleet(self, qs: Optional[Sequence] = None) -> dict:
        return self._get("/v1/fleet", {"qs": self._qs(qs)})

    def jobs(self) -> dict:
        return self._get("/v1/jobs")

    def job(self, job_id: str, qs: Optional[Sequence] = None) -> dict:
        return self._get(f"/v1/jobs/{quote(job_id, safe='')}",
                         {"qs": self._qs(qs)})

    def alerts(self, limit: Optional[int] = None) -> dict:
        return self._get("/v1/alerts", {"limit": limit})

    def query(self, kind: str, **params) -> dict:
        return self._get("/v1/query", {"kind": kind, **params})

    # -- conveniences over /v1/query ------------------------------------
    def top_regressions(self, k: int = 5, **detector_kw) -> dict:
        return self.query("top_regressions", k=k, **detector_kw)

    def goodput(self, healthy_ofu: Optional[float] = None) -> dict:
        return self.query("goodput", healthy_ofu=healthy_ofu)

    def divergence(self, flag_rel_err: Optional[float] = None,
                   ofu_floor: Optional[float] = None) -> dict:
        return self.query("divergence", flag_rel_err=flag_rel_err,
                          ofu_floor=ofu_floor)

    def correlation(self, **params) -> dict:
        """kind=correlation: the OFU<->MFU join report (params:
        ratio_high, ratio_low, min_buckets, ofu_floor, window)."""
        return self.query("correlation", **params)

    def post_mfu(self, job_id: str, samples) -> dict:
        """Ship app-reported MFU samples ([[t_s, mfu], ...] pairs, or
        `telemetry.mfu.MfuSample`s) to POST /v1/mfu.  One plain POST, no
        cursor: MFU rows are additive observations, so at-least-once
        delivery only needs the caller not to re-send the same batch."""
        rows = [[s.t_s, s.mfu] if hasattr(s, "mfu") else
                [float(s[0]), float(s[1])] for s in samples]
        body = json.dumps({"job_id": job_id, "samples": rows}).encode()
        url = self.base_url + "/v1/mfu"
        req = Request(url, data=body, method="POST",
                      headers={"Content-Type": "application/json"})
        delays = backoff_delays(self.retries, base_s=self.backoff_s,
                                cap_s=self.backoff_cap_s)
        while True:
            self.requests += 1
            try:
                with urlopen(req, timeout=self.timeout_s) as resp:
                    return json.loads(resp.read().decode())
            except HTTPError as e:
                try:
                    msg = json.loads(e.read().decode()).get("error",
                                                            e.reason)
                except Exception:  # noqa: BLE001 — error body optional
                    msg = str(e.reason)
                raise FleetAPIError(e.code, msg) from None
            except (TimeoutError, URLError, OSError) as e:
                reason = getattr(e, "reason", e)
                delay = next(delays, None)
                if delay is None:
                    raise FleetAPIError(
                        0, f"cannot reach {url}: {reason}") from None
                self.retried += 1
                self._sleep(delay)
                continue


class IngestClient:
    """One host's delta shipper: POSTs `rollup.delta_bytes(acked)` to
    `/v1/ingest` and advances the ack cursor from the server's answer.

    The cursor (`acked`) makes delivery self-healing: a duplicate POST
    is a no-op on the server (the blob's seq orders it out), a 409 gap
    answer resets the cursor to what the aggregator HAS so the next
    encode carries everything it is missing, and a 429 waits out the
    shard's `Retry-After` hint (never less than the local backoff step,
    never more than `backoff_cap_s`).
    """

    def __init__(self, base_url: str, host_id: str, rollup, *,
                 timeout_s: float = 10.0, retries: int = 5,
                 backoff_s: float = 0.05, backoff_cap_s: float = 2.0,
                 sleep: Callable[[float], None] = time.sleep):
        if not host_id:
            raise ValueError("host_id must be non-empty")
        self.base_url = base_url.rstrip("/")
        self.host_id = host_id
        self.rollup = rollup
        self.timeout_s = float(timeout_s)
        self.retries = int(retries)
        self.backoff_s = float(backoff_s)
        self.backoff_cap_s = float(backoff_cap_s)
        self._sleep = sleep
        self.acked = 0                # server-confirmed generation
        self.pushes = 0
        self.backpressure_hits = 0

    def push(self) -> dict:
        """Ship everything newer than the ack cursor; returns the
        server's answer ({"applied", "acked", "shard", ...}).

        The delta is RE-ENCODED from the live rollup on every attempt —
        rows observed while waiting out a 429 ride along on the retry
        instead of needing their own round trip.
        """
        url = self.base_url + "/v1/ingest"
        delays = backoff_delays(self.retries, base_s=self.backoff_s,
                                cap_s=self.backoff_cap_s)
        resyncs = 0
        while True:
            blob = self.rollup.delta_bytes(self.acked)
            req = Request(url, data=blob, method="POST",
                          headers={"Content-Type":
                                   "application/octet-stream",
                                   "X-Fleet-Host": self.host_id})
            self.pushes += 1
            try:
                with urlopen(req, timeout=self.timeout_s) as resp:
                    out = json.loads(resp.read().decode())
            except HTTPError as e:
                try:
                    body = json.loads(e.read().decode())
                except Exception:      # noqa: BLE001 — error body optional
                    body = {}
                if e.code == 429:
                    self.backpressure_hits += 1
                    delay = next(delays, None)
                    if delay is None:
                        raise FleetAPIError(
                            429, body.get("error",
                                          "shard backpressure")) from None
                    hint = body.get("retry_after_s") \
                        or e.headers.get("Retry-After") or 0.0
                    self._sleep(min(max(float(hint), delay),
                                    self.backoff_cap_s))
                    continue
                if e.code == 409 and "acked" in body:
                    # sequence gap: the aggregator lost a delta (or was
                    # restarted) — resync the cursor to what it HAS and
                    # re-encode; no backoff, this converges in one hop
                    # (the bound only guards a server that keeps moving)
                    resyncs += 1
                    if resyncs > self.retries + 1:
                        raise FleetAPIError(
                            409, body.get("error",
                                          "gap resync loop")) from None
                    self.acked = int(body["acked"])
                    continue
                raise FleetAPIError(
                    e.code, body.get("error", str(e.reason))) from None
            except (TimeoutError, URLError, OSError) as e:
                reason = getattr(e, "reason", e)
                delay = next(delays, None)
                if delay is None:
                    raise FleetAPIError(
                        0, f"cannot reach {url}: {reason}") from None
                self._sleep(delay)
                continue
            self.acked = int(out["acked"])
            return out
