"""`ServiceDaemon`: the wall-clock deployment mode of the collector.

`Collector.poll_round()` advances *simulated* time; a deployed daemon
(paper §VI — the thing that watched the fleet live) needs the missing
operational half, and this module is it:

  * REAL PACING — rounds fire on a wall-clock cadence with drift
    correction: the k-th round's deadline is `origin + k·round_s`, so a
    slow round eats its own slack instead of shifting every later round
    (an overrun skips the sleep and is counted, never "caught up" by
    polling faster).  The clock and sleep are injectable (`SimClock`)
    so tests and self-checks run the same loop in microseconds.
  * PUBLISHING — after every round the collector's state is published
    into a `FleetStore` generation, which `repro.serve.http` serves to
    dashboard pollers.
  * STREAM CHURN — `request_add_stream` / `request_remove_stream` queue
    changes from any thread; the daemon applies them between rounds, so
    jobs join and leave a live fleet without a restart.
  * PERSISTENCE — every `persist_every` rounds the windowed rollup,
    collector clock, per-stream cursors, alert history, and open
    alert-episode hysteresis are written atomically to `state_dir`;
    `ServiceDaemon.restore()` rebuilds the daemon after a process
    restart, replay sources `seek()` back to their cursors, and an
    episode that was open at the last persist does NOT re-page.
  * RECORDING TEE — with `tee_dir` set, every polled grid also appends
    to a per-job columnar `TraceWriter` (`<tee_dir>/<job_id>.ctr`),
    via the collector's `on_grid` round hook.  Tee manifests flush at
    every persistence point, so a kill -9 leaves REPLAYABLE archives
    covering everything up to the last persist; on restore the tee
    reopens in append mode and skips any overlap a mid-flight chunk
    flush already archived.  Archives are uniform-cadence, so the tee
    cannot be combined with adaptive retiming (rejected up front).

Clean shutdown is `close()` (or the context manager): final persist,
tee flush, writer close.  A crash skips all of that by definition —
which is exactly what the persistence points are for.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Optional

from repro.fleet.collector import (Collector, FleetCollector,
                                   _require_bounded)
from repro.fleet.streaming import StreamingRollup
from repro.serve.store import FleetStore
from repro.telemetry import tracestore
from repro.telemetry.tracestore import TraceWriter

STATE_NAME = "daemon_state.json"
ROLLUP_NAME = "rollup.snapshot"
STATE_FORMAT = "fleet-serve-state-v1"


class SimClock:
    """Deterministic (clock, sleep) pair for tests and self-checks:
    `sleep()` advances the clock instantly and records the request, so a
    paced daemon run finishes in microseconds while exercising the exact
    deadline arithmetic a real deployment uses."""

    def __init__(self, t0: float = 0.0):
        self.t = float(t0)
        self.sleeps: list = []

    def monotonic(self) -> float:
        return self.t

    def sleep(self, dt: float) -> None:
        if dt < 0:
            raise ValueError(f"sleep({dt}) is negative")
        self.sleeps.append(float(dt))
        self.t += dt

    def advance(self, dt: float) -> None:
        """Model work taking `dt` seconds of wall time."""
        self.t += float(dt)


class ServiceDaemon:
    """Runs a `Collector` (or `FleetCollector`) on a wall-clock cadence,
    publishing every round into a `FleetStore`.

    Persistence and the recording tee require a plain `Collector` (a
    `FleetCollector`'s per-host state lives with its hosts); serving and
    pacing work for both.
    """

    def __init__(self, collector, *, store: Optional[FleetStore] = None,
                 state_dir: Optional[str] = None, persist_every: int = 0,
                 tee_dir: Optional[str] = None,
                 tee_chunk_samples: int = 1024,
                 clock=time.monotonic, sleep=None, pace: bool = True,
                 on_round=None):
        """`clock`/`sleep` inject a time source (see `SimClock`).  The
        default real-clock sleep waits on the stop event, so `stop()`
        (e.g. wired to SIGTERM) interrupts an inter-round sleep
        immediately instead of after up to `round_s` seconds.

        on_round: optional callback invoked with each round's report
        AFTER that round's store generation is published (and persisted,
        when due) but before pacing — the synchronization point for
        anything downstream of the publish: tests gate round advancement
        on pollers having observed the new generation (a SimClock-paced
        run costs no wall time, so free-running readers would otherwise
        race the whole run), deployments emit per-round metrics.  May be
        reassigned on a live daemon; takes effect next round."""
        if persist_every < 0:
            raise ValueError(f"persist_every={persist_every} must be >= 0")
        if persist_every and not state_dir:
            raise ValueError("persist_every needs a state_dir")
        is_fleet = isinstance(collector, FleetCollector)
        if is_fleet and (state_dir or tee_dir):
            raise ValueError(
                "snapshot persistence and the recording tee need a plain "
                "Collector; a FleetCollector's state lives with its hosts")
        self.collector = collector
        self.store = store if store is not None else FleetStore()
        self.state_dir = state_dir
        self.persist_every = int(persist_every)
        self.tee_dir = tee_dir
        self.tee_chunk_samples = int(tee_chunk_samples)
        self._clock = clock
        self._sleep = sleep
        self.pace = bool(pace)
        self.on_round = on_round
        self._is_fleet = is_fleet
        self._churn_lock = threading.Lock()
        self._churn: list = []
        self._stop = threading.Event()
        self._writers: dict = {}       # job_id -> TraceWriter
        self._closed = False
        self.rounds = 0                # rounds THIS process has run
        self.overruns = 0              # rounds that blew their deadline
        if tee_dir:
            if collector.on_grid is not None:
                raise ValueError("collector already has an on_grid hook; "
                                 "the tee needs it")
            if collector.config.adaptive is not None:
                # archives are uniform-cadence: the first retiming would
                # make the next grid unappendable and crash the loop —
                # reject the combination up front instead
                raise ValueError(
                    "recording tee and adaptive scrape retiming cannot "
                    "be combined: a retimed source changes interval "
                    "mid-archive; record with fixed intervals (drop "
                    "CollectorConfig.adaptive) or drop tee_dir")
            os.makedirs(tee_dir, exist_ok=True)
            collector.on_grid = self._tee
        # publish generation 1 up front so the HTTP API answers (with
        # whatever restored/empty state we have) before the first round
        self.store.update_from(collector)

    # -- cadence --------------------------------------------------------
    @property
    def round_s(self) -> float:
        if self._is_fleet:
            return max(c.config.round_s for c in self.collector.collectors)
        return self.collector.config.round_s

    @property
    def done(self) -> bool:
        return self.collector.done

    # -- stream churn ---------------------------------------------------
    def request_add_stream(self, stream) -> None:
        """Queue a stream to join before the next round (thread-safe)."""
        self._require_plain("stream churn")
        with self._churn_lock:
            self._churn.append(("add", stream))

    def request_remove_stream(self, job_id: str) -> None:
        """Queue a stream to leave before the next round (thread-safe)."""
        self._require_plain("stream churn")
        with self._churn_lock:
            self._churn.append(("remove", job_id))

    def _apply_churn(self) -> None:
        with self._churn_lock:
            ops, self._churn = self._churn, []
        for op, arg in ops:
            if op == "add":
                self.collector.add_stream(arg)
            else:
                st = self.collector.remove_stream(arg)
                w = self._writers.pop(st.job_id, None)
                if w is not None:
                    w.close()

    def _require_plain(self, what: str) -> None:
        if self._is_fleet:
            raise ValueError(f"{what} needs a plain Collector "
                             "(FleetCollector hosts own their streams)")

    # -- recording tee --------------------------------------------------
    def _tee(self, stream, grid) -> None:
        w = self._writers.get(stream.job_id)
        if w is None:
            path = os.path.join(self.tee_dir, f"{stream.job_id}.ctr")
            if tracestore.is_archive(path):
                # restart: continue the pre-crash archive.  Anything a
                # mid-flight chunk flush already persisted beyond the
                # restored cursor will be re-polled by the resumed
                # deterministic replay — skip the overlap, don't re-append
                w = TraceWriter(path, grid.interval_s, grid.n_devices,
                                chunk_samples=self.tee_chunk_samples,
                                append=True)
            else:
                w = TraceWriter(path, grid.interval_s, grid.n_devices,
                                chunk_samples=self.tee_chunk_samples,
                                t0_s=grid.t0_s)
            self._writers[stream.job_id] = w
        overlap_s = w.end_s - grid.t0_s
        if w.total_samples and overlap_s > 1e-6 * w.interval_s:
            skip = int(round(overlap_s / w.interval_s))
            if skip >= grid.tpa.shape[1]:
                return                      # whole grid already archived
            w.append(grid.tpa[:, skip:], grid.clock_mhz[:, skip:])
        else:
            w.append_grid(grid)

    # -- persistence ----------------------------------------------------
    def persist(self) -> None:
        """Atomically write restart state; also the tee crash-safety
        point (every writer's manifest flushes here, buffered tail
        included)."""
        self._require_plain("snapshot persistence")
        if not self.state_dir:
            raise ValueError("no state_dir configured")
        os.makedirs(self.state_dir, exist_ok=True)
        for w in self._writers.values():
            w.flush(partial=True)
        blob = self.collector.snapshot()
        roll_path = os.path.join(self.state_dir, ROLLUP_NAME)
        tmp = roll_path + ".tmp"
        with open(tmp, "wb") as fh:
            fh.write(blob)
        os.replace(tmp, roll_path)
        state = {
            "format": STATE_FORMAT,
            "round_idx": self.collector.round_idx,
            "clock_s": self.collector.clock_s,
            "cursors": {st.job_id: st.source.cursor_s
                        for st in self.collector.streams},
            "rollup_file": ROLLUP_NAME,
            "alerts": self.collector.alert_state(),
        }
        # rollup first, manifest last: state.json always points at a
        # complete snapshot, whatever instant the process dies
        tmp = os.path.join(self.state_dir, STATE_NAME + ".tmp")
        with open(tmp, "w") as fh:
            json.dump(state, fh, indent=1)
            fh.write("\n")
        os.replace(tmp, os.path.join(self.state_dir, STATE_NAME))

    @classmethod
    def restore(cls, state_dir: str, streams, config=None,
                **daemon_kw) -> "ServiceDaemon":
        """Rebuild a daemon from `persist()` output: restored windowed
        rollup + collector clock/round, and every stream whose persisted
        cursor is nonzero `seek()`ed back to it.  Pass fresh `streams`
        (same job_ids) and the same `CollectorConfig`.  The alert log
        and open-episode hysteresis restore too: the resumed collector
        remembers every alert it already fired, and a collapse that was
        being tracked at persist time refreshes its episode silently
        instead of paging a duplicate on the first post-restart round.
        (State persisted by a pre-alert-state daemon restores with an
        empty log — the old re-fire-once behavior.)"""
        mf = os.path.join(state_dir, STATE_NAME)
        if not os.path.isfile(mf):
            raise ValueError(f"{state_dir!r} holds no daemon state "
                             f"(no {STATE_NAME})")
        with open(mf) as fh:
            state = json.load(fh)
        if state.get("format") != STATE_FORMAT:
            raise ValueError(f"unknown daemon state format "
                             f"{state.get('format')!r} in {state_dir!r}")
        with open(os.path.join(state_dir,
                               state.get("rollup_file", ROLLUP_NAME)),
                  "rb") as fh:
            roll = StreamingRollup.from_bytes(fh.read())
        cursors = state.get("cursors", {})
        unseekable = []
        for st in streams:
            cur = float(cursors.get(st.job_id, 0.0))
            if cur <= 0.0:
                continue
            if hasattr(st.source, "seek"):
                st.source.seek(cur)
            else:
                unseekable.append(st.job_id)
        if unseekable:
            raise ValueError(
                f"streams {unseekable} had nonzero persisted cursors but "
                "their sources cannot seek(); a mid-stream restore needs "
                "replayable sources")
        col = Collector(streams, config, rollup=roll,
                        clock_s=float(state["clock_s"]),
                        round_idx=int(state["round_idx"]))
        col.restore_alert_state(state.get("alerts", {}))
        daemon_kw.setdefault("state_dir", state_dir)
        return cls(col, **daemon_kw)

    # -- the loop -------------------------------------------------------
    def stop(self) -> None:
        """Ask a running `run()` loop (any thread) to exit: interrupts a
        default-clock pacing sleep immediately, then exits after the
        round in flight — wire this to SIGTERM for clean shutdown."""
        self._stop.set()

    def run(self, n_rounds: Optional[int] = None) -> list:
        """Paced round loop; returns the collected round reports.

        Exits when every stream is exhausted, `n_rounds` rounds have
        run, or `stop()` is called.  Does NOT close the daemon — the
        tee's buffered tail and a final persist happen in `close()`
        (or at the next persistence point), so a crash-kill test can
        observe exactly the crash-safe on-disk state.
        """
        if self._closed:
            raise ValueError("ServiceDaemon is closed")
        if n_rounds is None:
            streams = (self.collector.streams if not self._is_fleet else
                       [st for c in self.collector.collectors
                        for st in c.streams])
            _require_bounded(streams)
        self._stop.clear()
        origin = self._clock()
        start_round = self.rounds
        reports = []
        while not self._stop.is_set() \
                and (n_rounds is None or len(reports) < n_rounds):
            self._apply_churn()
            if self.collector.done:
                break
            reports.append(self.collector.poll_round())
            self.rounds += 1
            self.store.update_from(self.collector)
            if self.persist_every \
                    and self.rounds % self.persist_every == 0:
                self.persist()
            if self.on_round is not None:
                self.on_round(reports[-1])
            if self.pace and not self.collector.done:
                deadline = origin \
                    + (self.rounds - start_round) * self.round_s
                now = self._clock()
                if now < deadline - 1e-9:
                    if self._sleep is None:       # real clock: stoppable
                        self._stop.wait(deadline - now)
                    else:
                        self._sleep(deadline - now)
                else:
                    self.overruns += 1
        return reports

    # -- shutdown -------------------------------------------------------
    def close(self) -> None:
        """Clean shutdown: final persist (when configured), tee flush +
        close.  Idempotent."""
        if self._closed:
            return
        self._closed = True
        if self.state_dir and not self._is_fleet:
            self.persist()
        for w in self._writers.values():
            w.close()
        self._writers.clear()

    def __enter__(self) -> "ServiceDaemon":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
