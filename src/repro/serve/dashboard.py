"""The human half of the serve tier: one static HTML page.

`GET /dashboard` returns this page verbatim — no templating, no build
step, no external assets.  Everything dynamic happens client-side: a
few lines of inline JavaScript poll the same `/v1` JSON API every
machine client uses (`/v1/query?kind=series&scope=fleet`,
`kind=top_regressions`, `/v1/alerts`) and redraw an inline-SVG fleet
OFU chart, the top-regressions table, and the open-alerts panel.
Because the polls are plain conditional GETs, the browser's cache plus
the server's ETag/304 path make an idle dashboard cost generation-cache
lookups, not rollup readouts — the §II "instant visibility" property
holds for a human watching the page, too.
"""
from __future__ import annotations

DASHBOARD_TITLE = "fleet OFU dashboard"

#: client poll cadence; rollups only move once per collector round, so
#: anything faster just exercises the 304 path
POLL_MS = 5000

DASHBOARD_HTML = """<!DOCTYPE html>
<html lang="en">
<head>
<meta charset="utf-8">
<title>""" + DASHBOARD_TITLE + """</title>
<style>
  body { font: 14px/1.4 system-ui, sans-serif; margin: 1.5em;
         background: #111; color: #ddd; }
  h1 { font-size: 1.2em; } h2 { font-size: 1em; color: #9ad; }
  .panel { background: #1a1a1a; border: 1px solid #333;
           border-radius: 6px; padding: .8em 1em; margin: .8em 0; }
  table { border-collapse: collapse; width: 100%; }
  th, td { text-align: left; padding: .2em .6em; }
  th { color: #888; border-bottom: 1px solid #333; }
  .ok { color: #7c7; } .bad { color: #e77; } .dim { color: #777; }
  #headline { font-size: 1.6em; }
  svg { width: 100%; height: 180px; background: #161616; }
</style>
</head>
<body>
<h1>""" + DASHBOARD_TITLE + """ <span id="status" class="dim"></span></h1>
<div class="panel">
  <h2>fleet OFU (weighted: <span id="headline" class="ok">&ndash;</span>)</h2>
  <svg id="chart" viewBox="0 0 600 180" preserveAspectRatio="none"></svg>
  <div class="dim" id="chartmeta"></div>
</div>
<div class="panel">
  <h2>top regressions</h2>
  <table id="regs"><thead><tr><th>job</th><th>factor</th>
    <th>ref OFU</th><th>low OFU</th><th>buckets</th><th>state</th>
  </tr></thead><tbody></tbody></table>
</div>
<div class="panel">
  <h2>alerts (<span id="nalerts">0</span> fired,
      <span id="nopen">0</span> open)</h2>
  <table id="alerts"><thead><tr><th>kind</th><th>job</th>
    <th>detail</th></tr></thead><tbody></tbody></table>
</div>
<script>
"use strict";
const fmt = (x, d) => x == null ? "\\u2013" : Number(x).toFixed(d);

function drawChart(s) {
  const t = s.t_s || [], mean = s.mean || [];
  const pct = s.percentiles || {};
  const lo = pct["10"] || [], hi = pct["90"] || [];
  const svg = document.getElementById("chart");
  if (t.length < 1) { svg.innerHTML = ""; return; }
  const W = 600, H = 180, pad = 6;
  const t0 = t[0], t1 = t[t.length - 1] || t0 + 1;
  const x = v => t1 > t0 ? pad + (W - 2 * pad) * (v - t0) / (t1 - t0)
                         : W / 2;
  const y = v => H - pad - (H - 2 * pad) * Math.min(Math.max(v, 0), 1);
  const path = (ts, vs) => ts.map((tv, i) => vs[i] == null ? "" :
      (i && vs[i - 1] != null ? "L" : "M") +
      x(tv).toFixed(1) + " " + y(vs[i]).toFixed(1)).join(" ");
  let band = "";
  if (lo.length === t.length && hi.length === t.length &&
      lo.every(v => v != null) && hi.every(v => v != null)) {
    const up = t.map((tv, i) => x(tv).toFixed(1) + "," +
                                y(hi[i]).toFixed(1));
    const dn = t.map((tv, i) => x(tv).toFixed(1) + "," +
                                y(lo[i]).toFixed(1)).reverse();
    band = '<polygon points="' + up.concat(dn).join(" ") +
           '" fill="#9ad3" stroke="none"/>';
  }
  svg.innerHTML = band + '<path d="' + path(t, mean) +
      '" fill="none" stroke="#9ad" stroke-width="1.5"/>';
  document.getElementById("chartmeta").textContent =
      t.length + " buckets of " + fmt(s.bucket_s, 0) + "s, mean " +
      "(line) with p10\\u2013p90 band";
}

function drawRegs(r) {
  const body = document.querySelector("#regs tbody");
  body.innerHTML = "";
  for (const g of r.regressions || []) {
    const tr = document.createElement("tr");
    const span = g.end_bucket == null ? g.start_bucket + "\\u2013" :
        g.start_bucket + "\\u2013" + g.end_bucket;
    for (const v of [g.job_id, fmt(g.factor, 2) + "\\u00d7",
                     fmt(g.ref_ofu, 3), fmt(g.low_ofu, 3), span,
                     g.ongoing ? "ONGOING" : "resolved"]) {
      const td = document.createElement("td");
      td.textContent = String(v);
      tr.appendChild(td);
    }
    if (g.ongoing) tr.className = "bad";
    body.appendChild(tr);
  }
}

function drawAlerts(a) {
  document.getElementById("nalerts").textContent = a.total || 0;
  document.getElementById("nopen").textContent =
      (a.active_episodes || []).length;
  const body = document.querySelector("#alerts tbody");
  body.innerHTML = "";
  for (const al of (a.alerts || []).slice(-20).reverse()) {
    const tr = document.createElement("tr");
    for (const v of [al.kind, al.job_id,
                     al.message || JSON.stringify(al)]) {
      const td = document.createElement("td");
      td.textContent = String(v == null ? "\\u2013" : v);
      tr.appendChild(td);
    }
    body.appendChild(tr);
  }
}

async function poll() {
  const st = document.getElementById("status");
  try {
    const [series, regs, alerts] = await Promise.all([
      fetch("/v1/query?kind=series&scope=fleet").then(r => r.json()),
      fetch("/v1/query?kind=top_regressions&k=10").then(r => r.json()),
      fetch("/v1/alerts").then(r => r.json()),
    ]);
    document.getElementById("headline").textContent =
        series.weighted_ofu == null ? "no data yet"
        : (100 * series.weighted_ofu).toFixed(1) + "%";
    drawChart(series);
    drawRegs(regs);
    drawAlerts(alerts);
    st.textContent = "live \\u00b7 gen " + (series.generation ?? "?");
  } catch (e) {
    st.textContent = "unreachable: " + e;
  }
}
poll();
setInterval(poll, """ + str(POLL_MS) + """);
</script>
</body>
</html>
"""
