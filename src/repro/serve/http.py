"""Stdlib-only JSON API over a `FleetStore` — the dashboard wire.

Five endpoint families (JSON in both directions except ingest blobs):

    /v1/fleet                    fleet OFU series (+ ?qs=10,50,90)
    /v1/jobs                     the monitored population
    /v1/jobs/<job_id>            one job's series + ingest metadata
    /v1/alerts                   fired alerts + open episodes (?limit=N)
    /v1/query?kind=...           structured queries:
        kind=top_regressions     &k=5&window=4&min_duration=2
                                 &factor_threshold=1.5
        kind=goodput             &healthy_ofu=0.40
        kind=divergence          &flag_rel_err=0.30&ofu_floor=0.02
        kind=correlation         &ratio_high=1.5&ratio_low=&min_buckets=1
                                 &ofu_floor=0.02&window=8 — the OFU<->MFU
                                 join (r with/without the flagged set,
                                 per-scale error table, miscalc findings)
        kind=series              &scope=fleet|job|group&id=...&qs=...
    /v1/mfu                      app-MFU ingest (needs an aggregator):
        POST                     JSON body {"job_id", "samples":
                                 [[t_s, mfu], ...]} or an
                                 `MfuRollup.to_payload()` bucket dump;
                                 200 {"applied"} rows accepted
    /v1/ingest                   the WRITE half (needs an aggregator):
        POST                     body = `StreamingRollup.delta_bytes()`
                                 blob, `X-Fleet-Host: <host-id>` header;
                                 200 {"applied", "acked", "shard"},
                                 409 + {"acked"} on a sequence gap
                                 (re-encode from `acked`), 429 +
                                 `Retry-After` under shard backpressure
        GET                      aggregator counters (hosts/applied/
                                 duplicates/gaps/rejected per shard)
    /dashboard                   the HUMAN client: one static HTML page
                                 (`repro.serve.dashboard`) whose inline
                                 JS polls the JSON API above

Every response carries an `ETag` derived from the store GENERATION plus
a per-process boot nonce (so validators never collide across daemon
restarts), and a matching `If-None-Match` is answered with an empty 304
— the query itself is a generation-cache dict hit, so a dashboard
polling every few seconds between collector rounds costs lookups, not
readouts.  Invalid paths/params stay 404/400 even when the client's
validator is current (routing runs before the ETag check).

`FleetAPIServer` wraps `ThreadingHTTPServer` on an ephemeral port by
default (`port=0`), serving from a background thread — the shape both
the CLI (`tools/fleet_serve.py`) and the tests use.  No dependencies
beyond the standard library: deploying the dashboard API costs nothing
the collector didn't already cost.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional
from urllib.parse import parse_qs, unquote, urlsplit

from repro.serve.aggregator import Backpressure, SnapshotGap
from repro.serve.dashboard import DASHBOARD_HTML
from repro.serve.store import FleetStore


class ApiError(Exception):
    """An HTTP-mappable request error."""

    def __init__(self, status: int, message: str):
        super().__init__(message)
        self.status = int(status)


def _num(params: dict, key: str, default, cast=float):
    raw = params.get(key)
    if raw is None:
        return default
    try:
        val = cast(raw)
    except ValueError:
        raise ApiError(400, f"query param {key}={raw!r} is not a "
                       f"{cast.__name__}") from None
    # nan/inf would poison cache keys (nan != nan) and leak bare NaN
    # tokens into response bodies — the wire format is strict JSON
    if val != val or val in (float("inf"), float("-inf")):
        raise ApiError(400, f"query param {key}={raw!r} must be finite")
    return val


def _qs_param(params: dict) -> tuple:
    raw = params.get("qs")
    if raw is None:
        return (10, 50, 90)
    try:
        qs = tuple(float(x) for x in raw.split(",") if x.strip())
    except ValueError:
        raise ApiError(400, f"qs={raw!r} must be comma-separated "
                       "percentiles") from None
    if not qs or not all(0 <= q <= 100 for q in qs):
        raise ApiError(400, f"qs={raw!r} must hold percentiles in "
                       "[0, 100]")
    return qs


def _route(store: FleetStore, path: str, params: dict) -> dict:
    parts = [unquote(p) for p in path.split("/") if p]
    if not parts or parts[0] != "v1":
        raise ApiError(404, f"unknown path {path!r} (API root is /v1)")
    rest = parts[1:]
    try:
        if rest == ["fleet"]:
            return store.fleet_series(qs=_qs_param(params))
        if rest == ["jobs"]:
            return store.jobs()
        if len(rest) == 2 and rest[0] == "jobs":
            return store.job_series(rest[1], qs=_qs_param(params))
        if rest == ["alerts"]:
            limit = _num(params, "limit", None, int)
            return store.alerts(limit=limit)
        if rest == ["query"]:
            return _query(store, params)
    except KeyError as e:
        raise ApiError(404, str(e.args[0]) if e.args else "not found") \
            from None
    except ValueError as e:
        raise ApiError(400, str(e)) from None
    raise ApiError(404, f"unknown path {path!r}")


def _query(store: FleetStore, params: dict) -> dict:
    kind = params.get("kind")
    if kind == "top_regressions":
        kw = {}
        for name, cast in (("window", int), ("min_duration", int),
                           ("factor_threshold", float)):
            val = _num(params, name, None, cast)
            if val is not None:
                kw[name] = val
        return store.top_regressions(k=_num(params, "k", 5, int), **kw)
    if kind == "goodput":
        return store.goodput(
            healthy_ofu=_num(params, "healthy_ofu", 0.40))
    if kind == "divergence":
        return store.divergence(
            flag_rel_err=_num(params, "flag_rel_err", 0.30),
            ofu_floor=_num(params, "ofu_floor", 0.02))
    if kind == "correlation":
        return store.correlation(
            ratio_high=_num(params, "ratio_high", 1.5),
            ratio_low=_num(params, "ratio_low", None),
            min_buckets=_num(params, "min_buckets", 1, int),
            ofu_floor=_num(params, "ofu_floor", 0.02),
            window=_num(params, "window", 8, int))
    if kind == "series":
        scope = params.get("scope", "fleet")
        name = params.get("id")
        qs = _qs_param(params)
        if scope == "fleet":
            return store.fleet_series(qs=qs)
        if scope == "job":
            if not name:
                raise ApiError(400, "scope=job needs an id param")
            return store.job_series(name, qs=qs)
        if scope == "group":
            if not name:
                raise ApiError(400, "scope=group needs an id param")
            return store.group_series(name, qs=qs)
        raise ApiError(400, f"unknown scope {scope!r}")
    raise ApiError(400, f"unknown query kind {kind!r} (want "
                   "top_regressions, goodput, divergence, correlation, "
                   "or series)")


def _make_handler(store: FleetStore, aggregator=None):
    class Handler(BaseHTTPRequestHandler):
        server_version = "repro-fleet-serve/1"
        protocol_version = "HTTP/1.1"

        def log_message(self, *args):     # quiet: this is a library
            pass

        def _send(self, status: int, payload: dict,
                  etag: Optional[str] = None,
                  headers: Optional[dict] = None) -> None:
            try:
                # the wire format is STRICT JSON: a NaN that slipped
                # past the store's cleaning must fail here, not emit a
                # bare token no conforming parser accepts
                body = json.dumps(payload, allow_nan=False).encode()
            except ValueError as e:
                status = 500
                body = json.dumps({"error": f"non-finite value in "
                                   f"response payload ({e})",
                                   "path": self.path}).encode()
                etag = None
            self.send_response(status)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(body)))
            self.send_header("Cache-Control", "no-cache")
            if etag is not None:
                self.send_header("ETag", etag)
            for name, val in (headers or {}).items():
                self.send_header(name, val)
            self.end_headers()
            self.wfile.write(body)

        def _is_ingest(self, path: str) -> bool:
            return [unquote(p) for p in path.split("/") if p] \
                == ["v1", "ingest"]

        def _is_mfu(self, path: str) -> bool:
            return [unquote(p) for p in path.split("/") if p] \
                == ["v1", "mfu"]

        def _send_html(self, html: str) -> None:
            body = html.encode()
            self.send_response(200)
            self.send_header("Content-Type", "text/html; charset=utf-8")
            self.send_header("Content-Length", str(len(body)))
            self.send_header("Cache-Control", "no-cache")
            self.end_headers()
            self.wfile.write(body)

        def do_GET(self) -> None:
            sp = urlsplit(self.path)
            # the one non-JSON route: the static dashboard page (its
            # inline JS polls the /v1 JSON API like any other client)
            if sp.path.rstrip("/") == "/dashboard":
                self._send_html(DASHBOARD_HTML)
                return
            params = {k: v[-1] for k, v in
                      parse_qs(sp.query, keep_blank_values=True).items()}
            # route BEFORE the ETag check, so an invalid path or param
            # is a 404/400 even when the client's validator is current;
            # the store's generation cache keeps the repeat-poll path a
            # dict lookup, so 304s stay cheap
            try:
                if self._is_ingest(sp.path):
                    if aggregator is None:
                        raise ApiError(404, "no ingest tier configured "
                                       "on this server")
                    payload = aggregator.stats()
                else:
                    payload = _route(store, sp.path, params)
            except ApiError as e:
                self._send(e.status, {"error": str(e), "path": self.path})
                return
            except Exception as e:    # noqa: BLE001 — a handler must answer
                self._send(500, {"error": f"{type(e).__name__}: {e}",
                                 "path": self.path})
                return
            gen = payload.get("generation")
            if gen is None:           # ingest stats: live counters, no ETag
                self._send(200, payload)
                return
            # the boot nonce keeps validators from a previous server
            # process (whose generations restarted at 0) from colliding
            # into false 304s after a daemon restart
            etag = f'"gen-{store.boot}-{gen}"'
            if self.headers.get("If-None-Match") == etag:
                self.send_response(304)
                self.send_header("ETag", etag)
                self.send_header("Content-Length", "0")
                self.end_headers()
                return
            self._send(200, payload, etag=etag)

        def do_POST(self) -> None:
            sp = urlsplit(self.path)
            try:
                length = int(self.headers.get("Content-Length") or 0)
            except ValueError:
                length = 0
            # drain the body before answering anything, or the client's
            # keep-alive connection desynchronizes on the next request
            blob = self.rfile.read(length) if length else b""
            try:
                if self._is_mfu(sp.path):
                    if aggregator is None:
                        raise ApiError(404, "no ingest tier configured "
                                       "on this server")
                    if not blob:
                        raise ApiError(400, "POST /v1/mfu needs a JSON "
                                       "body")
                    try:
                        payload = json.loads(blob.decode())
                    except (UnicodeDecodeError,
                            json.JSONDecodeError) as e:
                        raise ApiError(400, f"POST /v1/mfu body is not "
                                       f"valid JSON ({e})") from None
                    out = aggregator.submit_mfu(payload)
                    self._send(200, out)
                    return
                if not self._is_ingest(sp.path):
                    raise ApiError(404, f"unknown POST path "
                                   f"{sp.path!r} (want /v1/ingest or "
                                   "/v1/mfu)")
                if aggregator is None:
                    raise ApiError(404, "no ingest tier configured on "
                                   "this server")
                host = self.headers.get("X-Fleet-Host")
                if not host:
                    raise ApiError(400, "POST /v1/ingest needs an "
                                   "X-Fleet-Host header")
                if not blob:
                    raise ApiError(400, "POST /v1/ingest needs a "
                                   "delta-blob body")
                out = aggregator.submit(host, blob)
            except ApiError as e:
                self._send(e.status, {"error": str(e), "path": self.path})
                return
            except Backpressure as e:
                self._send(429, {"error": str(e),
                                 "retry_after_s": e.retry_after_s},
                           headers={"Retry-After":
                                    f"{e.retry_after_s:g}"})
                return
            except SnapshotGap as e:
                self._send(409, {"error": str(e), "host": e.host,
                                 "acked": e.acked})
                return
            except ValueError as e:
                self._send(400, {"error": str(e), "path": self.path})
                return
            except Exception as e:    # noqa: BLE001 — a handler must answer
                self._send(500, {"error": f"{type(e).__name__}: {e}",
                                 "path": self.path})
                return
            self._send(200, {"host": host, **out})

    return Handler


class FleetAPIServer:
    """Threaded HTTP server over a `FleetStore`.

    `port=0` (default) binds an ephemeral port — read `.port`/`.url`
    after construction.  `start()` serves from a daemon thread;
    `stop()` (or the context manager) shuts it down.
    """

    def __init__(self, store: FleetStore, *, host: str = "127.0.0.1",
                 port: int = 0, aggregator=None):
        self.store = store
        self.aggregator = aggregator
        self.httpd = ThreadingHTTPServer((host, port),
                                         _make_handler(store, aggregator))
        self.host, self.port = self.httpd.server_address[:2]
        self._thread: Optional[threading.Thread] = None

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self) -> "FleetAPIServer":
        if self._thread is not None:
            raise ValueError("server already started")
        self._thread = threading.Thread(target=self.httpd.serve_forever,
                                        name="fleet-api", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        if self._thread is None:
            return
        self.httpd.shutdown()
        self.httpd.server_close()
        self._thread.join(timeout=10)
        self._thread = None

    def __enter__(self) -> "FleetAPIServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
