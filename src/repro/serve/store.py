"""`FleetStore`: the queryable read side of the serving subsystem.

The collector pipeline produces state (a `WindowedRollup`, a stream of
alerts); the paper's deployed story (§VI) needs that state *askable* —
per-job OFU time series, fleet percentiles, "what regressed hardest this
week", open incidents, goodput summaries — by many dashboard pollers at
once, cheaply.  `FleetStore` is that index:

  * `update()` / `update_from(collector)` publishes a new GENERATION of
    fleet state.  The rollup is copied on publish (`spawn_empty().merge`,
    pure array adds), so readers never observe a half-ingested round and
    the collector keeps mutating its own rollup freely.
  * Every query is answered from the published generation and CACHED
    keyed on (query, params): repeating a query between rounds is a dict
    hit, and `generation` rides along in every payload so the HTTP layer
    can turn "nothing changed" into an ETag 304 without recomputing
    anything.
  * Payloads are plain JSON-ready dicts (`BucketStats.payload` shapes
    the series; NaN never leaks into the wire format) — the same objects
    `repro.serve.http` serializes and `repro.serve.client` returns.

Thread-safe: one lock serializes publish and query; queries are
O(result) array readouts, so holding it is cheap.
"""
from __future__ import annotations

import os
import threading
from typing import Optional, Sequence

import numpy as np

from repro.fleet.correlation import (CorrelationConfig, MfuRollup,
                                     analyze_correlation)
from repro.fleet.divergence import DEFAULT_OFU_FLOOR, analyze_rollup
from repro.fleet.regression import scan_rollup
from repro.fleet.streaming import (StreamingRollup, _json_list,
                                   weighted_mean)


def _finite(x) -> Optional[float]:
    x = float(x)
    return x if np.isfinite(x) else None


def alert_payload(alert) -> dict:
    """JSON-ready dict for a `fleet.collector.Alert` (idempotent on
    dicts, so restored/forwarded alerts re-publish unchanged)."""
    if isinstance(alert, dict):
        return dict(alert)
    return {"round_idx": alert.round_idx, "t_s": alert.t_s,
            "job_id": alert.job_id, "kind": alert.kind,
            "message": alert.message, "factor": _finite(alert.factor)}


class FleetStore:
    """Generation-versioned index over collector state.

    Writers call `update*()` once per round; readers call the query
    methods.  Every payload carries the `generation` it was computed at.
    """

    #: cached answers kept per generation; param-cycling pollers cannot
    #: grow memory past this (the cache resets, correctness unaffected)
    max_cache_entries = 256

    def __init__(self):
        self._lock = threading.RLock()
        self._rollup: Optional[StreamingRollup] = None
        self._mfu: Optional[MfuRollup] = None    # app-reported half
        self._alerts: list = []          # alert payload dicts, in order
        self._alerts_raw: list = []      # the objects they came from
        self._active: list = []          # open episode keys [job, kind]
        self.generation = 0
        #: per-instance nonce: distinguishes this store's generations
        #: from a previous process's (the HTTP ETag includes it)
        self.boot = os.urandom(4).hex()
        self.round_idx = 0
        self.clock_s = 0.0
        self._cache: dict = {}
        self.cache_hits = 0
        self.cache_misses = 0

    # -- publish --------------------------------------------------------
    def update(self, rollup: Optional[StreamingRollup], *,
               alerts: Sequence = (), active: Sequence = (),
               mfu: Optional[MfuRollup] = None,
               round_idx: int = 0, clock_s: float = 0.0,
               copy: bool = True) -> int:
        """Publish a new generation of fleet state; returns it.

        `copy=True` (default) stores an isolated merge-copy of the
        rollup (and of `mfu`, the app-reported bucket store backing
        correlation queries), so the caller may keep mutating the
        originals between publishes — the contract a live collector
        needs.
        """
        if copy and rollup is not None:
            rollup = rollup.spawn_empty().merge(rollup)
        if copy and mfu is not None:
            mfu = mfu.copy()
        alerts = list(alerts)
        with self._lock:
            # a collector's alert log is append-only and republished
            # every round; convert only the new tail (identity-checked
            # prefix) so per-round publish cost is O(new alerts), not
            # O(every alert the daemon ever fired)
            n_prev = len(self._alerts_raw)
            if n_prev and len(alerts) >= n_prev and all(
                    a is b for a, b in zip(self._alerts_raw, alerts)):
                payloads = self._alerts[:n_prev] \
                    + [alert_payload(a) for a in alerts[n_prev:]]
            else:
                payloads = [alert_payload(a) for a in alerts]
            self._alerts_raw = alerts
            self._rollup = rollup
            self._mfu = mfu
            self._alerts = payloads
            self._active = [list(k) for k in active]
            self.round_idx = int(round_idx)
            self.clock_s = float(clock_s)
            self._cache.clear()
            self.generation += 1
            return self.generation

    def update_from(self, collector, *, copy: bool = True) -> int:
        """Publish straight from a `Collector` or `FleetCollector` after
        a poll round (the `ServiceDaemon` path)."""
        from repro.fleet.collector import Collector, FleetCollector
        if isinstance(collector, FleetCollector):
            hosts = collector.collectors
            alerts = sorted((a for c in hosts for a in c.alerts),
                            key=lambda a: (a.round_idx, a.job_id, a.kind))
            active = sorted({k for c in hosts for k in c.deduper.active},
                            key=repr)
            # MFU streams are per-host too: reduce them the same way the
            # counter rollups tree-reduce (merge is assoc + commutative)
            mfu = None
            for c in hosts:
                part = getattr(c, "mfu", None)
                if part is not None and part.jobs:
                    mfu = part.copy() if mfu is None else mfu.merge(part)
            return self.update(
                collector.fleet, alerts=alerts, active=active, mfu=mfu,
                round_idx=collector.rounds,
                clock_s=max((c.clock_s for c in hosts), default=0.0),
                copy=copy)
        if not isinstance(collector, Collector):
            raise TypeError(f"update_from wants a Collector or "
                            f"FleetCollector, got {type(collector).__name__}")
        return self.update(
            collector.rollup, alerts=collector.alerts,
            active=collector.deduper.active, mfu=collector.mfu,
            round_idx=collector.round_idx,
            clock_s=collector.clock_s, copy=copy)

    # -- query plumbing -------------------------------------------------
    def _query(self, key: tuple, fn) -> dict:
        """Answer from the generation cache or compute-and-remember.
        Returned dicts are shared across callers: treat as read-only."""
        with self._lock:
            hit = self._cache.get(key)
            if hit is not None:
                self.cache_hits += 1
                return hit
            self.cache_misses += 1
            out = fn()
            out["generation"] = self.generation
            out["round_idx"] = self.round_idx
            out["clock_s"] = self.clock_s
            if len(self._cache) >= self.max_cache_entries:
                self._cache.clear()
            self._cache[key] = out
            return out

    @property
    def _roll(self) -> Optional[StreamingRollup]:
        return self._rollup

    def _window_info(self, roll) -> Optional[dict]:
        if getattr(roll, "retain", None) is None:
            return None
        return {"bucket0": roll.bucket0, "end_bucket": roll.end_bucket,
                "retain": roll.retain}

    def _alltime(self, raw: dict) -> dict:
        return {"mean": _finite(raw["mean"]), "weight": raw["weight"],
                "percentiles": {f"{q:g}": _finite(v)
                                for q, v in raw["percentiles"].items()}}

    def _series_payload(self, scope: str, name: Optional[str],
                        qs: tuple) -> dict:
        roll = self._roll
        out = {"scope": scope, "id": name}
        if roll is None:
            out.update({"bucket_s": None, "t0_s": 0.0, "t_s": [],
                        "mean": [], "weight": [], "percentiles": {},
                        "weighted_ofu": None})
            return out
        if scope == "fleet":
            stats = roll.fleet_stats(qs)
        elif scope == "job":
            if name not in roll.jobs:
                raise KeyError(f"unknown job {name!r}")
            stats = roll.job_stats(name, qs)
        elif scope == "group":
            if name not in roll.groups:
                raise KeyError(f"unknown group {name!r}")
            stats = roll.group_stats(name, qs)
        else:
            raise ValueError(f"unknown scope {scope!r} "
                             "(want fleet, job, or group)")
        out.update(stats.payload())
        # null, not 0.0, when no samples have landed: a dashboard must
        # show "no data yet", never a phantom total outage
        out["weighted_ofu"] = _finite(weighted_mean(stats)) \
            if float(np.nansum(stats.weight)) > 0 else None
        win = self._window_info(roll)
        if win is not None:
            out["window"] = win
            if scope == "fleet":
                out["alltime"] = self._alltime(roll.fleet_alltime(qs))
            elif scope == "job":
                out["alltime"] = self._alltime(roll.job_alltime(name, qs))
        if scope == "job":
            out["meta"] = roll.job_meta(name)
        return out

    # -- queries --------------------------------------------------------
    def fleet_series(self, qs: Sequence = (10, 50, 90)) -> dict:
        """Fleet-wide OFU time series: bucket means, weights, histogram
        percentiles, the weighted-OFU headline, all-time view."""
        qs = tuple(qs)
        return self._query(("series", "fleet", None, qs),
                           lambda: self._series_payload("fleet", None, qs))

    def job_series(self, job_id: str, qs: Sequence = (10, 50, 90)) -> dict:
        """One job's OFU time series + ingest metadata.  KeyError for a
        job the rollup has never seen (HTTP maps it to 404)."""
        qs = tuple(qs)
        return self._query(("series", "job", job_id, qs),
                           lambda: self._series_payload("job", job_id, qs))

    def group_series(self, group: str, qs: Sequence = (10, 50, 90)) -> dict:
        qs = tuple(qs)
        return self._query(("series", "group", group, qs),
                           lambda: self._series_payload("group", group, qs))

    def jobs(self) -> dict:
        """The monitored population: job ids and precision groups."""
        def build():
            roll = self._roll
            return {"jobs": sorted(roll.jobs) if roll else [],
                    "groups": sorted(roll.groups) if roll else []}
        return self._query(("jobs",), build)

    def top_regressions(self, k: int = 5, **detector_kw) -> dict:
        """The k hardest-regressed jobs right now, by detector factor —
        the dashboard's 'what should I look at first' panel.  Bucket
        indices are ABSOLUTE (windowed `bucket0` already applied)."""
        if k < 1:
            raise ValueError(f"k={k} must be >= 1")
        key = ("topreg", k, tuple(sorted(detector_kw.items())))

        def build():
            roll = self._roll
            found = []
            if roll is not None:
                kw = detector_kw or {"window": 4, "min_duration": 2}
                for jid, regs in scan_rollup(roll, **kw).items():
                    for r in regs:
                        found.append({
                            "job_id": jid,
                            "factor": _finite(r.factor),
                            "start_bucket": roll.bucket0 + r.start_idx,
                            "end_bucket": None if r.end_idx is None
                            else roll.bucket0 + r.end_idx,
                            "ongoing": r.end_idx is None,
                            "ref_ofu": _finite(r.ref_ofu),
                            "low_ofu": _finite(r.low_ofu)})
            found.sort(key=lambda d: -(d["factor"] or 0.0))
            return {"total": len(found), "k": k,
                    "regressions": found[:k]}
        return self._query(key, build)

    def alerts(self, *, limit: Optional[int] = None) -> dict:
        """Every alert fired (newest last) plus the OPEN episode keys —
        what a pager integration tails.  `limit` keeps only the newest N
        (must be >= 1: limit=0 would silently mean 'all' via slicing)."""
        if limit is not None and limit < 1:
            raise ValueError(f"limit={limit} must be >= 1")
        key = ("alerts", limit)

        def build():
            fired = self._alerts if limit is None else self._alerts[-limit:]
            return {"alerts": list(fired),
                    "active_episodes": [list(k) for k in self._active],
                    "total": len(self._alerts)}
        return self._query(key, build)

    def goodput(self, healthy_ofu: float = 0.40) -> dict:
        """Chip-weighted fleet goodput off the rollup (the §II review
        vantage): weighted OFU, app-MFU coverage vs OFU's 100%, and the
        largest recoverable-waste pools ranked — `fleet.goodput.rollup`
        semantics with the rollup's chip-weighted sample mass standing
        in for chip-hours."""
        if not np.isfinite(healthy_ofu) or healthy_ofu <= 0:
            raise ValueError(f"healthy_ofu={healthy_ofu} must be a "
                             "positive finite number")
        key = ("goodput", healthy_ofu)

        def build():
            roll = self._roll
            jobs = []
            total_w = covered_w = ofu_w = 0.0
            if roll is not None:
                windowed = getattr(roll, "retain", None) is not None
                for jid in sorted(roll.jobs):
                    if windowed:
                        at = roll.job_alltime(jid, qs=())
                        w, mean = float(at["weight"]), float(at["mean"])
                    else:
                        s = roll.job_stats(jid, qs=())
                        w, mean = float(np.nansum(s.weight)), \
                            weighted_mean(s)
                    if w <= 0:
                        continue
                    waste = max(0.0, healthy_ofu - mean) / healthy_ofu * w
                    jobs.append({"job_id": jid, "ofu": _finite(mean),
                                 "weight": w, "waste": waste,
                                 "has_app_mfu":
                                 roll.job_meta(jid) is not None})
                    total_w += w
                    ofu_w += mean * w
                    if roll.job_meta(jid) is not None:
                        covered_w += w
            jobs.sort(key=lambda d: -d["waste"])
            return {"healthy_ofu": healthy_ofu,
                    "weight": total_w,
                    "weighted_ofu": _finite(ofu_w / total_w)
                    if total_w > 0 else None,
                    "app_mfu_coverage": covered_w / total_w
                    if total_w > 0 else 0.0,
                    "ofu_coverage": 1.0,
                    "jobs": jobs}
        return self._query(key, build)

    def divergence(self, flag_rel_err: float = 0.30,
                   ofu_floor: float = DEFAULT_OFU_FLOOR) -> dict:
        """MFU-vs-OFU triage over jobs that registered an app MFU (§V-C);
        empty when none have.  Jobs with OFU below `ofu_floor` are never
        flagged (an idle denominator proves nothing)."""
        if not np.isfinite(flag_rel_err) or flag_rel_err <= 0:
            raise ValueError(f"flag_rel_err={flag_rel_err} must be a "
                             "positive finite number")
        if not np.isfinite(ofu_floor) or ofu_floor < 0:
            raise ValueError(f"ofu_floor={ofu_floor} must be a "
                             "non-negative finite number")
        key = ("divergence", flag_rel_err, ofu_floor)

        def build():
            roll = self._roll
            rep = None if roll is None else analyze_rollup(
                roll, flag_rel_err=flag_rel_err, ofu_floor=ofu_floor,
                empty_ok=True)
            if rep is None:
                return {"flag_rel_err": flag_rel_err, "flagged": []}
            return {"flag_rel_err": flag_rel_err,
                    "r_all": _finite(rep.r_all),
                    "r_clean": _finite(rep.r_clean),
                    "mae": _finite(rep.mae_all),
                    "flagged": [{"job_id": p.job_id,
                                 "mfu": _finite(p.mfu),
                                 "ofu": _finite(p.ofu),
                                 "rel_err": _finite(p.rel_err)}
                                for p in rep.flagged]}
        return self._query(key, build)

    def correlation(self, *, ratio_high: float = 1.5,
                    ratio_low: Optional[float] = None,
                    min_buckets: int = 1,
                    ofu_floor: float = DEFAULT_OFU_FLOOR,
                    window: int = 8) -> dict:
        """The OFU<->MFU join over the published generation: fleet r
        with/without the miscalculation set, tile-quantization-corrected
        MAE, the per-scale error table (Table III live), per-job rows,
        and the flagged findings.  Empty-safe: without MFU samples the
        report is all zeros and no flags."""
        cfg = CorrelationConfig(ratio_high=ratio_high,
                                ratio_low=ratio_low,
                                min_buckets=min_buckets,
                                ofu_floor=ofu_floor, window=window)
        key = ("correlation", cfg.ratio_high, cfg.ratio_low,
               cfg.min_buckets, cfg.ofu_floor, cfg.window)

        def build():
            roll, mfu = self._roll, self._mfu
            if roll is None or mfu is None:
                return {"config": {"ratio_high": cfg.ratio_high,
                                   "ratio_low": cfg.ratio_low,
                                   "min_buckets": cfg.min_buckets,
                                   "ofu_floor": cfg.ofu_floor,
                                   "window": cfg.window},
                        "n_jobs": 0, "r_all": 0.0, "r_clean": 0.0,
                        "mae": 0.0, "flagged": [], "by_scale": {},
                        "jobs": []}
            rep = analyze_correlation(mfu, roll, config=cfg)
            out = rep.to_payload()
            out["config"] = {"ratio_high": cfg.ratio_high,
                             "ratio_low": cfg.ratio_low,
                             "min_buckets": cfg.min_buckets,
                             "ofu_floor": cfg.ofu_floor,
                             "window": cfg.window}
            return out
        return self._query(key, build)
