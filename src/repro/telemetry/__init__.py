from repro.telemetry.clock import ClockModel  # noqa: F401
from repro.telemetry.counters import (  # noqa: F401
    MAX_HW_AVG_WINDOW_S, CounterBackend, Event, SimulatedDeviceBackend,
    StepProfile, TpuProfilerBackend, check_scrape_interval, duty_grid,
    event_factors,
)
from repro.telemetry.scrape import DeviceGrid, ScrapeSeries, scrape  # noqa: F401
from repro.telemetry.source import (  # noqa: F401
    BackendSource, GridSource, SimulatorSource, TelemetrySource,
    TraceReplaySource, read_trace, write_trace,
)
from repro.telemetry.tracestore import (  # noqa: F401
    TraceReader, TraceWriter, read_archive, write_archive,
)
