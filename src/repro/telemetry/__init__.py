from repro.telemetry.backends import (  # noqa: F401
    DcgmFieldBackend, DcgmiTransport, FakeDcgmTransport, FakeTpuTransport,
    FieldTransport, PynvmlTransport, TpuProfilerBackend, TransportError,
    make_dcgm_backends,
)
from repro.telemetry.clock import ClockModel  # noqa: F401
from repro.telemetry.counters import (  # noqa: F401
    MAX_HW_AVG_WINDOW_S, CounterBackend, Event, SimulatedDeviceBackend,
    StepProfile, check_scrape_interval, duty_grid, event_factors,
)
from repro.telemetry.mfu import (  # noqa: F401
    MfuReplaySource, MfuReporter, MfuSample, compute_mfu,
    extract_tflops_from_log, reported_tflops_per_gpu,
)
from repro.telemetry.scrape import DeviceGrid, ScrapeSeries, scrape  # noqa: F401
from repro.telemetry.source import (  # noqa: F401
    BackendSource, GridSource, SimulatorSource, TelemetrySource,
    TraceReplaySource, read_trace, write_trace,
)
from repro.telemetry.tracestore import (  # noqa: F401
    TraceReader, TraceWriter, read_archive, write_archive,
)
