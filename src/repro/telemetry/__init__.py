from repro.telemetry.clock import ClockModel  # noqa: F401
from repro.telemetry.counters import (  # noqa: F401
    MAX_HW_AVG_WINDOW_S, CounterBackend, Event, SimulatedDeviceBackend,
    StepProfile, TpuProfilerBackend, duty_grid, event_factors,
)
from repro.telemetry.scrape import ScrapeSeries, scrape  # noqa: F401
