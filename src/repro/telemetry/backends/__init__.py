"""Live counter acquisition: the deploy tier under `CounterBackend`.

The paper's acquisition story is deliberately thin — OFU needs exactly
two per-device counters (PIPE_TENSOR_ACTIVE + SM_CLOCK), polled with no
application instrumentation.  This package is that tier:

  * `transport` — the injectable `FieldTransport` seam: "read these
    field ids for this GPU now", nothing else.  Everything above it
    (staleness, retry, §IV-C window policy) lives in the backend;
    everything below (dcgmi subprocess, NVML bindings, the CI fake) is a
    transport.
  * `dcgm` — `DcgmFieldBackend` (a `CounterBackend`: the rest of the
    pipeline runs unchanged via `BackendSource`) plus the real
    transports: `DcgmiTransport` (one `dcgmi dmon` snapshot per poll
    round) and `PynvmlTransport` (NVML bindings, gated on the module
    being installed).
  * `fake` — `FakeDcgmTransport`/`FakeTpuTransport`, driven by the
    simulator engine with the SAME chunk seeding as `SimulatorSource`,
    so the full live path (transport → backend → `BackendSource` →
    `Collector` → serve) runs deterministically in CI and its rollup is
    bucketwise-identical to the pure-simulation path on the same seed
    (`tools/fleet_live.py --self-check`).
  * `tpu` — `TpuProfilerBackend` over a `TpuTransport` duty-cycle/clock
    shim (`LibtpuTransport` for hardware, the fake for CI).
"""
from repro.telemetry.backends.dcgm import (  # noqa: F401
    DcgmFieldBackend, DcgmiTransport, PynvmlTransport, make_dcgm_backends,
    parse_dmon,
)
from repro.telemetry.backends.fake import (  # noqa: F401
    FakeDcgmTransport, FakeTpuTransport,
)
from repro.telemetry.backends.tpu import (  # noqa: F401
    LibtpuTransport, TpuProfilerBackend, TpuTransport,
)
from repro.telemetry.backends.transport import (  # noqa: F401
    DCGM_FI_DEV_SM_CLOCK, DCGM_FI_PROF_PIPE_TENSOR_ACTIVE, FieldSample,
    FieldTransport, TransportError,
)
