"""DCGM-side acquisition: `DcgmFieldBackend` plus the real transports.

`DcgmFieldBackend` is a `CounterBackend` — `poll(window_s)` returns the
paper's two signals `(tensor-pipe activity avg, SM clock sample)` — so
N of them under a `BackendSource` make the whole pipeline (collector,
detectors, serve tier) run against live hardware unchanged.  It owns
every policy the transports don't:

  * §IV-C window enforcement via the shared `check_scrape_interval`
    (polling slower than the 30 s hardware averaging window silently
    degrades to average-of-averages; strict mode refuses).
  * Per-field staleness detection: DCGM keeps serving the LAST value
    when a channel wedges — the value looks plausible, only the
    timestamp betrays it.  A few repeats are tolerated (fast polls
    legitimately straddle an update), a streak escalates.
  * Reconnect-with-backoff around every read, so one dropped `nv-hostengine`
    doesn't take down the recorder.

Transports:

  * `DcgmiTransport` — one `dcgmi dmon -e <fields> -c 1` subprocess
    snapshot per poll ROUND (all GPUs in one invocation; per-GPU reads
    consume from the snapshot and the next round's first read refreshes
    it).  The text parser (`parse_dmon`) is a standalone function so CI
    tests feed it captured output without the binary.
  * `PynvmlTransport` — NVML bindings when the `pynvml` module is
    installed (gated import; clear `TransportError` otherwise).
    SM clock maps to `nvmlDeviceGetClockInfo(NVML_CLOCK_SM)`; tensor
    activity to the profiling field when the driver exposes it, else
    documented fallback to coarse GPU utilization.
"""
from __future__ import annotations

import shutil
import subprocess
import time
from typing import Dict, Optional, Sequence

from repro.telemetry.backends.transport import (
    DCGM_FI_DEV_SM_CLOCK, DCGM_FI_PROF_PIPE_TENSOR_ACTIVE, FieldSample,
    FieldTransport, ResilientBackendMixin, TransportError,
)
from repro.telemetry.counters import CounterBackend, check_scrape_interval

#: tensor activity arrives in [0, 1]; SM clock in MHz.  Readings outside
#: sane bounds are transport corruption, not data.
_TPA_RANGE = (0.0, 1.0)
_CLK_RANGE_MHZ = (0.0, 10_000.0)


class DcgmFieldBackend(ResilientBackendMixin, CounterBackend):
    """Polls PIPE_TENSOR_ACTIVE + SM_CLOCK for one GPU through any
    `FieldTransport`.

    One backend per device, all sharing one transport — the shape
    `BackendSource` expects.  The first poll connects lazily (a
    constructor that probes hardware would make fleet wiring fragile);
    `healthy` plus the `polls/retries/reconnects/stale_reads` counters
    are the health-check surface a daemon exports.
    """

    def __init__(self, gpu: int, transport: FieldTransport, *,
                 strict: bool = True, max_retries: int = 3,
                 backoff_s: float = 0.05, backoff_mult: float = 2.0,
                 max_stale_polls: int = 3, sleep=None):
        self.gpu = int(gpu)
        self.strict = bool(strict)
        self._init_resilience(transport, max_retries=max_retries,
                              backoff_s=backoff_s,
                              backoff_mult=backoff_mult,
                              max_stale_polls=max_stale_polls, sleep=sleep)

    def _read_once(self) -> Dict[int, FieldSample]:
        fields = (DCGM_FI_PROF_PIPE_TENSOR_ACTIVE, DCGM_FI_DEV_SM_CLOCK)
        samples = self.transport.read(self.gpu, fields)
        missing = [f for f in fields if f not in samples]
        if missing:
            raise TransportError(
                f"transport returned no sample for field(s) {missing} "
                f"on GPU {self.gpu}")
        tpa = samples[DCGM_FI_PROF_PIPE_TENSOR_ACTIVE]
        clk = samples[DCGM_FI_DEV_SM_CLOCK]
        if not _TPA_RANGE[0] <= tpa.value <= _TPA_RANGE[1]:
            raise TransportError(
                f"tensor activity {tpa.value!r} outside {_TPA_RANGE} "
                f"on GPU {self.gpu}")
        if not _CLK_RANGE_MHZ[0] <= clk.value <= _CLK_RANGE_MHZ[1]:
            raise TransportError(
                f"SM clock {clk.value!r} MHz outside sane range "
                f"on GPU {self.gpu}")
        self._note_freshness(("tpa", self.gpu), tpa.t_s)
        self._note_freshness(("clk", self.gpu), clk.t_s)
        return samples

    # -- CounterBackend -------------------------------------------------
    def poll(self, window_s: float) -> tuple:
        """(hardware-averaged tensor activity, instantaneous SM clock)
        for the next window, enforcing §IV-C on the interval."""
        check_scrape_interval(window_s, strict=self.strict)
        samples = self._with_retries(self._read_once)
        self.polls += 1
        return (samples[DCGM_FI_PROF_PIPE_TENSOR_ACTIVE].value,
                samples[DCGM_FI_DEV_SM_CLOCK].value)


def make_dcgm_backends(transport: FieldTransport,
                       n_devices: Optional[int] = None,
                       **kw) -> list:
    """One `DcgmFieldBackend` per visible device over a shared
    transport — the list `BackendSource(backends=...)` wants."""
    if n_devices is None:
        with_connect = getattr(transport, "_connected", None)
        if with_connect is False:
            transport.connect()
        n_devices = transport.n_devices
    return [DcgmFieldBackend(gpu, transport, **kw)
            for gpu in range(int(n_devices))]


# ---------------------------------------------------------------------------
# dcgmi subprocess transport
# ---------------------------------------------------------------------------
def parse_dmon(text: str, field_ids: Sequence[int]) -> Dict[int, dict]:
    """Parse `dcgmi dmon` tabular output into {gpu: {field_id: value}}.

    Columns map positionally to `field_ids` (the `-e` request order).
    Tolerates the two row shapes dcgmi emits ("GPU 0  ..." and a bare
    leading entity id), skips `#` headers and blank lines, and treats
    `N/A` as a missing field (the backend decides whether that is
    fatal).  Unparsable rows raise `TransportError` — a half-garbled
    snapshot must not pass as data.
    """
    out: Dict[int, dict] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        toks = line.split()
        if toks[0].upper() in ("GPU", "TPU", "ENTITY") and len(toks) > 1:
            ent, vals = toks[1], toks[2:]
        else:
            ent, vals = toks[0], toks[1:]
        try:
            gpu = int(ent)
        except ValueError as e:
            raise TransportError(
                f"unparsable dmon row (bad entity id): {line!r}") from e
        if len(vals) < len(field_ids):
            raise TransportError(
                f"dmon row has {len(vals)} values for "
                f"{len(field_ids)} requested fields: {line!r}")
        fields = {}
        for fid, v in zip(field_ids, vals):
            if v.upper() in ("N/A", "NA", "-"):
                continue
            try:
                fields[fid] = float(v)
            except ValueError as e:
                raise TransportError(
                    f"unparsable dmon value {v!r} in row: {line!r}") from e
        out[gpu] = fields
    return out


class DcgmiTransport(FieldTransport):
    """Field transport over the `dcgmi` CLI (no bindings needed —
    present wherever DCGM is installed).

    One `dcgmi dmon -e <fields> -c 1` invocation snapshots EVERY GPU;
    per-GPU `read()`s consume from that snapshot and the first read of
    the next round (a GPU asking twice) refreshes it — so a
    `BackendSource` round costs one subprocess, not one per device.

    `runner` is injectable (a callable `cmd_list -> stdout_str`) so
    tests drive the full parse/snapshot path on captured output without
    the binary; the default runner shells out with a timeout.
    """

    def __init__(self, *, binary: str = "dcgmi",
                 field_ids: Sequence[int] = (
                     DCGM_FI_PROF_PIPE_TENSOR_ACTIVE,
                     DCGM_FI_DEV_SM_CLOCK),
                 timeout_s: float = 10.0, clock=time.monotonic,
                 runner=None):
        self.binary = binary
        self.field_ids = tuple(int(f) for f in field_ids)
        self.timeout_s = float(timeout_s)
        self._clock = clock
        self._run = runner if runner is not None else self._run_subprocess
        self._snapshot: Optional[Dict[int, dict]] = None
        self._snapshot_t = 0.0
        self._consumed: set = set()
        self._connected = False

    def _run_subprocess(self, cmd: list) -> str:
        try:
            proc = subprocess.run(cmd, capture_output=True, text=True,
                                  timeout=self.timeout_s)
        except (OSError, subprocess.TimeoutExpired) as e:
            raise TransportError(f"{cmd[0]} failed to run: {e}") from e
        if proc.returncode != 0:
            raise TransportError(
                f"{' '.join(cmd)} exited {proc.returncode}: "
                f"{proc.stderr.strip()[:200]}")
        return proc.stdout

    # -- FieldTransport -------------------------------------------------
    def connect(self) -> None:
        """Health check: the binary must exist and answer (the DCGM
        host engine being down surfaces here, not mid-recording)."""
        if shutil.which(self.binary) is None and self._run \
                == self._run_subprocess:
            raise TransportError(
                f"{self.binary!r} not found on PATH — is DCGM installed? "
                "(use --transport fake for hardware-less runs)")
        self._run([self.binary, "--version"])
        self._connected = True
        self._snapshot = None
        self._consumed = set()

    def close(self) -> None:
        self._connected = False
        self._snapshot = None

    def _refresh(self) -> None:
        cmd = [self.binary, "dmon",
               "-e", ",".join(str(f) for f in self.field_ids), "-c", "1"]
        snap = parse_dmon(self._run(cmd), self.field_ids)
        if not snap:
            raise TransportError(f"{' '.join(cmd)} returned no GPU rows")
        self._snapshot = snap
        self._snapshot_t = float(self._clock())
        self._consumed = set()

    @property
    def n_devices(self) -> int:
        if self._snapshot is None:
            self._refresh()
        return len(self._snapshot)

    def read(self, gpu: int,
             field_ids: Sequence[int]) -> Dict[int, FieldSample]:
        if not self._connected:
            raise TransportError("dcgmi transport is not connected")
        if self._snapshot is None or gpu in self._consumed:
            self._refresh()
        row = self._snapshot.get(gpu)
        if row is None:
            raise TransportError(
                f"GPU {gpu} absent from dmon snapshot "
                f"(saw {sorted(self._snapshot)})")
        self._consumed.add(gpu)
        out = {}
        for f in field_ids:
            if f not in row:
                raise TransportError(
                    f"field {f} is N/A for GPU {gpu} (profiling fields "
                    "need a profiling-capable driver/DCGM)")
            value = row[f]
            if f == DCGM_FI_PROF_PIPE_TENSOR_ACTIVE and value > 1.0:
                value /= 100.0       # some dcgmi builds report percent
            out[f] = FieldSample(value, self._snapshot_t)
        return out


# ---------------------------------------------------------------------------
# NVML bindings transport
# ---------------------------------------------------------------------------
class PynvmlTransport(FieldTransport):
    """Field transport over the `pynvml` NVML bindings.

    Gated on the module being importable (this container does not ship
    it) — `connect()` raises a clear `TransportError` otherwise, which
    `tools/fleet_live.py` turns into actionable CLI output.  Tensor
    activity uses the NVML profiling field when the driver exposes one;
    otherwise falls back to `nvmlDeviceGetUtilizationRates().gpu`
    (coarse "any SM busy" utilization — documented approximation, the
    paper's §IV point about why PIPE_TENSOR_ACTIVE is the right field).
    """

    def __init__(self, *, clock=time.monotonic):
        self._clock = clock
        self._nv = None
        self._handles: list = []

    def connect(self) -> None:
        try:
            import pynvml
        except ImportError as e:
            raise TransportError(
                "the 'pynvml' module is not installed; install "
                "nvidia-ml-py or use --transport dcgmi/fake") from e
        try:
            pynvml.nvmlInit()
            count = pynvml.nvmlDeviceGetCount()
            self._handles = [pynvml.nvmlDeviceGetHandleByIndex(i)
                             for i in range(count)]
        except pynvml.NVMLError as e:   # pragma: no cover - hardware only
            raise TransportError(f"NVML init failed: {e}") from e
        self._nv = pynvml

    def close(self) -> None:
        if self._nv is not None:        # pragma: no cover - hardware only
            try:
                self._nv.nvmlShutdown()
            except Exception:
                pass
        self._nv = None
        self._handles = []

    @property
    def n_devices(self) -> int:
        return len(self._handles)

    def read(self, gpu: int,     # pragma: no cover - hardware only
             field_ids: Sequence[int]) -> Dict[int, FieldSample]:
        nv = self._nv
        if nv is None:
            raise TransportError("pynvml transport is not connected")
        if not 0 <= gpu < len(self._handles):
            raise TransportError(f"no such GPU {gpu} "
                                 f"(NVML sees {len(self._handles)})")
        h = self._handles[gpu]
        t_s = float(self._clock())
        out = {}
        try:
            for f in field_ids:
                if f == DCGM_FI_DEV_SM_CLOCK:
                    out[f] = FieldSample(
                        float(nv.nvmlDeviceGetClockInfo(
                            h, nv.NVML_CLOCK_SM)), t_s)
                elif f == DCGM_FI_PROF_PIPE_TENSOR_ACTIVE:
                    fid = getattr(nv, "NVML_FI_PROF_PIPE_TENSOR_ACTIVE",
                                  None)
                    if fid is not None:
                        (val,) = nv.nvmlDeviceGetFieldValues(h, [fid])
                        out[f] = FieldSample(
                            float(val.value.dVal), t_s)
                    else:
                        util = nv.nvmlDeviceGetUtilizationRates(h)
                        out[f] = FieldSample(float(util.gpu) / 100.0, t_s)
                else:
                    raise TransportError(
                        f"unsupported field id {f} for NVML transport")
        except nv.NVMLError as e:
            raise TransportError(f"NVML read failed on GPU {gpu}: "
                                 f"{e}") from e
        return out
