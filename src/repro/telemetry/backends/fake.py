"""Deterministic engine-driven transports: the CI stand-ins for DCGM
and libtpu.

`FakeDcgmTransport` speaks the exact `FieldTransport` protocol the real
transports speak, but its field values come from the simulator engine —
and crucially it chunk-seeds IDENTICALLY to `SimulatorSource` (same
internal source, same poll cadence), so a live pipeline polled through
FakeDcgmTransport → `DcgmFieldBackend` → `BackendSource` produces
bit-identical samples to `SimulatorSource` on the same seed.  That is
what lets `tools/fleet_live.py --self-check` assert the whole
acquisition tier end-to-end: rollup buckets from the "live" path must
equal the simulation path's, bucket for bucket.

Failure injection (`fail_every`) raises a `TransportError` on a
deterministic schedule WITHOUT consuming the sample, so the backend's
retry/reconnect loop can be exercised in tests and the recovered stream
still matches the clean one exactly.

`quantize=True` serves DCGM-wire precision (tensor activity rounded to
3 decimals, clock to whole MHz — what `dcgmi`/NVML actually deliver)
instead of full-precision engine floats; the codec benchmarks record
against that fixture because it is what a live recorder stores.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence

import numpy as np

from repro.telemetry.backends.tpu import TpuTransport
from repro.telemetry.backends.transport import (
    DCGM_FI_DEV_SM_CLOCK, DCGM_FI_PROF_PIPE_TENSOR_ACTIVE, FieldSample,
    FieldTransport, TransportError,
)
from repro.telemetry.counters import Event, StepProfile

_KNOWN_FIELDS = (DCGM_FI_PROF_PIPE_TENSOR_ACTIVE, DCGM_FI_DEV_SM_CLOCK)


def quantize_wire(tpa: np.ndarray,
                  clock_mhz: np.ndarray) -> tuple:
    """Round counters to DCGM wire precision: activity at 3 decimals,
    clock in whole MHz (NVML reports an integer)."""
    return np.round(tpa, 3), np.round(clock_mhz, 0)


class FakeDcgmTransport(FieldTransport):
    """`FieldTransport` over the simulator engine, per-GPU cursors.

    `BackendSource` polls device-major (every sample for GPU 0, then
    GPU 1, ...), so each GPU keeps its own cursor into a shared buffer
    of engine chunks; the buffer refills one `chunk_s` engine poll at a
    time and compacts once every cursor has moved past a chunk, keeping
    residency O(chunk) however long the run.  Chunks come from an
    internal `SimulatorSource` with the caller's seed — the identity
    anchor for the live-vs-sim self-check (poll the comparison
    `SimulatorSource` with the same `chunk_s` cadence).
    """

    def __init__(self, profile: StepProfile, *, duration_s: float,
                 interval_s: float, n_devices: int = 1,
                 chunk_s: float = 300.0, chip=None,
                 events: Sequence[Event] = (),
                 stragglers: Optional[np.ndarray] = None, seed: int = 0,
                 quantize: bool = False,
                 fail_every: Optional[int] = None):
        if not np.isfinite(duration_s):
            raise ValueError("FakeDcgmTransport needs a finite duration_s "
                             "(the engine simulates a bounded run)")
        # the engine sits a layer above telemetry; import here so live
        # deployments importing the backends package never load it
        from repro.core.peaks import DEFAULT_CHIP
        from repro.telemetry.source import SimulatorSource
        self._src = SimulatorSource(
            profile=profile, duration_s=float(duration_s),
            interval_s=float(interval_s), chip=chip or DEFAULT_CHIP,
            events=list(events), stragglers=stragglers,
            n_devices=int(n_devices), seed=int(seed))
        self.chunk_s = float(chunk_s)
        self.quantize = bool(quantize)
        self.fail_every = fail_every
        self._n = int(n_devices)
        self._connected = False
        self._reads = 0              # includes injected failures
        self._base = 0               # global sample index of buffer[0]
        self._cursor = np.zeros(self._n, dtype=int)
        self._tpa = np.empty((self._n, 0))
        self._clk = np.empty((self._n, 0))
        self._times = np.empty(0)

    # -- FieldTransport -------------------------------------------------
    def connect(self) -> None:
        self._connected = True

    def close(self) -> None:
        self._connected = False

    @property
    def n_devices(self) -> int:
        return self._n

    @property
    def exhausted(self) -> bool:
        """True when the simulated run is fully consumed by every GPU."""
        return self._src.exhausted \
            and int(self._cursor.min()) - self._base >= self._tpa.shape[1]

    def read(self, gpu: int,
             field_ids: Sequence[int]) -> Dict[int, FieldSample]:
        if not self._connected:
            raise TransportError("fake DCGM transport is not connected "
                                 "(call connect() first)")
        if not 0 <= gpu < self._n:
            raise TransportError(f"no such GPU {gpu} "
                                 f"(transport sees {self._n})")
        bad = [f for f in field_ids if f not in _KNOWN_FIELDS]
        if bad:
            raise TransportError(f"unsupported DCGM field ids {bad} "
                                 f"(fake serves {list(_KNOWN_FIELDS)})")
        self._reads += 1
        if self.fail_every and self._reads % self.fail_every == 0:
            # deterministic flakiness: the sample is NOT consumed, so a
            # retried read returns exactly what this one would have
            raise TransportError(
                f"injected fault (read #{self._reads})")
        idx = int(self._cursor[gpu]) - self._base
        while idx >= self._tpa.shape[1]:
            self._refill()
            idx = int(self._cursor[gpu]) - self._base
        tpa, clk = float(self._tpa[gpu, idx]), float(self._clk[gpu, idx])
        if self.quantize:
            tpa, clk = round(tpa, 3), round(clk, 0)
        t_s = float(self._times[idx])
        self._cursor[gpu] += 1
        self._compact()
        return {f: FieldSample(
            tpa if f == DCGM_FI_PROF_PIPE_TENSOR_ACTIVE else clk, t_s)
            for f in field_ids}

    # -- engine feed ----------------------------------------------------
    def _refill(self) -> None:
        grid = self._src.poll(self.chunk_s)
        if grid.tpa.shape[1] == 0:
            raise TransportError(
                f"simulated run exhausted at "
                f"{self._src.cursor_s:g}s / {self._src.duration_s:g}s")
        self._tpa = np.concatenate([self._tpa, grid.tpa], axis=1)
        self._clk = np.concatenate([self._clk, grid.clock_mhz], axis=1)
        self._times = np.concatenate([self._times, grid.times_s])

    def _compact(self) -> None:
        done = int(self._cursor.min()) - self._base
        if done > 0 and done >= self._tpa.shape[1]:
            # every GPU consumed the whole buffer: drop it outright
            self._base += done
            self._tpa = self._tpa[:, done:]
            self._clk = self._clk[:, done:]
            self._times = self._times[done:]


class FakeTpuTransport(TpuTransport):
    """`TpuTransport` over the same engine feed: duty cycle is the
    hardware-averaged tensor activity, clock the point sample.  Takes
    the same constructor knobs as `FakeDcgmTransport` (it wraps one)."""

    def __init__(self, profile: StepProfile, **kw):
        self._f = FakeDcgmTransport(profile, **kw)

    def connect(self) -> None:
        self._f.connect()

    def close(self) -> None:
        self._f.close()

    @property
    def n_devices(self) -> int:
        return self._f.n_devices

    def read(self, device: int) -> tuple:
        s = self._f.read(device, _KNOWN_FIELDS)
        return (s[DCGM_FI_PROF_PIPE_TENSOR_ACTIVE].value,
                s[DCGM_FI_DEV_SM_CLOCK].value,
                s[DCGM_FI_PROF_PIPE_TENSOR_ACTIVE].t_s)
