"""TPU-side acquisition: `TpuProfilerBackend` over a duty-cycle/clock
transport shim.

TPUs expose the same two-signal story as DCGM GPUs — a hardware
tensorcore duty-cycle metric (libtpu's `tensorcore_utilization` /
megacore duty cycle, the TPU analogue of PIPE_TENSOR_ACTIVE) and a
power-management clock stream — so the backend is the same shape:
§IV-C window policy, staleness tracking, reconnect-with-backoff, all
shared via `ResilientBackendMixin`.  Only the transport differs:
`TpuTransport.read(device)` returns one `(duty, clock_mhz, t_s)`
triple instead of DCGM field ids.

`LibtpuTransport` is the hardware wiring point, gated on libtpu being
importable; CI runs the engine-driven `fake.FakeTpuTransport` through
the identical backend code path, so the deploy path is exercised end to
end minus the final syscall.
"""
from __future__ import annotations

import time

from repro.telemetry.backends.transport import (ResilientBackendMixin,
                                                TransportError)
from repro.telemetry.counters import CounterBackend, check_scrape_interval


class TpuTransport:
    """Interface: one `(duty_cycle, clock_mhz, t_s)` triple per device.

    Same lifecycle contract as `FieldTransport` (`connect()` is the
    reconnect path, `close()` idempotent, every failure a
    `TransportError`).
    """

    def connect(self) -> None:
        """Establish (or re-establish) the telemetry channel."""

    def close(self) -> None:
        """Tear the channel down (idempotent)."""

    @property
    def n_devices(self) -> int:
        raise NotImplementedError

    def read(self, device: int) -> tuple:
        """(duty_cycle in [0,1], clock_mhz, transport timestamp s)."""
        raise NotImplementedError

    def __enter__(self):
        self.connect()
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class LibtpuTransport(TpuTransport):
    """Hardware transport over libtpu's telemetry surface.

    Duty cycle comes from the `tensorcore_utilization`/megacore
    duty-cycle metric (`tpu-info`'s source), clock from the
    power-management stream.  Gated: this CPU container has no libtpu,
    so `connect()` raises a clear `TransportError` pointing at the fake
    — the same pattern as `PynvmlTransport` without its module.
    """

    def __init__(self, *, clock=time.monotonic):
        self._clock = clock
        self._lib = None

    def connect(self) -> None:
        import importlib.util
        for mod in ("libtpu", "tpu_info"):
            if importlib.util.find_spec(mod) is not None:
                self._lib = importlib.import_module(mod)
                break
        else:
            raise TransportError(
                "no libtpu telemetry available (neither 'libtpu' nor "
                "'tpu_info' is importable in this container); use "
                "FakeTpuTransport / --transport fake for hardware-less "
                "runs")

    def close(self) -> None:
        self._lib = None

    @property
    def n_devices(self) -> int:  # pragma: no cover - hardware only
        if self._lib is None:
            raise TransportError("libtpu transport is not connected")
        chips = getattr(self._lib, "device", None)
        if chips is not None and hasattr(chips, "get_local_chips"):
            return len(chips.get_local_chips())
        raise TransportError("libtpu is present but exposes no local "
                             "chip enumeration this shim recognizes")

    def read(self, device: int):  # pragma: no cover - hardware only
        if self._lib is None:
            raise TransportError("libtpu transport is not connected")
        metrics = getattr(self._lib, "metrics", None)
        if metrics is None or not hasattr(metrics, "get_chip_usage"):
            raise TransportError(
                "libtpu is present but exposes no duty-cycle metric "
                "this shim recognizes (expected metrics.get_chip_usage)")
        usage = metrics.get_chip_usage()[device]
        return (float(usage.duty_cycle_pct) / 100.0,
                float(getattr(usage, "clock_mhz", 0.0)),
                float(self._clock()))


class TpuProfilerBackend(ResilientBackendMixin, CounterBackend):
    """Deploy target for TPU fleets: the `CounterBackend` the paper's
    TPU deployments poll, now functional over any `TpuTransport`.

    Constructed with no transport it wires `LibtpuTransport` (the
    hardware default — in this container that raises a clear
    `TransportError` on first poll, pointing at the fake); CI
    constructs it over `FakeTpuTransport` and runs the identical
    policy/retry/staleness code path.
    """

    def __init__(self, device: int = 0, transport: TpuTransport = None, *,
                 strict: bool = True, max_retries: int = 3,
                 backoff_s: float = 0.05, backoff_mult: float = 2.0,
                 max_stale_polls: int = 3, sleep=None):
        self.device = int(device)
        self.strict = bool(strict)
        self._init_resilience(
            transport if transport is not None else LibtpuTransport(),
            max_retries=max_retries, backoff_s=backoff_s,
            backoff_mult=backoff_mult, max_stale_polls=max_stale_polls,
            sleep=sleep)

    def _read_once(self) -> tuple:
        duty, clock_mhz, t_s = self.transport.read(self.device)
        if not 0.0 <= duty <= 1.0:
            raise TransportError(
                f"duty cycle {duty!r} outside [0, 1] on device "
                f"{self.device}")
        self._note_freshness(("duty", self.device), t_s)
        return duty, clock_mhz

    # -- CounterBackend -------------------------------------------------
    def poll(self, window_s: float) -> tuple:
        """(hardware-averaged duty cycle, clock sample), §IV-C enforced
        identically to the DCGM side."""
        check_scrape_interval(window_s, strict=self.strict)
        duty, clock_mhz = self._with_retries(self._read_once)
        self.polls += 1
        return duty, clock_mhz
