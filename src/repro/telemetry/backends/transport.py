"""The transport seam: how a counter backend reaches real hardware.

A `FieldTransport` answers exactly one question — "what are these DCGM
field values for this GPU right now" — and owns nothing else: no
retry, no staleness policy, no window enforcement (those live in
`DcgmFieldBackend`, identically for every transport).  That keeps the
hardware surface small enough to fake deterministically
(`fake.FakeDcgmTransport`) and to swap between `dcgmi` subprocess and
NVML bindings without touching the pipeline.

Transports signal EVERY failure mode as `TransportError` — a dead
daemon, an unparsable snapshot, a missing GPU — so the backend has one
thing to catch and one recovery path (close → backoff → connect).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Sequence

#: the two DCGM field ids OFU consumes (paper §IV) — SM clock is an
#: instantaneous point sample, tensor-pipe activity a hardware average
#: over at most `MAX_HW_AVG_WINDOW_S`
DCGM_FI_DEV_SM_CLOCK = 100
DCGM_FI_PROF_PIPE_TENSOR_ACTIVE = 1002


class TransportError(RuntimeError):
    """Any transport-level failure (daemon down, parse failure, missing
    device/field).  The backend's retry/reconnect loop catches exactly
    this."""


@dataclass(frozen=True)
class FieldSample:
    """One field reading: the value plus the TRANSPORT's timestamp for
    it (monotonic seconds; the staleness detector compares successive
    timestamps per field, so the epoch does not matter)."""

    value: float
    t_s: float


class FieldTransport:
    """Interface a DCGM-shaped transport implements.

    Lifecycle: `connect()` may be called repeatedly (it is the
    reconnect path), `close()` is always safe.  `read()` must either
    return a sample for EVERY requested field id or raise
    `TransportError` — partial snapshots are a transport failure, not a
    backend policy decision.
    """

    def connect(self) -> None:
        """Establish (or re-establish) the underlying channel."""

    def close(self) -> None:
        """Tear the channel down (idempotent)."""

    @property
    def n_devices(self) -> int:
        """Devices visible through this transport."""
        raise NotImplementedError

    def read(self, gpu: int,
             field_ids: Sequence[int]) -> Dict[int, FieldSample]:
        """Current samples for `field_ids` on device `gpu`."""
        raise NotImplementedError

    def __enter__(self):
        self.connect()
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class ResilientBackendMixin:
    """Shared resilience policy for backends polling a transport: retry
    with exponential backoff and reconnect-between-attempts, plus
    per-field staleness tracking.

    Identical for DCGM and TPU backends by design — the recovery story
    ("close, back off, connect, re-read") is a property of polling a
    flaky channel, not of any particular hardware.  Subclasses call
    `_with_retries(fn)` around their read closure and `_note_freshness`
    per field inside it; `sleep` is injectable so tests exercise the
    backoff schedule without waiting it out.
    """

    def _init_resilience(self, transport: FieldTransport, *,
                         max_retries: int = 3, backoff_s: float = 0.05,
                         backoff_mult: float = 2.0,
                         max_stale_polls: int = 3, sleep=None) -> None:
        import time
        self.transport = transport
        self.max_retries = int(max_retries)
        self.backoff_s = float(backoff_s)
        self.backoff_mult = float(backoff_mult)
        self.max_stale_polls = int(max_stale_polls)
        self._sleep = sleep if sleep is not None else time.sleep
        self._started = False
        self._last_error: Exception | None = None
        #: health/ops counters a daemon can export
        self.polls = 0
        self.retries = 0
        self.reconnects = 0
        self.stale_reads = 0
        self._last_t: dict = {}      # field key -> newest timestamp seen
        self._stale_streak: dict = {}

    @property
    def healthy(self) -> bool:
        """True once polling has succeeded and the channel is currently
        clean (no unrecovered error, no field past its stale budget)."""
        return (self._started and self._last_error is None
                and all(s <= self.max_stale_polls
                        for s in self._stale_streak.values()))

    def _ensure_connected(self) -> None:
        if not self._started:
            self.transport.connect()
            self._started = True

    def _note_freshness(self, key, t_s: float) -> None:
        """Track per-field timestamps; a field whose timestamp stops
        advancing is stale.  A handful of stale polls is tolerated (the
        value is simply reused — DCGM legitimately repeats a sample
        when polled faster than its update cadence); a streak past
        `max_stale_polls` means the channel is wedged and escalates to
        the reconnect path."""
        last = self._last_t.get(key)
        if last is not None and t_s <= last:
            self.stale_reads += 1
            streak = self._stale_streak.get(key, 0) + 1
            self._stale_streak[key] = streak
            if streak > self.max_stale_polls:
                raise TransportError(
                    f"field {key} has been stale for {streak} consecutive "
                    f"polls (timestamp stuck at {last:.3f}s)")
        else:
            self._stale_streak[key] = 0
            self._last_t[key] = t_s

    def _with_retries(self, fn):
        """Run `fn` (a transport read closure), recovering from
        `TransportError` by close → backoff → connect, up to
        `max_retries` times."""
        delay = self.backoff_s
        last: Exception | None = None
        for attempt in range(self.max_retries + 1):
            try:
                self._ensure_connected()
                out = fn()
                self._last_error = None
                return out
            except TransportError as e:
                last = e
                self._last_error = e
                if attempt == self.max_retries:
                    break
                self.retries += 1
                try:
                    self.transport.close()
                except Exception:
                    pass
                self._started = False
                self._sleep(delay)
                delay *= self.backoff_mult
                self.reconnects += 1
        raise TransportError(
            f"{type(self).__name__} gave up after {self.max_retries} "
            f"reconnect attempts: {last}")
