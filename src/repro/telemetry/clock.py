"""Power/thermal clock-frequency process (paper §IV-C).

The SM/TensorCore clock under power management is a mean-reverting noisy
process: during a sustained 16384³ BF16 GEMM the paper measures the H100
clock fluctuating 1,201–1,558 MHz (mean 1,352, σ 32) at 1 kHz.  We model it
as an Ornstein–Uhlenbeck process whose mean depends on load (duty cycle):
heavier sustained matrix work pulls the clock down from boost.  The OFU
pipeline only ever sees *point samples* of this process — reproducing the
instantaneous-sample-vs-hardware-average asymmetry that drives Table I.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.peaks import DEFAULT_CHIP, ChipSpec


@dataclass
class ClockModel:
    """OU process: df = θ(μ(load) − f)dt + σ dW, clipped to [f_min, f_max]."""

    chip: ChipSpec = DEFAULT_CHIP
    theta: float = 2.0           # mean reversion rate (1/s)
    sigma_mhz: float = 32.0      # matches the paper's observed σ
    throttle_frac: float = 0.115  # full-load mean = (1-θf)·f_max
    f_min_frac: float = 0.60

    def mean_clock(self, duty: float) -> float:
        return self.chip.f_max_mhz * (1.0 - self.throttle_frac * duty)

    def simulate(self, duty: np.ndarray, dt_s: float,
                 seed: int = 0) -> np.ndarray:
        """Per-interval clock trajectory given a duty-cycle trajectory.

        duty: (T,) MXU duty cycle in [0,1] per dt_s interval.
        Returns (T,) instantaneous clock samples (MHz) at interval ends.
        """
        rng = np.random.default_rng(seed)
        T = len(duty)
        f = np.empty(T)
        cur = self.mean_clock(float(duty[0]))
        a = np.exp(-self.theta * dt_s)
        # exact OU discretization
        sd = self.sigma_mhz * np.sqrt(max(1e-12, 1 - a * a))
        noise = rng.standard_normal(T)
        f_min = self.chip.f_max_mhz * self.f_min_frac
        for t in range(T):
            mu = self.mean_clock(float(duty[t]))
            cur = mu + (cur - mu) * a + sd * noise[t]
            cur = min(max(cur, f_min), self.chip.f_max_mhz)
            f[t] = cur
        return f
