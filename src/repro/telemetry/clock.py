"""Power/thermal clock-frequency process (paper §IV-C).

The SM/TensorCore clock under power management is a mean-reverting noisy
process: during a sustained 16384³ BF16 GEMM the paper measures the H100
clock fluctuating 1,201–1,558 MHz (mean 1,352, σ 32) at 1 kHz.  We model it
as an Ornstein–Uhlenbeck process whose mean depends on load (duty cycle):
heavier sustained matrix work pulls the clock down from boost.  The OFU
pipeline only ever sees *point samples* of this process — reproducing the
instantaneous-sample-vs-hardware-average asymmetry that drives Table I.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.peaks import DEFAULT_CHIP, ChipSpec


@dataclass
class ClockModel:
    """OU process: df = θ(μ(load) − f)dt + σ dW, clipped to [f_min, f_max]."""

    chip: ChipSpec = DEFAULT_CHIP
    theta: float = 2.0           # mean reversion rate (1/s)
    sigma_mhz: float = 32.0      # matches the paper's observed σ
    throttle_frac: float = 0.115  # full-load mean = (1-θf)·f_max
    f_min_frac: float = 0.60

    def mean_clock(self, duty):
        """Load-dependent OU mean; accepts a scalar or an ndarray duty."""
        return self.chip.f_max_mhz * (1.0 - self.throttle_frac * duty)

    def ou_step_constants(self, dt_s: float) -> tuple[float, float]:
        """(a, sd) of the exact one-step OU discretization at step dt_s:
        f' = μ + (f − μ)·a + sd·N(0,1), with a = e^{−θ·dt} and
        sd = σ·sqrt(1 − a²).  The ONE definition shared by the scalar
        loop, the batched NumPy recurrence, and the jax backend's
        `lax.scan` — backends may not drift apart on the discretization.
        """
        a = float(np.exp(-self.theta * dt_s))
        sd = float(self.sigma_mhz * np.sqrt(max(1e-12, 1 - a * a)))
        return a, sd

    def simulate(self, duty: np.ndarray, dt_s: float,
                 seed: int = 0) -> np.ndarray:
        """Per-interval clock trajectory given a duty-cycle trajectory.

        duty: (T,) MXU duty cycle in [0,1] per dt_s interval.
        Returns (T,) instantaneous clock samples (MHz) at interval ends.
        """
        rng = np.random.default_rng(seed)
        T = len(duty)
        f = np.empty(T)
        cur = self.mean_clock(float(duty[0]))
        a, sd = self.ou_step_constants(dt_s)   # exact OU discretization
        noise = rng.standard_normal(T)
        f_min = self.chip.f_max_mhz * self.f_min_frac
        for t in range(T):
            mu = self.mean_clock(float(duty[t]))
            cur = mu + (cur - mu) * a + sd * noise[t]
            cur = min(max(cur, f_min), self.chip.f_max_mhz)
            f[t] = cur
        return f

    def simulate_batch(self, duty: np.ndarray, dt_s: float, seed: int = 0,
                       f0: np.ndarray | None = None) -> np.ndarray:
        """Batched OU trajectories: one clock process per device.

        duty: (n_devices, T) MXU duty cycle in [0,1] per dt_s interval.
        f0:   optional (n_devices,) initial clocks; defaults to the
              load-dependent mean at t=0 (same convention as simulate()).
        Returns (n_devices, T) instantaneous clock samples (MHz).  The
        recurrence is over T only; all device math is vectorized, which is
        what makes fleet-scale simulation tractable.
        """
        duty = np.asarray(duty)
        if duty.dtype != np.float32:      # clock resolution: f32 ≈ 1e-4 MHz
            duty = duty.astype(float, copy=False)  # fleet grids pass f32;
        dt = duty.dtype                   # scalar callers keep f64
        D, T = duty.shape
        rng = np.random.default_rng(seed)
        a, sd = self.ou_step_constants(dt_s)
        # time-major layout so every recurrence step touches contiguous
        # memory, with the non-recurrent terms (μ(1−a) + σ·dW) folded into
        # one precomputed drive array — the loop is 3 in-place ops per step.
        # μ·(1−a) expands to c1 − c2·duty, built transposed in two passes.
        drive = np.empty((T, D), dtype=dt)
        np.multiply(duty.T, -self.chip.f_max_mhz * self.throttle_frac
                    * (1.0 - a), out=drive)
        cur = self.mean_clock(duty[:, 0].copy()) if f0 is None else \
            np.broadcast_to(np.asarray(f0, dt), (D,)).astype(dt)
        drive += self.chip.f_max_mhz * (1.0 - a)
        # float32 N(0,1) draws: σ·dW granularity ~1e-5 MHz, far below the
        # 32 MHz noise floor, and generation is ~2× faster at fleet scale
        drive += sd * rng.standard_normal((T, D), dtype=np.float32)
        f_min = self.chip.f_max_mhz * self.f_min_frac
        f = np.empty((T, D), dtype=dt)
        for t in range(T):
            cur *= a
            cur += drive[t]
            np.clip(cur, f_min, self.chip.f_max_mhz, out=cur)
            f[t] = cur
        return np.ascontiguousarray(f.T)
