"""Pluggable column codecs for always-on counter recording.

A codec turns one aligned counter column — a `(n_devices, n_samples)`
array in its native dtype — into bytes and back, EXACTLY (bit-for-bit,
including NaN/Inf payloads).  The `ctr-v2` single-file container
(`telemetry.tracestore`) tags every chunk block with the codec that
wrote it, so archives mix codecs freely and readers never guess.

Three families:

  * ``raw`` — the array's native bytes.  Zero transform, zero copy on
    the mmap read path (`decode` returns a read-only view over the
    container's buffer), the speed-of-light baseline.
  * ``zlib`` — DEFLATE over the native bytes; what v1's `.npz` chunks
    effectively do, kept as the compatibility/back-compat point.
  * ``dbz`` — xor-delta along the time axis, then a bit-plane transpose
    (bitshuffle), then zstd when the optional ``zstandard`` module is
    present, zlib otherwise (tagged ``dbz-zstd`` / ``dbz-zlib`` so a
    reader knows which inner compressor to undo).  Counter series move
    slowly, so consecutive samples share high bits: the xor-delta zeroes
    them and the bit transpose lines the zeroed planes up into long runs
    the byte compressor eats.  On DCGM-wire-precision counters (tensor
    activity at ~3 decimals, SM clock in whole MHz — what `dcgmi`/NVML
    actually deliver) this lands ≥15x smaller than CSV; on synthetic
    full-precision f32 noise it still beats the zlib-npz path, pinned by
    the `trace_codecs` BENCH case.

The transform is LOSSLESS by construction: it permutes and xors bit
patterns, never rounds values — NaN payloads, signed zeros and Inf all
round-trip (the property suite in `tests/test_codecs.py` asserts bit
identity, not value closeness).
"""
from __future__ import annotations

import zlib
from typing import Optional

import numpy as np

try:                                     # optional: the container image
    import zstandard as _zstd            # does not ship zstandard
except ImportError:                      # pragma: no cover - env specific
    _zstd = None

HAVE_ZSTD = _zstd is not None

#: zlib/zstd effort levels — decode speed is flat in these, so they only
#: trade encode time for bytes; 6 is zlib's sweet spot on shuffled planes
ZLIB_LEVEL = 6
ZSTD_LEVEL = 7


def _uint_view(arr: np.ndarray) -> np.ndarray:
    """Reinterpret a numeric array as same-width unsigned ints (the
    domain the delta/shuffle transform operates in)."""
    kind = arr.dtype.kind
    if kind not in "fiu" or arr.dtype.itemsize not in (2, 4, 8):
        raise ValueError(
            f"codec supports 2/4/8-byte int and float columns, not "
            f"{arr.dtype}")
    return arr.view(f"u{arr.dtype.itemsize}")


def bit_transpose(u: np.ndarray) -> bytes:
    """Bitshuffle: regroup an unsigned-int array by BIT PLANE.

    Element k's bit b moves to position (b * n + k) of the output
    stream — all the sign bits together, then all the top-exponent
    bits, and so on.  Near-constant planes become runs of identical
    bytes; pure numpy (unpackbits/packbits), no compiled extension.
    """
    n, isz = u.size, u.dtype.itemsize
    if n == 0:
        return b""
    bits = np.unpackbits(u.reshape(-1).view(np.uint8).reshape(n, isz),
                         axis=1, bitorder="little")        # (n, 8*isz)
    return np.packbits(bits.T, bitorder="little").tobytes()


def bit_untranspose(data: bytes, n: int, itemsize: int) -> np.ndarray:
    """Invert `bit_transpose` back to n unsigned ints of `itemsize`."""
    if n == 0:
        return np.empty(0, dtype=f"u{itemsize}")
    nbits = 8 * itemsize
    bits = np.unpackbits(np.frombuffer(data, np.uint8),
                         bitorder="little")[:n * nbits]
    planes = bits.reshape(nbits, n)
    packed = np.packbits(planes.T, bitorder="little")
    # nbits is a multiple of 8, so the packed stream is exactly
    # n * itemsize bytes — no tail padding to trim
    return np.frombuffer(packed.tobytes(), dtype=f"u{itemsize}")


class Codec:
    """Interface: encode a column to bytes, decode it back exactly.

    `decode` receives the dtype and (n_devices, n_samples) shape the
    container recorded — codecs carry no geometry of their own.
    """

    #: tag written into the container's chunk table
    name: str = ""

    def encode(self, arr: np.ndarray) -> bytes:
        raise NotImplementedError

    def decode(self, data: bytes, dtype: np.dtype,
               shape: tuple) -> np.ndarray:
        raise NotImplementedError


class RawCodec(Codec):
    """Native array bytes; decode is a zero-copy view over the input
    buffer (read-only when the buffer is, e.g. an mmap'd archive)."""

    name = "raw"

    def encode(self, arr: np.ndarray) -> bytes:
        return np.ascontiguousarray(arr).tobytes()

    def decode(self, data, dtype, shape) -> np.ndarray:
        return np.frombuffer(data, dtype=dtype).reshape(shape)


class ZlibCodec(Codec):
    """DEFLATE over native bytes — the v1 `.npz` behaviour as a plain
    block codec (the back-compat point for tooling that expects it)."""

    name = "zlib"

    def encode(self, arr: np.ndarray) -> bytes:
        return zlib.compress(np.ascontiguousarray(arr).tobytes(),
                             ZLIB_LEVEL)

    def decode(self, data, dtype, shape) -> np.ndarray:
        return np.frombuffer(zlib.decompress(data),
                             dtype=dtype).reshape(shape)


class DeltaBitshuffleCodec(Codec):
    """xor-delta (time axis) + bit-plane transpose + zstd-or-zlib.

    The delta is an XOR of each sample with its predecessor IN THE SAME
    DEVICE ROW — exactly invertible in integer space with no overflow
    cases, and it zeroes every bit the two float patterns share.
    """

    def __init__(self, inner: str = "zstd" if HAVE_ZSTD else "zlib"):
        if inner == "zstd" and not HAVE_ZSTD:
            raise ValueError(
                "dbz-zstd codec requires the 'zstandard' module, which "
                "is not installed; use dbz-zlib (decoders pick the "
                "right inner compressor from the chunk's codec tag)")
        if inner not in ("zstd", "zlib"):
            raise ValueError(f"unknown inner compressor {inner!r}")
        self.inner = inner
        self.name = f"dbz-{inner}"

    # -- inner byte compressor -----------------------------------------
    def _squeeze(self, data: bytes) -> bytes:
        if self.inner == "zstd":
            return _zstd.ZstdCompressor(level=ZSTD_LEVEL).compress(data)
        return zlib.compress(data, ZLIB_LEVEL)

    def _unsqueeze(self, data: bytes) -> bytes:
        if self.inner == "zstd":
            return _zstd.ZstdDecompressor().decompress(data)
        return zlib.decompress(data)

    # -- Codec ----------------------------------------------------------
    def encode(self, arr: np.ndarray) -> bytes:
        arr = np.ascontiguousarray(arr)
        u = _uint_view(arr)
        d = u.copy()
        if d.ndim >= 1 and d.shape[-1] > 1:
            d[..., 1:] ^= u[..., :-1]
        return self._squeeze(bit_transpose(d))

    def decode(self, data, dtype, shape) -> np.ndarray:
        dtype = np.dtype(dtype)
        n = int(np.prod(shape)) if shape else 0
        u = bit_untranspose(self._unsqueeze(data) if n else b"",
                            n, dtype.itemsize).reshape(shape).copy()
        if u.ndim >= 1 and u.shape[-1] > 1:
            np.bitwise_xor.accumulate(u, axis=-1, out=u)
        return u.view(dtype)


#: the registry the container resolves chunk tags against
_CODECS: dict = {}
for _c in (RawCodec(), ZlibCodec(), DeltaBitshuffleCodec("zlib")):
    _CODECS[_c.name] = _c
if HAVE_ZSTD:                            # pragma: no cover - env specific
    _CODECS["dbz-zstd"] = DeltaBitshuffleCodec("zstd")

#: what `codec="auto"` resolves to: the best always-available recorder
DEFAULT_CODEC = "dbz-zstd" if HAVE_ZSTD else "dbz-zlib"


def get_codec(name: Optional[str]) -> Codec:
    """Resolve a codec tag (or None/'auto' for the default)."""
    if name in (None, "auto"):
        name = DEFAULT_CODEC
    if name == "dbz":                    # family alias -> concrete tag
        name = DEFAULT_CODEC
    codec = _CODECS.get(name)
    if codec is None:
        if name == "dbz-zstd":
            raise ValueError(
                "archive chunk was written with dbz-zstd but the "
                "'zstandard' module is not installed in this "
                "environment; install it to read this archive")
        raise ValueError(f"unknown codec {name!r} "
                         f"(have {sorted(_CODECS)})")
    return codec


def codec_names() -> list:
    """Registered codec tags (environment-dependent: dbz-zstd appears
    only when zstandard is installed)."""
    return sorted(_CODECS)
