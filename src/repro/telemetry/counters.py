"""Counter backends: the interface OFU consumes, with two implementations.

`CounterBackend` exposes exactly the two signals the paper's metric uses:
  * matrix-pipe duty cycle, HARDWARE-AVERAGED over the collection window
    (the DCGM PIPE_TENSOR_ACTIVE semantics, max 30 s averaging window), and
  * the pipeline clock as an INSTANTANEOUS point sample
    (the DCGM_FI_DEV_SM_CLOCK semantics).

`SimulatedDeviceBackend` generates both from a step profile (MXU-busy time
per step + step period, derivable from a compiled dry-run) plus injected
inefficiency events — so every downstream fleet component runs unchanged
against real counters (`telemetry.backends`: `DcgmFieldBackend` for DCGM
GPUs, `TpuProfilerBackend` for libtpu — the deploy tier).
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np

from repro.core.peaks import DEFAULT_CHIP, ChipSpec
from repro.telemetry.clock import ClockModel

#: DCGM averages tensor-pipe activity over at most this window (paper §IV-C);
#: scraping slower than this produces an average-of-averages.
MAX_HW_AVG_WINDOW_S = 30.0


def check_scrape_interval(interval_s: float, *, strict: bool = True,
                          stacklevel: int = 3) -> float:
    """Enforce the §IV-C rule shared by every scrape path (scalar scrape
    loop, vectorized engine, fused fleet grid).

    Returns the effective hardware averaging window.  strict=True raises
    on intervals beyond MAX_HW_AVG_WINDOW_S; strict=False degrades with a
    RuntimeWarning — each reading then only reflects the trailing window.
    """
    if interval_s > MAX_HW_AVG_WINDOW_S:
        msg = (f"scrape interval {interval_s}s exceeds the "
               f"{MAX_HW_AVG_WINDOW_S}s hardware averaging window "
               "(average-of-averages, paper §IV-C)")
        if strict:
            raise ValueError(msg)
        warnings.warn(msg + "; readings only cover the trailing "
                      f"{MAX_HW_AVG_WINDOW_S}s of each interval",
                      RuntimeWarning, stacklevel=stacklevel)
    return min(interval_s, MAX_HW_AVG_WINDOW_S)


@dataclass
class Event:
    """An injected inefficiency: between [start_s, end_s) every step is
    stretched by `slowdown` while MXU-busy time stays constant (host-sync
    serialization à la the paper's Gloo case), and/or MXU work is scaled."""

    start_s: float
    end_s: float
    slowdown: float = 1.0
    mxu_scale: float = 1.0
    kind: str = "host_sync"


@dataclass
class StepProfile:
    """What one training/serving step looks like on one device."""

    mxu_time_s: float            # time the matrix pipe is busy per step
    step_time_s: float           # wall-clock per step (>= mxu_time_s)
    flops_by_precision: dict = field(default_factory=dict)
    jitter: float = 0.03         # per-step lognormal wall-time jitter

    @property
    def duty(self) -> float:
        return min(1.0, self.mxu_time_s / self.step_time_s)


# ---------------------------------------------------------------------------
# Vectorized counter path (the fleet-engine hot loop)
# ---------------------------------------------------------------------------
def event_factors(events: Sequence[Event],
                  ts: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Per-time (slowdown, mxu_scale) arrays for a time grid of any shape.

    Vectorized replacement for the linear per-sample event scan: iterating
    the (few) events over the (many) samples instead of the reverse.  When
    events overlap, the FIRST event by start time wins — matching the
    scalar backend's scan order — hence the reversed assignment loop.
    """
    ts = np.asarray(ts, float)
    slow = np.ones_like(ts)
    scale = np.ones_like(ts)
    # reversed stable ascending sort: on equal start times the FIRST-listed
    # event is assigned last, i.e. wins — exactly the scan's tie-break
    for e in reversed(sorted(events, key=lambda e: e.start_s)):
        m = (e.start_s <= ts) & (ts < e.end_s)
        slow[m] = e.slowdown
        scale[m] = e.mxu_scale
    return slow, scale


def duty_grid(profile: StepProfile, ts: np.ndarray, *,
              straggler=1.0, events: Sequence[Event] = ()) -> np.ndarray:
    """Deterministic duty cycle evaluated on a whole time grid at once.

    ts may be any shape; `straggler` may be a scalar or an array that
    broadcasts against ts (e.g. (n_devices, 1, 1) against (S, n_sub) for a
    full fleet grid).  Semantics match SimulatedDeviceBackend._duty_at.
    """
    slow, scale = event_factors(events, ts)
    step = profile.step_time_s * np.asarray(straggler, float) * slow
    mxu = profile.mxu_time_s * scale
    return np.minimum(1.0, mxu / step)


class CounterBackend:
    """Interface: poll(window_s) -> (tpa_avg, clock_mhz_sample)."""

    def poll(self, window_s: float) -> tuple[float, float]:
        raise NotImplementedError


def __getattr__(name: str):
    """Lazy re-export: `TpuProfilerBackend` moved to
    `telemetry.backends.tpu` when it grew a real transport tier, but
    its historical home (`from repro.telemetry.counters import
    TpuProfilerBackend`) keeps working.  PEP 562 indirection instead of
    a top-level import because `backends` imports this module — the
    deferred lookup breaks the cycle."""
    if name == "TpuProfilerBackend":
        from repro.telemetry.backends.tpu import TpuProfilerBackend
        return TpuProfilerBackend
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


class SimulatedDeviceBackend(CounterBackend):
    """First-principles device simulator emitting counter streams.

    Time advances only via poll(); the device integrates MXU-busy time at
    sub-step resolution (hardware averaging), while the clock is sampled
    as a point value at the poll instant (the paper's noise source).
    """

    def __init__(self, profile: StepProfile, *,
                 chip: ChipSpec = DEFAULT_CHIP,
                 clock_model: Optional[ClockModel] = None,
                 events: Sequence[Event] = (),
                 straggler_factor: float = 1.0,
                 seed: int = 0):
        self.profile = profile
        self.chip = chip
        self.clock_model = clock_model or ClockModel(chip=chip)
        self.events = sorted(events, key=lambda e: e.start_s)
        self.straggler = straggler_factor
        self.rng = np.random.default_rng(seed)
        self.now_s = 0.0
        self._clock = self.clock_model.mean_clock(profile.duty)
        self._seed = seed

    # -- internals ----------------------------------------------------------
    def _duty_at(self, t: float) -> float:
        """Mean duty cycle around time t (deterministic component)."""
        return float(duty_grid(self.profile, np.asarray([t]),
                               straggler=self.straggler,
                               events=self.events)[0])

    # -- CounterBackend -----------------------------------------------------
    def poll(self, window_s: float) -> tuple[float, float]:
        """Advance time by window_s; return (hw-averaged TPA, clock sample).

        The hardware averages duty cycle over at most MAX_HW_AVG_WINDOW_S;
        longer scrape intervals therefore return the average of the LAST
        30 s only (average-of-averages hazard, paper §IV-C).
        """
        t0, t1 = self.now_s, self.now_s + window_s
        self.now_s = t1
        avg_w = min(window_s, MAX_HW_AVG_WINDOW_S)
        # integrate duty over the averaging window at sub-step resolution
        n = max(8, int(avg_w / max(self.profile.step_time_s / 4, 1e-3)))
        n = min(n, 4096)
        ts = np.linspace(t1 - avg_w, t1, n, endpoint=False)
        duties = duty_grid(self.profile, ts, straggler=self.straggler,
                           events=self.events)
        # per-step jitter -> duty wobble (hardware-averaged, so mild)
        duties = duties * np.exp(self.rng.standard_normal(n)
                                 * self.profile.jitter / np.sqrt(n))
        tpa = float(np.clip(duties.mean(), 0.0, 1.0))

        # clock: evolve the OU process across the full window, keep ONLY the
        # final instantaneous sample (point-sample semantics)
        steps = max(4, min(int(window_s * 10), 600))
        traj = self.clock_model.simulate(
            np.full(steps, self._duty_at(t1 - 1e-6)),
            dt_s=window_s / steps,
            seed=int(self.rng.integers(0, 2 ** 31)))
        self._clock = float(traj[-1])
        return tpa, self._clock

    # convenience: a dense 1 Hz reference trace (for Table I baselines)
    def trace(self, duration_s: float, interval_s: float = 1.0):
        out = []
        while self.now_s < duration_s:
            out.append(self.poll(interval_s))
        tpa, clk = np.array(out).T
        return tpa, clk
