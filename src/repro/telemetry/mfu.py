"""Application-side MFU reporting: the app half of the OFU<->MFU join.

Training frameworks already log their achieved model-FLOPs throughput —
Megatron-style progress lines carry ``throughput per GPU (TFLOP/s/GPU)``
and ``elapsed time per iteration (ms)`` fields.  This module turns that
stream into per-job, time-stamped MFU samples the correlation tier
(`repro.fleet.correlation`) can bucket against counter-derived OFU:

  * `extract_tflops_from_log` / `compute_mfu` — stateless log-line
    extraction and throughput -> MFU conversion (Eq. 10);
  * `MfuReporter` — a stateful line feeder that keeps the job clock
    (from the log's own elapsed-ms field when present), accumulates
    `MfuSample`s, and hands them off as a pollable source;
  * `MfuReplaySource` — poll/cursor semantics over an in-memory sample
    series, the MFU mirror of `telemetry.source.GridSource`: a
    `Collector` round polls `(cursor, cursor + duration]` and the
    cursor advances even through gaps;
  * `reported_tflops_per_gpu` — the analytic side: what a framework's
    FLOPs counter (exact or one of the buggy §V-C variants) would
    report for an arch at a measured step time, via
    `flops.accounting.step_flops`.

The reported number is whatever the framework BELIEVES it executed —
a miscalculated counter (``naive_moe``, ``naive_hybrid``) inflates it,
which is exactly the signature the correlation tier detects.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Iterable, Optional, Union

import numpy as np

from repro.core.ofu import effective_peak, mfu_from_throughput
from repro.core.peaks import DEFAULT_CHIP, ChipSpec

# Megatron-LM-style progress-line fields (tohtana's log-bench format)
ITERATION_RE = re.compile(r"iteration\s+(\d+)")
TFLOPS_RE = re.compile(
    r"throughput per GPU \(TFLOP/s/GPU\):\s*([0-9]*\.?[0-9]+)")
ELAPSED_MS_RE = re.compile(
    r"elapsed time per iteration \(ms\):\s*([0-9]*\.?[0-9]+)")
#: leading wall-clock stamp Megatron/torchrun prepend, e.g.
#: ``[2026-08-09 13:04:55]`` or ``2026-08-09 13:04:55,123`` — date and
#: time with optional fractional seconds
TIMESTAMP_RE = re.compile(
    r"(\d{4})-(\d{2})-(\d{2})[ T](\d{2}):(\d{2}):(\d{2})(?:[.,](\d+))?")


def extract_wall_time(line: str) -> Optional[float]:
    """Wall-clock seconds (arbitrary absolute epoch) from a log line's
    timestamp, or None.  Only DIFFERENCES between lines are meaningful
    — the reporter anchors its relative clock to them."""
    m = TIMESTAMP_RE.search(line)
    if m is None:
        return None
    import datetime
    y, mo, d, h, mi, s = (int(g) for g in m.groups()[:6])
    frac = m.group(7)
    us = int(round(float("0." + frac) * 1e6)) if frac else 0
    try:
        dt = datetime.datetime(y, mo, d, h, mi, s, us)
    except ValueError:            # e.g. month 13: not a real timestamp
        return None
    return dt.timestamp()


def compute_mfu(tflops_per_gpu: float, peak_tflops: float) -> float:
    """Reported throughput -> MFU fraction (Eq. 10, one-chip form)."""
    if peak_tflops <= 0:
        raise ValueError(f"peak_tflops={peak_tflops} must be positive")
    return mfu_from_throughput(tflops_per_gpu, peak_tflops)


def extract_tflops_from_log(
        lines: Union[str, Iterable[str]]) -> list[dict]:
    """Pull (iteration, tflops_per_gpu, elapsed_ms) records out of a
    training log.  Lines without a throughput field are skipped; the
    iteration and elapsed-ms fields are optional per line."""
    if isinstance(lines, str):
        lines = lines.splitlines()
    records = []
    for line in lines:
        m = TFLOPS_RE.search(line)
        if m is None:
            continue
        it = ITERATION_RE.search(line)
        ms = ELAPSED_MS_RE.search(line)
        records.append({
            "iteration": int(it.group(1)) if it else None,
            "tflops_per_gpu": float(m.group(1)),
            "elapsed_ms": float(ms.group(1)) if ms else None,
        })
    return records


@dataclass(frozen=True)
class MfuSample:
    """One app-reported efficiency observation."""

    t_s: float                 # job-relative seconds
    mfu: float                 # fraction of effective peak
    tflops_per_gpu: float
    iteration: Optional[int] = None


@dataclass
class MfuReporter:
    """Feed training-log lines, collect time-stamped MFU samples.

    The clock starts at `t0_s` and advances by each line's own
    elapsed-ms field when present, else by `default_interval_s` — so a
    log with no absolute timestamps still yields a monotone sample
    series aligned with the job's relative clock (the same clock the
    simulator's scrape grid uses).

    WALL-CLOCK ANCHORING: when lines carry real timestamps (Megatron
    prepends ``[YYYY-MM-DD HH:MM:SS]``), sample times anchor to them
    instead of the elapsed-ms accumulator — the first timestamped line
    pins (wall time ↔ job clock) and every later timestamped sample
    lands at `anchor + (wall - wall0)`.  Elapsed-ms only measures the
    iteration itself, so checkpoint stalls, evals and dataloader hangs
    silently DESYNC the accumulator from real time; the wall anchor is
    what lets a live reporter's samples join counter buckets on
    absolute time (the OFU↔MFU correlation join).  Untimestamped lines
    fall back to the accumulator, re-synced at each timestamped one.
    """

    job_id: str
    peak_tflops: float
    t0_s: float = 0.0
    default_interval_s: float = 30.0
    samples: list = field(default_factory=list)

    def __post_init__(self):
        if self.peak_tflops <= 0:
            raise ValueError(
                f"peak_tflops={self.peak_tflops} must be positive")
        self._clock_s = float(self.t0_s)
        self._wall0: Optional[float] = None    # first line's wall time
        self._anchor_s = 0.0                   # job clock at that line

    @classmethod
    def for_chip(cls, job_id: str, *, chip: ChipSpec = DEFAULT_CHIP,
                 precisions: Optional[dict] = None, **kw) -> "MfuReporter":
        """Reporter with the peak derived from a chip's effective peak
        over the job's precision mix (defaults to pure bf16)."""
        peak = effective_peak(precisions or {"bf16": 1.0}, chip)
        return cls(job_id, peak, **kw)

    def feed(self, line: str,
             t_s: Optional[float] = None) -> Optional[MfuSample]:
        """Parse one log line; returns the new sample or None.

        An explicit `t_s` pins the sample's timestamp (and resets the
        internal clock); otherwise the clock advances per the line.
        """
        recs = extract_tflops_from_log([line])
        if not recs:
            return None
        rec = recs[0]
        dt = (rec["elapsed_ms"] / 1e3 if rec["elapsed_ms"] is not None
              else self.default_interval_s)
        wall = extract_wall_time(line)
        if t_s is not None:
            self._clock_s = float(t_s)
            if wall is not None:       # explicit pin re-anchors the wall
                self._wall0, self._anchor_s = wall, self._clock_s
        elif wall is not None:
            if self._wall0 is None:
                # first timestamped line: accept the accumulator's
                # position once, then pin wall time to it
                self._clock_s += dt
                self._wall0, self._anchor_s = wall, self._clock_s
            else:
                self._clock_s = self._anchor_s + (wall - self._wall0)
        else:
            self._clock_s += dt
        sample = MfuSample(
            t_s=self._clock_s,
            mfu=compute_mfu(rec["tflops_per_gpu"], self.peak_tflops),
            tflops_per_gpu=rec["tflops_per_gpu"],
            iteration=rec["iteration"])
        self.samples.append(sample)
        return sample

    def feed_log(self, lines: Union[str, Iterable[str]]) -> list:
        """Feed a whole log (string or line iterable); returns the
        samples it produced."""
        if isinstance(lines, str):
            lines = lines.splitlines()
        return [s for s in (self.feed(ln) for ln in lines)
                if s is not None]

    def to_source(self) -> "MfuReplaySource":
        """Snapshot the accumulated samples as a pollable source."""
        return MfuReplaySource(
            np.array([s.t_s for s in self.samples], dtype=float),
            np.array([s.mfu for s in self.samples], dtype=float))


class MfuReplaySource:
    """Replays an in-memory MFU sample series with poll/cursor
    semantics — the MFU counterpart of `source.GridSource`.

    `poll(duration_s)` returns the `(t_s, mfu)` arrays with
    `cursor < t <= cursor + duration` and advances the cursor by the
    full duration (gaps advance time, like an empty scrape round).
    """

    def __init__(self, t_s, mfu):
        t = np.asarray(t_s, dtype=float)
        v = np.asarray(mfu, dtype=float)
        if t.ndim != 1 or t.shape != v.shape:
            raise ValueError(
                f"t_s {t.shape} and mfu {v.shape} must be equal-length "
                "1-D arrays")
        if t.size and np.any(np.diff(t) < 0):
            raise ValueError("sample times must be non-decreasing")
        self.t_s = t
        self.mfu = v
        self._cursor_s = 0.0

    @classmethod
    def constant(cls, mfu: float, *, duration_s: float,
                 interval_s: float = 30.0) -> "MfuReplaySource":
        """A steady reporter: one sample per interval at a fixed MFU
        (the scenario library's shape for always-on app reporting)."""
        n = int(round(duration_s / interval_s))
        t = (np.arange(n, dtype=float) + 1.0) * interval_s
        return cls(t, np.full(n, float(mfu)))

    @property
    def cursor_s(self) -> float:
        return self._cursor_s

    @property
    def exhausted(self) -> bool:
        return (not self.t_s.size
                or self._cursor_s >= float(self.t_s[-1]) - 1e-9)

    def seek(self, t_s: float) -> None:
        """Reposition the replay cursor (collector snapshot restore)."""
        if t_s < 0:
            raise ValueError(f"seek target {t_s}s must be >= 0")
        self._cursor_s = float(t_s)

    def poll(self, duration_s: float):
        if duration_s <= 0:
            raise ValueError(
                f"poll duration {duration_s}s must be positive")
        c = self._cursor_s
        i0, i1 = np.searchsorted(self.t_s,
                                 [c + 1e-9, c + duration_s + 1e-9])
        self._cursor_s = c + duration_s
        return self.t_s[i0:i1], self.mfu[i0:i1]


def reported_tflops_per_gpu(arch: str, step_time_s: float, chips: int, *,
                            shape: str = "train_4k",
                            variant: str = "exact",
                            remat: bool = False) -> float:
    """What an app's FLOPs counter would log per GPU for this arch at a
    measured step time — exact, or one of the §V-C buggy variants."""
    from repro.configs.base import SHAPES, get_config
    from repro.flops.accounting import step_flops
    if step_time_s <= 0:
        raise ValueError(f"step_time_s={step_time_s} must be positive")
    if chips < 1:
        raise ValueError(f"chips={chips} must be >= 1")
    bd = step_flops(get_config(arch), SHAPES[shape], variant=variant,
                    executed=False, remat=remat)
    return bd.total_mxu / step_time_s / chips / 1e12
