"""Prometheus-style scraper over counter backends (paper §V-B telemetry).

Enforces the §IV-C rule: scrape interval must be ≤ the hardware averaging
window (30 s), otherwise readings become averages-of-averages.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field

import numpy as np

from repro.telemetry.counters import MAX_HW_AVG_WINDOW_S, CounterBackend


@dataclass
class ScrapeSeries:
    """Aligned counter series for one device."""

    interval_s: float
    tpa: np.ndarray
    clock_mhz: np.ndarray

    def subsample(self, factor: int) -> "ScrapeSeries":
        """Coarser scrape (Table I methodology): keep every factor-th point."""
        return ScrapeSeries(self.interval_s * factor,
                            self.tpa[factor - 1::factor],
                            self.clock_mhz[factor - 1::factor])


def scrape(backend: CounterBackend, duration_s: float, interval_s: float,
           *, strict: bool = True) -> ScrapeSeries:
    """Collect (TPA, clock) at a fixed interval for duration_s."""
    if interval_s > MAX_HW_AVG_WINDOW_S:
        msg = (f"scrape interval {interval_s}s exceeds the "
               f"{MAX_HW_AVG_WINDOW_S}s hardware averaging window "
               "(average-of-averages, paper §IV-C)")
        if strict:
            raise ValueError(msg)
        # degraded mode: each reading only reflects the LAST 30 s before
        # the poll instant; everything in between is invisible
        warnings.warn(msg + "; readings only cover the trailing "
                      f"{MAX_HW_AVG_WINDOW_S}s of each interval",
                      RuntimeWarning, stacklevel=2)
    n = int(duration_s / interval_s)
    tpa = np.empty(n)
    clk = np.empty(n)
    for i in range(n):
        tpa[i], clk[i] = backend.poll(interval_s)
    return ScrapeSeries(interval_s, tpa, clk)
