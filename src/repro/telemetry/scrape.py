"""Prometheus-style scraper over counter backends (paper §V-B telemetry).

Enforces the §IV-C rule: scrape interval must be ≤ the hardware averaging
window (30 s), otherwise readings become averages-of-averages.

Also home of the two aligned-counter containers the whole pipeline speaks:
`ScrapeSeries` (one device) and `DeviceGrid` (a batched device group, the
return type of every `TelemetrySource`).  Rollups and detectors consume
these and never learn where the samples came from.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

from repro.telemetry.counters import CounterBackend, check_scrape_interval


@dataclass
class ScrapeSeries:
    """Aligned counter series for one device."""

    interval_s: float
    tpa: np.ndarray
    clock_mhz: np.ndarray
    t0_s: float = 0.0            # absolute start of the first window

    def subsample(self, factor: int) -> "ScrapeSeries":
        """Coarser scrape (Table I methodology): keep every factor-th point."""
        return ScrapeSeries(self.interval_s * factor,
                            self.tpa[factor - 1::factor],
                            self.clock_mhz[factor - 1::factor],
                            t0_s=self.t0_s)


@dataclass
class DeviceGrid:
    """Batched scrape result: row d is device d's aligned counter series."""

    interval_s: float
    tpa: np.ndarray              # (n_devices, n_samples)
    clock_mhz: np.ndarray        # (n_devices, n_samples)
    #: absolute start of the first collection window — nonzero when the
    #: grid is a slice of a longer run (e.g. a replayed mid-run trace), so
    #: rollup buckets land at the recorded times, not rebased to zero
    t0_s: float = 0.0

    @property
    def n_devices(self) -> int:
        return self.tpa.shape[0]

    @property
    def times_s(self) -> np.ndarray:
        """Poll instants (window ends) shared by every device."""
        return self.t0_s + (np.arange(self.tpa.shape[1]) + 1) \
            * self.interval_s

    def series(self, d: int) -> ScrapeSeries:
        return ScrapeSeries(self.interval_s, self.tpa[d], self.clock_mhz[d],
                            t0_s=self.t0_s)

    def to_series_list(self) -> list:
        return [self.series(d) for d in range(self.n_devices)]

    @classmethod
    def from_series(cls, series: Sequence[ScrapeSeries]) -> "DeviceGrid":
        """Stack per-device series (must be aligned: same interval/length)."""
        if not series:
            return cls(0.0, np.empty((0, 0)), np.empty((0, 0)))
        iv = series[0].interval_s
        n = len(series[0].tpa)
        t0 = series[0].t0_s
        if any(s.interval_s != iv or len(s.tpa) != n or s.t0_s != t0
               for s in series):
            raise ValueError("cannot stack misaligned ScrapeSeries "
                             "(intervals/lengths/offsets differ)")
        return cls(iv, np.stack([s.tpa for s in series]),
                   np.stack([s.clock_mhz for s in series]), t0_s=t0)


def scrape(backend: CounterBackend, duration_s: float, interval_s: float,
           *, strict: bool = True) -> ScrapeSeries:
    """Collect (TPA, clock) at a fixed interval for duration_s."""
    check_scrape_interval(interval_s, strict=strict)
    n = int(duration_s / interval_s)
    tpa = np.empty(n)
    clk = np.empty(n)
    for i in range(n):
        tpa[i], clk[i] = backend.poll(interval_s)
    return ScrapeSeries(interval_s, tpa, clk)
