"""Telemetry sources: one interface over simulated, replayed, and live
counter streams (the source-agnostic pipeline behind the paper's §V-B
fleet dashboards).

Every source answers `scrapes() -> DeviceGrid`; everything downstream —
`StreamingRollup`, `detect_regressions`, `divergence.analyze` — consumes
that grid and never learns whether the samples came from the vectorized
engine (`SimulatorSource`), a per-poll `CounterBackend` loop
(`BackendSource`, the adapter point for live DCGM/libtpu pollers), or a
recorded trace (`TraceReplaySource`).  Deploying against real hardware
telemetry means adding one more source, not touching the pipeline.

Trace format (CSV with header, or JSONL — one record per line):

    t_s,device,tpa,clock_mhz
    30.0,0,0.412,1328.5

`write_trace`/`read_trace` round-trip a `DeviceGrid` exactly (floats are
serialized at full repr precision).

Sources are also RESUMABLE: `poll(duration_s)` scrapes the next chunk of
wall-time from a per-source cursor (grids come back with the right
absolute `t0_s`), which is what the long-lived `fleet.collector.Collector`
drives round after round — and `set_interval` retimes a live source under
the shared §IV-C `check_scrape_interval` policy (the adaptive controller's
actuator).  `scrapes()` remains the stateless one-shot batch view.

See docs/ARCHITECTURE.md for the module-by-module pipeline walkthrough,
including where a real DCGM/libtpu backend plugs in.
"""
from __future__ import annotations

import csv
import json
from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from repro.core.peaks import DEFAULT_CHIP, ChipSpec
from repro.telemetry.counters import (CounterBackend, Event, StepProfile,
                                      check_scrape_interval)
from repro.telemetry.scrape import DeviceGrid, scrape


class TelemetrySource:
    """Interface: scrapes() -> DeviceGrid (aligned counter series), plus a
    stateful cursor for incremental collection.

    `scrapes()` is the one-shot batch view.  `poll(duration_s)` scrapes
    only the next `duration_s` seconds, advancing `cursor_s`; returned
    grids carry absolute `t0_s`, so incremental rounds land in the same
    rollup buckets batch ingestion would use.  `exhausted` reports when a
    finite source (fixed-duration simulation, recorded trace) has nothing
    left; `set_interval` retimes future polls where the cadence is ours to
    choose (`retimable` is False for replay — the recorded cadence is
    fixed).
    """

    #: whether set_interval may change this source's scrape cadence
    retimable = True

    def scrapes(self) -> DeviceGrid:
        raise NotImplementedError

    @property
    def cursor_s(self) -> float:
        """Absolute time up to which this source has been polled."""
        return getattr(self, "_cursor_s", 0.0)

    @property
    def exhausted(self) -> bool:
        """True when poll() can no longer produce a sample."""
        return False

    @property
    def bounded(self) -> bool:
        """True if poll() is guaranteed to exhaust eventually.

        Guards `Collector.run(n_rounds=None)` against spinning forever:
        the conservative default treats a source as unbounded unless it
        carries a finite `duration_s` (a custom live poller without one
        is exactly the case that never exhausts); replay overrides this —
        a recorded trace always runs out.
        """
        return bool(np.isfinite(getattr(self, "duration_s", np.inf)))

    def poll(self, duration_s: float) -> DeviceGrid:
        """Scrape the next duration_s seconds; advance the cursor."""
        raise NotImplementedError

    def set_interval(self, interval_s: float) -> None:
        """Retime future polls (§IV-C-checked) — the adaptive-controller
        actuator."""
        if not self.retimable:
            raise ValueError(f"{type(self).__name__} cadence is fixed and "
                             "cannot be retimed")
        if interval_s <= 0:
            raise ValueError(f"interval_s={interval_s} must be positive")
        # honor the source's own §IV-C policy: a strict=False source that
        # already runs degraded may be retimed within that same policy
        check_scrape_interval(interval_s,
                              strict=getattr(self, "strict", True))
        self.interval_s = float(interval_s)

    def _take(self, duration_s: float) -> int:
        """Whole samples in the next duration_s at the current interval."""
        iv = self.interval_s
        if duration_s < iv:
            raise ValueError(f"poll duration {duration_s}s is shorter than "
                             f"the scrape interval {iv}s — no sample fits")
        return int(duration_s / iv)

    def _chunk_budget(self, duration_s: float) -> int:
        """`_take` clamped to what remains before `duration_s` runs out —
        the shared poll() front half; 0 means 'emit an empty grid'."""
        n = self._take(duration_s)
        total = getattr(self, "duration_s", np.inf)
        if np.isfinite(total):
            n = min(n, int((total - self.cursor_s) / self.interval_s + 1e-9))
        return n

    def _empty_grid(self) -> DeviceGrid:
        return DeviceGrid(self.interval_s, np.empty((0, 0)),
                          np.empty((0, 0)), t0_s=self.cursor_s)


@dataclass
class SimulatorSource(TelemetrySource):
    """Generative source: one batched vectorized-engine pass."""

    profile: StepProfile
    duration_s: float
    interval_s: float
    chip: ChipSpec = DEFAULT_CHIP
    events: Sequence[Event] = ()
    stragglers: Optional[np.ndarray] = None
    n_devices: int = 1
    seed: int = 0
    strict: bool = True          # same §IV-C policy as BackendSource

    def scrapes(self) -> DeviceGrid:
        # sources are interchangeable, so they enforce §IV-C identically:
        # strict=True rejects average-of-averages intervals up front
        # (strict=False leaves the engine's own degraded-mode warning)
        if self.strict:
            check_scrape_interval(self.interval_s)
        # the engine sits a layer above telemetry; import at call time so
        # replay/live deployments never load the simulator
        from repro.fleet.engine import simulate_devices
        return simulate_devices(
            self.profile, duration_s=self.duration_s,
            interval_s=self.interval_s, chip=self.chip, events=self.events,
            stragglers=self.stragglers, n_devices=self.n_devices,
            seed=self.seed)

    @property
    def exhausted(self) -> bool:
        return self.cursor_s + self.interval_s > self.duration_s + 1e-9

    def poll(self, duration_s: float) -> DeviceGrid:
        """Simulate only the next chunk of the run (cursor-relative).

        Events keep their ABSOLUTE timeline (shifted into chunk-local
        time), and the chunk seed derives deterministically from
        (seed, poll count), so an incremental collection is reproducible
        run-to-run.  Chunks draw independent jitter/clock streams, so a
        chunked collection is statistically — not bit-for-bit — the
        continuation of `scrapes()`.
        """
        if self.strict:
            check_scrape_interval(self.interval_s)
        c = self.cursor_s
        n = self._chunk_budget(duration_s)
        if n <= 0:
            return self._empty_grid()
        rounds = getattr(self, "_polls", 0)
        from repro.fleet.engine import simulate_devices
        shifted = [Event(e.start_s - c, e.end_s - c, slowdown=e.slowdown,
                         mxu_scale=e.mxu_scale, kind=e.kind)
                   for e in self.events]
        chunk_seed = int(np.random.default_rng(
            [self.seed, rounds]).integers(0, 2 ** 31))
        grid = simulate_devices(
            self.profile, duration_s=n * self.interval_s,
            interval_s=self.interval_s, chip=self.chip, events=shifted,
            stragglers=self.stragglers, n_devices=self.n_devices,
            seed=chunk_seed)
        grid.t0_s = c
        self._cursor_s = c + n * self.interval_s
        self._polls = rounds + 1
        return grid


@dataclass
class BackendSource(TelemetrySource):
    """Adapter over scalar `CounterBackend`s: one poll loop per device.

    This is the shape a live poller takes — hand it N DCGM/libtpu-backed
    backends and the rest of the pipeline runs unchanged.
    """

    backends: Sequence[CounterBackend]
    duration_s: float            # may be float('inf') for poll-only use
    interval_s: float
    strict: bool = True

    def scrapes(self) -> DeviceGrid:
        return DeviceGrid.from_series(
            [scrape(be, self.duration_s, self.interval_s, strict=self.strict)
             for be in self.backends])

    @property
    def exhausted(self) -> bool:
        return self.cursor_s + self.interval_s > self.duration_s + 1e-9

    def poll(self, duration_s: float) -> DeviceGrid:
        """Poll every backend for the next chunk; backends keep their own
        clock state (a live DCGM/libtpu poller is naturally resumable)."""
        check_scrape_interval(self.interval_s, strict=self.strict)
        c = self.cursor_s
        n = self._chunk_budget(duration_s)
        if n <= 0:
            return self._empty_grid()
        tpa = np.empty((len(self.backends), n))
        clk = np.empty((len(self.backends), n))
        for d, be in enumerate(self.backends):
            for i in range(n):
                tpa[d, i], clk[d, i] = be.poll(self.interval_s)
        self._cursor_s = c + n * self.interval_s
        return DeviceGrid(self.interval_s, tpa, clk, t0_s=c)


@dataclass
class TraceReplaySource(TelemetrySource):
    """Replays recorded (t_s, device, tpa, clock_mhz) scrapes from disk.

    Not retimable: the cadence is whatever the recorder used.  `poll`
    slices the cached trace by the recorded timestamps, so a collector
    replays an archive round-for-round exactly as it would watch a live
    fleet (polls before the trace's first sample return empty grids).
    """

    path: str
    fmt: str = "auto"            # 'csv' | 'jsonl' | 'auto' (by suffix)
    interval_s: Optional[float] = None   # required for 1-sample traces

    retimable = False

    bounded = True               # a recorded trace always runs out

    def scrapes(self) -> DeviceGrid:
        return read_trace(self.path, fmt=self.fmt,
                          interval_s=self.interval_s)

    def _cached(self) -> DeviceGrid:
        grid = getattr(self, "_grid", None)
        if grid is None:
            grid = self._grid = self.scrapes()
        return grid

    @property
    def exhausted(self) -> bool:
        grid = self._cached()
        times = grid.times_s
        return not len(times) or self.cursor_s >= times[-1] - 1e-9

    def poll(self, duration_s: float) -> DeviceGrid:
        grid = self._cached()
        if duration_s <= 0:
            raise ValueError(f"poll duration {duration_s}s must be positive")
        c = self.cursor_s
        times = grid.times_s
        i0, i1 = np.searchsorted(times, [c + 1e-9, c + duration_s + 1e-9])
        sub = DeviceGrid(grid.interval_s, grid.tpa[:, i0:i1],
                         grid.clock_mhz[:, i0:i1],
                         t0_s=float(times[i0]) - grid.interval_s
                         if i1 > i0 else c)
        self._cursor_s = c + duration_s   # wall clock advances regardless
        return sub


_FIELDS = ("t_s", "device", "tpa", "clock_mhz")


def _resolve_fmt(path: str, fmt: str) -> str:
    if fmt != "auto":
        if fmt not in ("csv", "jsonl"):
            raise ValueError(f"unknown trace format {fmt!r}")
        return fmt
    low = str(path).lower()
    if low.endswith(".csv"):
        return "csv"
    if low.endswith((".jsonl", ".ndjson", ".json")):
        return "jsonl"
    raise ValueError(f"cannot infer trace format from {path!r}; "
                     "pass fmt='csv' or 'jsonl'")


def write_trace(grid: DeviceGrid, path: str, *, fmt: str = "auto") -> None:
    """Record a DeviceGrid as a replayable scrape trace (CSV or JSONL)."""
    fmt = _resolve_fmt(path, fmt)
    # bulk-convert once (tolist yields Python floats, repr-exact) instead
    # of a per-cell numpy-scalar conversion — fleet grids are millions of
    # samples and the trace writer must not dwarf the ~ms simulation
    tpa = grid.tpa.astype(float).tolist()
    clk = grid.clock_mhz.astype(float).tolist()
    with open(path, "w", newline="") as fh:
        if fmt == "csv":
            times = [repr(t) for t in grid.times_s.tolist()]
            w = csv.writer(fh)
            w.writerow(_FIELDS)
            w.writerows((t, d, repr(a), repr(c))
                        for d in range(grid.n_devices)
                        for t, a, c in zip(times, tpa[d], clk[d]))
        else:
            times_f = grid.times_s.tolist()
            fh.writelines(
                json.dumps({"t_s": t, "device": d, "tpa": a,
                            "clock_mhz": c}) + "\n"
                for d in range(grid.n_devices)
                for t, a, c in zip(times_f, tpa[d], clk[d]))


def read_trace(path: str, *, fmt: str = "auto",
               interval_s: Optional[float] = None) -> DeviceGrid:
    """Load a scrape trace back into an aligned DeviceGrid.

    Requires a rectangular trace: every device sampled the same number of
    times (what any fixed-interval scraper produces; per-device timestamp
    jitter is fine — samples align by poll rank).  The scrape interval is
    inferred from the poll-instant spacing unless given explicitly; a
    single-poll trace cannot be inferred and needs interval_s.
    """
    fmt = _resolve_fmt(path, fmt)
    recs = []
    with open(path, newline="") as fh:
        if fmt == "csv":
            rd = csv.reader(fh)
            header = next(rd, None)
            if header is not None:
                col = {name: k for k, name in enumerate(header)}
                missing = [f for f in _FIELDS if f not in col]
                if missing:
                    raise ValueError(f"trace {path!r} header is missing "
                                     f"column(s) {missing}")
                it, id_, ia, ic = (col[f] for f in _FIELDS)
                recs = [(float(row[it]), int(row[id_]),
                         float(row[ia]), float(row[ic])) for row in rd]
        else:
            for line in fh:
                if not line.strip():
                    continue
                r = json.loads(line)
                recs.append((float(r["t_s"]), int(r["device"]),
                             float(r["tpa"]), float(r["clock_mhz"])))
    if not recs:
        return DeviceGrid(0.0, np.empty((0, 0)), np.empty((0, 0)))
    # align samples by per-device time RANK, not exact timestamp equality:
    # real pollers jitter a few ms between devices, but a fixed-interval
    # scraper still yields one sample per device per poll round
    by_dev: dict = {}
    for t, d, a, c in recs:
        by_dev.setdefault(d, []).append((t, a, c))
    devices = sorted(by_dev)
    counts = {len(by_dev[d]) for d in devices}
    if len(counts) != 1:
        raise ValueError(f"ragged trace {path!r}: devices have differing "
                         f"sample counts {sorted(counts)}")
    for d in devices:
        by_dev[d].sort(key=lambda r: r[0])
    times = np.array([r[0] for r in by_dev[devices[0]]])
    if interval_s is not None:
        interval = float(interval_s)
    elif len(times) > 1:
        interval = float(np.median(np.diff(times)))
    else:
        raise ValueError(
            f"trace {path!r} has a single poll instant; the scrape "
            "interval cannot be inferred — pass interval_s explicitly")
    tpa = np.array([[r[1] for r in by_dev[d]] for d in devices])
    clk = np.array([[r[2] for r in by_dev[d]] for d in devices])
    # preserve the recorded clock: a mid-run trace (first poll at t≫0)
    # must land in the rollup buckets of the times it was captured at
    return DeviceGrid(interval, tpa, clk, t0_s=float(times[0]) - interval)
