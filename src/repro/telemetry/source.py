"""Telemetry sources: one interface over simulated, replayed, and live
counter streams (the source-agnostic pipeline behind the paper's §V-B
fleet dashboards).

Every source answers `scrapes() -> DeviceGrid`; everything downstream —
`StreamingRollup`, `detect_regressions`, `divergence.analyze` — consumes
that grid and never learns whether the samples came from the vectorized
engine (`SimulatorSource`), a per-poll `CounterBackend` loop
(`BackendSource`, the adapter point for live DCGM/libtpu pollers), or a
recorded trace (`TraceReplaySource`).  Deploying against real hardware
telemetry means adding one more source, not touching the pipeline.

Trace formats:

- CSV (with header) / JSONL — one record per line, the interchange path:

      t_s,device,tpa,clock_mhz
      30.0,0,0.412,1328.5

  `write_trace`/`read_trace` round-trip a `DeviceGrid` exactly (floats
  are serialized at full repr precision).

- Columnar chunked archive (`telemetry/tracestore.py`) — a directory of
  compressed npz column chunks plus a JSON manifest; ~6× smaller than
  CSV and the only format `TraceReplaySource` can STREAM: `poll()` over
  an archive decodes O(chunk) samples, never the whole trace, so a
  multi-week archive replays in constant memory.  `write_trace` /
  `read_trace` dispatch to it for `.ctr` paths (and `fmt="columnar"`);
  `tools/trace_convert.py` converts between all three.

Sources are also RESUMABLE: `poll(duration_s)` scrapes the next chunk of
wall-time from a per-source cursor (grids come back with the right
absolute `t0_s`), which is what the long-lived `fleet.collector.Collector`
drives round after round — and `set_interval` retimes a live source under
the shared §IV-C `check_scrape_interval` policy (the adaptive controller's
actuator).  `scrapes()` remains the stateless one-shot batch view.

See docs/ARCHITECTURE.md for the module-by-module pipeline walkthrough,
including where a real DCGM/libtpu backend plugs in.
"""
from __future__ import annotations

import csv
import json
import os
from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from repro.core.peaks import DEFAULT_CHIP, ChipSpec
from repro.telemetry import tracestore
from repro.telemetry.counters import (CounterBackend, Event, StepProfile,
                                      check_scrape_interval)
from repro.telemetry.scrape import DeviceGrid, scrape


class TelemetrySource:
    """Interface: scrapes() -> DeviceGrid (aligned counter series), plus a
    stateful cursor for incremental collection.

    `scrapes()` is the one-shot batch view.  `poll(duration_s)` scrapes
    only the next `duration_s` seconds, advancing `cursor_s`; returned
    grids carry absolute `t0_s`, so incremental rounds land in the same
    rollup buckets batch ingestion would use.  `exhausted` reports when a
    finite source (fixed-duration simulation, recorded trace) has nothing
    left; `set_interval` retimes future polls where the cadence is ours to
    choose (`retimable` is False for replay — the recorded cadence is
    fixed).
    """

    #: whether set_interval may change this source's scrape cadence
    retimable = True

    def scrapes(self) -> DeviceGrid:
        raise NotImplementedError

    @property
    def cursor_s(self) -> float:
        """Absolute time up to which this source has been polled."""
        return getattr(self, "_cursor_s", 0.0)

    @property
    def exhausted(self) -> bool:
        """True when poll() can no longer produce a sample."""
        return False

    @property
    def bounded(self) -> bool:
        """True if poll() is guaranteed to exhaust eventually.

        Guards `Collector.run(n_rounds=None)` against spinning forever:
        the conservative default treats a source as unbounded unless it
        carries a finite `duration_s` (a custom live poller without one
        is exactly the case that never exhausts); replay overrides this —
        a recorded trace always runs out.
        """
        return bool(np.isfinite(getattr(self, "duration_s", np.inf)))

    def poll(self, duration_s: float) -> DeviceGrid:
        """Scrape the next duration_s seconds; advance the cursor."""
        raise NotImplementedError

    def set_interval(self, interval_s: float) -> None:
        """Retime future polls (§IV-C-checked) — the adaptive-controller
        actuator."""
        if not self.retimable:
            raise ValueError(f"{type(self).__name__} cadence is fixed and "
                             "cannot be retimed")
        if interval_s <= 0:
            raise ValueError(f"interval_s={interval_s} must be positive")
        # honor the source's own §IV-C policy: a strict=False source that
        # already runs degraded may be retimed within that same policy
        check_scrape_interval(interval_s,
                              strict=getattr(self, "strict", True))
        self.interval_s = float(interval_s)

    def _take(self, duration_s: float) -> int:
        """Whole samples in the next duration_s at the current interval."""
        iv = self.interval_s
        if duration_s < iv:
            raise ValueError(f"poll duration {duration_s}s is shorter than "
                             f"the scrape interval {iv}s — no sample fits")
        return int(duration_s / iv)

    def _chunk_budget(self, duration_s: float) -> int:
        """`_take` clamped to what remains before `duration_s` runs out —
        the shared poll() front half; 0 means 'emit an empty grid'."""
        n = self._take(duration_s)
        total = getattr(self, "duration_s", np.inf)
        if np.isfinite(total):
            n = min(n, int((total - self.cursor_s) / self.interval_s + 1e-9))
        return n

    def _empty_grid(self) -> DeviceGrid:
        return DeviceGrid(self.interval_s, np.empty((0, 0)),
                          np.empty((0, 0)), t0_s=self.cursor_s)


@dataclass
class SimulatorSource(TelemetrySource):
    """Generative source: one batched vectorized-engine pass."""

    profile: StepProfile
    duration_s: float
    interval_s: float
    chip: ChipSpec = DEFAULT_CHIP
    events: Sequence[Event] = ()
    stragglers: Optional[np.ndarray] = None
    n_devices: int = 1
    seed: int = 0
    strict: bool = True          # same §IV-C policy as BackendSource

    def scrapes(self) -> DeviceGrid:
        # sources are interchangeable, so they enforce §IV-C identically:
        # strict=True rejects average-of-averages intervals up front
        # (strict=False leaves the engine's own degraded-mode warning)
        if self.strict:
            check_scrape_interval(self.interval_s)
        # the engine sits a layer above telemetry; import at call time so
        # replay/live deployments never load the simulator
        from repro.fleet.engine import simulate_devices
        return simulate_devices(
            self.profile, duration_s=self.duration_s,
            interval_s=self.interval_s, chip=self.chip, events=self.events,
            stragglers=self.stragglers, n_devices=self.n_devices,
            seed=self.seed)

    @property
    def exhausted(self) -> bool:
        return self.cursor_s + self.interval_s > self.duration_s + 1e-9

    def poll(self, duration_s: float) -> DeviceGrid:
        """Simulate only the next chunk of the run (cursor-relative).

        Events keep their ABSOLUTE timeline (shifted into chunk-local
        time), and the chunk seed derives deterministically from
        (seed, poll count), so an incremental collection is reproducible
        run-to-run.  Chunks draw independent jitter/clock streams, so a
        chunked collection is statistically — not bit-for-bit — the
        continuation of `scrapes()`.
        """
        if self.strict:
            check_scrape_interval(self.interval_s)
        c = self.cursor_s
        n = self._chunk_budget(duration_s)
        if n <= 0:
            return self._empty_grid()
        rounds = getattr(self, "_polls", 0)
        from repro.fleet.engine import simulate_devices
        shifted = [Event(e.start_s - c, e.end_s - c, slowdown=e.slowdown,
                         mxu_scale=e.mxu_scale, kind=e.kind)
                   for e in self.events]
        chunk_seed = int(np.random.default_rng(
            [self.seed, rounds]).integers(0, 2 ** 31))
        grid = simulate_devices(
            self.profile, duration_s=n * self.interval_s,
            interval_s=self.interval_s, chip=self.chip, events=shifted,
            stragglers=self.stragglers, n_devices=self.n_devices,
            seed=chunk_seed)
        grid.t0_s = c
        self._cursor_s = c + n * self.interval_s
        self._polls = rounds + 1
        return grid


@dataclass
class BackendSource(TelemetrySource):
    """Adapter over scalar `CounterBackend`s: one poll loop per device.

    This is the shape a live poller takes — hand it N DCGM/libtpu-backed
    backends and the rest of the pipeline runs unchanged.
    """

    backends: Sequence[CounterBackend]
    duration_s: float            # may be float('inf') for poll-only use
    interval_s: float
    strict: bool = True

    def scrapes(self) -> DeviceGrid:
        return DeviceGrid.from_series(
            [scrape(be, self.duration_s, self.interval_s, strict=self.strict)
             for be in self.backends])

    @property
    def exhausted(self) -> bool:
        return self.cursor_s + self.interval_s > self.duration_s + 1e-9

    def poll(self, duration_s: float) -> DeviceGrid:
        """Poll every backend for the next chunk; backends keep their own
        clock state (a live DCGM/libtpu poller is naturally resumable)."""
        check_scrape_interval(self.interval_s, strict=self.strict)
        c = self.cursor_s
        n = self._chunk_budget(duration_s)
        if n <= 0:
            return self._empty_grid()
        tpa = np.empty((len(self.backends), n))
        clk = np.empty((len(self.backends), n))
        for d, be in enumerate(self.backends):
            for i in range(n):
                tpa[d, i], clk[d, i] = be.poll(self.interval_s)
        self._cursor_s = c + n * self.interval_s
        return DeviceGrid(self.interval_s, tpa, clk, t0_s=c)


@dataclass
class GridSource(TelemetrySource):
    """Replays an in-memory `DeviceGrid` with poll/cursor semantics.

    The scenario scorecard's source: a fault-injected grid simulated up
    front (`simulate_fleet` + `apply_faults`) replays through a live
    `Collector` round-for-round, deterministically — same contract as
    `TraceReplaySource` without a file.  Not retimable: the grid's
    cadence is fixed.
    """

    grid: DeviceGrid

    retimable = False
    bounded = True               # a finite grid always runs out

    @property
    def interval_s(self) -> float:
        return self.grid.interval_s

    @property
    def exhausted(self) -> bool:
        times = self.grid.times_s
        return not times.size or self.cursor_s >= float(times[-1]) - 1e-9

    def seek(self, t_s: float) -> None:
        """Reposition the replay cursor (collector snapshot restore)."""
        if t_s < 0:
            raise ValueError(f"seek target {t_s}s must be >= 0")
        self._cursor_s = float(t_s)

    def poll(self, duration_s: float) -> DeviceGrid:
        if duration_s <= 0:
            raise ValueError(f"poll duration {duration_s}s must be positive")
        c = self.cursor_s
        times = self.grid.times_s
        i0, i1 = np.searchsorted(times, [c + 1e-9, c + duration_s + 1e-9])
        sub = DeviceGrid(self.grid.interval_s, self.grid.tpa[:, i0:i1],
                         self.grid.clock_mhz[:, i0:i1],
                         t0_s=float(times[i0]) - self.grid.interval_s
                         if i1 > i0 else c)
        self._cursor_s = c + duration_s
        return sub


@dataclass
class TraceReplaySource(TelemetrySource):
    """Replays recorded (t_s, device, tpa, clock_mhz) scrapes from disk.

    Not retimable: the cadence is whatever the recorder used.  `poll`
    slices the trace by the recorded timestamps, so a collector replays
    an archive round-for-round exactly as it would watch a live fleet
    (polls before the trace's first sample return empty grids).

    Row formats (CSV/JSONL) are materialized once and sliced; a COLUMNAR
    archive (`tracestore.TraceReader`) streams instead — each poll
    decodes only the chunks spanning it, so peak memory is O(chunk) even
    for a multi-week trace, and `exhausted` comes from the manifest
    without touching a single chunk.  `seek(t_s)` repositions the cursor
    (the restart path: resume replay where a snapshotted collector left
    off).
    """

    path: str
    fmt: str = "auto"        # 'csv' | 'jsonl' | 'columnar' | 'auto'
    interval_s: Optional[float] = None   # required for 1-sample row traces

    retimable = False

    bounded = True               # a recorded trace always runs out

    def scrapes(self) -> DeviceGrid:
        return read_trace(self.path, fmt=self.fmt,
                          interval_s=self.interval_s)

    @property
    def reader(self) -> Optional[tracestore.TraceReader]:
        """The archive reader behind a columnar source (None for row
        formats) — exposes the streaming instrumentation."""
        rd = getattr(self, "_reader", None)
        if rd is None and not getattr(self, "_row_fmt", False):
            if _resolve_fmt(self.path, self.fmt) == "columnar":
                rd = self._reader = tracestore.TraceReader(self.path)
            else:
                self._row_fmt = True     # don't re-stat on every poll
        return rd

    def _cached(self) -> DeviceGrid:
        grid = getattr(self, "_grid", None)
        if grid is None:
            grid = self._grid = self.scrapes()
        return grid

    def _span(self) -> tuple:
        """(t0_s, interval_s, n_samples) without materializing an
        archive; row traces still load once here."""
        rd = self.reader
        if rd is not None:
            return rd.t0_s, rd.interval_s, rd.n_samples
        grid = self._cached()
        return grid.t0_s, grid.interval_s, grid.tpa.shape[1]

    @property
    def exhausted(self) -> bool:
        t0, iv, n = self._span()
        return not n or self.cursor_s >= tracestore.sample_time(
            t0, iv, n - 1) - 1e-9

    def seek(self, t_s: float) -> None:
        """Reposition the replay cursor (absolute trace time) — the next
        poll() resumes there, e.g. after a collector snapshot restore."""
        if t_s < 0:
            raise ValueError(f"seek target {t_s}s must be >= 0")
        self._cursor_s = float(t_s)

    def poll(self, duration_s: float) -> DeviceGrid:
        if duration_s <= 0:
            raise ValueError(f"poll duration {duration_s}s must be positive")
        c = self.cursor_s
        rd = self.reader
        if rd is not None:
            # stream: manifest index -> sample range -> spanning chunks
            i0 = rd.searchsorted(c + 1e-9)
            i1 = rd.searchsorted(c + duration_s + 1e-9)
            tpa, clk = rd.read_samples(i0, i1)
            t0 = tracestore.sample_time(rd.t0_s, rd.interval_s, i0) \
                - rd.interval_s if i1 > i0 else c
            sub = DeviceGrid(rd.interval_s, tpa, clk, t0_s=t0)
        else:
            grid = self._cached()
            times = grid.times_s
            i0, i1 = np.searchsorted(times,
                                     [c + 1e-9, c + duration_s + 1e-9])
            sub = DeviceGrid(grid.interval_s, grid.tpa[:, i0:i1],
                             grid.clock_mhz[:, i0:i1],
                             t0_s=float(times[i0]) - grid.interval_s
                             if i1 > i0 else c)
        self._cursor_s = c + duration_s   # wall clock advances regardless
        return sub


_FIELDS = ("t_s", "device", "tpa", "clock_mhz")


def _resolve_fmt(path: str, fmt: str) -> str:
    if fmt != "auto":
        if fmt not in ("csv", "jsonl", "columnar"):
            raise ValueError(f"unknown trace format {fmt!r}")
        return fmt
    path = str(path)
    if os.path.isdir(path):
        if tracestore.is_archive(path):
            return "columnar"
        raise ValueError(
            f"{path!r} is a directory but not a columnar trace archive "
            f"(no {tracestore.MANIFEST_NAME}); pass fmt explicitly if "
            "this is intentional")
    low = path.lower()
    if low.endswith((tracestore.COLUMNAR_SUFFIX, tracestore.V2_SUFFIX)):
        return "columnar"
    if os.path.isfile(path) and tracestore.is_v2_archive(path):
        return "columnar"        # suffix-less ctr-v2 file: sniff the magic
    if low.endswith(".csv"):
        return "csv"
    if low.endswith((".jsonl", ".ndjson", ".json")):
        return "jsonl"
    raise ValueError(f"cannot infer trace format from {path!r}; "
                     "pass fmt='csv', 'jsonl', or 'columnar'")


def write_trace(grid: DeviceGrid, path: str, *, fmt: str = "auto",
                chunk_samples: int = tracestore.DEFAULT_CHUNK_SAMPLES,
                codec: Optional[str] = None) -> None:
    """Record a DeviceGrid as a replayable scrape trace (CSV, JSONL, or
    a chunked columnar archive for `.ctr`/`.ctr2`/fmt='columnar' paths —
    `chunk_samples` applies only there, and `codec` only to `.ctr2`)."""
    fmt = _resolve_fmt(path, fmt)
    if fmt == "columnar":
        tracestore.write_archive(grid, path, chunk_samples=chunk_samples,
                                 codec=codec)
        return
    if codec is not None:
        raise ValueError(f"codec={codec!r} applies only to columnar "
                         "ctr-v2 archives, not row formats")
    # bulk-convert once (tolist yields Python floats, repr-exact) instead
    # of a per-cell numpy-scalar conversion — fleet grids are millions of
    # samples and the trace writer must not dwarf the ~ms simulation
    tpa = grid.tpa.astype(float).tolist()
    clk = grid.clock_mhz.astype(float).tolist()
    with open(path, "w", newline="") as fh:
        if fmt == "csv":
            times = [repr(t) for t in grid.times_s.tolist()]
            w = csv.writer(fh)
            w.writerow(_FIELDS)
            w.writerows((t, d, repr(a), repr(c))
                        for d in range(grid.n_devices)
                        for t, a, c in zip(times, tpa[d], clk[d]))
        else:
            times_f = grid.times_s.tolist()
            fh.writelines(
                json.dumps({"t_s": t, "device": d, "tpa": a,
                            "clock_mhz": c}) + "\n"
                for d in range(grid.n_devices)
                for t, a, c in zip(times_f, tpa[d], clk[d]))


def _is_float(cell: str) -> bool:
    try:
        float(cell)
        return True
    except (TypeError, ValueError):
        return False


def _parse_csv(path: str, fh) -> list:
    rd = csv.reader(fh)
    header = next(rd, None)
    if header is None:
        return []
    col = {name.strip(): k for k, name in enumerate(header)}
    missing = [f for f in _FIELDS if f not in col]
    if missing:
        # distinguish "wrong columns" from "no header at all": a first
        # row of four numbers is DATA — silently skipping it used to
        # drop one poll per device and shift the inferred t0
        if len(header) >= len(_FIELDS) \
                and all(_is_float(c) for c in header[:len(_FIELDS)]):
            raise ValueError(
                f"trace {path!r} has no header row (first line parses as "
                f"data: {','.join(header)!r}); expected columns "
                f"{','.join(_FIELDS)}")
        raise ValueError(f"trace {path!r} header is missing "
                         f"column(s) {missing}")
    idx = [col[f] for f in _FIELDS]
    need = max(idx) + 1
    recs = []
    for ln, row in enumerate(rd, start=2):
        if not row:
            continue
        if len(row) < need:
            raise ValueError(
                f"trace {path!r} line {ln}: truncated row has "
                f"{len(row)} field(s), header promises >= {need}")
        try:
            recs.append((float(row[idx[0]]), int(row[idx[1]]),
                         float(row[idx[2]]), float(row[idx[3]])))
        except ValueError as e:
            raise ValueError(f"trace {path!r} line {ln}: malformed "
                             f"value in {row!r} ({e})") from None
    return recs


def _parse_jsonl(path: str, fh) -> list:
    recs = []
    for ln, line in enumerate(fh, start=1):
        if not line.strip():
            continue
        try:
            r = json.loads(line)
        except json.JSONDecodeError as e:
            raise ValueError(f"trace {path!r} line {ln}: invalid JSON "
                             f"({e})") from None
        if not isinstance(r, dict):
            raise ValueError(
                f"trace {path!r} line {ln}: record is {type(r).__name__}, "
                "expected one JSON object per line (a whole-file JSON "
                "array is not a JSONL trace)")
        missing = [f for f in _FIELDS if f not in r]
        if missing:
            raise ValueError(f"trace {path!r} line {ln}: record is "
                             f"missing key(s) {missing}")
        try:
            recs.append((float(r["t_s"]), int(r["device"]),
                         float(r["tpa"]), float(r["clock_mhz"])))
        except (TypeError, ValueError) as e:
            raise ValueError(f"trace {path!r} line {ln}: malformed "
                             f"value ({e})") from None
    return recs


def read_trace(path: str, *, fmt: str = "auto",
               interval_s: Optional[float] = None) -> DeviceGrid:
    """Load a scrape trace back into an aligned DeviceGrid.

    Row formats require a rectangular trace: every device sampled the
    same number of times (what any fixed-interval scraper produces;
    per-device timestamp jitter is fine — samples align by poll rank).
    The scrape interval is inferred from the poll-instant spacing unless
    given explicitly; a single-poll trace cannot be inferred and needs
    interval_s.  Malformed input (missing/implied header, truncated rows,
    non-object JSONL records, unparseable values) is REJECTED with the
    offending line, never silently mis-parsed.  Columnar archives are
    validated by `tracestore.TraceReader` and carry their own interval.
    """
    fmt = _resolve_fmt(path, fmt)
    if fmt == "columnar":
        return tracestore.read_archive(path, interval_s=interval_s)
    with open(path, newline="") as fh:
        recs = _parse_csv(path, fh) if fmt == "csv" \
            else _parse_jsonl(path, fh)
    if not recs:
        return DeviceGrid(0.0, np.empty((0, 0)), np.empty((0, 0)))
    # align samples by per-device time RANK, not exact timestamp equality:
    # real pollers jitter a few ms between devices, but a fixed-interval
    # scraper still yields one sample per device per poll round
    by_dev: dict = {}
    for t, d, a, c in recs:
        by_dev.setdefault(d, []).append((t, a, c))
    devices = sorted(by_dev)
    counts = {len(by_dev[d]) for d in devices}
    if len(counts) != 1:
        raise ValueError(f"ragged trace {path!r}: devices have differing "
                         f"sample counts {sorted(counts)}")
    for d in devices:
        by_dev[d].sort(key=lambda r: r[0])
    times = np.array([r[0] for r in by_dev[devices[0]]])
    if interval_s is not None:
        interval = float(interval_s)
    elif len(times) > 1:
        interval = float(np.median(np.diff(times)))
    else:
        raise ValueError(
            f"trace {path!r} has a single poll instant; the scrape "
            "interval cannot be inferred — pass interval_s explicitly")
    tpa = np.array([[r[1] for r in by_dev[d]] for d in devices])
    clk = np.array([[r[2] for r in by_dev[d]] for d in devices])
    # preserve the recorded clock: a mid-run trace (first poll at t≫0)
    # must land in the rollup buckets of the times it was captured at
    return DeviceGrid(interval, tpa, clk, t0_s=float(times[0]) - interval)
