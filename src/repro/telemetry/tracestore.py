"""Chunked columnar trace archive: the fleet-scale storage layer under
`TraceReplaySource` (ROADMAP "columnar trace format + chunked/streaming
replay" — months of archived counter scrapes are where fleet tooling
lives or dies).

An archive is a DIRECTORY:

    trace.ctr/
      manifest.json          # format, interval_s, n_devices, chunk index
      chunk-000000.npz       # {"tpa": (D, S), "clock_mhz": (D, S)}
      chunk-000001.npz
      ...

Counters are stored as columns in their NATIVE dtype (the engine emits
float32: ~8 B/sample vs ~50 B/sample for repr'd CSV text), compressed
per chunk (`np.savez_compressed`), with timestamps IMPLICIT: the grid is
uniform, so the manifest's `t0_s`/`interval_s` plus each chunk's sample
offset reconstruct every poll instant exactly — a multi-day archive
spends zero bytes on time or device columns.

`TraceWriter` is append-only (buffer → full chunk → flush; the manifest
is rewritten after every flush, so a killed recorder leaves a valid
archive minus its buffered tail).  `TraceReader` random-accesses sample
ranges by loading only the chunks that span them — peak decoded state is
O(chunk), never O(trace) — and instruments itself
(`peak_resident_samples`, `chunks_decoded`) so tests can ASSERT the
memory bound instead of trusting it.

CSV/JSONL (`source.write_trace`/`read_trace`) remain the interchange
path; `tools/trace_convert.py` converts between the three formats.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass
from typing import Iterator, Optional, Sequence

import numpy as np

from repro.telemetry.scrape import DeviceGrid

MANIFEST_NAME = "manifest.json"
FORMAT_TAG = "ctr-v1"
#: directory suffix `_resolve_fmt` sniffs as columnar even before the
#: archive exists (so a writer target can be format-inferred too)
COLUMNAR_SUFFIX = ".ctr"
DEFAULT_CHUNK_SAMPLES = 4096


def is_archive(path: str) -> bool:
    """True if path is (or names) a columnar trace archive directory."""
    return os.path.isfile(os.path.join(path, MANIFEST_NAME))


def sample_time(t0_s: float, interval_s: float, k: int) -> float:
    """Poll instant of 0-based sample k (window END, matching
    `DeviceGrid.times_s` bit-for-bit: t0 + (k+1)·interval in float64)."""
    return t0_s + (k + 1) * interval_s


def uniform_searchsorted(t0_s: float, interval_s: float, n: int,
                         x: float) -> int:
    """`np.searchsorted(times, x)` over the IMPLICIT uniform times array
    — O(1), no materialization.  Returns the smallest k in [0, n] with
    sample_time(k) >= x (side='left' semantics)."""
    if n <= 0:
        return 0
    # start provably at-or-below the answer, then walk up (float division
    # error is < 1 ulp, so this loop runs at most a few steps)
    k = min(max(int((x - t0_s) / interval_s) - 2, 0), n)
    while k < n and sample_time(t0_s, interval_s, k) < x:
        k += 1
    return k


@dataclass
class ChunkInfo:
    """One chunk's manifest entry."""

    file: str
    t0_s: float                  # absolute start of the chunk's first window
    n_samples: int


def _check(cond: bool, path: str, msg: str) -> None:
    if not cond:
        raise ValueError(f"corrupt trace archive {path!r}: {msg}")


class TraceWriter:
    """Append-only columnar trace recorder.

    Samples accumulate in a buffer; full `chunk_samples`-column chunks
    flush as compressed npz files and the manifest is rewritten, so the
    on-disk archive is valid after every flush.  Use as a context
    manager (`close()` flushes the final partial chunk).

    `append(tpa, clock_mhz)` takes aligned `(n_devices,)` or
    `(n_devices, s)` counter columns; `append_grid(grid)` additionally
    enforces that the grid CONTINUES the archive (same interval and
    device count, `t0_s` equal to the archive's current end) — the shape
    a `poll()`-driven recorder produces round after round.

    `append=True` reopens an existing archive and continues it (the
    restart path for a long-lived recorder).
    """

    def __init__(self, path: str, interval_s: float, n_devices: int, *,
                 chunk_samples: int = DEFAULT_CHUNK_SAMPLES,
                 t0_s: float = 0.0, append: bool = False):
        if interval_s <= 0:
            raise ValueError(f"interval_s={interval_s} must be positive")
        if n_devices < 1:
            raise ValueError(f"n_devices={n_devices} must be >= 1")
        if chunk_samples < 1:
            raise ValueError(f"chunk_samples={chunk_samples} must be >= 1")
        self.path = str(path)
        self.interval_s = float(interval_s)
        self.n_devices = int(n_devices)
        self.chunk_samples = int(chunk_samples)
        self.t0_s = float(t0_s)
        self.chunks: list = []
        self.n_samples = 0           # flushed samples (excludes the buffer)
        self._buf: list = []
        self._buffered = 0
        self._dtype = None
        self._closed = False
        if append and is_archive(self.path):
            rd = TraceReader(self.path)
            if rd.interval_s != self.interval_s \
                    or rd.n_devices != self.n_devices:
                raise ValueError(
                    f"cannot append to {path!r}: archive has "
                    f"interval_s={rd.interval_s}/n_devices={rd.n_devices}, "
                    f"writer asked for {self.interval_s}/{self.n_devices}")
            self.t0_s = rd.t0_s
            self.chunks = list(rd.chunks)
            self.n_samples = rd.n_samples
            self._dtype = rd.dtype
        elif is_archive(self.path):
            raise ValueError(f"{path!r} is already a trace archive; pass "
                             "append=True to continue it")
        os.makedirs(self.path, exist_ok=True)

    # -- recording ------------------------------------------------------
    @property
    def total_samples(self) -> int:
        """Flushed + buffered samples (what close() will have written)."""
        return self.n_samples + self._buffered

    @property
    def end_s(self) -> float:
        """Absolute time the archive will cover through after close()."""
        return sample_time(self.t0_s, self.interval_s,
                           self.total_samples - 1) \
            if self.total_samples else self.t0_s

    def append(self, tpa: np.ndarray, clock_mhz: np.ndarray) -> None:
        """Append aligned counter columns: (n_devices,) or (n_devices, s)."""
        if self._closed:
            raise ValueError("TraceWriter is closed")
        tpa = np.atleast_2d(np.asarray(tpa).T).T   # (D,) -> (D, 1)
        clk = np.atleast_2d(np.asarray(clock_mhz).T).T
        if tpa.shape != clk.shape or tpa.shape[0] != self.n_devices:
            raise ValueError(
                f"misaligned append: tpa {tpa.shape} / clock {clk.shape} "
                f"vs n_devices={self.n_devices}")
        if tpa.shape[1] == 0:
            return
        want = np.result_type(tpa, clk)
        if self._dtype is None:
            self._dtype = want
        elif not np.can_cast(want, self._dtype, casting="safe"):
            # never quantize silently: a float64 append into a float32
            # archive would corrupt the exact-roundtrip contract
            raise ValueError(
                f"cannot append {want} samples to a "
                f"{np.dtype(self._dtype).name} archive without losing "
                "precision; write a new archive at the wider dtype")
        self._buf.append((tpa.astype(self._dtype, copy=False),
                          clk.astype(self._dtype, copy=False)))
        self._buffered += tpa.shape[1]
        if self._buffered >= self.chunk_samples:
            self._drain()

    def append_grid(self, grid: DeviceGrid) -> None:
        """Append a DeviceGrid that CONTINUES the archive exactly."""
        if grid.tpa.shape[1] == 0:
            return
        tol = 1e-6 * self.interval_s
        if abs(grid.interval_s - self.interval_s) > tol:
            raise ValueError(
                f"grid interval {grid.interval_s}s does not match archive "
                f"interval {self.interval_s}s")
        if grid.n_devices != self.n_devices:
            raise ValueError(f"grid has {grid.n_devices} devices, archive "
                             f"has {self.n_devices}")
        if abs(grid.t0_s - self.end_s) > tol:
            raise ValueError(
                f"grid t0_s={grid.t0_s}s does not continue the archive "
                f"(current end {self.end_s}s) — archives must be gapless "
                "so timestamps stay implicit")
        self.append(grid.tpa, grid.clock_mhz)

    # -- persistence ----------------------------------------------------
    def _drain(self, final: bool = False) -> None:
        """Flush every full chunk in the buffer (all of it when final).

        One concatenation per drain, then sliced chunk writes — each
        sample is copied O(1) times however large the one-shot append
        was, instead of re-concatenating the shrinking tail per chunk.
        The manifest is rewritten once per drain; chunk files written
        before a crash mid-drain are simply not indexed yet and get
        overwritten on the next run.
        """
        if not self._buffered:
            return
        tpa = self._buf[0][0] if len(self._buf) == 1 \
            else np.concatenate([t for t, _ in self._buf], axis=1)
        clk = self._buf[0][1] if len(self._buf) == 1 \
            else np.concatenate([c for _, c in self._buf], axis=1)
        pos = 0
        while self._buffered - pos >= self.chunk_samples \
                or (final and self._buffered > pos):
            take = min(self.chunk_samples, self._buffered - pos)
            name = f"chunk-{len(self.chunks):06d}.npz"
            np.savez_compressed(os.path.join(self.path, name),
                                tpa=tpa[:, pos:pos + take],
                                clock_mhz=clk[:, pos:pos + take])
            self.chunks.append(ChunkInfo(
                name, sample_time(self.t0_s, self.interval_s,
                                  self.n_samples - 1), take))
            self.n_samples += take
            pos += take
        self._buf = [(tpa[:, pos:], clk[:, pos:])] if pos < self._buffered \
            else []
        self._buffered -= pos
        self._write_manifest()

    def _write_manifest(self) -> None:
        manifest = {
            "format": FORMAT_TAG,
            "interval_s": self.interval_s,
            "n_devices": self.n_devices,
            "t0_s": self.t0_s,
            "dtype": np.dtype(self._dtype or np.float64).name,
            "chunk_samples": self.chunk_samples,
            "n_samples": self.n_samples,
            "chunks": [{"file": c.file, "t0_s": c.t0_s,
                        "n_samples": c.n_samples} for c in self.chunks],
        }
        tmp = os.path.join(self.path, MANIFEST_NAME + ".tmp")
        with open(tmp, "w") as fh:
            json.dump(manifest, fh, indent=1)
            fh.write("\n")
        os.replace(tmp, os.path.join(self.path, MANIFEST_NAME))

    def flush(self, *, partial: bool = False) -> None:
        """Flush buffered samples and rewrite the manifest, keeping the
        writer open.

        With `partial=False` only full chunks are written (what `append`
        already does opportunistically) — this just forces the manifest
        rewrite.  `partial=True` also writes the buffered tail as a short
        chunk: the crash-safety point for a recording daemon.  After
        `flush(partial=True)` a kill loses NOTHING already appended — the
        on-disk archive replays through `TraceReplaySource` up to the
        flush, and later appends simply continue in new chunks (chunk
        sizes may vary; readers only require contiguity).
        """
        if self._closed:
            raise ValueError("TraceWriter is closed")
        if self._buffered:
            self._drain(final=partial)
        else:
            self._write_manifest()

    def close(self) -> None:
        if self._closed:
            return
        if self._buffered:
            self._drain(final=True)
        else:
            self._write_manifest()      # valid even with zero samples
        self._closed = True

    def __enter__(self) -> "TraceWriter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class TraceReader:
    """Random-access view over a columnar archive; loads O(chunk) at a
    time.

    The manifest is validated up front (format tag, chunk contiguity,
    file presence, sample-count consistency) so a truncated or
    hand-edited archive fails loudly at open, not as silently wrong
    replay.  `read_samples(i0, i1)` decodes only the chunks spanning the
    range (with a one-chunk cache for boundary-crossing polls);
    `iter_chunks()` streams chunk-sized `DeviceGrid`s;
    `peak_resident_samples` / `chunks_decoded` expose the memory story
    to tests.
    """

    def __init__(self, path: str):
        self.path = str(path)
        mf = os.path.join(self.path, MANIFEST_NAME)
        if not os.path.isfile(mf):
            raise ValueError(f"{self.path!r} is not a columnar trace "
                             f"archive (no {MANIFEST_NAME})")
        try:
            with open(mf) as fh:
                m = json.load(fh)
        except json.JSONDecodeError as e:
            raise ValueError(f"corrupt trace archive {self.path!r}: "
                             f"unreadable manifest ({e})") from e
        _check(isinstance(m, dict) and m.get("format") == FORMAT_TAG,
               self.path, f"manifest format is {m.get('format')!r}, "
               f"expected {FORMAT_TAG!r}")
        for key in ("interval_s", "n_devices", "t0_s", "n_samples",
                    "chunks"):
            _check(key in m, self.path, f"manifest missing key {key!r}")
        self.interval_s = float(m["interval_s"])
        _check(self.interval_s > 0, self.path,
               f"interval_s={self.interval_s} must be positive")
        self.n_devices = int(m["n_devices"])
        self.t0_s = float(m["t0_s"])
        self.dtype = np.dtype(m.get("dtype", "float64"))
        self.chunks = []
        cum = 0
        tol = 1e-6 * self.interval_s
        for k, c in enumerate(m["chunks"]):
            _check(isinstance(c, dict)
                   and all(f in c for f in ("file", "t0_s", "n_samples")),
                   self.path, f"malformed chunk entry #{k}: {c!r}")
            info = ChunkInfo(str(c["file"]), float(c["t0_s"]),
                             int(c["n_samples"]))
            _check(info.n_samples > 0, self.path,
                   f"chunk {info.file!r} has n_samples={info.n_samples}")
            _check(os.path.isfile(os.path.join(self.path, info.file)),
                   self.path, f"chunk file {info.file!r} is missing")
            want_t0 = sample_time(self.t0_s, self.interval_s, cum - 1)
            _check(abs(info.t0_s - want_t0) <= tol, self.path,
                   f"chunk {info.file!r} starts at {info.t0_s}s, expected "
                   f"{want_t0}s (chunks must be contiguous)")
            self.chunks.append(info)
            cum += info.n_samples
        self.n_samples = int(m["n_samples"])
        _check(self.n_samples == cum, self.path,
               f"manifest n_samples={self.n_samples} but chunks hold {cum}")
        #: chunk k covers global samples [_starts[k], _starts[k+1])
        self._starts = np.concatenate(
            [[0], np.cumsum([c.n_samples for c in self.chunks])]).astype(int)
        self._cache: Optional[tuple] = None    # (chunk_idx, tpa, clk)
        self.chunks_decoded = 0
        self.peak_resident_samples = 0

    # -- geometry -------------------------------------------------------
    @property
    def duration_s(self) -> float:
        return self.n_samples * self.interval_s

    @property
    def end_s(self) -> float:
        """Poll instant of the last sample (== t0_s for an empty archive)."""
        return sample_time(self.t0_s, self.interval_s, self.n_samples - 1) \
            if self.n_samples else self.t0_s

    def chunk_start(self, k: int) -> int:
        """Global index of chunk k's first sample."""
        return int(self._starts[k])

    def searchsorted(self, x: float) -> int:
        """Global index of the first sample whose poll instant is >= x."""
        return uniform_searchsorted(self.t0_s, self.interval_s,
                                    self.n_samples, x)

    # -- decoding -------------------------------------------------------
    def _decode(self, k: int) -> tuple:
        if self._cache is not None and self._cache[0] == k:
            return self._cache[1], self._cache[2]
        info = self.chunks[k]
        with np.load(os.path.join(self.path, info.file)) as z:
            _check("tpa" in z and "clock_mhz" in z, self.path,
                   f"chunk {info.file!r} is missing counter arrays")
            tpa, clk = z["tpa"], z["clock_mhz"]
        want = (self.n_devices, info.n_samples)
        _check(tpa.shape == want and clk.shape == want, self.path,
               f"chunk {info.file!r} arrays are {tpa.shape}/{clk.shape}, "
               f"manifest says {want}")
        self.chunks_decoded += 1
        self._cache = (k, tpa, clk)
        return tpa, clk

    def read_samples(self, i0: int, i1: int) -> tuple:
        """(tpa, clock_mhz) for global samples [i0, i1) — decodes only
        the spanning chunks."""
        i0 = max(int(i0), 0)
        i1 = min(int(i1), self.n_samples)
        if i1 <= i0:
            shape = (self.n_devices, 0)
            return (np.empty(shape, self.dtype), np.empty(shape, self.dtype))
        k0 = int(np.searchsorted(self._starts, i0, side="right")) - 1
        k1 = int(np.searchsorted(self._starts, i1, side="left"))
        parts_t, parts_c, resident = [], [], 0
        for k in range(k0, k1):
            tpa, clk = self._decode(k)
            lo = i0 - self.chunk_start(k)
            hi = i1 - self.chunk_start(k)
            parts_t.append(tpa[:, max(lo, 0):hi])
            parts_c.append(clk[:, max(lo, 0):hi])
            resident += self.chunks[k].n_samples * self.n_devices
        self.peak_resident_samples = max(self.peak_resident_samples,
                                         resident)
        if len(parts_t) == 1:
            return parts_t[0], parts_c[0]
        return (np.concatenate(parts_t, axis=1),
                np.concatenate(parts_c, axis=1))

    # -- streaming / batch views ---------------------------------------
    def iter_chunks(self, start_s: Optional[float] = None,
                    stop_s: Optional[float] = None) -> Iterator[DeviceGrid]:
        """Stream the archive chunk by chunk as `DeviceGrid`s (whole
        chunks whose time span overlaps [start_s, stop_s]; use
        `read_samples` for exact sub-chunk slicing)."""
        for k, info in enumerate(self.chunks):
            lo = sample_time(self.t0_s, self.interval_s,
                             self.chunk_start(k))
            hi = sample_time(self.t0_s, self.interval_s,
                             self.chunk_start(k) + info.n_samples - 1)
            if (stop_s is not None and lo > stop_s) \
                    or (start_s is not None and hi < start_s):
                continue
            tpa, clk = self._decode(k)
            self.peak_resident_samples = max(
                self.peak_resident_samples,
                info.n_samples * self.n_devices)
            yield DeviceGrid(self.interval_s, tpa, clk, t0_s=info.t0_s)

    def read_all(self) -> DeviceGrid:
        """Materialize the whole archive (the batch `scrapes()` view —
        O(trace) memory by definition; prefer iter_chunks/read_samples
        for long archives)."""
        if not self.n_samples:
            return DeviceGrid(self.interval_s,
                              np.empty((self.n_devices, 0), self.dtype),
                              np.empty((self.n_devices, 0), self.dtype),
                              t0_s=self.t0_s)
        tpa, clk = self.read_samples(0, self.n_samples)
        return DeviceGrid(self.interval_s, tpa, clk, t0_s=self.t0_s)

    def summary(self) -> str:
        span_h = self.duration_s / 3600.0
        return (f"ctr_archive devices={self.n_devices} "
                f"samples={self.n_samples} interval={self.interval_s:g}s "
                f"span={span_h:.2f}h chunks={len(self.chunks)} "
                f"dtype={self.dtype.name}")


def write_archive(grid: DeviceGrid, path: str, *,
                  chunk_samples: int = DEFAULT_CHUNK_SAMPLES) -> None:
    """One-shot archive write of a DeviceGrid (the `write_trace`
    dispatch target for columnar paths)."""
    if grid.n_devices < 1 or grid.interval_s <= 0:
        # e.g. the empty grid read_trace returns for a header-only CSV:
        # row formats round-trip it, but an archive needs real geometry
        raise ValueError(
            f"cannot write a columnar archive from an empty/degenerate "
            f"trace ({grid.n_devices} devices, interval "
            f"{grid.interval_s}s); keep empty traces in CSV/JSONL")
    with TraceWriter(path, grid.interval_s, grid.n_devices,
                     chunk_samples=chunk_samples, t0_s=grid.t0_s) as w:
        w.append(grid.tpa, grid.clock_mhz)


def read_archive(path: str,
                 interval_s: Optional[float] = None) -> DeviceGrid:
    """One-shot archive read (the `read_trace` dispatch target)."""
    rd = TraceReader(path)
    if interval_s is not None \
            and abs(interval_s - rd.interval_s) > 1e-6 * rd.interval_s:
        raise ValueError(
            f"explicit interval_s={interval_s} contradicts the archive "
            f"manifest ({rd.interval_s}s) — columnar archives carry their "
            "own interval")
    return rd.read_all()


def archive_nbytes(path: str) -> int:
    """Total on-disk size of an archive directory (manifest + chunks)."""
    return sum(os.path.getsize(os.path.join(path, f))
               for f in os.listdir(path))
