"""Columnar trace archives: the fleet-scale storage layer under
`TraceReplaySource` (months of archived counter scrapes are where fleet
tooling lives or dies).

Two on-disk formats behind one reader/writer API:

**ctr-v1** — a DIRECTORY of compressed npz column chunks plus a JSON
manifest (the original format, kept fully read/write compatible):

    trace.ctr/
      manifest.json          # format, interval_s, n_devices, chunk index
      chunk-000000.npz       # {"tpa": (D, S), "clock_mhz": (D, S)}
      ...

**ctr-v2** — ONE appendable file with a footer-indexed chunk table, for
many-small-files-hostile filesystems (one fd per archive however long
the recording runs) and pluggable column codecs (`telemetry.codecs`:
raw / zlib / delta+bitshuffle — the always-on-recording point):

    [8B magic][u32 len][header json]          # immutable geometry
    [chunk blocks ...]                        # codec-encoded columns
    [footer json][u32 crc][u64 len][8B magic] # cumulative chunk table
    [chunk blocks ...]                        # appended after a reopen
    [footer json][u32 crc][u64 len][8B magic] # newer footer wins

Every flush appends new chunk blocks THEN a new footer indexing all
chunks so far — earlier footers are never overwritten, so a recorder
killed mid-append leaves garbage only AFTER the last durable footer and
the archive reopens valid at that footer (readers scan backward for the
newest intact one; a reopening writer truncates the unindexed tail).
Dead footers cost tens of bytes per flush — the v2 analogue of v1's
manifest rewrite.  Reads are mmap-backed: the raw codec decodes as a
zero-copy view over the mapping.

Counters are stored in their NATIVE dtype (the engine emits float32),
with timestamps IMPLICIT: the grid is uniform, so `t0_s`/`interval_s`
plus each chunk's sample offset reconstruct every poll instant exactly —
a multi-day archive spends zero bytes on time or device columns.

Writers are append-only (buffer → full chunk → flush; the index is
rewritten after every flush, so a killed recorder leaves a valid archive
minus its buffered tail).  Readers random-access sample ranges by
decoding only the chunks that span them — peak decoded state is
O(chunk), never O(trace) — and instrument themselves
(`peak_resident_samples`, `chunks_decoded`) so tests can ASSERT the
memory bound instead of trusting it.

`TraceReader(path)` dispatches transparently: a directory opens as v1, a
`CTR2`-magic file as v2.  `write_archive` picks the version from the
path suffix (`.ctr` → v1, `.ctr2` → v2) unless told explicitly.
CSV/JSONL (`source.write_trace`/`read_trace`) remain the interchange
path; `tools/trace_convert.py` converts between all formats.
"""
from __future__ import annotations

import json
import mmap
import os
import zlib
from dataclasses import dataclass
from typing import Iterator, Optional, Union

import numpy as np

from repro.telemetry import codecs as _codecs
from repro.telemetry.scrape import DeviceGrid

MANIFEST_NAME = "manifest.json"
FORMAT_TAG = "ctr-v1"
FORMAT_TAG_V2 = "ctr-v2"
#: directory suffix `_resolve_fmt` sniffs as columnar even before the
#: archive exists (so a writer target can be format-inferred too)
COLUMNAR_SUFFIX = ".ctr"
#: single-file container suffix (ctr-v2)
V2_SUFFIX = ".ctr2"
DEFAULT_CHUNK_SAMPLES = 4096

#: ctr-v2 wire constants — the header magic doubles as the sniff byte
#: sequence for suffix-less files; the footer magic terminates every
#: chunk-table record so readers can walk back to the newest intact one
V2_MAGIC = b"CTR2\x00\x01\r\n"
V2_FOOTER_MAGIC = b"CTR2FTR\n"
_V2_TAIL = 4 + 8 + len(V2_FOOTER_MAGIC)      # crc32 + len + magic


def is_archive(path: str) -> bool:
    """True if path names a columnar trace archive (v1 directory or
    ctr-v2 file)."""
    return os.path.isfile(os.path.join(path, MANIFEST_NAME)) \
        or is_v2_archive(path)


def is_v2_archive(path: str) -> bool:
    """True if path is a ctr-v2 single-file archive (magic sniff)."""
    if not os.path.isfile(path):
        return False
    with open(path, "rb") as fh:
        return fh.read(len(V2_MAGIC)) == V2_MAGIC


def sample_time(t0_s: float, interval_s: float, k: int) -> float:
    """Poll instant of 0-based sample k (window END, matching
    `DeviceGrid.times_s` bit-for-bit: t0 + (k+1)·interval in float64)."""
    return t0_s + (k + 1) * interval_s


def uniform_searchsorted(t0_s: float, interval_s: float, n: int,
                         x: float) -> int:
    """`np.searchsorted(times, x)` over the IMPLICIT uniform times array
    — O(1), no materialization.  Returns the smallest k in [0, n] with
    sample_time(k) >= x (side='left' semantics)."""
    if n <= 0:
        return 0
    # start provably at-or-below the answer, then walk up (float division
    # error is < 1 ulp, so this loop runs at most a few steps)
    k = min(max(int((x - t0_s) / interval_s) - 2, 0), n)
    while k < n and sample_time(t0_s, interval_s, k) < x:
        k += 1
    return k


@dataclass
class ChunkInfo:
    """One v1 chunk's manifest entry."""

    file: str
    t0_s: float                  # absolute start of the chunk's first window
    n_samples: int


@dataclass
class ChunkInfoV2:
    """One ctr-v2 chunk's footer entry: where its two codec-encoded
    column blocks live in the file."""

    offset: int                  # absolute file offset of the tpa block
    t0_s: float
    n_samples: int
    codec: str                   # codec tag both blocks were written with
    tpa_nbytes: int
    clk_nbytes: int


def _check(cond: bool, path: str, msg: str) -> None:
    if not cond:
        raise ValueError(f"corrupt trace archive {path!r}: {msg}")


# ---------------------------------------------------------------------------
# Writers
# ---------------------------------------------------------------------------
class _ChunkedWriterBase:
    """Shared buffered-append machinery for both archive versions.

    Samples accumulate in a buffer; full `chunk_samples`-column chunks
    flush through `_emit_chunk` and the index is rewritten by `_commit`,
    so the on-disk archive is valid after every flush.  Use as a context
    manager (`close()` flushes the final partial chunk).

    `append(tpa, clock_mhz)` takes aligned `(n_devices,)` or
    `(n_devices, s)` counter columns; `append_grid(grid)` additionally
    enforces that the grid CONTINUES the archive (same interval and
    device count, `t0_s` equal to the archive's current end) — the shape
    a `poll()`-driven recorder produces round after round.

    `append=True` reopens an existing archive and continues it (the
    restart path for a long-lived recorder).
    """

    def __init__(self, path: str, interval_s: float, n_devices: int, *,
                 chunk_samples: int = DEFAULT_CHUNK_SAMPLES,
                 t0_s: float = 0.0):
        if interval_s <= 0:
            raise ValueError(f"interval_s={interval_s} must be positive")
        if n_devices < 1:
            raise ValueError(f"n_devices={n_devices} must be >= 1")
        if chunk_samples < 1:
            raise ValueError(f"chunk_samples={chunk_samples} must be >= 1")
        self.path = str(path)
        self.interval_s = float(interval_s)
        self.n_devices = int(n_devices)
        self.chunk_samples = int(chunk_samples)
        self.t0_s = float(t0_s)
        self.chunks: list = []
        self.n_samples = 0           # flushed samples (excludes the buffer)
        self._buf: list = []
        self._buffered = 0
        self._dtype = None
        self._closed = False

    # -- version hooks --------------------------------------------------
    def _emit_chunk(self, tpa: np.ndarray, clk: np.ndarray) -> None:
        """Write one full chunk and record its index entry."""
        raise NotImplementedError

    def _commit(self) -> None:
        """Make everything emitted so far durable (manifest/footer)."""
        raise NotImplementedError

    def _on_close(self) -> None:
        """Release version-specific resources (file handles)."""

    # -- recording ------------------------------------------------------
    @property
    def total_samples(self) -> int:
        """Flushed + buffered samples (what close() will have written)."""
        return self.n_samples + self._buffered

    @property
    def end_s(self) -> float:
        """Absolute time the archive will cover through after close()."""
        return sample_time(self.t0_s, self.interval_s,
                           self.total_samples - 1) \
            if self.total_samples else self.t0_s

    def append(self, tpa: np.ndarray, clock_mhz: np.ndarray) -> None:
        """Append aligned counter columns: (n_devices,) or (n_devices, s)."""
        if self._closed:
            raise ValueError(f"{type(self).__name__} is closed")
        tpa = np.atleast_2d(np.asarray(tpa).T).T   # (D,) -> (D, 1)
        clk = np.atleast_2d(np.asarray(clock_mhz).T).T
        if tpa.shape != clk.shape or tpa.shape[0] != self.n_devices:
            raise ValueError(
                f"misaligned append: tpa {tpa.shape} / clock {clk.shape} "
                f"vs n_devices={self.n_devices}")
        if tpa.shape[1] == 0:
            return
        want = np.result_type(tpa, clk)
        if self._dtype is None:
            self._dtype = want
        elif not np.can_cast(want, self._dtype, casting="safe"):
            # never quantize silently: a float64 append into a float32
            # archive would corrupt the exact-roundtrip contract
            raise ValueError(
                f"cannot append {want} samples to a "
                f"{np.dtype(self._dtype).name} archive without losing "
                "precision; write a new archive at the wider dtype")
        self._buf.append((tpa.astype(self._dtype, copy=False),
                          clk.astype(self._dtype, copy=False)))
        self._buffered += tpa.shape[1]
        if self._buffered >= self.chunk_samples:
            self._drain()

    def append_grid(self, grid: DeviceGrid) -> None:
        """Append a DeviceGrid that CONTINUES the archive exactly."""
        if grid.tpa.shape[1] == 0:
            return
        tol = 1e-6 * self.interval_s
        if abs(grid.interval_s - self.interval_s) > tol:
            raise ValueError(
                f"grid interval {grid.interval_s}s does not match archive "
                f"interval {self.interval_s}s")
        if grid.n_devices != self.n_devices:
            raise ValueError(f"grid has {grid.n_devices} devices, archive "
                             f"has {self.n_devices}")
        if abs(grid.t0_s - self.end_s) > tol:
            raise ValueError(
                f"grid t0_s={grid.t0_s}s does not continue the archive "
                f"(current end {self.end_s}s) — archives must be gapless "
                "so timestamps stay implicit")
        self.append(grid.tpa, grid.clock_mhz)

    def _drain(self, final: bool = False) -> None:
        """Flush every full chunk in the buffer (all of it when final).

        One concatenation per drain, then sliced chunk writes — each
        sample is copied O(1) times however large the one-shot append
        was, instead of re-concatenating the shrinking tail per chunk.
        The index is committed once per drain; chunk data written
        before a crash mid-drain is simply not indexed yet (v1
        overwrites it on the next run, v2 truncates it on reopen).
        """
        if not self._buffered:
            return
        tpa = self._buf[0][0] if len(self._buf) == 1 \
            else np.concatenate([t for t, _ in self._buf], axis=1)
        clk = self._buf[0][1] if len(self._buf) == 1 \
            else np.concatenate([c for _, c in self._buf], axis=1)
        pos = 0
        while self._buffered - pos >= self.chunk_samples \
                or (final and self._buffered > pos):
            take = min(self.chunk_samples, self._buffered - pos)
            self._emit_chunk(tpa[:, pos:pos + take],
                             clk[:, pos:pos + take])
            self.n_samples += take
            pos += take
        self._buf = [(tpa[:, pos:], clk[:, pos:])] if pos < self._buffered \
            else []
        self._buffered -= pos
        self._commit()

    def flush(self, *, partial: bool = False) -> None:
        """Flush buffered samples and rewrite the index, keeping the
        writer open.

        With `partial=False` only full chunks are written (what `append`
        already does opportunistically) — this just forces the index
        rewrite.  `partial=True` also writes the buffered tail as a short
        chunk: the crash-safety point for a recording daemon.  After
        `flush(partial=True)` a kill loses NOTHING already appended — the
        on-disk archive replays through `TraceReplaySource` up to the
        flush, and later appends simply continue in new chunks (chunk
        sizes may vary; readers only require contiguity).
        """
        if self._closed:
            raise ValueError(f"{type(self).__name__} is closed")
        if self._buffered:
            self._drain(final=partial)
        else:
            self._commit()

    def close(self) -> None:
        if self._closed:
            return
        if self._buffered:
            self._drain(final=True)
        else:
            self._commit()              # valid even with zero samples
        self._closed = True
        self._on_close()

    def __enter__(self):
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class TraceWriter(_ChunkedWriterBase):
    """Append-only ctr-v1 recorder: npz chunk files + JSON manifest,
    rewritten after every flush so a killed recorder leaves a valid
    archive minus its buffered tail."""

    def __init__(self, path: str, interval_s: float, n_devices: int, *,
                 chunk_samples: int = DEFAULT_CHUNK_SAMPLES,
                 t0_s: float = 0.0, append: bool = False):
        super().__init__(path, interval_s, n_devices,
                         chunk_samples=chunk_samples, t0_s=t0_s)
        if append and is_archive(self.path):
            rd = TraceReader(self.path)
            if rd.interval_s != self.interval_s \
                    or rd.n_devices != self.n_devices:
                raise ValueError(
                    f"cannot append to {path!r}: archive has "
                    f"interval_s={rd.interval_s}/n_devices={rd.n_devices}, "
                    f"writer asked for {self.interval_s}/{self.n_devices}")
            self.t0_s = rd.t0_s
            self.chunks = list(rd.chunks)
            self.n_samples = rd.n_samples
            self._dtype = rd.dtype
        elif is_archive(self.path):
            raise ValueError(f"{path!r} is already a trace archive; pass "
                             "append=True to continue it")
        os.makedirs(self.path, exist_ok=True)

    def _emit_chunk(self, tpa: np.ndarray, clk: np.ndarray) -> None:
        name = f"chunk-{len(self.chunks):06d}.npz"
        np.savez_compressed(os.path.join(self.path, name),
                            tpa=tpa, clock_mhz=clk)
        self.chunks.append(ChunkInfo(
            name, sample_time(self.t0_s, self.interval_s,
                              self.n_samples - 1), tpa.shape[1]))

    def _commit(self) -> None:
        manifest = {
            "format": FORMAT_TAG,
            "interval_s": self.interval_s,
            "n_devices": self.n_devices,
            "t0_s": self.t0_s,
            "dtype": np.dtype(self._dtype or np.float64).name,
            "chunk_samples": self.chunk_samples,
            "n_samples": self.n_samples,
            "chunks": [{"file": c.file, "t0_s": c.t0_s,
                        "n_samples": c.n_samples} for c in self.chunks],
        }
        tmp = os.path.join(self.path, MANIFEST_NAME + ".tmp")
        with open(tmp, "w") as fh:
            json.dump(manifest, fh, indent=1)
            fh.write("\n")
        os.replace(tmp, os.path.join(self.path, MANIFEST_NAME))


class TraceWriterV2(_ChunkedWriterBase):
    """Append-only ctr-v2 recorder: one file, codec-encoded chunk
    blocks, a cumulative footer per flush.

    `codec` picks the column codec for NEW chunks (`"auto"` → the best
    always-available one, delta+bitshuffle; see `telemetry.codecs`).
    Appending to an existing archive may use a different codec — every
    chunk carries its own tag.

    Durability contract: earlier footers are never overwritten, so the
    newest INTACT footer always indexes a valid prefix.  A crash between
    chunk emission and the footer write leaves unindexed bytes that the
    next `append=True` open truncates away.
    """

    def __init__(self, path: str, interval_s: float, n_devices: int, *,
                 chunk_samples: int = DEFAULT_CHUNK_SAMPLES,
                 t0_s: float = 0.0, append: bool = False,
                 codec: Optional[str] = "auto"):
        super().__init__(path, interval_s, n_devices,
                         chunk_samples=chunk_samples, t0_s=t0_s)
        self.codec = _codecs.get_codec(codec)
        if append and is_v2_archive(self.path):
            rd = TraceReaderV2(self.path)
            try:
                if rd.interval_s != self.interval_s \
                        or rd.n_devices != self.n_devices:
                    raise ValueError(
                        f"cannot append to {path!r}: archive has "
                        f"interval_s={rd.interval_s}/"
                        f"n_devices={rd.n_devices}, writer asked for "
                        f"{self.interval_s}/{self.n_devices}")
                self.t0_s = rd.t0_s
                self.chunks = list(rd.chunks)
                self.n_samples = rd.n_samples
                if rd.n_samples:
                    self._dtype = rd.dtype
                data_end = rd.footer_end
            finally:
                rd.close()
            self._fh = open(self.path, "r+b")
            # drop any unindexed tail a crashed writer left behind
            self._fh.truncate(data_end)
            self._fh.seek(data_end)
        elif is_v2_archive(self.path):
            raise ValueError(f"{path!r} is already a trace archive; pass "
                             "append=True to continue it")
        else:
            self._fh = open(self.path, "wb")
            header = json.dumps({
                "format": FORMAT_TAG_V2,
                "interval_s": self.interval_s,
                "n_devices": self.n_devices,
                "t0_s": self.t0_s,
                "chunk_samples": self.chunk_samples,
            }, sort_keys=True, separators=(",", ":")).encode()
            self._fh.write(V2_MAGIC)
            self._fh.write(np.uint32(len(header)).tobytes())
            self._fh.write(header)

    def _emit_chunk(self, tpa: np.ndarray, clk: np.ndarray) -> None:
        tb = self.codec.encode(tpa)
        cb = self.codec.encode(clk)
        off = self._fh.tell()
        self._fh.write(tb)
        self._fh.write(cb)
        self.chunks.append(ChunkInfoV2(
            off, sample_time(self.t0_s, self.interval_s,
                             self.n_samples - 1),
            tpa.shape[1], self.codec.name, len(tb), len(cb)))

    def _commit(self) -> None:
        footer = json.dumps({
            "format": FORMAT_TAG_V2,
            "interval_s": self.interval_s,
            "n_devices": self.n_devices,
            "t0_s": self.t0_s,
            "dtype": np.dtype(self._dtype or np.float64).name,
            "chunk_samples": self.chunk_samples,
            "n_samples": self.n_samples,
            "chunks": [{"off": c.offset, "t0_s": c.t0_s,
                        "n": c.n_samples, "codec": c.codec,
                        "tb": c.tpa_nbytes, "cb": c.clk_nbytes}
                       for c in self.chunks],
        }, sort_keys=True, separators=(",", ":")).encode()
        self._fh.write(footer)
        self._fh.write(np.uint32(zlib.crc32(footer)).tobytes())
        self._fh.write(np.uint64(len(footer)).tobytes())
        self._fh.write(V2_FOOTER_MAGIC)
        self._fh.flush()

    def _on_close(self) -> None:
        self._fh.close()


# ---------------------------------------------------------------------------
# Readers
# ---------------------------------------------------------------------------
class _ArchiveReaderBase:
    """Shared random-access machinery over a validated chunk index.

    Subclasses populate geometry (`interval_s`, `n_devices`, `t0_s`,
    `dtype`, `chunks`, `n_samples`) and implement `_load_chunk(k)`; this
    base provides range reads decoding only the spanning chunks (with a
    one-chunk cache for boundary-crossing polls), chunk streaming, and
    the residency instrumentation tests assert against.
    """

    path: str
    interval_s: float
    n_devices: int
    t0_s: float
    dtype: np.dtype
    chunks: list
    n_samples: int

    def _init_index(self) -> None:
        """Call after `chunks` is final: builds the sample-offset index
        and zeroes the instrumentation counters."""
        #: chunk k covers global samples [_starts[k], _starts[k+1])
        self._starts = np.concatenate(
            [[0], np.cumsum([c.n_samples for c in self.chunks])]).astype(int)
        self._cache: Optional[tuple] = None    # (chunk_idx, tpa, clk)
        self.chunks_decoded = 0
        self.peak_resident_samples = 0

    def _load_chunk(self, k: int) -> tuple:
        """Decode chunk k to (tpa, clk) arrays of the manifest shape."""
        raise NotImplementedError

    # -- geometry -------------------------------------------------------
    @property
    def duration_s(self) -> float:
        return self.n_samples * self.interval_s

    @property
    def end_s(self) -> float:
        """Poll instant of the last sample (== t0_s for an empty archive)."""
        return sample_time(self.t0_s, self.interval_s, self.n_samples - 1) \
            if self.n_samples else self.t0_s

    def chunk_start(self, k: int) -> int:
        """Global index of chunk k's first sample."""
        return int(self._starts[k])

    def searchsorted(self, x: float) -> int:
        """Global index of the first sample whose poll instant is >= x."""
        return uniform_searchsorted(self.t0_s, self.interval_s,
                                    self.n_samples, x)

    # -- decoding -------------------------------------------------------
    def _decode(self, k: int) -> tuple:
        if self._cache is not None and self._cache[0] == k:
            return self._cache[1], self._cache[2]
        tpa, clk = self._load_chunk(k)
        want = (self.n_devices, self.chunks[k].n_samples)
        _check(tpa.shape == want and clk.shape == want, self.path,
               f"chunk #{k} arrays are {tpa.shape}/{clk.shape}, "
               f"{self._index_name} says {want}")
        self.chunks_decoded += 1
        self._cache = (k, tpa, clk)
        return tpa, clk

    def read_samples(self, i0: int, i1: int) -> tuple:
        """(tpa, clock_mhz) for global samples [i0, i1) — decodes only
        the spanning chunks."""
        i0 = max(int(i0), 0)
        i1 = min(int(i1), self.n_samples)
        if i1 <= i0:
            shape = (self.n_devices, 0)
            return (np.empty(shape, self.dtype), np.empty(shape, self.dtype))
        k0 = int(np.searchsorted(self._starts, i0, side="right")) - 1
        k1 = int(np.searchsorted(self._starts, i1, side="left"))
        parts_t, parts_c, resident = [], [], 0
        for k in range(k0, k1):
            tpa, clk = self._decode(k)
            lo = i0 - self.chunk_start(k)
            hi = i1 - self.chunk_start(k)
            parts_t.append(tpa[:, max(lo, 0):hi])
            parts_c.append(clk[:, max(lo, 0):hi])
            resident += self.chunks[k].n_samples * self.n_devices
        self.peak_resident_samples = max(self.peak_resident_samples,
                                         resident)
        if len(parts_t) == 1:
            return parts_t[0], parts_c[0]
        return (np.concatenate(parts_t, axis=1),
                np.concatenate(parts_c, axis=1))

    # -- streaming / batch views ---------------------------------------
    def iter_chunks(self, start_s: Optional[float] = None,
                    stop_s: Optional[float] = None) -> Iterator[DeviceGrid]:
        """Stream the archive chunk by chunk as `DeviceGrid`s (whole
        chunks whose time span overlaps [start_s, stop_s]; use
        `read_samples` for exact sub-chunk slicing)."""
        for k, info in enumerate(self.chunks):
            lo = sample_time(self.t0_s, self.interval_s,
                             self.chunk_start(k))
            hi = sample_time(self.t0_s, self.interval_s,
                             self.chunk_start(k) + info.n_samples - 1)
            if (stop_s is not None and lo > stop_s) \
                    or (start_s is not None and hi < start_s):
                continue
            tpa, clk = self._decode(k)
            self.peak_resident_samples = max(
                self.peak_resident_samples,
                info.n_samples * self.n_devices)
            yield DeviceGrid(self.interval_s, tpa, clk, t0_s=info.t0_s)

    def read_all(self) -> DeviceGrid:
        """Materialize the whole archive (the batch `scrapes()` view —
        O(trace) memory by definition; prefer iter_chunks/read_samples
        for long archives)."""
        if not self.n_samples:
            return DeviceGrid(self.interval_s,
                              np.empty((self.n_devices, 0), self.dtype),
                              np.empty((self.n_devices, 0), self.dtype),
                              t0_s=self.t0_s)
        tpa, clk = self.read_samples(0, self.n_samples)
        return DeviceGrid(self.interval_s, tpa, clk, t0_s=self.t0_s)

    def summary(self) -> str:
        span_h = self.duration_s / 3600.0
        return (f"{self._summary_tag} devices={self.n_devices} "
                f"samples={self.n_samples} interval={self.interval_s:g}s "
                f"span={span_h:.2f}h chunks={len(self.chunks)} "
                f"dtype={self.dtype.name}{self._summary_extra()}")

    _summary_tag = "ctr_archive"
    _index_name = "manifest"     # what the chunk table is called in errors

    def _summary_extra(self) -> str:
        return ""


class TraceReaderV1(_ArchiveReaderBase):
    """Random-access view over a v1 archive directory; loads O(chunk)
    at a time.

    The manifest is validated up front (format tag, chunk contiguity,
    file presence, sample-count consistency) so a truncated or
    hand-edited archive fails loudly at open, not as silently wrong
    replay.
    """

    def __init__(self, path: str):
        self.path = str(path)
        mf = os.path.join(self.path, MANIFEST_NAME)
        if not os.path.isfile(mf):
            raise ValueError(f"{self.path!r} is not a columnar trace "
                             f"archive (no {MANIFEST_NAME})")
        try:
            with open(mf) as fh:
                m = json.load(fh)
        except json.JSONDecodeError as e:
            raise ValueError(f"corrupt trace archive {self.path!r}: "
                             f"unreadable manifest ({e})") from e
        _check(isinstance(m, dict) and m.get("format") == FORMAT_TAG,
               self.path, f"manifest format is {m.get('format')!r}, "
               f"expected {FORMAT_TAG!r}")
        for key in ("interval_s", "n_devices", "t0_s", "n_samples",
                    "chunks"):
            _check(key in m, self.path, f"manifest missing key {key!r}")
        self.interval_s = float(m["interval_s"])
        _check(self.interval_s > 0, self.path,
               f"interval_s={self.interval_s} must be positive")
        self.n_devices = int(m["n_devices"])
        self.t0_s = float(m["t0_s"])
        self.dtype = np.dtype(m.get("dtype", "float64"))
        self.chunks = []
        cum = 0
        tol = 1e-6 * self.interval_s
        for k, c in enumerate(m["chunks"]):
            _check(isinstance(c, dict)
                   and all(f in c for f in ("file", "t0_s", "n_samples")),
                   self.path, f"malformed chunk entry #{k}: {c!r}")
            info = ChunkInfo(str(c["file"]), float(c["t0_s"]),
                             int(c["n_samples"]))
            _check(info.n_samples > 0, self.path,
                   f"chunk {info.file!r} has n_samples={info.n_samples}")
            _check(os.path.isfile(os.path.join(self.path, info.file)),
                   self.path, f"chunk file {info.file!r} is missing")
            want_t0 = sample_time(self.t0_s, self.interval_s, cum - 1)
            _check(abs(info.t0_s - want_t0) <= tol, self.path,
                   f"chunk {info.file!r} starts at {info.t0_s}s, expected "
                   f"{want_t0}s (chunks must be contiguous)")
            self.chunks.append(info)
            cum += info.n_samples
        self.n_samples = int(m["n_samples"])
        _check(self.n_samples == cum, self.path,
               f"manifest n_samples={self.n_samples} but chunks hold {cum}")
        self._init_index()

    def _load_chunk(self, k: int) -> tuple:
        info = self.chunks[k]
        with np.load(os.path.join(self.path, info.file)) as z:
            _check("tpa" in z and "clock_mhz" in z, self.path,
                   f"chunk {info.file!r} is missing counter arrays")
            return z["tpa"], z["clock_mhz"]


class TraceReaderV2(_ArchiveReaderBase):
    """Random-access view over a ctr-v2 single-file archive.

    The file is mmap'd once; chunk decodes slice the mapping (the raw
    codec yields zero-copy read-only views).  The newest INTACT footer
    wins: a crash-truncated tail is skipped by walking the footer magic
    backward, so an archive is readable up to its last durable flush.
    `footer_end` is where that footer ends — the append point a
    reopening writer truncates to.
    """

    _summary_tag = "ctr2_archive"
    _index_name = "footer"

    def __init__(self, path: str):
        self.path = str(path)
        if not os.path.isfile(self.path):
            raise ValueError(f"{self.path!r} is not a ctr-v2 trace "
                             "archive (no such file)")
        self._fh = open(self.path, "rb")
        try:
            self._mm = mmap.mmap(self._fh.fileno(), 0,
                                 access=mmap.ACCESS_READ)
        except ValueError as e:
            self._fh.close()
            raise ValueError(f"corrupt trace archive {self.path!r}: "
                             f"cannot map ({e})") from e
        try:
            self._parse()
        except Exception:
            self.close()
            raise

    def _parse(self) -> None:
        mm = self._mm
        _check(mm[:len(V2_MAGIC)] == V2_MAGIC, self.path,
               f"bad magic (not a {FORMAT_TAG_V2} file)")
        hoff = len(V2_MAGIC)
        _check(len(mm) >= hoff + 4, self.path, "truncated header")
        hlen = int(np.frombuffer(mm[hoff:hoff + 4], np.uint32)[0])
        _check(len(mm) >= hoff + 4 + hlen, self.path, "truncated header")
        try:
            header = json.loads(mm[hoff + 4:hoff + 4 + hlen].decode())
        except (UnicodeDecodeError, json.JSONDecodeError) as e:
            raise ValueError(f"corrupt trace archive {self.path!r}: "
                             f"unreadable header ({e})") from e
        _check(header.get("format") == FORMAT_TAG_V2, self.path,
               f"header format is {header.get('format')!r}, expected "
               f"{FORMAT_TAG_V2!r}")
        self._data_start = hoff + 4 + hlen

        footer, self.footer_end = self._find_footer()
        for key in ("interval_s", "n_devices", "t0_s", "n_samples",
                    "chunks", "dtype"):
            _check(key in footer, self.path,
                   f"footer missing key {key!r}")
        self.interval_s = float(footer["interval_s"])
        _check(self.interval_s > 0, self.path,
               f"interval_s={self.interval_s} must be positive")
        self.n_devices = int(footer["n_devices"])
        _check(self.n_devices >= 1, self.path,
               f"n_devices={self.n_devices} must be >= 1")
        self.t0_s = float(footer["t0_s"])
        self.dtype = np.dtype(footer["dtype"])
        # header/footer geometry must agree — a footer from some OTHER
        # archive spliced onto this file is rejected, not trusted
        for key in ("interval_s", "n_devices", "t0_s"):
            _check(float(header.get(key, footer[key]))
                   == float(footer[key]), self.path,
                   f"header/footer disagree on {key}")
        self.chunks = []
        cum = 0
        tol = 1e-6 * self.interval_s
        for k, c in enumerate(footer["chunks"]):
            _check(isinstance(c, dict)
                   and all(f in c for f in ("off", "t0_s", "n", "codec",
                                            "tb", "cb")),
                   self.path, f"malformed chunk entry #{k}: {c!r}")
            info = ChunkInfoV2(int(c["off"]), float(c["t0_s"]),
                               int(c["n"]), str(c["codec"]),
                               int(c["tb"]), int(c["cb"]))
            _check(info.n_samples > 0, self.path,
                   f"chunk #{k} has n_samples={info.n_samples}")
            _check(self._data_start <= info.offset
                   and info.offset + info.tpa_nbytes + info.clk_nbytes
                   <= len(self._mm), self.path,
                   f"chunk #{k} block [{info.offset}, "
                   f"+{info.tpa_nbytes + info.clk_nbytes}) is out of "
                   "bounds")
            want_t0 = sample_time(self.t0_s, self.interval_s, cum - 1)
            _check(abs(info.t0_s - want_t0) <= tol, self.path,
                   f"chunk #{k} starts at {info.t0_s}s, expected "
                   f"{want_t0}s (chunks must be contiguous)")
            self.chunks.append(info)
            cum += info.n_samples
        self.n_samples = int(footer["n_samples"])
        _check(self.n_samples == cum, self.path,
               f"footer n_samples={self.n_samples} but chunks hold {cum}")
        self._init_index()

    def _try_footer(self, end: int):
        """Validate a footer whose magic ends at byte `end`; returns the
        parsed dict or None."""
        if end - _V2_TAIL < self._data_start:
            return None
        tail = self._mm[end - _V2_TAIL:end]
        if tail[-len(V2_FOOTER_MAGIC):] != V2_FOOTER_MAGIC:
            return None
        flen = int(np.frombuffer(tail[4:12], np.uint64)[0])
        crc = int(np.frombuffer(tail[:4], np.uint32)[0])
        start = end - _V2_TAIL - flen
        if start < self._data_start:
            return None
        blob = self._mm[start:end - _V2_TAIL]
        if zlib.crc32(blob) != crc:
            return None
        try:
            footer = json.loads(blob.decode())
        except (UnicodeDecodeError, json.JSONDecodeError):
            return None
        if not isinstance(footer, dict) \
                or footer.get("format") != FORMAT_TAG_V2:
            return None
        return footer

    def _find_footer(self) -> tuple:
        """Newest intact footer: try EOF first (the no-crash fast path),
        then walk the footer magic backward past any torn tail."""
        pos = len(self._mm)
        footer = self._try_footer(pos)
        if footer is not None:
            return footer, pos
        while pos > self._data_start:
            idx = self._mm.rfind(V2_FOOTER_MAGIC, self._data_start,
                                 pos - 1)
            if idx < 0:
                break
            pos = idx + len(V2_FOOTER_MAGIC)
            footer = self._try_footer(pos)
            if footer is not None:
                return footer, pos
            pos = idx  # torn footer: keep walking back
        raise ValueError(f"corrupt trace archive {self.path!r}: no "
                         "intact footer (file truncated before the "
                         "first flush completed?)")

    def _load_chunk(self, k: int) -> tuple:
        info = self.chunks[k]
        codec = _codecs.get_codec(info.codec)
        shape = (self.n_devices, info.n_samples)
        lo = info.offset
        mid = lo + info.tpa_nbytes
        hi = mid + info.clk_nbytes
        tpa = codec.decode(self._mm[lo:mid], self.dtype, shape)
        clk = codec.decode(self._mm[mid:hi], self.dtype, shape)
        return tpa, clk

    def _summary_extra(self) -> str:
        tags = sorted({c.codec for c in self.chunks})
        return f" codecs={','.join(tags) if tags else '-'}"

    def close(self) -> None:
        """Release the mapping and file handle (readers are also closed
        by GC; call this for deterministic cleanup, e.g. on Windows)."""
        if getattr(self, "_mm", None) is not None:
            self._mm.close()
            self._mm = None
        if getattr(self, "_fh", None) is not None:
            self._fh.close()
            self._fh = None


def TraceReader(path: str) -> Union[TraceReaderV1, TraceReaderV2]:
    """Open a columnar archive, dispatching on its format: a directory
    with a manifest reads as ctr-v1, a `CTR2`-magic file as ctr-v2."""
    if os.path.isdir(path):
        return TraceReaderV1(path)
    if os.path.isfile(path):
        return TraceReaderV2(path)
    raise ValueError(f"{path!r} is not a columnar trace archive "
                     "(neither a v1 directory nor a ctr-v2 file)")


# ---------------------------------------------------------------------------
# One-shot helpers (the write_trace/read_trace dispatch targets)
# ---------------------------------------------------------------------------
def write_archive(grid: DeviceGrid, path: str, *,
                  chunk_samples: int = DEFAULT_CHUNK_SAMPLES,
                  codec: Optional[str] = None,
                  version: Optional[int] = None) -> None:
    """One-shot archive write of a DeviceGrid.

    `version=None` infers from the path: `.ctr2` writes the single-file
    ctr-v2 container, anything else the v1 directory.  `codec` selects
    the v2 column codec (v1 is always npz and rejects one).
    """
    if grid.n_devices < 1 or grid.interval_s <= 0:
        # e.g. the empty grid read_trace returns for a header-only CSV:
        # row formats round-trip it, but an archive needs real geometry
        raise ValueError(
            f"cannot write a columnar archive from an empty/degenerate "
            f"trace ({grid.n_devices} devices, interval "
            f"{grid.interval_s}s); keep empty traces in CSV/JSONL")
    if version is None:
        version = 2 if str(path).lower().endswith(V2_SUFFIX) else 1
    if version == 1:
        if codec not in (None, "auto"):
            raise ValueError(
                f"codec={codec!r} is a ctr-v2 feature; v1 archives are "
                "always npz chunks (write a .ctr2 path or pass "
                "version=2)")
        with TraceWriter(path, grid.interval_s, grid.n_devices,
                         chunk_samples=chunk_samples, t0_s=grid.t0_s) as w:
            w.append(grid.tpa, grid.clock_mhz)
    elif version == 2:
        with TraceWriterV2(path, grid.interval_s, grid.n_devices,
                           chunk_samples=chunk_samples, t0_s=grid.t0_s,
                           codec=codec) as w:
            w.append(grid.tpa, grid.clock_mhz)
    else:
        raise ValueError(f"unknown archive version {version!r} "
                         "(want 1 or 2)")


def read_archive(path: str,
                 interval_s: Optional[float] = None) -> DeviceGrid:
    """One-shot archive read (the `read_trace` dispatch target)."""
    rd = TraceReader(path)
    try:
        if interval_s is not None \
                and abs(interval_s - rd.interval_s) > 1e-6 * rd.interval_s:
            raise ValueError(
                f"explicit interval_s={interval_s} contradicts the "
                f"archive ({rd.interval_s}s) — columnar archives carry "
                "their own interval")
        return rd.read_all()
    finally:
        if isinstance(rd, TraceReaderV2):
            rd.close()


def archive_nbytes(path: str) -> int:
    """Total on-disk size of an archive (v1 directory or v2 file)."""
    if os.path.isdir(path):
        return sum(os.path.getsize(os.path.join(path, f))
                   for f in os.listdir(path))
    return os.path.getsize(path)
