from repro.train import checkpoint  # noqa: F401
from repro.train.steps import (  # noqa: F401
    cross_entropy, loss_fn, make_prefill_step, make_serve_step,
    make_train_step,
)
from repro.train.trainer import TrainConfig, Trainer  # noqa: F401
