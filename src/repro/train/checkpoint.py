"""Atomic numpy-based sharded checkpointing (fault-tolerance substrate).

Layout:  <dir>/step_<n>/ { manifest.json, 0000.npy, 0001.npy, ... }
Writes go to a temp dir + atomic rename, so a crash mid-save never corrupts
the restore point.  `keep` bounds disk usage; `latest_step` drives restart.
On a multi-host deployment each host writes its local shards (addressable
devices) — here single-process, whole arrays.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Optional

import jax
import numpy as np

# numpy's npy format has no bf16/fp8 descriptor: store as a same-width
# integer view and restore the logical dtype from the manifest.
_WIDE_VIEW = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
              "float8_e5m2": np.uint8}


def _paths(tree) -> list[str]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [jax.tree_util.keystr(k) for k, _ in flat]


def save(ckpt_dir: str, step: int, tree: Any, *, keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        flat, _ = jax.tree_util.tree_flatten_with_path(tree)
        manifest = {"step": step, "leaves": []}
        for i, (key, val) in enumerate(flat):
            arr = np.asarray(val)
            fn = f"{i:04d}.npy"
            logical = str(arr.dtype)
            if logical in _WIDE_VIEW:  # np.save can't represent bf16/fp8
                arr = arr.view(_WIDE_VIEW[logical])
            np.save(os.path.join(tmp, fn), arr)
            manifest["leaves"].append(
                {"path": jax.tree_util.keystr(key), "file": fn,
                 "dtype": logical, "shape": list(arr.shape)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic commit
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_")
             and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json"))]
    return max(steps) if steps else None


def restore(ckpt_dir: str, like: Any, step: Optional[int] = None) -> Any:
    """Restore into the structure of `like` (validates paths + shapes)."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    by_path = {m["path"]: m for m in manifest["leaves"]}
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for key, ref in flat:
        ks = jax.tree_util.keystr(key)
        m = by_path[ks]
        arr = np.load(os.path.join(d, m["file"]))
        if m["dtype"] in _WIDE_VIEW:
            import ml_dtypes
            arr = arr.view(getattr(ml_dtypes, m["dtype"]))
        assert list(arr.shape) == list(np.shape(ref)), (ks, arr.shape)
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)
