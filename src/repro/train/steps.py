"""train_step / serve_step builders (the functions the dry-run lowers).

Loss is computed with vocab-sharded-friendly reductions (one-hot einsum +
logsumexp — no gather across the sharded vocab axis).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import api as models
from repro.models.common import ShardCtx
from repro.optim import adamw


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean next-token CE; vocab axis may be sharded (einsum-reduced)."""
    V = logits.shape[-1]
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    onehot = jax.nn.one_hot(labels, V, dtype=jnp.float32)
    ll = jnp.einsum("...v,...v->...", lf, onehot)
    return jnp.mean(lse - ll)


def loss_fn(cfg: ModelConfig, params, batch,
            ctx: Optional[ShardCtx] = None) -> tuple[jax.Array, dict]:
    labels = batch["labels"]
    if cfg.mtp_depth:
        logits, h = models.forward(cfg, params, batch, ctx,
                                   return_hidden=True)
        from repro.models.transformer import mtp_logits
        main = cross_entropy(logits[:, :-1], labels[:, 1:])
        mtp = mtp_logits(cfg, params, h, batch, ctx)
        mtp_loss = cross_entropy(mtp[:, :-2], labels[:, 2:])
        loss = main + 0.3 * mtp_loss
        return loss, {"loss": loss, "main_loss": main, "mtp_loss": mtp_loss}
    logits = models.forward(cfg, params, batch, ctx)
    loss = cross_entropy(logits[:, :-1], labels[:, 1:])
    return loss, {"loss": loss}


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.OptConfig,
                    ctx: Optional[ShardCtx] = None, *,
                    accum_steps: int = 1):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    accum_steps > 1 enables gradient accumulation: the global batch is split
    into microbatches processed by a scanned, rematted inner loop — the
    standard activation-memory lever for 100B+ models (activations scale
    with the microbatch, grads accumulate in a single sharded fp32 buffer).
    """

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch, ctx), has_aux=True)(params)

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            (loss, aux), grads = grads_of(params, batch)
        else:
            def split(x):
                B = x.shape[0]
                assert B % accum_steps == 0, (B, accum_steps)
                return x.reshape(accum_steps, B // accum_steps, *x.shape[1:])

            micro = {k: split(v) for k, v in batch.items()}

            def body(acc, mb):
                (l, a), g = grads_of(params, mb)
                acc = jax.tree.map(
                    lambda s, gi: s + gi.astype(s.dtype) / accum_steps,
                    acc, g)
                return acc, a

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, auxs = jax.lax.scan(body, zeros, micro)
            aux = jax.tree.map(lambda x: x.mean(), auxs)
        params, opt_state, om = adamw.update(opt_cfg, grads, opt_state,
                                             params)
        aux.update(om)
        return params, opt_state, aux

    return train_step


def make_prefill_step(cfg: ModelConfig, ctx: Optional[ShardCtx] = None):
    """(params, batch) -> greedy next token (B,) — inference prefill."""

    def prefill_step(params, batch):
        logits = models.forward(cfg, params, batch, ctx)
        return jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1)

    return prefill_step


def make_serve_step(cfg: ModelConfig, ctx: Optional[ShardCtx] = None):
    """(params, batch) -> (next_token (B,1), updated caches) — one decode."""

    def serve_step(params, batch):
        logits, caches = models.decode_step(cfg, params, batch, ctx)
        nxt = jnp.argmax(logits[:, -1:].astype(jnp.float32), axis=-1)
        return nxt, caches

    return serve_step
