"""Fault-tolerant trainer loop with OFU-driven recovery.

Closes the paper's §VI loop end-to-end:
  train step -> step timing -> telemetry (simulated counter backend here,
  TPU backend in deploy) -> scrape -> job OFU -> RecoveryService -> on
  sustained collapse, restart from the latest atomic checkpoint.

Also handles straight crash-recovery (resume from checkpoint + deterministic
data stream) and supports fault injection for the integration tests.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig, ShapeSpec
from repro.core.ofu import ofu_point
from repro.core.peaks import DEFAULT_CHIP, ChipSpec
from repro.data.pipeline import synthetic_batch
from repro.fleet.recovery import RecoveryService, StragglerMonitor
from repro.models import api as models
from repro.optim import adamw
from repro.train import checkpoint as ckpt
from repro.train.steps import make_train_step


@dataclass
class TrainConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    seed: int = 0
    log_every: int = 10
    chip: ChipSpec = DEFAULT_CHIP
    # OFU monitoring
    monitor: bool = True
    scrape_every_steps: int = 5
    # resilience
    max_restarts: int = 3


@dataclass
class StepTelemetry:
    """What the (real or simulated) counters say about recent steps."""

    step: int
    step_time_s: float
    tpa: float
    clock_mhz: float

    @property
    def ofu(self) -> float:
        return ofu_point(self.tpa, self.clock_mhz)


class Trainer:
    def __init__(self, cfg: ModelConfig, shape: ShapeSpec,
                 opt_cfg: Optional[adamw.OptConfig] = None,
                 train_cfg: Optional[TrainConfig] = None,
                 ctx=None,
                 fault_hook: Optional[Callable[[int], None]] = None,
                 flops_per_step: Optional[float] = None):
        self.cfg = cfg
        self.shape = shape
        self.opt_cfg = opt_cfg or adamw.OptConfig(warmup_steps=10,
                                                  decay_steps=1000)
        self.tc = train_cfg or TrainConfig()
        self.ctx = ctx
        self.fault_hook = fault_hook
        self.flops_per_step = flops_per_step
        self.step_fn = jax.jit(make_train_step(cfg, self.opt_cfg, ctx),
                               donate_argnums=(0, 1))
        self.recovery = RecoveryService(factor_threshold=2.0,
                                        sustain_samples=3,
                                        cooldown_samples=6)
        self.stragglers = StragglerMonitor()
        self.history: list[StepTelemetry] = []
        self.restarts = 0

    # ------------------------------------------------------------------
    @staticmethod
    def _device_put(tree):
        """Checkpoint restores give host numpy; donated jit args need
        committed jax.Arrays."""
        import jax.numpy as jnp
        return jax.tree.map(jnp.asarray, tree)

    def _init_state(self):
        params = models.init_params(self.cfg, jax.random.key(self.tc.seed))
        opt_state = adamw.init(self.opt_cfg, params)
        return params, opt_state

    def _telemetry(self, step: int, dt: float) -> StepTelemetry:
        """Derive counter readings from the measured step time.

        On TPU this is a scrape of the hardware counters; on CPU we compute
        the duty cycle the chip WOULD show: mxu_time = flops/peak.
        """
        if self.flops_per_step:
            mxu_t = self.flops_per_step / (self.tc.chip.peak_tflops() * 1e12)
        else:
            mxu_t = 0.35 * dt
        tpa = min(1.0, mxu_t / max(dt, 1e-9))
        clock = self.tc.chip.f_max_mhz * (1 - 0.115 * tpa)
        return StepTelemetry(step, dt, tpa, clock)

    # ------------------------------------------------------------------
    def run(self, start_step: Optional[int] = None) -> dict:
        tc = self.tc
        params, opt_state = self._init_state()
        step = 0
        latest = ckpt.latest_step(tc.ckpt_dir)
        if start_step is None and latest is not None:
            params = self._device_put(
                ckpt.restore(tc.ckpt_dir, params, latest))
            opt_state = self._device_put(
                ckpt.restore(tc.ckpt_dir + "/opt", opt_state, latest))
            step = latest
        elif start_step:
            step = start_step

        metrics_log = []
        while step < tc.total_steps:
            try:
                if self.fault_hook is not None:
                    self.fault_hook(step)
                batch = synthetic_batch(self.cfg, self.shape, step,
                                        seed=tc.seed)
                batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
                t0 = time.perf_counter()
                params, opt_state, m = self.step_fn(params, opt_state, batch)
                jax.block_until_ready(m["loss"])
                dt = time.perf_counter() - t0
                step += 1

                tel = self._telemetry(step, dt)
                self.history.append(tel)
                if tc.monitor and step % tc.scrape_every_steps == 0:
                    action = self.recovery.observe("train", tel.ofu)
                    if action is not None:
                        raise _RecoveryRestart(action.reason)
                if step % tc.log_every == 0:
                    metrics_log.append(
                        {"step": step,
                         "loss": float(m["loss"]),
                         "ofu": tel.ofu,
                         "step_time_s": dt})
                if step % tc.ckpt_every == 0 or step == tc.total_steps:
                    ckpt.save(tc.ckpt_dir, step, params, keep=tc.keep)
                    ckpt.save(tc.ckpt_dir + "/opt", step, opt_state,
                              keep=tc.keep)
            except _RecoveryRestart as e:
                self.restarts += 1
                if self.restarts > tc.max_restarts:
                    raise RuntimeError("restart budget exhausted") from e
                latest = ckpt.latest_step(tc.ckpt_dir)
                params, opt_state = self._init_state()
                if latest is not None:
                    params = self._device_put(
                        ckpt.restore(tc.ckpt_dir, params, latest))
                    opt_state = self._device_put(
                        ckpt.restore(tc.ckpt_dir + "/opt", opt_state,
                                     latest))
                    step = latest
                else:
                    step = 0
            except KeyboardInterrupt:
                raise

        return {"final_step": step, "metrics": metrics_log,
                "restarts": self.restarts,
                "final_loss": metrics_log[-1]["loss"] if metrics_log
                else None}


class _RecoveryRestart(Exception):
    pass
