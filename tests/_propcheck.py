"""Property-check shim: real `hypothesis` when installed, a minimal
deterministic fallback otherwise.

The seed image does not ship `hypothesis`, which used to crash tier-1 at
COLLECTION time (three modules `import hypothesis` at top level).  Test
modules now do

    from _propcheck import given, settings, st

and get either the real library or this fallback: a fixed-seed random
sampler that runs each property `max_examples` times.  The fallback
supports exactly the strategy surface the suite uses (floats, integers,
sampled_from, lists, tuples, booleans, just) — extend it here if a test
needs more.  Install `requirements-dev.txt` to get real shrinking/edge
cases; CI without it still executes every property.
"""
from __future__ import annotations

try:
    from hypothesis import assume, given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # ------------------------------------------------ shim
    import functools
    import inspect
    import random
    import zlib

    HAVE_HYPOTHESIS = False
    _DEFAULT_EXAMPLES = 25

    class _Unsatisfied(Exception):
        """Raised by assume(False): skip this example."""

    def assume(condition):
        if not condition:
            raise _Unsatisfied
        return True

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

        def map(self, f):
            return _Strategy(lambda rng: f(self._draw(rng)))

        def filter(self, pred):
            def draw(rng):
                for _ in range(1000):
                    v = self._draw(rng)
                    if pred(v):
                        return v
                raise _Unsatisfied
            return _Strategy(draw)

    class _St:
        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_):
            lo, hi = float(min_value), float(max_value)

            def draw(rng):
                # hit the endpoints now and then — the cheap edge cases
                r = rng.random()
                if r < 0.05:
                    return lo
                if r < 0.10:
                    return hi
                return lo + rng.random() * (hi - lo)
            return _Strategy(draw)

        @staticmethod
        def integers(min_value=0, max_value=2 ** 31 - 1):
            lo, hi = int(min_value), int(max_value)

            def draw(rng):
                r = rng.random()
                if r < 0.05:
                    return lo
                if r < 0.10:
                    return hi
                return rng.randint(lo, hi)
            return _Strategy(draw)

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def just(value):
            return _Strategy(lambda rng: value)

        @staticmethod
        def lists(elements, min_size=0, max_size=10, **_):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                return [elements.draw(rng) for _ in range(n)]
            return _Strategy(draw)

        @staticmethod
        def tuples(*strategies):
            return _Strategy(
                lambda rng: tuple(s.draw(rng) for s in strategies))

    st = _St()

    def settings(max_examples=_DEFAULT_EXAMPLES, **_):
        """Records max_examples; works above or below @given."""
        def deco(fn):
            fn._pc_max_examples = max_examples
            return fn
        return deco

    def given(*strategies, **kw_strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_pc_max_examples", None) \
                    or getattr(fn, "_pc_max_examples", _DEFAULT_EXAMPLES)
                # deterministic per-test seed so failures reproduce
                rng = random.Random(
                    zlib.crc32(fn.__qualname__.encode()))
                ran = 0
                for _ in range(n * 4):
                    if ran >= n:
                        break
                    try:
                        vals = [s.draw(rng) for s in strategies]
                        kw = {k: s.draw(rng)
                              for k, s in kw_strategies.items()}
                    except _Unsatisfied:
                        continue
                    try:
                        fn(*args, *vals, **kw, **kwargs)
                    except _Unsatisfied:
                        continue
                    except AssertionError as e:
                        raise AssertionError(
                            f"property falsified on example "
                            f"args={vals} kwargs={kw}: {e}") from e
                    ran += 1
            # strategy-fed params must not look like pytest fixtures
            if hasattr(wrapper, "__wrapped__"):
                del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            return wrapper
        return deco
