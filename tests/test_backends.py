"""Acquisition-tier unit tests (ISSUE 10 tentpole): the transport
protocol, the `dcgmi dmon` parser, snapshot-per-round batching, the
shared retry/backoff/staleness policy, and the engine-driven fakes.

The end-to-end bit-identity claim (fake transport -> backend -> source
-> collector -> HTTP == pure simulator) lives in
`tools/fleet_live.py --self-check`; these tests pin the pieces.
"""
import numpy as np
import pytest

from repro.telemetry.backends import (
    DCGM_FI_DEV_SM_CLOCK, DCGM_FI_PROF_PIPE_TENSOR_ACTIVE,
    DcgmFieldBackend, DcgmiTransport, FakeDcgmTransport, FakeTpuTransport,
    FieldSample, LibtpuTransport, PynvmlTransport, TpuProfilerBackend,
    TransportError, make_dcgm_backends, parse_dmon,
)
from repro.telemetry.backends.fake import quantize_wire
from repro.telemetry.counters import StepProfile
from repro.telemetry.source import BackendSource, SimulatorSource

PROFILE = StepProfile(mxu_time_s=0.84, step_time_s=2.0)
TPA, CLK = DCGM_FI_PROF_PIPE_TENSOR_ACTIVE, DCGM_FI_DEV_SM_CLOCK


def _fake(**kw):
    kw.setdefault("duration_s", 600.0)
    kw.setdefault("interval_s", 30.0)
    kw.setdefault("n_devices", 2)
    kw.setdefault("seed", 3)
    t = FakeDcgmTransport(PROFILE, **kw)
    t.connect()
    return t


# ---------------------------------------------------------------------------
# parse_dmon
# ---------------------------------------------------------------------------
def test_parse_dmon_both_row_shapes_and_headers():
    text = """\
# Entity  TENSO  SMCLK
# Id
GPU 0     0.412  1410
GPU 1     0.000  210
2         0.985  1980

"""
    out = parse_dmon(text, (TPA, CLK))
    assert out == {0: {TPA: 0.412, CLK: 1410.0},
                   1: {TPA: 0.0, CLK: 210.0},
                   2: {TPA: 0.985, CLK: 1980.0}}


def test_parse_dmon_na_is_missing_not_zero():
    out = parse_dmon("GPU 0  N/A  1410\n", (TPA, CLK))
    assert out == {0: {CLK: 1410.0}}        # TPA absent, not 0.0


@pytest.mark.parametrize("row", [
    "GPU zero 0.4 1410",            # bad entity id
    "GPU 0 0.4",                    # too few values
    "0 0.4 fast",                   # unparsable value
])
def test_parse_dmon_garbage_raises(row):
    with pytest.raises(TransportError):
        parse_dmon(row, (TPA, CLK))


# ---------------------------------------------------------------------------
# DcgmiTransport with an injected runner
# ---------------------------------------------------------------------------
class _Runner:
    """Scripted dcgmi: answers --version, serves dmon snapshots in
    sequence (last one repeats), counts invocations."""

    def __init__(self, snapshots):
        self.snapshots = list(snapshots)
        self.dmon_calls = 0
        self.version_calls = 0

    def __call__(self, cmd):
        if "--version" in cmd:
            self.version_calls += 1
            return "dcgmi version 3.0\n"
        assert cmd[1] == "dmon" and "-e" in cmd
        self.dmon_calls += 1
        k = min(self.dmon_calls - 1, len(self.snapshots) - 1)
        return self.snapshots[k]


def test_dcgmi_snapshot_per_round_batching():
    """One dmon invocation covers every GPU; a GPU reading twice marks
    the new round and refreshes the snapshot."""
    r = _Runner(["GPU 0  0.10  1000\nGPU 1  0.20  1100\n",
                 "GPU 0  0.30  1200\nGPU 1  0.40  1300\n"])
    t = DcgmiTransport(runner=r)
    t.connect()
    assert r.version_calls == 1
    assert t.n_devices == 2 and r.dmon_calls == 1
    s0 = t.read(0, (TPA, CLK))
    s1 = t.read(1, (TPA, CLK))
    assert r.dmon_calls == 1                 # same snapshot served both
    assert s0[TPA].value == 0.10 and s1[TPA].value == 0.20
    assert t.read(0, (TPA, CLK))[TPA].value == 0.30   # round 2 refresh
    assert r.dmon_calls == 2
    assert t.read(1, (TPA, CLK))[CLK].value == 1300.0
    assert r.dmon_calls == 2


def test_dcgmi_percent_scale_and_error_paths():
    r = _Runner(["GPU 0  41.2  1410\n"])     # percent-reporting build
    t = DcgmiTransport(runner=r)
    t.connect()
    assert t.read(0, (TPA, CLK))[TPA].value == pytest.approx(0.412)
    with pytest.raises(TransportError, match="absent from dmon"):
        t.read(7, (TPA, CLK))
    t.close()
    with pytest.raises(TransportError, match="not connected"):
        t.read(0, (TPA, CLK))
    # a missing (N/A) profiling field is fatal at read, with a hint
    t2 = DcgmiTransport(runner=_Runner(["GPU 0  N/A  1410\n"]))
    t2.connect()
    with pytest.raises(TransportError, match="N/A for GPU 0"):
        t2.read(0, (TPA, CLK))
    # an empty snapshot is a transport failure, not 0 devices
    t3 = DcgmiTransport(runner=_Runner(["# nothing\n"]))
    t3.connect()
    with pytest.raises(TransportError, match="no GPU rows"):
        t3.read(0, (TPA, CLK))


def test_dcgmi_connect_requires_binary_on_path():
    t = DcgmiTransport(binary="definitely-not-a-real-dcgmi-binary")
    with pytest.raises(TransportError, match="not found on PATH"):
        t.connect()


def test_pynvml_connect_is_gated_on_module():
    try:
        import pynvml  # noqa: F401
        pytest.skip("pynvml installed; gating path not reachable")
    except ImportError:
        pass
    with pytest.raises(TransportError, match="pynvml"):
        PynvmlTransport().connect()


# ---------------------------------------------------------------------------
# DcgmFieldBackend policy: ranges, staleness, retry/backoff
# ---------------------------------------------------------------------------
class _ScriptedTransport:
    """Serves a scripted list of (tpa, clk, t_s) triples; entries that
    are exceptions raise instead."""

    def __init__(self, script):
        self.script = list(script)
        self.i = 0
        self.connects = 0
        self.closes = 0

    def connect(self):
        self.connects += 1

    def close(self):
        self.closes += 1

    @property
    def n_devices(self):
        return 1

    def read(self, gpu, field_ids):
        item = self.script[min(self.i, len(self.script) - 1)]
        self.i += 1
        if isinstance(item, Exception):
            raise item
        tpa, clk, t_s = item
        return {TPA: FieldSample(tpa, t_s), CLK: FieldSample(clk, t_s)}


def test_backend_rejects_out_of_range_readings():
    for bad in [(1.7, 1400.0, 1.0), (-0.1, 1400.0, 1.0),
                (0.5, -3.0, 1.0), (0.5, 99_999.0, 1.0)]:
        be = DcgmFieldBackend(0, _ScriptedTransport([bad]),
                              max_retries=0, sleep=lambda s: None)
        with pytest.raises(TransportError, match="outside"):
            be.poll(30.0)
        assert not be.healthy


def test_backend_staleness_tolerates_then_escalates():
    """A frozen timestamp is tolerated for max_stale_polls reads (DCGM
    legitimately repeats when over-polled), then escalates."""
    frozen = [(0.4, 1400.0, 5.0)] * 10      # t_s never advances
    be = DcgmFieldBackend(0, _ScriptedTransport(frozen), max_retries=0,
                          max_stale_polls=3, sleep=lambda s: None)
    assert be.poll(30.0) == (0.4, 1400.0)   # first: fresh
    for _ in range(3):                      # tolerated repeats
        assert be.poll(30.0) == (0.4, 1400.0)
    assert be.healthy
    with pytest.raises(TransportError, match="stale for 4 consecutive"):
        be.poll(30.0)
    # 3 tolerated polls count both fields; the 4th counts tpa then
    # escalates before reaching clk
    assert be.stale_reads == 7 and not be.healthy


def test_backend_retry_backoff_schedule_and_reconnect():
    t = _ScriptedTransport([TransportError("boom 1"),
                            TransportError("boom 2"),
                            (0.4, 1400.0, 1.0)])
    naps = []
    be = DcgmFieldBackend(0, t, max_retries=3, backoff_s=0.05,
                          backoff_mult=2.0, sleep=naps.append)
    assert be.poll(30.0) == (0.4, 1400.0)
    assert naps == [0.05, 0.1]              # exponential schedule
    assert be.retries == 2 and be.reconnects == 2
    assert t.closes == 2 and t.connects == 3   # close -> backoff -> connect
    assert be.healthy and be.polls == 1


def test_backend_gives_up_after_max_retries():
    t = _ScriptedTransport([TransportError("dead daemon")] * 10)
    be = DcgmFieldBackend(0, t, max_retries=2, sleep=lambda s: None)
    with pytest.raises(TransportError, match="gave up after 2"):
        be.poll(30.0)
    assert not be.healthy and be.retries == 2


def test_backend_enforces_scrape_window():
    be = DcgmFieldBackend(0, _ScriptedTransport([(0.4, 1400.0, 1.0)]))
    with pytest.raises(ValueError, match="30"):
        be.poll(45.0)                        # §IV-C: > hardware window
    lax = DcgmFieldBackend(0, _ScriptedTransport([(0.4, 1400.0, 1.0)]),
                           strict=False)
    with pytest.warns(RuntimeWarning):
        lax.poll(45.0)


# ---------------------------------------------------------------------------
# fakes + make_dcgm_backends + BackendSource integration
# ---------------------------------------------------------------------------
def test_fake_transport_matches_simulator_bitwise():
    t = _fake(chunk_s=300.0)
    # chunk seeds derive from the poll COUNT, so the reference simulator
    # must be polled at the fake's chunk_s cadence (as a collector with
    # round_s == chunk_s does)
    sim = SimulatorSource(profile=PROFILE, duration_s=600.0,
                          interval_s=30.0, n_devices=2, seed=3)
    want = sim.poll(300.0)
    want2 = sim.poll(300.0)
    want = np.concatenate([want.tpa, want2.tpa], axis=1)
    got = np.empty_like(want)
    # device-major like BackendSource: exercises the per-GPU cursors
    for d in range(2):
        for i in range(20):
            got[d, i] = t.read(d, (TPA,))[TPA].value
    np.testing.assert_array_equal(got, want)
    assert t.exhausted
    with pytest.raises(TransportError, match="exhausted"):
        t.read(0, (TPA,))


def test_fake_transport_validation_and_quantize():
    t = _fake(quantize=True)
    s = t.read(0, (TPA, CLK))
    assert s[TPA].value == round(s[TPA].value, 3)
    assert s[CLK].value == round(s[CLK].value, 0)
    with pytest.raises(TransportError, match="no such GPU"):
        t.read(9, (TPA,))
    with pytest.raises(TransportError, match="unsupported DCGM field"):
        t.read(0, (123,))
    t.close()
    with pytest.raises(TransportError, match="not connected"):
        t.read(0, (TPA,))
    with pytest.raises(ValueError, match="finite duration"):
        FakeDcgmTransport(PROFILE, duration_s=float("inf"),
                          interval_s=30.0)


def test_quantize_wire_shapes():
    tpa, clk = quantize_wire(np.array([0.123456, 0.5]),
                             np.array([1410.7, 899.2]))
    np.testing.assert_array_equal(tpa, [0.123, 0.5])
    np.testing.assert_array_equal(clk, [1411.0, 899.0])


def test_make_dcgm_backends_and_source_roundtrip():
    t = _fake(chunk_s=300.0)
    backends = make_dcgm_backends(t, sleep=lambda s: None)
    assert len(backends) == 2
    assert [b.gpu for b in backends] == [0, 1]
    src = BackendSource(backends=backends, duration_s=600.0,
                        interval_s=30.0)
    sim = SimulatorSource(profile=PROFILE, duration_s=600.0,
                          interval_s=30.0, n_devices=2, seed=3)
    # poll both at the fake's chunk cadence: chunk seeds match poll
    # count, so the grids must be bit-identical round by round
    for _ in range(2):
        grid = src.poll(300.0)
        want = sim.poll(300.0)
        np.testing.assert_array_equal(grid.tpa, want.tpa)
        np.testing.assert_array_equal(grid.clock_mhz, want.clock_mhz)
    assert all(b.healthy and b.polls == 20 for b in backends)


def test_fault_injection_is_sample_transparent():
    clean = _fake(chunk_s=300.0)
    flaky = _fake(chunk_s=300.0, fail_every=13)
    b_clean = make_dcgm_backends(clean, 2, sleep=lambda s: None)
    b_flaky = make_dcgm_backends(flaky, 2, sleep=lambda s: None)
    g1 = BackendSource(backends=b_clean, duration_s=600.0,
                       interval_s=30.0).poll(600.0)
    g2 = BackendSource(backends=b_flaky, duration_s=600.0,
                       interval_s=30.0).poll(600.0)
    np.testing.assert_array_equal(g1.tpa, g2.tpa)
    assert sum(b.retries for b in b_flaky) > 0
    assert all(b.healthy for b in b_flaky)


# ---------------------------------------------------------------------------
# TPU side
# ---------------------------------------------------------------------------
def test_tpu_backend_polls_through_fake_transport():
    be = TpuProfilerBackend(0, FakeTpuTransport(
        PROFILE, duration_s=300.0, interval_s=30.0, n_devices=1, seed=5))
    duty, clock = be.poll(30.0)
    assert 0.0 <= duty <= 1.0 and clock > 0.0
    assert be.healthy and be.polls == 1


def test_tpu_backend_validates_duty_range():
    class Bad(FakeTpuTransport):
        def read(self, device):
            return (1.5, 940.0, 1.0)

    be = TpuProfilerBackend(0, Bad(PROFILE, duration_s=300.0,
                                   interval_s=30.0),
                            max_retries=0, sleep=lambda s: None)
    with pytest.raises(TransportError, match="outside"):
        be.poll(30.0)


def test_tpu_default_transport_is_gated_libtpu():
    be = TpuProfilerBackend(0, max_retries=0, sleep=lambda s: None)
    assert isinstance(be.transport, LibtpuTransport)
    # whether libtpu imports or not, a CPU container cannot serve duty
    # cycles — the poll must fail with an actionable TransportError
    with pytest.raises(TransportError):
        be.poll(30.0)


def test_lazy_reexport_from_counters():
    """`telemetry.counters.TpuProfilerBackend` stays importable (PEP 562
    forward) so pre-backends callers keep working."""
    from repro.telemetry import counters
    assert counters.TpuProfilerBackend is TpuProfilerBackend
    with pytest.raises(AttributeError):
        counters.NoSuchThing
