"""Codec + ctr-v2 container properties (ISSUE 10 satellite): encode/
decode is BIT-exact for arbitrary shapes/dtypes/values (NaN payloads,
signed zeros, Inf included), v1<->v2 conversion through
`tools/trace_convert.py` preserves every sample byte, and a v2 file
truncated anywhere after its first flush still opens valid at the
newest intact footer (the crash-mid-flush contract).

Runs under real `hypothesis` when installed, the `_propcheck` fallback
otherwise — see tests/_propcheck.py.
"""
import os
import struct
import sys
import zlib

import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(__file__))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tools"))
from _propcheck import given, settings, st  # noqa: E402

import trace_convert  # noqa: E402
from repro.telemetry import codecs  # noqa: E402
from repro.telemetry import tracestore as ts  # noqa: E402
from repro.telemetry.scrape import DeviceGrid  # noqa: E402
from repro.telemetry.source import read_trace  # noqa: E402

DTYPES = ["float32", "float64", "int32", "uint16", "int64"]

#: special float bit patterns the transform must carry UNCHANGED
SPECIALS = [np.nan, np.inf, -np.inf, -0.0, 0.0,
            np.finfo(np.float32).tiny, np.finfo(np.float32).max]


def _column(rng, dtype, d, s):
    """A (d, s) column of `dtype` mixing smooth series, noise and (for
    floats) special values — the adversarial recording."""
    dt = np.dtype(dtype)
    if dt.kind == "f":
        base = np.cumsum(rng.standard_normal((d, s)), axis=1) * 0.01
        arr = base.astype(dt)
        n_spec = min(s * d // 4, 16)
        if n_spec:
            flat = arr.ravel()
            idx = rng.choice(flat.size, size=n_spec, replace=False)
            flat[idx] = rng.choice(SPECIALS, size=n_spec)
        return arr
    info = np.iinfo(dt)
    return rng.integers(info.min, info.max, size=(d, s),
                        endpoint=True).astype(dt)


@settings(max_examples=60)
@given(st.integers(min_value=1, max_value=5),
       st.integers(min_value=0, max_value=70),
       st.sampled_from(DTYPES),
       st.sampled_from(codecs.codec_names()),
       st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_codec_roundtrip_is_bit_exact(d, s, dtype, name, seed):
    arr = _column(np.random.default_rng(seed), dtype, d, s)
    codec = codecs.get_codec(name)
    blob = codec.encode(arr)
    out = codec.decode(blob, arr.dtype, arr.shape)
    assert out.dtype == arr.dtype and out.shape == arr.shape
    # bit identity, not value closeness: NaN != NaN but its BYTES match
    assert out.tobytes() == arr.tobytes(), (name, dtype, arr.shape)


@settings(max_examples=40)
@given(st.integers(min_value=0, max_value=300),
       st.sampled_from([2, 4, 8]),
       st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_bit_transpose_inverts(n, itemsize, seed):
    rng = np.random.default_rng(seed)
    u = rng.integers(0, 2 ** (8 * itemsize), size=n,
                     dtype=f"u{itemsize}")
    back = codecs.bit_untranspose(codecs.bit_transpose(u), n, itemsize)
    assert back.tobytes() == u.tobytes()


def test_codec_registry_contract():
    assert codecs.DEFAULT_CODEC in codecs.codec_names()
    assert codecs.get_codec(None).name == codecs.DEFAULT_CODEC
    assert codecs.get_codec("auto").name == codecs.DEFAULT_CODEC
    assert codecs.get_codec("dbz").name.startswith("dbz-")
    with pytest.raises(ValueError, match="unknown codec"):
        codecs.get_codec("lz4-fantasy")
    if not codecs.HAVE_ZSTD:
        with pytest.raises(ValueError, match="zstandard"):
            codecs.get_codec("dbz-zstd")
        with pytest.raises(ValueError, match="zstandard"):
            codecs.DeltaBitshuffleCodec("zstd")
    with pytest.raises(ValueError, match="codec supports"):
        codecs.get_codec("dbz-zlib").encode(
            np.zeros((2, 3), dtype=np.uint8))


def _grid(seed=5, d=3, s=137, dtype=np.float32, interval=30.0, t0=0.0):
    rng = np.random.default_rng(seed)
    clk = rng.uniform(900.0, 1500.0, size=(d, s)).astype(dtype)
    return DeviceGrid(interval, _column(rng, dtype, d, s), clk, t0_s=t0)


@settings(max_examples=12)
@given(st.sampled_from(codecs.codec_names()),
       st.integers(min_value=1, max_value=64),
       st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_v2_archive_roundtrip_any_codec_and_chunking(name, chunk, seed):
    import tempfile
    grid = _grid(seed=seed, s=1 + seed % 150)
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "a.ctr2")
        ts.write_archive(grid, path, chunk_samples=chunk, codec=name)
        back = ts.read_archive(path)
    assert back.tpa.tobytes() == grid.tpa.tobytes()
    assert back.clock_mhz.tobytes() == grid.clock_mhz.tobytes()
    assert back.interval_s == grid.interval_s and back.t0_s == grid.t0_s


def test_v1_v2_conversion_is_byte_exact_via_trace_convert(tmp_path):
    """csv -> v1 -> v2 -> v1 through the CLI-level convert(): every hop
    must carry the same sample bytes (float64 once CSV parses them)."""
    grid = _grid(seed=9, s=101, dtype=np.float64)
    csv = str(tmp_path / "t.csv")
    v1 = str(tmp_path / "t.ctr")
    v2 = str(tmp_path / "t.ctr2")
    v1b = str(tmp_path / "back.ctr")
    trace_convert.write_trace(grid, csv)
    trace_convert.convert(csv, v1, chunk_samples=40)
    trace_convert.convert(v1, v2, chunk_samples=23, codec="dbz")
    trace_convert.convert(v2, v1b, chunk_samples=64)
    a1, a2, a1b = read_trace(v1), read_trace(v2), read_trace(v1b)
    assert a1.tpa.tobytes() == a2.tpa.tobytes() == a1b.tpa.tobytes()
    assert a1.clock_mhz.tobytes() == a2.clock_mhz.tobytes() \
        == a1b.clock_mhz.tobytes()
    assert a1.t0_s == a2.t0_s == a1b.t0_s
    assert a1.interval_s == a2.interval_s == a1b.interval_s
    # v1 refuses a codec: it has exactly one encoding
    with pytest.raises(ValueError, match="ctr-v2 feature"):
        trace_convert.convert(csv, str(tmp_path / "x.ctr"),
                              chunk_samples=40, codec="raw")


def test_v2_crash_mid_flush_opens_valid_at_last_footer(tmp_path):
    """Truncate the file at EVERY byte position after the first flush:
    the reader must either open with all first-flush samples intact or
    (only while the first footer itself is torn) refuse loudly."""
    path = str(tmp_path / "crash.ctr2")
    g1 = _grid(seed=1, s=32, interval=30.0)
    with ts.TraceWriterV2(path, 30.0, 3, chunk_samples=16,
                          codec="dbz-zlib") as w:
        w.append(g1.tpa, g1.clock_mhz)
    flush1_end = os.path.getsize(path)
    base = ts.read_archive(path)
    # now a second flush that a crash will tear
    g2 = _grid(seed=2, s=48, interval=30.0, t0=base.times_s[-1])
    with ts.TraceWriterV2(path, 30.0, 3, chunk_samples=16,
                          append=True, codec="raw") as w:
        w.append_grid(g2)
    full = os.path.getsize(path)
    blob = open(path, "rb").read()
    assert full > flush1_end

    step = 7            # every 7th cut point keeps the test fast
    for cut in range(flush1_end, full, step):
        torn = str(tmp_path / "torn.ctr2")
        with open(torn, "wb") as fh:
            fh.write(blob[:cut])
        rd = ts.TraceReaderV2(torn)
        try:
            assert rd.footer_end <= cut
            assert rd.n_samples >= 32     # never loses flushed data
            grid = rd.read_all()
        finally:
            rd.close()
        assert grid.tpa[:, :32].tobytes() == base.tpa.tobytes()
    # the untorn file serves both flushes
    whole = ts.read_archive(path)
    assert whole.n_devices == 3 and whole.tpa.shape[1] == 80
    assert whole.tpa[:, 32:].tobytes() == g2.tpa.tobytes()


def test_v2_append_reopen_truncates_unindexed_tail(tmp_path):
    path = str(tmp_path / "resume.ctr2")
    g1 = _grid(seed=3, s=20, interval=10.0)
    with ts.TraceWriterV2(path, 10.0, 3, chunk_samples=8) as w:
        w.append(g1.tpa, g1.clock_mhz)
    durable = os.path.getsize(path)
    # a crashed writer's unindexed garbage after the last footer
    with open(path, "ab") as fh:
        fh.write(b"\x00garbage torn chunk bytes" * 9)
    g2 = _grid(seed=4, s=12, interval=10.0, t0=200.0)
    with ts.TraceWriterV2(path, 10.0, 3, chunk_samples=8,
                          append=True) as w:
        assert os.path.getsize(path) == durable   # tail dropped
        w.append_grid(g2)
    out = ts.read_archive(path)
    assert out.tpa.shape == (3, 32)
    assert out.tpa[:, :20].tobytes() == g1.tpa.tobytes()
    assert out.tpa[:, 20:].tobytes() == g2.tpa.tobytes()


def test_v2_truncated_before_first_footer_fails_loudly(tmp_path):
    path = str(tmp_path / "dead.ctr2")
    g = _grid(seed=6, s=8)
    with ts.TraceWriterV2(path, 30.0, 3, chunk_samples=4) as w:
        w.append(g.tpa, g.clock_mhz)
    # find where the first footer STARTS and cut inside the header/data
    blob = open(path, "rb").read()
    first_magic = blob.index(ts.V2_FOOTER_MAGIC)
    flen = struct.unpack("<Q", blob[first_magic - 8:first_magic])[0]
    footer_start = first_magic + len(ts.V2_FOOTER_MAGIC) \
        - ts._V2_TAIL - flen
    with open(path, "wb") as fh:
        fh.write(blob[:footer_start + 3])
    with pytest.raises(ValueError, match="no intact footer"):
        ts.TraceReaderV2(path)


def test_v2_reader_residency_stays_per_chunk(tmp_path):
    """The O(chunk) memory contract holds for the mmap'd container just
    as it does for v1 directories."""
    path = str(tmp_path / "big.ctr2")
    grid = _grid(seed=8, d=4, s=400)
    ts.write_archive(grid, path, chunk_samples=50, codec="dbz-zlib")
    rd = ts.TraceReaderV2(path)
    try:
        for k in range(0, 400, 37):
            rd.read_samples(k, min(k + 30, 400))
        assert rd.peak_resident_samples <= 2 * 50 * 4
        assert rd.chunks_decoded >= 8
        # a mid-archive read touches only its spanning chunks
        before = rd.chunks_decoded
        rd.read_samples(55, 60)
        assert rd.chunks_decoded <= before + 1
    finally:
        rd.close()


def _flip_last_footer_bit(path):
    blob = bytearray(open(path, "rb").read())
    tail = len(blob) - ts._V2_TAIL
    flen = struct.unpack("<Q", blob[tail + 4:tail + 12])[0]
    blob[tail - flen + 5] ^= 0x40
    with open(path, "wb") as fh:
        fh.write(blob)


def test_v2_footer_crc_rejects_bitrot(tmp_path):
    # s < chunk_samples: the ONLY footer is the close() one — bitrot in
    # its json must fail the crc and, with nothing to fall back to,
    # refuse loudly
    path = str(tmp_path / "rot.ctr2")
    g = _grid(seed=10, s=5)
    ts.write_archive(g, path, chunk_samples=8, codec="raw")
    _flip_last_footer_bit(path)
    with pytest.raises(ValueError, match="intact footer"):
        ts.TraceReaderV2(path)

    # s == chunk_samples: append() committed an EARLIER cumulative
    # footer indexing the same chunk, so bitrot in the newest one falls
    # back instead of losing the archive
    path2 = str(tmp_path / "rot2.ctr2")
    g2 = _grid(seed=10, s=8)
    ts.write_archive(g2, path2, chunk_samples=8, codec="raw")
    _flip_last_footer_bit(path2)
    out = ts.read_archive(path2)
    assert out.tpa.tobytes() == g2.tpa.tobytes()


def test_dbz_beats_zlib_on_wire_precision_counters(tmp_path):
    """The reason dbz exists: on DCGM-wire-precision counters the
    delta+bitshuffle transform must beat plain DEFLATE, and both must
    beat raw."""
    from repro.telemetry.backends.fake import quantize_wire
    from repro.telemetry.counters import StepProfile
    from repro.telemetry.source import SimulatorSource

    src = SimulatorSource(
        profile=StepProfile(mxu_time_s=0.84, step_time_s=2.0),
        duration_s=6 * 3600.0, interval_s=30.0, n_devices=4, seed=11)
    grid = src.poll(6 * 3600.0)
    tpa, clk = quantize_wire(grid.tpa, grid.clock_mhz)
    wire = DeviceGrid(30.0, tpa.astype(np.float32),
                      clk.astype(np.float32))
    sizes = {}
    for name in ("raw", "zlib", "dbz-zlib"):
        p = str(tmp_path / f"{name}.ctr2")
        ts.write_archive(wire, p, chunk_samples=512, codec=name)
        sizes[name] = os.path.getsize(p)
        back = ts.read_archive(p)
        assert back.tpa.tobytes() == wire.tpa.tobytes()
    assert sizes["dbz-zlib"] < sizes["zlib"] < sizes["raw"], sizes


def test_mixed_codec_archive_reads_transparently(tmp_path):
    path = str(tmp_path / "mixed.ctr2")
    g1 = _grid(seed=12, s=16, interval=30.0)
    with ts.TraceWriterV2(path, 30.0, 3, chunk_samples=8,
                          codec="raw") as w:
        w.append(g1.tpa, g1.clock_mhz)
    g2 = _grid(seed=13, s=16, interval=30.0, t0=16 * 30.0)
    with ts.TraceWriterV2(path, 30.0, 3, chunk_samples=8, append=True,
                          codec="dbz-zlib") as w:
        w.append_grid(g2)
    rd = ts.TraceReaderV2(path)
    try:
        assert sorted({c.codec for c in rd.chunks}) \
            == ["dbz-zlib", "raw"]
        assert "codecs=dbz-zlib,raw" in rd.summary()
        out = rd.read_all()
    finally:
        rd.close()
    assert out.tpa.tobytes() == np.concatenate(
        [g1.tpa, g2.tpa], axis=1).tobytes()
