"""Collector daemon + windowed rollup coverage (ISSUE 3 acceptance):
windowed eviction is detector-transparent over the retained span, windowed
merge stays associative/commutative (and tree-reduces), the adaptive
controller tightens on variance spikes without ever violating §IV-C, and
a Collector's incremental ingestion matches one-shot batch ingestion.
"""
import numpy as np
import pytest

from repro.fleet.collector import (AdaptiveConfig, AdaptiveScrapeController,
                                   AlertDeduper, Collector, CollectorConfig,
                                   FleetCollector, JobStream)
from repro.fleet.distributed import tree_reduce
from repro.fleet.regression import detect_regressions
from repro.fleet.streaming import StreamingRollup, WindowedRollup
from repro.telemetry import Event, StepProfile
from repro.telemetry.counters import MAX_HW_AVG_WINDOW_S
from repro.telemetry.source import SimulatorSource

PROFILE = StepProfile(mxu_time_s=0.84, step_time_s=2.0)


def _dense_series(seed, n_buckets=30, bucket_s=60.0, per_bucket=8):
    """(t, v) samples hitting every bucket (regression-shaped: collapse)."""
    rng = np.random.default_rng(seed)
    t = np.concatenate([(b + rng.uniform(0.05, 0.95, per_bucket)) * bucket_s
                        for b in range(n_buckets)])
    level = np.where(np.arange(n_buckets) < n_buckets // 2, 0.42, 0.17)
    v = np.concatenate([level[b] + rng.normal(0, 0.01, per_bucket)
                        for b in range(n_buckets)])
    return t, np.clip(v, 0, 1.05)


# ---------------------------------------------------------------------------
# WindowedRollup: eviction transparency, merge laws, wire format
# ---------------------------------------------------------------------------
def test_windowed_matches_fresh_rollup_over_retained_span():
    win = WindowedRollup(bucket_s=60, retain=8)
    fresh = StreamingRollup(bucket_s=60)
    for seed, jid in ((1, "a"), (2, "b")):
        t, v = _dense_series(seed)
        win.observe(jid, t, v, group="bf16", weight=3.0)
        fresh.observe(jid, t, v, group="bf16", weight=3.0)
    b0 = win.bucket0
    assert b0 == 30 - 8 and win.n_buckets == 8
    for jid in ("a", "b"):
        sw, sf = win.job_stats(jid), fresh.job_stats(jid)
        np.testing.assert_array_equal(sw.mean, sf.mean[b0:])
        np.testing.assert_array_equal(sw.weight, sf.weight[b0:])
        for q in (10, 50, 90):
            np.testing.assert_array_equal(sw.percentiles[q],
                                          sf.percentiles[q][b0:])
        np.testing.assert_allclose(sw.centers_s, sf.centers_s[b0:])
        # detector output over the retained span is identical
        regs_w = detect_regressions(win.job_ofu(jid), window=3,
                                    min_duration=1)
        regs_f = detect_regressions(fresh.job_ofu(jid)[b0:], window=3,
                                    min_duration=1)
        assert [(r.start_idx, r.end_idx, r.factor) for r in regs_w] \
            == [(r.start_idx, r.end_idx, r.factor) for r in regs_f]


def test_windowed_alltime_conserves_evicted_mass():
    win = WindowedRollup(bucket_s=60, retain=5)
    fresh = StreamingRollup(bucket_s=60)
    t, v = _dense_series(3)
    win.observe("j", t, v, weight=2.0)
    fresh.observe("j", t, v, weight=2.0)
    at = win.fleet_alltime(qs=(50,))
    f = fresh.fleet_stats(qs=())
    w_total = float(np.nansum(f.weight))
    assert np.isclose(at["weight"], w_total)
    assert np.isclose(at["mean"],
                      float(np.nansum(f.mean * f.weight)) / w_total)
    assert np.isfinite(at["percentiles"][50])
    # job-level lifetime view survives full eviction of early buckets
    assert np.isclose(win.job_alltime("j")["weight"], w_total)


def _windowed(seed, retain=6):
    rng = np.random.default_rng(seed)
    roll = WindowedRollup(bucket_s=60, retain=retain)
    for _ in range(12):
        t = rng.uniform(1, rng.uniform(300, 1800), size=10)
        v = rng.uniform(0, 1.05, size=10)
        roll.observe(f"job{rng.integers(3)}", t, v,
                     group=("bf16", "fp8")[int(rng.integers(2))],
                     weight=float(rng.integers(1, 8)))
    return roll


def _assert_same_windowed(a: WindowedRollup, b: WindowedRollup):
    assert (a.bucket0, a.n_buckets, a.retain) \
        == (b.bucket0, b.n_buckets, b.retain)
    assert set(a._hists) == set(b._hists)
    for scope in a._hists:
        pad_a = np.pad(a._hists[scope],
                       ((0, a.n_buckets - a._hists[scope].shape[0]), (0, 0)))
        pad_b = np.pad(b._hists[scope],
                       ((0, b.n_buckets - b._hists[scope].shape[0]), (0, 0)))
        np.testing.assert_allclose(pad_a, pad_b, atol=1e-12)
    assert set(a._ev_hist) == set(b._ev_hist)
    for scope in a._ev_hist:
        np.testing.assert_allclose(a._ev_hist[scope], b._ev_hist[scope],
                                   atol=1e-12)
        assert np.isclose(a._ev_sum[scope], b._ev_sum[scope])


def test_windowed_merge_commutative_associative():
    def m(*seeds):
        out = WindowedRollup(bucket_s=60, retain=6)
        for s in seeds:
            out.merge(_windowed(s))
        return out

    _assert_same_windowed(m(1, 2), m(2, 1))
    left = m(1, 2).merge(_windowed(3))
    right = m(1).merge(m(2, 3))
    _assert_same_windowed(left, right)
    # tree_reduce over snapshots agrees too, any fanin
    red2 = tree_reduce([_windowed(s).to_bytes() for s in (1, 2, 3)], fanin=2)
    red3 = tree_reduce([_windowed(s) for s in (1, 2, 3)], fanin=3)
    assert isinstance(red2, WindowedRollup)
    _assert_same_windowed(left, red2)
    _assert_same_windowed(red2, red3)


def test_tree_reduce_mixed_plain_windowed_is_order_independent():
    plain = StreamingRollup(bucket_s=60)
    win = WindowedRollup(bucket_s=60, retain=5)
    rng = np.random.default_rng(0)
    t, v = rng.uniform(1, 900, 50), rng.uniform(0, 1.05, 50)
    plain.observe("a", t, v)
    win.observe("b", t, v)
    r1 = tree_reduce([plain.to_bytes(), win.to_bytes()])
    r2 = tree_reduce([win.to_bytes(), plain.to_bytes()])
    # the windowed element wins the accumulator regardless of host order
    assert isinstance(r1, WindowedRollup) and isinstance(r2, WindowedRollup)
    _assert_same_windowed(r1, r2)


def test_windowed_merge_guards():
    with pytest.raises(ValueError, match="retention"):
        WindowedRollup(bucket_s=60, retain=6).merge(
            WindowedRollup(bucket_s=60, retain=8))
    with pytest.raises(ValueError, match="WindowedRollup into a plain"):
        StreamingRollup(bucket_s=60).merge(WindowedRollup(bucket_s=60))
    # plain INTO windowed is fine: treated as a window starting at bucket 0
    plain = StreamingRollup(bucket_s=60)
    t, v = _dense_series(4)
    plain.observe("j", t, v)
    win = WindowedRollup(bucket_s=60, retain=5).merge(plain)
    assert win.bucket0 == plain.n_buckets - 5
    np.testing.assert_array_equal(win.job_stats("j").mean,
                                  plain.job_stats("j").mean[win.bucket0:])


def test_windowed_serialization_roundtrip():
    roll = _windowed(9)
    back = StreamingRollup.from_bytes(roll.to_bytes())   # self-describing
    assert isinstance(back, WindowedRollup)
    _assert_same_windowed(roll, back)
    assert back._job_meta == roll._job_meta
    a, b = roll.fleet_alltime(), back.fleet_alltime()
    assert np.isclose(a["mean"], b["mean"]) and a["weight"] == b["weight"]


# ---------------------------------------------------------------------------
# Adaptive scrape scheduling
# ---------------------------------------------------------------------------
def test_adaptive_tightens_on_spike_and_relaxes_when_quiet():
    cfg = AdaptiveConfig(min_interval_s=5.0, max_interval_s=30.0,
                         quiet_rounds=2)
    ctl = AdaptiveScrapeController(cfg)
    rng = np.random.default_rng(0)
    quiet = lambda: 0.4 + rng.normal(0, 0.005, 64)         # noqa: E731
    spiky = lambda: rng.choice([0.4, 0.15], 64)            # noqa: E731
    iv = 30.0
    iv = ctl.update("j", quiet(), iv)                      # builds baseline
    assert iv == 30.0
    iv = ctl.update("j", spiky(), iv)                      # variance spike
    assert iv == 15.0
    iv = ctl.update("j", spiky(), iv)                      # still spiking
    assert iv == 7.5
    history = [iv]
    for _ in range(6):                                     # quiet again
        iv = ctl.update("j", quiet(), iv)
        history.append(iv)
    assert history[-1] == 30.0                             # relaxed back
    assert all(cfg.min_interval_s <= h <= cfg.max_interval_s
               for h in history)


def test_adaptive_respects_interval_policy_bounds():
    ctl = AdaptiveScrapeController(AdaptiveConfig(min_interval_s=10.0,
                                                  max_interval_s=20.0,
                                                  quiet_rounds=1))
    rng = np.random.default_rng(1)
    iv = 20.0
    for k in range(20):   # alternate spiky/quiet; never leaves the bounds
        samples = rng.choice([0.4, 0.1], 64) if k % 2 \
            else 0.4 + rng.normal(0, 0.003, 64)
        iv = ctl.update("j", samples, iv)
        assert 10.0 <= iv <= 20.0 <= MAX_HW_AVG_WINDOW_S
    with pytest.raises(ValueError, match="averaging window"):
        AdaptiveConfig(max_interval_s=45.0)    # §IV-C ceiling is enforced


def test_collector_adaptive_retimes_source_on_event_boundary():
    streams = [JobStream("reg", SimulatorSource(
        PROFILE, duration_s=4800, interval_s=30, n_devices=4, seed=2,
        events=[Event(2550, 4800, slowdown=2.5)]))]
    cfg = CollectorConfig(round_s=300, bucket_s=300, retain=8,
                          adaptive=AdaptiveConfig(min_interval_s=5.0,
                                                  episode_aware=False))
    col = Collector(streams, cfg)
    reports = col.run()
    ivs = [r.intervals["reg"] for r in reports]
    assert min(ivs) < 30.0          # tightened on the dispersion spike
    assert ivs[-1] == 30.0          # relaxed once the new level is quiet
    assert all(5.0 <= i <= MAX_HW_AVG_WINDOW_S for i in ivs)


def test_collector_episode_aware_holds_interval_while_alert_open():
    # same collapse, episode-aware (the default): once the regression
    # episode opens, the interval pins to the floor and HOLDS until the
    # run ends (the collapse never recovers), instead of relaxing the
    # moment the regressed level goes quiet
    streams = [JobStream("reg", SimulatorSource(
        PROFILE, duration_s=4800, interval_s=30, n_devices=4, seed=2,
        events=[Event(2550, 4800, slowdown=2.5)]))]
    cfg = CollectorConfig(round_s=300, bucket_s=300, retain=8,
                          detector={"window": 3, "min_duration": 1},
                          adaptive=AdaptiveConfig(min_interval_s=5.0))
    col = Collector(streams, cfg)
    reports = col.run()
    ivs = [r.intervals["reg"] for r in reports]
    first_alert = next(r.round_idx for r in reports if r.alerts)
    assert "reg" in col.deduper.active_jobs       # still open at the end
    assert ivs[-1] == 5.0                         # pinned hot
    # every round after the episode opened ran at/below the pre-episode
    # cadence, stepping down to the floor and never relaxing
    tail = ivs[first_alert:]
    assert all(b <= a for a, b in zip(tail, tail[1:]))
    assert all(5.0 <= i <= MAX_HW_AVG_WINDOW_S for i in ivs)


# ---------------------------------------------------------------------------
# Collector: batch equivalence, alerts, fleet reduction
# ---------------------------------------------------------------------------
class _RecordingSource(SimulatorSource):
    """Captures every polled grid so the test can batch-ingest the same."""

    def poll(self, duration_s):
        grid = super().poll(duration_s)
        self.__dict__.setdefault("polled", []).append(grid)
        return grid


def test_collector_incremental_matches_batch_ingestion():
    src = _RecordingSource(PROFILE, duration_s=3600, interval_s=30,
                           n_devices=3, seed=5,
                           events=[Event(1800, 3600, slowdown=2.5)])
    cfg = CollectorConfig(round_s=300, bucket_s=300, retain=12)
    col = Collector([JobStream("j", src, chips=96, group="bf16",
                               app_mfu=0.35)], cfg)
    col.run()
    batch = WindowedRollup(bucket_s=300, retain=12)
    for grid in src.polled:
        batch.add_grid("j", grid, group="bf16", chips=96, app_mfu=0.35)
    assert col.rollup.bucket0 == batch.bucket0
    np.testing.assert_array_equal(col.rollup.job_ofu("j"),
                                  batch.job_ofu("j"))
    np.testing.assert_array_equal(col.rollup.fleet_stats().mean,
                                  batch.fleet_stats().mean)
    regs_c = detect_regressions(col.rollup.job_ofu("j"), window=4,
                                min_duration=2)
    regs_b = detect_regressions(batch.job_ofu("j"), window=4, min_duration=2)
    assert [(r.start_idx, r.factor) for r in regs_c] \
        == [(r.start_idx, r.factor) for r in regs_b]


def test_collector_alert_fires_once_per_episode():
    streams = [JobStream("reg", SimulatorSource(
        PROFILE, duration_s=7200, interval_s=30, n_devices=4, seed=2,
        events=[Event(3600, 7200, slowdown=2.5)]), chips=128)]
    col = Collector(streams, CollectorConfig(round_s=300, retain=24))
    col.run()
    regression_alerts = [a for a in col.alerts if a.kind == "regression"]
    assert len(regression_alerts) == 1         # dedup across ~12 hot rounds
    assert regression_alerts[0].factor > 1.8
    assert "reg" == regression_alerts[0].job_id


def test_collector_divergence_alert_and_dedup():
    # app reports 40% MFU but true duty is ~17%: miscalc signature
    src = SimulatorSource(StepProfile(mxu_time_s=0.34, step_time_s=2.0),
                          duration_s=1800, interval_s=30, n_devices=4, seed=3)
    col = Collector([JobStream("liar", src, chips=64, app_mfu=0.40)],
                    CollectorConfig(round_s=300))
    col.run()
    div = [a for a in col.alerts if a.kind == "divergence"]
    assert len(div) == 1 and div[0].job_id == "liar"


def test_alert_deduper_rearms_after_clear_rounds():
    key = ("j", "regression")
    d = AlertDeduper(clear_rounds=2)
    assert d.offer(key) is True                 # round 1: fires
    d.tick()
    assert d.offer(key) is False                # round 2: still active
    d.tick()
    d.tick()                                    # round 3: quiet #1
    assert key in d._active                     # not yet re-armed
    d.tick()                                    # round 4: quiet #2 -> retired
    assert d.offer(key) is True                 # round 5: fresh episode


def test_alert_deduper_tracks_drift_but_fires_distinct_episodes():
    d = AlertDeduper(clear_rounds=2, anchor_tolerance=4)
    assert d.offer(("j", "regression"), anchor=10) is True
    d.tick()
    # window eviction drifts the detected start a little: same episode
    assert d.offer(("j", "regression"), anchor=12) is False
    # a second, distant collapse fires while the first is still active
    assert d.offer(("j", "regression"), anchor=30) is True
    d.tick()
    assert d.offer(("j", "regression"), anchor=13) is False
    assert d.offer(("j", "regression"), anchor=29) is False


def test_collector_pages_second_distinct_collapse():
    # two separate dips: recover in between, collapse again much later —
    # the second episode must page even though the first is still in the
    # retained window (and is re-detected by every round's scan)
    streams = [JobStream("twice", SimulatorSource(
        PROFILE, duration_s=9600, interval_s=30, n_devices=4, seed=4,
        events=[Event(1200, 2100, slowdown=2.5),
                Event(5400, 9600, slowdown=3.0)]), chips=64)]
    col = Collector(streams, CollectorConfig(round_s=300, retain=32))
    col.run()
    regs = [a for a in col.alerts if a.kind == "regression"]
    assert len(regs) == 2
    assert regs[0].round_idx < regs[1].round_idx


def test_adaptive_rebaselines_after_sustained_regime_change():
    ctl = AdaptiveScrapeController(AdaptiveConfig(min_interval_s=5.0,
                                                  quiet_rounds=2))
    rng = np.random.default_rng(2)
    iv = ctl.update("j", 0.4 + rng.normal(0, 0.005, 64), 30.0)
    # dispersion steps PERMANENTLY ~10x: must tighten, then re-baseline
    # and relax instead of pinning the interval at min forever
    ivs = []
    for _ in range(40):
        iv = ctl.update("j", rng.choice([0.45, 0.25], 64), iv)
        ivs.append(iv)
    assert min(ivs) == 5.0          # reacted hard to the shift
    assert ivs[-1] == 30.0          # absorbed the new regime, relaxed back


def test_adaptive_episode_driven_tighten_hold_relax_cycle():
    # the detector-aware satellite, at the controller level: an OPEN
    # episode tightens to the floor and holds even though dispersion is
    # perfectly calm; CLEARing re-enters the normal quiet-rounds relax
    cfg = AdaptiveConfig(min_interval_s=5.0, max_interval_s=30.0,
                         quiet_rounds=2)
    ctl = AdaptiveScrapeController(cfg)
    rng = np.random.default_rng(0)
    quiet = lambda: 0.4 + rng.normal(0, 0.003, 64)         # noqa: E731
    iv = ctl.update("j", quiet(), 30.0)                    # baseline
    assert iv == 30.0
    for want in (15.0, 7.5, 5.0, 5.0, 5.0):                # open episode
        iv = ctl.update("j", quiet(), iv, episode_open=True)
        assert iv == want                                  # tighten, hold
        check_ok = cfg.min_interval_s <= iv <= cfg.max_interval_s
        assert check_ok
    history = [iv]
    for _ in range(8):                                     # episode clear
        iv = ctl.update("j", quiet(), iv, episode_open=False)
        history.append(iv)
    assert history[-1] == 30.0                             # relaxed back
    # relaxation steps the quiet_rounds ladder: 5 -> 10 -> 20 -> 30
    from itertools import groupby
    assert [k for k, _ in groupby(history)] == [5.0, 10.0, 20.0, 30.0]
    # an episode mid-relax re-pins immediately
    iv = ctl.update("j", quiet(), 30.0, episode_open=True)
    assert iv == 15.0
    # episode_aware=False ignores the episode signal entirely
    off = AdaptiveScrapeController(AdaptiveConfig(episode_aware=False))
    off.update("k", quiet(), 30.0)
    assert off.update("k", quiet(), 30.0, episode_open=True) == 30.0


def test_deduper_active_jobs_tracks_open_episodes():
    d = AlertDeduper(clear_rounds=1)
    assert d.active_jobs == set()
    d.offer(("a", "regression"))
    d.offer(("b", "divergence"))
    d.tick()                       # end of the round that saw them
    assert d.active_jobs == {"a", "b"}
    d.tick()                       # clear_rounds=1: both retire unseen
    assert d.active_jobs == set()


def test_adaptive_tighten_clamps_degraded_interval_into_policy():
    # a degraded source at 120 s spikes: one half-step lands at 60 s,
    # still past the §IV-C ceiling — the tighten must clamp, not crash
    ctl = AdaptiveScrapeController(AdaptiveConfig())
    rng = np.random.default_rng(3)
    ctl.update("j", 0.4 + rng.normal(0, 0.003, 64), 120.0)   # baseline
    new = ctl.update("j", rng.choice([0.45, 0.1], 64), 120.0)
    assert new == MAX_HW_AVG_WINDOW_S


def test_adaptive_collector_tolerates_degraded_source_interval():
    # a strict=False source legitimately sits beyond the 30 s averaging
    # window; the controller must not crash it while leaving it untouched
    src = SimulatorSource(PROFILE, duration_s=1800, interval_s=45.0,
                          n_devices=2, seed=0, strict=False)
    col = Collector([JobStream("degraded", src)],
                    CollectorConfig(round_s=300, adaptive=AdaptiveConfig()))
    with pytest.warns(RuntimeWarning, match="averaging window"):
        reports = col.run()
    assert all(r.intervals["degraded"] == 45.0 for r in reports)


def test_fleet_collector_rejects_unbounded_run():
    from repro.telemetry.counters import SimulatedDeviceBackend
    from repro.telemetry.source import BackendSource
    live = BackendSource([SimulatedDeviceBackend(PROFILE)],
                         duration_s=float("inf"), interval_s=30.0)
    fc = FleetCollector([Collector([JobStream("live", live)],
                                   CollectorConfig(round_s=300))])
    with pytest.raises(ValueError, match="unbounded"):
        fc.run()
    assert len(fc.run(n_rounds=2)) == 2


def test_run_requires_n_rounds_for_custom_unbounded_source():
    class LivePoller(SimulatorSource):      # no finite duration_s
        pass

    src = LivePoller(PROFILE, duration_s=float("inf"), interval_s=30.0)
    assert not src.bounded
    with pytest.raises(ValueError, match="unbounded.*live"):
        Collector([JobStream("live", src)]).run()
    # bounded run still works with an explicit budget
    reps = Collector([JobStream("live", src)],
                     CollectorConfig(round_s=300)).run(n_rounds=2)
    assert len(reps) == 2


def test_fleet_collector_reduces_to_single_process_state():
    def host(jid, seed):
        src = SimulatorSource(PROFILE, duration_s=1800, interval_s=30,
                              n_devices=2, seed=seed)
        return Collector([JobStream(jid, src, chips=32)],
                         CollectorConfig(round_s=300, retain=6))

    fc = FleetCollector([host("a", 1), host("b", 2)], reduce_every=1)
    fc.run()
    assert fc.fleet is not None and set(fc.fleet.jobs) == {"a", "b"}
    # reduced fleet state == merging the hosts' rollups directly
    direct = fc.collectors[0].rollup.spawn_empty()
    for c in fc.collectors:
        direct.merge(c.rollup)
    np.testing.assert_allclose(fc.fleet.fleet_stats().mean,
                               direct.fleet_stats().mean, equal_nan=True)
    assert fc.scan() == {}                         # nothing regressed


def test_collector_config_guards():
    with pytest.raises(ValueError, match="round_s"):
        CollectorConfig(round_s=0)
    with pytest.raises(ValueError, match="at.*least one scrape"):
        CollectorConfig(round_s=20.0,
                        adaptive=AdaptiveConfig(max_interval_s=30.0))
    with pytest.raises(ValueError, match="duplicate"):
        src = SimulatorSource(PROFILE, duration_s=60, interval_s=30)
        Collector([JobStream("x", src), JobStream("x", src)])
    with pytest.raises(ValueError, match="n_rounds"):
        from repro.telemetry.counters import SimulatedDeviceBackend
        from repro.telemetry.source import BackendSource
        be = BackendSource([SimulatedDeviceBackend(PROFILE)],
                           duration_s=float("inf"), interval_s=30)
        Collector([JobStream("live", be)]).run()


# ---------------------------------------------------------------------------
# Chunked trace replay under the collector (ISSUE 4): poll rounds cross
# chunk boundaries exactly, and a snapshot restore resumes mid-trace
# ---------------------------------------------------------------------------
def _regressed_trace(tmp_path, fmt_suffix, chunk_samples=40):
    """A 1-hour 4-device trace with a 2.5x collapse at t=1800, recorded
    to disk (chunk span 1200 s deliberately misaligned with the 300 s
    collector round)."""
    from repro.fleet.engine import simulate_devices
    from repro.telemetry.source import write_trace
    grid = simulate_devices(PROFILE, duration_s=3600, interval_s=30.0,
                            events=[Event(1800, 3600, slowdown=2.5)],
                            n_devices=4, seed=21)
    path = str(tmp_path / f"trace{fmt_suffix}")
    write_trace(grid, path, chunk_samples=chunk_samples)
    return path


def _replay_collector(path, **collector_kw):
    from repro.telemetry.source import TraceReplaySource
    streams = [JobStream("traced", TraceReplaySource(path), chips=128,
                         group="bf16", app_mfu=0.38)]
    cfg = CollectorConfig(round_s=300, bucket_s=300, retain=6,
                          detector={"window": 3, "min_duration": 1})
    return Collector(streams, cfg, **collector_kw)


def _alert_keys(alerts):
    return [(a.round_idx, a.job_id, a.kind) for a in alerts]


def test_collector_chunked_replay_matches_inmemory_replay(tmp_path):
    """The same trace through a chunked columnar archive and through a
    fully-materialized CSV produces the same rounds, the same alert
    episodes, and the same final windowed state — while the archive path
    never holds more than O(chunk) samples."""
    ctr = _regressed_trace(tmp_path, ".ctr")
    csv = _regressed_trace(tmp_path, ".csv")
    col_c, col_m = _replay_collector(ctr), _replay_collector(csv)
    reps_c, reps_m = col_c.run(), col_m.run()

    assert [r.samples for r in reps_c] == [r.samples for r in reps_m]
    assert _alert_keys(col_c.alerts) == _alert_keys(col_m.alerts)
    assert any(a.kind == "regression" for a in col_c.alerts)
    np.testing.assert_allclose([a.factor for a in col_c.alerts],
                               [a.factor for a in col_m.alerts], atol=1e-9)
    fc, fm = col_c.rollup.fleet_stats(), col_m.rollup.fleet_stats()
    np.testing.assert_array_equal(fc.weight, fm.weight)
    np.testing.assert_allclose(fc.mean, fm.mean, atol=1e-12)
    np.testing.assert_array_equal(fc.percentiles[50], fm.percentiles[50])

    rd = col_c.streams[0].source.reader
    total = 4 * 120
    assert rd.peak_resident_samples < total / 2   # O(chunk), not O(trace)


def test_collector_resumes_after_snapshot_restore(tmp_path):
    """Kill the collector mid-trace, restore from its snapshot() in a
    fresh Collector, seek a fresh source to the old cursor: the resumed
    run fires the same alert episodes and converges to the same windowed
    state as the uninterrupted run."""
    from repro.fleet.streaming import WindowedRollup
    from repro.telemetry.source import TraceReplaySource

    ctr = _regressed_trace(tmp_path, ".ctr")
    straight = _replay_collector(ctr)
    straight_reports = straight.run()

    first = _replay_collector(ctr)
    for _ in range(4):                       # die after round 4 (t=1200)
        first.poll_round()
    snap = first.snapshot()
    cursor = first.streams[0].source.cursor_s
    assert not first.alerts                  # collapse starts at t=1800

    resumed_src = TraceReplaySource(ctr)     # fresh process, same archive
    resumed_src.seek(cursor)
    resumed = _replay_collector(
        ctr, rollup=WindowedRollup.from_bytes(snap),
        clock_s=first.clock_s, round_idx=first.round_idx)
    resumed.streams[0].source.seek(cursor)
    resumed_reports = resumed.run()

    assert resumed_reports[0].round_idx == 5
    assert [r.samples for r in resumed_reports] \
        == [r.samples for r in straight_reports[4:]]
    # the collapse pages once, in the same round, on both runs
    assert _alert_keys(resumed.alerts) == _alert_keys(straight.alerts)
    fs, fr = straight.rollup.fleet_stats(), resumed.rollup.fleet_stats()
    np.testing.assert_array_equal(fs.weight, fr.weight)
    np.testing.assert_allclose(fs.mean, fr.mean, atol=1e-12)
    np.testing.assert_array_equal(fs.percentiles[50], fr.percentiles[50])
    assert straight.rollup.bucket0 == resumed.rollup.bucket0


def test_collector_rejects_mismatched_restored_rollup(tmp_path):
    from repro.fleet.streaming import WindowedRollup
    ctr = _regressed_trace(tmp_path, ".ctr")
    with pytest.raises(ValueError, match="does not match config"):
        _replay_collector(ctr, rollup=WindowedRollup(bucket_s=60,
                                                     retain=6))
