"""OFU<->MFU correlation tier (ISSUE 9 acceptance): the app-reporter ->
`MfuRollup` -> join -> miscalculation-detector -> serve chain, plus the
two divergence bugfixes that ride along (idle-job `ofu_floor` exemption,
NaN-free degenerate populations through strict-JSON `/v1/query`).
"""
import json
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.fleet.collector import Collector, CollectorConfig, JobStream
from repro.fleet.correlation import (CorrelationConfig, MfuRollup,
                                     analyze_correlation, joined_series,
                                     rolling_pearson, scan_miscalc,
                                     tile_quant_factor)
from repro.fleet.divergence import (DEFAULT_OFU_FLOOR, JobPoint, analyze,
                                    analyze_rollup)
from repro.fleet.streaming import StreamingRollup
from repro.serve import (FleetAPIError, FleetAPIServer, FleetClient,
                         FleetStore, IngestAggregator)
from repro.telemetry import Event, StepProfile
from repro.telemetry.mfu import (MfuReplaySource, MfuReporter, MfuSample,
                                 compute_mfu, extract_tflops_from_log,
                                 reported_tflops_per_gpu)
from repro.telemetry.source import GridSource

from repro.fleet.engine import simulate_devices

PROFILE = StepProfile(mxu_time_s=0.84, step_time_s=2.0)
IDLE_PROFILE = StepProfile(mxu_time_s=0.002, step_time_s=2.0)


def _grid(profile=PROFILE, seed=7, duration_s=1800.0, events=()):
    return simulate_devices(profile, duration_s=duration_s,
                            interval_s=30.0, events=list(events),
                            n_devices=2, seed=seed)


def _mfu_roll(series, bucket_s=300.0):
    """MfuRollup from {job_id: (t_s, mfu)} arrays."""
    roll = MfuRollup(bucket_s)
    for jid, (t, v) in series.items():
        roll.observe_series(jid, t, v)
    return roll


# ---------------------------------------------------------------------------
# MfuRollup: bucket rule, merge laws, wire round-trip
# ---------------------------------------------------------------------------
def test_mfu_bucket_rule_matches_counter_rollup():
    """Right-closed buckets, the ONE rule both rollups share: a sample
    AT a boundary belongs to the earlier bucket."""
    mfu = MfuRollup(bucket_s=300.0)
    ctr = StreamingRollup(bucket_s=300.0)
    for t in (0.0, 1.0, 299.9, 300.0, 300.1, 900.0):
        mfu.observe("j", t, 0.4)
        ctr.observe("j", np.array([t]), np.array([0.4]))
    idx, _ = mfu.job_series("j")
    rows = np.nonzero(ctr.job_stats("j", qs=()).weight > 0)[0]
    np.testing.assert_array_equal(idx, rows)     # [0, 1, 2]
    assert idx.tolist() == [0, 1, 2]


def test_observe_series_equals_repeated_observe():
    t = np.array([30.0, 60.0, 330.0, 610.0])
    v = np.array([0.3, 0.5, 0.4, 0.2])
    bulk, loop = MfuRollup(300.0), MfuRollup(300.0)
    bulk.observe_series("j", t, v)
    for ti, vi in zip(t, v):
        loop.observe("j", ti, vi)
    for roll in (bulk, loop):
        idx, mean = roll.job_series("j")
        assert idx.tolist() == [0, 1, 2]
        np.testing.assert_allclose(mean, [0.4, 0.4, 0.2])
    assert bulk.job_mean("j") == pytest.approx(loop.job_mean("j"))
    assert bulk.n_samples("j") == 4


def test_merge_is_commutative_and_payload_round_trips():
    a = _mfu_roll({"x": (np.array([30.0, 330.0]), np.array([0.3, 0.5]))})
    b = _mfu_roll({"x": (np.array([40.0]), np.array([0.7])),
                   "y": (np.array([630.0]), np.array([0.2]))})
    ab = a.copy().merge(b)
    ba = b.copy().merge(a)
    assert ab.to_payload() == ba.to_payload()
    # merge accumulated, operands untouched
    assert ab.job_mean("x") == pytest.approx((0.3 + 0.5 + 0.7) / 3)
    assert a.job_mean("x") == pytest.approx(0.4)
    # wire round-trip: apply_payload rebuilds the exact accumulator
    back = MfuRollup(300.0)
    assert back.apply_payload(ab.to_payload()) == 3   # bucket rows
    assert back.to_payload() == ab.to_payload()
    # raw-sample body (the POST /v1/mfu shape)
    raw = MfuRollup(300.0)
    n = raw.apply_payload(
        {"job_id": "j", "samples": [[30.0, 0.4], [90.0, 0.6]]})
    assert n == 2 and raw.job_mean("j") == pytest.approx(0.5)


@pytest.mark.parametrize("payload", [
    "not a dict",
    {"samples": [[0, 0.4]]},                       # missing job_id
    {"job_id": "j", "samples": [[1.0]]},           # not pairs
    {"job_id": "j", "samples": [["x", "y"]]},      # not numbers
    {"jobs": "nope"},                              # jobs not a dict
    {"jobs": {"j": [[0, -1.0, 0.4]]}},             # non-positive weight
    {"jobs": {"j": [[0, 1.0]]}},                   # not triples
    {"bucket_s": 60.0, "jobs": {"j": [[0, 1.0, 0.4]]}},  # bucket clash
])
def test_apply_payload_rejects_malformed(payload):
    with pytest.raises(ValueError):
        MfuRollup(300.0).apply_payload(payload)


def test_mfu_rollup_validation():
    with pytest.raises(ValueError):
        MfuRollup(0.0)
    roll = MfuRollup(300.0)
    with pytest.raises(ValueError):
        roll.observe("", 30.0, 0.4)
    with pytest.raises(ValueError):
        roll.observe("j", 30.0, 0.4, weight=0.0)
    with pytest.raises(ValueError):
        roll.observe_series("j", [1.0, 2.0], [0.4])
    with pytest.raises(ValueError):
        roll.merge(MfuRollup(60.0))
    assert roll.job_mean("absent") is None


# ---------------------------------------------------------------------------
# join + rolling r
# ---------------------------------------------------------------------------
def test_joined_series_intersects_on_absolute_buckets():
    ctr = StreamingRollup(bucket_s=300.0)
    # OFU in buckets 0..3
    t = np.arange(30.0, 1200.0 + 1e-9, 30.0)
    ctr.observe("j", t, np.full(t.size, 0.4))
    # MFU only in buckets 1, 2, and 9 (no counter data there)
    mfu = _mfu_roll({"j": (np.array([330.0, 630.0, 2730.0]),
                           np.array([0.41, 0.42, 0.9]))})
    idx, mval, oval = joined_series(mfu, ctr, "j")
    assert idx.tolist() == [1, 2]
    np.testing.assert_allclose(mval, [0.41, 0.42])
    np.testing.assert_allclose(oval, [0.4, 0.4])
    # either side missing the job -> empty join, not an error
    empty = joined_series(mfu, ctr, "ghost")
    assert all(arr.size == 0 for arr in empty)
    with pytest.raises(ValueError):
        joined_series(MfuRollup(60.0), ctr, "j")


def test_rolling_pearson_tracks_and_degrades_to_zero():
    x = np.linspace(0.1, 0.5, 12)
    r = rolling_pearson(x, 2.0 * x + 0.05, window=4)
    assert r[0] == 0.0                       # one point: undefined -> 0
    np.testing.assert_allclose(r[1:], 1.0, atol=1e-12)
    flat = rolling_pearson(np.full(6, 0.3), x[:6], window=4)
    assert np.all(flat == 0.0)               # zero variance, never NaN
    with pytest.raises(ValueError):
        rolling_pearson(x, x, window=1)
    with pytest.raises(ValueError):
        rolling_pearson(x, x[:-1])


# ---------------------------------------------------------------------------
# the miscalculation scan
# ---------------------------------------------------------------------------
def _ctr(series, bucket_s=300.0):
    roll = StreamingRollup(bucket_s=bucket_s)
    for jid, level in series.items():
        t = np.arange(30.0, 1800.0 + 1e-9, 30.0)
        roll.observe(jid, t, np.full(t.size, level))
    return roll


def test_scan_miscalc_flags_ratio_band_violations():
    ctr = _ctr({"ok": 0.40, "hot": 0.40, "cold": 0.40, "idle": 0.005})
    t = np.arange(30.0, 1800.0 + 1e-9, 30.0)
    mfu = _mfu_roll({
        "ok": (t, np.full(t.size, 0.42)),     # ratio 1.05: healthy
        "hot": (t, np.full(t.size, 1.20)),    # ratio 3.0: inflated
        "cold": (t, np.full(t.size, 0.10)),   # ratio 0.25: deflated
        "idle": (t, np.full(t.size, 0.40)),   # sub-floor OFU: exempt
    })
    found = {f.job_id: f for f in scan_miscalc(mfu, ctr)}
    assert set(found) == {"hot", "cold"}
    assert found["hot"].direction == "inflated"
    assert found["hot"].ratio == pytest.approx(3.0)
    assert found["hot"].tq_factor == 1.0      # unknown arch: identity
    assert found["cold"].direction == "deflated"
    # worst |log ratio| first
    assert [f.job_id for f in scan_miscalc(mfu, ctr)] == ["cold", "hot"]
    # the idle exemption is the floor's doing: floor 0 flags it too
    cfg = CorrelationConfig(ofu_floor=0.0)
    assert "idle" in {f.job_id for f in scan_miscalc(mfu, ctr, config=cfg)}
    # min_buckets guards thin joins
    thin = _mfu_roll({"hot": (np.array([330.0]), np.array([1.2]))})
    cfg = CorrelationConfig(min_buckets=2)
    assert scan_miscalc(thin, ctr, config=cfg) == []


def test_correlation_config_validation():
    assert CorrelationConfig().ratio_low == pytest.approx(1 / 1.5)
    for kw in ({"ratio_high": 1.0}, {"ratio_low": 1.2},
               {"min_buckets": 0}, {"window": 1}):
        with pytest.raises(ValueError):
            CorrelationConfig(**kw)


def test_tile_quant_factor_identity_for_unknown_arch():
    assert tile_quant_factor("no-such-arch") == 1.0
    tq = tile_quant_factor("llama3.2-3b")
    assert 0.5 < tq <= 1.0


def test_analyze_correlation_degenerate_populations_stay_finite():
    # empty: all zeros, strict-JSON clean
    rep = analyze_correlation(MfuRollup(300.0), _ctr({}))
    assert (rep.n_jobs, rep.r_all, rep.r_clean, rep.mae) == (0, 0, 0, 0)
    json.dumps(rep.to_payload(), allow_nan=False)
    # one job / zero-variance population: r guards to 0.0, never NaN
    ctr = _ctr({"only": 0.40})
    t = np.arange(30.0, 1800.0 + 1e-9, 30.0)
    rep = analyze_correlation(
        _mfu_roll({"only": (t, np.full(t.size, 0.42))}), ctr)
    assert rep.n_jobs == 1 and rep.r_all == 0.0 and rep.r_clean == 0.0
    assert rep.mae == pytest.approx(0.02)
    json.dumps(rep.to_payload(), allow_nan=False)


# ---------------------------------------------------------------------------
# divergence bugfixes: idle-job floor, degenerate r
# ---------------------------------------------------------------------------
def test_divergence_idle_job_exempt_below_ofu_floor():
    """A parked job (OFU ~0.1%) with any reported MFU used to dominate
    the flag list through the rel_err denominator; the floor exempts it
    from flagging without dropping it from the statistics."""
    pts = [JobPoint("busy", "llama3.2-3b", 64, mfu=0.41, ofu=0.40),
           JobPoint("busy2", "llama3.2-3b", 64, mfu=0.30, ofu=0.29),
           JobPoint("idle", "llama3.2-3b", 8, mfu=0.05, ofu=0.001)]
    rep = analyze(pts, flag_rel_err=0.30)
    assert [p.job_id for p in rep.flagged] == []
    # still counted in the population statistics
    assert 8 in rep.by_scale
    # floor 0 restores the old (buggy) behaviour on demand
    rep0 = analyze(pts, flag_rel_err=0.30, ofu_floor=0.0)
    assert [p.job_id for p in rep0.flagged] == ["idle"]
    assert DEFAULT_OFU_FLOOR == pytest.approx(0.02)


def test_divergence_degenerate_population_is_nan_free():
    one = analyze([JobPoint("a", "x", 8, mfu=0.4, ofu=0.4)])
    assert one.r_all == 0.0 and one.r_clean == 0.0
    assert np.isfinite(one.mae_all)
    empty = analyze_rollup(StreamingRollup(300.0), empty_ok=True)
    assert empty is None
    with pytest.raises(ValueError):
        analyze_rollup(StreamingRollup(300.0))


# ---------------------------------------------------------------------------
# live collector: MFU streams feed the rollup, miscalc alerts fire
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def miscalc_collector():
    """Two healthy jobs + one whose reporter claims ~3x its OFU."""
    grids = {name: _grid(seed=s) for name, s in
             (("ok-a", 11), ("ok-b", 12), ("bad", 13))}
    ofu_level = {}
    for name, grid in grids.items():
        probe = StreamingRollup(bucket_s=300.0)
        probe.add_grid(name, grid)
        st = probe.job_stats(name, qs=())
        ofu_level[name] = float(np.nansum(st.mean * st.weight)
                                / np.nansum(st.weight))
    factor = {"ok-a": 1.03, "ok-b": 0.98, "bad": 3.0}
    streams = [JobStream(
        name, GridSource(grid), chips=64,
        mfu_source=MfuReplaySource.constant(
            factor[name] * ofu_level[name], duration_s=1800.0,
            interval_s=30.0))
        for name, grid in grids.items()]
    col = Collector(streams, CollectorConfig(round_s=300.0,
                                             bucket_s=300.0))
    col.run()
    return col, ofu_level, factor


def test_collector_streams_mfu_and_flags_miscalc(miscalc_collector):
    col, ofu_level, factor = miscalc_collector
    # every stream's samples landed in the collector's MfuRollup
    for name, lvl in ofu_level.items():
        assert col.mfu.n_samples(name) == 60            # 1800 / 30
        assert col.mfu.job_mean(name) == pytest.approx(factor[name] * lvl)
        # divergence metadata follows the reporter, not a static scalar
        meta = col.rollup.job_meta(name)
        assert meta["app_mfu"] == pytest.approx(factor[name] * lvl)
    flagged = {a.job_id for a in col.alerts if a.kind == "miscalc"}
    assert flagged == {"bad"}
    # unanchored population-level episode: fires once, stays active
    alerts = [a for a in col.alerts if a.kind == "miscalc"]
    assert len(alerts) == 1 and ("bad", "miscalc") in col.deduper.active


def test_collector_miscalc_none_disables_detector():
    grid = _grid(seed=13)
    streams = [JobStream("bad", GridSource(grid), chips=64,
                         mfu_source=MfuReplaySource.constant(
                             1.5, duration_s=1800.0, interval_s=30.0))]
    col = Collector(streams, CollectorConfig(round_s=300.0, bucket_s=300.0,
                                             miscalc=None))
    col.run()
    assert not [a for a in col.alerts if a.kind == "miscalc"]


# ---------------------------------------------------------------------------
# serve path: /v1/query kinds, POST /v1/mfu, client surface
# ---------------------------------------------------------------------------
def test_correlation_through_live_serve(miscalc_collector):
    col, ofu_level, factor = miscalc_collector
    store = FleetStore()
    store.update_from(col)
    agg = IngestAggregator(n_shards=1)
    with FleetAPIServer(store, aggregator=agg) as server:
        client = FleetClient(server.url)
        corr = client.correlation()
        assert corr["n_jobs"] == 3
        assert {f["job_id"] for f in corr["flagged"]} == {"bad"}
        f = next(f for f in corr["flagged"] if f["job_id"] == "bad")
        assert f["ratio"] == pytest.approx(3.0, rel=0.05)
        assert f["direction"] == "inflated"
        by_job = {row["job_id"]: row for row in corr["jobs"]}
        assert by_job["bad"]["flagged"] and not by_job["ok-a"]["flagged"]
        # parameter plumbing: a wide-open band flags nothing
        assert client.correlation(ratio_high=10.0)["flagged"] == []
        # identical query rides the generation cache (same dict)
        assert client.correlation() == corr
        json.dumps(corr, allow_nan=False)

        # POST /v1/mfu -> aggregator -> publish -> visible in the store
        t = np.arange(30.0, 1800.0 + 1e-9, 30.0)
        out = client.post_mfu(
            "posted", [[float(ti), 0.35] for ti in t])
        assert out["applied"] == t.size
        agg.publish(store, clock_s=col.clock_s)
        stats = client._get("/v1/ingest")
        assert stats["mfu_jobs"] == 1 and stats["mfu_rows"] == t.size

        # malformed body is a JSON 400, not a traceback
        req = urllib.request.Request(
            server.url + "/v1/mfu", data=b"{not json",
            headers={"Content-Type": "application/json"}, method="POST")
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(req)
        assert ei.value.code == 400
        assert "error" in json.loads(ei.value.read().decode())
        with pytest.raises(FleetAPIError) as ce:
            client.post_mfu("", [[30.0, 0.4]])
        assert ce.value.status == 400


def test_post_mfu_without_aggregator_is_404():
    store = FleetStore()
    with FleetAPIServer(store) as server:
        with pytest.raises(FleetAPIError) as ei:
            FleetClient(server.url).post_mfu("j", [[30.0, 0.4]])
        assert ei.value.status == 404


def test_divergence_floor_and_degenerate_through_query():
    """The two bugfixes, regression-tested end to end over HTTP."""
    roll = StreamingRollup(bucket_s=300.0)
    roll.add_grid("healthy", _grid(seed=31), chips=64, app_mfu=0.38)
    roll.add_grid("healthy2", _grid(
        PROFILE, seed=32,
        events=[Event(0.0, 1800.0, slowdown=1.4)]), chips=64, app_mfu=0.28)
    roll.add_grid("idle", _grid(IDLE_PROFILE, seed=33), chips=8,
                  app_mfu=0.05)
    store = FleetStore()
    store.update(roll)
    with FleetAPIServer(store) as server:
        client = FleetClient(server.url)
        div = client.divergence()
        assert "idle" not in {f["job_id"] for f in div["flagged"]}
        div0 = client.divergence(ofu_floor=0.0)
        assert "idle" in {f["job_id"] for f in div0["flagged"]}
        json.dumps(div, allow_nan=False)

    # degenerate population (one reporting job): finite zeros over HTTP
    lone = StreamingRollup(bucket_s=300.0)
    lone.add_grid("only", _grid(seed=34), chips=64, app_mfu=0.40)
    store2 = FleetStore()
    store2.update(lone)
    with FleetAPIServer(store2) as server:
        div = FleetClient(server.url).divergence()
        assert div["r_all"] == 0.0 and div["r_clean"] == 0.0
        json.dumps(div, allow_nan=False)
        corr = FleetClient(server.url).correlation()
        assert corr["n_jobs"] == 0 and corr["flagged"] == []


# ---------------------------------------------------------------------------
# the reporter: log lines -> samples -> sources
# ---------------------------------------------------------------------------
MEGATRON_LINE = (" iteration {it}/ 1000 | consumed samples: 4096 | "
                 "elapsed time per iteration (ms): {ms} | "
                 "throughput per GPU (TFLOP/s/GPU): {tfl} | "
                 "learning rate: 3.0E-04 |")


def test_extract_tflops_parses_megatron_lines():
    lines = [MEGATRON_LINE.format(it=10, ms="2100.5", tfl="412.3"),
             "saving checkpoint at iteration 10",
             MEGATRON_LINE.format(it=20, ms="2050.0", tfl="430.1")]
    recs = extract_tflops_from_log("\n".join(lines))
    assert [r["iteration"] for r in recs] == [10, 20]
    assert recs[0]["tflops_per_gpu"] == pytest.approx(412.3)
    assert recs[1]["elapsed_ms"] == pytest.approx(2050.0)


def test_reporter_clock_follows_elapsed_ms():
    rep = MfuReporter("j", peak_tflops=1000.0)
    out = rep.feed_log([
        MEGATRON_LINE.format(it=1, ms="2000.0", tfl="400.0"),
        "noise line",
        MEGATRON_LINE.format(it=2, ms="3000.0", tfl="500.0")])
    assert [s.t_s for s in out] == [2.0, 5.0]
    assert out[0].mfu == pytest.approx(0.4)
    assert out[1].iteration == 2
    # explicit t_s pins and resets the clock
    s = rep.feed(MEGATRON_LINE.format(it=3, ms="2000.0", tfl="600.0"),
                 t_s=100.0)
    assert s.t_s == 100.0 and rep.samples[-1].mfu == pytest.approx(0.6)
    # to_source round-trips through poll semantics
    src = rep.to_source()
    t, v = src.poll(10.0)
    assert t.tolist() == [2.0, 5.0]
    assert not src.exhausted
    t, v = src.poll(1000.0)
    assert t.tolist() == [100.0] and src.exhausted


def test_reporter_anchors_to_log_wall_clock():
    """Timestamped Megatron lines pin sample times to REAL wall time:
    a checkpoint stall between iterations (elapsed-ms never sees it)
    must not desync the samples from absolute time."""
    stamped = "[2026-08-09 {hms}] " + MEGATRON_LINE
    rep = MfuReporter("j", peak_tflops=1000.0)
    out = rep.feed_log([
        # first stamped line: accumulator position accepted, wall pinned
        stamped.format(hms="13:00:02", it=1, ms="2000.0", tfl="400.0"),
        # 58 wall seconds later — a stall ate ~55s the elapsed-ms field
        # (3000ms) never recorded
        stamped.format(hms="13:01:00", it=2, ms="3000.0", tfl="500.0")])
    assert [s.t_s for s in out] == [2.0, 60.0]   # wall delta, not 2+3
    # untimestamped lines fall back to the accumulator FROM the anchor
    s3 = rep.feed(MEGATRON_LINE.format(it=3, ms="2500.0", tfl="450.0"))
    assert s3.t_s == pytest.approx(62.5)
    # the next stamped line re-syncs onto the wall anchor
    s4 = rep.feed("2026-08-09 13:01:30,500 " + MEGATRON_LINE.format(
        it=4, ms="2000.0", tfl="480.0"))
    assert s4.t_s == pytest.approx(2.0 + 88.5)
    # a garbage almost-timestamp is not a timestamp
    from repro.telemetry.mfu import extract_wall_time
    assert extract_wall_time("2026-13-40 99:99:99 oops") is None
    # an un-stamped log behaves exactly as before (accumulator only)
    plain = MfuReporter("j", peak_tflops=1000.0)
    outs = plain.feed_log([
        MEGATRON_LINE.format(it=1, ms="2000.0", tfl="400.0"),
        MEGATRON_LINE.format(it=2, ms="3000.0", tfl="500.0")])
    assert [s.t_s for s in outs] == [2.0, 5.0]


def test_replay_source_poll_contract():
    src = MfuReplaySource.constant(0.4, duration_s=300.0, interval_s=30.0)
    assert src.t_s.size == 10 and src.t_s[0] == 30.0
    t1, _ = src.poll(150.0)      # (0, 150]
    assert t1.tolist() == [30.0, 60.0, 90.0, 120.0, 150.0]
    t2, _ = src.poll(150.0)      # (150, 300]
    assert t2.size == 5 and src.exhausted
    src.seek(0.0)
    assert not src.exhausted
    with pytest.raises(ValueError):
        src.poll(0.0)
    with pytest.raises(ValueError):
        src.seek(-1.0)
    with pytest.raises(ValueError):
        MfuReplaySource([2.0, 1.0], [0.1, 0.2])    # non-monotone


def test_reported_tflops_reflects_miscalculated_counters():
    exact = reported_tflops_per_gpu("deepseek-v3-671b", 2.0, 288)
    naive = reported_tflops_per_gpu("deepseek-v3-671b", 2.0, 288,
                                    variant="naive_moe")
    assert naive / exact == pytest.approx(3.186, rel=1e-3)
    assert compute_mfu(400.0, 1000.0) == pytest.approx(0.4)
    with pytest.raises(ValueError):
        compute_mfu(400.0, 0.0)
    with pytest.raises(ValueError):
        reported_tflops_per_gpu("llama3.2-3b", 0.0, 64)


def test_client_post_mfu_accepts_sample_objects(miscalc_collector):
    col, _, _ = miscalc_collector
    store = FleetStore()
    store.update_from(col)
    agg = IngestAggregator(n_shards=1)
    samples = [MfuSample(t_s=30.0 * (k + 1), mfu=0.35,
                         tflops_per_gpu=350.0) for k in range(4)]
    with FleetAPIServer(store, aggregator=agg) as server:
        out = FleetClient(server.url).post_mfu("obj-job", samples)
    assert out["applied"] == 4
    stats = agg.stats()
    assert stats["mfu_rows"] == 4 and stats["mfu_jobs"] == 1
    # publishing folds the posted rows into the store's MFU generation
    probe = FleetStore()
    agg.publish(probe)
    assert probe._mfu is not None
    assert probe._mfu.job_mean("obj-job") == pytest.approx(0.35)
