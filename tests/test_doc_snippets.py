"""README / ARCHITECTURE code fences must run against the current tree.

Intentionally the same check CI's standalone docs job performs via
tools/check_doc_snippets.py: the CI job gives doc health its own named
status check, while this wrapper puts it in tier-1 so LOCAL runs (the
gate most development actually goes through) catch doc rot too.  Keep
the file list here and in .github/workflows/ci.yml in sync."""
import os
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]


def test_doc_snippets_execute_cleanly():
    docs = [ROOT / "README.md", ROOT / "docs" / "ARCHITECTURE.md"]
    for d in docs:
        assert d.exists(), f"missing doc {d}"
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    res = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "check_doc_snippets.py"),
         *map(str, docs)],
        capture_output=True, text=True, env=env)
    assert res.returncode == 0, f"doc snippets failed:\n{res.stdout}\n{res.stderr}"
    # both files must actually contribute runnable snippets
    for d in docs:
        assert f"{d}: 0 snippet(s) ran" not in res.stdout
