"""jax fleet engine backend (ISSUE 6 tentpole): statistical equivalence
against the NumPy fused reference at the fused-vs-scalar tolerances, the
fused pallas/XLA histogram ingest producing rollups bucketwise IDENTICAL
to the host path, and the `simulate_fleet(engine="jax")` dispatch."""
import numpy as np
import pytest

from _propcheck import given, settings, st

jax = pytest.importorskip("jax")
jnp = jax.numpy

from repro.fleet import JobSpec, simulate_fleet, simulate_job  # noqa: E402
from repro.fleet.engine import JobSlot, simulate_jobs_fused  # noqa: E402
from repro.fleet.engine_jax import default_mesh, simulate_jobs_jax  # noqa: E402
from repro.fleet.streaming import StreamingRollup, WindowedRollup  # noqa: E402
from repro.kernels.fleet_hist import (_aligned_spb, bucket_hist_ref,  # noqa: E402
                                      ofu_bucket_hist)
from repro.telemetry import Event, StepProfile  # noqa: E402
from repro.telemetry.scrape import DeviceGrid  # noqa: E402


def _profile(duty=0.4, step_s=2.0):
    return StepProfile(mxu_time_s=duty * step_s, step_time_s=step_s)


def _host_grid(g: DeviceGrid) -> DeviceGrid:
    """Device grid -> identical-valued NumPy grid (host ingest path)."""
    return DeviceGrid(g.interval_s, np.asarray(g.tpa),
                      np.asarray(g.clock_mhz), t0_s=g.t0_s)


def _scope_state_equal(a: StreamingRollup, b: StreamingRollup):
    """Bucketwise identity: same scopes, identical histogram counts,
    value sums equal to f32-accumulation tolerance."""
    assert set(a._hists) == set(b._hists)
    for scope in b._hists:
        np.testing.assert_array_equal(a._hists[scope], b._hists[scope],
                                      err_msg=str(scope))
        np.testing.assert_allclose(a._sums[scope], b._sums[scope],
                                   rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# equivalence: jax backend vs the NumPy fused reference
# ---------------------------------------------------------------------------
def test_steady_state_statistics_match_numpy():
    slot = JobSlot(_profile(0.42), 1800.0, 30.0, stragglers=np.ones(16))
    (ref,) = simulate_jobs_fused([slot], seed=0)
    (g,) = simulate_jobs_jax([slot], seed=0)
    tpa, clk = np.asarray(g.tpa), np.asarray(g.clock_mhz)
    assert tpa.shape == ref.tpa.shape == (16, 60)
    # same tolerances the fused-vs-scalar suite freezes (test_fleet_engine)
    assert tpa.mean() == pytest.approx(ref.tpa.mean(), abs=0.005)
    assert clk.mean() == pytest.approx(ref.clock_mhz.mean(), abs=15.0)
    assert clk.std() == pytest.approx(ref.clock_mhz.std(), rel=0.5)
    ofu_j = tpa * clk / 1558.0
    ofu_n = ref.tpa * ref.clock_mhz / 1558.0
    assert ofu_j.mean() == pytest.approx(ofu_n.mean(), abs=0.005)


def test_event_collapse_window_by_window():
    """The 2.5x host-sync collapse lands in the same windows on both
    backends."""
    ev = [Event(start_s=300, end_s=900, slowdown=2.5)]
    slot = JobSlot(_profile(0.45), 900.0, 30.0, events=ev,
                   stragglers=np.ones(8))
    (ref,) = simulate_jobs_fused([slot], seed=3)
    (g,) = simulate_jobs_jax([slot], seed=3)
    tpa = np.asarray(g.tpa)
    assert tpa[:, :10].mean() == pytest.approx(ref.tpa[:, :10].mean(),
                                               abs=0.01)
    assert tpa[:, 10:].mean() == pytest.approx(ref.tpa[:, 10:].mean(),
                                               abs=0.01)
    assert tpa[:, :10].mean() / tpa[:, 10:].mean() \
        == pytest.approx(2.5, rel=0.05)


def test_straggler_and_mxu_scale_event_equivalence():
    ev = [Event(start_s=120, end_s=360, mxu_scale=0.5, kind="shrunk_gemm")]
    stragglers = np.array([1.0, 1.0, 2.0, 1.3])
    slot = JobSlot(_profile(0.5, step_s=1.0), 600.0, 30.0, events=ev,
                   stragglers=stragglers)
    (ref,) = simulate_jobs_fused([slot], seed=11)
    (g,) = simulate_jobs_jax([slot], seed=11)
    tpa = np.asarray(g.tpa)
    np.testing.assert_allclose(tpa.mean(axis=1), ref.tpa.mean(axis=1),
                               atol=0.01)
    assert tpa[2].mean() == pytest.approx(tpa[0].mean() / 2, rel=0.05)


def test_multi_job_grouping_and_ragged_slices_match_numpy_layout():
    """Heterogeneous slots land in the same groups with the same output
    shapes and clock domains as the NumPy backend (incl. the S == 0
    degenerate slot)."""
    from repro.core.peaks import TPU_V6E_LIKE
    slots = [JobSlot(StepProfile(0.8, 2.0), 600, 30.0,
                     stragglers=np.ones(3)),
             JobSlot(StepProfile(0.8, 2.0), 600, 15.0,
                     stragglers=np.ones(2)),
             JobSlot(StepProfile(0.9, 2.0), 450, 30.0,
                     chip=TPU_V6E_LIKE, stragglers=np.ones(4)),
             JobSlot(StepProfile(0.5, 2.0), 10.0, 30.0)]
    grids = simulate_jobs_jax(slots, seed=0)
    assert [np.asarray(g.tpa).shape for g in grids] \
        == [(3, 20), (2, 40), (4, 15), (1, 0)]
    assert grids[1].interval_s == 15.0
    assert np.asarray(grids[0].clock_mhz).max() <= 1500.0
    assert np.asarray(grids[2].clock_mhz).mean() > 1500.0


@settings(max_examples=10, derandomize=True, deadline=None)
@given(duty=st.floats(0.15, 0.6), n_dev=st.integers(1, 12),
       n_samp=st.integers(1, 80), sigma=st.floats(0.0, 0.3),
       evented=st.booleans(), seed=st.integers(0, 2 ** 16))
def test_property_jax_matches_numpy_and_ingest_is_bucketwise_identical(
        duty, n_dev, n_samp, sigma, evented, seed):
    """Same-seed property suite (acceptance): over random jobs the jax
    backend matches NumPy statistics within sample-count-scaled
    tolerances, and its device grid ingested through add_grid yields a
    rollup bucketwise identical to host ingestion of the same values."""
    dur = n_samp * 30.0
    strag = np.exp(np.random.default_rng(seed).standard_normal(n_dev)
                   * sigma)
    events = [Event(dur / 4, 3 * dur / 4, slowdown=2.0)] if evented else ()
    slot = JobSlot(_profile(duty), dur, 30.0, events=events,
                   stragglers=strag)
    (ref,) = simulate_jobs_fused([slot], seed=seed)
    (g,) = simulate_jobs_jax([slot], seed=seed)
    tpa, clk = np.asarray(g.tpa), np.asarray(g.clock_mhz)
    assert tpa.shape == ref.tpa.shape == (n_dev, n_samp)
    n = max(n_dev * n_samp, 1)
    # deterministic duty + tiny jitter: tight; OU noise: se ~ sigma/sqrt(n)
    assert tpa.mean() == pytest.approx(ref.tpa.mean(), abs=0.01)
    assert clk.mean() == pytest.approx(
        ref.clock_mhz.mean(), abs=15.0 + 110.0 / np.sqrt(n))
    ofu_j = (tpa * clk / 1558.0).mean()
    ofu_n = (ref.tpa * ref.clock_mhz / 1558.0).mean()
    assert ofu_j == pytest.approx(ofu_n, abs=0.005 + 0.06 / np.sqrt(n))

    r_dev, r_host = StreamingRollup(bucket_s=300), StreamingRollup(
        bucket_s=300)
    # integer chips-per-device weight: repeated-add (host) and count *
    # weight (device) stay binary-identical
    r_dev.add_grid("j", g, chips=4 * n_dev, group="bf16")
    r_host.add_grid("j", _host_grid(g), chips=4 * n_dev, group="bf16")
    _scope_state_equal(r_dev, r_host)


# ---------------------------------------------------------------------------
# device-side rollup ingest: add_grid over jax grids
# ---------------------------------------------------------------------------
def test_add_grid_device_path_matches_host_bucketwise():
    ev = [Event(1200, 2400, slowdown=2.5)]
    slot = JobSlot(_profile(0.42), 3600.0, 30.0, events=ev,
                   stragglers=np.ones(8))
    (g,) = simulate_jobs_jax([slot], seed=3)
    r_dev, r_host = StreamingRollup(bucket_s=300), StreamingRollup(
        bucket_s=300)
    ofu_dev = r_dev.add_grid("j", g, chips=128, group="bf16", app_mfu=0.4)
    ofu_host = r_host.add_grid("j", _host_grid(g), chips=128, group="bf16",
                               app_mfu=0.4)
    _scope_state_equal(r_dev, r_host)
    # identical readouts all the way to percentiles and job metadata
    sd, sh = r_dev.job_stats("j"), r_host.job_stats("j")
    np.testing.assert_array_equal(sd.weight, sh.weight)
    for q in (10, 50, 90):
        np.testing.assert_array_equal(sd.percentiles[q], sh.percentiles[q])
    assert r_dev.job_meta("j") == r_host.job_meta("j")
    # the returned OFU series stays a device array with the host's values
    assert type(ofu_dev).__module__.startswith(("jax", "jaxlib"))
    np.testing.assert_allclose(np.asarray(ofu_dev), ofu_host, rtol=1e-6)


def test_add_grid_device_path_windowed_with_eviction():
    """Windowed ingest evicts identically: a grid longer than the window
    folds its oldest buckets into the all-time totals on both paths."""
    slot = JobSlot(_profile(0.42), 3600.0, 30.0, stragglers=np.ones(4))
    (g,) = simulate_jobs_jax([slot], seed=5)
    w_dev = WindowedRollup(bucket_s=300, retain=6)
    w_host = WindowedRollup(bucket_s=300, retain=6)
    w_dev.add_grid("j", g, chips=32, group="bf16")
    w_host.add_grid("j", _host_grid(g), chips=32, group="bf16")
    assert w_dev.bucket0 == w_host.bucket0 == 6
    _scope_state_equal(w_dev, w_host)
    for scope in w_host._ev_hist:
        np.testing.assert_array_equal(w_dev._ev_hist[scope],
                                      w_host._ev_hist[scope])
        assert w_dev._ev_sum[scope] == pytest.approx(
            w_host._ev_sum[scope], rel=1e-5)
    assert w_dev.job_alltime("j")["weight"] \
        == w_host.job_alltime("j")["weight"]


def test_observe_hist_validates_bin_count():
    roll = StreamingRollup(bucket_s=300, bins=128)
    with pytest.raises(ValueError, match="64 bins"):
        roll.observe_hist("j", np.zeros((2, 64)), np.zeros(2))
    roll.observe_hist("j", np.zeros((0, 64)), np.zeros(0))  # empty: no-op
    assert roll.n_buckets == 0


# ---------------------------------------------------------------------------
# the fused histogram kernel itself (pallas + XLA vs the NumPy oracle)
# ---------------------------------------------------------------------------
def test_hist_kernel_pallas_and_xla_match_reference_exactly():
    rng = np.random.default_rng(0)
    D, S = 513, 40                      # deliberately unaligned row count
    tpa = rng.uniform(0, 1, (D, S)).astype(np.float32)
    clk = rng.uniform(900, 1558, (D, S)).astype(np.float32)
    edges = np.linspace(0.0, 1.1, 129)
    col = np.arange(S) // 10
    kw = dict(inv_fmax=1 / 1558.0, edges=edges, col_bucket=col,
              n_buckets=4)
    hr, sr = bucket_hist_ref(tpa, clk, **kw)
    assert hr.sum() == D * S            # every sample lands exactly once
    for use_pallas in (True, False):
        h, s = ofu_bucket_hist(jnp.asarray(tpa), jnp.asarray(clk),
                               use_pallas=use_pallas, **kw)
        np.testing.assert_array_equal(np.asarray(h), hr)
        np.testing.assert_allclose(np.asarray(s), sr, rtol=1e-5)


def test_hist_kernel_ragged_bucket_map_falls_back_to_xla():
    rng = np.random.default_rng(1)
    tpa = rng.uniform(0, 1, (64, 25)).astype(np.float32)
    clk = rng.uniform(900, 1558, (64, 25)).astype(np.float32)
    edges = np.linspace(0.0, 1.1, 129)
    col = np.repeat([0, 1, 2, 3], [3, 9, 9, 4])  # uneven bucket widths
    assert _aligned_spb(col, 4) is None
    kw = dict(inv_fmax=1 / 1558.0, edges=edges, col_bucket=col,
              n_buckets=4)
    hr, sr = bucket_hist_ref(tpa, clk, **kw)
    h, s = ofu_bucket_hist(jnp.asarray(tpa), jnp.asarray(clk),
                           use_pallas=True, **kw)   # still correct via XLA
    np.testing.assert_array_equal(np.asarray(h), hr)
    np.testing.assert_allclose(np.asarray(s), sr, rtol=1e-5)


def test_hist_kernel_rejects_bad_edges():
    tpa = np.ones((2, 2), np.float32)
    with pytest.raises(ValueError, match="strictly-increasing"):
        ofu_bucket_hist(tpa, tpa, inv_fmax=1.0,
                        edges=np.array([0.0, 1.0, 0.5]),
                        col_bucket=np.zeros(2, int), n_buckets=1)


def test_aligned_spb_detection():
    assert _aligned_spb(np.arange(30) // 10, 3) == 10
    assert _aligned_spb(np.arange(25) // 10, 3) == 10   # short last bucket
    assert _aligned_spb(np.array([0, 0, 1, 1, 1]), 2) is None
    assert _aligned_spb(np.empty(0, int), 0) is None


# ---------------------------------------------------------------------------
# dispatch + sharding knobs
# ---------------------------------------------------------------------------
def test_simulate_fleet_jax_dispatch():
    specs = [JobSpec("a", "granite-3-2b", chips=16, true_duty=0.35,
                     duration_s=600, seed=1),
             JobSpec("b", "granite-3-2b", chips=16, true_duty=0.5,
                     duration_s=900, seed=2)]
    jx = simulate_fleet(specs, max_devices=4, engine="jax")
    ref = simulate_fleet(specs, max_devices=4)           # fused NumPy
    for tj, tr in zip(jx, ref):
        assert tj.app_mfu == tr.app_mfu                  # shared profile math
        assert np.asarray(tj.grid.tpa).shape == tr.grid.tpa.shape
        assert float(tj.ofu) == pytest.approx(tr.ofu, abs=0.015)
    with pytest.raises(ValueError, match="unknown engine"):
        simulate_fleet(specs, engine="warp")


def test_simulate_job_jax_dispatch():
    spec = JobSpec("eq", "granite-3-2b", chips=32, true_duty=0.35,
                   duration_s=600, seed=5)
    jx = simulate_job(spec, max_devices=8, engine="jax")
    ref = simulate_job(spec, max_devices=8, engine="vector")
    assert jx.app_mfu == ref.app_mfu
    assert float(jx.ofu) == pytest.approx(ref.ofu, abs=0.015)
    assert len(jx.device_series) == 8


def test_mesh_knobs_and_materialize():
    slot = JobSlot(_profile(0.4), 600.0, 30.0, stragglers=np.ones(4))
    # explicit 1-device mesh: the sharding constraint is semantically a
    # no-op, so results are bit-identical to the unconstrained run
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("devices",))
    (a,) = simulate_jobs_jax([slot], seed=9, mesh=mesh, materialize=True)
    (b,) = simulate_jobs_jax([slot], seed=9, mesh=None, materialize=True)
    assert isinstance(a.tpa, np.ndarray)
    np.testing.assert_array_equal(a.tpa, b.tpa)
    np.testing.assert_array_equal(a.clock_mhz, b.clock_mhz)
    # auto mesh on a single-device host resolves to None
    if len(jax.devices()) == 1:
        assert default_mesh() is None
    with pytest.raises(ValueError, match="mesh spec"):
        simulate_jobs_jax([slot], mesh="torus")
