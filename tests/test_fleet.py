"""Fleet layer: job simulation, divergence triage (§V), regression
detection + recovery (§VI), goodput rollup (§II)."""
import numpy as np
import pytest

from repro.fleet import (JobSpec, RecoveryService, StragglerMonitor, analyze,
                         detect_regressions, rollup, simulate_job)
from repro.fleet.divergence import JobPoint
from repro.telemetry import Event


def test_healthy_job_ofu_close_to_mfu():
    t = simulate_job(JobSpec("j", "qwen3-4b", chips=256, true_duty=0.4,
                             duration_s=300))
    # paper §V-A: pure workloads agree within a few pp
    assert abs(t.ofu - t.app_mfu) < 0.05
    assert t.app_mfu == pytest.approx(t.app_mfu_exact)


def test_moe_miscalc_reproduces_3x_inflation():
    """§V-C case 1: latent projections not accounted -> ~3x MFU inflation."""
    t = simulate_job(JobSpec("j", "deepseek-v3-671b", chips=512,
                             flops_variant="naive_moe", true_duty=0.3,
                             duration_s=300))
    assert t.app_mfu / t.app_mfu_exact > 2.5
    assert t.app_mfu > 2 * t.ofu          # the 54% vs 25% signature


def test_hybrid_miscalc_inflates():
    """§V-C case 2: every layer billed as attn+MLP."""
    t = simulate_job(JobSpec("j", "zamba2-7b", chips=256,
                             flops_variant="naive_hybrid", true_duty=0.3,
                             duration_s=300))
    assert 1.3 < t.app_mfu / t.app_mfu_exact < 3.0


def test_remat_accounting_case():
    """§VI-C: hardware executes 4F with remat while the counter bills 3F."""
    t = simulate_job(JobSpec("j", "llama3.2-3b", chips=256, true_duty=0.4,
                             duration_s=300, remat=True))
    # app MFU underestimates OFU by ~F/4F = 25%
    assert t.ofu / t.app_mfu == pytest.approx(4 / 3, rel=0.12)


def test_divergence_analysis_flags_and_improves_r():
    rng = np.random.default_rng(0)
    jobs = []
    for i in range(100):
        ofu = rng.uniform(0.15, 0.5)
        jobs.append(JobPoint(f"ok{i}", "dense", 256,
                             ofu + rng.normal(0, 0.02), ofu))
    for i in range(12):
        ofu = rng.uniform(0.2, 0.3)
        jobs.append(JobPoint(f"bug{i}", "moe", 288, ofu * 2.2, ofu,
                             "naive_moe"))
    rep = analyze(jobs)
    assert len(rep.flagged) >= 10
    assert all(j.flops_variant == "naive_moe" for j in rep.flagged)
    assert rep.r_clean > rep.r_all
    assert rep.r_clean > 0.9


def test_regression_detector_finds_2p5x():
    ofu = np.concatenate([np.full(40, 0.45), np.full(40, 0.18),
                          np.full(20, 0.45)])
    regs = detect_regressions(ofu, factor_threshold=1.5)
    assert len(regs) == 1
    assert regs[0].factor == pytest.approx(2.5, rel=0.1)
    assert regs[0].end_idx is not None


def test_recovery_service_fires_once_with_cooldown():
    svc = RecoveryService(factor_threshold=2.0, sustain_samples=3,
                          cooldown_samples=50)
    actions = []
    svc.on_recover = actions.append
    for v in [0.4] * 20 + [0.1] * 20:
        svc.observe("job", v)
    assert len(actions) == 1
    assert actions[0].reason == "sustained_regression"


def test_straggler_monitor():
    tpa = np.array([0.40, 0.41, 0.39, 0.40, 0.12, 0.40])
    assert StragglerMonitor().flag(tpa) == [4]


def test_goodput_rollup_coverage():
    specs = [JobSpec(f"j{i}", "granite-3-2b", chips=64, true_duty=0.3,
                     duration_s=60,
                     flops_variant="none" if i < 8 else "exact")
             for i in range(10)]
    jobs = [simulate_job(s, max_devices=1) for s in specs]
    r = rollup(jobs)
    # the paper's §II finding: app MFU covers a minority of chip-hours,
    # OFU covers 100%
    assert r.app_mfu_coverage == pytest.approx(0.2)
    assert r.ofu_coverage == 1.0
    assert 0.2 < r.weighted_ofu < 0.4
