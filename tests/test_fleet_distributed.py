"""Distributed rollups: merge is associative/commutative, the wire format
round-trips, and tree-reducing per-host rollups is bucketwise identical to
single-process ingestion (no raw scrapes centralized)."""
import numpy as np
import pytest

from repro.fleet.distributed import host_partition, tree_reduce
from repro.fleet.jobs import JobSpec, simulate_fleet
from repro.fleet.streaming import StreamingRollup
from repro.telemetry import Event


def _random_rollup(seed, n_obs=5, bucket_s=60.0):
    rng = np.random.default_rng(seed)
    roll = StreamingRollup(bucket_s=bucket_s)
    for k in range(n_obs):
        t = rng.uniform(1, 900, size=rng.integers(3, 40))
        v = rng.uniform(0, 1.05, size=len(t))
        roll.observe(f"job{rng.integers(4)}", t, v,
                     group=("bf16", "fp8")[int(rng.integers(2))],
                     weight=float(rng.integers(1, 64)))
    return roll


def _assert_same_state(a: StreamingRollup, b: StreamingRollup,
                       atol=1e-12) -> None:
    assert set(a._hists) == set(b._hists)
    assert a.n_buckets == b.n_buckets
    for scope in a._hists:
        ha, hb = a._hists[scope], b._hists[scope]
        np.testing.assert_allclose(np.pad(ha, ((0, a.n_buckets - ha.shape[0]),
                                               (0, 0))),
                                   np.pad(hb, ((0, b.n_buckets - hb.shape[0]),
                                               (0, 0))), atol=atol)
        np.testing.assert_allclose(np.pad(a._sums[scope],
                                          (0, a.n_buckets - len(a._sums[scope]))),
                                   np.pad(b._sums[scope],
                                          (0, b.n_buckets - len(b._sums[scope]))),
                                   atol=atol)


def _merged(*rolls):
    out = StreamingRollup.from_bytes(rolls[0].to_bytes())
    for r in rolls[1:]:
        out.merge(r)
    return out


def test_merge_commutative():
    a, b = _random_rollup(1), _random_rollup(2)
    _assert_same_state(_merged(a, b), _merged(b, a))


def test_merge_associative():
    a, b, c = (_random_rollup(s) for s in (3, 4, 5))
    left = _merged(_merged(a, b), c)
    right = _merged(a, _merged(b, c))
    _assert_same_state(left, right)
    # inputs untouched by the copies
    _assert_same_state(a, _random_rollup(3))


def test_merge_rejects_mismatched_bucketing():
    a = StreamingRollup(bucket_s=60)
    with pytest.raises(ValueError, match="bucketing"):
        a.merge(StreamingRollup(bucket_s=300))
    with pytest.raises(ValueError, match="bucketing"):
        a.merge(StreamingRollup(bucket_s=60, bins=64))


def test_serialization_roundtrip():
    roll = _random_rollup(9)
    roll._job_meta["job1"] = {"chips": 64, "app_mfu": 0.4, "arch": "dense",
                              "flops_variant": "exact"}
    back = StreamingRollup.from_bytes(roll.to_bytes())
    _assert_same_state(roll, back, atol=0.0)      # wire format is lossless
    assert back._job_meta == roll._job_meta
    assert back.bucket_s == roll.bucket_s and back.bins == roll.bins
    np.testing.assert_array_equal(back.edges, roll.edges)
    f0, f1 = roll.fleet_stats(), back.fleet_stats()
    np.testing.assert_array_equal(f0.mean, f1.mean)
    np.testing.assert_array_equal(f0.percentiles[50], f1.percentiles[50])


def test_tree_reduce_matches_single_process_ingestion():
    """The acceptance property: per-host rollups reduced tree-wise give
    the same fleet dashboard as ingesting every job on one process."""
    specs = [JobSpec(f"j{i}", "granite-3-2b", chips=32,
                     true_duty=0.2 + 0.03 * (i % 8),
                     duration_s=600 + 300 * (i % 3), seed=i,
                     events=[Event(300, 600, slowdown=2.0)] if i == 5 else ())
             for i in range(12)]
    tels = simulate_fleet(specs, max_devices=4)
    single = StreamingRollup(bucket_s=120)
    for t in tels:
        single.add_job(t)
    hosts = host_partition(tels, 5)
    assert [len(h) for h in hosts] == [3, 3, 2, 2, 2]
    blobs = []
    for host_tels in hosts:
        local = StreamingRollup(bucket_s=120)
        for t in host_tels:
            local.add_job(t)
        blobs.append(local.to_bytes())            # ship kilobytes, not scrapes
    for fanin in (2, 3, 16):
        fleet = tree_reduce(blobs, fanin=fanin)
        _assert_same_state(single, fleet)
        assert sorted(fleet.jobs) == sorted(single.jobs)
        fs, ss = fleet.fleet_stats(), single.fleet_stats()
        np.testing.assert_allclose(fs.mean, ss.mean, atol=1e-12)
        for q in (10, 50, 90):
            np.testing.assert_allclose(fs.percentiles[q], ss.percentiles[q],
                                       atol=1e-12)
        # the reduced dashboard still answers per-job queries
        np.testing.assert_allclose(fleet.job_ofu("j5"), single.job_ofu("j5"),
                                   atol=1e-12)


def test_analyze_rollup_requires_app_mfu_metadata():
    from repro.fleet.divergence import analyze_rollup

    roll = _random_rollup(11)                 # observed without metadata
    with pytest.raises(ValueError, match="app-MFU metadata"):
        analyze_rollup(roll)


def test_tree_reduce_edge_cases():
    a = _random_rollup(7)
    lone = tree_reduce([a])
    _assert_same_state(a, lone)
    assert lone is not a                          # inputs never mutated
    with pytest.raises(ValueError, match="at least one"):
        tree_reduce([])
    with pytest.raises(ValueError, match="fanin"):
        tree_reduce([a], fanin=1)
    with pytest.raises(ValueError, match="n_hosts"):
        host_partition([1, 2], 0)
