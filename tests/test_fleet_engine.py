"""Vectorized fleet engine: statistical equivalence against the scalar
reference backend, streaming-rollup correctness, and the fleet-scale
performance contract (1,000 devices x 1 hour in seconds, not minutes)."""
import time

import numpy as np
import pytest

from repro.core.ofu import ofu_series
from repro.fleet import (JobSpec, StreamingRollup, simulate_devices,
                         simulate_fleet, simulate_job)
from repro.fleet.regression import detect_regressions
from repro.fleet.streaming import precision_label
from repro.telemetry import Event, SimulatedDeviceBackend, StepProfile, scrape


def _profile(duty=0.4, step_s=2.0):
    return StepProfile(mxu_time_s=duty * step_s, step_time_s=step_s)


def _scalar_grid(profile, *, duration_s, interval_s, events=(),
                 stragglers=(1.0,), seed=0):
    """Reference: one SimulatedDeviceBackend per device, polled serially."""
    rng = np.random.default_rng(seed)
    tpa, clk = [], []
    for s in stragglers:
        be = SimulatedDeviceBackend(profile, events=list(events),
                                    straggler_factor=float(s),
                                    seed=int(rng.integers(0, 2 ** 31)))
        series = scrape(be, duration_s, interval_s)
        tpa.append(series.tpa)
        clk.append(series.clock_mhz)
    return np.array(tpa), np.array(clk)


# ---------------------------------------------------------------------------
# equivalence: engine vs scalar backend (same generative model)
# ---------------------------------------------------------------------------
def test_steady_state_tpa_and_clock_statistics_match():
    prof = _profile(0.42)
    n_dev, dur, iv = 16, 1800.0, 30.0
    grid = simulate_devices(prof, duration_s=dur, interval_s=iv,
                            n_devices=n_dev, seed=0)
    s_tpa, s_clk = _scalar_grid(prof, duration_s=dur, interval_s=iv,
                                stragglers=np.ones(n_dev), seed=0)
    assert grid.tpa.shape == s_tpa.shape == (n_dev, 60)
    # duty is deterministic up to tiny jitter: means must agree tightly
    assert grid.tpa.mean() == pytest.approx(s_tpa.mean(), abs=0.005)
    # clock: same OU stationary distribution (1% of f_max in the mean,
    # generous band on the spread)
    assert grid.clock_mhz.mean() == pytest.approx(s_clk.mean(), abs=15.0)
    assert grid.clock_mhz.std() == pytest.approx(s_clk.std(), rel=0.5)
    # derived OFU agrees within a fraction of a percentage point
    assert ofu_series(grid.tpa, grid.clock_mhz).mean() == pytest.approx(
        ofu_series(s_tpa, s_clk).mean(), abs=0.005)


def test_event_injection_statistics_match():
    """The 2.5x host-sync collapse must look identical through both
    paths, window by window."""
    prof = _profile(0.45)
    ev = [Event(start_s=300, end_s=900, slowdown=2.5)]
    grid = simulate_devices(prof, duration_s=900, interval_s=30.0,
                            events=ev, n_devices=8, seed=3)
    s_tpa, _ = _scalar_grid(prof, duration_s=900, interval_s=30.0,
                            events=ev, stragglers=np.ones(8), seed=3)
    v_before, v_during = grid.tpa[:, :10].mean(), grid.tpa[:, 10:].mean()
    r_before, r_during = s_tpa[:, :10].mean(), s_tpa[:, 10:].mean()
    assert v_before == pytest.approx(r_before, abs=0.01)
    assert v_during == pytest.approx(r_during, abs=0.01)
    assert v_before / v_during == pytest.approx(2.5, rel=0.05)


def test_mxu_scale_event_and_straggler_equivalence():
    prof = _profile(0.5, step_s=1.0)
    ev = [Event(start_s=120, end_s=360, mxu_scale=0.5, kind="shrunk_gemm")]
    stragglers = np.array([1.0, 1.0, 2.0, 1.3])
    grid = simulate_devices(prof, duration_s=600, interval_s=30.0,
                            events=ev, stragglers=stragglers, seed=11)
    s_tpa, _ = _scalar_grid(prof, duration_s=600, interval_s=30.0,
                            events=ev, stragglers=stragglers, seed=11)
    # per-device means match: straggler halves duty, event halves MXU work
    np.testing.assert_allclose(grid.tpa.mean(axis=1), s_tpa.mean(axis=1),
                               atol=0.01)
    assert grid.tpa[2].mean() == pytest.approx(grid.tpa[0].mean() / 2,
                                               rel=0.05)


def test_simulate_job_engines_agree():
    spec = JobSpec("eq", "granite-3-2b", chips=32, true_duty=0.35,
                   duration_s=600, seed=5)
    vec = simulate_job(spec, max_devices=8, engine="vector")
    ref = simulate_job(spec, max_devices=8, engine="scalar")
    assert vec.app_mfu == ref.app_mfu          # profile math is shared
    assert vec.ofu == pytest.approx(ref.ofu, abs=0.01)
    assert len(vec.device_series) == len(ref.device_series) == 8
    with pytest.raises(ValueError):
        simulate_job(spec, engine="warp")


# ---------------------------------------------------------------------------
# streaming rollup: buckets, percentiles, detector feeds
# ---------------------------------------------------------------------------
def test_rollup_percentiles_and_groups():
    specs = [
        JobSpec("lo", "granite-3-2b", chips=64, true_duty=0.2,
                duration_s=1200, seed=1),
        JobSpec("hi", "granite-3-2b", chips=64, true_duty=0.5,
                duration_s=1200, seed=2),
        JobSpec("fp8", "granite-3-2b", chips=64, true_duty=0.35,
                duration_s=1200, seed=3,
                precisions={"bf16": 0.4, "fp8": 0.6}),
    ]
    roll = StreamingRollup(bucket_s=300)
    for t in simulate_fleet(specs, max_devices=4):
        roll.add_job(t)
    assert set(roll.groups) == {"bf16", "bf16+fp8"}
    assert precision_label(specs[2].precisions) == "bf16+fp8"
    f = roll.fleet_stats()
    # fleet p10 tracks the low job, p90 the high job; median in between
    assert f.percentiles[10][1] < 0.3 < f.percentiles[90][1]
    assert np.all(f.percentiles[10][:4] <= f.percentiles[50][:4] + 1e-9)
    assert np.all(f.percentiles[50][:4] <= f.percentiles[90][:4] + 1e-9)
    # per-job bucket means recover each job's true efficiency band
    assert roll.job_ofu("lo").mean() == pytest.approx(0.2, abs=0.03)
    assert roll.job_ofu("hi").mean() == pytest.approx(0.48, abs=0.04)
    # chip-weighting: every job contributes chips x samples of weight
    assert np.nansum(f.weight) == pytest.approx(3 * 64 * 40)


def test_rollup_feeds_regression_detector_at_fleet_scale():
    """Paper SecVI-A at scale: a 512-chip job collapses 2.5x mid-run; the
    bucketed rollup series must trip the existing detector."""
    spec = JobSpec("gloo", "granite-3-2b", chips=512, true_duty=0.45,
                   duration_s=7200, seed=7,
                   events=[Event(start_s=3600, end_s=7200, slowdown=2.5)])
    (tel,) = simulate_fleet([spec], max_devices=64)
    roll = StreamingRollup(bucket_s=120)
    roll.add_job(tel)
    series = roll.job_ofu("gloo")
    assert len(series) >= 60
    assert not np.isnan(series).any()
    regs = detect_regressions(series, factor_threshold=1.5)
    assert len(regs) == 1
    # TPA collapses exactly 2.5x but the idler clock throttles less, so
    # the OFU factor lands a bit under 2.5; the detector also dilutes the
    # reference through its drift tracker — accept the documented band
    assert series[:29].mean() / series[32:].mean() == pytest.approx(
        2.42, rel=0.05)
    assert 2.0 < regs[0].factor < 2.6
    # divergence bridge: the same rollup yields analyzable job points
    pts = roll.to_job_points()
    assert len(pts) == 1 and pts[0].job_id == "gloo"
    assert pts[0].ofu == pytest.approx(tel.ofu, abs=0.02)


def test_rollup_forward_fill_and_empty_scopes():
    roll = StreamingRollup(bucket_s=10)
    roll.observe("a", np.array([5.0, 25.0]), np.array([0.4, 0.2]),
                 group="bf16")
    filled = roll.job_ofu("a")
    assert filled == pytest.approx([0.4, 0.4, 0.2])   # gap forward-filled
    raw = roll.job_stats("a", qs=()).mean
    assert np.isnan(raw[1]) and raw[0] == pytest.approx(0.4)
    assert len(roll.job_stats("missing").mean) == 0


# ---------------------------------------------------------------------------
# the fleet-scale performance contract (acceptance criterion)
# ---------------------------------------------------------------------------
def test_thousand_devices_one_hour_under_ten_seconds():
    spec = JobSpec("fleet", "granite-3-2b", chips=1000, true_duty=0.35,
                   duration_s=3600, scrape_interval_s=30, seed=0)
    t0 = time.perf_counter()
    (tel,) = simulate_fleet([spec], max_devices=1000)
    elapsed = time.perf_counter() - t0
    assert elapsed < 10.0, f"fleet sim took {elapsed:.1f}s"
    assert len(tel.device_series) == 1000
    assert len(tel.device_series[0].tpa) == 120
    assert tel.ofu == pytest.approx(0.35 * 0.96, abs=0.03)
