"""Vectorized fleet engine: statistical equivalence against the scalar
reference backend (and of the fused multi-job grid against the per-job
loop), streaming-rollup correctness, and the fleet-scale performance
contract (1,000 devices x 1 hour in seconds, not minutes)."""
import time

import numpy as np
import pytest

from repro.core.ofu import hist_percentile, hist_percentile_grid, ofu_series
from repro.core.peaks import TPU_V6E_LIKE
from repro.fleet import (JobSpec, StreamingRollup, simulate_devices,
                         simulate_fleet, simulate_job)
from repro.fleet.engine import EngineParams, JobSlot, simulate_jobs_fused
from repro.fleet.regression import detect_regressions
from repro.fleet.streaming import precision_label
from repro.telemetry import Event, SimulatedDeviceBackend, StepProfile, scrape


def _profile(duty=0.4, step_s=2.0):
    return StepProfile(mxu_time_s=duty * step_s, step_time_s=step_s)


def _scalar_grid(profile, *, duration_s, interval_s, events=(),
                 stragglers=(1.0,), seed=0):
    """Reference: one SimulatedDeviceBackend per device, polled serially."""
    rng = np.random.default_rng(seed)
    tpa, clk = [], []
    for s in stragglers:
        be = SimulatedDeviceBackend(profile, events=list(events),
                                    straggler_factor=float(s),
                                    seed=int(rng.integers(0, 2 ** 31)))
        series = scrape(be, duration_s, interval_s)
        tpa.append(series.tpa)
        clk.append(series.clock_mhz)
    return np.array(tpa), np.array(clk)


# ---------------------------------------------------------------------------
# equivalence: engine vs scalar backend (same generative model)
# ---------------------------------------------------------------------------
def test_steady_state_tpa_and_clock_statistics_match():
    prof = _profile(0.42)
    n_dev, dur, iv = 16, 1800.0, 30.0
    grid = simulate_devices(prof, duration_s=dur, interval_s=iv,
                            n_devices=n_dev, seed=0)
    s_tpa, s_clk = _scalar_grid(prof, duration_s=dur, interval_s=iv,
                                stragglers=np.ones(n_dev), seed=0)
    assert grid.tpa.shape == s_tpa.shape == (n_dev, 60)
    # duty is deterministic up to tiny jitter: means must agree tightly
    assert grid.tpa.mean() == pytest.approx(s_tpa.mean(), abs=0.005)
    # clock: same OU stationary distribution (1% of f_max in the mean,
    # generous band on the spread)
    assert grid.clock_mhz.mean() == pytest.approx(s_clk.mean(), abs=15.0)
    assert grid.clock_mhz.std() == pytest.approx(s_clk.std(), rel=0.5)
    # derived OFU agrees within a fraction of a percentage point
    assert ofu_series(grid.tpa, grid.clock_mhz).mean() == pytest.approx(
        ofu_series(s_tpa, s_clk).mean(), abs=0.005)


def test_event_injection_statistics_match():
    """The 2.5x host-sync collapse must look identical through both
    paths, window by window."""
    prof = _profile(0.45)
    ev = [Event(start_s=300, end_s=900, slowdown=2.5)]
    grid = simulate_devices(prof, duration_s=900, interval_s=30.0,
                            events=ev, n_devices=8, seed=3)
    s_tpa, _ = _scalar_grid(prof, duration_s=900, interval_s=30.0,
                            events=ev, stragglers=np.ones(8), seed=3)
    v_before, v_during = grid.tpa[:, :10].mean(), grid.tpa[:, 10:].mean()
    r_before, r_during = s_tpa[:, :10].mean(), s_tpa[:, 10:].mean()
    assert v_before == pytest.approx(r_before, abs=0.01)
    assert v_during == pytest.approx(r_during, abs=0.01)
    assert v_before / v_during == pytest.approx(2.5, rel=0.05)


def test_mxu_scale_event_and_straggler_equivalence():
    prof = _profile(0.5, step_s=1.0)
    ev = [Event(start_s=120, end_s=360, mxu_scale=0.5, kind="shrunk_gemm")]
    stragglers = np.array([1.0, 1.0, 2.0, 1.3])
    grid = simulate_devices(prof, duration_s=600, interval_s=30.0,
                            events=ev, stragglers=stragglers, seed=11)
    s_tpa, _ = _scalar_grid(prof, duration_s=600, interval_s=30.0,
                            events=ev, stragglers=stragglers, seed=11)
    # per-device means match: straggler halves duty, event halves MXU work
    np.testing.assert_allclose(grid.tpa.mean(axis=1), s_tpa.mean(axis=1),
                               atol=0.01)
    assert grid.tpa[2].mean() == pytest.approx(grid.tpa[0].mean() / 2,
                                               rel=0.05)


def test_simulate_job_engines_agree():
    spec = JobSpec("eq", "granite-3-2b", chips=32, true_duty=0.35,
                   duration_s=600, seed=5)
    vec = simulate_job(spec, max_devices=8, engine="vector")
    ref = simulate_job(spec, max_devices=8, engine="scalar")
    assert vec.app_mfu == ref.app_mfu          # profile math is shared
    assert vec.ofu == pytest.approx(ref.ofu, abs=0.01)
    assert len(vec.device_series) == len(ref.device_series) == 8
    with pytest.raises(ValueError):
        simulate_job(spec, engine="warp")


# ---------------------------------------------------------------------------
# fused multi-job grid: one padded pass over the whole fleet
# ---------------------------------------------------------------------------
def _sweep_specs(n=24):
    """Ragged sweep: mixed durations/duties, an evented job, a straggler."""
    return [JobSpec(f"j{i}", "granite-3-2b", chips=16,
                    true_duty=0.2 + 0.03 * (i % 8),
                    duration_s=300.0 + 150.0 * (i % 4), seed=i,
                    events=[Event(120, 360, slowdown=2.5)] if i % 7 == 0
                    else (),
                    straggler_sigma=0.2 if i % 5 == 0 else 0.0)
            for i in range(n)]


def test_fused_fleet_matches_per_job_loop():
    """Same-seed tolerance test (acceptance): the fused default must be
    statistically indistinguishable from the per-job engine loop."""
    specs = _sweep_specs()
    fused = simulate_fleet(specs, max_devices=4)          # default = fused
    perjob = simulate_fleet(specs, max_devices=4, engine="vector")
    for f, p in zip(fused, perjob):
        assert f.app_mfu == p.app_mfu                     # shared profile math
        assert f.ofu == pytest.approx(p.ofu, abs=0.01)
        assert len(f.device_series) == len(p.device_series)
        for sf, sp in zip(f.device_series, p.device_series):
            assert sf.tpa.shape == sp.tpa.shape           # ragged S preserved
            assert sf.interval_s == sp.interval_s


def test_fused_is_the_default_and_deterministic():
    specs = _sweep_specs(6)
    a = simulate_fleet(specs)
    b = simulate_fleet(specs, engine="fused")
    for ta, tb in zip(a, b):
        for sa, sb in zip(ta.device_series, tb.device_series):
            np.testing.assert_array_equal(sa.tpa, sb.tpa)
            np.testing.assert_array_equal(sa.clock_mhz, sb.clock_mhz)


def test_fused_event_collapse_window_by_window():
    """The 2.5x host-sync signature must appear in the fused grid exactly
    where the per-job path puts it."""
    ev = [Event(start_s=300, end_s=900, slowdown=2.5)]
    specs = [JobSpec("quiet", "granite-3-2b", chips=8, true_duty=0.4,
                     duration_s=900, seed=1),
             JobSpec("gloo", "granite-3-2b", chips=8, true_duty=0.45,
                     duration_s=900, seed=2, events=ev)]
    quiet, gloo = simulate_fleet(specs, max_devices=8)
    g = np.stack([s.tpa for s in gloo.device_series])
    assert g[:, :10].mean() / g[:, 10:].mean() == pytest.approx(2.5,
                                                                rel=0.05)
    q = np.stack([s.tpa for s in quiet.device_series])
    assert q[:, :10].mean() == pytest.approx(q[:, 10:].mean(), abs=0.01)


def test_fused_groups_heterogeneous_intervals_and_chips():
    """Jobs that cannot share a grid (different scrape interval or clock
    domain) land in separate fused groups but one call still serves all."""
    slots = [JobSlot(StepProfile(0.8, 2.0), 600, 30.0,
                     stragglers=np.ones(3)),
             JobSlot(StepProfile(0.8, 2.0), 600, 15.0,
                     stragglers=np.ones(2)),
             JobSlot(StepProfile(0.9, 2.0), 450, 30.0,
                     chip=TPU_V6E_LIKE, stragglers=np.ones(4)),
             JobSlot(StepProfile(0.5, 2.0), 10.0, 30.0)]   # S == 0
    grids = simulate_jobs_fused(slots, seed=0)
    assert [g.tpa.shape for g in grids] == [(3, 20), (2, 40), (4, 15),
                                            (1, 0)]
    assert grids[1].interval_s == 15.0
    # each job's clock lives in its own chip's domain
    assert grids[0].clock_mhz.max() <= 1500.0
    assert grids[2].clock_mhz.mean() > 1500.0


def test_fused_straggler_scaling():
    slot = JobSlot(StepProfile(1.0, 2.0), 600, 30.0,
                   stragglers=np.array([1.0, 2.0]))
    (grid,) = simulate_jobs_fused([slot], seed=4)
    assert grid.tpa[1].mean() == pytest.approx(grid.tpa[0].mean() / 2,
                                               rel=0.05)


def test_simulate_job_accepts_fused_and_profile_cache_not_chip_aliased():
    import dataclasses

    spec = JobSpec("one", "granite-3-2b", chips=8, true_duty=0.35,
                   duration_s=300, seed=3)
    fused = simulate_job(spec, max_devices=4, engine="fused")
    vec = simulate_job(spec, max_devices=4, engine="vector")
    np.testing.assert_array_equal(fused.grid.tpa, vec.grid.tpa)
    # a customized chip must not alias the stock entry in the profile
    # cache (same .name, different physics)
    slow = dataclasses.replace(spec.chip, f_max_mhz=spec.chip.f_max_mhz / 2)
    halved = simulate_job(dataclasses.replace(spec, chip=slow),
                          max_devices=4)
    assert halved.step_time_s == pytest.approx(vec.step_time_s * 2)


def test_engine_params_default_not_shared():
    """Regression guard for the mutable-default bug: each call constructs
    its own EngineParams, and an explicit params object is honored."""
    import inspect
    sig = inspect.signature(simulate_devices)
    assert sig.parameters["params"].default is None
    grid = simulate_devices(StepProfile(0.8, 2.0), duration_s=300,
                            interval_s=30.0, n_devices=2, seed=0,
                            params=EngineParams(n_sub_max=8))
    assert grid.tpa.shape == (2, 10)


def test_simulate_devices_rejects_device_count_mismatch():
    """Regression (ISSUE 6): n_devices=1 alongside 5 stragglers used to
    silently simulate 5 devices; conflicting counts now raise, and each
    argument alone still infers the other."""
    prof = StepProfile(0.8, 2.0)
    with pytest.raises(ValueError,
                       match=r"n_devices=1 conflicts .*stragglers\)=5"):
        simulate_devices(prof, duration_s=300, interval_s=30.0,
                         n_devices=1, stragglers=np.ones(5))
    grid = simulate_devices(prof, duration_s=300, interval_s=30.0,
                            stragglers=np.full(5, 1.2), seed=0)
    assert grid.tpa.shape == (5, 10)        # inferred from stragglers
    grid = simulate_devices(prof, duration_s=300, interval_s=30.0,
                            n_devices=3, seed=0)
    assert grid.tpa.shape == (3, 10)        # unit stragglers materialized
    grid = simulate_devices(prof, duration_s=300, interval_s=30.0,
                            n_devices=2, stragglers=np.ones(2), seed=0)
    assert grid.tpa.shape == (2, 10)        # agreeing counts still fine


# ---------------------------------------------------------------------------
# streaming rollup: buckets, percentiles, detector feeds
# ---------------------------------------------------------------------------
def test_hist_percentile_grid_matches_scalar_readout():
    """Satellite: the vectorized per-bucket percentile readout must agree
    with the scalar hist_percentile loop bucket for bucket."""
    rng = np.random.default_rng(0)
    edges = np.linspace(0.0, 1.1, 129)
    h = rng.integers(0, 20, size=(12, 128)).astype(float) \
        * rng.uniform(0.5, 64, size=(12, 1))
    h[3] = 0.0                                   # an empty bucket row
    h[7, :64] = 0.0
    qs = (0, 10, 50, 90, 100)
    grid = hist_percentile_grid(edges, h, qs)
    assert grid.shape == (5, 12)
    for k, q in enumerate(qs):
        ref = [hist_percentile(edges, h[b], q) for b in range(12)]
        np.testing.assert_allclose(grid[k], ref, atol=1e-12, equal_nan=True)
    assert hist_percentile_grid(edges, np.empty((0, 128)), qs).shape == (5, 0)


def test_rollup_percentiles_and_groups():
    specs = [
        JobSpec("lo", "granite-3-2b", chips=64, true_duty=0.2,
                duration_s=1200, seed=1),
        JobSpec("hi", "granite-3-2b", chips=64, true_duty=0.5,
                duration_s=1200, seed=2),
        JobSpec("fp8", "granite-3-2b", chips=64, true_duty=0.35,
                duration_s=1200, seed=3,
                precisions={"bf16": 0.4, "fp8": 0.6}),
    ]
    roll = StreamingRollup(bucket_s=300)
    for t in simulate_fleet(specs, max_devices=4):
        roll.add_job(t)
    assert set(roll.groups) == {"bf16", "bf16+fp8"}
    assert precision_label(specs[2].precisions) == "bf16+fp8"
    f = roll.fleet_stats()
    # fleet p10 tracks the low job, p90 the high job; median in between
    assert f.percentiles[10][1] < 0.3 < f.percentiles[90][1]
    assert np.all(f.percentiles[10][:4] <= f.percentiles[50][:4] + 1e-9)
    assert np.all(f.percentiles[50][:4] <= f.percentiles[90][:4] + 1e-9)
    # per-job bucket means recover each job's true efficiency band
    assert roll.job_ofu("lo").mean() == pytest.approx(0.2, abs=0.03)
    assert roll.job_ofu("hi").mean() == pytest.approx(0.48, abs=0.04)
    # chip-weighting: every job contributes chips x samples of weight
    assert np.nansum(f.weight) == pytest.approx(3 * 64 * 40)


def test_rollup_feeds_regression_detector_at_fleet_scale():
    """Paper SecVI-A at scale: a 512-chip job collapses 2.5x mid-run; the
    bucketed rollup series must trip the existing detector."""
    spec = JobSpec("gloo", "granite-3-2b", chips=512, true_duty=0.45,
                   duration_s=7200, seed=7,
                   events=[Event(start_s=3600, end_s=7200, slowdown=2.5)])
    (tel,) = simulate_fleet([spec], max_devices=64)
    roll = StreamingRollup(bucket_s=120)
    roll.add_job(tel)
    series = roll.job_ofu("gloo")
    assert len(series) >= 60
    assert not np.isnan(series).any()
    regs = detect_regressions(series, factor_threshold=1.5)
    assert len(regs) == 1
    # TPA collapses exactly 2.5x but the idler clock throttles less, so
    # the OFU factor lands a bit under 2.5; the detector also dilutes the
    # reference through its drift tracker — accept the documented band
    assert series[:29].mean() / series[32:].mean() == pytest.approx(
        2.42, rel=0.05)
    assert 2.0 < regs[0].factor < 2.6
    # divergence bridge: the same rollup yields analyzable job points
    pts = roll.to_job_points()
    assert len(pts) == 1 and pts[0].job_id == "gloo"
    assert pts[0].ofu == pytest.approx(tel.ofu, abs=0.02)


def test_rollup_forward_fill_and_empty_scopes():
    roll = StreamingRollup(bucket_s=10)
    roll.observe("a", np.array([5.0, 25.0]), np.array([0.4, 0.2]),
                 group="bf16")
    filled = roll.job_ofu("a")
    assert filled == pytest.approx([0.4, 0.4, 0.2])   # gap forward-filled
    raw = roll.job_stats("a", qs=()).mean
    assert np.isnan(raw[1]) and raw[0] == pytest.approx(0.4)
    assert len(roll.job_stats("missing").mean) == 0


# ---------------------------------------------------------------------------
# the fleet-scale performance contract (acceptance criterion)
# ---------------------------------------------------------------------------
def test_thousand_devices_one_hour_under_ten_seconds():
    spec = JobSpec("fleet", "granite-3-2b", chips=1000, true_duty=0.35,
                   duration_s=3600, scrape_interval_s=30, seed=0)
    t0 = time.perf_counter()
    (tel,) = simulate_fleet([spec], max_devices=1000)
    elapsed = time.perf_counter() - t0
    assert elapsed < 10.0, f"fleet sim took {elapsed:.1f}s"
    assert len(tel.device_series) == 1000
    assert len(tel.device_series[0].tpa) == 120
    assert tel.ofu == pytest.approx(0.35 * 0.96, abs=0.03)
