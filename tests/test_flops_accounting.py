"""FLOPs accounting: published param counts, buggy-variant signatures,
remat multiplier, and cross-validation against compiled HLO."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import SHAPES, get_config, make_inputs
from repro.configs.base import ShapeSpec
from repro.flops import (decode_step_flops, forward_flops, model_flops_6nd,
                         param_count_analytic, step_flops, train_step_flops)

PUBLISHED = {  # total params, tolerance
    "deepseek-moe-16b": (16.4e9, 0.05),
    "deepseek-v3-671b": (671e9, 0.01),
    "qwen3-4b": (4.0e9, 0.05),
    "nemotron-4-340b": (340e9, 0.03),
    "granite-3-2b": (2.5e9, 0.05),
    "llama3.2-3b": (3.2e9, 0.05),
    "mamba2-780m": (0.78e9, 0.05),
}


@pytest.mark.parametrize("arch,expect", list(PUBLISHED.items()))
def test_param_counts_match_published(arch, expect):
    total, tol = expect
    pc = param_count_analytic(get_config(arch))
    assert pc == pytest.approx(total, rel=tol)


def test_active_params_moe():
    cfg = get_config("deepseek-v3-671b")
    active = param_count_analytic(cfg, active_only=True)
    assert active == pytest.approx(37e9, rel=0.05)  # published 37B active


def test_train_is_3f_and_remat_is_4f():
    cfg = get_config("granite-3-2b")
    shape = SHAPES["train_4k"]
    fwd = forward_flops(cfg, shape).total_mxu
    assert train_step_flops(cfg, shape, executed=False).total_mxu \
        == pytest.approx(3 * fwd)
    assert train_step_flops(cfg, shape, executed=True,
                            remat=True).total_mxu \
        == pytest.approx(4 * forward_flops(cfg, shape,
                                           executed=True).total_mxu)


def test_naive_moe_variant_inflates_3x():
    cfg = get_config("deepseek-v3-671b")
    shape = SHAPES["train_4k"]
    exact = step_flops(cfg, shape).total_mxu
    naive = step_flops(cfg, shape, variant="naive_moe").total_mxu
    assert 2.5 < naive / exact < 4.5  # paper: ~3x


def test_naive_hybrid_variant_inflates():
    cfg = get_config("zamba2-7b")
    shape = SHAPES["train_4k"]
    exact = step_flops(cfg, shape).total_mxu
    naive = step_flops(cfg, shape, variant="naive_hybrid").total_mxu
    assert 1.3 < naive / exact < 2.5  # paper: 24.51/15.56 = 1.57x


def test_ssm_vpu_fraction_material():
    """DESIGN.md §2: non-MXU undercounting is material for SSM archs."""
    bd_ssm = forward_flops(get_config("mamba2-780m"), SHAPES["train_4k"])
    bd_dense = forward_flops(get_config("granite-3-2b"), SHAPES["train_4k"])
    frac_ssm = bd_ssm.total_vpu / bd_ssm.total
    frac_dense = bd_dense.total_vpu / bd_dense.total
    assert frac_ssm > 3 * frac_dense


def test_decode_flops_scale_with_context():
    cfg = get_config("qwen3-4b")
    a = decode_step_flops(cfg, ShapeSpec("d", 8192, 128, "decode")).total_mxu
    b = decode_step_flops(cfg, ShapeSpec("d", 32768, 128, "decode")).total_mxu
    assert b > a  # KV reads grow with context
    assert b < 4 * a  # ...but weights dominate at these sizes


def test_6nd_convention():
    cfg = get_config("llama3.2-3b")
    shape = SHAPES["train_4k"]
    got = model_flops_6nd(cfg, shape)
    assert got == pytest.approx(
        6 * param_count_analytic(cfg) * shape.global_batch * shape.seq_len)


def test_analytic_close_to_compiled_hlo():
    """Cross-validate the analytic counter against XLA cost analysis on a
    smoke config (single layer, unscanned ops dominate)."""
    cfg = get_config("granite-3-2b").smoke()
    shape = ShapeSpec("t", 64, 2, "train")
    batch = make_inputs(cfg, shape)
    from repro.models import forward, init_params
    params = init_params(cfg, jax.random.key(0))
    comp = jax.jit(lambda p, b: forward(cfg, p, b)).lower(params,
                                                          batch).compile()
    ca = comp.cost_analysis()
    if isinstance(ca, list):   # older jax: one dict per device
        ca = ca[0] if ca else {}
    hlo_flops = ca.get("flops", 0.0)
    # scan bodies are counted once by XLA; smoke cfg has 2 layers -> correct
    # by adding one extra body worth. We only check the right order.
    analytic = forward_flops(cfg, shape).total_mxu
    assert 0.2 < hlo_flops / analytic < 5.0
