"""Property checks for the FLOPs accounting layer (`flops.accounting`).

Three families of invariants:

  * `Breakdown` algebra — `merged` is a commutative monoid action on the
    category dicts (totals add, no category lost), `scaled` is linear
    and composes multiplicatively;
  * the train/forward convention — with remat off, a train step is
    EXACTLY 3x the forward pass (F + 2F backward), category by
    category, for every family in the config registry; remat + executed
    adds the recompute F (4x);
  * the §V-C miscalculation fixtures — the naive counters' inflation
    ratios on the exact archs the correlation fixture
    (`repro.fleet.table3`) and the scenario library replay are PINNED,
    so a counting change that silently moves the paper's ~3x MoE /
    ~1.8x hybrid story fails here first.
"""
import pytest
from _propcheck import given, settings, st

from repro.configs.base import SHAPES, get_config
from repro.flops.accounting import (Breakdown, forward_flops, step_flops,
                                    train_step_flops)

ARCHS = ["qwen3-4b", "granite-3-2b", "llama3.2-3b", "mamba2-780m",
         "phi-3-vision-4.2b", "deepseek-moe-16b", "deepseek-v3-671b",
         "zamba2-7b"]

_cat = st.sampled_from(["attn_proj", "attn_score", "mlp", "experts",
                        "router", "ssd", "lm_head", "norms"])
_flops = st.floats(0.0, 1e15)


def _breakdown(rng_draws):
    """Build a Breakdown from drawn (cat, flops, unit) triples."""
    bd = Breakdown()
    for cat, fl, is_mxu in rng_draws:
        bd.add(cat, fl, "mxu" if is_mxu else "vpu")
    return bd


_triples = st.lists(st.tuples(_cat, _flops, st.booleans()), min_size=0,
                    max_size=6)


# ---------------------------------------------------------------------------
# Breakdown algebra
# ---------------------------------------------------------------------------
@given(_triples, _triples)
@settings(max_examples=50, deadline=None)
def test_merged_adds_totals_and_preserves_categories(a_draws, b_draws):
    a, b = _breakdown(a_draws), _breakdown(b_draws)
    m = a.merged(b)
    assert m.total_mxu == pytest.approx(a.total_mxu + b.total_mxu)
    assert m.total_vpu == pytest.approx(a.total_vpu + b.total_vpu)
    assert m.total == pytest.approx(a.total + b.total)
    assert set(m.mxu) == set(a.mxu) | set(b.mxu)
    assert set(m.vpu) == set(a.vpu) | set(b.vpu)
    # commutative, and the operands are untouched (merged copies)
    m2 = b.merged(a)
    assert m2.mxu == pytest.approx(m.mxu) and m2.vpu == pytest.approx(m.vpu)
    assert a.mxu == _breakdown(a_draws).mxu


@given(_triples, st.floats(0.0, 8.0), st.floats(0.0, 8.0))
@settings(max_examples=50, deadline=None)
def test_scaled_is_linear_and_composes(draws, f, g):
    bd = _breakdown(draws)
    s = bd.scaled(f)
    assert s.total_mxu == pytest.approx(f * bd.total_mxu)
    assert s.total_vpu == pytest.approx(f * bd.total_vpu)
    assert set(s.mxu) == set(bd.mxu) and set(s.vpu) == set(bd.vpu)
    # identity and composition
    one = bd.scaled(1.0)
    assert one.mxu == pytest.approx(bd.mxu) and one.vpu == pytest.approx(bd.vpu)
    ab = bd.scaled(f).scaled(g)
    ba = bd.scaled(f * g)
    assert ab.total == pytest.approx(ba.total)


# ---------------------------------------------------------------------------
# train = 3 x forward (the PaLM/Megatron convention), 4 x when remat bills
# ---------------------------------------------------------------------------
@given(st.sampled_from(ARCHS))
@settings(max_examples=20, deadline=None)
def test_train_is_exactly_3x_forward_without_remat(arch):
    cfg = get_config(arch)
    shape = SHAPES["train_4k"]
    fwd = forward_flops(cfg, shape, variant="exact")
    train = train_step_flops(cfg, shape, variant="exact", remat=False)
    assert set(train.mxu) == set(fwd.mxu)
    for cat, v in fwd.mxu.items():
        assert train.mxu[cat] == pytest.approx(3.0 * v, rel=1e-12), cat
    assert train.total_vpu == pytest.approx(3.0 * fwd.total_vpu, rel=1e-12)


@given(st.sampled_from(ARCHS))
@settings(max_examples=20, deadline=None)
def test_remat_bills_4x_executed_but_3x_reported(arch):
    """§VI-C: hardware executes F+2F+F(recompute); the app-side counter
    (executed=False) keeps billing 3F whether remat is on or not."""
    cfg = get_config(arch)
    shape = SHAPES["train_4k"]
    fwd_exec = forward_flops(cfg, shape, variant="exact", executed=True)
    hw = train_step_flops(cfg, shape, variant="exact", executed=True,
                          remat=True)
    assert hw.total_mxu == pytest.approx(4.0 * fwd_exec.total_mxu, rel=1e-12)
    app = train_step_flops(cfg, shape, variant="exact", executed=False,
                           remat=True)
    fwd_app = forward_flops(cfg, shape, variant="exact", executed=False)
    assert app.total_mxu == pytest.approx(3.0 * fwd_app.total_mxu, rel=1e-12)


@given(st.sampled_from(["qwen3-4b", "granite-3-2b", "llama3.2-3b",
                        "mamba2-780m", "phi-3-vision-4.2b"]),
       st.sampled_from(["naive_moe", "naive_hybrid"]))
@settings(max_examples=20, deadline=None)
def test_naive_variants_are_noops_on_unaffected_families(arch, variant):
    """The buggy counters only touch MoE/MLA/hybrid layer math — a dense
    or pure-SSM model's books are identical under every variant."""
    cfg = get_config(arch)
    if cfg.family in ("moe", "mla_moe", "hybrid"):
        return                   # affected family: covered below
    shape = SHAPES["train_4k"]
    exact = step_flops(cfg, shape, variant="exact")
    naive = step_flops(cfg, shape, variant=variant)
    assert naive.total_mxu == pytest.approx(exact.total_mxu, rel=1e-12)


# ---------------------------------------------------------------------------
# §V-C inflation ratios, pinned on the fixture archs
# ---------------------------------------------------------------------------
def test_naive_moe_inflation_pinned_deepseek():
    """Case 1: dense-billed sparse experts + unaccounted MLA latents on
    the 671B MoE — the fixture's ~3x story.  Pinned so counting changes
    move this number only deliberately."""
    cfg = get_config("deepseek-v3-671b")
    shape = SHAPES["train_4k"]
    exact = step_flops(cfg, shape, variant="exact").total_mxu
    naive = step_flops(cfg, shape, variant="naive_moe").total_mxu
    assert naive / exact == pytest.approx(3.1859, rel=1e-3)


def test_naive_hybrid_inflation_pinned_zamba():
    """Case 2: every Mamba block billed as attention + dense MLP on the
    7B hybrid — the fixture's ~1.8x story."""
    cfg = get_config("zamba2-7b")
    shape = SHAPES["train_4k"]
    exact = step_flops(cfg, shape, variant="exact").total_mxu
    naive = step_flops(cfg, shape, variant="naive_hybrid").total_mxu
    assert naive / exact == pytest.approx(1.8369, rel=1e-3)


def test_inflation_survives_the_train_multiplier():
    """The miscalculation ratio cancels the 3x train multiplier: forward
    and train inflate by the same factor at a fixed shape (scaled()
    linearity end-to-end through the real counters), which is why the
    correlation detector's ratio threshold needs no train/infer split.
    It is NOT sequence-invariant (at 32k the quadratic attention term
    dilutes the expert inflation) — pin that too."""
    cfg = get_config("deepseek-v3-671b")
    shape = SHAPES["train_4k"]
    fwd_ratio = (forward_flops(cfg, shape, variant="naive_moe").total_mxu
                 / forward_flops(cfg, shape, variant="exact").total_mxu)
    train_ratio = (step_flops(cfg, shape, variant="naive_moe").total_mxu
                   / step_flops(cfg, shape, variant="exact").total_mxu)
    assert train_ratio == pytest.approx(fwd_ratio, rel=1e-12)
    long = SHAPES["prefill_32k"]
    long_ratio = (step_flops(cfg, long, variant="naive_moe").total_mxu
                  / step_flops(cfg, long, variant="exact").total_mxu)
    assert long_ratio == pytest.approx(2.3030, rel=1e-3)
