"""`fleet.goodput` coverage (ISSUE 8 satellite): the streaming
`from_rollup` view is MERGE-CONSISTENT (goodput over a tree-reduced
fleet of per-host rollups equals goodput over single-process ingest —
property-tested), empty/all-idle rollups degrade to zeros rather than
NaN, and `scan_goodput` finds sustained fleet-wide OFU drops while
staying silent on healthy fleets.
"""
import numpy as np
import pytest

from _propcheck import given, settings, st
from repro.core.peaks import DEFAULT_CHIP
from repro.fleet.distributed import host_partition, tree_reduce
from repro.fleet.goodput import (FleetRollup, from_rollup,
                                 goodput_from_rollup, rollup, scan_goodput)
from repro.fleet.streaming import StreamingRollup, WindowedRollup
from repro.telemetry.scrape import DeviceGrid

F_MAX = DEFAULT_CHIP.f_max_mhz


def _grid(tpa_rows, interval=60.0, t0=0.0, clock=None):
    tpa = np.asarray(tpa_rows, float)
    clk = np.full_like(tpa, F_MAX) if clock is None \
        else np.asarray(clock, float)
    return DeviceGrid(interval, tpa, clk, t0_s=t0)


# ---------------------------------------------------------------------------
# merge consistency: tree_reduce of per-host rollups == one-shot ingest
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(st.integers(1, 3), st.integers(2, 5), st.integers(2, 12),
       st.integers(0, 10 ** 6), st.booleans())
def test_from_rollup_is_merge_consistent(n_jobs, n_hosts, n_samples, seed,
                                         windowed):
    rng = np.random.default_rng(seed)
    make = (lambda: WindowedRollup(60.0, retain=8, bins=32)) if windowed \
        else (lambda: StreamingRollup(60.0, bins=32))
    single = make()
    hosts = [make() for _ in range(n_hosts)]
    for j in range(n_jobs):
        n_dev = n_hosts * int(rng.integers(1, 3))
        tpa = rng.uniform(0.0, 1.0, size=(n_dev, n_samples))
        clock = rng.uniform(0.6, 1.0, size=(n_dev, n_samples)) * F_MAX
        grid = _grid(tpa, clock=clock)
        app_mfu = float(rng.uniform(0.1, 0.5)) if j % 2 == 0 else None
        kw = dict(app_mfu=app_mfu, arch="a", group="bf16")
        chips = 8 * (j + 1)
        single.add_grid(f"job-{j}", grid, chips=chips, **kw)
        # shard the DEVICE rows over hosts, as a per-host collector
        # would; each host claims its share of the job's chip footprint
        # (per-sample weight chips/n_dev on both sides)
        per_dev = chips / n_dev
        for h, rows in enumerate(host_partition(list(range(n_dev)),
                                                n_hosts)):
            if not rows:
                continue
            sub = _grid(tpa[rows], clock=clock[rows])
            hosts[h].add_grid(f"job-{j}", sub,
                              chips=per_dev * len(rows), **kw)
    reduced = tree_reduce([h.to_bytes() for h in hosts])
    a = from_rollup(single)
    b = from_rollup(reduced)
    assert a.chip_hours == pytest.approx(b.chip_hours, rel=1e-9)
    assert a.weighted_ofu == pytest.approx(b.weighted_ofu, rel=1e-6)
    assert a.app_mfu_coverage == pytest.approx(b.app_mfu_coverage,
                                               rel=1e-9)
    assert [j for j, _ in a.waste_ranking] \
        == [j for j, _ in b.waste_ranking]
    for (_, wa), (_, wb) in zip(a.waste_ranking, b.waste_ranking):
        assert wa == pytest.approx(wb, rel=1e-6, abs=1e-9)


# ---------------------------------------------------------------------------
# degenerate inputs
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("make", [
    lambda: StreamingRollup(60.0), lambda: WindowedRollup(60.0, retain=4)])
def test_from_rollup_empty_is_zero_not_nan(make):
    fr = from_rollup(make())
    assert fr.chip_hours == 0.0
    assert fr.weighted_ofu == 0.0 and np.isfinite(fr.weighted_ofu)
    assert fr.app_mfu_coverage == 0.0
    assert fr.ofu_coverage == 1.0 and fr.waste_ranking == []


def test_from_rollup_all_idle_buckets():
    roll = WindowedRollup(60.0, retain=8)
    roll.add_grid("idle", _grid(np.zeros((2, 6))), chips=4)
    fr = from_rollup(roll, healthy_ofu=0.4)
    assert fr.chip_hours > 0
    assert fr.weighted_ofu == 0.0
    # a fully idle job is 100% recoverable waste
    (jid, waste) = fr.waste_ranking[0]
    assert jid == "idle" and waste == pytest.approx(fr.chip_hours)


def test_from_rollup_validates_healthy_ofu():
    roll = StreamingRollup(60.0)
    for bad in (0.0, -1.0, float("nan"), float("inf")):
        with pytest.raises(ValueError, match="healthy_ofu"):
            from_rollup(roll, healthy_ofu=bad)


def test_batch_rollup_empty_fleet():
    fr = rollup([])
    assert isinstance(fr, FleetRollup)
    assert fr.chip_hours == 0.0 and fr.weighted_ofu == 0.0


def test_goodput_from_rollup_is_the_package_alias():
    assert goodput_from_rollup is from_rollup
    import repro.fleet as fleet
    assert fleet.goodput_from_rollup is from_rollup


# ---------------------------------------------------------------------------
# scan_goodput: the fleet-wide drop detector
# ---------------------------------------------------------------------------
def _fleet_roll(levels, per_bucket=4, interval=60.0, bucket_s=240.0):
    """One job whose per-bucket OFU follows `levels` (clock at f_max so
    OFU == tpa)."""
    roll = WindowedRollup(bucket_s, retain=len(levels))
    tpa = np.repeat(np.asarray(levels, float),
                    per_bucket)[None, :]
    roll.add_grid("j", _grid(tpa, interval=interval))
    return roll


def test_scan_goodput_detects_a_sustained_drop():
    roll = _fleet_roll([0.5] * 8 + [0.2] * 4)
    (ev,) = scan_goodput(roll, drop_threshold=0.25, window=4,
                         min_duration=2)
    # detector convention: start = trigger - min_duration + 1, and the
    # reported low averages the sustain window (first point straddles)
    assert ev.start_idx in (7, 8) and ev.end_idx is None
    assert ev.drop_frac == pytest.approx(0.55, abs=0.1)
    assert ev.ref_ofu == pytest.approx(0.5, abs=0.02)
    assert 0.15 < ev.low_ofu < 0.3


def test_scan_goodput_recovered_drop_has_end():
    roll = _fleet_roll([0.5] * 6 + [0.1] * 3 + [0.5] * 3)
    (ev,) = scan_goodput(roll, drop_threshold=0.25, window=4,
                         min_duration=2)
    assert ev.start_idx in (5, 6) and ev.end_idx is not None


def test_scan_goodput_silent_on_healthy_and_empty():
    assert scan_goodput(_fleet_roll([0.5] * 12)) == []
    # a drop smaller than the threshold stays silent too
    assert scan_goodput(_fleet_roll([0.5] * 8 + [0.45] * 4),
                        drop_threshold=0.25) == []
    assert scan_goodput(WindowedRollup(240.0, retain=8)) == []


def test_scan_goodput_validates_threshold():
    roll = _fleet_roll([0.5] * 8)
    for bad in (0.0, 1.0, -0.5, 2.0):
        with pytest.raises(ValueError, match="drop_threshold"):
            scan_goodput(roll, drop_threshold=bad)


def test_fleet_ofu_forward_fills_gap_buckets():
    roll = WindowedRollup(60.0, retain=12)
    # two grids with a 3-bucket silence between them
    roll.add_grid("j", _grid(np.full((1, 4), 0.5), interval=60.0, t0=0.0))
    roll.add_grid("j", _grid(np.full((1, 2), 0.3), interval=60.0,
                             t0=7 * 60.0))
    filled = roll.fleet_ofu()
    assert not np.isnan(filled).any()
    np.testing.assert_allclose(filled[4:7], 0.5)      # held, not NaN
    raw = roll.fleet_ofu(fill=False)
    assert np.isnan(raw[4:7]).all()
