"""Ingest tier end-to-end: sharded aggregator, POST /v1/ingest, client
backoff — delta blobs from many hosts must reduce to exactly the state
single-process ingestion would have built, under duplicates, gaps,
backpressure, and stalled sockets."""
import socket
import threading
import time

import numpy as np
import pytest

from repro.fleet.streaming import StreamingRollup
from repro.serve import (Backpressure, FleetAPIError, FleetAPIServer,
                         FleetClient, FleetStore, IngestAggregator,
                         IngestClient, SnapshotGap, backoff_delays)

BINS, BUCKET_S = 32, 300.0


def _mk_host(seed, rounds=2, jobs=2):
    """A host rollup plus the list of (job, hist, sums, b0, group)
    observations that built it (to replay into a reference)."""
    rng = np.random.default_rng(seed)
    roll = StreamingRollup(BUCKET_S, bins=BINS)
    obs = []
    for r in range(rounds):
        for j in range(jobs):
            hist = rng.poisson(2.0, (2, BINS)).astype(float)
            sums = hist.sum(axis=1) * rng.uniform(0.2, 0.6)
            rec = (f"job-{j}", hist, sums, 2 * r,
                   "bf16" if j % 2 else "fp8")
            roll.observe_hist(rec[0], rec[1], rec[2], b0=rec[3],
                              group=rec[4], weight=8)
            obs.append(rec)
    return roll, obs


def _reference(all_obs):
    ref = StreamingRollup(BUCKET_S, bins=BINS)
    for job, hist, sums, b0, group in all_obs:
        ref.observe_hist(job, hist, sums, b0=b0, group=group, weight=8)
    return ref


def _assert_matches(fleet, ref):
    """Bucketwise equality, padding short scope arrays with the zero
    rows they implicitly hold (reduction grows every scope to the
    global bucket count; per-scope ingest only grows on touch)."""
    assert set(fleet._hists) == set(ref._hists)

    def grow(x, rows):
        out = np.zeros((rows,) + x.shape[1:])
        out[:x.shape[0]] = x
        return out

    for scope in ref._hists:
        n = max(fleet._hists[scope].shape[0], ref._hists[scope].shape[0])
        np.testing.assert_allclose(grow(fleet._hists[scope], n),
                                   grow(ref._hists[scope], n),
                                   rtol=1e-9, atol=1e-12,
                                   err_msg=f"scope {scope}")
        np.testing.assert_allclose(grow(fleet._sums[scope], n),
                                   grow(ref._sums[scope], n),
                                   rtol=1e-9, atol=1e-12)


# -- aggregator (no HTTP) -------------------------------------------------
def test_aggregator_totals_match_single_process():
    agg = IngestAggregator(n_shards=4)
    all_obs = []
    for h in range(12):
        roll, obs = _mk_host(h)
        all_obs += obs
        agg.submit(f"host-{h}", roll.to_bytes_v2())
    _assert_matches(agg.fleet_rollup(), _reference(all_obs))
    assert agg.hosts == 12


def test_aggregator_delta_rounds_and_duplicates():
    agg = IngestAggregator(n_shards=2)
    roll = StreamingRollup(BUCKET_S, bins=BINS)
    rng = np.random.default_rng(0)
    acked = 0
    blobs = []
    for r in range(3):
        hist = rng.poisson(2.0, (2, BINS)).astype(float)
        roll.observe_hist("job-0", hist, hist.sum(axis=1), b0=2 * r)
        blob = roll.delta_bytes(acked)
        out = agg.submit("h", blob)
        assert out["applied"] is True
        acked = out["acked"]
        blobs.append(blob)
    # redeliver every round's blob: all duplicates, state unchanged
    mirror = agg._shards[agg.shard_of("h")].mirrors["h"]
    frozen = {s: mirror._hists[s].copy() for s in mirror._hists}
    for blob in blobs:
        assert agg.submit("h", blob)["applied"] is False
    for s, h in frozen.items():
        np.testing.assert_array_equal(mirror._hists[s], h)
    _assert_matches(agg.fleet_rollup(), roll)
    assert agg.stats()["duplicates"] == 3


def test_aggregator_gap_then_full_resync():
    agg = IngestAggregator(n_shards=1)
    roll, _ = _mk_host(1, rounds=1)
    cut = roll.generation
    agg.submit("h", roll.to_bytes_v2())
    # aggregator loses the mirror (restart); host keeps advancing
    agg._shards[0].mirrors.clear()
    roll.observe_hist("job-0", np.ones((1, BINS)), np.ones(1), b0=4)
    with pytest.raises(SnapshotGap) as ei:
        agg.submit("h", roll.delta_bytes(cut))
    assert ei.value.acked == 0
    assert agg.stats()["gaps"] == 1
    # re-encode from the acked cursor -> applies, state is exact
    out = agg.submit("h", roll.delta_bytes(ei.value.acked))
    assert out["applied"] is True
    _assert_matches(agg.fleet_rollup(), roll)


def test_backpressure_when_shard_is_saturated():
    agg = IngestAggregator(n_shards=1, max_queue=3, retry_after_s=0.07)
    roll, _ = _mk_host(2)
    blob = roll.to_bytes_v2()
    shard = agg._shards[0]
    done = []
    with shard.lock:                   # stall applies; submits pile up
        threads = [threading.Thread(
            target=lambda i=i: done.append(agg.submit(f"h{i}", blob)),
            daemon=True) for i in range(3)]
        for t in threads:
            t.start()
        deadline = time.time() + 10
        while shard.inflight < 3:
            assert time.time() < deadline, "submits never queued"
            time.sleep(0.002)
        with pytest.raises(Backpressure) as ei:
            agg.submit("h-overflow", blob)
        assert ei.value.retry_after_s == 0.07
        assert agg.stats()["rejected"] == 1
    for t in threads:
        t.join(timeout=10)
    assert len(done) == 3              # the queued ones all landed
    assert agg.hosts == 3


def test_publish_feeds_the_read_path():
    agg = IngestAggregator(n_shards=2)
    all_obs = []
    for h in range(4):
        roll, obs = _mk_host(h)
        all_obs += obs
        agg.submit(f"host-{h}", roll.to_bytes_v2())
    store = FleetStore()
    agg.publish(store, clock_s=12.5)
    series = store.fleet_series()
    assert series["t_s"], "published fleet series is empty"
    ref = _reference(all_obs).fleet_stats(qs=())
    np.testing.assert_allclose(series["weight"], ref.weight)


# -- HTTP layer -----------------------------------------------------------
@pytest.fixture
def served():
    agg = IngestAggregator(n_shards=2, max_queue=8, retry_after_s=0.01)
    store = FleetStore()
    with FleetAPIServer(store, aggregator=agg) as server:
        yield server, agg, store


def test_http_ingest_end_to_end(served):
    server, agg, store = served
    all_obs, pushers = [], []
    for h in range(6):
        roll, obs = _mk_host(h, rounds=1)
        all_obs += obs
        pusher = IngestClient(server.url, f"host-{h}", roll,
                              timeout_s=10.0)
        out = pusher.push()
        assert out["applied"] is True and out["acked"] == roll.generation
        pushers.append((pusher, roll))
    # second round of deltas through the same cursors
    rng = np.random.default_rng(99)
    for pusher, roll in pushers:
        hist = rng.poisson(2.0, (1, BINS)).astype(float)
        rec = ("job-0", hist, hist.sum(axis=1), 5, "bf16")
        roll.observe_hist(rec[0], rec[1], rec[2], b0=rec[3],
                          group=rec[4], weight=8)
        all_obs.append(rec)
        assert pusher.push()["applied"] is True
    _assert_matches(agg.fleet_rollup(), _reference(all_obs))
    # counters endpoint agrees
    stats = FleetClient(server.url)._get("/v1/ingest")
    assert stats["hosts"] == 6 and stats["applied"] == 12


def test_http_duplicate_push_is_noop(served):
    server, agg, _ = served
    roll, _ = _mk_host(0, rounds=1)
    pusher = IngestClient(server.url, "h", roll, timeout_s=10.0)
    pusher.push()
    acked = pusher.acked
    pusher.acked = 0                   # stale cursor: full redelivery
    out = pusher.push()
    assert out["applied"] is False and pusher.acked == acked
    assert agg.stats()["duplicates"] == 1


def test_http_gap_recovery_is_transparent(served):
    server, agg, _ = served
    roll, _ = _mk_host(3, rounds=1)
    pusher = IngestClient(server.url, "h", roll, timeout_s=10.0)
    pusher.push()
    agg._shards[agg.shard_of("h")].mirrors.clear()     # server restart
    roll.observe_hist("job-0", np.ones((1, BINS)), np.ones(1), b0=4)
    out = pusher.push()                # 409 -> resync -> success
    assert out["applied"] is True
    _assert_matches(agg.fleet_rollup(), roll)
    assert agg.stats()["gaps"] == 1


def test_http_backpressure_429_retry_after(served):
    server, agg, _ = served
    roll, _ = _mk_host(4, rounds=1)
    sid = agg.shard_of("h")
    shard = agg._shards[sid]
    shard.inflight = agg.max_queue     # saturate without real traffic
    slept = []

    def unblock(delay):
        slept.append(delay)
        shard.inflight = 0             # pressure clears while we wait

    pusher = IngestClient(server.url, "h", roll, timeout_s=10.0,
                          retries=3, backoff_s=0.05, sleep=unblock)
    out = pusher.push()
    assert out["applied"] is True
    assert pusher.backpressure_hits == 1
    # the wait honoured the server's Retry-After (0.01) or the local
    # backoff step (0.05), whichever is larger
    assert slept == [0.05]
    assert agg.stats()["rejected"] == 1


def test_http_backpressure_gives_up_after_retries(served):
    server, agg, _ = served
    roll, _ = _mk_host(5, rounds=1)
    shard = agg._shards[agg.shard_of("h")]
    shard.inflight = agg.max_queue     # and never clears
    slept = []
    pusher = IngestClient(server.url, "h", roll, timeout_s=10.0,
                          retries=2, backoff_s=0.05, sleep=slept.append)
    with pytest.raises(FleetAPIError) as ei:
        pusher.push()
    assert ei.value.status == 429
    assert slept == [0.05, 0.1]        # capped exponential schedule
    shard.inflight = 0


def test_http_post_without_host_header_is_400(served):
    server, _, _ = served
    import urllib.error
    import urllib.request
    req = urllib.request.Request(server.url + "/v1/ingest", data=b"x",
                                 method="POST")
    with pytest.raises(urllib.error.HTTPError) as ei:
        urllib.request.urlopen(req, timeout=10)
    assert ei.value.code == 400


def test_http_post_corrupt_blob_is_400(served):
    server, _, _ = served
    import urllib.error
    import urllib.request
    req = urllib.request.Request(
        server.url + "/v1/ingest", data=b"not a v2 blob at all",
        method="POST", headers={"X-Fleet-Host": "h"})
    with pytest.raises(urllib.error.HTTPError) as ei:
        urllib.request.urlopen(req, timeout=10)
    assert ei.value.code == 400


def test_ingest_404_without_aggregator():
    store = FleetStore()
    with FleetAPIServer(store) as server:        # read-only deployment
        import urllib.error
        import urllib.request
        req = urllib.request.Request(
            server.url + "/v1/ingest", data=b"x", method="POST",
            headers={"X-Fleet-Host": "h"})
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(req, timeout=10)
        assert ei.value.code == 404


# -- backoff + stalled sockets (satellite: client timeout regression) -----
def test_backoff_delays_schedule():
    assert list(backoff_delays(5, base_s=0.05, cap_s=0.4)) == \
        [0.05, 0.1, 0.2, 0.4, 0.4]
    assert list(backoff_delays(0)) == []
    with pytest.raises(ValueError):
        list(backoff_delays(-1))
    with pytest.raises(ValueError):
        list(backoff_delays(2, base_s=0.0))


@pytest.fixture
def stalled_server():
    """A socket that accepts connections and then says NOTHING — the
    pathological peer a missing socket timeout would hang on forever."""
    srv = socket.socket()
    srv.bind(("127.0.0.1", 0))
    srv.listen(8)
    srv.settimeout(0.1)
    conns = []
    stop = threading.Event()

    def accept_loop():
        while not stop.is_set():
            try:
                conn, _ = srv.accept()
                conns.append(conn)     # hold it open, never respond
            except socket.timeout:
                continue
            except OSError:
                return

    t = threading.Thread(target=accept_loop, daemon=True)
    t.start()
    yield f"http://127.0.0.1:{srv.getsockname()[1]}"
    stop.set()
    t.join(timeout=5)
    for c in conns:
        c.close()
    srv.close()


def test_fleet_client_fails_fast_on_stalled_socket(stalled_server):
    slept = []
    client = FleetClient(stalled_server, timeout_s=0.2, retries=2,
                         backoff_s=0.05, sleep=slept.append)
    t0 = time.perf_counter()
    with pytest.raises(FleetAPIError) as ei:
        client.fleet()
    wall = time.perf_counter() - t0
    assert ei.value.status == 0
    assert slept == [0.05, 0.1]        # both retries took the schedule
    assert client.requests == 3
    # 3 attempts x 0.2 s timeout + scheduling slack — NOT a hang
    assert wall < 5.0


def test_ingest_client_fails_fast_on_stalled_socket(stalled_server):
    roll, _ = _mk_host(6, rounds=1)
    slept = []
    pusher = IngestClient(stalled_server, "h", roll, timeout_s=0.2,
                          retries=1, backoff_s=0.05, sleep=slept.append)
    t0 = time.perf_counter()
    with pytest.raises(FleetAPIError) as ei:
        pusher.push()
    assert ei.value.status == 0
    assert slept == [0.05]
    assert time.perf_counter() - t0 < 5.0
    assert pusher.acked == 0           # nothing was acked
