"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode)."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.tile_quant import TilePolicy
from repro.kernels import ops
from repro.kernels.ref import ref_attention, ref_matmul, ref_ssd_intra
from repro.models.ssm import ssd_chunked

RNG = np.random.default_rng(42)


def _arr(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape) * scale, dtype)


# ---------------------------------------------------------------------------
# GEMM: shape x dtype sweep
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("M,N,K", [
    (128, 128, 128),      # exact single tile
    (256, 512, 384),      # multi-tile aligned
    (300, 150, 200),      # ragged (tile quantization engaged)
    (1, 128, 128),        # degenerate M
    (129, 257, 513),      # off-by-one everywhere
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gemm_matches_ref(M, N, K, dtype):
    x = _arr((M, K), dtype)
    y = _arr((K, N), dtype)
    out, prof = ops.matmul(x, y, policy=TilePolicy(128, 128, 128))
    ref = ref_matmul(x, y)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol * 10, atol=tol)
    assert out.shape == (M, N)
    assert prof.profiled_flops >= prof.theoretical_flops


def test_gemm_int8():
    x = jnp.asarray(RNG.integers(-100, 100, (200, 300)), jnp.int8)
    y = jnp.asarray(RNG.integers(-100, 100, (300, 100)), jnp.int8)
    out, _ = ops.matmul(x, y, policy=TilePolicy(128, 128, 128))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref_matmul(x, y)))


def test_gemm_profile_is_exact_tile_count():
    pol = TilePolicy(128, 128, 128)
    _, prof = ops.matmul(_arr((300, 200)), _arr((200, 150)), policy=pol)
    assert prof.profiled_flops == 2 * 384 * 256 * 256
    assert prof.overhead == pytest.approx(
        (2 * 384 * 256 * 256 - 2 * 300 * 150 * 200) / (2 * 300 * 150 * 200))


# ---------------------------------------------------------------------------
# flash attention: shape sweep incl. GQA + causal
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,Sq,Sk,H,KV,hd,causal", [
    (2, 128, 128, 8, 8, 32, True),     # MHA causal
    (2, 128, 128, 8, 2, 32, True),     # GQA causal
    (1, 64, 128, 4, 4, 16, False),     # cross-shaped, full
    (2, 256, 256, 4, 1, 64, True),     # MQA
])
def test_flash_matches_ref(B, Sq, Sk, H, KV, hd, causal):
    q = _arr((B, Sq, H, hd))
    k = _arr((B, Sk, KV, hd))
    v = _arr((B, Sk, KV, hd))
    out = ops.flash(q, k, v, causal=causal, bq=64, bkv=64)
    ref = ref_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)


def test_flash_bf16():
    q = _arr((2, 128, 4, 32), jnp.bfloat16)
    k = _arr((2, 128, 4, 32), jnp.bfloat16)
    v = _arr((2, 128, 4, 32), jnp.bfloat16)
    out = ops.flash(q, k, v, causal=True, bq=64, bkv=64)
    ref = ref_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=5e-2, atol=5e-2)


# ---------------------------------------------------------------------------
# SSD intra-chunk kernel + full kernel path vs model path
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("BC,Q,nh,hd,ds,hb", [
    (4, 16, 4, 16, 8, 2),
    (2, 32, 8, 8, 16, 4),
    (1, 64, 2, 32, 4, 2),
])
def test_ssd_intra_matches_ref(BC, Q, nh, hd, ds, hb):
    from repro.kernels.ssd_scan import ssd_intra_kernel
    x = _arr((BC, Q, nh, hd), scale=0.5)
    dt = jnp.asarray(RNG.uniform(0.001, 0.1, (BC, Q, nh)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 2.0, (nh,)), jnp.float32)
    dA = dt * A
    dacs = jnp.cumsum(dA, axis=1)
    b = _arr((BC, Q, nh, ds), scale=0.3)
    c = _arr((BC, Q, nh, ds), scale=0.3)
    out = ssd_intra_kernel(x, dt, dacs, b, c, head_block=hb, interpret=True)
    ref = ref_ssd_intra(x, dt, dacs, b, c)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)


def test_ssd_full_kernel_path_matches_model_path():
    B, S, nh, hd, g, ds, Q = 2, 64, 4, 16, 2, 8, 16
    x = _arr((B, S, nh, hd), scale=0.5)
    dt = jnp.asarray(RNG.uniform(0.001, 0.1, (B, S, nh)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 2.0, (nh,)), jnp.float32)
    Bm = _arr((B, S, g, ds), scale=0.3)
    Cm = _arr((B, S, g, ds), scale=0.3)
    yk = ops.ssd(x, dt, A, Bm, Cm, chunk=Q)
    yj = ssd_chunked(x, dt, A, Bm, Cm, Q)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yj),
                               rtol=1e-3, atol=1e-3)
