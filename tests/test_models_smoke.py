"""Per-architecture smoke tests: REDUCED same-family config, one forward +
one train step + one decode step on CPU; asserts shapes + no NaNs.
(The FULL configs are exercised only via the dry-run.)"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_configs, make_inputs
from repro.configs.base import ShapeSpec
from repro.models import decode_step, forward, init_params
from repro.optim import adamw
from repro.train.steps import make_train_step

ARCHS = ["deepseek-moe-16b", "deepseek-v3-671b", "qwen3-4b",
         "nemotron-4-340b", "granite-3-2b", "llama3.2-3b", "whisper-small",
         "phi-3-vision-4.2b", "mamba2-780m", "zamba2-7b"]


def test_all_assigned_archs_registered():
    assert sorted(ARCHS) == list_configs()


_CACHE: dict = {}


def _state(arch):
    if arch not in _CACHE:
        cfg = get_config(arch).smoke()
        _CACHE[arch] = (cfg, init_params(cfg, jax.random.key(0)))
    return _CACHE[arch]


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_smoke(arch):
    cfg, params = _state(arch)
    batch = make_inputs(cfg, ShapeSpec("t", 32, 2, "train"))
    logits = forward(cfg, params, batch)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert not jnp.isnan(logits.astype(jnp.float32)).any()


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_smoke(arch):
    cfg, params = _state(arch)
    batch = make_inputs(cfg, ShapeSpec("d", 16, 2, "decode"))
    logits, caches = decode_step(cfg, params, batch)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert not jnp.isnan(logits.astype(jnp.float32)).any()
    for k, v in caches.items():
        assert not jnp.isnan(v.astype(jnp.float32)).any(), k


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg, params = _state(arch)
    # lr large enough that one update survives bf16 weight quantization
    opt_cfg = adamw.OptConfig(peak_lr=0.05, warmup_steps=1, decay_steps=10)
    opt_state = adamw.init(opt_cfg, params)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    batch = {k: jnp.asarray(v)
             for k, v in make_inputs(cfg, ShapeSpec("t", 32, 2, "train")).items()}
    new_params, new_opt, m = step(params, opt_state, batch)
    assert jnp.isfinite(m["loss"])
    assert float(m["grad_norm"]) > 0
    # params must actually change
    moved = any(
        float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max()) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params))
        if jnp.issubdtype(a.dtype, jnp.floating))  # note: bf16 kind is 'V'
    assert moved


def test_decode_matches_forward_incrementally():
    """Greedy decode over a cached prefix must agree with full forward
    logits at the same position (dense smoke config)."""
    cfg = get_config("granite-3-2b").smoke()
    params = init_params(cfg, jax.random.key(1))
    import numpy as np
    rng = np.random.default_rng(3)
    T = 8
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, T)), jnp.int32)

    from repro.configs.base import cache_specs
    caches = {k: jnp.zeros(v.shape, v.dtype)
              for k, v in cache_specs(cfg, 1, 16, jnp.float32).items()}
    dec_logits = []
    for t in range(T):
        batch = {"tokens": toks[:, t:t + 1],
                 "cache_index": jnp.asarray(t, jnp.int32), **caches}
        lg, caches = decode_step(cfg, params, batch)
        dec_logits.append(np.asarray(lg[:, 0].astype(jnp.float32)))
    full = forward(cfg, params, {"tokens": toks}).astype(jnp.float32)
    full = np.asarray(full)
    for t in range(T):
        np.testing.assert_allclose(dec_logits[t], full[:, t], rtol=2e-2,
                                   atol=2e-2)
