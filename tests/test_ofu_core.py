"""Unit tests for the OFU metric core (paper Eq. 1, 5, 8, 9, 12)."""
import numpy as np
import pytest
from _propcheck import given, settings, st

from repro.core import (TPU_V5E, AccuracyReport, adjusted_ofu, effective_peak,
                        hist_percentile, mae, mfu_from_throughput, ofu_mean,
                        ofu_point, ofu_series, pct_within, pearson_r)


def test_peak_derivation_matches_published():
    # Eq. 5 audit: 4 MXUs x 128x128 x 2 x 1500 MHz = 196.6 TF/s (~197 pub.)
    assert TPU_V5E.peak_tflops("bf16") == pytest.approx(196.608)
    assert TPU_V5E.peak_tflops("int8") == pytest.approx(393.216)
    assert TPU_V5E.peak_tflops("fp32") == pytest.approx(196.608 / 4)


def test_ofu_point_eq1():
    # full duty at full clock = 1.0; clock throttle scales linearly
    assert ofu_point(1.0, TPU_V5E.f_max_mhz) == pytest.approx(1.0)
    assert ofu_point(0.5, TPU_V5E.f_max_mhz * 0.9) == pytest.approx(0.45)


@given(st.floats(0, 1), st.floats(0.5, 1.0))
@settings(max_examples=50, deadline=None)
def test_ofu_bounded(tpa, clock_frac):
    v = ofu_point(tpa, TPU_V5E.f_max_mhz * clock_frac)
    assert 0.0 <= v <= 1.0 + 1e-9


def test_adjusted_ofu_eq8():
    # hardware executed 10% extra FLOPs -> OFU_adj shrinks by that factor
    assert adjusted_ofu(0.55, 100.0, 110.0) == pytest.approx(0.5)
    assert adjusted_ofu(0.55, 100.0, 0.0) == 0.55  # degenerate guard


def test_effective_peak_harmonic_mean_eq12():
    # all bf16 -> bf16 peak; all int8 -> int8 peak
    assert effective_peak({"bf16": 1e12}) == pytest.approx(196.608)
    assert effective_peak({"int8": 1e12}) == pytest.approx(393.216)
    # 50/50 FLOPs split -> harmonic mean
    p = effective_peak({"bf16": 1.0, "int8": 1.0})
    expect = 2 / (1 / 196.608 + 1 / 393.216)
    assert p == pytest.approx(expect)
    # mixed peak sits strictly between the two
    assert 196.608 < p < 393.216


def test_effective_peak_bf16_only_raises_mfu():
    """Paper §VI-B: constant throughput, BF16-only -> lower peak -> higher
    MFU.  The effective-peak denominator must reproduce that."""
    tflops_per_chip = 80.0
    p_mixed = effective_peak({"bf16": 0.4, "fp8": 0.6})
    p_bf16 = effective_peak({"bf16": 1.0})
    assert mfu_from_throughput(tflops_per_chip, p_bf16) > \
        mfu_from_throughput(tflops_per_chip, p_mixed)


# ---------------------------------------------------------------------------
# property-based hardening of the metric core
# ---------------------------------------------------------------------------
_PRECS = ["bf16", "int8", "fp8", "fp32"]


@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 64))
@settings(max_examples=25, deadline=None)
def test_ofu_series_matches_pointwise(seed, n):
    """Eq. 11 must be exactly the element-wise map of Eq. 1."""
    rng = np.random.default_rng(seed)
    tpa = rng.uniform(0, 1, n)
    clk = rng.uniform(0.6, 1.0, n) * TPU_V5E.f_max_mhz
    series = ofu_series(tpa, clk)
    assert series.shape == (n,)
    for i in range(n):
        assert series[i] == pytest.approx(ofu_point(tpa[i], clk[i]))
    assert ofu_mean(tpa, clk) == pytest.approx(float(series.mean()))


@given(st.lists(st.tuples(st.sampled_from(_PRECS),
                          st.floats(1e6, 1e15)),
                min_size=1, max_size=4))
@settings(max_examples=50, deadline=None)
def test_effective_peak_bounded_by_component_peaks(mix):
    """Eq. 12: the harmonic mean can never leave [min, max] of the
    per-precision peaks present in the mix."""
    flops = {}
    for p, f in mix:
        flops[p] = flops.get(p, 0.0) + f
    peaks = [TPU_V5E.peak_tflops(p) for p in flops]
    eff = effective_peak(flops, TPU_V5E)
    assert min(peaks) - 1e-9 <= eff <= max(peaks) + 1e-9


@given(st.floats(0.01, 1.0), st.floats(1.0, 1e12),
       st.floats(1.0, 2.0), st.floats(1.0, 2.0))
@settings(max_examples=50, deadline=None)
def test_adjusted_ofu_monotonicity(ofu, th, k_prof, k_th):
    """Eq. 8: OFU_adj grows with theoretical FLOPs, shrinks as the
    hardware executes more padding, and never exceeds raw OFU when
    profiled >= theoretical (padding can only inflate the raw metric)."""
    prof = th * k_prof                     # profiled >= theoretical
    base = adjusted_ofu(ofu, th, prof)
    assert base <= ofu + 1e-12
    assert adjusted_ofu(ofu, th * k_th, prof) >= base - 1e-12
    assert adjusted_ofu(ofu, th, prof * k_th) <= base + 1e-12


@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 100))
@settings(max_examples=50, deadline=None)
def test_pearson_r_bounded(seed, n):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=n) * rng.uniform(0.1, 100)
    b = rng.normal(size=n) * rng.uniform(0.1, 100)
    assert -1.0 - 1e-9 <= pearson_r(a, b) <= 1.0 + 1e-9
    # degenerate series: zero variance must not divide by zero
    assert pearson_r(np.full(n, 3.0), b) == 0.0
    # perfect (anti-)correlation hits the bounds
    assert pearson_r(a, 2 * a + 1) == pytest.approx(1.0)
    assert pearson_r(a, -3 * a) == pytest.approx(-1.0)


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=25, deadline=None)
def test_hist_percentile_matches_exact_on_fine_bins(seed):
    """The streaming-rollup readout must agree with np.percentile up to
    one bin width."""
    rng = np.random.default_rng(seed)
    vals = rng.uniform(0, 1, 500)
    edges = np.linspace(0, 1.1, 129)
    counts, _ = np.histogram(vals, edges)
    for q in (10, 50, 90):
        est = hist_percentile(edges, counts, q)
        assert abs(est - np.percentile(vals, q)) <= 1.1 / 128 + 1e-9
    assert np.isnan(hist_percentile(edges, np.zeros(128), 50))


def test_accuracy_stats():
    est = [10.0, 12.0, 20.0]
    tru = [11.0, 12.0, 15.0]
    assert mae(est, tru) == pytest.approx(2.0)
    assert pct_within(est, tru, 2.0) == pytest.approx(2 / 3)
    r = pearson_r([1, 2, 3, 4], [2, 4, 6, 8])
    assert r == pytest.approx(1.0)
    rep = AccuracyReport.build("ofu", est, tru)
    assert rep.within_5pp == 1.0
