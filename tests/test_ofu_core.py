"""Unit tests for the OFU metric core (paper Eq. 1, 5, 8, 9, 12)."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (TPU_V5E, AccuracyReport, adjusted_ofu, effective_peak,
                        mae, mfu_from_throughput, ofu_mean, ofu_point,
                        pct_within, pearson_r)


def test_peak_derivation_matches_published():
    # Eq. 5 audit: 4 MXUs x 128x128 x 2 x 1500 MHz = 196.6 TF/s (~197 pub.)
    assert TPU_V5E.peak_tflops("bf16") == pytest.approx(196.608)
    assert TPU_V5E.peak_tflops("int8") == pytest.approx(393.216)
    assert TPU_V5E.peak_tflops("fp32") == pytest.approx(196.608 / 4)


def test_ofu_point_eq1():
    # full duty at full clock = 1.0; clock throttle scales linearly
    assert ofu_point(1.0, TPU_V5E.f_max_mhz) == pytest.approx(1.0)
    assert ofu_point(0.5, TPU_V5E.f_max_mhz * 0.9) == pytest.approx(0.45)


@given(st.floats(0, 1), st.floats(0.5, 1.0))
@settings(max_examples=50, deadline=None)
def test_ofu_bounded(tpa, clock_frac):
    v = ofu_point(tpa, TPU_V5E.f_max_mhz * clock_frac)
    assert 0.0 <= v <= 1.0 + 1e-9


def test_adjusted_ofu_eq8():
    # hardware executed 10% extra FLOPs -> OFU_adj shrinks by that factor
    assert adjusted_ofu(0.55, 100.0, 110.0) == pytest.approx(0.5)
    assert adjusted_ofu(0.55, 100.0, 0.0) == 0.55  # degenerate guard


def test_effective_peak_harmonic_mean_eq12():
    # all bf16 -> bf16 peak; all int8 -> int8 peak
    assert effective_peak({"bf16": 1e12}) == pytest.approx(196.608)
    assert effective_peak({"int8": 1e12}) == pytest.approx(393.216)
    # 50/50 FLOPs split -> harmonic mean
    p = effective_peak({"bf16": 1.0, "int8": 1.0})
    expect = 2 / (1 / 196.608 + 1 / 393.216)
    assert p == pytest.approx(expect)
    # mixed peak sits strictly between the two
    assert 196.608 < p < 393.216


def test_effective_peak_bf16_only_raises_mfu():
    """Paper §VI-B: constant throughput, BF16-only -> lower peak -> higher
    MFU.  The effective-peak denominator must reproduce that."""
    tflops_per_chip = 80.0
    p_mixed = effective_peak({"bf16": 0.4, "fp8": 0.6})
    p_bf16 = effective_peak({"bf16": 1.0})
    assert mfu_from_throughput(tflops_per_chip, p_bf16) > \
        mfu_from_throughput(tflops_per_chip, p_mixed)


def test_accuracy_stats():
    est = [10.0, 12.0, 20.0]
    tru = [11.0, 12.0, 15.0]
    assert mae(est, tru) == pytest.approx(2.0)
    assert pct_within(est, tru, 2.0) == pytest.approx(2 / 3)
    r = pearson_r([1, 2, 3, 4], [2, 4, 6, 8])
    assert r == pytest.approx(1.0)
    rep = AccuracyReport.build("ofu", est, tru)
    assert rep.within_5pp == 1.0
