"""`fleet.recovery` wake-up (ISSUE 8 satellite): the observe() policy
(absolute floor, sustained relative regression, cooldown, callback) and
the new `consume_alerts` collector integration — each collector alert
episode maps to AT MOST one recovery action, idempotently.
"""
import numpy as np
import pytest

from repro.fleet.collector import Alert
from repro.fleet.recovery import RecoveryService, StragglerMonitor
from repro.scenarios import build, run_scenario


# ---------------------------------------------------------------------------
# observe(): the service's own sustained-collapse policy
# ---------------------------------------------------------------------------
def _feed(svc, job, values):
    return [svc.observe(job, v) for v in values]


def test_observe_fires_on_absolute_floor():
    svc = RecoveryService(abs_floor=0.02, sustain_samples=3)
    out = _feed(svc, "j", [0.4] * 6 + [0.01] * 3)
    fired = [a for a in out if a is not None]
    assert len(fired) == 1
    assert fired[0].reason == "ofu_below_floor"
    assert fired[0].factor == float("inf")


def test_observe_fires_on_sustained_regression_not_blips():
    svc = RecoveryService(factor_threshold=2.0, sustain_samples=3,
                          cooldown_samples=100)
    # a single-sample dip is not sustained
    out = _feed(svc, "j", [0.4] * 8 + [0.1] + [0.4] * 4)
    assert all(a is None for a in out)
    # a sustained 4x collapse is
    out = _feed(svc, "k", [0.4] * 8 + [0.1] * 5)
    fired = [a for a in out if a is not None]
    assert len(fired) == 1
    assert fired[0].reason == "sustained_regression"
    assert fired[0].factor == pytest.approx(4.0, rel=0.25)


def test_observe_cooldown_then_rearm():
    svc = RecoveryService(abs_floor=0.05, sustain_samples=2,
                          cooldown_samples=6)
    out = _feed(svc, "j", [0.4] * 4 + [0.01] * 12)
    idx = [i for i, a in enumerate(out) if a is not None]
    assert len(idx) >= 2                       # re-fires after cooldown
    assert idx[1] - idx[0] >= 6                # but never inside it


def test_observe_callback_fires_exactly_once_per_action():
    calls = []
    svc = RecoveryService(abs_floor=0.05, sustain_samples=2,
                          cooldown_samples=10 ** 6,
                          on_recover=calls.append)
    _feed(svc, "j", [0.4] * 4 + [0.01] * 10)
    assert len(calls) == 1
    assert calls[0] is svc.actions[0]


# ---------------------------------------------------------------------------
# consume_alerts(): downstream of the collector's deduper
# ---------------------------------------------------------------------------
def _alert(job="j", factor=2.5, kind="regression", round_idx=3,
           t_s=900.0, msg="2.50x OFU collapse"):
    return Alert(round_idx, t_s, job, kind, msg, factor=factor)


def test_consume_alerts_is_idempotent_under_refeed():
    svc = RecoveryService()
    log = [_alert()]
    assert len(svc.consume_alerts(log)) == 1
    # re-feeding the append-only log (as a per-round driver would) is a
    # no-op; a NEW alert in the grown log still fires
    log.append(_alert(round_idx=7, t_s=2100.0))
    again = svc.consume_alerts(log)
    assert len(again) == 1 and again[0].at_sample == 7
    assert len(svc.actions) == 2


def test_consume_alerts_filters_kind_and_factor():
    svc = RecoveryService(min_alert_factor=2.0)
    actions = svc.consume_alerts([
        _alert(kind="divergence"),             # not a regression
        _alert(job="wobble", factor=1.6),      # below min_alert_factor
        _alert(job="nanjob", factor=float("nan")),
        _alert(job="dead", factor=3.0),
    ])
    assert [a.job_id for a in actions] == ["dead"]
    assert actions[0].reason == "collector_regression"


def test_consume_alerts_fires_callback_once_per_episode():
    calls = []
    svc = RecoveryService(on_recover=calls.append)
    log = [_alert()]
    svc.consume_alerts(log)
    svc.consume_alerts(log)
    svc.consume_alerts(log)
    assert len(calls) == 1


def test_recovery_closes_the_loop_on_the_paper_scenario():
    """End-to-end: replay the 2.5x regression scenario through the live
    collector, feed its alert log to the recovery service — exactly one
    restart of exactly the faulted job, idempotent per round."""
    sc = build("gloo_regression_2p5x")
    run = run_scenario(sc)
    restarts = []
    svc = RecoveryService(min_alert_factor=2.0,
                          on_recover=lambda a: restarts.append(a.job_id))
    for _ in range(3):                         # one call per "round"
        svc.consume_alerts(run.alerts)
    assert restarts == ["allreduce-7b"]
    assert svc.actions[0].factor == pytest.approx(2.5, rel=0.2)


def test_straggler_monitor_flags_the_outlier():
    rng = np.random.default_rng(0)
    tpa = 0.42 + 0.01 * rng.standard_normal(16)   # healthy spread
    tpa[11] = 0.02
    assert StragglerMonitor().flag(tpa) == [11]
