"""Repository hygiene: bytecode caches must never be tracked.

PR 3 purged a committed `__pycache__/`; this is the regression guard
(the same check runs as a dedicated CI step, so a reintroduction fails
the build even if the test suite is skipped).  Runs against `git
ls-files` — the INDEX, not the working tree — because on-disk caches
are normal runtime artifacts that `.gitignore` already hides.
"""
import os
import subprocess

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _tracked_files():
    try:
        out = subprocess.run(["git", "ls-files"], cwd=REPO,
                             capture_output=True, text=True, timeout=30)
    except (OSError, subprocess.TimeoutExpired):
        pytest.skip("git unavailable")
    if out.returncode != 0:
        pytest.skip("not a git checkout")
    return out.stdout.splitlines()


def test_no_bytecode_tracked():
    offenders = [f for f in _tracked_files()
                 if "__pycache__" in f.split(os.sep)
                 or "__pycache__" in f.split("/")
                 or f.endswith((".pyc", ".pyo"))]
    assert not offenders, (
        f"bytecode artifacts are tracked: {offenders[:10]} — "
        "git rm -r --cached them; .gitignore already excludes them")


def test_gitignore_excludes_bytecode():
    with open(os.path.join(REPO, ".gitignore")) as fh:
        patterns = [ln.strip() for ln in fh if ln.strip()
                    and not ln.startswith("#")]
    assert "__pycache__/" in patterns
    assert any(p in ("*.py[cod]", "*.pyc") for p in patterns)
